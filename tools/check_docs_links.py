#!/usr/bin/env python3
"""Intra-repo link checker for the docs tree.

Scans README.md and docs/*.md for inline markdown links/images and verifies
that every relative target resolves to a real file or directory in the
repo (fragments are stripped; http(s)/mailto targets are ignored).  CI runs
this in the ``docs`` job so a moved/renamed file cannot silently orphan the
documentation.

    python tools/check_docs_links.py            # check, exit 1 on breakage
    python tools/check_docs_links.py --list     # also print every link
"""
from __future__ import annotations

import argparse
import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent

# inline links/images: [text](target) / ![alt](target); stops at the first
# ')' so "[a](b) and [c](d)" yields two links.  Markdown autolinks and bare
# URLs are out of scope — the docs use inline style throughout.
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_EXTERNAL = ("http://", "https://", "mailto:")


def doc_files() -> list[pathlib.Path]:
    files = [REPO / "README.md"]
    files += sorted((REPO / "docs").glob("*.md")) if (REPO / "docs").is_dir() else []
    return [f for f in files if f.is_file()]


def check(list_all: bool = False) -> int:
    broken: list[str] = []
    n_links = 0
    for md in doc_files():
        rel_md = md.relative_to(REPO)
        for lineno, line in enumerate(md.read_text().splitlines(), 1):
            for m in _LINK.finditer(line):
                target = m.group(1)
                if target.startswith(_EXTERNAL) or target.startswith("#"):
                    continue
                n_links += 1
                path = target.split("#", 1)[0]
                resolved = (md.parent / path).resolve()
                ok = resolved.exists()
                if list_all or not ok:
                    print(f"{'ok ' if ok else 'BROKEN'} {rel_md}:{lineno}: {target}")
                if not ok:
                    broken.append(f"{rel_md}:{lineno}: {target}")
    print(f"checked {n_links} intra-repo links across {len(doc_files())} files")
    if broken:
        print(f"{len(broken)} broken link(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--list", action="store_true", help="print every link checked")
    sys.exit(check(list_all=ap.parse_args().list))
