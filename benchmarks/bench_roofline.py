"""Roofline table assembly: reads artifacts/dryrun/*.json (produced by
``python -m repro.launch.dryrun``) and renders the EXPERIMENTS.md §Roofline
table plus the compressed-exchange comparison."""
from __future__ import annotations

import json
from pathlib import Path

from .datasets import save_result

DRYRUN = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"


def collect(mesh: str = "16x16") -> dict:
    rows = {}
    for p in sorted(DRYRUN.glob(f"*__{mesh}.json")):
        d = json.loads(p.read_text())
        if d.get("compressed"):
            continue
        key = f"{d['arch']}|{d['shape']}"
        r = d["roofline"]
        rows[key] = {
            "arch": d["arch"],
            "shape": d["shape"],
            "kind": d["kind"],
            "compute_s": r["compute_s"],
            "memory_s": r["memory_s"],
            "collective_s": r["collective_s"],
            "dominant": r["dominant"],
            "useful_flops_ratio": r["useful_flops_ratio"],
            "model_flops_total": r["model_flops_total"],
            "flops_per_device": d["cost"]["flops_per_device"],
            "bytes_per_device": d["cost"]["bytes_per_device"],
            "collective_bytes": d["collectives"]["total_bytes"],
            "arg_bytes": (d.get("memory") or {}).get("argument_bytes"),
            "compile_s": d["seconds"]["compile"],
        }
    return rows


def collect_exchange() -> dict:
    out = {}
    for p in sorted(DRYRUN.glob("*__comp.json")):
        d = json.loads(p.read_text())
        if "exchange" not in d:
            continue
        out[d["arch"]] = {
            "compressed_bytes": d["exchange"]["compressed"]["collective_bytes"],
            "plain_bytes": d["exchange"]["plain_psum"]["collective_bytes"],
            "wire_reduction": d["exchange"]["plain_psum"]["collective_bytes"]
            / max(d["exchange"]["compressed"]["collective_bytes"], 1),
            "analytic": d.get("analytic_wire"),
        }
    return out


def render_table(rows: dict) -> str:
    hdr = (
        f"| {'arch':26s} | {'shape':11s} | {'compute s':>10s} | {'memory s':>10s} "
        f"| {'collect s':>10s} | {'dominant':>10s} | {'useful':>6s} |"
    )
    sep = "|" + "-" * 28 + "|" + "-" * 13 + "|" + "-" * 12 + "|" + "-" * 12 + "|" + "-" * 12 + "|" + "-" * 12 + "|" + "-" * 8 + "|"
    lines = [hdr, sep]
    for key in sorted(rows):
        r = rows[key]
        u = f"{r['useful_flops_ratio']:.3f}" if r["useful_flops_ratio"] else "-"
        lines.append(
            f"| {r['arch']:26s} | {r['shape']:11s} | {r['compute_s']:10.3e} | {r['memory_s']:10.3e} "
            f"| {r['collective_s']:10.3e} | {r['dominant']:>10s} | {u:>6s} |"
        )
    return "\n".join(lines)


def run() -> dict:
    single = collect("16x16")
    multi = collect("2x16x16")
    exchange = collect_exchange()
    payload = {"single_pod": single, "multi_pod": multi, "exchange": exchange}
    save_result("roofline", payload)
    print(f"single-pod cells: {len(single)}   multi-pod cells: {len(multi)}")
    print(render_table(single))
    if exchange:
        print("\ncross-pod exchange (per-device bytes):")
        for arch, e in exchange.items():
            print(
                f"  {arch:28s} plain {e['plain_bytes']/1e6:8.2f}MB -> compressed "
                f"{e['compressed_bytes']/1e6:8.2f}MB  ({e['wire_reduction']:.2f}x)"
            )
    return payload
