"""Progressive residual pyramid benchmarks.

``pyramid_vs_independent``: archive bytes of ONE layered 4-tier archive
({1e-1, 1e-2, 1e-3, lossless} of range) against the pre-pyramid layout —
the same tiers encoded as independent streams from the base (measured by
compressing each tier alone and summing the residual sections; the base is
shared in both layouts and excluded from the ratio).  The refinement
layers store only the delta below the previous tier's guarantee, so the
pyramid must be strictly smaller — asserted as claim
``C_pyramid_smaller``.

``tiered_decode``: decode MB/s at each tier through the layer-prefix
decoder (``decompress_at`` resolving the cheapest sufficient prefix), plus
the progressive-refinement rate: refining a coarse reconstruction to
lossless via ``ProgressiveDecoder`` against decoding lossless cold — the
refinement path re-uses the already-decoded coarse layers, so it is the
cheaper way to zoom in.

``progressive_json`` bundles both for the BENCH_throughput.json
trajectory.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (
    BYTES_PER_ROW,
    ProgressiveDecoder,
    ShrinkCodec,
    decompress_at,
)

from .datasets import bench_series, save_result


def _best_of(fn, reps: int = 3) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


_TIER_RELS = (1e-1, 1e-2, 1e-3)  # + lossless


def _ladder(v: np.ndarray) -> list[float]:
    rng = float(v.max() - v.min())
    return [r * rng for r in _TIER_RELS] + [0.0]


def pyramid_vs_independent(
    n: int = 100_000,
    datasets=("WindSpeed", "Pressure", "ECG"),
) -> dict:
    """Residual bytes: one layered archive vs per-tier independent streams."""
    out = {"tiers": list(_TIER_RELS) + [0.0], "datasets": {}}
    for name in datasets:
        v = bench_series(name, n)
        from repro.data.synthetic import DATASETS

        decimals = DATASETS[name].decimals
        codec = ShrinkCodec.from_fraction(v, frac=0.05, backend="rans")
        tiers = _ladder(v)
        cs = codec.compress(v, eps_targets=tiers, decimals=decimals)
        pyramid_bytes = cs.pyramid.nbytes()
        independent_bytes = sum(
            codec.compress(v, eps_targets=[e], decimals=decimals).pyramid.nbytes()
            for e in tiers
        )
        out["datasets"][name] = {
            "n": int(len(v)),
            "base_bytes": len(cs.base_bytes),
            "pyramid_residual_bytes": int(pyramid_bytes),
            "independent_residual_bytes": int(independent_bytes),
            "pyramid_vs_independent": pyramid_bytes / max(independent_bytes, 1),
            "per_layer_bytes": [layer.nbytes() for layer in cs.pyramid.layers],
            "archive_bytes": int(cs.total_nbytes()),
        }
    return out


def tiered_decode(n: int = 100_000, name: str = "Pressure", reps: int = 3) -> dict:
    """Decode MB/s per tier + progressive refinement vs cold lossless."""
    v = bench_series(name, n)
    from repro.data.synthetic import DATASETS

    decimals = DATASETS[name].decimals
    codec = ShrinkCodec.from_fraction(v, frac=0.05, backend="rans")
    tiers = _ladder(v)
    cs = codec.compress(v, eps_targets=tiers, decimals=decimals)
    mb = len(v) * BYTES_PER_ROW / 1e6
    out = {"dataset": name, "n": int(len(v)), "decode_mb_s": {}}
    for eps, rel in zip(tiers, list(_TIER_RELS) + ["lossless"]):
        t = _best_of(lambda e=eps: decompress_at(cs, e), reps)
        out["decode_mb_s"][str(rel)] = mb / t

    # progressive refinement: coarse prefix already decoded, pay the delta
    def refine():
        dec = ProgressiveDecoder(cs)
        dec.at(tiers[1])  # the dashboard's standing coarse view
        t0 = time.perf_counter()
        dec.at(0.0)
        return time.perf_counter() - t0

    refine_t = min(refine() for _ in range(reps))
    cold_t = _best_of(lambda: decompress_at(cs, 0.0), reps)
    out["refine_coarse_to_lossless_mb_s"] = mb / refine_t
    out["cold_lossless_mb_s"] = mb / cold_t
    out["refine_vs_cold"] = cold_t / refine_t
    return out


def progressive_json(quick: bool = False) -> dict:
    n = 20_000 if quick else 100_000
    return {
        "archive": pyramid_vs_independent(n=n),
        "decode": tiered_decode(n=n),
    }


def validate_claims(prog: dict) -> dict:
    """C_pyramid_smaller: on every standard-workload dataset the 4-tier
    layered archive's residual section is strictly smaller than the
    independent-stream layout's."""
    ratios = {
        name: round(row["pyramid_vs_independent"], 4)
        for name, row in prog["archive"]["datasets"].items()
    }
    checks = {
        "C_pyramid_smaller": {
            "pyramid_vs_independent_ratio": ratios,
            "pass": bool(all(r < 1.0 for r in ratios.values())),
        }
    }
    save_result("claims_progressive", checks)
    return checks
