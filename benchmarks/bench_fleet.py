"""Fleet benchmarks: sharded serving scaling and multi-tenant admission.

``fleet_json`` drives :func:`repro.launch.serve.run_fleet_sim` — the same
seeded Poisson mixed workload behind ``--mode fleet`` — once on 1 shard
and once on 4 shards over identical traffic, and reports:

* per-request ingest/query latency (p50/p99, wall clock);
* aggregate ingest throughput under the **critical-path model**: on this
  single-CPU container the shards execute sequentially, so the aggregate
  rate a one-worker-per-shard deployment would sustain is
  ``total bytes / max(per-shard busy time)`` — the slowest shard is the
  fleet's critical path (docs/fleet.md documents the model and its
  assumptions honestly; nothing here pretends to be a multi-core wall
  clock);
* the cross-shard differential + shard-kill chaos tallies, which double
  as a zero-silent-corruption gate inside the bench itself.

Claims:

``C_fleet_scaling``      — 1 -> 4 shards grows aggregate critical-path
                           ingest throughput >= 1.5x (hash placement over
                           enough series balances the shards; perfect
                           balance would be 4x).
``C_fleet_no_silent``    — the bench's differential checks find zero
                           silent corruptions and zero cross-shard byte
                           mismatches (sharding is semantically
                           invisible, measured not just unit-tested).
"""
from __future__ import annotations

from repro.launch.serve import run_fleet_sim

from .datasets import save_result


def fleet_json(quick: bool = False) -> dict:
    kw = (
        dict(series=16, ticks=60, queries=96, flush_samples=1024)
        if quick
        else dict(series=48, ticks=240, queries=256, flush_samples=2048)
    )
    base = run_fleet_sim(n_shards=1, check=False, kill=False, **kw)
    sharded = run_fleet_sim(n_shards=4, check=True, kill=True, **kw)
    out = {
        "quick": quick,
        "workload": {
            "series": sharded["series"],
            "samples": sharded["samples"],
            "mb": round(sharded["mb"], 3),
            "quota_rejected_ingest": sharded["ingest"]["rejected_quota"],
        },
        "one_shard": {
            "agg_mb_s": round(base["ingest"]["agg_mb_s"], 2),
            "critical_path_s": round(base["ingest"]["critical_path_s"], 4),
            "ingest_p50_ms": round(base["ingest"]["p50_ms"], 4),
            "ingest_p99_ms": round(base["ingest"]["p99_ms"], 4),
            "query_p50_ms": round(base["query"]["p50_ms"], 4),
            "query_p99_ms": round(base["query"]["p99_ms"], 4),
        },
        "four_shards": {
            "agg_mb_s": round(sharded["ingest"]["agg_mb_s"], 2),
            "critical_path_s": round(sharded["ingest"]["critical_path_s"], 4),
            "busy_s": sharded["ingest"]["busy_s"],
            "ingest_p50_ms": round(sharded["ingest"]["p50_ms"], 4),
            "ingest_p99_ms": round(sharded["ingest"]["p99_ms"], 4),
            "query_p50_ms": round(sharded["query"]["p50_ms"], 4),
            "query_p99_ms": round(sharded["query"]["p99_ms"], 4),
            "queries": {
                k: sharded["query"][k] for k in ("ok", "degraded", "error", "SILENT")
            },
            "shard_kill": sharded["kill"],
            "kb_syncs": sharded["kb"]["syncs"],
        },
        "scaling_1_to_4": round(
            sharded["ingest"]["agg_mb_s"] / base["ingest"]["agg_mb_s"], 3
        ),
        "silent": sharded["silent"],
        "byte_mismatch": sharded["byte_mismatch"],
    }
    save_result("fleet", out)
    return out


def validate_claims(fl: dict) -> dict:
    checks = {
        "C_fleet_scaling": {
            "scaling_1_to_4": fl["scaling_1_to_4"],
            "one_shard_mb_s": fl["one_shard"]["agg_mb_s"],
            "four_shard_mb_s": fl["four_shards"]["agg_mb_s"],
            "pass": fl["scaling_1_to_4"] >= 1.5,
        },
        "C_fleet_no_silent": {
            "silent": fl["silent"],
            "byte_mismatch": fl["byte_mismatch"],
            "queries_checked": fl["four_shards"]["queries"]["ok"]
            + fl["four_shards"]["queries"]["degraded"]
            + fl["four_shards"]["queries"]["error"],
            "pass": fl["silent"] == 0
            and fl["byte_mismatch"] == 0
            and fl["four_shards"]["queries"]["ok"] > 0,
        },
    }
    save_result("claims_fleet", checks)
    return checks
