"""Fig. 10: effect of data-set size — base stays ~flat, residuals grow
linearly, so CR improves with scale.  Uses the household-power analogue
with injected N(0, 0.1) noise, exactly the paper's methodology."""
from __future__ import annotations

import numpy as np

from repro.core import ShrinkCodec
from repro.data.synthetic import household_power

from .datasets import cr, save_result


def fig10_size_scaling(sizes=(50_000, 100_000, 250_000, 500_000, 1_000_000, 2_000_000)) -> dict:
    """Fig. 10 splits the paper's Def. 3 'base' (the k (origin, span, slope)
    cone dictionary — the knowledge that saturates as patterns repeat) from
    the per-segment timestamp lists (which grow with the segment count, i.e.
    linearly under stationary noise, like residuals)."""
    import dataclasses as _dc

    import numpy as np

    from repro.core.serialize import encode_base

    out = {"sizes": list(sizes), "base_bytes": [], "dict_bytes": [], "k_subbases": [],
           "timestamp_bytes": [], "residual_bytes": [], "cr_lossless": [], "cr_1e-3": []}
    for n in sizes:
        v = household_power(rng_seed=7, n=n)
        rng = float(v.max() - v.min())
        eps = 1e-3 * rng
        codec = ShrinkCodec.from_fraction(v, frac=0.05, backend="rans")
        cs = codec.compress(v, eps_targets=[eps, 0.0], decimals=3)
        res_bytes = cs.size_at(eps) - len(cs.base_bytes)  # pyramid prefix for eps
        # dictionary-only size: strip the timestamp lists
        stripped = _dc.replace(
            cs.base,
            subbases=[
                _dc.replace(sb, t0s=np.zeros(0, np.int64), lengths=np.zeros(0, np.int64))
                for sb in cs.base.subbases
            ],
        )
        dict_bytes = len(encode_base(stripped))
        out["base_bytes"].append(len(cs.base_bytes))
        out["dict_bytes"].append(dict_bytes)
        out["k_subbases"].append(cs.base.k)
        out["timestamp_bytes"].append(len(cs.base_bytes) - dict_bytes)
        out["residual_bytes"].append(res_bytes)
        out["cr_lossless"].append(cr(n, cs.size_at(0.0)))
        out["cr_1e-3"].append(cr(n, cs.size_at(eps)))
    save_result("fig10_scaling", out)
    return out


def validate_claims(fig10) -> dict:
    sizes = np.array(fig10["sizes"], float)
    base = np.array(fig10.get("dict_bytes", fig10["base_bytes"]), float)
    res = np.array(fig10["residual_bytes"], float)
    # C3: the cone DICTIONARY grows much slower than data (the repeated-
    # semantics claim); residuals ~linear
    base_growth = (base[-1] / max(base[0], 1)) / (sizes[-1] / sizes[0])
    res_growth = (res[-1] / res[0]) / (sizes[-1] / sizes[0])
    checks = {
        "C3_base_sublinear": {
            "dictionary_growth_vs_linear": float(base_growth),
            "residual_growth_vs_linear": float(res_growth),
            "k_subbases": fig10.get("k_subbases"),
            "pass": bool(base_growth < 0.5 and 0.5 < res_growth < 2.0),
        },
        "C3b_cr_increases_with_size": {
            "cr_lossless": fig10["cr_lossless"],
            "pass": bool(fig10["cr_lossless"][-1] >= fig10["cr_lossless"][0]),
        },
    }
    save_result("claims_scaling", checks)
    return checks
