"""Compressed-domain analytics benchmarks.

``segment_vs_decode``: the headline claim — aggregate queries answered in
the segment domain (closed-form over the knowledge base, ZERO entropy
work) against the decode-then-numpy oracle at the same guarantee
(eps = 1e-2 of range: the archive's base is built tight enough that the
segment path already meets it, so both answers carry the same per-point
bound).  Claim ``C_analytics_segment_10x``: the segment path is >= 10x
faster on every standard-workload dataset.

``predicate_refine``: the refine loop over a SHRKS container — a
threshold count at the exact tier pays pyramid layers only for frames
whose segment bounds straddle the threshold; reported as queries/s plus
the planner's frame accounting (and differentially verified against the
decode oracle on every query).

``analytics_json`` bundles both for the BENCH_throughput.json
trajectory.
"""
from __future__ import annotations

import gc
import time

import numpy as np

from repro.analytics import AnalyticsEngine, SeriesAnalytics
from repro.core import BYTES_PER_ROW, ShrinkCodec, ShrinkConfig, ShrinkStreamCodec
from repro.core.semantics import global_range
from repro.core.shrink import decompress_at

from .datasets import bench_series, save_result

_AGG_OPS = ("min", "max", "sum", "mean", "stddev")
_EPS_REL = 1e-2  # the claim's query resolution (fraction of range)


def _timed(fn, inner: int) -> float:
    """Mean seconds per call over ``inner`` back-to-back calls (amortizes
    timer noise on µs-scale calls)."""
    t0 = time.perf_counter()
    for _ in range(inner):
        fn()
    return (time.perf_counter() - t0) / inner


def _paired_ratio(fast_fn, slow_fn, reps: int, fast_inner: int = 16,
                  slow_inner: int = 2) -> tuple[float, float, float]:
    """(t_fast, t_slow, speedup) with the two sides timed *adjacently* in
    each round and the speedup taken as the median of per-round ratios —
    machine-speed drift between rounds (this box swings 2x) then cancels
    instead of landing on one side of the ratio.  GC stays off inside the
    timed region: earlier benches in a harness run leave enough garbage
    that a collection mid-call swamps a 100µs measurement."""
    gc.collect()
    on = gc.isenabled()
    gc.disable()
    try:
        pairs = [
            (_timed(fast_fn, fast_inner), _timed(slow_fn, slow_inner))
            for _ in range(reps)
        ]
    finally:
        if on:
            gc.enable()
    ratios = sorted(ts / max(tf, 1e-12) for tf, ts in pairs)
    return (
        min(tf for tf, _ in pairs),
        min(ts for _, ts in pairs),
        ratios[len(ratios) // 2],
    )


def segment_vs_decode(
    n: int = 100_000,
    datasets=("WindSpeed", "Pressure", "ECG"),
    reps: int = 5,
) -> dict:
    """Aggregates at eps = 1e-2·range: segment-domain closed form vs
    decode-then-numpy, per dataset and op, answers differentially checked
    (truth inside the interval) before timing."""
    out: dict = {"eps_rel": _EPS_REL, "datasets": {}}
    for name in datasets:
        v = bench_series(name, n)
        from repro.data.synthetic import DATASETS

        decimals = DATASETS[name].decimals
        rng = float(v.max() - v.min())
        eps_q = _EPS_REL * rng
        # base tight enough that eps_q is served from segments alone (the
        # adaptive threshold can reach ~2x eps_b, hence the 0.004 margin)
        codec = ShrinkCodec.from_fraction(v, frac=0.004, backend="rans")
        cs = codec.compress(v, eps_targets=[eps_q, 1e-3 * rng, 0.0], decimals=decimals)
        assert cs.eps_b_practical <= eps_q, (
            f"{name}: base guarantee {cs.eps_b_practical:.3g} looser than "
            f"eps {eps_q:.3g} — segment path would not qualify")
        sa = SeriesAnalytics(cs)
        row: dict = {
            "n": int(len(v)),
            "segments": sa.table.k,
            "eps_b_practical": cs.eps_b_practical,
            "eps_query": eps_q,
            "ops": {},
        }
        for op in _AGG_OPS:
            ans = sa.aggregate(op, eps=eps_q)
            assert ans.source == "segments" and ans.layers_paid == 0
            truth = {
                "min": v.min(), "max": v.max(), "sum": v.sum(),
                "mean": v.mean(), "stddev": v.std(),
            }[op]
            assert ans.lo <= truth <= ans.hi, (name, op)

            def oracle(o=op):
                vhat = decompress_at(cs, eps_q)
                return {
                    "min": vhat.min, "max": vhat.max, "sum": vhat.sum,
                    "mean": vhat.mean, "stddev": vhat.std,
                }[o]()

            t_seg, t_dec, speedup = _paired_ratio(
                lambda o=op: sa.aggregate(o, eps=eps_q), oracle, reps
            )
            row["ops"][op] = {
                "segment_us": t_seg * 1e6,
                "decode_us": t_dec * 1e6,
                "speedup": speedup,
            }
        row["min_speedup"] = min(o["speedup"] for o in row["ops"].values())
        out["datasets"][name] = row
    return out


def predicate_refine(
    n: int = 100_000, name: str = "Pressure", frame_len: int = 8192,
    queries: int = 64,
) -> dict:
    """Threshold counts at the exact tier over a framed container: the
    planner decodes only straddling frames; every answer is checked
    against the decode-then-numpy oracle."""
    v = bench_series(name, n)
    from repro.data.synthetic import DATASETS

    decimals = DATASETS[name].decimals
    rng = float(v.max() - v.min())
    cfg = ShrinkConfig(eps_b=0.01 * rng, lam=1e-4)
    sc = ShrinkStreamCodec(
        cfg, eps_targets=[1e-2 * rng, 1e-3 * rng, 0.0], decimals=decimals,
        backend="rans", value_range=global_range(v), frame_len=frame_len,
    )
    sc.ingest(v)
    eng = AnalyticsEngine(sc.finalize())
    qrng = np.random.default_rng(0)
    thresholds = np.quantile(v, qrng.uniform(0.02, 0.98, queries))
    t0 = time.perf_counter()
    for c in thresholds:
        ans = eng.count_where(0, "gt", float(c), eps=0.0)
        assert ans.exact and ans.lo == float(int((v > c).sum()))
    dt = time.perf_counter() - t0
    st = eng.stats
    frames = st["frames_touched"]
    return {
        "dataset": name,
        "n": int(len(v)),
        "queries": int(queries),
        "queries_per_s": queries / dt,
        "frames_touched": frames,
        "frames_refined": st["frames_refined"],
        "frames_settled_by_segments": st["frames_skipped"],
        "refine_fraction": st["frames_refined"] / max(frames, 1),
        "layers_paid": st["layers_paid"],
        "mb_covered_per_s": queries * len(v) * BYTES_PER_ROW / 1e6 / dt,
    }


def analytics_json(quick: bool = False) -> dict:
    # the 10x claim is defined at the standard workload size: at small n
    # the decode oracle's O(n) cost shrinks toward the segment path's
    # fixed python overhead and the ratio measures interpreter noise, so
    # --quick trims reps and the predicate sweep but NOT the claim's n
    return {
        "segment_vs_decode": segment_vs_decode(n=100_000, reps=3 if quick else 5),
        "predicate": predicate_refine(
            n=20_000 if quick else 100_000,
            frame_len=4096 if quick else 8192,
            queries=32 if quick else 64,
        ),
    }


def validate_claims(analytics: dict) -> dict:
    """C_analytics_segment_10x: on every standard-workload dataset,
    segment-domain aggregates at eps = 1e-2·range beat decode-then-numpy
    by >= 10x (same per-point guarantee on both sides)."""
    speedups = {
        name: round(row["min_speedup"], 2)
        for name, row in analytics["segment_vs_decode"]["datasets"].items()
    }
    checks = {
        "C_analytics_segment_10x": {
            "min_speedup_per_dataset": speedups,
            "pass": bool(all(s >= 10.0 for s in speedups.values())),
        }
    }
    save_result("claims_analytics", checks)
    return checks
