"""Robustness benchmarks: what fault tolerance costs, and proof it works.

``integrity_overhead``: the CRC ladder (SHRKS footer + lazy frame CRCs,
SHRK header CRC, SHRR directory + per-layer CRCs) is verified on every
serve — this measures the pure checksum pass over the container against
the full decode, so the overhead is reported as a fraction of real work.
Claim ``C_robustness_crc_overhead``: integrity verification costs < 25%
of decode time (it is a single crc32 sweep vs an entropy decode).

``degraded_path``: serving latency for a healthy frame vs the same frame
with its finest pyramid layer corrupted (the gateway's tolerant re-parse
+ intact-prefix serve).  The degraded path re-reads the payload and
re-parses under ``strict=False``, so it costs roughly one extra parse —
reported as a ratio.  Claim ``C_robustness_degraded_overhead``: a
degraded answer costs < 5x a healthy one (no retry storms, no decode of
the corrupt layer).

``chaos_campaign``: a seeded single-fault campaign (flip / truncate /
CRC smash / frame drop) with every surviving answer differentially
checked against the pristine oracle.  Claim
``C_robustness_no_silent_corruption``: zero answers outside their
reported bound — the headline invariant of docs/robustness.md, here
measured rather than unit-tested.

``robustness_json`` bundles all three for the BENCH_throughput.json
trajectory.
"""
from __future__ import annotations

import time
import zlib

import numpy as np

from repro.core import BYTES_PER_ROW, ShrinkConfig, ShrinkStreamCodec, ShrinkError
from repro.serving import FaultTolerantGateway, RangeQuery
from repro.testing import ChaosInjector, flip_byte, list_frames

from .datasets import save_result


def _container(s: int, n: int, frame_len: int):
    rng = np.random.default_rng(5)
    v = np.cumsum(rng.standard_normal((s, n)) * 0.05, axis=1)
    v += rng.standard_normal((s, n)) * 0.02
    v = np.round(v, 4)
    vrange = float(v.max() - v.min())
    cfg = ShrinkConfig(eps_b=0.05 * vrange, lam=1e-4)
    eps = 0.01 * vrange
    sc = ShrinkStreamCodec(
        cfg, eps_targets=[eps], backend="rans",
        value_range=(float(v.min()), float(v.max())), frame_len=frame_len,
    )
    for sid in range(s):
        sc.ingest(v[sid], series_id=sid)
    return v, eps, sc.finalize()


def _serve_all(blob: bytes, s: int, n: int, eps: float) -> float:
    gw = FaultTolerantGateway(blob, cache_frames=0)  # cold: every decode real
    for sid in range(s):
        gw.submit(RangeQuery(qid=sid, series_id=sid, t0=0, t1=n, eps=eps))
    t0 = time.perf_counter()
    for q in gw.run():
        assert q.error is None
    return time.perf_counter() - t0


def integrity_overhead(quick: bool = False) -> dict:
    s, n, frame = (2, 16_384, 2048) if quick else (4, 65_536, 8192)
    v, eps, blob = _container(s, n, frame)
    reps = 3 if quick else 5
    decode_s = min(_serve_all(blob, s, n, eps) for _ in range(reps))
    # the checksum work the ladder adds, measured as a raw crc32 sweep of
    # every byte the decode path verifies (footer + frames + layers)
    crc_s = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        zlib.crc32(blob)
        crc_s = min(crc_s, time.perf_counter() - t0)
    mb = s * n * BYTES_PER_ROW / 1e6
    return {
        "series": s, "points_per_series": n, "container_bytes": len(blob),
        "full_decode_s": decode_s,
        "decode_mb_s": mb / decode_s,
        "crc_sweep_s": crc_s,
        "crc_overhead_frac": crc_s / decode_s,
    }


def degraded_path(quick: bool = False) -> dict:
    s, n, frame = (2, 16_384, 2048) if quick else (2, 32_768, 4096)
    v, eps, blob = _container(s, n, frame)
    m = list_frames(blob)[0]
    corrupt, _ = flip_byte(blob, m.offset + m.length - 3)  # finest layer dies
    inner = 4 if quick else 16

    def serve(b: bytes) -> tuple[float, bool]:
        t_best, degraded = float("inf"), False
        for _ in range(inner):
            gw = FaultTolerantGateway(b, cache_frames=0)
            gw.submit(RangeQuery(qid=0, series_id=m.series_id,
                                 t0=m.t_lo, t1=m.t_hi, eps=eps))
            t0 = time.perf_counter()
            (q,) = gw.run()
            t_best = min(t_best, time.perf_counter() - t0)
            assert q.error is None
            degraded = q.degraded
            err = float(np.max(np.abs(
                q.result - v[m.series_id, m.t_lo:m.t_hi])))
            assert err <= max(q.achieved, eps) * (1 + 1e-9)
        return t_best, degraded

    healthy_s, d0 = serve(blob)
    degraded_s, d1 = serve(corrupt)
    assert not d0 and d1
    return {
        "frame_samples": m.t_hi - m.t_lo,
        "healthy_ms": healthy_s * 1e3,
        "degraded_ms": degraded_s * 1e3,
        "degraded_vs_healthy": degraded_s / healthy_s,
    }


def chaos_campaign(quick: bool = False) -> dict:
    s, n, frame = (2, 8192, 1024) if quick else (2, 16_384, 2048)
    v, eps, blob = _container(s, n, frame)
    chaos = ChaosInjector(seed=0)
    qrng = np.random.default_rng(3)
    rounds = 24 if quick else 96
    per = 4
    tally = {"ok": 0, "degraded": 0, "typed_error": 0, "silent": 0,
             "rejected_at_parse": 0}
    t0 = time.perf_counter()
    for _ in range(rounds):
        mutant, _fault = chaos.corrupt(blob)
        try:
            gw = FaultTolerantGateway(mutant)
        except ShrinkError:
            tally["rejected_at_parse"] += 1
            continue
        for qid in range(per):
            sid = int(qrng.integers(0, s))
            lo = int(qrng.integers(0, n - 16))
            hi = int(min(n, lo + qrng.integers(16, 2 * frame)))
            gw.submit(RangeQuery(qid=qid, series_id=sid, t0=lo, t1=hi, eps=eps))
        for q in gw.run(deadline_s=30.0):
            if q.error is not None:
                tally["typed_error"] += 1
                continue
            err = float(np.max(np.abs(q.result - v[q.series_id, q.t0:q.t1])))
            if err > max(q.achieved, eps) * (1 + 1e-9):
                tally["silent"] += 1
            elif q.degraded:
                tally["degraded"] += 1
            else:
                tally["ok"] += 1
    dt = time.perf_counter() - t0
    checked = sum(tally.values()) - tally["rejected_at_parse"]
    return {
        "rounds": rounds, "queries_checked": checked,
        "campaign_s": dt,
        "queries_per_s": checked / dt if dt > 0 else 0.0,
        **tally,
    }


def robustness_json(quick: bool = False) -> dict:
    out = {
        "integrity_overhead": integrity_overhead(quick=quick),
        "degraded_path": degraded_path(quick=quick),
        "chaos_campaign": chaos_campaign(quick=quick),
    }
    save_result("robustness", out)
    return out


def validate_claims(rob: dict) -> dict:
    checks = {
        "C_robustness_no_silent_corruption": {
            "queries_checked": rob["chaos_campaign"]["queries_checked"],
            "silent": rob["chaos_campaign"]["silent"],
            "pass": rob["chaos_campaign"]["silent"] == 0
            and rob["chaos_campaign"]["queries_checked"] > 0,
        },
        "C_robustness_crc_overhead": {
            "crc_overhead_frac": round(
                rob["integrity_overhead"]["crc_overhead_frac"], 4),
            "pass": rob["integrity_overhead"]["crc_overhead_frac"] < 0.25,
        },
        "C_robustness_degraded_overhead": {
            "degraded_vs_healthy": round(
                rob["degraded_path"]["degraded_vs_healthy"], 2),
            "pass": rob["degraded_path"]["degraded_vs_healthy"] < 5.0,
        },
    }
    save_result("claims_robustness", checks)
    return checks
