"""KB-store benchmarks: cross-archive dictionary dedup, measured.

The paper's compression-ratio-grows-with-data claim hinges on semantic
lines repeating; per-archive KBs pay that dictionary once PER ARCHIVE.
This bench builds a fleet-shaped corpus — many small archives whose
segments tile a small shared motif bank, i.e. exactly the cross-archive
repetition the store exists to harvest — twice over identical data:

* **inline**: every archive self-contained (its own SHKB footer);
* **shared**: every archive in ref mode against one :class:`KBStore`
  (footer carries only the ``kb_snapshot_ref``), plus ONE latest SHKS
  snapshot blob that amortizes the dictionary across the corpus.

Every archive is then decoded both ways and compared exactly; the store
is compacted, spilled, and reloaded, and the re-based containers are
decoded again — any float mismatch counts as a differential failure, so
the byte win can never be bought with silent corruption.

Claims:

``C_kbstore_cr``        — shared-store corpus bytes (ref containers +
                          the one snapshot) <= 0.9x the per-archive
                          inline corpus bytes over identical data.
``C_kbstore_roundtrip`` — zero decode mismatches across ref-vs-inline,
                          post-compaction, and post-spill/load paths,
                          and every container KB view rebuilt from the
                          store equals the writer's KB exactly.
"""
from __future__ import annotations

import numpy as np

from repro.core import ShrinkConfig, ShrinkStreamCodec, decode_series
from repro.core.semantics import global_range
from repro.core.serialize import parse_framed_container, read_snapshot_ref
from repro.serving import KBStore

from .datasets import save_result

_DECIMALS = 3


def _motif_bank(n_motifs: int, motif_len: int, seed: int) -> list[np.ndarray]:
    """A small bank of piecewise-linear motifs: each is a dozen-odd ramps,
    so the semantic extractor summarizes it with a batch of KB lines that
    recur identically wherever the motif is tiled — across archives, the
    exact repetition the shared store harvests."""
    rng = np.random.default_rng(seed)
    bank = []
    for _ in range(n_motifs):
        knots = np.sort(
            rng.choice(np.arange(4, motif_len - 4), size=15, replace=False)
        )
        xs = np.concatenate([[0], knots, [motif_len - 1]])
        ys = np.round(rng.uniform(-4.0, 4.0, size=xs.size), 1)
        bank.append(np.round(np.interp(np.arange(motif_len), xs, ys), _DECIMALS))
    return bank


def _archive_series(bank: list[np.ndarray], tiles: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return np.concatenate([bank[rng.integers(0, len(bank))] for _ in range(tiles)])


def _corpus(n_archives: int, tiles: int, seed: int = 11) -> list[np.ndarray]:
    bank = _motif_bank(n_motifs=8, motif_len=128, seed=seed)
    return [_archive_series(bank, tiles, seed=seed + 1 + i) for i in range(n_archives)]


def kbstore_json(quick: bool = False) -> dict:
    import tempfile

    n_archives, tiles = (32, 2) if quick else (64, 2)
    series = _corpus(n_archives, tiles)
    allv = np.concatenate(series)
    vr = global_range(allv)
    cfg = ShrinkConfig(eps_b=0.05 * (vr[1] - vr[0]), lam=1e-3)
    eps = [0.02 * (vr[1] - vr[0])]

    def encode(v, store=None, source=None):
        # "best" = per-stream cost-model backend routing; small frames take
        # the table-free bitpack path, so the dictionary (not entropy-coder
        # overhead) dominates the archive byte budget
        sc = ShrinkStreamCodec(
            cfg, eps_targets=eps, decimals=_DECIMALS, backend="best",
            value_range=vr, frame_len=tiles * 128, kb_store=store, source=source,
        )
        sc.ingest(v)
        return sc, sc.finalize()

    # pass 1: self-contained archives (the status quo)
    inline_blobs = [encode(v)[1] for v in series]
    inline_bytes = sum(len(b) for b in inline_blobs)
    inline_kb_bytes = sum(
        len(parse_framed_container(b)[1]) for b in inline_blobs
    )

    # pass 2: identical data through one shared store, ref-mode footers
    store = KBStore(cfg)
    ref_codecs = [
        encode(v, store=store, source=f"ar{i}") for i, v in enumerate(series)
    ]
    ref_blobs = [store.container(f"ar{i}") for i in range(n_archives)]
    snapshot_bytes = len(store.snapshots[-1].blob)
    shared_bytes = sum(len(b) for b in ref_blobs) + snapshot_bytes

    mismatches = 0
    kb_mismatches = 0
    for i, v in enumerate(series):
        a = decode_series(inline_blobs[i], 0, eps[0])
        b = decode_series(ref_blobs[i], 0, eps[0])
        if not np.array_equal(a, b) or float(np.abs(a - v).max()) > eps[0] + 1e-9:
            mismatches += 1
        ref = read_snapshot_ref(ref_blobs[i])
        kb = store.container_kb(ref)
        sc = ref_codecs[i][0]
        if kb.canonical() != sc.kb.canonical() or [
            e.refs for e in kb.entries
        ] != [e.refs for e in sc.kb.entries]:
            kb_mismatches += 1

    # lifecycle: detach a third of the corpus, compact, verify re-based
    # containers decode identically, then spill + reload and re-resolve
    dropped = list(range(0, n_archives, 3))
    for i in dropped:
        store.detach(f"ar{i}")
    compact_rep = store.compact()
    survivors = [i for i in range(n_archives) if i not in dropped]
    for i in survivors:
        if not np.array_equal(
            decode_series(store.container(f"ar{i}"), 0, eps[0]),
            decode_series(inline_blobs[i], 0, eps[0]),
        ):
            mismatches += 1
    with tempfile.TemporaryDirectory() as d:
        store.spill(d)
        loaded = KBStore.load(d)
        for i in survivors:
            blob = store.container(f"ar{i}")
            ref = read_snapshot_ref(blob)
            kb = loaded.container_kb(ref)
            if kb.canonical() != ref_codecs[i][0].kb.canonical():
                kb_mismatches += 1

    st = store.stats()
    out = {
        "quick": quick,
        "corpus": {
            "archives": n_archives,
            "samples": int(allv.size),
            "raw_mb": round(allv.nbytes / 1e6, 3),
        },
        "inline": {
            "total_bytes": inline_bytes,
            "kb_bytes": inline_kb_bytes,
            "kb_share": round(inline_kb_bytes / inline_bytes, 4),
        },
        "shared": {
            "container_bytes": shared_bytes - snapshot_bytes,
            "snapshot_bytes": snapshot_bytes,
            "total_bytes": shared_bytes,
            "store_live_entries": st["live"],
            "store_dedup_ratio": round(st["dedup_ratio"], 2),
        },
        "cr_shared_over_inline": round(shared_bytes / inline_bytes, 4),
        "compaction": {
            "dropped_entries": compact_rep["dropped"],
            "rebased_containers": len(compact_rep["rebased"]),
        },
        "decode_mismatches": mismatches,
        "kb_view_mismatches": kb_mismatches,
    }
    save_result("kbstore", out)
    return out


def validate_claims(kb: dict) -> dict:
    checks = {
        "C_kbstore_cr": {
            "cr_shared_over_inline": kb["cr_shared_over_inline"],
            "inline_bytes": kb["inline"]["total_bytes"],
            "shared_bytes": kb["shared"]["total_bytes"],
            "inline_kb_share": kb["inline"]["kb_share"],
            "pass": kb["cr_shared_over_inline"] <= 0.9,
        },
        "C_kbstore_roundtrip": {
            "decode_mismatches": kb["decode_mismatches"],
            "kb_view_mismatches": kb["kb_view_mismatches"],
            "rebased_containers": kb["compaction"]["rebased_containers"],
            "pass": kb["decode_mismatches"] == 0
            and kb["kb_view_mismatches"] == 0
            and kb["compaction"]["rebased_containers"] > 0,
        },
    }
    save_result("claims_kbstore", checks)
    return checks
