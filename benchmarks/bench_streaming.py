"""Streaming-ingest benchmarks: chunk-at-a-time throughput and the paper's
CR-grows-with-size effect measured through the streamed pipeline.

``ingest_throughput``: MB/s of ``ShrinkStreamCodec.ingest`` (pinned-range
incremental scan, framed output) at gateway chunk sizes, against the
one-shot ``ShrinkCodec.compress`` baseline on the same data — the price of
chunk-at-a-time operation (it should be near 1x: the incremental scan is
the same chunked-vectorized recurrence).

``cr_vs_stream_length``: compression ratio of the finalized container as a
function of how much of the repeated-semantics stream
(``data.synthetic.household_power``, the paper's Fig. 10 methodology) has
been ingested.  SHRINK's knowledge base amortizes as the stream grows —
identical appliance plateaus keep hitting the same (origin, slope) lines —
so CR must increase monotonically with stream length.  This is the
streaming counterpart of bench_scaling's Fig. 10 and is asserted as claim
``C_stream_cr_grows``.

``streaming_json`` bundles both for the BENCH_throughput.json trajectory.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import BYTES_PER_ROW, ShrinkCodec, ShrinkConfig, ShrinkStreamCodec
from repro.data.synthetic import household_power

from .datasets import save_result


def _best_of(fn, reps: int = 3) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _gateway_streams(s: int, n: int, seed: int = 42) -> np.ndarray:
    rng = np.random.default_rng(seed)
    v = np.cumsum(rng.standard_normal((s, n)) * 0.05, axis=1)
    v += rng.standard_normal((s, n)) * 0.02
    return np.round(v, 4)


def ingest_throughput(
    s: int = 16, n: int = 32_768, chunks=(1024, 4096, 16_384), reps: int = 3
) -> dict:
    """Streamed ingest MB/s per chunk size vs the one-shot baseline."""
    v = _gateway_streams(s, n)
    vmin, vmax = float(v.min()), float(v.max())
    cfg = ShrinkConfig(eps_b=0.05 * (vmax - vmin), lam=1e-4)
    eps = 1e-3 * (vmax - vmin)
    mb = s * n * BYTES_PER_ROW / 1e6

    def stream_all(chunk: int) -> None:
        codec = ShrinkStreamCodec(
            cfg, eps_targets=[eps], backend="rans",
            value_range=(vmin, vmax), frame_len=8192,
        )
        for c0 in range(0, n, chunk):
            for sid in range(s):
                codec.ingest(v[sid, c0 : c0 + chunk], series_id=sid)
        codec.finalize()

    one_shot = ShrinkCodec(config=cfg, backend="rans")
    t_base = _best_of(
        lambda: [one_shot.compress(v[i], eps_targets=[eps]) for i in range(s)], reps
    )
    out = {
        "series": s,
        "points_per_series": n,
        "bytes_per_row": BYTES_PER_ROW,
        "one_shot_mb_s": mb / t_base,
    }
    for chunk in chunks:
        t = _best_of(lambda: stream_all(chunk), reps)
        out[f"chunk_{chunk}_mb_s"] = mb / t
    out["stream_vs_one_shot"] = out[f"chunk_{chunks[-1]}_mb_s"] / out["one_shot_mb_s"]
    save_result("streaming_ingest", out)
    return out


def cr_vs_stream_length(lengths=(8_192, 32_768, 131_072, 524_288)) -> dict:
    """CR of the finalized container after ingesting ``length`` samples of
    the household-power stream (lossless + one lossy target), streamed in
    4096-sample chunks as a single flush-at-end frame.

    One gateway configuration for every prefix: ``n_hint`` (and hence the
    Alg. 2 interval length L) is pinned to the longest stream, exactly as
    a deployed gateway keeps its config fixed while data accumulates.
    Letting L rescale with each prefix would change the segmentation
    regime between measurements and confound the knowledge-base
    amortization effect this benchmark isolates."""
    n_max = max(lengths)
    v = household_power(7, n_max)
    vmin, vmax = float(v.min()), float(v.max())
    cfg = ShrinkConfig(eps_b=0.05 * (vmax - vmin), lam=1e-4)
    out = {"lengths": list(lengths), "cr_lossless": [], "cr_eps1e-3": []}
    for n in lengths:
        for key, eps_targets, decimals in (
            ("cr_lossless", [0.0], 3),
            ("cr_eps1e-3", [1e-3 * (vmax - vmin)], None),
        ):
            codec = ShrinkStreamCodec(
                cfg, eps_targets=eps_targets, decimals=decimals, backend="rans",
                value_range=(vmin, vmax), n_hint=n_max,
            )
            for c0 in range(0, n, 4096):
                codec.ingest(v[c0 : c0 + 4096])
            blob = codec.finalize()
            out[key].append(n * BYTES_PER_ROW / len(blob))
    out["kb_entries_at_max"] = codec.kb.stats()["entries"]
    save_result("streaming_cr_growth", out)
    return out


def streaming_json(quick: bool = False) -> dict:
    if quick:
        tp = ingest_throughput(s=8, n=16_384, chunks=(1024, 4096))
        cr = cr_vs_stream_length(lengths=(4_096, 16_384, 65_536))
    else:
        tp = ingest_throughput()
        cr = cr_vs_stream_length()
    return {"ingest": tp, "cr_growth": cr}


def validate_claims(stream: dict) -> dict:
    """The paper's CR-grows-with-data-size claim, measured end-to-end
    through streamed ingest (chunked scan + framed container overhead)."""
    crs = stream["cr_growth"]["cr_lossless"]
    crs_lossy = stream["cr_growth"]["cr_eps1e-3"]
    grows = all(b > a for a, b in zip(crs, crs[1:]))
    grows_lossy = all(b > a for a, b in zip(crs_lossy, crs_lossy[1:]))
    checks = {
        "C_stream_cr_grows": {
            "cr_lossless": [round(c, 2) for c in crs],
            "cr_eps1e-3": [round(c, 2) for c in crs_lossy],
            "pass": bool(grows and grows_lossy),
        },
        # chunked ingest must stay near the one-shot path (the 16k-chunk
        # drift to 0.85x came from sealing frames one at a time — each seal
        # paid its own entropy pass; the batched multi-frame seal retired it)
        "C_stream_near_one_shot": {
            "stream_vs_one_shot": round(float(stream["ingest"]["stream_vs_one_shot"]), 2),
            "pass": bool(stream["ingest"]["stream_vs_one_shot"] >= 0.9),
        },
    }
    save_result("claims_streaming", checks)
    return checks
