"""Ragged multi-series benchmarks: bucketed ``compress_batch`` and the
``RaggedBatcher`` admission scheduler against the per-series loop.

``ragged_throughput`` is the headline number (claim ``C_ragged_batch_faster``):
aggregate MB/s of one ragged ``ShrinkCodec.compress_batch`` call over a
mixed-length workload — series lengths drawn log-uniform across ~1.5 decades,
the regime Sprintz (arXiv:1808.02515) reports for device-side streams —
versus the same work as a python loop of ``compress``.  The numpy batch path
is byte-identical to the loop (property-tested), so this is a pure
throughput comparison: the win comes from percentile length-bucketing
(masked multi-series scans instead of S single scans) plus the single
shared ragged rANS entropy pass.

``scheduler_throughput`` measures the full admission path: interleaved
per-sensor chunks -> ``RaggedBatcher`` (size-trigger flushes) -> sealed
SHRKS frames, i.e. what a gateway actually runs, including container
assembly and knowledge-base ingest.

``ragged_json`` bundles both for the BENCH_throughput.json trajectory
(see ``docs/benchmarks.md``).
"""
from __future__ import annotations

import math
import time

import numpy as np

from repro.core import BYTES_PER_ROW, ShrinkCodec, ShrinkConfig
from repro.serving.ragged import RaggedBatcher

from .datasets import save_result


def _best_of(fn, reps: int = 3) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def ragged_workload(
    s: int = 64, n_min: int = 512, n_max: int = 16_384, seed: int = 42
) -> list[np.ndarray]:
    """S gateway streams (random walk + sensor noise) with lengths drawn
    log-uniform in [n_min, n_max] — orders-of-magnitude spread."""
    rng = np.random.default_rng(seed)
    lengths = np.exp(rng.uniform(np.log(n_min), np.log(n_max), size=s)).astype(int)
    out = []
    for n in lengths:
        v = np.cumsum(rng.standard_normal(n) * 0.05)
        v += rng.standard_normal(n) * 0.02
        out.append(np.round(v, 4))
    return out


def ragged_throughput(
    s: int = 64, n_min: int = 512, n_max: int = 16_384, reps: int = 5
) -> dict:
    """Ragged compress_batch vs per-series loop, same eps targets, rans
    backend (byte-identical outputs -> pure throughput comparison)."""
    series = ragged_workload(s, n_min, n_max)
    lengths = np.array([v.size for v in series])
    allv = np.concatenate(series)
    rngv = float(allv.max() - allv.min())
    cfg = ShrinkConfig(eps_b=0.05 * rngv, lam=1e-5)
    codec = ShrinkCodec(config=cfg, backend="rans")
    eps_ts = [1e-2 * rngv, 1e-3 * rngv, 0.0]
    mb = int(lengths.sum()) * BYTES_PER_ROW / 1e6

    # full-size warm pass per path (jit shape buckets, lazy imports), then
    # drift-cancelling interleaved reps: batch and loop alternate so a
    # machine-load swing hits both paths, not just whichever ran second
    codec.compress_batch(series, eps_targets=eps_ts, decimals=4)
    [codec.compress(v, eps_targets=eps_ts, decimals=4) for v in series[:2]]
    t_batch = math.inf
    t_loop = math.inf
    for _ in range(reps):
        t_batch = min(
            t_batch,
            _best_of(
                lambda: codec.compress_batch(series, eps_targets=eps_ts, decimals=4), 1
            ),
        )
        t_loop = min(
            t_loop,
            _best_of(
                lambda: [
                    codec.compress(v, eps_targets=eps_ts, decimals=4) for v in series
                ],
                1,
            ),
        )
    out = {
        "series": s,
        "len_min": int(lengths.min()),
        "len_max": int(lengths.max()),
        "len_total": int(lengths.sum()),
        "bytes_per_row": BYTES_PER_ROW,
        "batch_mb_s": mb / t_batch,
        "loop_mb_s": mb / t_loop,
        "batch_speedup": t_loop / t_batch,
    }
    save_result("ragged_pipeline", out)
    return out


def scheduler_throughput(s: int = 64, ticks: int = 64, reps: int = 3) -> dict:
    """End-to-end RaggedBatcher ingest MB/s: heterogeneous-rate sensors
    (the shared ``data.synthetic.ragged_sensor_traffic`` workload, also
    driven by ``launch/serve.py --mode ingest``), size-trigger flushes,
    SHRKS container out."""
    from repro.data.synthetic import ragged_sensor_traffic

    chunks = [d for tick in ragged_sensor_traffic(s, ticks, seed=7) for d in tick]
    total = sum(c.size for _, c in chunks)
    cfg = ShrinkConfig(eps_b=0.4, lam=1e-4)
    mb = total * BYTES_PER_ROW / 1e6

    def run() -> None:
        b = RaggedBatcher(
            cfg, eps_targets=[8e-3], backend="rans", flush_samples=131_072
        )
        for sid, c in chunks:
            b.submit(sid, c)
        b.finalize()

    t = _best_of(run, reps)
    out = {
        "series": s,
        "samples": total,
        "bytes_per_row": BYTES_PER_ROW,
        "ingest_mb_s": mb / t,
    }
    save_result("ragged_scheduler", out)
    return out


def ragged_json(quick: bool = False) -> dict:
    if quick:
        tp = ragged_throughput(s=24, n_min=256, n_max=4096)
        sched = scheduler_throughput(s=24, ticks=24)
    else:
        tp = ragged_throughput()
        sched = scheduler_throughput()
    return {"pipeline": tp, "scheduler": sched}


def validate_claims(ragged: dict) -> dict:
    """This repo's own scale claim: bucketed ragged batching must hold a
    clear aggregate-MB/s margin over the per-series loop on the 64-series
    mixed-length workload.  Historical note: the ragged-ingest PR recorded
    2.41x when the loop encoded each residual stream through the *scalar*
    rANS coder; the pyramid refactor routed the single-series path through
    the batched entropy machine too (one pass over all of a series'
    layers), making the loop baseline ~2.6x faster — both absolute numbers
    rose, so the bar is a margin over the improved baseline, not the old
    ratio."""
    speedup = ragged["pipeline"]["batch_speedup"]
    checks = {
        "C_ragged_batch_faster": {
            "batch_speedup": round(float(speedup), 2),
            "batch_mb_s": round(float(ragged["pipeline"]["batch_mb_s"]), 2),
            "loop_mb_s": round(float(ragged["pipeline"]["loop_mb_s"]), 2),
            "pass": bool(speedup >= 1.2),
        }
    }
    save_result("claims_ragged", checks)
    return checks
