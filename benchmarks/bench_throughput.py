"""Fig. 11 + Table III: compression throughput / latency.

All methods are measured under the same harness (pure Python/numpy, one
CPU), so the paper's claim is validated as a RELATIVE ordering (SHRINK ~3x
Sim-Piece/APCA, comparable to LFZip/HIRE), not absolute MB/s.  Table III's
base-vs-residual split is reproduced by timing build_base separately from
residual encoding at eps in {0, 0.001, 0.01}.
"""
from __future__ import annotations

import numpy as np

from repro.baselines import LOSSLESS, LOSSY
from repro.core import ShrinkCodec, compute_residuals, quantize_exact, quantize_residuals
from repro.core.serialize import encode_residuals
from repro.data.synthetic import DATASETS

from .datasets import NINE, Timer, bench_series, save_result


def fig11_throughput(n=50_000, datasets=("FaceFour", "MoteStrain", "ECG", "WindSpeed", "Pressure")) -> dict:
    """MB/s per lossy compressor, averaged over eps in {1e-2, 1e-3} of range."""
    out = {}
    for name in datasets:
        v = bench_series(name, n)
        rng = float(v.max() - v.min())
        mb = len(v) * 16 / 1e6
        row = {}
        for method in ("SimPiece", "APCA", "LFZip", "HIRE"):
            ts = []
            for rel in (1e-2, 1e-3):
                with Timer() as t:
                    LOSSY[method](v, rel * rng)
                ts.append(t.seconds)
            row[method] = mb / np.mean(ts)
        ts = []
        for rel in (1e-2, 1e-3):
            codec = ShrinkCodec.from_fraction(v, frac=0.05, backend="zstd")
            with Timer() as t:
                codec.compress(v, eps_targets=[rel * rng])
            ts.append(t.seconds)
        row["SHRINK"] = mb / np.mean(ts)
        out[name] = row
    save_result("fig11_throughput", out)
    return out


def table3_latency(n=50_000, datasets=NINE) -> dict:
    """Lossless baselines vs SHRINK split into base construction + residual
    encoding at eps in {0 (lossless), 0.001, 0.01} of range."""
    out = {}
    for name in datasets:
        v = bench_series(name, n)
        d = DATASETS[name].decimals
        rng = float(v.max() - v.min())
        row = {}
        for method in ("GZip", "TRC", "BZip2", "Gorilla", "GD"):
            with Timer() as t:
                LOSSLESS[method](v, d)
            row[method] = t.seconds
        codec = ShrinkCodec.from_fraction(v, frac=0.05, backend="zstd")
        with Timer() as t:
            base = codec.build_base(v)
        row["SHRINK_base"] = t.seconds
        r = compute_residuals(v, base)
        res_times = {}
        for eps_rel in (0.0, 0.001, 0.01):
            with Timer() as t:
                if eps_rel == 0.0:
                    stream = quantize_exact(v, base, d)
                else:
                    stream = quantize_residuals(r, eps_rel * rng)
                encode_residuals(stream, backend="zstd")
            res_times[str(eps_rel)] = t.seconds
        row["SHRINK_residual"] = res_times
        out[name] = row
    save_result("table3_latency", out)
    return out


def validate_claims(fig11) -> dict:
    ratios = []
    for name, row in fig11.items():
        ratios.append(row["SHRINK"] / max(min(row["SimPiece"], row["APCA"]), 1e-9))
    checks = {
        "C6_shrink_faster_than_piecewise": {
            "median_speedup_vs_slowest_piecewise": float(np.median(ratios)),
            "pass": bool(np.median(ratios) >= 1.5),
        }
    }
    save_result("claims_throughput", checks)
    return checks
