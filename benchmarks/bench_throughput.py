"""Fig. 11 + Table III: compression throughput / latency, plus the repo's
own engine benchmarks (entropy backends, batched multi-series pipeline).

All methods are measured under the same harness (pure Python/numpy, one
CPU), so the paper's claim is validated as a RELATIVE ordering (SHRINK ~3x
Sim-Piece/APCA, comparable to LFZip/HIRE), not absolute MB/s.  Table III's
base-vs-residual split is reproduced by timing build_base separately from
residual encoding at eps in {0, 0.001, 0.01}.

``entropy_backends`` and ``batched_pipeline`` track this reproduction's own
perf surface: the vectorized rANS engine against the per-symbol adaptive
range coder, and ``ShrinkCodec.compress_batch`` against a python loop of
``compress``.  ``throughput_json`` assembles both into the machine-readable
trajectory written to BENCH_throughput.json at the repo root.
"""
from __future__ import annotations

import time

import numpy as np

from repro.baselines import LOSSLESS, LOSSY
from repro.core import ShrinkCodec, compute_residuals, quantize_exact, quantize_residuals
from repro.core import entropy as entropy_mod
from repro.data.synthetic import DATASETS

from .datasets import NINE, Timer, bench_series, save_result


def fig11_throughput(n=50_000, datasets=("FaceFour", "MoteStrain", "ECG", "WindSpeed", "Pressure")) -> dict:
    """MB/s per lossy compressor, averaged over eps in {1e-2, 1e-3} of range."""
    out = {}
    for name in datasets:
        v = bench_series(name, n)
        rng = float(v.max() - v.min())
        mb = len(v) * 16 / 1e6
        row = {}
        for method in ("SimPiece", "APCA", "LFZip", "HIRE"):
            ts = []
            for rel in (1e-2, 1e-3):
                with Timer() as t:
                    LOSSY[method](v, rel * rng)
                ts.append(t.seconds)
            row[method] = mb / np.mean(ts)
        ts = []
        for rel in (1e-2, 1e-3):
            codec = ShrinkCodec.from_fraction(v, frac=0.05, backend="rans")
            with Timer() as t:
                codec.compress(v, eps_targets=[rel * rng])
            ts.append(t.seconds)
        row["SHRINK"] = mb / np.mean(ts)
        out[name] = row
    save_result("fig11_throughput", out)
    return out


def table3_latency(n=50_000, datasets=NINE) -> dict:
    """Lossless baselines vs SHRINK split into base construction + residual
    encoding at eps in {0 (lossless), 0.001, 0.01} of range."""
    out = {}
    for name in datasets:
        v = bench_series(name, n)
        d = DATASETS[name].decimals
        rng = float(v.max() - v.min())
        row = {}
        for method in ("GZip", "TRC", "BZip2", "Gorilla", "GD"):
            with Timer() as t:
                LOSSLESS[method](v, d)
            row[method] = t.seconds
        codec = ShrinkCodec.from_fraction(v, frac=0.05, backend="rans")
        with Timer() as t:
            base = codec.build_base(v)
        row["SHRINK_base"] = t.seconds
        r = compute_residuals(v, base)
        res_times = {}
        for eps_rel in (0.0, 0.001, 0.01):
            with Timer() as t:
                if eps_rel == 0.0:
                    stream = quantize_exact(v, base, d)
                else:
                    stream = quantize_residuals(r, eps_rel * rng)
                entropy_mod.encode_ints(stream.q, backend="rans")
            res_times[str(eps_rel)] = t.seconds
        row["SHRINK_residual"] = res_times
        out[name] = row
    save_result("table3_latency", out)
    return out


def _best_of(fn, reps: int = 3) -> float:
    """Best wall-clock of ``reps`` runs — the standard defense against a
    noisy shared-CPU box."""
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def entropy_backends(n: int = 50_000, reps: int = 3) -> dict:
    """Encode+decode MB/s per entropy backend on a gaussian residual stream
    (the shape residual quantization emits).  MB/s counts 8 B/symbol (the
    int64 payload)."""
    rng = np.random.default_rng(0)
    q = np.round(rng.standard_normal(n) * 200).astype(np.int64)
    mb = q.size * 8 / 1e6
    out = {"symbols": n, "bytes_per_symbol": 8}
    for backend in entropy_mod.available_backends():
        blob = entropy_mod.encode_ints(q, backend=backend)
        t_enc = _best_of(lambda: entropy_mod.encode_ints(q, backend=backend), reps)
        t_dec = _best_of(lambda: entropy_mod.decode_ints(blob), reps)
        out[backend] = {
            "encode_mb_s": mb / t_enc,
            "decode_mb_s": mb / t_dec,
            "roundtrip_mb_s": mb / (t_enc + t_dec),
            "bytes": len(blob),
        }
    if "rans" in out and "rc" in out:
        out["rans_vs_rc_roundtrip_speedup"] = (
            out["rans"]["roundtrip_mb_s"] / out["rc"]["roundtrip_mb_s"]
        )
    save_result("entropy_backends", out)
    return out


def entropy_kernel(n: int = 50_000, reps: int = 3) -> dict:
    """Device rANS engine (kernels.rans) vs the numpy coder on the same
    stream, toggled via the ``SHRINK_RANS_DEVICE`` override.  Both routes
    emit the same wire format; ``bytes_identical`` asserts it per run so a
    silent format divergence fails the benchmark, not just the tests."""
    import os

    rng = np.random.default_rng(7)
    q = np.round(rng.standard_normal(n) * 200).astype(np.int64)
    mb = q.size * 8 / 1e6
    saved = os.environ.get("SHRINK_RANS_DEVICE")

    def _force(mode: str) -> None:
        os.environ["SHRINK_RANS_DEVICE"] = mode
        # un-quarantine + drop the cached module handle so the toggle is
        # re-evaluated on the next encode/decode
        entropy_mod._rans_device_state.update(mod=None, broken=False)

    try:
        _force("0")
        blob_np = entropy_mod.encode_ints(q, backend="rans")
        t_enc_np = _best_of(lambda: entropy_mod.encode_ints(q, backend="rans"), reps)
        t_dec_np = _best_of(lambda: entropy_mod.decode_ints(blob_np), reps)

        _force("1")
        entropy_mod.decode_ints(entropy_mod.encode_ints(q, backend="rans"))  # warm jit
        blob_dev = entropy_mod.encode_ints(q, backend="rans")
        t_enc_dev = _best_of(lambda: entropy_mod.encode_ints(q, backend="rans"), reps)
        t_dec_dev = _best_of(lambda: entropy_mod.decode_ints(blob_dev), reps)
        engaged = not entropy_mod._rans_device_state["broken"]
    finally:
        if saved is None:
            os.environ.pop("SHRINK_RANS_DEVICE", None)
        else:
            os.environ["SHRINK_RANS_DEVICE"] = saved
        entropy_mod._rans_device_state.update(mod=None, broken=False)

    out = {
        "symbols": n,
        "bytes_per_symbol": 8,
        "device_engaged": bool(engaged),
        "bytes_identical": blob_np == blob_dev,
        "numpy": {
            "encode_mb_s": mb / t_enc_np,
            "decode_mb_s": mb / t_dec_np,
            "roundtrip_mb_s": mb / (t_enc_np + t_dec_np),
        },
        "device": {
            "encode_mb_s": mb / t_enc_dev,
            "decode_mb_s": mb / t_dec_dev,
            "roundtrip_mb_s": mb / (t_enc_dev + t_dec_dev),
        },
        "vs_numpy": (t_enc_np + t_dec_np) / (t_enc_dev + t_dec_dev),
    }
    save_result("entropy_kernel", out)
    return out


def batched_pipeline(s: int = 64, t: int = 8192, reps: int = 3) -> dict:
    """compress_batch vs a python loop of compress on S synthetic gateway
    streams (random walk + sensor noise), same eps targets, rans backend.
    The numpy batch path is byte-identical to the loop, so this is a pure
    throughput comparison."""
    rng = np.random.default_rng(42)
    v = np.cumsum(rng.standard_normal((s, t)) * 0.05, axis=1)
    v += rng.standard_normal((s, t)) * 0.02
    v = np.round(v, 4)
    codec = ShrinkCodec.from_fraction(v, frac=0.05, backend="rans")
    rngv = float(v.max() - v.min())
    eps_ts = [1e-2 * rngv, 1e-3 * rngv, 0.0]
    mb = s * t * 16 / 1e6

    codec.compress_batch(v[:2], eps_targets=eps_ts, decimals=4)  # warm caches
    t_batch = _best_of(lambda: codec.compress_batch(v, eps_targets=eps_ts, decimals=4), reps)
    t_loop = _best_of(
        lambda: [codec.compress(v[i], eps_targets=eps_ts, decimals=4) for i in range(s)],
        reps,
    )
    out = {
        "series": s,
        "points_per_series": t,
        # 16 B/row (timestamp, value) — the repo-wide CR/throughput
        # accounting shared with fig11 (see core.shrink.BYTES_PER_ROW)
        "bytes_per_row": 16,
        "batch_mb_s": mb / t_batch,
        "loop_mb_s": mb / t_loop,
        "batch_speedup": t_loop / t_batch,
    }
    save_result("batched_pipeline", out)
    return out


def throughput_json(quick: bool = False) -> dict:
    """The machine-readable perf trajectory (BENCH_throughput.json).  The
    workload sizes are embedded so trajectories from --quick runs are never
    mistaken for (or diffed against) full-size numbers."""
    n = 20_000 if quick else 50_000
    s, t = (16, 4096) if quick else (64, 8192)
    return {
        "workload": "quick" if quick else "full",
        "entropy_backends": entropy_backends(n=n),
        "entropy_kernel": entropy_kernel(n=n),
        "batched_pipeline": batched_pipeline(s=s, t=t),
    }


def validate_claims(fig11) -> dict:
    ratios = []
    for name, row in fig11.items():
        ratios.append(row["SHRINK"] / max(min(row["SimPiece"], row["APCA"]), 1e-9))
    checks = {
        "C6_shrink_faster_than_piecewise": {
            "median_speedup_vs_slowest_piecewise": float(np.median(ratios)),
            "pass": bool(np.median(ratios) >= 1.5),
        }
    }
    save_result("claims_throughput", checks)
    return checks


# the numpy coder's roundtrip MB/s at the seed of this claim (pre-kernel,
# pre-vectorized-normalize) — the device engine is ratcheted against this
# fixed baseline, not the live numpy path, which also got faster
_SEED_NUMPY_ROUNDTRIP_MB_S = 6.5


def validate_engine_claims(engine: dict) -> dict:
    """Ratcheted claims over the repo's own engine trajectory: the device
    entropy kernel must hold >= 5x the seed numpy coder's 6.5 MB/s
    roundtrip, and the rect batch pipeline must stay >= 1.2x over the
    python loop (the PR-7 regression retired at 0.88x must never come
    back)."""
    ek = engine["entropy_kernel"]
    bp = engine["batched_pipeline"]
    dev_rt = float(ek["device"]["roundtrip_mb_s"])
    checks = {
        "C_entropy_kernel_5x": {
            "device_roundtrip_mb_s": round(dev_rt, 2),
            "seed_numpy_roundtrip_mb_s": _SEED_NUMPY_ROUNDTRIP_MB_S,
            "vs_live_numpy": round(float(ek["vs_numpy"]), 2),
            "bytes_identical": bool(ek["bytes_identical"]),
            "device_engaged": bool(ek["device_engaged"]),
            "pass": bool(
                ek["device_engaged"]
                and ek["bytes_identical"]
                and dev_rt >= 5.0 * _SEED_NUMPY_ROUNDTRIP_MB_S
            ),
        },
        "C_rect_batch_faster": {
            "batch_speedup": round(float(bp["batch_speedup"]), 2),
            "pass": bool(bp["batch_speedup"] >= 1.2),
        },
    }
    save_result("claims_engine", checks)
    return checks
