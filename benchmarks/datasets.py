"""Shared benchmark plumbing: dataset loading at benchmark sizes, the CR
accounting rule, and result IO.

CR denominator: S = 16 bytes/row (timestamp f64 + value f64), identical for
every method — matching the paper's file-size accounting (Table II is
~16-18 B/row).  Timestamps are a regular grid and are stored by no method.

Default sizes: comparison figures run on 100k-row prefixes (the paper's
smaller datasets are this size; the pure-Python LFZip/HIRE replays make
full-size sweeps impractical on 1 CPU — full sizes remain available via
``--full`` and the scaling study exercises growth explicitly).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.core import original_size_bytes
from repro.data.synthetic import DATASETS, load

ARTIFACTS = Path(__file__).resolve().parent.parent / "artifacts" / "bench"

NINE = [
    "FaceFour", "MoteStrain", "Lightning", "ECG", "Cricket",
    "WindDirection", "Wafer", "WindSpeed", "Pressure",
]

# error thresholds of Fig. 6 (piecewise-lossy comparison)
EPS_FIG6 = [0.01, 0.0075, 0.005, 0.0025, 0.001, 0.00075, 0.0005, 0.00025, 0.0001]
# Fig. 7 (general-purpose lossy): 1e-2 .. 1e-5 log scale
EPS_FIG7 = [1e-2, 1e-3, 1e-4, 1e-5]


def bench_series(name: str, n: int | None = 100_000) -> np.ndarray:
    spec = DATASETS[name]
    rows = spec.rows if n is None else min(n, spec.rows)
    return load(name, n=rows)


def eps_values(name: str, eps_list: list[float]) -> list[float]:
    """Absolute eps from relative thresholds; 2-decimal datasets stop at
    1e-3 of range (the paper does the same for WindSpeed/WindDirection)."""
    spec = DATASETS[name]
    rng = spec.vmax - spec.vmin
    floor = 10.0 ** (-spec.decimals) / rng
    return [e * rng for e in eps_list if e >= floor * 0.99]


def cr(n_rows: int, nbytes: int) -> float:
    return original_size_bytes(n_rows) / max(nbytes, 1)


def save_result(name: str, payload: dict) -> Path:
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    p = ARTIFACTS / f"{name}.json"
    p.write_text(json.dumps(payload, indent=2, default=float))
    return p


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
