"""Compression-ratio benchmarks: Fig. 6 (vs Sim-Piece/APCA), Fig. 7
(vs LFZip/HIRE), Fig. 8 (lossless vs GZip/BZip2/zstd/TRC/Gorilla/GD)."""
from __future__ import annotations

import numpy as np

from repro.baselines import LOSSLESS, LOSSY
from repro.core import ShrinkCodec
from repro.data.synthetic import DATASETS

from .datasets import EPS_FIG6, EPS_FIG7, NINE, Timer, bench_series, cr, eps_values, save_result


def _shrink_sizes(v, eps_abs_list, decimals, frac, include_lossless=True):
    codec = ShrinkCodec.from_fraction(v, frac=frac, backend="best")
    targets = list(eps_abs_list) + ([0.0] if include_lossless else [])
    with Timer() as t:
        cs = codec.compress(v, eps_targets=targets, decimals=decimals)
    out = {float(e): cs.size_at(e) for e in targets}
    return out, t.seconds, cs


def fig6_piecewise_lossy(n=100_000, datasets=NINE) -> dict:
    """SHRINK (eps_b = 5% range) vs Sim-Piece vs APCA at the paper's nine
    error resolutions; dashed line = lossless SHRINK."""
    results = {}
    for name in datasets:
        v = bench_series(name, n)
        d = DATASETS[name].decimals
        eps_list = eps_values(name, EPS_FIG6)
        shrink_sizes, _, _ = _shrink_sizes(v, eps_list, d, frac=0.05)
        row = {
            "eps": eps_list,
            "SHRINK": [cr(len(v), shrink_sizes[e]) for e in eps_list],
            "SHRINK_lossless": cr(len(v), shrink_sizes[0.0]),
        }
        for method in ("SimPiece", "APCA"):
            crs = []
            for e in eps_list:
                blob = LOSSY[method](v, e)
                crs.append(cr(len(v), len(blob)))
            row[method] = crs
        results[name] = row
    save_result("fig6_piecewise_lossy", results)
    return results


def fig7_general_lossy(n=50_000, datasets=NINE) -> dict:
    """SHRINK (eps_b = 15% range: compression is the goal) vs LFZip / HIRE
    at 1e-2..1e-5 of range."""
    results = {}
    for name in datasets:
        v = bench_series(name, n)
        d = DATASETS[name].decimals
        eps_list = eps_values(name, EPS_FIG7)
        shrink_sizes, _, _ = _shrink_sizes(v, eps_list, d, frac=0.15)
        row = {
            "eps": eps_list,
            "SHRINK": [cr(len(v), shrink_sizes[e]) for e in eps_list],
            "SHRINK_lossless": cr(len(v), shrink_sizes[0.0]),
        }
        for method in ("LFZip", "HIRE"):
            crs = []
            for e in eps_list:
                blob = LOSSY[method](v, e)
                crs.append(cr(len(v), len(blob)))
            row[method] = crs
        results[name] = row
    save_result("fig7_general_lossy", results)
    return results


def fig8_lossless(n=100_000, datasets=NINE) -> dict:
    """Lossless SHRINK vs the five general-purpose lossless baselines."""
    results = {}
    for name in datasets:
        v = bench_series(name, n)
        d = DATASETS[name].decimals
        sizes, _, _ = _shrink_sizes(v, [], d, frac=0.05)
        row = {"SHRINK": cr(len(v), sizes[0.0])}
        for method in sorted(LOSSLESS):
            from repro.baselines import LOSSLESS_D  # noqa

            blob = LOSSLESS[method](v, d)
            row[method] = cr(len(v), len(blob))
        results[name] = row
    save_result("fig8_lossless", results)
    return results


def validate_claims(fig6, fig7, fig8) -> dict:
    """The paper's headline claims (C1, C2) as checks over our tables."""
    checks = {}
    # C1: at the strictest shared eps, SHRINK >= 2x Sim-Piece CR on most sets
    gains = []
    for name, row in fig6.items():
        if not row["eps"]:
            continue
        gains.append(row["SHRINK"][-1] / max(row["SimPiece"][-1], 1e-9))
    checks["C1_strict_eps_gain_vs_simpiece"] = {
        "median_gain": float(np.median(gains)),
        "min_gain": float(np.min(gains)),
        "pass": bool(np.median(gains) >= 2.0),
    }
    # C1b: lossy methods degrade below lossless SHRINK at strict eps
    below = [
        row["SimPiece"][-1] < row["SHRINK_lossless"]
        for row in fig6.values()
        if len(row["eps"]) == len(EPS_FIG6)
    ]
    checks["C1b_simpiece_below_lossless_at_1e-4"] = {
        "fraction": float(np.mean(below)) if below else None,
        "pass": bool(np.mean(below) >= 0.5) if below else False,
    }
    # C2: lossless SHRINK beats every general-purpose lossless on most sets
    wins = []
    for name, row in fig8.items():
        best_other = max(v for k, v in row.items() if k != "SHRINK")
        wins.append(row["SHRINK"] > best_other)
    checks["C2_lossless_beats_all"] = {
        "fraction": float(np.mean(wins)),
        "pass": bool(np.mean(wins) >= 0.5),
    }
    save_result("claims_compression", checks)
    return checks
