"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

Runs one benchmark per paper table/figure + the roofline assembly, prints
compact tables, validates the paper's claims (C1..C6), and writes JSON to
artifacts/bench/.  ``--quick`` shrinks sizes for CI-speed runs; ``--full``
uses Table II row counts where tractable.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

from . import (
    bench_analytics,
    bench_backends,
    bench_compression,
    bench_fleet,
    bench_kbstore,
    bench_progressive,
    bench_ragged,
    bench_robustness,
    bench_roofline,
    bench_scaling,
    bench_sensitivity,
    bench_streaming,
    bench_throughput,
)

_REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _fmt_cr_table(fig, methods) -> str:
    lines = []
    for name, row in fig.items():
        if not row["eps"]:
            continue
        strict = {m: row[m][-1] for m in methods if m in row}
        loose = {m: row[m][0] for m in methods if m in row}
        lines.append(
            f"  {name:14s} loosest: "
            + "  ".join(f"{m}={loose[m]:7.1f}" for m in loose)
            + f"   strictest: "
            + "  ".join(f"{m}={strict[m]:7.1f}" for m in strict)
            + f"   lossless(SHRINK)={row['SHRINK_lossless']:.1f}"
        )
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="small sizes (CI)")
    ap.add_argument("--full", action="store_true", help="Table II row counts")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args(argv)

    n6 = 20_000 if args.quick else (None if args.full else 100_000)
    n7 = 10_000 if args.quick else (50_000 if not args.full else 200_000)
    n8 = 20_000 if args.quick else (100_000 if not args.full else None)
    n_sens = 30_000 if args.quick else 200_000
    sizes10 = (
        (20_000, 50_000, 100_000)
        if args.quick
        else (50_000, 100_000, 250_000, 500_000, 1_000_000, 2_000_000)
    )
    n11 = 10_000 if args.quick else 50_000

    t0 = time.time()
    print("== Fig 6: vs Sim-Piece / APCA (piecewise lossy) ==")
    fig6 = bench_compression.fig6_piecewise_lossy(n=n6)
    print(_fmt_cr_table(fig6, ["SHRINK", "SimPiece", "APCA"]))

    print("\n== Fig 7: vs LFZip / HIRE (general lossy) ==")
    fig7 = bench_compression.fig7_general_lossy(n=n7)
    print(_fmt_cr_table(fig7, ["SHRINK", "LFZip", "HIRE"]))

    print("\n== Fig 8: lossless ==")
    fig8 = bench_compression.fig8_lossless(n=n8)
    for name, row in fig8.items():
        print("  " + name.ljust(14) + "  ".join(f"{k}={v:6.2f}" for k, v in sorted(row.items())))

    checks = bench_compression.validate_claims(fig6, fig7, fig8)

    print("\n== Fig 9: eps_b sensitivity ==")
    fig9 = bench_sensitivity.fig9_eps_b_effect(n=n_sens)
    for k, v in fig9.items():
        if k != "eps":
            print(f"  {k}: CR={['%.1f' % c for c in v['cr']]} base={v['base_bytes']}B k={v['k_subbases']}")

    print("\n== Fig 12: lambda sensitivity ==")
    fig12 = bench_sensitivity.fig12_lambda_effect(n=n_sens)
    for k, v in fig12.items():
        print(f"  lambda={k}: CR={v['cr']:.1f} latency={v['latency_s']:.2f}s segments={v['segments']}")
    checks.update(bench_sensitivity.validate_claims(fig9, fig12))

    print("\n== Fig 10: size scaling ==")
    fig10 = bench_scaling.fig10_size_scaling(sizes=sizes10)
    for i, n in enumerate(fig10["sizes"]):
        print(
            f"  n={n:9d} dict={fig10['dict_bytes'][i]:7d}B (k={fig10['k_subbases'][i]:5d}) "
            f"timestamps={fig10['timestamp_bytes'][i]:9d}B residual={fig10['residual_bytes'][i]:10d}B "
            f"CR(lossless)={fig10['cr_lossless'][i]:6.2f}"
        )
    checks.update(bench_scaling.validate_claims(fig10))

    print("\n== Fig 11 / Table III: throughput ==")
    fig11 = bench_throughput.fig11_throughput(n=n11)
    for name, row in fig11.items():
        print("  " + name.ljust(14) + "  ".join(f"{k}={v:6.2f}MB/s" for k, v in sorted(row.items())))
    t3 = bench_throughput.table3_latency(n=n11)
    checks.update(bench_throughput.validate_claims(fig11))

    print("\n== Engine throughput (entropy backends + batched pipeline) ==")
    engine = bench_throughput.throughput_json(quick=args.quick)
    for backend, row in engine["entropy_backends"].items():
        if isinstance(row, dict):
            print(
                f"  entropy[{backend:4s}] enc={row['encode_mb_s']:8.2f}MB/s "
                f"dec={row['decode_mb_s']:8.2f}MB/s size={row['bytes']}B"
            )
    ek = engine["entropy_kernel"]
    print(
        f"  kernel[n={ek['symbols']}] "
        f"device={ek['device']['roundtrip_mb_s']:.2f}MB/s "
        f"numpy={ek['numpy']['roundtrip_mb_s']:.2f}MB/s "
        f"({ek['vs_numpy']:.2f}x, bytes_identical={ek['bytes_identical']})"
    )
    bp = engine["batched_pipeline"]
    print(
        f"  batch[{bp['series']}x{bp['points_per_series']}] "
        f"batch={bp['batch_mb_s']:.2f}MB/s loop={bp['loop_mb_s']:.2f}MB/s "
        f"speedup={bp['batch_speedup']:.2f}x"
    )
    checks.update(bench_throughput.validate_engine_claims(engine))

    print("\n== Adaptive entropy dispatch (cost-model routing vs all-rans) ==")
    adaptive = bench_backends.adaptive_json(quick=args.quick)
    engine["adaptive"] = adaptive
    mix = "  ".join(
        f"{b}={d['streams']}" for b, d in sorted(adaptive["adaptive"]["routing"].items())
    )
    print(
        f"  corpus[{adaptive['series']}x{adaptive['points_per_series']}] "
        f"adaptive={adaptive['adaptive']['archive_bytes']:,}B "
        f"all-rans={adaptive['forced_rans']['archive_bytes']:,}B "
        f"(cr_ratio={adaptive['cr_ratio']:.3f})"
    )
    print(
        f"  encode: adaptive={adaptive['adaptive']['encode_mb_s']:.2f}MB/s "
        f"all-rans={adaptive['forced_rans']['encode_mb_s']:.2f}MB/s "
        f"(speed_ratio={adaptive['speed_ratio']:.2f})  streams: {mix}"
    )
    checks.update(bench_backends.validate_claims(adaptive))

    print("\n== Streaming ingest (chunked scan + framed container) ==")
    stream = bench_streaming.streaming_json(quick=args.quick)
    engine["streaming"] = stream
    ing = stream["ingest"]
    chunk_cols = "  ".join(
        f"{k.removeprefix('chunk_').removesuffix('_mb_s')}={v:.1f}MB/s"
        for k, v in ing.items() if k.startswith("chunk_")
    )
    print(
        f"  ingest[{ing['series']}x{ing['points_per_series']}] "
        f"one-shot={ing['one_shot_mb_s']:.1f}MB/s  {chunk_cols} "
        f"({ing['stream_vs_one_shot']:.2f}x one-shot)"
    )
    crg = stream["cr_growth"]
    for i, n in enumerate(crg["lengths"]):
        print(
            f"  n={n:8d}  CR(lossless)={crg['cr_lossless'][i]:6.2f} "
            f"CR(eps=1e-3)={crg['cr_eps1e-3'][i]:6.2f}"
        )
    checks.update(bench_streaming.validate_claims(stream))

    print("\n== Ragged multi-series ingest (bucketed batch + scheduler) ==")
    ragged = bench_ragged.ragged_json(quick=args.quick)
    engine["ragged"] = ragged
    rp = ragged["pipeline"]
    print(
        f"  ragged[{rp['series']} series, len {rp['len_min']}..{rp['len_max']}] "
        f"batch={rp['batch_mb_s']:.2f}MB/s loop={rp['loop_mb_s']:.2f}MB/s "
        f"speedup={rp['batch_speedup']:.2f}x"
    )
    rs = ragged["scheduler"]
    print(
        f"  scheduler[{rs['series']} sensors, {rs['samples']} samples] "
        f"ingest={rs['ingest_mb_s']:.2f}MB/s (admission + SHRKS assembly)"
    )
    checks.update(bench_ragged.validate_claims(ragged))

    print("\n== Progressive pyramid (layered archive + tiered decode) ==")
    prog = bench_progressive.progressive_json(quick=args.quick)
    engine["progressive"] = prog
    for name, row in prog["archive"]["datasets"].items():
        print(
            f"  {name:10s} pyramid={row['pyramid_residual_bytes']:9,d}B "
            f"independent={row['independent_residual_bytes']:9,d}B "
            f"({row['pyramid_vs_independent']:.2f}x)"
        )
    dec = prog["decode"]
    tier_cols = "  ".join(
        f"{k}={v:.1f}MB/s" for k, v in dec["decode_mb_s"].items()
    )
    print(f"  decode[{dec['dataset']}] {tier_cols}")
    print(
        f"  refine coarse->lossless {dec['refine_coarse_to_lossless_mb_s']:.1f}MB/s "
        f"vs cold {dec['cold_lossless_mb_s']:.1f}MB/s "
        f"({dec['refine_vs_cold']:.2f}x)"
    )
    checks.update(bench_progressive.validate_claims(prog))

    print("\n== Compressed-domain analytics (segment algebra + refine planner) ==")
    analytics = bench_analytics.analytics_json(quick=args.quick)
    engine["analytics"] = analytics
    for name, row in analytics["segment_vs_decode"]["datasets"].items():
        worst = min(row["ops"], key=lambda o: row["ops"][o]["speedup"])
        print(
            f"  {name:10s} segments={row['segments']:6d} "
            f"min speedup={row['min_speedup']:6.1f}x (op={worst}) "
            f"eps_b={row['eps_b_practical']:.3g} <= eps_q={row['eps_query']:.3g}"
        )
    pred = analytics["predicate"]
    print(
        f"  predicate[{pred['dataset']}] {pred['queries_per_s']:.0f} q/s exact counts, "
        f"refined {pred['frames_refined']}/{pred['frames_touched']} frames "
        f"({pred['mb_covered_per_s']:.0f} MB/s covered)"
    )
    checks.update(bench_analytics.validate_claims(analytics))

    print("\n== Robustness (CRC overhead, degraded path, chaos campaign) ==")
    rob = bench_robustness.robustness_json(quick=args.quick)
    engine["robustness"] = rob
    io_ = rob["integrity_overhead"]
    print(
        f"  integrity[{io_['series']}x{io_['points_per_series']}] "
        f"decode={io_['decode_mb_s']:.1f}MB/s "
        f"crc sweep={io_['crc_sweep_s']*1e3:.2f}ms "
        f"({io_['crc_overhead_frac']*100:.1f}% of decode)"
    )
    dp = rob["degraded_path"]
    print(
        f"  degraded path: healthy={dp['healthy_ms']:.2f}ms "
        f"corrupt-layer={dp['degraded_ms']:.2f}ms "
        f"({dp['degraded_vs_healthy']:.2f}x)"
    )
    cc = rob["chaos_campaign"]
    print(
        f"  chaos[{cc['rounds']} faults] {cc['queries_checked']} answers checked "
        f"({cc['queries_per_s']:.0f} q/s): {cc['ok']} ok, {cc['degraded']} degraded, "
        f"{cc['typed_error']} typed errors, {cc['rejected_at_parse']} parse rejects, "
        f"{cc['silent']} SILENT"
    )
    checks.update(bench_robustness.validate_claims(rob))

    print("\n== Sharded serving fleet (scaling, tenancy, cross-shard diff) ==")
    fl = bench_fleet.fleet_json(quick=args.quick)
    engine["fleet"] = fl
    one, four = fl["one_shard"], fl["four_shards"]
    print(
        f"  workload[{fl['workload']['series']} series, "
        f"{fl['workload']['samples']:,} samples, {fl['workload']['mb']:.1f}MB, "
        f"{fl['workload']['quota_rejected_ingest']} quota-rejected]"
    )
    print(
        f"  1 shard : {one['agg_mb_s']:6.1f}MB/s  "
        f"ingest p50={one['ingest_p50_ms']:.2f}ms p99={one['ingest_p99_ms']:.2f}ms  "
        f"query p50={one['query_p50_ms']:.2f}ms p99={one['query_p99_ms']:.2f}ms"
    )
    print(
        f"  4 shards: {four['agg_mb_s']:6.1f}MB/s  "
        f"ingest p50={four['ingest_p50_ms']:.2f}ms p99={four['ingest_p99_ms']:.2f}ms  "
        f"query p50={four['query_p50_ms']:.2f}ms p99={four['query_p99_ms']:.2f}ms  "
        f"(critical-path scaling {fl['scaling_1_to_4']:.2f}x)"
    )
    q, k = four["queries"], four["shard_kill"]
    print(
        f"  diff: {q['ok']} ok / {q['degraded']} degraded / {q['error']} typed / "
        f"{q['SILENT']} SILENT; shard-kill [{k.get('fault', '')}] "
        f"{k['ok']} ok / {k['degraded']} degraded / {k['error']} typed / "
        f"{k['SILENT']} SILENT; byte mismatches={fl['byte_mismatch']}"
    )
    checks.update(bench_fleet.validate_claims(fl))

    print("\n== Cross-archive KB store (shared dictionary vs per-archive) ==")
    kbs = bench_kbstore.kbstore_json(quick=args.quick)
    engine["kbstore"] = kbs
    print(
        f"  corpus[{kbs['corpus']['archives']} archives, "
        f"{kbs['corpus']['samples']:,} samples]  "
        f"inline={kbs['inline']['total_bytes']:,}B "
        f"(KB share {kbs['inline']['kb_share']:.1%})"
    )
    print(
        f"  shared={kbs['shared']['total_bytes']:,}B "
        f"({kbs['shared']['container_bytes']:,}B containers + "
        f"{kbs['shared']['snapshot_bytes']:,}B snapshot; "
        f"{kbs['shared']['store_live_entries']} live entries, "
        f"dedup {kbs['shared']['store_dedup_ratio']:.1f}x)  "
        f"CR={kbs['cr_shared_over_inline']:.3f}"
    )
    print(
        f"  lifecycle: compacted {kbs['compaction']['dropped_entries']} entries, "
        f"rebased {kbs['compaction']['rebased_containers']} containers; "
        f"decode mismatches={kbs['decode_mismatches']}, "
        f"KB-view mismatches={kbs['kb_view_mismatches']}"
    )
    checks.update(bench_kbstore.validate_claims(kbs))
    # machine-readable perf trajectory for future PRs to diff against; only
    # full-size runs update the repo-root trajectory (quick numbers live in
    # artifacts/bench via save_result and must not clobber the baseline)
    if not args.quick:
        (_REPO_ROOT / "BENCH_throughput.json").write_text(json.dumps(engine, indent=2))
        print(f"  wrote {_REPO_ROOT / 'BENCH_throughput.json'}")

    if not args.skip_roofline:
        print("\n== Roofline (from dry-run artifacts) ==")
        try:
            bench_roofline.run()
        except Exception as e:  # dry-run artifacts may not exist yet
            print(f"  (skipped: {e})")

    print("\n== Paper-claim checks ==")
    ok = True
    for k, v in checks.items():
        status = "PASS" if v.get("pass") else "FAIL"
        ok = ok and v.get("pass", False)
        print(f"  [{status}] {k}: { {kk: vv for kk, vv in v.items() if kk != 'pass'} }")
    print(f"\ntotal bench time: {time.time()-t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
