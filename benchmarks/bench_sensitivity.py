"""Hyper-parameter sensitivity: Fig. 9 (base error threshold eps_b) and
Fig. 12 (default interval length lambda)."""
from __future__ import annotations

import numpy as np

from repro.core import ShrinkCodec
from repro.data.synthetic import DATASETS

from .datasets import Timer, bench_series, cr, save_result


def fig9_eps_b_effect(n=200_000, dataset="WindSpeed") -> dict:
    """CR vs eps_b in {5%, 8%, 10%} of range at several eps (paper: CR
    rises as eps_b relaxes — base/residual trade-off)."""
    v = bench_series(dataset, n)
    d = DATASETS[dataset].decimals
    rng = float(v.max() - v.min())
    eps_list = [e * rng for e in (0.01, 0.005, 0.001)]
    out = {"eps": eps_list}
    for frac in (0.05, 0.08, 0.10):
        codec = ShrinkCodec.from_fraction(v, frac=frac, backend="rans")
        cs = codec.compress(v, eps_targets=eps_list)
        out[f"eps_b={int(frac*100)}%"] = {
            "cr": [cr(len(v), cs.size_at(e)) for e in eps_list],
            "base_bytes": len(cs.base_bytes),
            "k_subbases": cs.base.k,
        }
    save_result("fig9_eps_b", out)
    return out


def fig12_lambda_effect(n=200_000, dataset="WindSpeed") -> dict:
    """CR + compression latency vs lambda (paper: smaller lambda -> higher
    CR and lower latency)."""
    v = bench_series(dataset, n)
    rng = float(v.max() - v.min())
    eps = 0.001 * rng
    out = {}
    for lam in (1e-6, 1e-5, 1e-4, 1e-3, 1e-2):
        codec = ShrinkCodec(
            config=type(ShrinkCodec.from_fraction(v).config)(
                eps_b=0.05 * rng, lam=lam
            ),
            backend="rans",
        )
        with Timer() as t:
            cs = codec.compress(v, eps_targets=[eps])
        out[f"{lam:.0e}"] = {
            "cr": cr(len(v), cs.size_at(eps)),
            "latency_s": t.seconds,
            "k_subbases": cs.base.k,
            "segments": cs.base.segment_count(),
        }
    save_result("fig12_lambda", out)
    return out


def validate_claims(fig9, fig12) -> dict:
    checks = {}
    # C4: CR rises as eps_b relaxes (at the loosest eps)
    crs = [fig9[f"eps_b={p}%"]["cr"][0] for p in (5, 8, 10)]
    checks["C4_cr_rises_with_eps_b"] = {
        "crs": crs,
        "pass": bool(crs[0] <= crs[2] * 1.05),
    }
    lam_keys = sorted(fig12.keys(), key=float)
    crs12 = [fig12[k]["cr"] for k in lam_keys]
    lats = [fig12[k]["latency_s"] for k in lam_keys]
    # C5: smaller lambda -> CR no worse, latency no worse (monotone trend)
    checks["C5_small_lambda_better"] = {
        "cr_by_lambda": dict(zip(lam_keys, crs12)),
        "latency_by_lambda": dict(zip(lam_keys, lats)),
        "pass": bool(crs12[0] >= crs12[-1] * 0.95),
    }
    save_result("claims_sensitivity", checks)
    return checks
