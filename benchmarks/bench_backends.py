"""Adaptive entropy dispatch vs forced-rans on a mixed corpus.

The cost-model dispatcher (``backend='best'``) routes each residual
stream to the backend with the smallest *predicted* encoding: short and
low-width streams to the ``bitpack`` packer (18 B header vs the rANS
machine's ~313 B of bitmap/state overhead), high-entropy streams to the
fused rANS machines, run-structured streams to zstd where the extra is
installed.  This benchmark drives the full codec (base + pyramid +
container) over a corpus mixing the regimes the gateway actually sees —
smooth analog drift, noise-dominated walks, coarse ADC plateaus, and
near-constant quantized sensors — once with every stream forced to rans
and once adaptively, and validates two claims:

* ``C_adaptive_cr``: the adaptive archive is <= 0.95x the all-rans
  archive over the corpus (routing must pay for itself in bytes);
* ``C_adaptive_not_slower``: adaptive aggregate encode throughput stays
  >= 0.95x all-rans (the O(n) cost model plus group splitting must not
  tax the encode path, because bitpack encodes faster than rans).

Frame sizes are deliberately gateway-sized (2k samples); per-stream
header overhead is exactly the regime adaptive dispatch exists for.
Larger frames amortize the rANS overhead and the two paths converge —
that regime is already covered by ``bench_throughput``.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import ShrinkCodec
from repro.core.shrink import cs_to_bytes
from repro.core.types import merge_backend_stats

from .datasets import save_result

# relative eps ladder: three lossy tiers + lossless, so every series
# contributes four residual streams with very different statistics
_EPS_LADDER = (2e-2, 5e-3, 1e-3, 0.0)


def _smooth(rng: np.random.Generator, n: int) -> np.ndarray:
    t = np.arange(n)
    v = np.sin(t / 180.0) * 4.0 + t / n * 2.0 + rng.standard_normal(n) * 0.01
    return np.round(v, 4)


def _noisy(rng: np.random.Generator, n: int) -> np.ndarray:
    v = np.cumsum(rng.standard_normal(n) * 0.05) + rng.standard_normal(n) * 0.5
    return np.round(v, 4)


def _quantized(rng: np.random.Generator, n: int) -> np.ndarray:
    """Coarse ADC: step levels on a 0.5 grid + one-LSB dither."""
    steps = np.repeat(rng.integers(-40, 40, size=max(1, n // 128)), 128)[:n]
    v = steps * 0.5 + np.round(rng.standard_normal(n), 0) * 0.5
    return np.round(v, 4)


def _plateau(rng: np.random.Generator, n: int) -> np.ndarray:
    """Near-constant quantized sensor (IoT temperature-style): long
    holds, occasional step, readings on a 0.01 grid."""
    steps = np.repeat(rng.normal(21.0, 0.8, size=max(1, n // 512)), 512)[:n]
    v = steps + rng.standard_normal(n) * 0.005
    return np.round(v, 2)


FAMILIES = {
    "smooth": _smooth,
    "noisy": _noisy,
    "quantized": _quantized,
    "plateau": _plateau,
}


def _corpus(n_each: int, per_family: int, seed: int = 20260808) -> list:
    rng = np.random.default_rng(seed)
    return [
        (name, fn(rng, n_each))
        for _ in range(per_family)
        for name, fn in FAMILIES.items()
    ]


def _corpus_pass(corpus: list, backend: str) -> tuple[int, dict, dict]:
    """One full compress of the corpus under one backend policy; returns
    (total archive bytes, per-family bytes, realized backend routing)."""
    total = 0
    per_family: dict[str, int] = {}
    routing: dict[str, dict[str, int]] = {}
    for name, v in corpus:
        codec = ShrinkCodec.from_fraction(v, frac=0.05, backend=backend)
        rngv = max(float(v.max() - v.min()), 1e-9)
        cs = codec.compress(
            v, eps_targets=[e * rngv for e in _EPS_LADDER], decimals=4
        )
        b = len(cs_to_bytes(cs))
        total += b
        per_family[name] = per_family.get(name, 0) + b
        merge_backend_stats(routing, cs.backend_stats())
    return total, per_family, routing


def _measure(corpus: list, backends: tuple[str, ...], reps: int = 5) -> dict:
    """Archive bytes (deterministic, from the warm pass) + best-of-``reps``
    aggregate encode throughput per backend policy.  The warm pass runs
    first so jit shape compiles for the grouped batch machines never land
    in the timed region, and the timed passes INTERLEAVE the policies so
    a noisy-neighbor slowdown on a shared box biases both sides equally
    instead of whichever policy happened to run second."""
    out = {}
    for backend in backends:  # warm + bytes
        total, per_family, routing = _corpus_pass(corpus, backend)
        out[backend] = {
            "archive_bytes": total,
            "per_family_bytes": per_family,
            "routing": routing,
            "_best_t": float("inf"),
        }
    for _ in range(reps):
        for backend in backends:
            t0 = time.perf_counter()
            _corpus_pass(corpus, backend)
            dt = time.perf_counter() - t0
            out[backend]["_best_t"] = min(out[backend]["_best_t"], dt)
    mb = sum(len(v) for _, v in corpus) * 16 / 1e6
    for row in out.values():
        row["encode_mb_s"] = mb / row.pop("_best_t")
    return out


def adaptive_json(quick: bool = False) -> dict:
    """The machine-readable adaptive-dispatch trajectory for
    BENCH_throughput.json: all-rans vs cost-model routing on the same
    corpus, plus the realized per-backend stream/byte mix."""
    n_each, per_family = (1024, 2) if quick else (2048, 4)
    corpus = _corpus(n_each, per_family)
    measured = _measure(corpus, ("rans", "best"), reps=3 if quick else 5)
    rans, best = measured["rans"], measured["best"]
    out = {
        "workload": "quick" if quick else "full",
        "series": len(corpus),
        "points_per_series": n_each,
        "families": sorted(FAMILIES),
        "eps_ladder_rel": list(_EPS_LADDER),
        "forced_rans": rans,
        "adaptive": best,
        "cr_ratio": best["archive_bytes"] / rans["archive_bytes"],
        "speed_ratio": best["encode_mb_s"] / rans["encode_mb_s"],
    }
    save_result("adaptive_backends", out)
    return out


def validate_claims(adaptive: dict) -> dict:
    routing = adaptive["adaptive"]["routing"]
    checks = {
        "C_adaptive_cr": {
            "adaptive_bytes": adaptive["adaptive"]["archive_bytes"],
            "forced_rans_bytes": adaptive["forced_rans"]["archive_bytes"],
            "cr_ratio": round(float(adaptive["cr_ratio"]), 4),
            "routing": {b: d["streams"] for b, d in sorted(routing.items())},
            "pass": bool(adaptive["cr_ratio"] <= 0.95),
        },
        "C_adaptive_not_slower": {
            "adaptive_mb_s": round(float(adaptive["adaptive"]["encode_mb_s"]), 2),
            "forced_rans_mb_s": round(float(adaptive["forced_rans"]["encode_mb_s"]), 2),
            "speed_ratio": round(float(adaptive["speed_ratio"]), 3),
            "pass": bool(adaptive["speed_ratio"] >= 0.95),
        },
    }
    save_result("claims_adaptive", checks)
    return checks
