"""Fault-tolerant serving gateway over a ``SHRKS`` container.

:class:`FaultTolerantGateway` fronts a :class:`RangeQueryBatcher`
(degraded-mode enabled) with the operational armor an edge deployment
needs — every knob deterministic and injectable for tests:

* **retry** — :class:`RetryPolicy`: exponential backoff with jitter on an
  injectable clock/sleep/RNG.  ONLY :class:`TransientError` is retried;
  corruption errors are permanent facts about bytes and retrying them
  would just burn the deadline (they feed the breaker instead).
* **circuit breaker** — :class:`CircuitBreaker`, keyed per frame: a frame
  that keeps failing stops being attempted for ``recovery_s`` (one trial
  call is let through after the window — classic half-open).
* **deadlines** — ``serve(q, deadline_s=...)`` checks the clock before
  every decode attempt and every backoff sleep; an exceeded deadline is a
  typed :class:`DeadlineExceededError`, never a silent stall.
* **backpressure** — the admission queue is bounded; beyond it requests
  are *shed to coarse*: re-admitted at ``coarse_eps`` (segment-tier
  service, marked ``degraded``) instead of queued, or rejected with
  :class:`BackpressureError` when no coarse tier is configured.

Corruption handling rides on the batcher's scoped degradation
(``degraded_ok=True``): a corrupt layer/frame yields a flagged coarser
answer with a valid bound (docs/robustness.md), not an error — only a
frame whose base cannot be proven intact errors.

Fault injection hooks: ``gw.frame_decode`` is the per-(frame, eps) decode
step; tests and ``--mode chaos`` wrap it in a
:class:`repro.testing.chaos.FlakyCallable` to exercise the retry path.
"""
from __future__ import annotations

import dataclasses
import random
import time
from collections import deque
from typing import Callable, Optional

import numpy as np

from ..core.errors import (
    BackpressureError,
    CircuitOpenError,
    DeadlineExceededError,
    RangeCoverageError,
    ShrinkError,
    TransientError,
)
from .batching import RangeQuery, RangeQueryBatcher

__all__ = ["RetryPolicy", "CircuitBreaker", "FaultTolerantGateway"]


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff with jitter: attempt k (0-based) sleeps
    ``min(base * multiplier**k, max_delay) * (1 ± jitter)``."""

    max_attempts: int = 3
    base_delay_s: float = 0.05
    multiplier: float = 2.0
    max_delay_s: float = 2.0
    jitter: float = 0.25  # fraction of the delay, uniform both ways

    def delay_s(self, attempt: int, rng: random.Random) -> float:
        d = min(self.base_delay_s * self.multiplier**attempt, self.max_delay_s)
        return max(0.0, d * (1.0 + self.jitter * (2.0 * rng.random() - 1.0)))


class CircuitBreaker:
    """Per-key consecutive-failure breaker with a half-open recovery trial.

    ``failure_threshold`` consecutive failures open the circuit for
    ``recovery_s`` (on the injected clock); the first call after the
    window is allowed through as a trial — success closes the circuit,
    failure re-opens it for another window."""

    def __init__(
        self,
        failure_threshold: int = 3,
        recovery_s: float = 30.0,
        clock: Callable[[], float] = time.monotonic,
    ):
        if failure_threshold < 1:
            raise ValueError(f"failure_threshold must be >= 1, got {failure_threshold}")
        self.failure_threshold = failure_threshold
        self.recovery_s = recovery_s
        self._clock = clock
        self._failures: dict = {}
        self._opened_at: dict = {}

    def allow(self, key) -> bool:
        opened = self._opened_at.get(key)
        if opened is None:
            return True
        if self._clock() - opened >= self.recovery_s:
            # half-open: let one trial through; a failure re-opens
            del self._opened_at[key]
            self._failures[key] = self.failure_threshold - 1
            return True
        return False

    def record_success(self, key) -> None:
        self._failures.pop(key, None)
        self._opened_at.pop(key, None)

    def record_failure(self, key) -> None:
        n = self._failures.get(key, 0) + 1
        self._failures[key] = n
        if n >= self.failure_threshold:
            self._opened_at[key] = self._clock()

    def is_open(self, key) -> bool:
        opened = self._opened_at.get(key)
        return opened is not None and self._clock() - opened < self.recovery_s


class FaultTolerantGateway:
    """Hardened range-query service: bounded admission, retries with
    backoff, per-frame circuit breaking, deadlines, scoped degradation."""

    def __init__(
        self,
        blob: bytes,
        retry: RetryPolicy | None = None,
        breaker: CircuitBreaker | None = None,
        max_queue: int = 256,
        coarse_eps: Optional[float] = float("inf"),
        cache_frames: int = 32,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] | None = None,
        seed: int = 0,
    ):
        self.batcher = RangeQueryBatcher(
            blob, cache_frames=cache_frames, degraded_ok=True
        )
        self.retry = retry if retry is not None else RetryPolicy()
        self.breaker = (
            breaker if breaker is not None else CircuitBreaker(clock=clock)
        )
        self.max_queue = max_queue
        self.coarse_eps = coarse_eps
        self._clock = clock
        self._sleep = sleep if sleep is not None else time.sleep
        self._rng = random.Random(seed)
        self.queue: deque[RangeQuery] = deque()
        self._shed_qids: set[int] = set()
        self.completed: list[RangeQuery] = []
        # the injectable decode step: chaos tests wrap this in a
        # FlakyCallable to make it raise TransientError / run slow
        self.frame_decode: Callable = self.batcher._decoded_frame
        self.stats = {
            "queries": 0,
            "retries": 0,
            "transient_failures": 0,
            "breaker_opens": 0,
            "breaker_skips": 0,
            "deadline_exceeded": 0,
            "shed": 0,
            "rejected": 0,
            "degraded": 0,
            "errors": 0,
        }

    # -- admission ------------------------------------------------------ #
    def submit(self, q: RangeQuery) -> None:
        """Admit a query.  Beyond ``max_queue`` pending requests the query
        is *shed to coarse*: re-admitted at ``coarse_eps`` (it will be
        answered from segment-tier data, flagged degraded) — or rejected
        with :class:`BackpressureError` when no coarse tier is set."""
        if len(self.queue) >= self.max_queue:
            if self.coarse_eps is None:
                self.stats["rejected"] += 1
                raise BackpressureError(
                    f"admission queue full ({self.max_queue} pending)",
                    series_id=q.series_id,
                )
            q.eps = max(q.eps, self.coarse_eps)
            self._shed_qids.add(q.qid)
            self.stats["shed"] += 1
        self.queue.append(q)

    # -- serving --------------------------------------------------------- #
    def serve(self, q: RangeQuery, deadline_s: float | None = None) -> RangeQuery:
        """Serve one query end to end; failures land in ``q.error`` as the
        typed error's message (the exception type name prefixed), never an
        unhandled raise."""
        self.stats["queries"] += 1
        t_start = self._clock()
        try:
            self._serve_inner(q, t_start, deadline_s)
            if q.qid in self._shed_qids:
                q.degraded = True
            if q.degraded:
                self.stats["degraded"] += 1
        except ShrinkError as e:
            q.error = f"{type(e).__name__}: {e}"
            self.stats["errors"] += 1
        self.completed.append(q)
        return q

    def _serve_inner(
        self, q: RangeQuery, t_start: float, deadline_s: float | None
    ) -> None:
        touched = self.batcher.frames_overlapping(q.series_id, q.t0, q.t1)
        out = np.empty(q.t1 - q.t0, dtype=np.float64)
        achieved = 0.0
        degraded = False
        expected = q.t0
        for i, m in enumerate(touched):
            if m.t_lo > expected:
                raise RangeCoverageError(
                    f"gap in series {q.series_id} frames at sample {expected}",
                    series_id=q.series_id, frame_index=i,
                )
            vals, g, frame_degraded = self._decode_with_retry(
                m, q.eps, t_start, deadline_s
            )
            achieved = max(achieved, g)
            degraded = degraded or frame_degraded
            lo, hi = max(q.t0, m.t_lo), min(q.t1, m.t_hi)
            out[lo - q.t0 : hi - q.t0] = vals[lo - m.t_lo : hi - m.t_lo]
            expected = hi
        q.result = out
        q.achieved = achieved
        q.degraded = degraded

    def _check_deadline(
        self, t_start: float, deadline_s: float | None, doing: str
    ) -> None:
        if deadline_s is not None and self._clock() - t_start >= deadline_s:
            self.stats["deadline_exceeded"] += 1
            raise DeadlineExceededError(
                f"deadline of {deadline_s:g}s exceeded while {doing}"
            )

    def _decode_with_retry(
        self, meta, eps: float, t_start: float, deadline_s: float | None
    ):
        key = meta.offset
        if not self.breaker.allow(key):
            self.stats["breaker_skips"] += 1
            raise CircuitOpenError(
                f"circuit open for frame at offset {key}",
                series_id=meta.series_id, offset=key,
            )
        last: TransientError | None = None
        for attempt in range(self.retry.max_attempts):
            self._check_deadline(t_start, deadline_s, "decoding frame")
            try:
                result = self.frame_decode(meta, eps)
            except TransientError as e:
                self.stats["transient_failures"] += 1
                was_open = self.breaker.is_open(key)
                self.breaker.record_failure(key)
                if self.breaker.is_open(key) and not was_open:
                    self.stats["breaker_opens"] += 1
                last = e
                if attempt + 1 < self.retry.max_attempts:
                    self.stats["retries"] += 1
                    self._check_deadline(t_start, deadline_s, "backing off")
                    self._sleep(self.retry.delay_s(attempt, self._rng))
                continue
            # corruption errors propagate: they are permanent, retrying
            # cannot fix bytes, and the batcher has already degraded
            # everything degradable before raising
            self.breaker.record_success(key)
            return result
        raise last

    def run(self, deadline_s: float | None = None) -> list[RangeQuery]:
        """Drain the admission queue; each query gets its own deadline."""
        done = []
        while self.queue:
            done.append(self.serve(self.queue.popleft(), deadline_s=deadline_s))
        return done
