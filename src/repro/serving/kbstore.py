"""Persistent cross-archive knowledge-base store.

The paper's central claim — compression ratio *grows* with data size as
semantic lines repeat — stops at the container boundary everywhere else
in this repo: each SHRKS archive carries its own private
:class:`~repro.core.streaming.KnowledgeBase` in its footer, so repetition
across archives, tenants, and fleet shards is never harvested.
:class:`KBStore` is the missing durable dictionary:

* **One ref-counted id space.**  ``attach_kb`` folds a container's KB into
  the store (``KnowledgeBase.merge`` semantics: identical lines dedup to
  one entry, refcounts sum) and records *exactly* which store entries the
  attachment references with which counts, so ``detach`` reverses it to
  the reference.  Re-attaching under the same handle (a shard gossiping a
  grown KB, a codec re-finalizing) first releases the previous
  contribution — repeated syncs never double-count.

* **Versioned snapshots containers reference by id.**  Every attach seals
  a :class:`StoreSnapshot` — an ``SHKS`` blob (CRC-sealed wrapper around
  the existing ``SHKB`` layout, normative spec in docs/wire-format.md) —
  and hands back a :class:`~repro.core.serialize.KBSnapshotRef` for the
  container footer.  A ref pins the snapshot ``version``, the total id
  space, the order-invariant semantic id, and the container-local →
  store id ``remap`` with per-entry refcounts, so ``container_kb``
  rebuilds the container's private KB view bit-for-bit from the store
  alone and ``resolve`` can *prove* a ref matches before binding
  (:class:`~repro.core.errors.StaleSnapshotError` otherwise, never a
  silent wrong dictionary).  Ref-mode containers omit the inline footer
  KB — that is the cross-archive byte win (``benchmarks/bench_kbstore.py``,
  claim ``C_kbstore_cr``); writers can also keep the inline copy
  (``inline_kb=True``) as a self-contained fallback.

* **Eviction, spill/load, compaction.**  Zero-ref entries not pinned by
  any live attachment are evicted LRU when ``max_entries`` is exceeded —
  eviction *tombstones* the id (the positional id space never shifts
  under a live container).  ``spill``/``load`` persist the versioned
  snapshots to disk and restore a store from them (attach handles are
  runtime state and are not persisted).  ``compact`` drops tombstones,
  renumbers the surviving entries, reseals one compacted snapshot, and
  re-bases every registered ref-mode container onto it — the rewrite is
  verified byte-identical over the whole frame region before the old
  container is replaced, so decode is provably unchanged.

Decode never *requires* the store (each SHRK frame payload carries its
own base); the KB is the dedup/routing dictionary.  The store therefore
fails loudly on identity mismatches and otherwise stays out of the read
path.
"""
from __future__ import annotations

import dataclasses
import pathlib
import struct
import zlib

from ..core.errors import (
    ConfigError,
    CorruptFrameError,
    FormatError,
    KBReferenceError,
    ShrinkError,
    StaleSnapshotError,
    TruncatedArchiveError,
)
from ..core.serialize import (
    FramedWriter,
    KBSnapshotRef,
    frame_payload,
    parse_framed_container,
    read_snapshot_ref,
    read_varint,
    write_varint,
)
from ..core.streaming import KBEntry, KnowledgeBase, _slope_key
from ..core.types import ShrinkConfig

__all__ = [
    "KBStore",
    "StoreSnapshot",
    "AttachRecord",
    "snapshot_to_bytes",
    "snapshot_from_bytes",
    "resolve_container_kb",
]

_SNAP_MAGIC = b"SHKS"
_SNAP_VERSION = 1
_TAIL_LEN = 16  # SHRKS tail: u64 footer offset + u32 footer crc + end magic


@dataclasses.dataclass(frozen=True)
class StoreSnapshot:
    """One sealed, immutable store state: ``version`` is the monotonic
    snapshot counter, ``entries`` the total positional id space (live +
    tombstoned), ``sem_id`` the order-invariant semantic identity of the
    live lines, ``blob`` the serialized ``SHKS`` bytes."""

    version: int
    entries: int
    sem_id: int
    blob: bytes


@dataclasses.dataclass(frozen=True)
class AttachRecord:
    """Receipt for one attachment: the ``handle`` to ``detach`` with, and
    the :class:`KBSnapshotRef` for the container footer (``None`` when the
    attach was sealed without a snapshot, e.g. fleet gossip)."""

    handle: str
    ref: KBSnapshotRef | None


# --------------------------------------------------------------------- #
# SHKS snapshot blob (normative layout in docs/wire-format.md)
# --------------------------------------------------------------------- #
def snapshot_to_bytes(
    version: int, sem_id: int, live_kb: KnowledgeBase, tombstones: list[int]
) -> bytes:
    """Serialize one store snapshot: ``SHKS`` wrapper (version, semantic
    id, gap-coded tombstone ids) around the live entries' ``SHKB`` blob,
    CRC-sealed over everything."""
    buf = bytearray()
    buf += _SNAP_MAGIC
    buf.append(_SNAP_VERSION)
    write_varint(buf, version)
    buf += struct.pack("<I", sem_id & 0xFFFFFFFF)
    write_varint(buf, len(tombstones))
    prev = -1
    for t in tombstones:  # strictly ascending; gap coding cannot encode otherwise
        write_varint(buf, t - prev - 1)
        prev = t
    kb_bytes = live_kb.to_bytes()
    write_varint(buf, len(kb_bytes))
    buf += kb_bytes
    buf += struct.pack("<I", zlib.crc32(bytes(buf)) & 0xFFFFFFFF)
    return bytes(buf)


def snapshot_from_bytes(
    data: bytes,
) -> tuple[int, int, KnowledgeBase, set[int]]:
    """Decode an ``SHKS`` blob to ``(version, sem_id, master_kb,
    tombstones)``.  ``master_kb`` has the snapshot's full positional id
    space: live entries at their original ids, zeroed placeholder husks at
    tombstoned ids (excluded from the lookup index).  Raises the usual
    typed taxonomy on foreign/truncated/corrupt input; the trailing CRC
    covers every preceding byte, so bit flips and trailing garbage both
    surface as :class:`CorruptFrameError`."""
    data = bytes(data)
    if len(data) < 5 or data[:4] != _SNAP_MAGIC:
        raise FormatError("bad snapshot magic: not an SHKS blob")
    if data[4] != _SNAP_VERSION:
        raise FormatError(f"unsupported SHKS version {data[4]}")
    if len(data) < 9:
        raise TruncatedArchiveError("truncated SHKS snapshot: missing CRC")
    (crc_stored,) = struct.unpack_from("<I", data, len(data) - 4)
    if zlib.crc32(data[:-4]) & 0xFFFFFFFF != crc_stored:
        raise CorruptFrameError("corrupt SHKS snapshot: CRC mismatch")
    try:
        pos = 5
        version, pos = read_varint(data, pos)
        (sem_id,) = struct.unpack_from("<I", data, pos)
        pos += 4
        n_tomb, pos = read_varint(data, pos)
        tombs: list[int] = []
        prev = -1
        for _ in range(n_tomb):
            gap, pos = read_varint(data, pos)
            prev = prev + 1 + gap
            tombs.append(prev)
        kb_len, pos = read_varint(data, pos)
        if pos + kb_len != len(data) - 4:
            raise CorruptFrameError(
                "corrupt SHKS snapshot: knowledge-base section length mismatch"
            )
        live = KnowledgeBase.from_bytes(data[pos : pos + kb_len])
    except ShrinkError:
        raise
    except (IndexError, struct.error) as e:
        raise TruncatedArchiveError(f"truncated SHKS snapshot: {e}") from e
    total = len(live.entries) + len(tombs)
    if tombs and tombs[-1] >= total:
        raise CorruptFrameError(
            f"corrupt SHKS snapshot: tombstone id {tombs[-1]} outside "
            f"id space [0, {total})",
            entry=tombs[-1],
        )
    if live.snapshot_id() != sem_id:
        raise CorruptFrameError(
            "corrupt SHKS snapshot: semantic id does not match the entries"
        )
    master = KnowledgeBase(live.config)
    tomb_set = set(tombs)
    live_iter = iter(live.entries)
    for eid in range(total):
        if eid in tomb_set:
            master.entries.append(
                KBEntry(level=0, origin_idx=0, slope=0.0, slope_digits=0, refs=0)
            )
        else:
            e = next(live_iter)
            key = (e.level, e.origin_idx) + _slope_key(e.slope, e.slope_digits)
            master._index[key] = eid
            master.entries.append(e)
    return version, sem_id, master, tomb_set


# --------------------------------------------------------------------- #
# The store
# --------------------------------------------------------------------- #
class KBStore:
    """Shared, versioned, ref-counted knowledge-base store (module
    docstring has the full contract).

    ``max_entries`` bounds the *live* entry count: exceeding it evicts
    zero-ref, unpinned entries LRU (entries referenced by any live
    attachment are never evicted — the store may transiently exceed the
    bound when everything is referenced).
    """

    def __init__(self, config: ShrinkConfig, max_entries: int | None = None):
        if max_entries is not None and max_entries <= 0:
            raise ConfigError(f"max_entries must be positive, got {max_entries}")
        self.config = config
        self.kb = KnowledgeBase(config)
        self.max_entries = max_entries
        self._tombstones: set[int] = set()
        self._touch: dict[int, int] = {}
        self._seq = 0
        self._auto = 0
        # handle -> {store id: refcount contributed}; handle -> local->store remap
        self._handles: dict[str, dict[int, int]] = {}
        self._remaps: dict[str, list[int]] = {}
        # store id -> number of live attachments whose remap names it
        self._pins: dict[int, int] = {}
        self._containers: dict[str, bytes] = {}
        self._snapshots: list[StoreSnapshot] = []
        self._next_version = 1
        self.counters = {
            "attaches": 0,
            "detaches": 0,
            "evictions": 0,
            "compactions": 0,
            "spills": 0,
        }

    # -- identity / views ---------------------------------------------- #
    @property
    def live_count(self) -> int:
        return len(self.kb.entries) - len(self._tombstones)

    def _live_kb(self) -> KnowledgeBase:
        """A frozen copy of the live entries, in store id order (positional
        ids are *compacted* in this view; the snapshot records the
        tombstone positions to reconstruct the full id space)."""
        kb = KnowledgeBase(self.config)
        for eid, e in enumerate(self.kb.entries):
            if eid in self._tombstones:
                continue
            key = (e.level, e.origin_idx) + _slope_key(e.slope, e.slope_digits)
            kb._index[key] = len(kb.entries)
            kb.entries.append(dataclasses.replace(e))
        return kb

    def sem_id(self) -> int:
        """Order-invariant semantic identity of the live lines (the same
        quantity as ``KnowledgeBase.snapshot_id`` — equal to the merged
        global KB's id when the store's sources are exactly those KBs)."""
        return self._live_kb().snapshot_id()

    def stats(self) -> dict:
        live_refs = sum(
            e.refs
            for eid, e in enumerate(self.kb.entries)
            if eid not in self._tombstones
        )
        return {
            "entries": len(self.kb.entries),
            "live": self.live_count,
            "tombstones": len(self._tombstones),
            "total_refs": live_refs,
            "dedup_ratio": live_refs / self.live_count if self.live_count else 1.0,
            "handles": len(self._handles),
            "containers": len(self._containers),
            "snapshots": len(self._snapshots),
            "next_version": self._next_version,
            "counters": dict(self.counters),
        }

    # -- attach / detach ----------------------------------------------- #
    def attach_kb(
        self,
        kb: KnowledgeBase,
        source: str | None = None,
        snapshot: bool = True,
    ) -> AttachRecord:
        """Fold a container/shard KB into the store with exact reference
        accounting.  Re-attaching an existing ``source`` handle first
        releases its previous contribution (replace semantics — this is
        what fleet gossip and codec re-finalize rely on).  With
        ``snapshot=True`` the post-attach state is sealed and the returned
        record carries the :class:`KBSnapshotRef` for the container
        footer."""
        handle = f"h{self._auto}" if source is None else str(source)
        if source is None:
            self._auto += 1
        if handle in self._handles:
            self._release_handle(handle)
        remap = self.kb.merge(kb)  # raises ConfigError on config mismatch
        counts: dict[int, int] = {}
        for rid, e in zip(remap, kb.entries):
            self._pins[rid] = self._pins.get(rid, 0) + 1
            self._seq += 1
            self._touch[rid] = self._seq
            if e.refs:
                counts[rid] = counts.get(rid, 0) + e.refs
        self._handles[handle] = counts
        self._remaps[handle] = list(remap)
        self.counters["attaches"] += 1
        self._evict_if_needed()
        ref = None
        if snapshot:
            snap = self.snapshot()
            ref = KBSnapshotRef(
                version=snap.version,
                entries=snap.entries,
                sem_id=snap.sem_id,
                remap=tuple(remap),
                refs=tuple(e.refs for e in kb.entries),
            )
        return AttachRecord(handle=handle, ref=ref)

    def attach(self, blob: bytes, source: str | None = None) -> AttachRecord:
        """Attach a whole self-contained SHRKS container: its inline
        footer KB is folded in and the container is registered for
        compaction re-basing."""
        _, kb_bytes = parse_framed_container(blob)
        if not kb_bytes:
            raise ConfigError(
                "container carries no inline knowledge base to attach "
                "(ref-mode containers are attached by their writer)"
            )
        rec = self.attach_kb(KnowledgeBase.from_bytes(kb_bytes), source=source)
        self._containers[rec.handle] = bytes(blob)
        return rec

    def register_container(self, handle: str, blob: bytes) -> None:
        """Associate the finished container bytes with an attach handle
        (writers call this after ``finish`` — the ref must exist before
        the footer is built).  Registered ref-mode containers are re-based
        by ``compact``."""
        if handle not in self._handles:
            raise KBReferenceError(f"unknown attach handle {handle!r}")
        self._containers[handle] = bytes(blob)

    def container(self, handle: str) -> bytes:
        """The registered (possibly compaction-rebased) container bytes."""
        try:
            return self._containers[handle]
        except KeyError:
            raise KBReferenceError(
                f"no container registered under handle {handle!r}"
            ) from None

    def _release_handle(self, handle: str) -> None:
        counts = self._handles.pop(handle)
        for rid, cnt in counts.items():
            self.kb.release([rid] * cnt)  # typed underflow via KBReferenceError
        for rid in self._remaps.pop(handle):
            self._pins[rid] -= 1
            if not self._pins[rid]:
                del self._pins[rid]
        self._containers.pop(handle, None)
        self.counters["detaches"] += 1

    def detach(self, handle: str) -> None:
        """Reverse one attachment exactly: every refcount it contributed
        is released; entries that drop to zero refs become eviction
        candidates."""
        if handle not in self._handles:
            raise KBReferenceError(f"unknown attach handle {handle!r}")
        self._release_handle(handle)
        self._evict_if_needed()

    def gossip(self, source: str, kb: KnowledgeBase) -> dict:
        """Fleet-shard sync: (re-)attach ``source``'s current KB under its
        stable handle — replace semantics, so repeated syncs of a growing
        shard KB never double-count — and return the epoch-tagged record
        the fleet logs."""
        self.attach_kb(kb, source=source, snapshot=False)
        return {
            "source": source,
            "entries": len(self.kb.entries),
            "live": self.live_count,
            "sem_id": self.sem_id(),
        }

    # -- eviction ------------------------------------------------------ #
    def _evict_if_needed(self) -> int:
        if self.max_entries is None:
            return 0
        evicted = 0
        while self.live_count > self.max_entries:
            victim, oldest = None, None
            for eid, e in enumerate(self.kb.entries):
                if eid in self._tombstones or eid in self._pins or e.refs:
                    continue
                t = self._touch.get(eid, -1)
                if oldest is None or t < oldest:
                    victim, oldest = eid, t
            if victim is None:
                break  # everything is referenced/pinned: bound is soft
            e = self.kb.entries[victim]
            key = (e.level, e.origin_idx) + _slope_key(e.slope, e.slope_digits)
            self.kb._index.pop(key, None)
            self._tombstones.add(victim)
            self._touch.pop(victim, None)
            self.counters["evictions"] += 1
            evicted += 1
        return evicted

    # -- snapshots ----------------------------------------------------- #
    def snapshot(self) -> StoreSnapshot:
        """Seal the current store state into a new versioned ``SHKS``
        snapshot (kept in memory; ``spill`` persists them)."""
        live = self._live_kb()
        sem = live.snapshot_id()
        version = self._next_version
        self._next_version += 1
        blob = snapshot_to_bytes(version, sem, live, sorted(self._tombstones))
        snap = StoreSnapshot(
            version=version, entries=len(self.kb.entries), sem_id=sem, blob=blob
        )
        self._snapshots.append(snap)
        return snap

    @property
    def snapshots(self) -> list[StoreSnapshot]:
        return list(self._snapshots)

    def _find_snapshot(self, version: int) -> StoreSnapshot | None:
        for snap in reversed(self._snapshots):
            if snap.version == version:
                return snap
        return None

    def resolve(self, ref: KBSnapshotRef) -> KnowledgeBase:
        """The master KB view of the snapshot a ref names, after proving
        the ref actually matches it: unknown version, semantic id
        disagreement, id space overrun, or a remap id that was tombstoned
        all raise :class:`StaleSnapshotError` — a ref never silently binds
        to the wrong snapshot."""
        snap = self._find_snapshot(ref.version)
        if snap is None:
            raise StaleSnapshotError(
                f"unknown KB snapshot version {ref.version} "
                f"(store holds {[s.version for s in self._snapshots]})"
            )
        if (ref.sem_id & 0xFFFFFFFF) != snap.sem_id:
            raise StaleSnapshotError(
                f"KB snapshot v{ref.version} semantic id mismatch: "
                f"ref {ref.sem_id:#x} != store {snap.sem_id:#x}"
            )
        if ref.entries > snap.entries:
            raise StaleSnapshotError(
                f"KB snapshot v{ref.version} id space overrun: ref claims "
                f"{ref.entries} entries, snapshot holds {snap.entries}"
            )
        _, _, master, tombs = snapshot_from_bytes(snap.blob)
        for rid in ref.remap:
            if rid in tombs:
                raise StaleSnapshotError(
                    f"kb_snapshot_ref names retired entry {rid} of snapshot "
                    f"v{ref.version}",
                    entry=rid,
                )
        return master

    def container_kb(self, ref: KBSnapshotRef) -> KnowledgeBase:
        """Rebuild a container's private KB view — positional entry ids,
        exact refcounts — from the store snapshot its ref names."""
        master = self.resolve(ref)
        kb = KnowledgeBase(self.config)
        for rid, refs in zip(ref.remap, ref.refs):
            e = master.entries[rid]
            key = (e.level, e.origin_idx) + _slope_key(e.slope, e.slope_digits)
            kb._index[key] = len(kb.entries)
            kb.entries.append(dataclasses.replace(e, refs=refs))
        return kb

    # -- compaction ---------------------------------------------------- #
    def compact(self) -> dict:
        """Garbage-collect the id space: drop tombstones AND zero-ref
        entries no live attachment pins, renumber the survivors, seal one
        compacted snapshot, and re-base every registered ref-mode
        container onto it.  Old snapshots are retired (their refs become
        stale *by design* — the re-based containers carry fresh refs).
        Each rewrite is verified byte-identical over the whole frame
        region before replacing the original, so decode provably cannot
        change."""
        entries_before = len(self.kb.entries)
        old_to_new: dict[int, int] = {}
        new_kb = KnowledgeBase(self.config)
        for eid, e in enumerate(self.kb.entries):
            if eid in self._tombstones:
                continue
            if not e.refs and eid not in self._pins:
                continue  # dead line: no refs, no container names it
            key = (e.level, e.origin_idx) + _slope_key(e.slope, e.slope_digits)
            old_to_new[eid] = len(new_kb.entries)
            new_kb._index[key] = len(new_kb.entries)
            new_kb.entries.append(e)
        self.kb = new_kb
        self._tombstones = set()
        self._handles = {
            h: {old_to_new[r]: c for r, c in counts.items()}
            for h, counts in self._handles.items()
        }
        self._remaps = {
            h: [old_to_new[r] for r in rm] for h, rm in self._remaps.items()
        }
        self._pins = {old_to_new[r]: c for r, c in self._pins.items()}
        self._touch = {
            old_to_new[r]: t for r, t in self._touch.items() if r in old_to_new
        }
        self._snapshots = []
        snap = self.snapshot()
        rebased: list[str] = []
        for handle, blob in list(self._containers.items()):
            old_ref = read_snapshot_ref(blob)
            if old_ref is None:
                continue  # self-contained container: nothing to re-base
            metas, kb_bytes = parse_framed_container(blob)
            w = FramedWriter()
            for m in metas:
                w.add_frame(
                    m.series_id, m.t_lo, m.t_hi, m.kb_epoch,
                    frame_payload(blob, m, verify_crc=True),
                )
            new_ref = KBSnapshotRef(
                version=snap.version,
                entries=snap.entries,
                sem_id=snap.sem_id,
                remap=tuple(self._remaps[handle]),
                refs=old_ref.refs,
            )
            new_blob = w.finish(kb_bytes, snapshot_ref=new_ref)
            (old_fo,) = struct.unpack_from("<Q", blob, len(blob) - _TAIL_LEN)
            (new_fo,) = struct.unpack_from("<Q", new_blob, len(new_blob) - _TAIL_LEN)
            if blob[:old_fo] != new_blob[:new_fo]:
                raise CorruptFrameError(
                    f"compaction changed frame bytes of container {handle!r}"
                )
            self._containers[handle] = new_blob
            rebased.append(handle)
        self.counters["compactions"] += 1
        return {
            "version": snap.version,
            "entries_before": entries_before,
            "entries_after": len(self.kb.entries),
            "dropped": entries_before - len(self.kb.entries),
            "rebased": rebased,
        }

    # -- spill / load -------------------------------------------------- #
    def spill(self, directory) -> list[str]:
        """Persist every in-memory snapshot to ``directory`` as
        ``kbsnap_v<version>.shks`` files; returns the paths written."""
        d = pathlib.Path(directory)
        d.mkdir(parents=True, exist_ok=True)
        paths = []
        for snap in self._snapshots:
            p = d / f"kbsnap_v{snap.version:08d}.shks"
            p.write_bytes(snap.blob)
            paths.append(str(p))
        self.counters["spills"] += 1
        return paths

    @classmethod
    def load(cls, directory, max_entries: int | None = None) -> "KBStore":
        """Restore a store from spilled ``SHKS`` snapshots: the highest
        version becomes the master state, every snapshot stays resolvable
        for old refs.  Attach handles and registered containers are
        runtime state and are NOT persisted — a loaded store serves
        ``resolve``/``container_kb`` and accepts fresh attachments."""
        d = pathlib.Path(directory)
        paths = sorted(d.glob("*.shks"))
        if not paths:
            raise FormatError(f"no SHKS snapshots under {d}")
        decoded = []
        seen_versions: set[int] = set()
        for p in paths:
            blob = p.read_bytes()
            version, sem, master, tombs = snapshot_from_bytes(blob)
            if version in seen_versions:
                raise FormatError(
                    f"duplicate snapshot version {version} under {d}"
                )
            seen_versions.add(version)
            decoded.append((version, sem, master, tombs, blob))
        decoded.sort(key=lambda x: x[0])
        latest_version, _, master, tombs, _ = decoded[-1]
        store = cls(master.config, max_entries=max_entries)
        store.kb = master
        store._tombstones = set(tombs)
        store._snapshots = [
            StoreSnapshot(
                version=v, entries=len(m.entries), sem_id=s, blob=b
            )
            for v, s, m, _, b in decoded
        ]
        store._next_version = latest_version + 1
        return store


def resolve_container_kb(
    blob: bytes, store: KBStore | None = None
) -> tuple[KnowledgeBase | None, str]:
    """The KB view of a container, with the fallback ladder readers use:
    a ``kb_snapshot_ref`` resolved against ``store`` wins (``"store"``);
    if the ref is stale but an inline footer KB exists, fall back to it
    (``"inline-fallback"``); containers without a ref use their inline KB
    (``"inline"``) or have none (``"none"``).  A ref-only container whose
    ref cannot resolve raises :class:`StaleSnapshotError` — never a
    silently wrong dictionary."""
    _, kb_bytes = parse_framed_container(blob)
    ref = read_snapshot_ref(blob)
    if ref is not None and store is not None:
        try:
            return store.container_kb(ref), "store"
        except ShrinkError:
            if kb_bytes:
                return KnowledgeBase.from_bytes(kb_bytes), "inline-fallback"
            raise
    if kb_bytes:
        return KnowledgeBase.from_bytes(kb_bytes), "inline"
    if ref is not None:
        raise StaleSnapshotError(
            "ref-mode container (no inline knowledge base) but no KB store "
            "was supplied to resolve it"
        )
    return None, "none"
