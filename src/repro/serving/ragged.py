"""Gateway admission scheduler for ragged multi-sensor ingest.

An IoT gateway does not see tidy [S, T] blocks: hundreds of sensors publish
at wildly different rates, so at any flush instant the pending buffers form
a ragged batch whose lengths span orders of magnitude (Sprintz's device-side
observation, arXiv:1808.02515).  ``RaggedBatcher`` is the admission layer
that turns that traffic into efficient batched compression:

* ``submit(series_id, chunk)`` appends a sensor's next chunk to its pending
  buffer (O(1), no compression on the hot path).
* Admission policy — the batch **flushes** when either trigger fires:
  - *size*: total pending samples reach ``flush_samples`` (amortization —
    bigger batches, fewer scans), or
  - *deadline*: the oldest pending sample has waited ``flush_deadline_s``
    (latency bound — a slow sensor cannot stall the gateway forever).
  ``poll()`` checks the deadline without new data (call it from a timer).
* ``scope="series"`` re-interprets BOTH triggers per series: a series
  seals a frame when ITS OWN pending samples reach ``flush_samples`` or
  its own oldest pending sample ages past ``flush_deadline_s``, and a
  flush seals only the due series (co-pending neighbors keep buffering).
  Frame boundaries are then a pure function of each series' own ingest
  history — independent of which other series share the batcher — which
  is the invariant the sharded fleet (``serving/fleet.py``) relies on to
  make partitioning semantically invisible.  Due series flushing at the
  same instant still share one ragged ``compress_batch``.
* A flush runs ONE ragged ``ShrinkCodec.compress_batch`` over every pending
  buffer — percentile length-bucketing into padded lanes, masked cone
  scans, one shared rANS entropy pass (see ``docs/architecture.md``) — and
  seals each series' buffer as a ``SHRKS`` frame.  Every frame's sub-base
  lines feed the shared, deduplicating ``KnowledgeBase`` (pass ``kb=`` to
  share one dictionary with other batchers or a ``ShrinkStreamCodec``).
* ``finalize()`` emits the standard ``SHRKS`` container
  (``docs/wire-format.md``): the output is readable by ``decode_range`` /
  ``decode_series`` / ``RangeQueryBatcher`` exactly like a
  ``ShrinkStreamCodec`` container.  Indeed each frame's payload is
  byte-identical to what a deferred-scan ``ShrinkStreamCodec`` (no pinned
  range, flush-per-window) would seal for the same buffer boundaries —
  property the tests pin.

The scheduler is time-source agnostic: inject ``clock`` (a ``() -> float``
monotonic-seconds callable) to drive deadlines deterministically in tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np

from ..core.errors import BatcherFinalizedError, ConfigError
from ..core.serialize import FramedWriter
from ..core.shrink import ShrinkCodec, cs_to_bytes
from ..core.streaming import KnowledgeBase
from ..core.types import ShrinkConfig, merge_backend_stats

__all__ = ["RaggedBatcher"]


@dataclasses.dataclass
class _PendingSeries:
    start: int  # absolute sample index of the buffer's first sample
    oldest: Optional[float] = None  # clock() when the buffer became nonempty
    chunks: list = dataclasses.field(default_factory=list)
    samples: int = 0

    def append(self, vals: np.ndarray) -> None:
        self.chunks.append(vals)
        self.samples += int(vals.size)

    def take(self) -> np.ndarray:
        out = np.concatenate(self.chunks) if len(self.chunks) > 1 else self.chunks[0]
        self.chunks = []
        self.samples = 0
        return out


class RaggedBatcher:
    """Bucketed admission scheduler: many concurrent ragged series ->
    batched ragged compression -> ``SHRKS`` frames + shared knowledge base.

    Parameters
    ----------
    config:           ShrinkConfig shared by every series on this gateway.
    eps_targets:      residual resolutions per frame (0.0 = lossless,
                      requires ``decimals``).
    flush_samples:    size trigger — flush when total pending samples reach
                      this (None disables; flush on deadline/finalize only).
    flush_deadline_s: latency trigger — flush when the oldest pending
                      sample has waited this long (None disables).
    max_buckets:      percentile length-buckets per flush (None = scale
                      with series count; see ``ShrinkCodec.compress_batch``).
    semantics:        scan route forwarded to ``compress_batch`` ("auto" |
                      "numpy" | "pallas").
    scope:            "batch" (default) applies the triggers to the whole
                      pending pool and a flush seals every pending series;
                      "series" applies both triggers per series and seals
                      only the due ones (shard-invariant frame boundaries
                      — see the module docstring).
    kb:               share a KnowledgeBase across batchers/codecs.
    kb_store:         a ``serving.kbstore.KBStore`` to attach the finalized
                      container's KB to; the footer then carries a
                      ``kb_snapshot_ref`` and (unless ``inline_kb=True``)
                      omits the inline KB.
    inline_kb:        force the inline footer KB on/off; default ``None``
                      = inline exactly when no ``kb_store`` is attached.
    source:           stable attach handle for ``kb_store``.
    clock:            monotonic-seconds source (injectable for tests).
    """

    def __init__(
        self,
        config: ShrinkConfig,
        eps_targets: list[float],
        decimals: int | None = None,
        backend: str = "rans",
        flush_samples: int | None = 262_144,
        flush_deadline_s: float | None = None,
        max_buckets: int | None = None,
        semantics: str = "auto",
        scope: str = "batch",
        kb: KnowledgeBase | None = None,
        kb_store=None,  # serving.kbstore.KBStore
        inline_kb: bool | None = None,
        source: str | None = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        if 0.0 in eps_targets and decimals is None:
            raise ConfigError("lossless eps target 0.0 requires `decimals`")
        if inline_kb is False and kb_store is None:
            raise ConfigError(
                "inline_kb=False requires a kb_store (a container with "
                "neither an inline KB nor a snapshot ref loses its dictionary)"
            )
        if flush_samples is not None and flush_samples < 1:
            raise ConfigError(f"flush_samples must be >= 1, got {flush_samples}")
        if flush_deadline_s is not None and flush_deadline_s < 0:
            raise ConfigError(
                f"flush_deadline_s must be >= 0, got {flush_deadline_s}"
            )
        if scope not in ("batch", "series"):
            raise ConfigError(f"scope must be 'batch' or 'series', got {scope!r}")
        self.scope = scope
        self.codec = ShrinkCodec(config=config, backend=backend)
        self.eps_targets = list(eps_targets)
        self.decimals = decimals
        self.flush_samples = flush_samples
        self.flush_deadline_s = flush_deadline_s
        self.max_buckets = max_buckets
        self.semantics = semantics
        self.kb = kb if kb is not None else KnowledgeBase(config)
        self.kb_store = kb_store
        self.inline_kb = inline_kb
        self._store_source = source
        self._store_handle: str | None = None
        self._clock = clock
        self._writer = FramedWriter()
        self._pending: dict[int, _PendingSeries] = {}
        self._series_pos: dict[int, int] = {}  # next absolute sample index
        self._pending_samples = 0
        self._frames: list[tuple[int, int, int]] = []
        self._flushes = 0
        self._samples_in = 0
        self._payload_bytes = 0
        self._backend_stats: dict[str, dict[str, int]] = {}
        self._finalized = False
        self._container: Optional[bytes] = None

    # -- admission ------------------------------------------------------ #
    def submit(self, series_id: int, values_chunk) -> list[tuple[int, int, int]]:
        """Append one series' next chunk; returns the frames sealed by this
        call ([] unless a flush trigger fired)."""
        if self._finalized:
            raise BatcherFinalizedError(
                "batcher already finalized", series_id=int(series_id)
            )
        sid = int(series_id)
        vals = np.asarray(values_chunk, dtype=np.float64).ravel()
        if vals.size:
            st = self._pending.get(sid)
            if st is None:
                st = self._pending[sid] = _PendingSeries(
                    start=self._series_pos.setdefault(sid, 0),
                    oldest=self._clock(),
                )
            st.append(vals)
            self._pending_samples += int(vals.size)
            self._samples_in += int(vals.size)
        return self._maybe_flush()

    def due(self) -> bool:
        """True when a flush trigger (size or deadline) has fired.  Always
        False once finalized: a late deadline timer must not re-seal."""
        if self._finalized or self._pending_samples == 0:
            return False
        if self.scope == "series":
            return bool(self.due_series())
        if self.flush_samples is not None and self._pending_samples >= self.flush_samples:
            return True
        if self.flush_deadline_s is None:
            return False
        oldest = min(ps.oldest for ps in self._pending.values())
        return self._clock() - oldest >= self.flush_deadline_s

    def due_series(self) -> list[int]:
        """The series whose own size/deadline trigger has fired (meaningful
        under ``scope="series"``; [] once finalized)."""
        if self._finalized or not self._pending:
            return []
        now: Optional[float] = None
        out = []
        for sid, ps in self._pending.items():
            if self.flush_samples is not None and ps.samples >= self.flush_samples:
                out.append(sid)
                continue
            if self.flush_deadline_s is not None and ps.oldest is not None:
                if now is None:
                    now = self._clock()
                if now - ps.oldest >= self.flush_deadline_s:
                    out.append(sid)
        return sorted(out)

    def poll(self) -> list[tuple[int, int, int]]:
        """Deadline check with no new data (drive from a timer loop)."""
        return self._maybe_flush()

    def _maybe_flush(self) -> list[tuple[int, int, int]]:
        if self.scope == "series":
            due = self.due_series()
            return self.flush(due) if due else []
        return self.flush() if self.due() else []

    # -- flush / finalize ----------------------------------------------- #
    def flush(self, series_ids=None) -> list[tuple[int, int, int]]:
        """Compress pending buffers as one ragged batch and seal each as a
        SHRKS frame; returns (series_id, t_lo, t_hi) per frame.
        ``series_ids`` restricts the flush to a subset (None = all).

        A flush after ``finalize`` is a NO-OP (returns []), and the buffers
        being flushed are detached from the pending pool *before* any
        compression work: a ``flush_deadline_s`` timer firing ``poll``
        concurrently with ``finalize`` (or reentrantly from inside the
        compression callback) can no longer double-seal the pending pool —
        the second flush simply finds nothing pending."""
        if self._finalized or not self._pending:
            return []
        if series_ids is None:
            sids = sorted(self._pending)
        else:
            sids = sorted(s for s in set(series_ids) if s in self._pending)
            if not sids:
                return []
        taken = [(sid, self._pending.pop(sid)) for sid in sids]
        self._pending_samples -= sum(ps.samples for _, ps in taken)
        arrs = [ps.take() for _, ps in taken]
        css = self.codec.compress_batch(
            arrs,
            eps_targets=self.eps_targets,
            decimals=self.decimals,
            semantics=self.semantics,
            max_buckets=self.max_buckets,
        )
        sealed = []
        for (sid, ps), vals, cs in zip(taken, arrs, css):
            merge_backend_stats(self._backend_stats, cs.backend_stats())
            payload = cs_to_bytes(cs)
            self.kb.ingest_base(cs.base)
            t_lo = ps.start
            t_hi = t_lo + int(vals.size)
            self._writer.add_frame(sid, t_lo, t_hi, self.kb.epoch, payload)
            self._payload_bytes += len(payload)
            self._series_pos[sid] = t_hi
            sealed.append((sid, t_lo, t_hi))
        self._frames.extend(sealed)
        self._flushes += 1
        return sealed

    def finalize(self) -> bytes:
        """Flush the remainder and emit the SHRKS container (knowledge base
        in the footer).  Idempotent: a retried ``finalize`` (e.g. after a
        delivery timeout upstream) returns the SAME bytes instead of
        corrupting writer state."""
        if self._finalized:
            return self._container
        self.flush()
        self._finalized = True
        ref = None
        if self.kb_store is not None:
            rec = self.kb_store.attach_kb(self.kb, source=self._store_source)
            self._store_handle = rec.handle
            ref = rec.ref
        inline = self.inline_kb if self.inline_kb is not None else self.kb_store is None
        self._container = self._writer.finish(
            self.kb.to_bytes() if inline else b"", snapshot_ref=ref
        )
        if self.kb_store is not None:
            self.kb_store.register_container(self._store_handle, self._container)
        return self._container

    # -- introspection -------------------------------------------------- #
    @property
    def sealed_frames(self) -> list[tuple[int, int, int]]:
        return list(self._frames)

    def stats(self) -> dict:
        return {
            "series": len(self._series_pos),
            "flushes": self._flushes,
            "frames": len(self._frames),
            "samples_ingested": self._samples_in,
            "samples_pending": self._pending_samples,
            "payload_bytes": self._payload_bytes,
            "backends": {b: dict(d) for b, d in self._backend_stats.items()},
            "kb": self.kb.stats(),
        }
