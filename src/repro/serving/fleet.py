"""Sharded multi-tenant serving fleet: many gateways, one semantic truth.

:class:`ShrinkFleet` scales the single-process serving stack
(``RaggedBatcher`` -> ``SHRKS`` -> ``FaultTolerantGateway`` ->
``AnalyticsEngine``) across shards.  Each shard owns a disjoint set of
series end to end; placement comes from a :class:`repro.parallel.FleetPlan`
(deterministic hash by default, any explicit assignment for tests), so the
only cross-shard coupling is the periodic knowledge-base sync.

**The load-bearing invariant — sharding is semantically invisible.**  For
ANY partition of series across ANY shard count, every per-series frame's
payload bytes are identical to the single-process stack's, every range
query decodes to the identical floats, and every analytics interval is
equal (or provably contained when degraded).  Two properties make this
hold by construction, and the cross-shard differential suites
(tests/test_fleet.py, tests/test_fleet_property.py) pin both:

* shard batchers run with ``scope="series"``: flush triggers are a pure
  function of each series' own ingest history, so frame boundaries cannot
  depend on which series happen to share a shard;
* a frame's payload is a pure function of (its sample slice, eps targets,
  config, decimals) — pinned since PR 3 by the batch/loop and
  batcher/stream byte-identity properties — so identical boundaries force
  identical bytes, whatever was co-batched.

**Knowledge-base replication.**  Every shard KB deduplicates its own
traffic; ``sync_kbs`` rebuilds the fleet-global KB by ``merge()``-ing the
shard KBs (order-invariant — property-tested) and records an epoch-tagged
sync point: the per-shard entry counts plus the global semantic snapshot
id (``KnowledgeBase.snapshot_id``).  Each shard's container footer carries
that shard's own KB, so frames ALWAYS decode against a snapshot containing
their refs — ``seal()`` verifies this via ``routing_metadata`` before any
shard enters service.

**Multi-tenant admission.**  :class:`TenantQuota` is a token bucket
(tokens = samples) on an injectable clock.  Ingest beyond quota is a typed
:class:`QuotaExceededError` (data loss is never silent); queries beyond
quota are *shed to coarse* — re-admitted at ``coarse_eps`` / segment-tier
analytics, flagged ``degraded`` with honest bounds — or typed-rejected
when no coarse tier is configured.  Per-shard gateways keep their full
retry/breaker/deadline/backpressure armor; a shard whose container is lost
or corrupt degrades SCOPED: its queries return typed errors or flagged
in-bound answers while every other shard keeps serving byte-exact
(docs/fleet.md has the full degradation matrix).
"""
from __future__ import annotations

import time
from dataclasses import replace
from typing import Callable, Mapping, Optional, Union

import numpy as np

from ..core.errors import (
    BatcherFinalizedError,
    ConfigError,
    QuotaExceededError,
    ShrinkError,
)
from ..core.serialize import frame_payload, parse_framed_container
from ..core.streaming import KnowledgeBase, routing_metadata
from ..core.types import ShrinkConfig, merge_backend_stats
from ..parallel.fleet import FleetPlan, plan_fleet
from .batching import RangeQuery
from .gateway import FaultTolerantGateway, RetryPolicy
from .ragged import RaggedBatcher

__all__ = ["TenantQuota", "ShrinkFleet"]


class TenantQuota:
    """Per-tenant admission token bucket (tokens = samples) on an
    injectable clock: ``burst`` tokens capacity, refilled continuously at
    ``rate_per_s``.  ``try_take`` is the whole protocol — no partial
    grants, so admission is all-or-nothing and a huge request cannot
    starve forever on a trickle of tokens it keeps half-consuming."""

    def __init__(
        self,
        rate_per_s: float,
        burst: float,
        clock: Callable[[], float] = time.monotonic,
    ):
        if rate_per_s < 0:
            raise ConfigError(f"rate_per_s must be >= 0, got {rate_per_s}")
        if burst <= 0:
            raise ConfigError(f"burst must be > 0, got {burst}")
        self.rate_per_s = float(rate_per_s)
        self.burst = float(burst)
        self._clock = clock
        self._tokens = float(burst)
        self._last = clock()

    def _refill(self) -> None:
        now = self._clock()
        if now > self._last:
            self._tokens = min(
                self.burst, self._tokens + (now - self._last) * self.rate_per_s
            )
        self._last = now

    def available(self) -> float:
        self._refill()
        return self._tokens

    def try_take(self, cost: float) -> bool:
        """Take ``cost`` tokens if the bucket holds them; False otherwise
        (nothing is consumed on refusal)."""
        self._refill()
        if self._tokens >= cost:
            self._tokens -= cost
            return True
        return False


class ShrinkFleet:
    """The sharded serving fleet.  Lifecycle: ``submit``/``poll`` ingest
    (routed to per-shard ``scope="series"`` batchers), ``seal`` to per-shard
    SHRKS containers (idempotent; auto-invoked by the first query), then
    ``query``/``enqueue``+``run``/``aggregate``/``count_where`` route per
    shard through fault-tolerant gateways and analytics engines.

    Parameters mirror the single-process stack; fleet-specific knobs:

    n_shards:      shard count (placement from ``parallel.plan_fleet``).
    assignment:    explicit series->shard map/callable (tests quantify
                   over this; default = stable hash).
    tenant_of:     series_id -> tenant name (default: one "default"
                   tenant).  Quotas and shed accounting key on it.
    quotas:        {tenant: TenantQuota}; unlisted tenants are unmetered.
    coarse_eps:    the shed-to-coarse tier for over-quota / over-queue
                   queries (None = typed rejection instead).
    kb_sync_every: automatic ``sync_kbs`` after this many fleet-wide
                   flush events (None = only at seal / on demand).
    """

    def __init__(
        self,
        config: ShrinkConfig,
        eps_targets: list[float],
        n_shards: int = 1,
        decimals: int | None = None,
        backend: str = "rans",
        flush_samples: int | None = 8192,
        flush_deadline_s: float | None = None,
        max_buckets: int | None = None,
        assignment: Optional[Union[Mapping[int, int], Callable[[int], int]]] = None,
        tenant_of: Callable[[int], str] | None = None,
        quotas: Mapping[str, TenantQuota] | None = None,
        coarse_eps: Optional[float] = float("inf"),
        kb_sync_every: int | None = 4,
        kb_store=None,  # serving.kbstore.KBStore: shards gossip into it on sync
        retry: RetryPolicy | None = None,
        max_queue: int = 256,
        cache_frames: int = 32,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] | None = None,
        seed: int = 0,
    ):
        self.config = config
        self.plan: FleetPlan = plan_fleet(n_shards, assignment)
        self.batchers = [
            RaggedBatcher(
                config,
                eps_targets=eps_targets,
                decimals=decimals,
                backend=backend,
                flush_samples=flush_samples,
                flush_deadline_s=flush_deadline_s,
                max_buckets=max_buckets,
                scope="series",
                clock=clock,
            )
            for _ in range(n_shards)
        ]
        self.tenant_of = tenant_of if tenant_of is not None else (lambda sid: "default")
        self.quotas = dict(quotas) if quotas else {}
        self.coarse_eps = coarse_eps
        self.kb_sync_every = kb_sync_every
        self.kb_store = kb_store
        self.global_kb = KnowledgeBase(config)
        self.kb_syncs: list[dict] = []
        self._flushes_since_sync = 0
        self._retry = retry
        self._gw_kwargs = dict(
            max_queue=max_queue,
            coarse_eps=coarse_eps,
            cache_frames=cache_frames,
            clock=clock,
            sleep=sleep,
            seed=seed,
        )
        self._blobs: Optional[list[bytes]] = None
        self._routing: Optional[list[dict]] = None
        self._gateways: list[Optional[FaultTolerantGateway]] = [None] * n_shards
        self._engines: list[Optional[AnalyticsEngine]] = [None] * n_shards
        self._down: dict[int, str] = {}
        self._quota_shed_qids: set[int] = set()
        self.completed: list[RangeQuery] = []
        self.stats = {
            "samples_ingested": 0,
            "frames_sealed": 0,
            "quota_rejected_ingest": 0,
            "quota_shed_queries": 0,
            "quota_rejected_queries": 0,
            "queries": 0,
            "shard_down_queries": 0,
            "kb_syncs": 0,
        }

    # -- topology ------------------------------------------------------- #
    @property
    def n_shards(self) -> int:
        return self.plan.n_shards

    def shard_of(self, series_id: int) -> int:
        return self.plan.shard_of(series_id)

    def _tenant(self, series_id: int, tenant: Optional[str]) -> str:
        return tenant if tenant is not None else self.tenant_of(int(series_id))

    # -- ingest --------------------------------------------------------- #
    def submit(
        self, series_id: int, values_chunk, tenant: Optional[str] = None
    ) -> list[tuple[int, int, int]]:
        """Route one series' next chunk to its shard's batcher; returns the
        frames that shard sealed.  Over-quota ingest raises a typed
        :class:`QuotaExceededError` — dropping samples to a coarse tier
        would be silent data loss, so ingest is admit-or-reject."""
        if self._blobs is not None:
            raise BatcherFinalizedError(
                "fleet already sealed", series_id=int(series_id)
            )
        sid = int(series_id)
        vals = np.asarray(values_chunk, dtype=np.float64).ravel()
        tq = self.quotas.get(self._tenant(sid, tenant))
        if tq is not None and vals.size and not tq.try_take(float(vals.size)):
            self.stats["quota_rejected_ingest"] += 1
            raise QuotaExceededError(
                f"tenant {self._tenant(sid, tenant)!r} ingest quota exhausted "
                f"({vals.size} samples > {tq.available():.0f} tokens)",
                series_id=sid,
            )
        self.stats["samples_ingested"] += int(vals.size)
        sealed = self.batchers[self.shard_of(sid)].submit(sid, vals)
        if sealed:
            self._note_flush(len(sealed))
        return sealed

    def poll(self) -> list[tuple[int, int, int]]:
        """Deadline sweep across every shard (drive from a timer loop)."""
        sealed: list[tuple[int, int, int]] = []
        for b in self.batchers:
            sealed.extend(b.poll())
        if sealed:
            self._note_flush(len(sealed))
        return sealed

    def _note_flush(self, n_frames: int) -> None:
        self.stats["frames_sealed"] += n_frames
        self._flushes_since_sync += 1
        if (
            self.kb_sync_every is not None
            and self._flushes_since_sync >= self.kb_sync_every
        ):
            self.sync_kbs()

    # -- knowledge-base replication ------------------------------------- #
    def sync_kbs(self) -> dict:
        """Rebuild the fleet-global KB by merging every shard KB (merge
        order cannot matter — the canonical maps are equal under any
        permutation, property-tested) and record an epoch-tagged sync
        point: per-shard entry counts + the global semantic snapshot id.
        Frames sealed before this sync reference only entries below their
        shard's recorded epoch, so any snapshot at/after the sync contains
        their refs.  With a ``kb_store`` attached, every shard also
        gossips its KB into the store under a stable ``shard<i>`` handle
        (replace semantics — repeated syncs of a growing shard KB never
        double-count) and the sync record carries the store's epoch-tagged
        state; after the last sync the store's semantic id equals the
        global KB's ``snapshot_id()`` whenever the shards are its only
        sources (property-tested)."""
        g = KnowledgeBase(self.config)
        shard_epochs = []
        for b in self.batchers:
            g.merge(b.kb)
            shard_epochs.append(b.kb.epoch)
        self.global_kb = g
        rec = {
            "sync": len(self.kb_syncs),
            "global_entries": g.epoch,
            "shard_epochs": shard_epochs,
            "semantic_id": g.snapshot_id(),
        }
        if self.kb_store is not None:
            for i, b in enumerate(self.batchers):
                self.kb_store.gossip(f"shard{i}", b.kb)
            rec["store"] = {
                "live": self.kb_store.live_count,
                "sem_id": self.kb_store.sem_id(),
            }
        self.kb_syncs.append(rec)
        self.stats["kb_syncs"] += 1
        self._flushes_since_sync = 0
        return rec

    # -- seal / routing -------------------------------------------------- #
    def seal(self) -> list[bytes]:
        """Finalize every shard batcher into its SHRKS container, run a
        final KB sync, and verify the routing invariant (every frame's
        ``kb_epoch`` <= its shard snapshot's entry count).  Idempotent —
        repeated calls return the same blobs."""
        if self._blobs is None:
            self._blobs = [b.finalize() for b in self.batchers]
            # finalize flushed whatever was still pending; re-base the
            # fleet frame counter on the authoritative per-shard totals
            self.stats["frames_sealed"] = sum(
                b.stats()["frames"] for b in self.batchers
            )
            self.sync_kbs()
            self._routing = [routing_metadata(bl) for bl in self._blobs]
            for shard, meta in enumerate(self._routing):
                if meta["frames"] and not meta["self_contained"]:
                    self._down[shard] = (
                        f"shard {shard} container violates the KB routing "
                        f"invariant (frame epoch {meta['max_frame_epoch']} > "
                        f"snapshot entries {meta['kb_entries']})"
                    )
        return list(self._blobs)

    @property
    def shard_blobs(self) -> list[bytes]:
        return self.seal()

    def routing(self) -> list[dict]:
        """Per-shard ``routing_metadata`` (series ids, frame KB epochs, KB
        snapshot ids) — what a fleet router would gossip."""
        self.seal()
        return [dict(m) for m in self._routing]

    def inject_shard_blob(self, shard: int, blob: bytes) -> None:
        """Replace one shard's container and reset its serving stack (the
        chaos suite's shard-kill hook; also the path a real repair/restore
        would take).  Other shards are untouched."""
        self.seal()
        self._blobs[shard] = bytes(blob)
        self._gateways[shard] = None
        self._engines[shard] = None
        self._down.pop(shard, None)

    def shards_down(self) -> dict[int, str]:
        """Shards currently out of service, with the typed reason."""
        return dict(self._down)

    # -- per-shard serving stacks ---------------------------------------- #
    def gateway(self, shard: int) -> FaultTolerantGateway:
        """The shard's fault-tolerant gateway (built lazily over its
        container).  A container that cannot even parse marks the shard
        down and raises the typed error — queries to OTHER shards are
        unaffected."""
        self.seal()
        if shard in self._down:
            raise ShrinkError(self._down[shard])
        gw = self._gateways[shard]
        if gw is None:
            try:
                gw = FaultTolerantGateway(
                    self._blobs[shard], retry=self._retry, **self._gw_kwargs
                )
            except ShrinkError as e:
                self._down[shard] = f"{type(e).__name__}: {e}"
                raise
            self._gateways[shard] = gw
        return gw

    def engine(self, shard: int):
        """The shard's analytics engine (:class:`repro.analytics.
        AnalyticsEngine`), sharing the gateway's frame LRU (range decodes
        and aggregates never decode a layer twice)."""
        # Deferred import: repro.analytics imports serving.batching, so a
        # module-level import here would make the serving<->analytics
        # package cycle order-dependent (analytics-first imports break).
        from ..analytics import AnalyticsEngine

        eng = self._engines[shard]
        if eng is None:
            eng = AnalyticsEngine(self.gateway(shard).batcher)
            self._engines[shard] = eng
        return eng

    # -- queries --------------------------------------------------------- #
    def _admit_query(self, q: RangeQuery, tenant: Optional[str]) -> Optional[str]:
        """Quota admission for one query.  Returns None when admitted
        (possibly shed to coarse — ``q.eps`` is then widened and the qid
        recorded), or the typed error string when rejected outright."""
        tq = self.quotas.get(self._tenant(q.series_id, tenant))
        if tq is None or tq.try_take(float(max(q.t1 - q.t0, 1))):
            return None
        if self.coarse_eps is not None:
            q.eps = max(q.eps, self.coarse_eps)
            self._quota_shed_qids.add(q.qid)
            self.stats["quota_shed_queries"] += 1
            return None
        self.stats["quota_rejected_queries"] += 1
        e = QuotaExceededError(
            f"tenant {self._tenant(q.series_id, tenant)!r} query quota "
            f"exhausted and no coarse tier configured",
            series_id=q.series_id,
        )
        return f"{type(e).__name__}: {e}"

    def query(
        self,
        q: RangeQuery,
        tenant: Optional[str] = None,
        deadline_s: float | None = None,
    ) -> RangeQuery:
        """Serve one range query synchronously through its shard's gateway.
        Failures land typed in ``q.error`` (quota rejection, shard down,
        or anything the gateway itself types) — never an unhandled raise,
        never a silent wrong answer."""
        self.stats["queries"] += 1
        rejected = self._admit_query(q, tenant)
        if rejected is not None:
            q.error = rejected
            self.completed.append(q)
            return q
        try:
            gw = self.gateway(self.shard_of(q.series_id))
        except ShrinkError as e:
            self.stats["shard_down_queries"] += 1
            q.error = f"{type(e).__name__}: {e}"
            self.completed.append(q)
            return q
        gw.serve(q, deadline_s=deadline_s)
        if q.qid in self._quota_shed_qids and q.error is None:
            q.degraded = True
        self.completed.append(q)
        return q

    def enqueue(self, q: RangeQuery, tenant: Optional[str] = None) -> None:
        """Queue a query on its shard's gateway (bounded admission: beyond
        the queue bound the gateway sheds to coarse / raises
        :class:`BackpressureError`).  Quota rejection raises the typed
        :class:`QuotaExceededError` here — there is no result object to
        park the error on until ``run``."""
        rejected = self._admit_query(q, tenant)
        if rejected is not None:
            raise QuotaExceededError(rejected, series_id=q.series_id)
        self.gateway(self.shard_of(q.series_id)).submit(q)

    def run(self, deadline_s: float | None = None) -> list[RangeQuery]:
        """Drain every shard gateway's queue; returns the completed
        queries (quota-shed ones flagged degraded)."""
        done: list[RangeQuery] = []
        for shard in range(self.n_shards):
            gw = self._gateways[shard]
            if gw is None or not gw.queue:
                continue
            for q in gw.run(deadline_s=deadline_s):
                self.stats["queries"] += 1
                if q.qid in self._quota_shed_qids and q.error is None:
                    q.degraded = True
                done.append(q)
        self.completed.extend(done)
        return done

    # -- analytics ------------------------------------------------------- #
    def aggregate(
        self,
        series_id: int,
        op: str,
        t0: int = 0,
        t1: int | None = None,
        eps: float | None = None,
        tenant: Optional[str] = None,
    ):
        """Interval-guaranteed aggregate through the series' shard engine.
        Over-quota requests are shed to the segment tier (``eps=None`` —
        zero entropy work) and flagged ``degraded``: the interval is wider
        than asked but still contains the truth."""
        sid = int(series_id)
        tq = self.quotas.get(self._tenant(sid, tenant))
        shed = False
        if tq is not None:
            hi = t1 if t1 is not None else self.engine(self.shard_of(sid)).span(sid)[1]
            if not tq.try_take(float(max(hi - t0, 1))):
                self.stats["quota_shed_queries"] += 1
                eps = None
                shed = True
        ans = self.engine(self.shard_of(sid)).aggregate(sid, op, t0, t1, eps=eps)
        return replace(ans, degraded=True) if shed else ans

    def count_where(
        self,
        series_id: int,
        op: str,
        value: float,
        t0: int = 0,
        t1: int | None = None,
        eps: float | None = None,
    ):
        sid = int(series_id)
        return self.engine(self.shard_of(sid)).count_where(
            sid, op, value, t0, t1, eps=eps
        )

    def topk_segments(self, series_id: int, k: int = 5, by: str = "length"):
        sid = int(series_id)
        return self.engine(self.shard_of(sid)).topk_segments(sid, k=k, by=by)

    # -- differential plumbing ------------------------------------------- #
    def series_frames(self, series_id: int) -> list[tuple[int, int, bytes]]:
        """One series' sealed frames as ``(t_lo, t_hi, payload_bytes)`` in
        time order, pulled from its shard's container — the unit the
        cross-shard byte-identity differential compares."""
        sid = int(series_id)
        blob = self.seal()[self.shard_of(sid)]
        metas, _ = parse_framed_container(blob)
        mine = sorted((m for m in metas if m.series_id == sid), key=lambda m: m.t_lo)
        return [(m.t_lo, m.t_hi, frame_payload(blob, m)) for m in mine]

    def decode_range(self, series_id: int, t0: int, t1: int, eps: float) -> np.ndarray:
        """Direct (armor-free) range decode against the shard container."""
        from ..core.streaming import decode_range as _decode_range

        sid = int(series_id)
        return _decode_range(self.seal()[self.shard_of(sid)], sid, t0, t1, eps)

    # -- introspection --------------------------------------------------- #
    def fleet_stats(self) -> dict:
        st = dict(self.stats)
        st["n_shards"] = self.n_shards
        st["shards_down"] = sorted(self._down)
        st["global_kb"] = self.global_kb.stats() if self.global_kb.entries else {}
        st["shards"] = [b.stats() for b in self.batchers]
        backends: dict[str, dict[str, int]] = {}
        for shard in st["shards"]:
            merge_backend_stats(backends, shard.get("backends", {}))
        st["backends"] = backends
        st["gateways"] = [
            (gw.stats if gw is not None else None) for gw in self._gateways
        ]
        return st
