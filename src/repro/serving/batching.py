"""Continuous-batching request scheduler for the decode loop.

Fixed-slot batch (static shapes for jit): requests occupy slots; finished
slots are recycled for queued requests.  All slots share one decode step —
the per-slot position mask lives in the KV cache's kpos (-1 = empty), so a
fresh request starting at position 0 coexists with one at position 10k.
Slot admission resets the slot's cache region lazily via position masking
(kpos entries of stale data are overwritten as decode proceeds; correctness
comes from the per-slot `pos` counters used to build attention masks).

This container's single CPU device runs the same code the 512-chip mesh
would jit — the scheduler is device-count agnostic.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Request", "ContinuousBatcher"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    """decode_fn(tokens[B,1], caches, index) -> (logits, caches).

    NOTE: this simple scheduler advances all slots with a single shared
    cache_index (the max position across slots); per-slot validity is
    enforced by kpos masks.  Prompts are fed token-by-token (prefill==decode
    path) which keeps the demo simple; a production system would batch
    prefill separately (see examples/serve_decode.py).
    """

    def __init__(
        self,
        decode_fn: Callable,
        make_caches: Callable[[], object],
        n_slots: int,
        eos_token: int = 2,
        greedy: bool = True,
    ):
        self.decode_fn = decode_fn
        self.caches = make_caches()
        self.n_slots = n_slots
        self.eos = eos_token
        self.greedy = greedy
        self.queue: deque[Request] = deque()
        self.slots: list[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, dtype=np.int64)  # next prompt idx
        self.global_index = 0
        self.completed: list[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for i in range(self.n_slots):
            if self.slots[i] is None and self.queue:
                self.slots[i] = self.queue.popleft()
                self.slot_pos[i] = 0

    def step(self) -> bool:
        """One decode step for all active slots; returns True if any work
        remains."""
        self._admit()
        if all(s is None for s in self.slots) and not self.queue:
            return False
        tokens = np.zeros((self.n_slots, 1), dtype=np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            p = int(self.slot_pos[i])
            if p < len(req.prompt):
                tokens[i, 0] = req.prompt[p]
            elif req.generated:
                tokens[i, 0] = req.generated[-1]
        logits, self.caches = self.decode_fn(
            jnp.asarray(tokens), self.caches, jnp.asarray(self.global_index, jnp.int32)
        )
        nxt = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self.slot_pos[i] += 1
            if self.slot_pos[i] >= len(req.prompt):
                tok = int(nxt[i])
                req.generated.append(tok)
                if tok == self.eos or len(req.generated) >= req.max_new_tokens:
                    req.done = True
                    self.completed.append(req)
                    self.slots[i] = None
        self.global_index += 1
        return True

    def run(self, max_steps: int = 10_000) -> list[Request]:
        steps = 0
        while self.step() and steps < max_steps:
            steps += 1
        return self.completed
