"""Continuous-batching request schedulers: LLM decode loop + SHRINK range
queries.

``ContinuousBatcher`` drives the token decode loop (fixed-slot batch,
static shapes for jit).  ``RangeQueryBatcher`` serves time-series range
queries against a SHRKS framed container: queries are queued, grouped by
the frames they touch, and each (frame, eps) is decoded at most once per
batch via an LRU of reconstructed frames — the batching win is that N
queries hitting the same hot frame cost one frame decode, not N.

Fixed-slot batch (static shapes for jit): requests occupy slots; finished
slots are recycled for queued requests.  All slots share one decode step —
the per-slot position mask lives in the KV cache's kpos (-1 = empty), so a
fresh request starting at position 0 coexists with one at position 10k.
Slot admission resets the slot's cache region lazily via position masking
(kpos entries of stale data are overwritten as decode proceeds; correctness
comes from the per-slot `pos` counters used to build attention masks).

This container's single CPU device runs the same code the 512-chip mesh
would jit — the scheduler is device-count agnostic.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from ..core.errors import (
    CorruptFrameError,
    LayerCorruptError,
    RangeCoverageError,
    UnknownSeriesError,
)
from ..core.serialize import frame_payload, parse_framed_container, read_snapshot_ref
from ..core.shrink import ProgressiveDecoder, cs_from_bytes

__all__ = ["Request", "ContinuousBatcher", "RangeQuery", "RangeQueryBatcher"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    """decode_fn(tokens[B,1], caches, index) -> (logits, caches).

    NOTE: this simple scheduler advances all slots with a single shared
    cache_index (the max position across slots); per-slot validity is
    enforced by kpos masks.  Prompts are fed token-by-token (prefill==decode
    path) which keeps the demo simple; a production system would batch
    prefill separately (see examples/serve_decode.py).
    """

    def __init__(
        self,
        decode_fn: Callable,
        make_caches: Callable[[], object],
        n_slots: int,
        eos_token: int = 2,
        greedy: bool = True,
    ):
        self.decode_fn = decode_fn
        self.caches = make_caches()
        self.n_slots = n_slots
        self.eos = eos_token
        self.greedy = greedy
        self.queue: deque[Request] = deque()
        self.slots: list[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, dtype=np.int64)  # next prompt idx
        self.global_index = 0
        self.completed: list[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for i in range(self.n_slots):
            if self.slots[i] is None and self.queue:
                self.slots[i] = self.queue.popleft()
                self.slot_pos[i] = 0

    def step(self) -> bool:
        """One decode step for all active slots; returns True if any work
        remains."""
        self._admit()
        if all(s is None for s in self.slots) and not self.queue:
            return False
        tokens = np.zeros((self.n_slots, 1), dtype=np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            p = int(self.slot_pos[i])
            if p < len(req.prompt):
                tokens[i, 0] = req.prompt[p]
            elif req.generated:
                tokens[i, 0] = req.generated[-1]
        logits, self.caches = self.decode_fn(
            jnp.asarray(tokens), self.caches, jnp.asarray(self.global_index, jnp.int32)
        )
        nxt = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self.slot_pos[i] += 1
            if self.slot_pos[i] >= len(req.prompt):
                tok = int(nxt[i])
                req.generated.append(tok)
                if tok == self.eos or len(req.generated) >= req.max_new_tokens:
                    req.done = True
                    self.completed.append(req)
                    self.slots[i] = None
        self.global_index += 1
        return True

    def run(self, max_steps: int = 10_000) -> list[Request]:
        steps = 0
        while self.step() and steps < max_steps:
            steps += 1
        return self.completed


# --------------------------------------------------------------------- #
# SHRINK range-query serving
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class RangeQuery:
    """One range-decode request against a streamed container: reconstruct
    samples [t0, t1) of ``series_id`` at resolution ``eps``.  ``achieved``
    reports the guarantee of the tier the pyramid actually served (always
    <= eps on success; coarser than eps only for ``peek`` sketches)."""

    qid: int
    series_id: int
    t0: int
    t1: int
    eps: float
    result: Optional[np.ndarray] = None
    achieved: Optional[float] = None
    error: Optional[str] = None
    # True when corruption forced a coarser answer than requested:
    # ``achieved`` is then the (still valid) guarantee actually served,
    # possibly > eps.  Never set on a full-resolution answer.
    degraded: bool = False


class RangeQueryBatcher:
    """Progressive batched random-access decode over a ``SHRKS`` container.

    The container directory is parsed once; each submitted query resolves
    to the frames overlapping its range.  Each frame payload holds a
    residual refinement *pyramid*, and the LRU caches one
    ``ProgressiveDecoder`` per hot frame — i.e. the frame's decoded **layer
    prefix**, not a single-eps reconstruction:

    * a query at a coarse eps decodes only the coarse layers;
    * a later query at a finer eps on the same frame pays only the
      refinement layers below the cached prefix (``layer_hits`` counts the
      layers it did NOT have to re-decode);
    * ``peek`` answers from whatever prefix is already materialized with
      ZERO entropy work — serve the dashboard a coarse sketch immediately,
      let ``run`` fetch refinement layers on demand.

    Frame payload CRCs are verified on first touch (lazily, per the SHRKS
    contract).

    ``degraded_ok=True`` turns corruption from an error into *scoped
    degradation* (docs/robustness.md): a corrupt pyramid layer quarantines
    only that layer and the query is served from the finest intact prefix
    (``q.degraded=True``, ``q.achieved`` = the bound actually delivered);
    a frame whose residual section is unusable but whose header/base CRC
    holds falls back to base-only (segment) reconstruction.  Answers are
    never silently wrong — a frame that cannot even prove its base is
    intact still errors.
    """

    def __init__(
        self,
        blob: bytes,
        cache_frames: int = 32,
        degraded_ok: bool = False,
        kb_store=None,  # serving.kbstore.KBStore
    ):
        self.degraded_ok = bool(degraded_ok)
        self._blob = bytes(blob)
        metas, kb_bytes = parse_framed_container(self._blob)
        self._frames: dict[int, list] = {}
        for m in metas:
            self._frames.setdefault(m.series_id, []).append(m)
        for frames in self._frames.values():
            frames.sort(key=lambda m: m.t_lo)
        self._cache: OrderedDict[int, ProgressiveDecoder] = OrderedDict()
        self._cache_frames = cache_frames
        self.queue: deque[RangeQuery] = deque()
        self.completed: list[RangeQuery] = []
        # decode never needs the KB (frame payloads carry their bases), but
        # a router wants the dictionary binding validated BEFORE serving:
        # with a kb_store, resolve the container's kb_snapshot_ref now — a
        # stale ref either falls back to the inline footer KB or raises a
        # typed StaleSnapshotError here, never binds silently wrong.
        if kb_store is not None:
            from .kbstore import resolve_container_kb

            _, kb_source = resolve_container_kb(self._blob, kb_store)
        elif kb_bytes:
            kb_source = "inline"
        else:
            kb_source = (
                "ref-unresolved" if read_snapshot_ref(self._blob) else "none"
            )
        self.stats = {
            "queries": 0,
            "frames_decoded": 0,
            "frame_hits": 0,
            "layers_decoded": 0,
            "layer_hits": 0,
            "errors": 0,
            "degraded": 0,
            "kb_source": kb_source,
        }

    @property
    def blob(self) -> bytes:
        """The raw container bytes (frame payloads are slices of this)."""
        return self._blob

    @property
    def series_ids(self) -> list[int]:
        return sorted(self._frames)

    def span(self, series_id: int) -> tuple[int, int]:
        """[t_lo, t_hi) covered by a series' frames."""
        frames = self._frames.get(series_id)
        if not frames:
            raise UnknownSeriesError(f"unknown series {series_id}", series_id=series_id)
        return frames[0].t_lo, frames[-1].t_hi

    def submit(self, q: RangeQuery) -> None:
        self.queue.append(q)

    def decoder(self, meta) -> ProgressiveDecoder:
        """The cached :class:`ProgressiveDecoder` for one frame (decoding
        the frame's container bytes on first touch).  Public so the
        compressed-domain analytics engine (``repro.analytics``) can
        refine through the SAME layer-prefix LRU range queries use — a
        dashboard mixing range decodes and aggregates never decodes a
        layer twice."""
        return self._decoder(meta)

    def _decoder(self, meta) -> ProgressiveDecoder:
        dec = self._cache.get(meta.offset)
        if dec is not None:
            self._cache.move_to_end(meta.offset)
            self.stats["frame_hits"] += 1
            return dec
        try:
            dec = ProgressiveDecoder(cs_from_bytes(frame_payload(self._blob, meta)))
        except CorruptFrameError:
            if not self.degraded_ok:
                raise
            # Tolerant path: skip the frame-level CRC and parse the SHRK
            # blob quarantining corrupt pyramid layers.  The SHRK header
            # CRC (eps_hat + base) is STILL verified inside cs_from_bytes
            # — if the base itself cannot be trusted, this re-raises and
            # the query errors rather than serving unprovable data.
            dec = ProgressiveDecoder(
                cs_from_bytes(
                    frame_payload(self._blob, meta, verify_crc=False), strict=False
                )
            )
        self.stats["frames_decoded"] += 1
        self._cache[meta.offset] = dec
        while len(self._cache) > self._cache_frames:
            self._cache.popitem(last=False)
        return dec

    def _decoded_frame(self, meta, eps: float) -> tuple[np.ndarray, float, bool]:
        dec = self._decoder(meta)
        k = dec.cs.pyramid.resolve(eps, dec.cs.eps_b_practical)
        degraded = False
        intact = dec.intact_depth()
        if k > intact:
            if not self.degraded_ok:
                raise LayerCorruptError(
                    f"frame needs layer prefix {k} but finest intact prefix is "
                    f"{intact}",
                    series_id=meta.series_id, layer=intact + 1,
                )
            k = intact  # serve the finest intact prefix, flagged
            degraded = True
        before = dec.layers_decoded
        vals = dec.prefix(k)
        paid = dec.layers_decoded - before
        self.stats["layers_decoded"] += paid
        # layers the cached prefix already covered (k+1 layers needed, minus
        # identity layers which are free by construction)
        needed = sum(
            1 for layer in dec.cs.pyramid.layers[: k + 1] if layer.mode != "identity"
        )
        self.stats["layer_hits"] += needed - paid
        return vals, dec.guarantee(k), degraded

    def frames_overlapping(self, series_id: int, t0: int, t1: int) -> list:
        """Directory entries of the frames covering samples [t0, t1) of a
        series, in time order; raises :class:`UnknownSeriesError` /
        :class:`RangeCoverageError` for an unknown series or a range the
        frames do not fully cover."""
        frames = self._frames.get(series_id)
        if not frames:
            raise UnknownSeriesError(f"unknown series {series_id}", series_id=series_id)
        touched = [m for m in frames if m.t_lo < t1 and m.t_hi > t0]
        if t1 <= t0 or not touched or touched[0].t_lo > t0 or touched[-1].t_hi < t1:
            raise RangeCoverageError(
                f"range [{t0}, {t1}) not covered by series {series_id} frames "
                f"[{frames[0].t_lo}, {frames[-1].t_hi})",
                series_id=series_id,
            )
        return touched

    def _frames_for(self, q: RangeQuery) -> list:
        return self.frames_overlapping(q.series_id, q.t0, q.t1)

    def _serve(self, q: RangeQuery) -> None:
        touched = self._frames_for(q)
        out = np.empty(q.t1 - q.t0, dtype=np.float64)
        achieved = 0.0
        degraded = False
        expected = q.t0
        for i, m in enumerate(touched):
            if m.t_lo > expected:
                raise RangeCoverageError(
                    f"gap in series {q.series_id} frames at sample {expected} "
                    f"(next frame covers [{m.t_lo}, {m.t_hi}))",
                    series_id=q.series_id, frame_index=i,
                )
            vals, guarantee, frame_degraded = self._decoded_frame(m, q.eps)
            achieved = max(achieved, guarantee)
            degraded = degraded or frame_degraded
            lo, hi = max(q.t0, m.t_lo), min(q.t1, m.t_hi)
            out[lo - q.t0 : hi - q.t0] = vals[lo - m.t_lo : hi - m.t_lo]
            expected = hi
        q.result = out
        q.achieved = achieved
        q.degraded = degraded
        if degraded:
            self.stats["degraded"] += 1

    def peek(self, q: RangeQuery) -> Optional[np.ndarray]:
        """Serve ``q`` from already-cached layer prefixes with NO entropy
        decode: returns a coarse sketch (setting ``q.result`` and
        ``q.achieved`` to the coarsest cached guarantee among touched
        frames), or ``None`` when any touched frame is cold.  The query
        stays in / may still be submitted to the refinement queue —
        ``run`` will then only pay for the missing layers."""
        try:
            touched = self._frames_for(q)
        except ValueError:
            return None
        parts: list[tuple] = []
        achieved = 0.0
        expected = q.t0
        for m in touched:
            if m.t_lo > expected:
                return None
            dec = self._cache.get(m.offset)
            avail = dec.available() if dec is not None else None
            if avail is None:
                return None
            vals, guarantee = avail
            achieved = max(achieved, guarantee)
            parts.append((m, vals))
            expected = m.t_hi
        out = np.empty(q.t1 - q.t0, dtype=np.float64)
        for m, vals in parts:
            lo, hi = max(q.t0, m.t_lo), min(q.t1, m.t_hi)
            out[lo - q.t0 : hi - q.t0] = vals[lo - m.t_lo : hi - m.t_lo]
        q.result = out
        q.achieved = achieved
        return out

    def run(self) -> list[RangeQuery]:
        """Drain the queue; returns the queries completed by this call."""
        done: list[RangeQuery] = []
        while self.queue:
            q = self.queue.popleft()
            self.stats["queries"] += 1
            try:
                self._serve(q)
            except (ValueError, KeyError) as e:
                q.error = str(e)
                self.stats["errors"] += 1
            done.append(q)
        self.completed.extend(done)
        return done
