"""Continuous-batching request schedulers: LLM decode loop + SHRINK range
queries.

``ContinuousBatcher`` drives the token decode loop (fixed-slot batch,
static shapes for jit).  ``RangeQueryBatcher`` serves time-series range
queries against a SHRKS framed container: queries are queued, grouped by
the frames they touch, and each (frame, eps) is decoded at most once per
batch via an LRU of reconstructed frames — the batching win is that N
queries hitting the same hot frame cost one frame decode, not N.

Fixed-slot batch (static shapes for jit): requests occupy slots; finished
slots are recycled for queued requests.  All slots share one decode step —
the per-slot position mask lives in the KV cache's kpos (-1 = empty), so a
fresh request starting at position 0 coexists with one at position 10k.
Slot admission resets the slot's cache region lazily via position masking
(kpos entries of stale data are overwritten as decode proceeds; correctness
comes from the per-slot `pos` counters used to build attention masks).

This container's single CPU device runs the same code the 512-chip mesh
would jit — the scheduler is device-count agnostic.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.serialize import frame_payload, parse_framed_container
from ..core.shrink import cs_from_bytes, decompress_at

__all__ = ["Request", "ContinuousBatcher", "RangeQuery", "RangeQueryBatcher"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    """decode_fn(tokens[B,1], caches, index) -> (logits, caches).

    NOTE: this simple scheduler advances all slots with a single shared
    cache_index (the max position across slots); per-slot validity is
    enforced by kpos masks.  Prompts are fed token-by-token (prefill==decode
    path) which keeps the demo simple; a production system would batch
    prefill separately (see examples/serve_decode.py).
    """

    def __init__(
        self,
        decode_fn: Callable,
        make_caches: Callable[[], object],
        n_slots: int,
        eos_token: int = 2,
        greedy: bool = True,
    ):
        self.decode_fn = decode_fn
        self.caches = make_caches()
        self.n_slots = n_slots
        self.eos = eos_token
        self.greedy = greedy
        self.queue: deque[Request] = deque()
        self.slots: list[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, dtype=np.int64)  # next prompt idx
        self.global_index = 0
        self.completed: list[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for i in range(self.n_slots):
            if self.slots[i] is None and self.queue:
                self.slots[i] = self.queue.popleft()
                self.slot_pos[i] = 0

    def step(self) -> bool:
        """One decode step for all active slots; returns True if any work
        remains."""
        self._admit()
        if all(s is None for s in self.slots) and not self.queue:
            return False
        tokens = np.zeros((self.n_slots, 1), dtype=np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            p = int(self.slot_pos[i])
            if p < len(req.prompt):
                tokens[i, 0] = req.prompt[p]
            elif req.generated:
                tokens[i, 0] = req.generated[-1]
        logits, self.caches = self.decode_fn(
            jnp.asarray(tokens), self.caches, jnp.asarray(self.global_index, jnp.int32)
        )
        nxt = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self.slot_pos[i] += 1
            if self.slot_pos[i] >= len(req.prompt):
                tok = int(nxt[i])
                req.generated.append(tok)
                if tok == self.eos or len(req.generated) >= req.max_new_tokens:
                    req.done = True
                    self.completed.append(req)
                    self.slots[i] = None
        self.global_index += 1
        return True

    def run(self, max_steps: int = 10_000) -> list[Request]:
        steps = 0
        while self.step() and steps < max_steps:
            steps += 1
        return self.completed


# --------------------------------------------------------------------- #
# SHRINK range-query serving
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class RangeQuery:
    """One range-decode request against a streamed container: reconstruct
    samples [t0, t1) of ``series_id`` at resolution ``eps``."""

    qid: int
    series_id: int
    t0: int
    t1: int
    eps: float
    result: Optional[np.ndarray] = None
    error: Optional[str] = None


class RangeQueryBatcher:
    """Batched random-access decode over a ``SHRKS`` framed container.

    The container directory is parsed once; each submitted query resolves
    to the frames overlapping its range.  ``run`` drains the queue,
    decoding each (frame, eps) at most once per batch and keeping up to
    ``cache_frames`` reconstructed frames in an LRU for the next batch —
    a gateway dashboard polling the same hot window repeatedly never
    re-pays the entropy decode.  Frame payload CRCs are verified on first
    touch (lazily, per the SHRKS contract).
    """

    def __init__(self, blob: bytes, cache_frames: int = 32):
        self._blob = bytes(blob)
        metas, _ = parse_framed_container(self._blob)
        self._frames: dict[int, list] = {}
        for m in metas:
            self._frames.setdefault(m.series_id, []).append(m)
        for frames in self._frames.values():
            frames.sort(key=lambda m: m.t_lo)
        self._cache: OrderedDict[tuple[int, float], np.ndarray] = OrderedDict()
        self._cache_frames = cache_frames
        self.queue: deque[RangeQuery] = deque()
        self.completed: list[RangeQuery] = []
        self.stats = {"queries": 0, "frames_decoded": 0, "frame_hits": 0, "errors": 0}

    @property
    def series_ids(self) -> list[int]:
        return sorted(self._frames)

    def span(self, series_id: int) -> tuple[int, int]:
        """[t_lo, t_hi) covered by a series' frames."""
        frames = self._frames[series_id]
        return frames[0].t_lo, frames[-1].t_hi

    def submit(self, q: RangeQuery) -> None:
        self.queue.append(q)

    def _decoded_frame(self, meta, eps: float) -> np.ndarray:
        key = (meta.offset, eps)
        hit = self._cache.get(key)
        if hit is not None:
            self._cache.move_to_end(key)
            self.stats["frame_hits"] += 1
            return hit
        cs = cs_from_bytes(frame_payload(self._blob, meta))
        vals = decompress_at(cs, eps)
        self.stats["frames_decoded"] += 1
        self._cache[key] = vals
        while len(self._cache) > self._cache_frames:
            self._cache.popitem(last=False)
        return vals

    def _serve(self, q: RangeQuery) -> None:
        frames = self._frames.get(q.series_id)
        if not frames:
            raise ValueError(f"unknown series {q.series_id}")
        touched = [m for m in frames if m.t_lo < q.t1 and m.t_hi > q.t0]
        if q.t1 <= q.t0 or not touched or touched[0].t_lo > q.t0 or touched[-1].t_hi < q.t1:
            raise ValueError(f"range [{q.t0}, {q.t1}) not covered")
        out = np.empty(q.t1 - q.t0, dtype=np.float64)
        expected = q.t0
        for m in touched:
            if m.t_lo > expected:
                raise ValueError(f"gap in series {q.series_id} frames at sample {expected}")
            vals = self._decoded_frame(m, q.eps)
            lo, hi = max(q.t0, m.t_lo), min(q.t1, m.t_hi)
            out[lo - q.t0 : hi - q.t0] = vals[lo - m.t_lo : hi - m.t_lo]
            expected = hi
        q.result = out

    def run(self) -> list[RangeQuery]:
        """Drain the queue; returns the queries completed by this call."""
        done: list[RangeQuery] = []
        while self.queue:
            q = self.queue.popleft()
            self.stats["queries"] += 1
            try:
                self._serve(q)
            except (ValueError, KeyError) as e:
                q.error = str(e)
                self.stats["errors"] += 1
            done.append(q)
        self.completed.extend(done)
        return done
