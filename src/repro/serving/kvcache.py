"""Serving KV-cache utilities: prefill->decode buffer promotion and
SHRINK residual-quantized cache blocks.

``promote_caches`` pads prefill-built caches (buffer == prompt length) into
decode buffers (buffer == max_seq), preserving ring-buffer semantics for
local-attention layers.

``QuantizedKV`` compresses K/V blocks with the residual_quant kernel
(per-block linear base + int8 residuals): ~3.7x cache memory reduction at a
bounded L-infinity error — SHRINK's bit-level phase applied to the cache.
Inapplicable to attention-free archs (rwkv): their recurrent state is
compressed with the same kernel by the caller instead (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..core.jaxshrink import CompressedTensor, TensorCodecConfig, compress_tensor, decompress_tensor
from ..models.layers import AttnCache, MLACache

__all__ = ["promote_caches", "QuantizedKV", "quantize_cache", "dequantize_cache"]


def _pad_axis(x: jax.Array, axis: int, new_size: int, fill=0):
    old = x.shape[axis]
    if old >= new_size:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, new_size - old)
    return jnp.pad(x, pad, constant_values=fill)


def promote_caches(caches: Any, max_seq: int) -> Any:
    """Pad every full-attention cache buffer (and MLA latent cache) from
    prompt length to max_seq; kpos pads with -1 (empty)."""

    def promote(leaf):
        return leaf

    def walk(node):
        if isinstance(node, AttnCache):
            return AttnCache(
                k=_pad_axis(node.k, 1, max_seq),
                v=_pad_axis(node.v, 1, max_seq),
                kpos=_pad_axis(node.kpos, 1, max_seq, fill=-1),
            )
        if isinstance(node, MLACache):
            return MLACache(
                c_kv=_pad_axis(node.c_kv, 1, max_seq),
                k_rope=_pad_axis(node.k_rope, 1, max_seq),
                kpos=_pad_axis(node.kpos, 1, max_seq, fill=-1),
            )
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [walk(v) for v in node]
            return type(node)(t) if not isinstance(node, tuple) else tuple(t)
        return promote(node)

    # stacked caches carry the group dim in axis 0 -> seq axis shifts by 1
    def walk_stacked(node, stacked: bool):
        if isinstance(node, AttnCache):
            ax = 2 if stacked else 1
            return AttnCache(
                k=_pad_axis(node.k, ax, max_seq),
                v=_pad_axis(node.v, ax, max_seq),
                kpos=_pad_axis(node.kpos, ax, max_seq, fill=-1),
            )
        if isinstance(node, MLACache):
            ax = 2 if stacked else 1
            return MLACache(
                c_kv=_pad_axis(node.c_kv, ax, max_seq),
                k_rope=_pad_axis(node.k_rope, ax, max_seq),
                kpos=_pad_axis(node.kpos, ax, max_seq, fill=-1),
            )
        if isinstance(node, dict):
            return {k: walk_stacked(v, stacked) for k, v in node.items()}
        if isinstance(node, list):
            return [walk_stacked(v, stacked) for v in node]
        return node

    return {
        "prefix": walk_stacked(caches.get("prefix", []), stacked=False),
        "groups": walk_stacked(caches.get("groups"), stacked=True),
        "tail": walk_stacked(caches.get("tail", []), stacked=False),
    }


@dataclasses.dataclass
class QuantizedKV:
    k: CompressedTensor
    v: CompressedTensor
    kpos: jax.Array

    def memory_bits(self) -> int:
        return self.k.wire_bits() + self.v.wire_bits() + self.kpos.size * 32


def quantize_cache(cache: AttnCache, cfg: TensorCodecConfig = TensorCodecConfig()) -> QuantizedKV:
    ck, _ = compress_tensor(cache.k, cfg)
    cv, _ = compress_tensor(cache.v, cfg)
    return QuantizedKV(k=ck, v=cv, kpos=cache.kpos)


def dequantize_cache(q: QuantizedKV, cfg: TensorCodecConfig = TensorCodecConfig()) -> AttnCache:
    return AttnCache(
        k=decompress_tensor(q.k, cfg).astype(jnp.bfloat16),
        v=decompress_tensor(q.v, cfg).astype(jnp.bfloat16),
        kpos=q.kpos,
    )
