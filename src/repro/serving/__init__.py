"""Serving: KV caches (+ SHRINK quantized), continuous batching, and
batched range-query decode over streamed SHRINK containers."""
from .kvcache import QuantizedKV, dequantize_cache, promote_caches, quantize_cache  # noqa: F401
from .batching import ContinuousBatcher, RangeQuery, RangeQueryBatcher, Request  # noqa: F401
