"""Serving: KV caches (+ SHRINK quantized), continuous batching."""
from .kvcache import QuantizedKV, dequantize_cache, promote_caches, quantize_cache  # noqa: F401
from .batching import ContinuousBatcher, Request  # noqa: F401
