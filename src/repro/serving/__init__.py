"""Serving: KV caches (+ SHRINK quantized), continuous batching, batched
range-query decode over streamed SHRINK containers, the ragged
multi-sensor ingest scheduler, the fault-tolerant gateway, the sharded
multi-tenant fleet, and the persistent cross-archive KB store."""
from .kvcache import QuantizedKV, dequantize_cache, promote_caches, quantize_cache  # noqa: F401
from .batching import ContinuousBatcher, RangeQuery, RangeQueryBatcher, Request  # noqa: F401
from .ragged import RaggedBatcher  # noqa: F401
from .gateway import CircuitBreaker, FaultTolerantGateway, RetryPolicy  # noqa: F401
from .fleet import ShrinkFleet, TenantQuota  # noqa: F401
from .kbstore import AttachRecord, KBStore, StoreSnapshot, resolve_container_kb  # noqa: F401
