"""Distribution: logical-axis sharding, param partitioning, collectives,
and fleet partitioning (series->shard placement for the serving fleet)."""
from .sharding import AxisRules, axis_rules, make_rules, shard  # noqa: F401
from .partition import param_specs, param_shardings, fsdp_axes_for  # noqa: F401
from .fleet import FleetPlan, plan_fleet, shard_of  # noqa: F401
