"""Distribution: logical-axis sharding, param partitioning, collectives."""
from .sharding import AxisRules, axis_rules, make_rules, shard  # noqa: F401
from .partition import param_specs, param_shardings, fsdp_axes_for  # noqa: F401
