"""Fleet partitioning: deterministic series->shard placement over a
device mesh.

The serving fleet (``repro.serving.fleet``) is a data-parallel system:
each shard owns a disjoint set of series end to end (ingest batcher,
container, gateway, analytics engine), so the only cross-shard traffic is
the periodic knowledge-base sync.  This module supplies the placement
math, kept separate from the serving logic so tests can drive ANY
assignment (the cross-shard differential suites quantify over it):

* :func:`shard_of` — the default stable hash (splitmix64 finalizer) from
  series id to shard.  Consecutive ids land on different shards, so the
  common "sensor 0..N-1" numbering balances without coordination.
* :class:`FleetPlan` — the frozen topology: shard count, the mesh the
  fleet runs over (built with ``launch.mesh.make_local_mesh`` over the
  process' devices, "data" axis = fleet parallelism), the shard->device
  placement, and the assignment function actually in force.
* :func:`plan_fleet` — build a plan; ``assignment`` overrides the hash
  with an explicit ``{series_id: shard}`` map (unknown ids fall back to
  the hash) or any callable.

On this container's single CPU device every shard maps to device 0 and
the shards execute sequentially — the same placement code that fans out
over a multi-device "data" axis, which is how the fleet benchmark models
aggregate throughput (critical path over per-shard busy time; see
docs/fleet.md).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Optional, Union

import jax

from ..launch.mesh import make_local_mesh

__all__ = ["FleetPlan", "plan_fleet", "shard_of"]

_MASK64 = (1 << 64) - 1


def shard_of(series_id: int, n_shards: int) -> int:
    """Deterministic, stable series->shard hash (splitmix64 finalizer):
    uniform over shards, independent of process/interpreter state, and
    identical across every node that routes for the fleet."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    x = (int(series_id) * 0x9E3779B97F4A7C15) & _MASK64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _MASK64
    return (x ^ (x >> 31)) % n_shards


@dataclasses.dataclass(frozen=True)
class FleetPlan:
    """Frozen fleet topology: who routes where, on which device."""

    n_shards: int
    mesh: object  # jax Mesh with a "data" axis = fleet parallelism
    devices: tuple  # shard i runs on devices[i]
    assign: Callable[[int], int]  # series_id -> shard

    def shard_of(self, series_id: int) -> int:
        s = int(self.assign(int(series_id)))
        if not 0 <= s < self.n_shards:
            raise ValueError(
                f"assignment sent series {series_id} to shard {s} "
                f"outside [0, {self.n_shards})"
            )
        return s

    def device_of(self, shard: int) -> object:
        return self.devices[shard]

    def describe(self) -> dict:
        return {
            "n_shards": self.n_shards,
            "mesh_devices": int(len(self.mesh.devices.flat)),
            "devices": [str(d) for d in self.devices],
        }


def plan_fleet(
    n_shards: int,
    assignment: Optional[Union[Mapping[int, int], Callable[[int], int]]] = None,
) -> FleetPlan:
    """Build the fleet topology: a local mesh whose "data" axis spans the
    process' devices, shard->device placement (round-robin when shards
    outnumber devices — the single-host regime), and the series->shard
    assignment (default: :func:`shard_of`; a mapping overrides specific
    ids and falls back to the hash for the rest)."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    n_dev = jax.device_count()
    mesh = make_local_mesh(data=min(n_shards, n_dev), model=1)
    mesh_devs = list(mesh.devices.flat)
    devices = tuple(mesh_devs[i % len(mesh_devs)] for i in range(n_shards))
    if assignment is None:
        assign = lambda sid: shard_of(sid, n_shards)  # noqa: E731
    elif callable(assignment):
        assign = assignment
    else:
        table = {int(k): int(v) for k, v in assignment.items()}
        assign = lambda sid: table.get(sid, shard_of(sid, n_shards))  # noqa: E731
    return FleetPlan(n_shards=n_shards, mesh=mesh, devices=devices, assign=assign)
