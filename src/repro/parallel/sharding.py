"""Logical-axis sharding: models annotate activations with logical names;
a mesh-specific rule set maps names to mesh axes.  Outside a rules context
the annotations are no-ops, so the same model code runs in CPU smoke tests
(1 device) and in the 512-device dry-run.

Logical activation axes:
    batch     -> ("pod", "data") on the multi-pod mesh, ("data",) single-pod
    heads     -> "model"
    kv_heads  -> "model"   (pads when kv < 16; see DESIGN.md §5 + §Perf)
    ffn       -> "model"
    experts   -> "model"   (expert parallelism)
    vocab     -> "model"
    seq_model -> "model"   (sequence parallelism, hillclimb lever)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "AxisRules",
    "abstract_mesh",
    "axis_rules",
    "current_rules",
    "shard",
    "make_rules",
    "shard_map_compat",
]

_STATE = threading.local()


def shard_map_compat(
    f, *, mesh, in_specs, out_specs, check_vma: bool = True, axis_names=None
):
    """``jax.shard_map`` across jax versions: new releases expose it at the
    top level (``check_vma``, ``axis_names``); 0.4.x has
    ``jax.experimental.shard_map`` where the same knobs are ``check_rep``
    and the complementary ``auto`` axis set."""
    if hasattr(jax, "shard_map"):
        kwargs = {} if axis_names is None else {"axis_names": set(axis_names)}
        return jax.shard_map(
            f,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_vma=check_vma,
            **kwargs,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    auto = (
        frozenset(mesh.axis_names) - frozenset(axis_names)
        if axis_names is not None
        else frozenset()
    )
    return _shard_map(
        f,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_rep=check_vma,
        auto=auto,
    )


class AxisRules:
    def __init__(self, mesh: Mesh, mapping: dict[str, object]):
        self.mesh = mesh
        self.mapping = dict(mapping)

    def resolve(self, name: Optional[str]):
        if name is None:
            return None
        return self.mapping.get(name)

    def spec(self, *names) -> P:
        return P(*[self.resolve(n) for n in names])


def make_rules(mesh: Mesh, cfg=None, overrides: Optional[dict] = None) -> AxisRules:
    """cfg (a ModelConfig) gates head axes by divisibility: forcing 8 kv
    heads onto a 16-way axis makes GSPMD fall back to 'involuntary full
    rematerialization' (replicate + repartition) per layer — replicating
    the small KV activations instead is strictly cheaper."""
    axes = mesh.axis_names
    batch = tuple(a for a in ("pod", "data") if a in axes) or None
    fsdp = "data" if "data" in axes else None
    msize = mesh.shape.get("model", 1) if "model" in axes else 1

    def fits(n: Optional[int]) -> Optional[str]:
        if "model" not in axes:
            return None
        if cfg is None or n is None:
            return "model"
        return "model" if (n % msize == 0) else None

    n_heads = getattr(cfg, "n_heads", None)
    n_kv = getattr(cfg, "n_kv_heads", None)
    force = bool(getattr(cfg, "force_head_sharding", False))
    mapping = {
        "batch": batch,
        "heads": ("model" if ("model" in axes and force) else fits(n_heads)),
        "kv_heads": fits(n_kv),
        "ffn": "model" if "model" in axes else None,
        "experts": "model" if "model" in axes else None,
        "vocab": "model" if "model" in axes else None,
        "seq_model": None,  # flipped to "model" by the sequence-parallel lever
        "fsdp": fsdp,
    }
    if overrides:
        mapping.update(overrides)
    return AxisRules(mesh, mapping)


def current_rules() -> Optional[AxisRules]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def axis_rules(rules: Optional[AxisRules]):
    prev = current_rules()
    _STATE.rules = rules
    try:
        yield
    finally:
        _STATE.rules = prev


def shard(x: jax.Array, *names) -> jax.Array:
    """Constrain ``x``'s sharding by logical axis names (None = unsheared
    dim).  No-op when no rules are active (CPU smoke tests).  Inside a
    shard_map region (Manual axes) the constraint must be spec-only so it
    canonicalizes against the context AbstractMesh.  (See
    ``shard_map_compat`` for the cross-version shard_map entry point.)"""
    rules = current_rules()
    if rules is None:
        return x
    if len(names) != x.ndim:
        raise ValueError(f"shard(): {len(names)} names for rank-{x.ndim} array")
    spec = rules.spec(*names)
    am = _get_abstract_mesh()
    if am is not None and not am.empty:
        return jax.lax.with_sharding_constraint(x, spec)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


def _get_abstract_mesh():
    """The context AbstractMesh across jax versions: public
    ``jax.sharding.get_abstract_mesh`` on new jax, the internal
    ``jax._src.mesh`` getter on 0.4.x, ``None`` when neither exists (the
    caller then constrains with an explicit NamedSharding)."""
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is None:
        try:
            from jax._src import mesh as _mesh_lib

            fn = getattr(_mesh_lib, "get_abstract_mesh", None)
        except ImportError:  # pragma: no cover
            fn = None
    return fn() if fn is not None else None


def abstract_mesh(axis_sizes, axis_names):
    """Cross-version ``AbstractMesh`` constructor: new jax takes
    ``(axis_sizes, axis_names)``, 0.4.x takes a single
    ``((name, size), ...)`` shape tuple."""
    try:
        return jax.sharding.AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(axis_names, axis_sizes)))
