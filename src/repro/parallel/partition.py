"""Parameter partition specs, derived from leaf path names + ranks.

Params are nested dicts; block stacks add a leading ``n_groups`` dim which
maps to ``None`` (every device holds its slice of every layer).  The fsdp
axis is ("pod","data") when ``cfg.dcn_fsdp`` and the mesh has a pod axis
(ZeRO-3 across pods — llama4-400b), else ("data",).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig

__all__ = ["param_specs", "param_shardings", "fsdp_axes_for"]


def fsdp_axes_for(cfg: ModelConfig, mesh: Mesh):
    axes = mesh.axis_names
    if "data" not in axes:
        return None
    if cfg.dcn_fsdp and "pod" in axes:
        return ("pod", "data")
    return "data"


# rules keyed by (leaf name); value = base spec builder given fsdp axis F.
def _base_rule(name: str, ndim: int, F, in_moe: bool = False):
    M = "model"
    two = {
        "embed": (M, F),
        "head": (F, M),
        "wq": (F, M),
        "wk": (F, M),
        "wv": (F, M),
        "wr": (F, M),
        "wg": (F, M),
        "wu": (F, M),
        "wo": (M, F),
        "wd": (M, F),
        "w_dkv": (F, None),
        "w_krope": (F, None),
        "w_kup": (None, M),
        "w_vup": (None, M),
        "router": (F, None),
        "w_in_x": (F, M),
        "w_in_g": (F, M),
        "wa": (F, M),
        "wx": (F, M),
        "w_out": (M, F),
        "conv": (None, M),
        "w_lora_a": (F, None),
        "w_lora_b": (None, F),
        "cm_k": (F, M),
        "cm_v": (M, F),
        "cm_r": (F, M),
        "mix_rkvwg": (None, None),
        "cm_mix": (None, None),
        "decay_base": (None, None),
        "bonus_u": (None, None),
    }
    three = {  # expert-stacked weights [E, in, out] (only under a moe path)
        "wg": (M, F, None),
        "wu": (M, F, None),
        "wd": (M, None, F),
    }
    if ndim == 1:
        return (None,)
    if in_moe and name in three:
        return three[name]
    if name in two:
        return two[name]
    return tuple([None] * ndim)


def _spec_for_leaf(path, leaf, F) -> P:
    name = None
    keys = []
    for entry in path:
        key = getattr(entry, "key", None) or getattr(entry, "name", None)
        if isinstance(key, str):
            keys.append(key)
    name = keys[-1] if keys else None
    # expert weights live directly under a "moe" dict (shared experts are a
    # plain mlp under moe/shared and keep the 2D rules)
    in_moe = len(keys) >= 2 and keys[-2] == "moe"
    ndim = leaf.ndim
    base = _base_rule(name or "", ndim, F, in_moe=in_moe)
    if len(base) == ndim:
        return P(*base)
    if len(base) == ndim - 1:
        return P(None, *base)  # stacked blocks: leading group dim
    if len(base) == ndim - 2:
        return P(None, None, *base)
    return P(*([None] * ndim))


def param_specs(params, cfg: ModelConfig, mesh: Mesh, vocab_dim_sharded: bool = True):
    """Pytree of PartitionSpec matching ``params`` (works on shapes too).

    vocab_dim_sharded=False re-lays the embedding table as (None, d-sharded):
    gathers from a vocab-sharded table inside a partial-auto shard_map crash
    XLA's SPMD partitioner (spmd_partitioner_util.cc:504 check, reproduced in
    tests/test_sharding.py) — the compressed cross-pod train step uses this
    layout as the workaround (DESIGN.md §6).
    """
    F = fsdp_axes_for(cfg, mesh)

    def spec(path, leaf):
        s = _spec_for_leaf(path, leaf, F)
        if not vocab_dim_sharded:
            keys = [getattr(e, "key", None) or getattr(e, "name", None) for e in path]
            if keys and keys[-1] == "embed":
                model = "model" if "model" in mesh.axis_names else None
                dshard = tuple(a for a in ((F,) if isinstance(F, str) else (F or ())) )
                combo = tuple(x for x in ((("data",) if "data" in mesh.axis_names else ()) + ((model,) if model else ())))
                return P(None, combo or None)
        return s

    return jax.tree_util.tree_map_with_path(spec, params)


def param_shardings(params, cfg: ModelConfig, mesh: Mesh):
    specs = param_specs(params, cfg, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
