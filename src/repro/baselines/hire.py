"""HIRE-style baseline (Barbarioli et al., SIGMOD/PACMMOD 2023) —
hierarchical residual encoding with max-error pruning.

Top-down dyadic decomposition: a node covering [lo, hi) stores its mid-range
value; if every point is within eps of it the node is a leaf, otherwise it
splits in half and the children encode residual structure.  Leaf values are
quantized onto the eps grid and entropy-coded; the tree shape is a bit per
node.  This captures HIRE's hierarchical-residual/multiresolution mechanism
in a compact reimplementation (documented deviation: HIRE fits per-level
affine functions; we use mid-range constants, which matches its behaviour on
the piecewise-flat IoT series benchmarked here).
"""
from __future__ import annotations

import struct

import numpy as np

from .bitio import BitReader, BitWriter
from ..core import entropy

__all__ = ["compress", "decompress"]

_MAGIC = b"HIRE"


def compress(values: np.ndarray, eps: float) -> bytes:
    values = np.asarray(values, dtype=np.float64)
    n = len(values)
    structure = BitWriter()
    leaf_vals: list[int] = []

    # iterative DFS, preorder; grid-quantize leaf mid-ranges to step eps
    stack: list[tuple[int, int]] = [(0, n)]
    while stack:
        lo, hi = stack.pop()
        seg = values[lo:hi]
        vmin = float(seg.min())
        vmax = float(seg.max())
        mid = 0.5 * (vmin + vmax)
        qmid = int(round(mid / eps)) if eps > 0 else 0
        ok = (vmax - vmin) <= 2 * eps and abs(qmid * eps - mid) + 0.5 * (vmax - vmin) <= eps
        if ok or hi - lo == 1:
            structure.write(1, 1)
            if hi - lo == 1:
                qmid = int(round(float(seg[0]) / eps))
            leaf_vals.append(qmid)
        else:
            structure.write(0, 1)
            m = (lo + hi) // 2
            stack.append((m, hi))  # preorder: left first -> push right first
            stack.append((lo, m))
    sbits = structure.finish()
    payload = entropy.encode_ints(np.array(leaf_vals, dtype=np.int64), backend="best")
    return (
        _MAGIC
        + struct.pack("<Qd I", n, eps, len(sbits))
        + sbits
        + payload
    )


def decompress(blob: bytes) -> np.ndarray:
    if blob[:4] != _MAGIC:
        raise ValueError("bad HIRE magic")
    n, eps, slen = struct.unpack_from("<QdI", blob, 4)
    off = 4 + 20
    sbits = BitReader(blob[off : off + slen])
    leaf_vals = entropy.decode_ints(blob[off + slen :])
    out = np.empty(n, dtype=np.float64)
    li = 0
    stack: list[tuple[int, int]] = [(0, n)]
    while stack:
        lo, hi = stack.pop()
        if sbits.read(1) == 1:
            out[lo:hi] = leaf_vals[li] * eps
            li += 1
        else:
            m = (lo + hi) // 2
            stack.append((m, hi))
            stack.append((lo, m))
    return out
