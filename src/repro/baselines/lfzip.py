"""LFZip baseline (Chandak et al., DCC 2020) — lossy floating-point
compression via an adaptive (NLMS) linear predictor + uniform quantization of
the prediction error + entropy coding.

We use filter order 8 (the original defaults to 32; order 8 keeps the pure
-Python replay tractable on multi-hundred-k series and costs little CR at the
error levels benchmarked — noted in EXPERIMENTS.md).  Quantization uses step
2*eps with round-to-nearest, so |v - v_hat| <= eps.
"""
from __future__ import annotations

import struct

import numpy as np

from ..core import entropy

__all__ = ["compress", "decompress", "ORDER"]

_MAGIC = b"LFZP"
ORDER = 8
_MU = 0.5
_EPS_NORM = 1e-6


def _nlms_quantize(values: np.ndarray, eps: float) -> tuple[np.ndarray, float]:
    """Replay NLMS on reconstructed values; return quantized error ints."""
    n = len(values)
    step = 2.0 * eps
    w = [0.0] * ORDER
    hist = [0.0] * ORDER  # most recent first
    q = np.empty(n, dtype=np.int64)
    vals = values.tolist()
    for i in range(n):
        pred = 0.0
        for j in range(ORDER):
            pred += w[j] * hist[j]
        e = vals[i] - pred
        qi = int(round(e / step))
        q[i] = qi
        recon = pred + qi * step
        # NLMS update with reconstructed error (decoder-replayable)
        err = recon - pred
        norm = _EPS_NORM
        for j in range(ORDER):
            norm += hist[j] * hist[j]
        g = _MU * err / norm
        for j in range(ORDER):
            w[j] += g * hist[j]
        hist.pop()
        hist.insert(0, recon)
    return q, step


def compress(values: np.ndarray, eps: float) -> bytes:
    values = np.asarray(values, dtype=np.float64)
    q, step = _nlms_quantize(values, eps)
    payload = entropy.encode_ints(q, backend="best")
    return _MAGIC + struct.pack("<Qd", len(values), step) + payload


def decompress(blob: bytes) -> np.ndarray:
    if blob[:4] != _MAGIC:
        raise ValueError("bad LFZip magic")
    n, step = struct.unpack_from("<Qd", blob, 4)
    q = entropy.decode_ints(blob[20:])
    out = np.empty(n, dtype=np.float64)
    w = [0.0] * ORDER
    hist = [0.0] * ORDER
    ql = q.tolist()
    for i in range(n):
        pred = 0.0
        for j in range(ORDER):
            pred += w[j] * hist[j]
        recon = pred + ql[i] * step
        out[i] = recon
        err = recon - pred
        norm = _EPS_NORM
        for j in range(ORDER):
            norm += hist[j] * hist[j]
        g = _MU * err / norm
        for j in range(ORDER):
            w[j] += g * hist[j]
        hist.pop()
        hist.insert(0, recon)
    return out
