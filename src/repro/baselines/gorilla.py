"""Gorilla float compression (Pelkonen et al., PVLDB 8(12), 2015) — lossless
XOR-based encoding of float64 streams with leading/trailing-zero windows.
"""
from __future__ import annotations

import struct

import numpy as np

from .bitio import BitReader, BitWriter

__all__ = ["compress", "decompress"]

_MAGIC = b"GORI"


def _clz64(x: int) -> int:
    return 64 - x.bit_length() if x else 64


def _ctz64(x: int) -> int:
    return (x & -x).bit_length() - 1 if x else 64


def compress(values: np.ndarray) -> bytes:
    bits = np.asarray(values, dtype=np.float64).view(np.uint64)
    n = len(bits)
    w = BitWriter()
    prev = 0
    prev_lz, prev_tz = -1, -1
    first = True
    for cur in bits.tolist():
        if first:
            w.write(cur, 64)
            prev = cur
            first = False
            continue
        xor = cur ^ prev
        prev = cur
        if xor == 0:
            w.write(0, 1)
            continue
        lz = min(_clz64(xor), 31)
        tz = _ctz64(xor)
        if prev_lz >= 0 and lz >= prev_lz and tz >= prev_tz:
            meaning = 64 - prev_lz - prev_tz
            w.write(0b10, 2)
            w.write(xor >> prev_tz, meaning)
        else:
            meaning = 64 - lz - tz
            w.write(0b11, 2)
            w.write(lz, 5)
            w.write(meaning - 1, 6)
            w.write(xor >> tz, meaning)
            prev_lz, prev_tz = lz, tz
    return _MAGIC + struct.pack("<Q", n) + w.finish()


def decompress(blob: bytes) -> np.ndarray:
    if blob[:4] != _MAGIC:
        raise ValueError("bad Gorilla magic")
    (n,) = struct.unpack_from("<Q", blob, 4)
    r = BitReader(blob[12:])
    out = np.empty(n, dtype=np.uint64)
    if n == 0:
        return out.view(np.float64)
    prev = r.read(64)
    out[0] = prev
    prev_lz, prev_tz = -1, -1
    for i in range(1, n):
        if r.read(1) == 0:
            out[i] = prev
            continue
        if r.read(1) == 0:  # '10' reuse window
            meaning = 64 - prev_lz - prev_tz
            xor = r.read(meaning) << prev_tz
        else:  # '11' new window
            lz = r.read(5)
            meaning = r.read(6) + 1
            tz = 64 - lz - meaning
            xor = r.read(meaning) << tz
            prev_lz, prev_tz = lz, tz
        prev ^= xor
        out[i] = prev
    return out.view(np.float64).copy()
