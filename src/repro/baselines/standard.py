"""General-purpose lossless baselines: GZip, BZip2, zstd, and TRC.

Input representation: the raw float64 value stream (8 B/value).  Timestamps
are a regular grid for every benchmark series and are reconstructible for
free by all methods (SHRINK does not store them either), so the comparison
is apples-to-apples; the CR denominator (16 B/row) is shared — see
benchmarks/datasets.py.

"TRC" (Turbo Range Coder) is represented by our adaptive range coder from
``core.entropy`` applied to the byte stream (small inputs) or zstd in a
byte-transposed layout (large inputs) — the transposition plays the role of
TRC's BWT block reordering for this data class.
"""
from __future__ import annotations

import bz2 as _bz2
import struct
import zlib as _zlib

import numpy as np

from ..core import entropy

try:
    import zstandard as _zstd
except Exception:  # pragma: no cover
    _zstd = None

__all__ = ["gzip_c", "bzip2_c", "zstd_c", "trc_c"]


def _tag(name: bytes, n: int, payload: bytes) -> bytes:
    return name + struct.pack("<Q", n) + payload


def _untag(blob: bytes) -> tuple[bytes, int, bytes]:
    return blob[:4], struct.unpack_from("<Q", blob, 4)[0], blob[12:]


class gzip_c:
    name = "GZip"

    @staticmethod
    def compress(values: np.ndarray) -> bytes:
        raw = np.asarray(values, dtype=np.float64).tobytes()
        return _tag(b"GZIP", len(values), _zlib.compress(raw, 9))

    @staticmethod
    def decompress(blob: bytes) -> np.ndarray:
        _, n, payload = _untag(blob)
        return np.frombuffer(_zlib.decompress(payload), dtype=np.float64)


class bzip2_c:
    name = "BZip2"

    @staticmethod
    def compress(values: np.ndarray) -> bytes:
        raw = np.asarray(values, dtype=np.float64).tobytes()
        return _tag(b"BZP2", len(values), _bz2.compress(raw, 9))

    @staticmethod
    def decompress(blob: bytes) -> np.ndarray:
        _, n, payload = _untag(blob)
        return np.frombuffer(_bz2.decompress(payload), dtype=np.float64)


class zstd_c:
    name = "zstd"

    @staticmethod
    def compress(values: np.ndarray) -> bytes:
        raw = np.asarray(values, dtype=np.float64).tobytes()
        comp = _zstd.ZstdCompressor(level=19).compress(raw)
        return _tag(b"ZSTD", len(values), comp)

    @staticmethod
    def decompress(blob: bytes) -> np.ndarray:
        _, n, payload = _untag(blob)
        raw = _zstd.ZstdDecompressor().decompress(payload)
        return np.frombuffer(raw, dtype=np.float64)


class trc_c:
    name = "TRC"
    _RC_LIMIT = 150_000  # bytes through the pure-python coder

    @staticmethod
    def compress(values: np.ndarray) -> bytes:
        v = np.asarray(values, dtype=np.float64)
        raw = v.tobytes()
        if len(raw) <= trc_c._RC_LIMIT:
            sym = np.frombuffer(raw, dtype=np.uint8).astype(np.int64)
            payload = b"\x00" + entropy.encode_ints(sym, backend="rc")
        else:
            # byte-plane transposition (BWT-like reordering) + entropy stage:
            # zstd when installed, the vectorized rANS engine otherwise
            planes = v.view(np.uint64)
            mat = np.stack([(planes >> np.uint64(8 * i)) & np.uint64(0xFF) for i in range(8)])
            if _zstd is not None:
                body = mat.astype(np.uint8).tobytes()
                payload = b"\x01" + _zstd.ZstdCompressor(level=19).compress(body)
            else:
                sym = mat.astype(np.int64).ravel()
                payload = b"\x02" + entropy.encode_ints(sym, backend="rans")
        return _tag(b"TRC0", len(v), payload)

    @staticmethod
    def decompress(blob: bytes) -> np.ndarray:
        _, n, payload = _untag(blob)
        mode, body = payload[0], payload[1:]
        if mode == 0:
            sym = entropy.decode_ints(body).astype(np.uint8)
            return np.frombuffer(sym.tobytes(), dtype=np.float64)
        if mode == 2:
            mat = entropy.decode_ints(body).astype(np.uint64).reshape(8, n)
        else:
            if _zstd is None:
                raise RuntimeError(
                    "this TRC blob was encoded with the zstd entropy stage; "
                    "install the 'zstandard' extra to decode it"
                )
            raw = _zstd.ZstdDecompressor().decompress(body)
            mat = np.frombuffer(raw, dtype=np.uint8).reshape(8, n).astype(np.uint64)
        planes = np.zeros(n, dtype=np.uint64)
        for i in range(8):
            planes |= mat[i] << np.uint64(8 * i)
        return planes.view(np.float64).copy()
