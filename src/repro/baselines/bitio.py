"""Bit-level IO helpers shared by the Gorilla and GD baselines."""
from __future__ import annotations

import numpy as np

__all__ = ["BitWriter", "BitReader", "pack_fixed", "unpack_fixed"]


class BitWriter:
    """MSB-first bit writer; ~O(1) amortized per write call."""

    def __init__(self) -> None:
        self.buf = bytearray()
        self.acc = 0
        self.nacc = 0

    def write(self, value: int, nbits: int) -> None:
        if nbits == 0:
            return
        self.acc = (self.acc << nbits) | (value & ((1 << nbits) - 1))
        self.nacc += nbits
        while self.nacc >= 8:
            self.nacc -= 8
            self.buf.append((self.acc >> self.nacc) & 0xFF)
        self.acc &= (1 << self.nacc) - 1

    def finish(self) -> bytes:
        if self.nacc:
            self.buf.append((self.acc << (8 - self.nacc)) & 0xFF)
            self.acc = 0
            self.nacc = 0
        return bytes(self.buf)


class BitReader:
    def __init__(self, data: bytes) -> None:
        self.data = data
        self.pos = 0  # bit position

    def read(self, nbits: int) -> int:
        if nbits == 0:
            return 0
        out = 0
        pos = self.pos
        data = self.data
        for _ in range(nbits):
            byte = data[pos >> 3] if (pos >> 3) < len(data) else 0
            bit = (byte >> (7 - (pos & 7))) & 1
            out = (out << 1) | bit
            pos += 1
        self.pos = pos
        return out


def pack_fixed(vals: np.ndarray, width: int) -> bytes:
    """Vectorized fixed-width bit packing of non-negative ints."""
    if width == 0 or vals.size == 0:
        return b""
    v = vals.astype(np.uint64)
    bitmat = ((v[:, None] >> np.arange(width - 1, -1, -1, dtype=np.uint64)) & 1).astype(np.uint8)
    return np.packbits(bitmat.reshape(-1)).tobytes()


def unpack_fixed(data: bytes, count: int, width: int) -> np.ndarray:
    if width == 0 or count == 0:
        return np.zeros(count, dtype=np.int64)
    bits = np.unpackbits(np.frombuffer(data, dtype=np.uint8))[: count * width]
    bitmat = bits.reshape(count, width).astype(np.uint64)
    weights = np.left_shift(np.uint64(1), np.arange(width - 1, -1, -1, dtype=np.uint64))
    return (bitmat * weights).sum(axis=1).astype(np.int64)
