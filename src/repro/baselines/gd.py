"""Generalized Deduplication baseline (Vestergaard et al., INFOCOM 2020;
GreedyGD, Hurst et al., 2024) — lossless, random-access-friendly.

Values with d decimals are lifted to integers at scale 10^d; each integer is
split into a high-bit *base* and a low-bit *deviation*.  Bases deduplicate
through a dictionary; the stream stores per-value (base id, deviation).  The
deviation width is chosen per dataset by exhaustive cost scan — the greedy
bit-selection of GreedyGD specialised to contiguous low-bit deviations.
"""
from __future__ import annotations

import math
import struct

import numpy as np

from .bitio import pack_fixed, unpack_fixed
from ..core.serialize import read_varint, write_varint

__all__ = ["compress", "decompress", "choose_deviation_bits"]

_MAGIC = b"GDDP"


def _to_ints(values: np.ndarray, decimals: int) -> np.ndarray:
    return np.round(np.asarray(values, dtype=np.float64) * 10.0**decimals).astype(np.int64)


def choose_deviation_bits(ints: np.ndarray) -> tuple[int, int]:
    """Scan deviation widths; return (bits, estimated_total_bytes)."""
    off = ints - ints.min()
    max_bits = max(1, int(off.max()).bit_length()) if off.size else 1
    best = (0, math.inf)
    for b in range(0, max_bits + 1):
        bases = off >> b
        u = int(np.unique(bases).size)
        id_bits = max(1, (u - 1).bit_length()) if u > 1 else 1
        cost = u * 8 + (off.size * (id_bits + b)) / 8
        if cost < best[1]:
            best = (b, cost)
    return best[0], int(best[1])


def compress(values: np.ndarray, decimals: int) -> bytes:
    ints = _to_ints(values, decimals)
    lo = int(ints.min()) if ints.size else 0
    off = (ints - lo).astype(np.uint64)
    b, _ = choose_deviation_bits(ints)
    bases = (off >> np.uint64(b)).astype(np.int64)
    devs = (off & np.uint64((1 << b) - 1)).astype(np.int64) if b else np.zeros_like(bases)
    uniq, ids = np.unique(bases, return_inverse=True)
    id_bits = max(1, (len(uniq) - 1).bit_length()) if len(uniq) > 1 else 1

    buf = bytearray()
    buf += _MAGIC
    write_varint(buf, len(ints))
    buf += struct.pack("<qBB", lo, decimals, b)
    write_varint(buf, len(uniq))
    prev = 0
    for u in uniq.tolist():  # sorted ascending -> delta varint
        write_varint(buf, u - prev)
        prev = u
    ids_packed = pack_fixed(ids.astype(np.uint64), id_bits)
    devs_packed = pack_fixed(devs.astype(np.uint64), b)
    buf.append(id_bits)
    write_varint(buf, len(ids_packed))
    buf += ids_packed
    write_varint(buf, len(devs_packed))
    buf += devs_packed
    return bytes(buf)


def decompress(blob: bytes) -> np.ndarray:
    if blob[:4] != _MAGIC:
        raise ValueError("bad GD magic")
    pos = 4
    n, pos = read_varint(blob, pos)
    lo, decimals, b = struct.unpack_from("<qBB", blob, pos)
    pos += 10
    u, pos = read_varint(blob, pos)
    uniq = np.empty(u, dtype=np.int64)
    prev = 0
    for i in range(u):
        d, pos = read_varint(blob, pos)
        prev += d
        uniq[i] = prev
    id_bits = blob[pos]
    pos += 1
    ids_len, pos = read_varint(blob, pos)
    ids = unpack_fixed(blob[pos : pos + ids_len], n, id_bits)
    pos += ids_len
    devs_len, pos = read_varint(blob, pos)
    devs = unpack_fixed(blob[pos : pos + devs_len], n, b)
    ints = (uniq[ids] << b) + devs + lo
    return ints.astype(np.float64) / 10.0**decimals
