"""APCA baseline (Keogh et al., SIGMOD 2001): adaptive piecewise-constant
approximation with an L-infinity guarantee.

Greedy max-length segments: extend while (running max - running min) <= 2*eps;
the segment value is the mid-range.  Serialization: varint length + f32 value
per segment.
"""
from __future__ import annotations

import struct

import numpy as np

from ..core.serialize import read_varint, write_varint

__all__ = ["compress", "decompress"]

_MAGIC = b"APCA"


def _segments(values: np.ndarray, eps: float) -> list[tuple[int, float]]:
    n = len(values)
    out: list[tuple[int, float]] = []
    i = 0
    while i < n:
        vmin = vmax = float(values[i])
        j = i + 1
        chunk = 256
        closed = False
        while j < n:
            end = min(n, j + chunk)
            seg = values[j:end]
            run_max = np.maximum(np.maximum.accumulate(seg), vmax)
            run_min = np.minimum(np.minimum.accumulate(seg), vmin)
            viol = (run_max - run_min) > 2 * eps
            if viol.any():
                idx = int(np.argmax(viol))
                if idx > 0:
                    vmax = float(run_max[idx - 1])
                    vmin = float(run_min[idx - 1])
                k = j + idx
                out.append((k - i, 0.5 * (vmin + vmax)))
                i = k
                closed = True
                break
            vmax = float(run_max[-1])
            vmin = float(run_min[-1])
            j = end
            chunk = min(chunk * 2, 65536)
        if not closed:
            out.append((n - i, 0.5 * (vmin + vmax)))
            i = n
    return out


def compress(values: np.ndarray, eps: float) -> bytes:
    values = np.asarray(values, dtype=np.float64)
    segs = _segments(values, eps)
    buf = bytearray()
    buf += _MAGIC
    write_varint(buf, len(values))
    write_varint(buf, len(segs))
    for ln, val in segs:
        write_varint(buf, ln)
        buf += struct.pack("<f", val)
    return bytes(buf)


def decompress(blob: bytes) -> np.ndarray:
    if blob[:4] != _MAGIC:
        raise ValueError("bad APCA magic")
    pos = 4
    n, pos = read_varint(blob, pos)
    k, pos = read_varint(blob, pos)
    out = np.empty(n, dtype=np.float64)
    i = 0
    for _ in range(k):
        ln, pos = read_varint(blob, pos)
        (val,) = struct.unpack_from("<f", blob, pos)
        pos += 4
        out[i : i + ln] = val
        i += ln
    return out
