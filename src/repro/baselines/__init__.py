"""Every comparator in the paper's evaluation, reimplemented.

Lossy (error-bounded):  Sim-Piece, APCA, LFZip, HIRE
Lossless:               GZip, BZip2, zstd, TRC, Gorilla, GD

Uniform registry interface for the benchmark harness:

    LOSSY[name](values, eps)          -> bytes
    LOSSY_D[name](blob)               -> values
    LOSSLESS[name](values, decimals)  -> bytes
    LOSSLESS_D[name](blob)            -> values
"""
from __future__ import annotations

import numpy as np

from . import apca, gd, gorilla, hire, lfzip, simpiece, standard

__all__ = ["LOSSY", "LOSSY_D", "LOSSLESS", "LOSSLESS_D"]

LOSSY = {
    "SimPiece": lambda v, eps: simpiece.compress(v, eps),
    "APCA": lambda v, eps: apca.compress(v, eps),
    "LFZip": lambda v, eps: lfzip.compress(v, eps),
    "HIRE": lambda v, eps: hire.compress(v, eps),
}

LOSSY_D = {
    "SimPiece": simpiece.decompress,
    "APCA": apca.decompress,
    "LFZip": lfzip.decompress,
    "HIRE": hire.decompress,
}

LOSSLESS = {
    "GZip": lambda v, d: standard.gzip_c.compress(v),
    "BZip2": lambda v, d: standard.bzip2_c.compress(v),
    "TRC": lambda v, d: standard.trc_c.compress(v),
    "Gorilla": lambda v, d: gorilla.compress(v),
    "GD": lambda v, d: gd.compress(v, d),
}

LOSSLESS_D = {
    "GZip": standard.gzip_c.decompress,
    "BZip2": standard.bzip2_c.decompress,
    "TRC": standard.trc_c.decompress,
    "Gorilla": gorilla.decompress,
    "GD": gd.decompress,
}

# zstd rides only when the optional dependency is installed; TRC degrades to
# its rANS entropy stage on its own, so it stays unconditional.
if standard._zstd is not None:
    LOSSLESS["zstd"] = lambda v, d: standard.zstd_c.compress(v)
    LOSSLESS_D["zstd"] = standard.zstd_c.decompress
