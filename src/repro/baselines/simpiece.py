"""Sim-Piece reimplementation (Kitsios et al., PVLDB 16(8), 2023).

PLA with a *fixed* error threshold: shrinking cones anchored at origins
quantized onto the eps grid, grouped by origin, spans merged greedily after
sorting by the lower slope.  This is exactly SHRINK minus (a) the adaptive
threshold and (b) residuals — which makes it the natural ablation baseline.

Serialization mirrors the published format: per sub-base a zigzag-varint
origin-grid delta, a float32 slope, and varint timestamp deltas; segment
lengths are implicit in the global ordering of start indices.
"""
from __future__ import annotations

import math
import struct

import numpy as np

from ..core.serialize import read_varint, write_varint

__all__ = ["compress", "decompress", "extract_segments"]

_MAGIC = b"SIMP"
_INF = math.inf


def extract_segments(values: np.ndarray, eps: float) -> list[tuple[float, float, float, int, int]]:
    """Fixed-eps shrinking-cone scan (chunked-vectorized).

    Returns [(b, psi_lo, psi_hi, t0, length)] with b = floor(v0/eps)*eps.
    """
    values = np.asarray(values, dtype=np.float64)
    n = len(values)
    segs: list[tuple[float, float, float, int, int]] = []
    i = 0
    while i < n:
        b = math.floor(values[i] / eps) * eps
        psi_lo, psi_hi = -_INF, _INF
        j = i + 1
        chunk = 256
        closed = False
        while j < n:
            end = min(n, j + chunk)
            dt = np.arange(j - i, end - i, dtype=np.float64)
            seg_vals = values[j:end]
            hi = (seg_vals + (eps - b)) / dt
            lo = (seg_vals - (eps + b)) / dt
            run_hi = np.minimum(np.minimum.accumulate(hi), psi_hi)
            run_lo = np.maximum(np.maximum.accumulate(lo), psi_lo)
            viol = run_lo > run_hi
            if viol.any():
                idx = int(np.argmax(viol))
                if idx > 0:
                    psi_hi = float(run_hi[idx - 1])
                    psi_lo = float(run_lo[idx - 1])
                k = j + idx
                segs.append((b, psi_lo, psi_hi, i, k - i))
                i = k
                closed = True
                break
            psi_hi = float(run_hi[-1])
            psi_lo = float(run_lo[-1])
            j = end
            chunk = min(chunk * 2, 65536)
        if not closed:
            segs.append((b, psi_lo, psi_hi, i, n - i))
            i = n
    return segs


def compress(values: np.ndarray, eps: float) -> bytes:
    values = np.asarray(values, dtype=np.float64)
    n = len(values)
    segs = extract_segments(values, eps)

    # group by origin grid index, merge sorted spans greedily
    groups: dict[int, list[tuple[float, float, float, int, int]]] = {}
    for seg in segs:
        idx = int(round(seg[0] / eps))
        groups.setdefault(idx, []).append(seg)

    subbases: list[tuple[int, float, list[int]]] = []  # (origin idx, slope, t0s)
    for idx in sorted(groups):
        group = sorted(groups[idx], key=lambda s: (s[1], s[2]))
        cur_lo, cur_hi = -_INF, _INF
        cur_t0s: list[int] = []
        for b, lo, hi, t0, ln in group:
            new_lo, new_hi = max(cur_lo, lo), min(cur_hi, hi)
            if not cur_t0s or new_lo <= new_hi:
                cur_lo, cur_hi = new_lo, new_hi
                cur_t0s.append(t0)
            else:
                subbases.append((idx, _mid_slope(cur_lo, cur_hi), sorted(cur_t0s)))
                cur_lo, cur_hi, cur_t0s = lo, hi, [t0]
        if cur_t0s:
            subbases.append((idx, _mid_slope(cur_lo, cur_hi), sorted(cur_t0s)))

    buf = bytearray()
    buf += _MAGIC
    write_varint(buf, n)
    buf += struct.pack("<d", eps)
    write_varint(buf, len(subbases))
    prev_idx = 0
    for idx, slope, t0s in subbases:
        z = idx - prev_idx
        write_varint(buf, (z << 1) ^ (z >> 63) if z < 0 else (z << 1))
        prev_idx = idx
        buf += struct.pack("<f", slope)
        write_varint(buf, len(t0s))
        prev_t = 0
        for t0 in t0s:
            write_varint(buf, t0 - prev_t)
            prev_t = t0
    return bytes(buf)


def _mid_slope(lo: float, hi: float) -> float:
    if math.isinf(lo) and math.isinf(hi):
        return 0.0
    if math.isinf(lo):
        return min(hi, 0.0)
    if math.isinf(hi):
        return max(lo, 0.0)
    return 0.5 * (lo + hi)


def decompress(blob: bytes) -> np.ndarray:
    if blob[:4] != _MAGIC:
        raise ValueError("bad Sim-Piece magic")
    pos = 4
    n, pos = read_varint(blob, pos)
    (eps,) = struct.unpack_from("<d", blob, pos)
    pos += 8
    k, pos = read_varint(blob, pos)
    pieces: list[tuple[int, float, float]] = []  # (t0, b, slope)
    prev_idx = 0
    for _ in range(k):
        z, pos = read_varint(blob, pos)
        d = (z >> 1) ^ -(z & 1)
        idx = prev_idx + d
        prev_idx = idx
        (slope,) = struct.unpack_from("<f", blob, pos)
        pos += 4
        m, pos = read_varint(blob, pos)
        prev_t = 0
        for _ in range(m):
            dt, pos = read_varint(blob, pos)
            t0 = prev_t + dt
            prev_t = t0
            pieces.append((t0, idx * eps, float(slope)))
    pieces.sort()
    out = np.empty(n, dtype=np.float64)
    for j, (t0, b, slope) in enumerate(pieces):
        end = pieces[j + 1][0] if j + 1 < len(pieces) else n
        t = np.arange(end - t0, dtype=np.float64)
        out[t0:end] = b + slope * t
    return out
