"""Deterministic, seeded fault injection for SHRK/SHRKS blobs and decoders.

Every injector is a pure function ``bytes -> bytes`` (plus a :class:`Fault`
record saying exactly what was done), so a test can hold the pristine blob
as its oracle and assert the reader's reaction to the mutant:

* :func:`flip_byte`      — flip one bit anywhere in the blob;
* :func:`truncate`       — cut the blob at any boundary;
* :func:`smash_frame_crc`— rewrite ONE frame's stored CRC in a ``SHRKS``
  directory (footer CRC re-sealed, so the corruption is only detectable
  lazily at frame-payload read, per the wire contract);
* :func:`drop_frame`     — remove one frame from a ``SHRKS`` container
  (rebuilt through :class:`FramedWriter`, so the result is a *valid*
  container with a coverage gap — the reader must detect the gap, not a
  broken checksum);
* :class:`FlakyCallable` — wrap any decoder callable in seeded transient
  failures and injected latency (for retry/circuit-breaker tests);
* :func:`kill_shard`     — take one shard of a serving fleet out (container
  lost outright, or corrupted by any single-blob fault above); the fleet
  must degrade SCOPED: healthy shards stay byte-exact, the dead shard's
  queries come back as typed errors or flagged in-bound answers, never a
  silent wrong byte (tests/test_chaos.py::TestShardKill).

:class:`ChaosInjector` draws faults from a seeded RNG so a whole chaos
campaign replays byte-identically from its seed (the CI ``chaos`` job and
``launch/serve.py --mode chaos`` both run derandomized).
"""
from __future__ import annotations

import dataclasses
import random
import struct
import zlib
from typing import Callable, Optional

from ..core.errors import TransientError
from ..core.serialize import (
    FramedWriter,
    KBSnapshotRef,
    frame_payload,
    parse_framed_container,
    read_snapshot_ref,
    read_varint,
)
from ..core.types import FrameMeta

__all__ = [
    "Fault",
    "FlakyCallable",
    "ChaosInjector",
    "flip_byte",
    "truncate",
    "smash_frame_crc",
    "drop_frame",
    "stale_snapshot_ref",
    "kill_shard",
    "list_frames",
]

_TAIL_LEN = 16  # u64 footer offset + u32 footer crc + 4-byte end magic


@dataclasses.dataclass(frozen=True)
class Fault:
    """What a single injection did — enough to reproduce it by hand."""

    kind: str  # 'flip' | 'truncate' | 'crc_smash' | 'frame_drop' | 'flaky'
    #     | 'shard_kill' | 'stale_ref'
    offset: Optional[int] = None  # byte offset (flip), cut length (truncate)
    bit: Optional[int] = None
    frame_index: Optional[int] = None
    shard: Optional[int] = None  # which fleet shard a shard_kill hit
    detail: str = ""


# --------------------------------------------------------------------- #
# blob mutators
# --------------------------------------------------------------------- #
def flip_byte(blob: bytes, offset: int, bit: int = 0) -> tuple[bytes, Fault]:
    """Flip one bit of ``blob[offset]``."""
    if not 0 <= offset < len(blob):
        raise IndexError(f"offset {offset} outside blob of {len(blob)} bytes")
    b = bytearray(blob)
    b[offset] ^= 1 << (bit & 7)
    return bytes(b), Fault(
        kind="flip", offset=offset, bit=bit & 7,
        detail=f"flipped bit {bit & 7} of byte {offset}/{len(blob)}",
    )


def truncate(blob: bytes, keep: int) -> tuple[bytes, Fault]:
    """Cut the blob to its first ``keep`` bytes."""
    keep = max(0, min(int(keep), len(blob)))
    return bytes(blob[:keep]), Fault(
        kind="truncate", offset=keep, detail=f"kept {keep}/{len(blob)} bytes"
    )


def list_frames(blob: bytes) -> list[FrameMeta]:
    """The frame directory of a ``SHRKS`` container (no payload checks)."""
    return parse_framed_container(blob)[0]


def _footer_bounds(blob: bytes) -> tuple[int, int]:
    (footer_offset,) = struct.unpack_from("<Q", blob, len(blob) - _TAIL_LEN)
    return footer_offset, len(blob) - _TAIL_LEN


def smash_frame_crc(blob: bytes, frame_index: int) -> tuple[bytes, Fault]:
    """Invert the stored CRC of one frame in a ``SHRKS`` directory and
    re-seal the footer CRC.  The container still parses — the corruption
    surfaces only when that frame's payload is actually read (the SHRKS
    lazy per-frame CRC contract), which is exactly the case the serving
    layer's scoped degradation must handle."""
    metas = list_frames(blob)  # validates the container first
    if not 0 <= frame_index < len(metas):
        raise IndexError(f"frame {frame_index} outside directory of {len(metas)}")
    fo, fe = _footer_bounds(blob)
    footer = blob[fo:fe]
    pos = 0
    _, pos = read_varint(footer, pos)
    crc_pos = None
    for i in range(len(metas)):
        for _ in range(6):  # sid, t_lo, n, epoch, offset, length
            _, pos = read_varint(footer, pos)
        if i == frame_index:
            crc_pos = fo + pos
            break
        pos += 4
    b = bytearray(blob)
    for j in range(4):
        b[crc_pos + j] ^= 0xFF
    new_footer_crc = zlib.crc32(bytes(b[fo:fe])) & 0xFFFFFFFF
    struct.pack_into("<QI", b, len(b) - _TAIL_LEN, fo, new_footer_crc)
    return bytes(b), Fault(
        kind="crc_smash", frame_index=frame_index, offset=crc_pos,
        detail=f"inverted stored CRC of frame {frame_index} (footer re-sealed)",
    )


def drop_frame(blob: bytes, frame_index: int) -> tuple[bytes, Fault]:
    """Rebuild a ``SHRKS`` container without one frame.  The result is a
    fully valid container whose directory has a coverage hole — readers
    must fail (or degrade) on the *gap*, not on a checksum."""
    metas, kb_bytes = parse_framed_container(blob)
    if not 0 <= frame_index < len(metas):
        raise IndexError(f"frame {frame_index} outside directory of {len(metas)}")
    w = FramedWriter()
    for i, m in enumerate(metas):
        if i == frame_index:
            continue
        w.add_frame(
            m.series_id, m.t_lo, m.t_hi, m.kb_epoch,
            frame_payload(blob, m, verify_crc=False),
        )
    dropped = metas[frame_index]
    # a ref-mode container stays ref-mode: carry the kb_snapshot_ref through
    return w.finish(kb_bytes, snapshot_ref=read_snapshot_ref(blob)), Fault(
        kind="frame_drop", frame_index=frame_index,
        detail=(
            f"dropped frame {frame_index} (series {dropped.series_id}, "
            f"samples [{dropped.t_lo}, {dropped.t_hi}))"
        ),
    )


def stale_snapshot_ref(blob: bytes) -> tuple[bytes, Fault]:
    """Rewrite a container's ``kb_snapshot_ref`` so it no longer resolves:
    the version is bumped past any real snapshot and the semantic id is
    inverted.  The container itself stays fully valid (frames, CRCs,
    inline KB all intact) — exactly the operational fault of a store
    losing/compacting away a snapshot that containers still reference.
    Readers must fall back to the inline footer KB when present, or raise
    a typed :class:`StaleSnapshotError` — never bind to a wrong snapshot."""
    metas, kb_bytes = parse_framed_container(blob)
    ref = read_snapshot_ref(blob)
    if ref is None:
        raise ValueError("container carries no kb_snapshot_ref to stale")
    w = FramedWriter()
    for m in metas:
        w.add_frame(
            m.series_id, m.t_lo, m.t_hi, m.kb_epoch,
            frame_payload(blob, m, verify_crc=False),
        )
    bad = KBSnapshotRef(
        version=ref.version + 1_000_000,
        entries=ref.entries,
        sem_id=ref.sem_id ^ 0xFFFFFFFF,
        remap=ref.remap,
        refs=ref.refs,
    )
    return w.finish(kb_bytes, snapshot_ref=bad), Fault(
        kind="stale_ref",
        detail=(
            f"kb_snapshot_ref v{ref.version} -> v{bad.version}, "
            "sem_id inverted (snapshot can no longer resolve)"
        ),
    )


def kill_shard(
    fleet, shard: int, mode: str = "lost", injector: "ChaosInjector | None" = None
) -> Fault:
    """Take one shard of a serving fleet out of action.

    ``fleet`` is duck-typed (anything with ``seal()`` and
    ``inject_shard_blob(shard, blob)`` — in practice
    :class:`repro.serving.ShrinkFleet`), keeping this module free of a
    serving dependency.  Modes:

    * ``"lost"``    — the shard's container is gone (replaced by empty
      bytes): every query to it must come back a typed error;
    * ``"corrupt"`` — one seeded single-blob fault (flip / truncate /
      crc_smash / frame_drop) is applied to the shard's container: queries
      must come back typed errors or flagged degraded answers with valid
      bounds.

    Either way the blast radius is ONE shard — the differential tests
    assert every other shard still serves byte-exact.
    """
    blobs = fleet.seal()
    if not 0 <= shard < len(blobs):
        raise IndexError(f"shard {shard} outside fleet of {len(blobs)}")
    if mode == "lost":
        mutant = b""
        fault = Fault(
            kind="shard_kill", shard=shard,
            detail=f"shard {shard}: container lost (replaced by empty blob)",
        )
    elif mode == "corrupt":
        inj = injector if injector is not None else ChaosInjector(0)
        mutant, inner = inj.corrupt(blobs[shard])
        fault = Fault(
            kind="shard_kill", shard=shard, offset=inner.offset,
            bit=inner.bit, frame_index=inner.frame_index,
            detail=f"shard {shard}: {inner.detail}",
        )
    else:
        raise ValueError(f"unknown kill mode {mode!r}: expected 'lost'|'corrupt'")
    fleet.inject_shard_blob(shard, mutant)
    return fault


# --------------------------------------------------------------------- #
# decoder wrappers
# --------------------------------------------------------------------- #
class FlakyCallable:
    """Wrap a callable in seeded transient failures and injected latency.

    Each call draws from its own ``random.Random(seed)`` stream: with
    probability ``fail_rate`` it raises :class:`TransientError` (the ONLY
    error class the gateway retries) instead of calling through; a
    successful call first invokes ``sleep(slow_s)`` when configured (pass
    a fake sleep to keep tests instant).  ``calls``/``failures`` count
    what happened.
    """

    def __init__(
        self,
        fn: Callable,
        fail_rate: float = 0.0,
        seed: int = 0,
        slow_s: float = 0.0,
        sleep: Callable[[float], None] | None = None,
    ):
        if not 0.0 <= fail_rate <= 1.0:
            raise ValueError(f"fail_rate must be in [0, 1], got {fail_rate}")
        self.fn = fn
        self.fail_rate = fail_rate
        self.slow_s = slow_s
        self.sleep = sleep
        self.rng = random.Random(seed)
        self.calls = 0
        self.failures = 0

    def __call__(self, *args, **kwargs):
        self.calls += 1
        if self.fail_rate and self.rng.random() < self.fail_rate:
            self.failures += 1
            raise TransientError(
                f"injected transient fault (call {self.calls})"
            )
        if self.slow_s and self.sleep is not None:
            self.sleep(self.slow_s)
        return self.fn(*args, **kwargs)


# --------------------------------------------------------------------- #
# seeded campaign driver
# --------------------------------------------------------------------- #
class ChaosInjector:
    """Seeded source of single faults: same seed, same fault sequence.

    ``corrupt(blob)`` applies ONE randomly chosen fault and returns
    ``(mutant, fault)``; ``kinds`` restricts the menu.  Structural faults
    (CRC smash / frame drop) silently fall back to a byte flip when the
    blob is not a parseable ``SHRKS`` container.
    """

    KINDS = ("flip", "truncate", "crc_smash", "frame_drop")

    def __init__(self, seed: int = 0):
        self.rng = random.Random(seed)

    def corrupt(
        self, blob: bytes, kinds: tuple[str, ...] | None = None
    ) -> tuple[bytes, Fault]:
        kinds = tuple(kinds) if kinds else self.KINDS
        kind = self.rng.choice(kinds)
        if kind == "flip":
            return flip_byte(blob, self.rng.randrange(len(blob)), self.rng.randrange(8))
        if kind == "truncate":
            return truncate(blob, self.rng.randrange(len(blob)))
        # structural SHRKS faults need a parseable container
        try:
            n = len(list_frames(blob))
        except ValueError:
            n = 0
        if n == 0:
            return flip_byte(blob, self.rng.randrange(len(blob)), self.rng.randrange(8))
        idx = self.rng.randrange(n)
        if kind == "crc_smash":
            return smash_frame_crc(blob, idx)
        if kind == "frame_drop":
            return drop_frame(blob, idx)
        raise ValueError(f"unknown fault kind {kind!r}")

    def kill_shard(self, fleet, shard: int | None = None, mode: str | None = None) -> Fault:
        """Kill a (randomly drawn, unless pinned) shard of ``fleet`` in a
        (randomly drawn, unless pinned) mode, seeded from this stream."""
        n = len(fleet.seal())
        if shard is None:
            shard = self.rng.randrange(n)
        if mode is None:
            mode = self.rng.choice(("lost", "corrupt"))
        return kill_shard(fleet, shard, mode=mode, injector=self)

    def flaky(
        self,
        fn: Callable,
        fail_rate: float,
        slow_s: float = 0.0,
        sleep: Callable[[float], None] | None = None,
    ) -> FlakyCallable:
        """A :class:`FlakyCallable` seeded from this injector's stream."""
        return FlakyCallable(
            fn, fail_rate=fail_rate, seed=self.rng.randrange(2**31),
            slow_s=slow_s, sleep=sleep,
        )
