"""Deterministic fault-injection tooling for the SHRINK stack.

``repro.testing.chaos`` wraps any SHRK/SHRKS blob or decoder callable in
seeded, reproducible faults — the harness behind ``tests/test_chaos*.py``
and ``launch/serve.py --mode chaos``.
"""
from .chaos import (  # noqa: F401
    ChaosInjector,
    Fault,
    FlakyCallable,
    drop_frame,
    flip_byte,
    kill_shard,
    list_frames,
    smash_frame_crc,
    stale_snapshot_ref,
    truncate,
)
