"""Deterministic sharded data pipeline.

* ``TokenPipeline`` — synthetic LM token streams keyed by (step, shard):
  a pure function of the step index, which is what makes deterministic
  resume and elastic restarts possible (fault_tolerance.py).  Tokens follow
  a Zipfian unigram draw with short-range repetition so the loss actually
  has learnable structure for the end-to-end example.
* ``ShardStore`` — SHRINK-compressed series shards on disk: the paper's IoT
  ingestion path.  Series are chunked, each chunk compressed once (base +
  requested resolutions), random-access by (name, chunk) without touching
  other chunks.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Optional

import numpy as np

from ..core import entropy
from ..core.shrink import ShrinkCodec, cs_from_bytes, cs_to_bytes

__all__ = ["TokenPipeline", "ShardStore"]


def _store_backend() -> str:
    """zstd when the optional extra is installed (the historical choice for
    bulk stores), the vectorized rANS engine otherwise.  NOT 'best': that
    would pull the O(n) pure-python range coder into every encode just to
    compare sizes."""
    return "zstd" if "zstd" in entropy.available_backends() else "rans"


@dataclasses.dataclass
class TokenPipeline:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    n_shards: int = 16  # over-decomposition factor for straggler re-dispatch

    def _shard_tokens(self, step: int, shard: int, rows: int) -> np.ndarray:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + shard
        )
        # Zipf-ish unigram + repetition: learnable bigram structure
        base = rng.zipf(1.3, size=(rows, self.seq_len)).astype(np.int64)
        tokens = np.clip(base, 1, self.vocab_size - 1)
        rep = rng.random((rows, self.seq_len)) < 0.3
        tokens[:, 1:] = np.where(rep[:, 1:], tokens[:, :-1], tokens[:, 1:])
        return tokens.astype(np.int32)

    def batch_at(self, step: int) -> dict:
        """Global batch for `step` — pure function of step (resume-safe)."""
        rows_per_shard = max(1, self.batch // self.n_shards)
        shards = [
            self._shard_tokens(step, s, rows_per_shard)
            for s in range(self.n_shards)
        ]
        tokens = np.concatenate(shards, axis=0)[: self.batch]
        if tokens.shape[0] < self.batch:  # n_shards > batch
            reps = -(-self.batch // tokens.shape[0])
            tokens = np.tile(tokens, (reps, 1))[: self.batch]
        labels = np.roll(tokens, -1, axis=1)
        return {"tokens": tokens, "labels": labels}


class ShardStore:
    """SHRINK-compressed chunked series store with random access.

    put(name, values, eps_list, decimals) chunks the series and compresses
    each chunk independently; get(name, eps, chunk) decompresses one chunk
    (edge analytics never touch the rest — the GD/random-access story with
    SHRINK's multiresolution on top)."""

    def __init__(self, directory: str | Path, chunk: int = 65_536):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.chunk = chunk

    def put(
        self,
        name: str,
        values: np.ndarray,
        eps_list: list[float],
        decimals: Optional[int] = None,
        frac: float = 0.05,
    ) -> dict:
        values = np.asarray(values, dtype=np.float64)
        d = self.dir / name
        d.mkdir(parents=True, exist_ok=True)
        n_chunks = -(-len(values) // self.chunk)
        total = 0
        for c in range(n_chunks):
            seg = values[c * self.chunk : (c + 1) * self.chunk]
            codec = ShrinkCodec.from_fraction(seg, frac=frac, backend=_store_backend())
            cs = codec.compress(seg, eps_targets=eps_list, decimals=decimals)
            blob = cs_to_bytes(cs)
            (d / f"chunk_{c}.shrk").write_bytes(blob)
            total += len(blob)
        meta = {
            "n": int(len(values)),
            "chunk": self.chunk,
            "n_chunks": n_chunks,
            "eps_list": eps_list,
            "decimals": decimals,
            "bytes": total,
        }
        (d / "meta.json").write_text(json.dumps(meta))
        return meta

    def meta(self, name: str) -> dict:
        return json.loads((self.dir / name / "meta.json").read_text())

    def get_chunk(self, name: str, eps: float, chunk_idx: int) -> np.ndarray:
        blob = (self.dir / name / f"chunk_{chunk_idx}.shrk").read_bytes()
        cs = cs_from_bytes(blob)
        codec = ShrinkCodec.from_fraction(np.zeros(2), frac=0.05)
        return codec.decompress_at(cs, eps)

    def get(self, name: str, eps: float) -> np.ndarray:
        m = self.meta(name)
        parts = [self.get_chunk(name, eps, c) for c in range(m["n_chunks"])]
        return np.concatenate(parts)[: m["n"]]
