"""Data pipeline: synthetic series + SHRINK shard store + token streams."""
from .synthetic import DATASETS, DatasetSpec, household_power, load  # noqa: F401
from .pipeline import ShardStore, TokenPipeline  # noqa: F401
