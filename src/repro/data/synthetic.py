"""Deterministic synthetic analogues of the paper's nine evaluation series.

The container is offline, so the UCR / NEON / ECG files cannot be fetched.
Each generator below is matched to Table II's published statistics (rows,
value range, decimal places) and to the qualitative structure the paper
describes (ECG periodicity, WindSpeed/WindDirection sharp discontinuities on
a 2-decimal grid, Pressure smooth drift with recurring patterns, Wafer step
plateaus, Lightning bursts, ...).  All generators are seeded and pure — the
benchmark tables in EXPERIMENTS.md are exactly reproducible.

``load(name, n=None)`` returns float64 values rounded to the dataset's
decimal count; ``n=None`` uses the full Table II row count (scaled down by
benchmarks via the ``n`` argument where runtime matters — noted per table).
"""
from __future__ import annotations

import dataclasses
import zlib as _zlib
from typing import Callable

import numpy as np

__all__ = ["DatasetSpec", "DATASETS", "load", "household_power", "ragged_sensor_traffic"]


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    decimals: int
    vmin: float
    vmax: float
    rows: int
    gen: Callable[[np.random.Generator, int], np.ndarray]


def _scale_to(v: np.ndarray, vmin: float, vmax: float) -> np.ndarray:
    lo, hi = float(v.min()), float(v.max())
    if hi <= lo:
        return np.full_like(v, (vmin + vmax) / 2)
    return vmin + (v - lo) * (vmax - vmin) / (hi - lo)


def _face_four(rng: np.random.Generator, n: int) -> np.ndarray:
    """UCR FaceFour: concatenated facial outlines — smooth quasi-periodic arcs."""
    t = np.arange(n)
    period = 350
    phase = 2 * np.pi * (t % period) / period
    shape_id = (t // period) % 4
    v = (
        np.sin(phase)
        + 0.45 * np.sin(2 * phase + shape_id * 0.7)
        + 0.2 * np.sin(5 * phase + shape_id)
        + 0.02 * rng.standard_normal(n)
    )
    return v


def _mote_strain(rng: np.random.Generator, n: int) -> np.ndarray:
    """Sensor strain: noisy oscillation with drifting mean and bursts."""
    t = np.arange(n)
    drift = np.cumsum(rng.standard_normal(n)) * 0.003
    osc = np.sin(2 * np.pi * t / 84.0) * (1.0 + 0.5 * np.sin(2 * np.pi * t / 5000.0))
    bursts = (rng.random(n) < 0.001) * rng.standard_normal(n) * 4.0
    return osc + drift + bursts + 0.08 * rng.standard_normal(n)


def _lightning(rng: np.random.Generator, n: int) -> np.ndarray:
    """Mostly-flat signal with sharp exponential-decay strikes."""
    v = 0.03 * rng.standard_normal(n)
    n_strikes = max(4, n // 800)
    starts = rng.integers(0, n - 60, size=n_strikes)
    for s in starts:
        amp = rng.uniform(3.0, 20.0)
        decay = np.exp(-np.arange(50) / rng.uniform(3.0, 12.0)) * amp
        v[s : s + 50] += decay[: max(0, min(50, n - s))]
    return v


def _ecg(rng: np.random.Generator, n: int) -> np.ndarray:
    """Periodic PQRST-like waveform with beat-to-beat variability."""
    out = np.empty(n)
    i = 0
    while i < n:
        beat_len = int(rng.normal(140, 6))
        beat_len = max(100, min(180, beat_len))
        t = np.linspace(0, 1, beat_len)
        p = 0.18 * np.exp(-((t - 0.18) ** 2) / 0.0012)
        q = -0.28 * np.exp(-((t - 0.40) ** 2) / 0.0002)
        r = 1.0 * np.exp(-((t - 0.45) ** 2) / 0.0003) * rng.uniform(0.9, 1.1)
        s = -0.32 * np.exp(-((t - 0.50) ** 2) / 0.0002)
        tw = 0.30 * np.exp(-((t - 0.72) ** 2) / 0.0035)
        beat = p + q + r + s + tw
        m = min(beat_len, n - i)
        out[i : i + m] = beat[:m]
        i += m
    return out + 0.01 * rng.standard_normal(n)


def _cricket(rng: np.random.Generator, n: int) -> np.ndarray:
    """Wrist accelerometer: smooth segments + vigorous motion bursts."""
    t = np.arange(n)
    base = np.sin(2 * np.pi * t / 300.0) * 0.8
    k = max(1, n // 1200)
    env = np.zeros(n)
    starts = rng.integers(0, max(1, n - 400), size=k)
    for s in starts:
        ln = int(rng.uniform(150, 400))
        env[s : s + ln] += rng.uniform(1.5, 5.0)
    motion = env * np.sin(2 * np.pi * t / rng.uniform(20, 40)) * 0.8
    return base + motion + 0.05 * rng.standard_normal(n)


def _wind_direction(rng: np.random.Generator, n: int) -> np.ndarray:
    """Degrees 0..360, 2 decimals: slow meander + wrap-around jumps + plateaus."""
    steps = rng.standard_normal(n) * 0.8
    calm = rng.random(n) < 0.15
    steps[calm] = 0.0  # plateaus (instrument repeats identical readings)
    v = np.cumsum(steps) + 180.0
    v = np.mod(v, 360.0)
    return v


def _wafer(rng: np.random.Generator, n: int) -> np.ndarray:
    """Process-control traces: long flat plateaus + rapid transitions."""
    out = np.empty(n)
    levels = np.array([-0.9, 0.0, 1.0, 2.2, 4.0, 7.5, 10.5])
    i = 0
    cur = 0.0
    while i < n:
        ln = int(rng.uniform(40, 400))
        tgt = float(levels[rng.integers(0, len(levels))])
        ramp = min(12, ln)
        m = min(ln, n - i)
        seg = np.concatenate([np.linspace(cur, tgt, ramp), np.full(max(0, ln - ramp), tgt)])[:m]
        out[i : i + m] = seg
        cur = tgt
        i += m
    return out + 0.002 * rng.standard_normal(n)


def _ar1(e: np.ndarray, phi: float) -> np.ndarray:
    """x_t = sum_{k<=t} phi^(t-k) e_k via recursive doubling, O(n log n)."""
    x = e.copy()
    shift = 1
    while shift < len(x):
        factor = phi**shift
        if factor < 1e-14:
            break
        x[shift:] += factor * x[:-shift]
        shift *= 2
    return x


def _wind_speed(rng: np.random.Generator, n: int) -> np.ndarray:
    """m/s, 2 decimals: gusty, zero-clamped, sharp discontinuities."""
    v = _ar1(rng.standard_normal(n) * 0.25, 0.995) + 4.0
    jumps = (rng.random(n) < 0.0008) * rng.uniform(-4, 7, size=n)
    v = v + np.cumsum(jumps) * 0.05
    return np.abs(v)


def _pressure(rng: np.random.Generator, n: int) -> np.ndarray:
    """kPa, 5 decimals: smooth diurnal cycles + slow drift; highly repetitive."""
    t = np.arange(n)
    diurnal = 1.2 * np.sin(2 * np.pi * t / 14400.0) + 0.4 * np.sin(2 * np.pi * t / 7200.0 + 1.0)
    drift = np.cumsum(rng.standard_normal(n)) * 0.0008
    return 97.0 + diurnal + drift + 0.003 * rng.standard_normal(n)


def household_power(rng_seed: int, n: int, noise_sigma: float = 0.1) -> np.ndarray:
    """Fig. 10's scaling dataset: household power consumption analogue with
    sharp discontinuities (appliance switching) + N(0, 0.1) injected noise,
    mirroring the paper's synthetic-growth methodology."""
    rng = np.random.default_rng(rng_seed)
    out = np.empty(n)
    i = 0
    cur = 0.4
    while i < n:
        ln = int(rng.uniform(30, 600))
        if rng.random() < 0.35:
            cur = float(rng.choice([0.2, 0.4, 1.5, 2.4, 3.6, 5.0]))
        m = min(ln, n - i)
        out[i : i + m] = cur
        i += m
    out = out + rng.normal(0.0, noise_sigma, size=n)
    return np.round(out, 3)


def ragged_sensor_traffic(
    s: int,
    ticks: int,
    rate_lo: float = 2.0,
    rate_hi: float = 512.0,
    seed: int = 0,
) -> list[list[tuple[int, np.ndarray]]]:
    """Heterogeneous-rate gateway traffic: ``s`` sensors whose per-tick
    publish rates are drawn log-uniform over [rate_lo, rate_hi] (~2.5
    decades by default — the ragged regime of Sprintz, arXiv:1808.02515).
    Each tick, sensor ``sid`` emits ``Poisson(rate_sid)`` samples of its
    random walk (plus measurement noise, rounded to 4 decimals).

    Returns one list per tick of ``(sid, chunk)`` deliveries (zero-sample
    ticks omitted).  Shared by ``launch/serve.py --mode ingest`` and
    ``benchmarks/bench_ragged.py`` so the demo and the benchmark always
    simulate the same workload.
    """
    rng = np.random.default_rng(seed)
    rates = np.exp(rng.uniform(np.log(rate_lo), np.log(rate_hi), size=s))
    walks = np.zeros(s)
    out: list[list[tuple[int, np.ndarray]]] = []
    for _ in range(ticks):
        tick: list[tuple[int, np.ndarray]] = []
        for sid in range(s):
            n = int(rng.poisson(rates[sid]))
            if n == 0:
                continue
            chunk = walks[sid] + np.cumsum(rng.standard_normal(n) * 0.03)
            walks[sid] = chunk[-1]
            tick.append((sid, np.round(chunk + rng.standard_normal(n) * 0.01, 4)))
        out.append(tick)
    return out


_SPECS = [
    DatasetSpec("FaceFour", 8, -4.6, 5.9, 39_200, _face_four),
    DatasetSpec("MoteStrain", 8, -8.5, 8.5, 106_848, _mote_strain),
    DatasetSpec("Lightning", 8, -1.6, 23.1, 122_694, _lightning),
    DatasetSpec("ECG", 11, -7.0, 7.4, 699_720, _ecg),
    DatasetSpec("Cricket", 8, -10.1, 12.7, 702_000, _cricket),
    DatasetSpec("WindDirection", 2, 0.0, 360.0, 1_169_510, _wind_direction),
    DatasetSpec("Wafer", 7, -3.0, 12.1, 1_088_928, _wafer),
    DatasetSpec("WindSpeed", 2, 0.0, 20.4, 4_119_081, _wind_speed),
    DatasetSpec("Pressure", 5, 90.9, 104.1, 12_098_677, _pressure),
]

DATASETS: dict[str, DatasetSpec] = {s.name: s for s in _SPECS}


def load(name: str, n: int | None = None, seed: int = 1234) -> np.ndarray:
    """Generate dataset `name` with `n` rows (default: full Table II size)."""
    spec = DATASETS[name]
    rows = spec.rows if n is None else int(n)
    rng = np.random.default_rng(seed + _zlib.crc32(name.encode()) % 100_000)
    v = spec.gen(rng, rows)
    v = _scale_to(v, spec.vmin, spec.vmax)
    return np.round(v, spec.decimals)
