"""Single-archive compressed-domain query engine.

``SeriesAnalytics`` answers queries over one :class:`CompressedSeries`
(a ``SHRK`` archive) without reconstructing it:

* the **segment path** evaluates closed-form per-segment algebra
  (``core.segment_algebra``) over the knowledge base — O(#segments), zero
  entropy work — and widens the result by the base's practical error
  bound;
* the **dense path** decodes the cheapest pyramid layer prefix whose
  guarantee satisfies the requested ``eps`` (through a cached
  :class:`ProgressiveDecoder`, so repeated queries pay each layer once)
  and widens by that tier's guarantee;
* ``count_where`` runs the **refine loop**: classify every sample's
  interval against the predicate, descend one pyramid layer at a time,
  and re-examine only the samples whose intervals still straddle the
  threshold — stopping the moment none do.

Every answer is an :class:`AggregateAnswer` interval ``[lo, hi]``
guaranteed to contain the decode-then-numpy truth; at the lossless tier
the interval collapses (``lo == hi``) to the numpy oracle exactly.  The
containment margins mirror the pyramid's tested guarantee slack
(``g·(1+1e-9) + 8·ulp·scale``) plus a float-summation allowance, so the
oracle-differential property suite can assert strict containment.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from ..core.segment_algebra import (
    SegmentTable,
    base_aggregate,
    base_aggregate_with_m2,
    count_cmp,
    segment_table,
)
from ..core.shrink import ProgressiveDecoder
from ..core.types import CompressedSeries

__all__ = [
    "AGG_OPS",
    "CMP_OPS",
    "AggregateAnswer",
    "SeriesAnalytics",
    "classify",
    "point_margin",
    "rank_similar",
    "rank_topk",
    "refine_count",
    "resolve_or_finest",
    "segment_records",
]

AGG_OPS = ("min", "max", "sum", "mean", "count", "stddev")
CMP_OPS = ("gt", "ge", "lt", "le")

_EPS64 = float(np.finfo(np.float64).eps)


def _fp_slack(scale: float) -> float:
    """Float allowance per point: covers the pyramid guarantee's tested ulp
    slack plus closed-form-vs-dense summation rounding."""
    return 8.0 * _EPS64 * max(1.0, scale)


def point_margin(g: float, scale: float) -> float:
    """Per-point containment margin for a representation with guarantee
    ``g``: the tier's bound, its relative slack, and float rounding.  A
    guarantee of exactly 0.0 (lossless prefix / exact base) means the
    reconstruction IS the decimal-grid truth — no margin."""
    if g == 0.0:
        return 0.0
    return g * (1.0 + 1e-9) + _fp_slack(scale)


def resolve_or_finest(cs: CompressedSeries, eps: float) -> int:
    """Layer-prefix index serving ``eps``, falling back to the finest
    available tier when no tier qualifies — an analytics answer then
    simply stays as tight as the archive allows (the achieved guarantee
    is always reported, so the caller sees what it got)."""
    try:
        return cs.pyramid.resolve(eps, cs.eps_b_practical)
    except ValueError:
        return len(cs.pyramid.layers) - 1


@dataclasses.dataclass
class AggregateAnswer:
    """One interval answer: the truth is guaranteed to lie in [lo, hi].

    ``eps`` is the per-point guarantee of the representation that served
    the query (0.0 = exact); ``exact`` marks a collapsed interval served
    from an exact reconstruction.  ``source`` is ``"segments"`` (closed
    form, zero entropy work), ``"dense"`` (pyramid prefix), or
    ``"mixed"`` (multi-frame plans using both).  ``layers_paid`` counts
    entropy-decoded layers this query actually triggered;
    ``frames_touched``/``frames_skipped``/``frames_refined`` report the
    planner's work (trivially 1/0/0-or-1 for a single archive)."""

    op: str
    lo: float
    hi: float
    m: int
    eps: float
    exact: bool
    source: str
    layers_paid: int = 0
    frames_touched: int = 1
    frames_skipped: int = 0
    frames_refined: int = 0
    # True when corruption capped refinement short of the requested eps:
    # the interval is then wider than asked for but STILL contains the
    # truth (``eps`` reports the guarantee actually achieved).
    degraded: bool = False

    @property
    def achieved_eps(self) -> float:
        """The per-point guarantee actually served (alias of ``eps``; the
        name the degradation contract in docs/robustness.md uses)."""
        return self.eps

    @property
    def width(self) -> float:
        return self.hi - self.lo

    @property
    def midpoint(self) -> float:
        return 0.5 * (self.lo + self.hi)

    def contains(self, x: float) -> bool:
        return self.lo <= x <= self.hi


def _compose(op: str, m: int, est: float, e_pt: float, e_sum: float) -> tuple[float, float]:
    """[lo, hi] for an aggregate estimate ``est`` whose per-point error is
    bounded by ``e_pt`` (``e_sum`` = the summed-error bound for ``sum``)."""
    if op in ("min", "max", "mean", "stddev"):
        lo, hi = est - e_pt, est + e_pt
        if op == "stddev":
            lo = max(lo, 0.0)
        return lo, hi
    if op == "sum":
        return est - e_sum, est + e_sum
    raise ValueError(f"unknown aggregate op {op!r}")


def classify(op: str, lb: np.ndarray, ub: np.ndarray, value: float):
    """(definitely-satisfies, definitely-not) masks for per-point truth
    intervals [lb, ub] against ``pred <op> value``."""
    if op == "gt":
        return lb > value, ub <= value
    if op == "ge":
        return lb >= value, ub < value
    if op == "lt":
        return ub < value, lb >= value
    if op == "le":
        return ub <= value, lb > value
    raise ValueError(f"unknown comparison {op!r}: expected one of {CMP_OPS}")


def refine_count(
    dec: ProgressiveDecoder,
    a: int,
    b: int,
    op: str,
    value: float,
    scale: float,
    k_target: int,
) -> tuple[int, int, float, int]:
    """The refine loop over one frame's samples [a, b): classify each
    sample's interval against the predicate, descending one pyramid layer
    at a time and re-examining ONLY the still-straddling samples; stops as
    soon as none straddle (or the target tier is reached).  Returns
    (definite_in, straddling, achieved_guarantee, layers_paid)."""
    n_in = 0
    idx: np.ndarray | None = None
    g = dec.cs.eps_b_practical
    paid0 = dec.layers_decoded
    for d in range(-1, k_target + 1):
        recon = dec.prefix(d)[a:b]
        g = dec.guarantee(d)
        gm = point_margin(g, scale)
        r = recon if idx is None else recon[idx]
        lb, ub = r - gm, r + gm
        in_m, out_m = classify(op, lb, ub, value)
        n_in += int(np.count_nonzero(in_m))
        keep = ~(in_m | out_m)
        idx = np.flatnonzero(keep) if idx is None else idx[keep]
        if idx.size == 0:
            break
    return n_in, int(idx.size), g, dec.layers_decoded - paid0


class SeriesAnalytics:
    """Compressed-domain queries over one :class:`CompressedSeries`.

    ``eps`` on every query is the per-point resolution the answer must be
    computed at: ``None`` = whatever the base alone guarantees (zero
    entropy work), ``0.0`` = exact.  The engine serves it from the
    cheapest sufficient representation and reports what it achieved.
    """

    def __init__(self, cs: CompressedSeries, decoder: ProgressiveDecoder | None = None):
        self.cs = cs
        self.dec = decoder if decoder is not None else ProgressiveDecoder(cs)
        self.table: SegmentTable = segment_table(cs.base)
        # conservative magnitude bound for float slack: the data's recorded
        # range, padded by the coarsest error the engine will ever serve
        self.scale = max(abs(cs.base.vmin), abs(cs.base.vmax)) + cs.eps_b_practical
        # per-range running intersection of the stddev prefix chain:
        # (deepest depth folded in, lo, hi) — repeated/refining stddev
        # queries pay one np.std per NEWLY decoded layer, not per call
        self._std_chain: dict[tuple[int, int], tuple[int, float, float]] = {}

    # ------------------------------------------------------------------ #
    @property
    def n(self) -> int:
        return self.cs.base.n

    def _span(self, t0: int, t1: int | None) -> tuple[int, int]:
        t1 = self.n if t1 is None else min(int(t1), self.n)
        t0 = max(int(t0), 0)
        return t0, t1

    def _resolve(self, eps: float) -> int:
        return resolve_or_finest(self.cs, eps)

    def _resolve_capped(self, eps: float) -> tuple[int, bool]:
        """Like ``_resolve`` but never descends into a quarantined layer:
        returns (prefix index, degraded?) where degraded means corruption
        forced a coarser prefix than ``eps`` asked for.  The interval math
        widens by the achieved guarantee, so a capped answer stays valid —
        just wider, and flagged."""
        k = resolve_or_finest(self.cs, eps)
        intact = self.dec.intact_depth()
        if k > intact:
            return intact, True
        return k, False

    def _use_segments(self, eps: float | None) -> bool:
        return eps is None or (eps > 0.0 and eps >= self.cs.eps_b_practical)

    # ------------------------------------------------------------------ #
    def aggregate(
        self, op: str, t0: int = 0, t1: int | None = None, eps: float | None = None
    ) -> AggregateAnswer:
        """Interval answer for ``op`` over samples [t0, t1)."""
        if op not in AGG_OPS:
            raise ValueError(f"unknown aggregate op {op!r}: expected one of {AGG_OPS}")
        t0, t1 = self._span(t0, t1)
        m = t1 - t0
        if op == "count":
            return AggregateAnswer(
                op=op, lo=float(max(m, 0)), hi=float(max(m, 0)), m=max(m, 0),
                eps=0.0, exact=True, source="segments",
            )
        if m <= 0:
            raise ValueError(f"empty sample range [{t0}, {t1})")

        if self._use_segments(eps):
            if op == "stddev":
                st, m2 = base_aggregate_with_m2(self.table, t0, t1)
                est = math.sqrt(max(m2, 0.0) / m)
            else:
                st = base_aggregate(self.table, t0, t1)
                est = {
                    "min": st.vmin, "max": st.vmax, "sum": st.total, "mean": st.mean,
                }[op]
            g = self.cs.eps_b_practical
            e_pt = point_margin(g, self.scale) + _fp_slack(self.scale)
            lo, hi = _compose(op, m, est, e_pt, m * e_pt)
            return AggregateAnswer(
                op=op, lo=lo, hi=hi, m=m, eps=g, exact=False, source="segments",
            )

        k, capped = self._resolve_capped(eps)
        paid0 = self.dec.layers_decoded
        sl = self.dec.prefix(k)[t0:t1]
        paid = self.dec.layers_decoded - paid0
        g = self.dec.guarantee(k)
        exact = g == 0.0
        e_pt = point_margin(g, self.scale)
        if op == "stddev" and not exact:
            # the 0-clamp on stddev's lower bound breaks simple
            # per-tier width monotonicity (a finer tier's estimate can
            # escape the clamp); intersecting the intervals of every
            # materialized prefix — already decoded on the way to k —
            # restores "refining only tightens" by construction.  The
            # running intersection is cached per range, so only depths not
            # folded in yet pay an np.std pass (a repeat query pays none,
            # and an already-deeper chain simply serves its tighter bound)
            done, lo, hi = self._std_chain.get((t0, t1), (-2, -math.inf, math.inf))
            for d in range(done + 1, k + 1):
                if d < 0:  # the segment path's own interval, term for term
                    _, m2 = base_aggregate_with_m2(self.table, t0, t1)
                    est_d = math.sqrt(max(m2, 0.0) / m)
                    e_d = point_margin(self.cs.eps_b_practical, self.scale)
                    e_d += _fp_slack(self.scale)
                else:
                    est_d = float(np.std(self.dec.prefix(d)[t0:t1]))
                    e_d = point_margin(self.dec.guarantee(d), self.scale)
                    e_d += _fp_slack(self.scale) if e_d else 0.0
                lo = max(lo, est_d - e_d)
                hi = min(hi, est_d + e_d)
            if k > done:
                self._std_chain[(t0, t1)] = (k, lo, hi)
            return AggregateAnswer(
                op=op, lo=max(lo, 0.0), hi=hi, m=m, eps=g, exact=False,
                source="dense", layers_paid=paid, frames_refined=1 if paid else 0,
                degraded=capped,
            )
        est = {
            "min": float(sl.min()),
            "max": float(sl.max()),
            "sum": float(np.sum(sl)),
            "mean": float(np.mean(sl)),
            "stddev": float(np.std(sl)),
        }[op]
        if exact:
            lo = hi = est
        else:
            # np.sum's own rounding (vs. the real-arithmetic Σ both bounds
            # refer to) rides on top of the per-point tier bound
            lo, hi = _compose(op, m, est, e_pt + _fp_slack(self.scale),
                              m * (e_pt + _fp_slack(self.scale)))
        return AggregateAnswer(
            op=op, lo=lo, hi=hi, m=m, eps=g, exact=exact, source="dense",
            layers_paid=paid, frames_refined=1 if paid else 0, degraded=capped,
        )

    # ------------------------------------------------------------------ #
    def count_where(
        self,
        op: str,
        value: float,
        t0: int = 0,
        t1: int | None = None,
        eps: float | None = None,
    ) -> AggregateAnswer:
        """Integer interval [definite, definite+straddling] for
        ``#{t in [t0, t1) : v_t <op> value}``.  Starts from the
        closed-form segment counts (zero decode); refines through pyramid
        layers only while some sample's interval still straddles the
        threshold and the requested ``eps`` asks for more."""
        if op not in CMP_OPS:
            raise ValueError(f"unknown comparison {op!r}: expected one of {CMP_OPS}")
        t0, t1 = self._span(t0, t1)
        m = t1 - t0
        if m <= 0:
            return AggregateAnswer(op=op, lo=0.0, hi=0.0, m=0, eps=0.0, exact=True,
                                   source="segments")
        g = self.cs.eps_b_practical
        margin = point_margin(g, self.scale)
        sgn = 1.0 if op in ("gt", "ge") else -1.0
        definite = count_cmp(self.table, t0, t1, op, value + sgn * margin)
        possible = count_cmp(self.table, t0, t1, op, value - sgn * margin)
        if definite == possible or self._use_segments(eps):
            return AggregateAnswer(
                op=op, lo=float(definite), hi=float(possible), m=m, eps=g,
                exact=definite == possible, source="segments",
            )
        k, capped = self._resolve_capped(eps)
        n_in, straddle, g, paid = refine_count(
            self.dec, t0, t1, op, value, self.scale, k
        )
        # both the segment interval and the refined interval contain the
        # truth; return their intersection (monotone by construction)
        lo = max(definite, n_in)
        hi = min(possible, n_in + straddle)
        return AggregateAnswer(
            op=op, lo=float(lo), hi=float(hi), m=m, eps=g, exact=lo == hi,
            source="dense", layers_paid=paid, frames_refined=1 if paid else 0,
            degraded=capped,
        )

    # ------------------------------------------------------------------ #
    def segments(self, t0: int = 0, t1: int | None = None) -> list[dict]:
        """The knowledge base's member segments overlapping [t0, t1) as
        plain records — the raw material of top-k queries."""
        t0, t1 = self._span(t0, t1)
        return segment_records(self.table, t0, t1)

    def topk_segments(
        self, k: int = 5, by: str = "length", t0: int = 0, t1: int | None = None
    ) -> list[dict]:
        """Top-k segments by ``length`` / ``slope`` / ``abs_slope`` /
        ``max`` / ``min`` — exact compressed-domain facts (for ``min`` the
        k *lowest-reaching* segments).  Deterministic tie-break by t0."""
        return rank_topk(self.segments(t0, t1), k, by)

    def similar_segments(
        self, slope: float, length: float, k: int = 5,
        t0: int = 0, t1: int | None = None,
    ) -> list[dict]:
        """k segments most similar to a query shape (slope, length) under
        a z-normalized L2 distance over the knowledge base — segment-level
        similarity search that never touches residuals."""
        return rank_similar(self.segments(t0, t1), slope, length, k)


# --------------------------------------------------------------------- #
# segment-record queries, shared with the multi-frame planner
# --------------------------------------------------------------------- #
def segment_records(
    table: SegmentTable, t0: int, t1: int, offset: int = 0
) -> list[dict]:
    """Member segments of ``table`` overlapping local samples [t0, t1) as
    plain records; ``offset`` shifts reported positions into container
    coordinates (a SHRKS frame's payload indexes from its own 0)."""
    idx, a, b = table.overlap(t0, t1)
    out = []
    for j, i in enumerate(idx):
        theta = float(table.thetas[i])
        slope = float(table.slopes[i])
        va = theta + slope * float(a[j])
        vb = theta + slope * float(b[j] - 1)
        out.append({
            "t0": int(offset + table.t0s[i] + a[j]),
            "length": int(b[j] - a[j]),
            "theta": theta,
            "slope": slope,
            "vmin": min(va, vb),
            "vmax": max(va, vb),
        })
    return out


def rank_topk(recs: list[dict], k: int, by: str) -> list[dict]:
    key = {
        "length": lambda r: -r["length"],
        "slope": lambda r: -r["slope"],
        "abs_slope": lambda r: -abs(r["slope"]),
        "max": lambda r: -r["vmax"],
        "min": lambda r: r["vmin"],
    }.get(by)
    if key is None:
        raise ValueError(f"unknown top-k metric {by!r}")
    recs = sorted(recs, key=lambda r: (key(r), r["t0"]))
    return recs[: max(int(k), 0)]


def rank_similar(recs: list[dict], slope: float, length: float, k: int) -> list[dict]:
    if not recs:
        return []
    slopes = np.array([r["slope"] for r in recs])
    lens = np.array([r["length"] for r in recs], dtype=np.float64)
    s_sd = float(slopes.std()) or 1.0
    l_sd = float(lens.std()) or 1.0
    d = ((slopes - slope) / s_sd) ** 2 + ((lens - length) / l_sd) ** 2
    order = np.lexsort((np.array([r["t0"] for r in recs]), d))
    out = []
    for i in order[: max(int(k), 0)]:
        rec = dict(recs[int(i)])
        rec["distance"] = float(d[int(i)])
        out.append(rec)
    return out
