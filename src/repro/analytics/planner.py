"""Frame-skipping query planner over ``SHRKS`` containers.

``AnalyticsEngine`` answers the same query surface as
:class:`SeriesAnalytics` but against a framed stream container, planning
per frame:

* **sketch** — each touched frame's knowledge base is parsed ONCE (no
  entropy work) into a :class:`SegmentTable` + practical error bound,
  cached for the life of the engine;
* **skip** — frames whose sketch bounds cannot affect the answer are
  never decoded: for min/max, a frame whose optimistic bound is worse
  than another frame's pessimistic bound is dead; for predicates, a frame
  whose segment-domain count interval already collapsed needs no
  residuals;
* **refine** — the surviving frames descend their residual pyramids
  through the *serving LRU's* cached :class:`ProgressiveDecoder` prefixes
  (``RangeQueryBatcher.decoder``), so analytics and range queries share
  decoded layers.

Answers are :class:`AggregateAnswer` intervals guaranteed to contain the
decode-then-numpy truth; ``stats`` tallies the planner's work
(``frames_skipped`` / ``frames_refined`` / ``layers_paid`` ...).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from ..core.segment_algebra import (
    SegmentTable,
    base_aggregate,
    base_central_m2,
    count_cmp,
    segment_table,
)
from ..core.errors import CorruptFrameError
from ..core.serialize import frame_payload
from ..core.shrink import cs_from_bytes
from ..serving.batching import RangeQueryBatcher
from .engine import (
    AGG_OPS,
    CMP_OPS,
    AggregateAnswer,
    _fp_slack,
    point_margin,
    rank_similar,
    rank_topk,
    refine_count,
    resolve_or_finest,
    segment_records,
)

__all__ = ["AnalyticsEngine"]


@dataclasses.dataclass
class _FrameSketch:
    """Per-frame zero-decode synopsis: the parsed knowledge base and its
    guarantee — everything the planner needs before deciding to pay for
    residual layers."""

    meta: object
    table: SegmentTable
    eps_b: float
    scale: float


@dataclasses.dataclass
class _Part:
    """One frame's contribution to a planned aggregate."""

    sk: _FrameSketch
    a: int  # frame-local overlap [a, b)
    b: int
    m: int
    est: float = 0.0
    e_pt: float = 0.0  # per-point containment margin of this contribution
    dense: np.ndarray | None = None  # decoded slice when refined
    exact: bool = False
    degraded: bool = False  # corruption capped this frame short of eps


class AnalyticsEngine:
    """Compressed-domain analytics over a ``SHRKS`` container.

    ``source`` is either the container bytes or an existing
    :class:`RangeQueryBatcher` — passing the serving batcher shares its
    frame-decoder LRU, so a dashboard mixing range decodes and aggregates
    pays each pyramid layer at most once.
    """

    def __init__(
        self,
        source: bytes | RangeQueryBatcher,
        cache_frames: int = 32,
        degraded_ok: bool = False,
        kb_store=None,  # serving.kbstore.KBStore, forwarded to the batcher
    ):
        if isinstance(source, RangeQueryBatcher):
            self.batcher = source  # inherits the batcher's degraded_ok
        else:
            self.batcher = RangeQueryBatcher(
                source,
                cache_frames=cache_frames,
                degraded_ok=degraded_ok,
                kb_store=kb_store,
            )
        self._sketches: dict[int, _FrameSketch] = {}
        self.stats = {
            "queries": 0,
            "frames_touched": 0,
            "frames_skipped": 0,
            "frames_refined": 0,
            "segment_frames": 0,
            "layers_paid": 0,
            "degraded": 0,
        }

    # ------------------------------------------------------------------ #
    @property
    def series_ids(self) -> list[int]:
        return self.batcher.series_ids

    def span(self, series_id: int) -> tuple[int, int]:
        return self.batcher.span(series_id)

    def _sketch(self, meta) -> _FrameSketch:
        sk = self._sketches.get(meta.offset)
        if sk is None:
            try:
                cs = cs_from_bytes(frame_payload(self.batcher.blob, meta))
            except CorruptFrameError:
                if not self.batcher.degraded_ok:
                    raise
                # a sketch only needs the base + eps_hat, which the SHRK
                # header CRC protects independently of the frame CRC: a
                # frame whose residual section is damaged still yields a
                # valid (coarse) synopsis.  cs_from_bytes re-raises if the
                # header/base CRC itself fails — no unprovable sketches.
                cs = cs_from_bytes(
                    frame_payload(self.batcher.blob, meta, verify_crc=False),
                    strict=False,
                )
            sk = _FrameSketch(
                meta=meta,
                table=segment_table(cs.base),
                eps_b=cs.eps_b_practical,
                scale=max(abs(cs.base.vmin), abs(cs.base.vmax)) + cs.eps_b_practical,
            )
            self._sketches[meta.offset] = sk
        return sk

    def _plan(self, series_id: int, t0: int, t1: int | None):
        if t1 is None:
            t1 = self.batcher.span(series_id)[1]
        touched = self.batcher.frames_overlapping(series_id, int(t0), int(t1))
        parts = []
        for meta in touched:
            sk = self._sketch(meta)
            a = max(int(t0), meta.t_lo) - meta.t_lo
            b = min(int(t1), meta.t_hi) - meta.t_lo
            parts.append(_Part(sk=sk, a=a, b=b, m=b - a))
        self.stats["frames_touched"] += len(parts)
        return int(t0), int(t1), parts

    @staticmethod
    def _wants_refine(eps: float | None, sk: _FrameSketch) -> bool:
        """Does ``eps`` ask for more than this frame's base guarantees?"""
        return eps is not None and not (eps > 0.0 and eps >= sk.eps_b)

    def _refine(self, part: _Part, eps: float) -> int:
        """Decode the cheapest sufficient layer prefix of one frame (via
        the shared serving LRU) and replace the part's estimate with the
        dense slice; returns the entropy decodes actually paid."""
        dec = self.batcher.decoder(part.sk.meta)
        k = resolve_or_finest(dec.cs, eps)
        intact = dec.intact_depth()
        if k > intact:
            # strict-mode decoders never carry corrupt layers (parse would
            # have raised), so reaching here means degraded_ok: serve the
            # finest intact prefix, flagged
            k = intact
            part.degraded = True
        paid0 = dec.layers_decoded
        part.dense = dec.prefix(k)[part.a : part.b]
        paid = dec.layers_decoded - paid0
        self.stats["layers_paid"] += paid
        self.batcher.stats["layers_decoded"] += paid
        self.stats["frames_refined"] += 1
        g = dec.guarantee(k)
        part.exact = g == 0.0
        part.e_pt = point_margin(g, part.sk.scale)
        return paid

    # ------------------------------------------------------------------ #
    def aggregate(
        self,
        series_id: int,
        op: str,
        t0: int = 0,
        t1: int | None = None,
        eps: float | None = None,
    ) -> AggregateAnswer:
        """Interval answer for ``op`` over samples [t0, t1) of one series.

        min/max skip every frame whose segment-domain bounds cannot reach
        the answer; sum/mean/stddev refine each touched frame only when
        ``eps`` is finer than that frame's base guarantee."""
        if op not in AGG_OPS:
            raise ValueError(f"unknown aggregate op {op!r}: expected one of {AGG_OPS}")
        self.stats["queries"] += 1
        t0, t1, parts = self._plan(series_id, t0, t1)
        m = sum(p.m for p in parts)
        if op == "count":
            return AggregateAnswer(
                op=op, lo=float(m), hi=float(m), m=m, eps=0.0, exact=True,
                source="segments", frames_touched=len(parts),
            )
        if op in ("min", "max"):
            return self._extremum(op, parts, eps)
        return self._moments(op, parts, eps, m)

    def _extremum(self, op: str, parts, eps: float | None) -> AggregateAnswer:
        sign = 1.0 if op == "min" else -1.0  # work in "min" orientation
        for p in parts:
            st = base_aggregate(p.sk.table, p.a, p.b)
            p.est = sign * (st.vmin if op == "min" else st.vmax)
            p.e_pt = point_margin(p.sk.eps_b, p.sk.scale) + _fp_slack(p.sk.scale)
        # frame-skipping: a frame whose optimistic bound cannot beat the
        # best pessimistic bound can never contain the extremum
        best_hi = min(p.est + p.e_pt for p in parts)
        live = [p for p in parts if p.est - p.e_pt <= best_hi]
        skipped = len(parts) - len(live)
        paid = 0
        for p in live:
            if self._wants_refine(eps, p.sk):
                paid += self._refine(p, eps)
                sl = p.dense
                p.est = sign * float(sl.min() if op == "min" else sl.max())
                if not p.exact:
                    p.e_pt += _fp_slack(p.sk.scale)
            else:
                self.stats["segment_frames"] += 1
        self.stats["frames_skipped"] += skipped
        # skipped frames keep their (valid) sketch bounds: min composes
        lo = min(p.est - p.e_pt for p in parts)
        hi = min(p.est + p.e_pt for p in parts)
        if sign < 0:
            lo, hi = -hi, -lo
        g = max(p.e_pt for p in live)
        exact = all(p.exact for p in live) and lo == hi
        degraded = any(p.degraded for p in live)
        if degraded:
            self.stats["degraded"] += 1
        return AggregateAnswer(
            op=op, lo=lo, hi=hi, m=sum(p.m for p in parts),
            eps=0.0 if exact else g, exact=exact,
            source="dense" if all(p.dense is not None for p in parts) else (
                "segments" if all(p.dense is None for p in parts) else "mixed"),
            layers_paid=paid, frames_touched=len(parts),
            frames_skipped=skipped,
            frames_refined=sum(1 for p in live if p.dense is not None),
            degraded=degraded,
        )

    def _moments(self, op: str, parts, eps: float | None, m: int) -> AggregateAnswer:
        if m <= 0:
            raise ValueError("empty sample range")
        paid = 0
        for p in parts:
            if self._wants_refine(eps, p.sk):
                paid += self._refine(p, eps)
                p.est = float(np.sum(p.dense))
                if not p.exact:
                    p.e_pt += _fp_slack(p.sk.scale)
            else:
                st = base_aggregate(p.sk.table, p.a, p.b)
                p.est = st.total
                p.e_pt = point_margin(p.sk.eps_b, p.sk.scale) + _fp_slack(p.sk.scale)
                self.stats["segment_frames"] += 1
        scale = max(p.sk.scale for p in parts)
        total = sum(p.est for p in parts)
        mu = total / m
        single_exact = len(parts) == 1 and parts[0].exact
        # composing float partial sums across frames costs its own slack
        compose = 0.0 if single_exact else _fp_slack(scale)
        refined = sum(1 for p in parts if p.dense is not None)
        src = "dense" if refined == len(parts) else (
            "segments" if refined == 0 else "mixed")
        degraded = any(p.degraded for p in parts)
        if degraded:
            self.stats["degraded"] += 1
        common = dict(
            m=m, source=src, layers_paid=paid,
            frames_touched=len(parts), frames_refined=refined,
            degraded=degraded,
        )
        g = max(p.e_pt for p in parts)
        if op == "sum":
            e = sum(p.m * (p.e_pt + compose) for p in parts)
            lo, hi = (total, total) if single_exact else (total - e, total + e)
            return AggregateAnswer(op=op, lo=lo, hi=hi, eps=0.0 if single_exact else g,
                                   exact=single_exact, **common)
        if op == "mean":
            if single_exact:
                est = float(np.mean(parts[0].dense))
                return AggregateAnswer(op=op, lo=est, hi=est, eps=0.0, exact=True,
                                       **common)
            e = sum(p.m * p.e_pt for p in parts) / m + compose
            return AggregateAnswer(op=op, lo=mu - e, hi=mu + e, eps=g, exact=False,
                                   **common)
        # stddev: centering is a contraction in L2, so the per-point errors
        # bound the stddev shift by sqrt(Σ m_f e_f² / m)
        if single_exact:
            est = float(np.std(parts[0].dense))
            return AggregateAnswer(op=op, lo=est, hi=est, eps=0.0, exact=True, **common)
        m2 = 0.0
        for p in parts:
            if p.dense is not None:
                m2 += float(((p.dense - mu) ** 2).sum())
            else:
                m2 += base_central_m2(p.sk.table, p.a, p.b, mu)
        est = math.sqrt(max(m2, 0.0) / m)
        e = math.sqrt(sum(p.m * p.e_pt * p.e_pt for p in parts) / m) + compose
        return AggregateAnswer(op=op, lo=max(est - e, 0.0), hi=est + e, eps=g,
                               exact=False, **common)

    # ------------------------------------------------------------------ #
    def count_where(
        self,
        series_id: int,
        op: str,
        value: float,
        t0: int = 0,
        t1: int | None = None,
        eps: float | None = None,
    ) -> AggregateAnswer:
        """Integer interval for ``#{t : v_t <op> value}`` over [t0, t1).
        Each frame is first counted in closed form from its segments; only
        frames whose interval still straddles pay residual layers, one at
        a time, re-examining only the straddling samples."""
        if op not in CMP_OPS:
            raise ValueError(f"unknown comparison {op!r}: expected one of {CMP_OPS}")
        self.stats["queries"] += 1
        t0, t1, parts = self._plan(series_id, t0, t1)
        sgn = 1.0 if op in ("gt", "ge") else -1.0
        lo_total, hi_total = 0, 0
        g_worst = 0.0
        refined = skipped = paid_q = 0
        for p in parts:
            margin = point_margin(p.sk.eps_b, p.sk.scale)
            definite = count_cmp(p.sk.table, p.a, p.b, op, value + sgn * margin)
            possible = count_cmp(p.sk.table, p.a, p.b, op, value - sgn * margin)
            if definite == possible or not self._wants_refine(eps, p.sk):
                if definite == possible:
                    skipped += 1  # segment bounds settled it: no decode
                else:
                    self.stats["segment_frames"] += 1
                    g_worst = max(g_worst, p.sk.eps_b)
                lo_total += definite
                hi_total += possible
                continue
            dec = self.batcher.decoder(p.sk.meta)
            k = resolve_or_finest(dec.cs, eps)
            intact = dec.intact_depth()
            if k > intact:
                k = intact
                p.degraded = True
            n_in, straddle, g, paid = refine_count(
                dec, p.a, p.b, op, value, p.sk.scale, k
            )
            self.stats["layers_paid"] += paid
            self.batcher.stats["layers_decoded"] += paid
            paid_q += paid
            refined += 1
            g_worst = max(g_worst, g)
            lo_total += max(definite, n_in)
            hi_total += min(possible, n_in + straddle)
        self.stats["frames_skipped"] += skipped
        self.stats["frames_refined"] += refined
        degraded = any(p.degraded for p in parts)
        if degraded:
            self.stats["degraded"] += 1
        return AggregateAnswer(
            op=op, lo=float(lo_total), hi=float(hi_total), m=sum(p.m for p in parts),
            eps=g_worst, exact=lo_total == hi_total,
            source="dense" if refined == len(parts) else (
                "segments" if refined == 0 else "mixed"),
            layers_paid=paid_q, frames_touched=len(parts),
            frames_skipped=skipped, frames_refined=refined,
            degraded=degraded,
        )

    # ------------------------------------------------------------------ #
    def segments(self, series_id: int, t0: int = 0, t1: int | None = None) -> list[dict]:
        """Member segments overlapping [t0, t1), in container coordinates
        — pure directory+base reads, no residual decode."""
        _, _, parts = self._plan(series_id, t0, t1)
        recs: list[dict] = []
        for p in parts:
            recs.extend(segment_records(p.sk.table, p.a, p.b, offset=p.sk.meta.t_lo))
        return recs

    def topk_segments(
        self, series_id: int, k: int = 5, by: str = "length",
        t0: int = 0, t1: int | None = None,
    ) -> list[dict]:
        return rank_topk(self.segments(series_id, t0, t1), k, by)

    def similar_segments(
        self, series_id: int, slope: float, length: float, k: int = 5,
        t0: int = 0, t1: int | None = None,
    ) -> list[dict]:
        return rank_similar(self.segments(series_id, t0, t1), slope, length, k)
