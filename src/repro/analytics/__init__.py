"""Compressed-domain analytics: query SHRK archives and SHRKS containers
without decoding them.

The engine answers aggregates (min/max/sum/mean/count/stddev), range
predicates (``count_where``), and top-k segment/similarity queries
directly on the knowledge base's linear segments plus the residual
pyramid's per-tier error bounds.  Every answer is an interval
``[lo, hi]`` guaranteed to contain the exact (decode-then-numpy) value;
a refine loop pays pyramid layers — through the same
``ProgressiveDecoder`` prefixes the serving LRU caches — only for frames
whose bounds still straddle the query.  See docs/analytics.md for the
query model, bound semantics, and cost model.
"""
from .engine import AggregateAnswer, SeriesAnalytics  # noqa: F401
from .planner import AnalyticsEngine  # noqa: F401
