"""Streaming ingest for SHRINK: chunk-at-a-time compression on a gateway.

The one-shot codec (``ShrinkCodec.compress``) needs the whole series in
memory.  An IoT gateway sees the opposite regime — Sprintz-style
chunk-at-a-time ingest from many sensors at once — and SHRINK's central
claim (compression ratio *grows* with data size as the knowledge base
amortizes) only pays off if the codec can run in that regime.  This module
provides it:

* ``ShrinkStreamCodec`` — stateful, multi-series.  ``ingest(chunk,
  series_id)`` advances an *incremental* cone scan whose open-cone state
  (origin, adaptive threshold, running slope intersection) carries across
  chunk boundaries, so segment breaks — and therefore every downstream
  byte — are identical to the one-shot scan over the concatenated data.
  Sealed frames accumulate; ``finalize()`` emits a ``SHRKS`` framed
  container (normative layout in docs/wire-format.md).

* ``KnowledgeBase`` — the gateway-resident dictionary of semantic lines
  (fluctuation level, origin grid index, slope).  Every sealed frame's
  sub-bases are ingested; identical lines discovered in different chunks
  *or different series* dedup to one ref-counted entry.  ``merge``
  combines the KBs of two gateways, ``to_bytes``/``from_bytes`` spill and
  restore it, and the serialized KB rides in the container footer.

* ``decode_range`` / ``decode_series`` — random access: a range query
  touches only the frames overlapping [t0, t1), verifying payload CRCs
  lazily per touched frame.  Each frame payload is a ``SHRK`` container
  holding a residual refinement *pyramid*, so any requested eps resolves
  to the cheapest sufficient layer prefix of each touched frame.

Exactness contract (property-tested in tests/test_streaming_property.py):
every frame payload is byte-identical to ``ShrinkCodec.compress`` of that
frame's sample slice under the same pinned parameters, for ANY chunking of
the input.  Two global quantities make the incremental scan possible:

* ``value_range`` pins the fluctuation denominator delta_global (IoT
  sensors publish their measurement range up front; the paper derives
  eps_b from the same range).
* The interval length L is pinned from ``n_hint`` (falling back to
  ``frame_len``).

With both pinned, the scan runs incrementally as chunks arrive, holding
only the unscanned tail plus the current frame's raw samples.  Without
them the scan is *deferred* to frame seal (the frame buffer is scanned
one-shot with frame-local range/L) — still chunking-invariant, no longer
incremental.  With ``frame_len=None`` and range/n pinned to the full
series, flushing a fully streamed series reproduces the one-shot
``cs_to_bytes(ShrinkCodec.compress(v, ...))`` bytes exactly.
"""
from __future__ import annotations

import dataclasses
import math
import struct
import zlib

import numpy as np

from .base import construct_base, origin_index
from .errors import (
    ConfigError,
    FormatError,
    KBReferenceError,
    RangeCoverageError,
    ShrinkError,
    TruncatedArchiveError,
    UnknownSeriesError,
)
from .phases import default_interval_length, divide, eps_hat_for_level
from .semantics import extract_semantics, global_range
from .serialize import (
    FramedWriter,
    _read_svarint,
    _write_svarint,
    frame_payload,
    kb_snapshot_id,
    parse_framed_container,
    read_snapshot_ref,
    read_varint,
    write_varint,
)
from .shrink import (
    cs_from_bytes,
    cs_to_bytes,
    decompress_at,
    encode_frames_with_bases,
    encode_with_base,
)
from .types import Base, FrameMeta, Segment, ShrinkConfig, merge_backend_stats

__all__ = [
    "KnowledgeBase",
    "ShrinkStreamCodec",
    "decode_range",
    "decode_series",
    "read_knowledge_base",
    "routing_metadata",
]

_INF = math.inf
# Deferred-encode watermark: collected frames accumulate until this many
# samples are pending, then drain through one fused residual+entropy batch.
# Keeps per-ingest fixed dispatch costs (jit launch, device transfer)
# amortized even when callers feed small chunks.
_PENDING_ENCODE_SAMPLES = 128 * 1024
_KB_MAGIC = b"SHKB"
_KB_VERSION = 1
_RAW_SLOPE = 255


# --------------------------------------------------------------------- #
# Knowledge base: deduplicating dictionary of semantic lines
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class KBEntry:
    """One deduplicated semantic line: value = theta(level, origin_idx) at
    the segment start, advancing by ``slope`` per sample.  ``refs`` counts
    the sub-bases (across all frames and series) that use this line."""

    level: int
    origin_idx: int
    slope: float
    slope_digits: int
    refs: int = 0


def _slope_key(slope: float, digits: int) -> tuple:
    if digits <= 13:
        return (digits, int(round(slope * 10**digits)))
    return (_RAW_SLOPE, struct.pack("<d", slope))


class KnowledgeBase:
    """Gateway-resident, append-only dictionary of (level, origin, slope)
    lines shared across chunks and series.

    Entries are identified positionally: the container records each
    frame's ``kb_epoch`` (= entry count at seal time), so entry ids below
    a frame's epoch were known when that frame was written.  ``merge``
    folds another gateway's KB in (summing refcounts) and returns the id
    remap; ``to_bytes``/``from_bytes`` spill/restore the whole dictionary.
    """

    def __init__(self, config: ShrinkConfig):
        self.config = config
        self.entries: list[KBEntry] = []
        self._index: dict[tuple, int] = {}

    # -- identity ------------------------------------------------------ #
    @property
    def epoch(self) -> int:
        """Number of entries; frames record this at seal time."""
        return len(self.entries)

    def theta_of(self, entry: KBEntry) -> float:
        return entry.origin_idx * eps_hat_for_level(entry.level, self.config)

    def _find_or_add(self, level: int, oidx: int, slope: float, digits: int) -> int:
        key = (level, oidx) + _slope_key(slope, digits)
        eid = self._index.get(key)
        if eid is None:
            eid = len(self.entries)
            self.entries.append(
                KBEntry(level=level, origin_idx=oidx, slope=slope, slope_digits=digits)
            )
            self._index[key] = eid
        return eid

    # -- ingest / merge ------------------------------------------------ #
    def ingest_base(self, base) -> list[int]:
        """Register every sub-base of a sealed frame's base; returns the
        entry id for each (deduplicated, refcount bumped)."""
        ids = []
        for sb in base.subbases:
            oidx = origin_index(sb.theta, sb.level, self.config)
            eid = self._find_or_add(sb.level, oidx, sb.slope, sb.slope_digits)
            self.entries[eid].refs += 1
            ids.append(eid)
        return ids

    def merge(self, other: "KnowledgeBase") -> list[int]:
        """Fold ``other`` into self (e.g. two gateways syncing).  Returns
        ``remap`` with ``remap[other_id] == self_id``; refcounts sum."""
        for attr in ("eps_b", "lam", "beta_levels"):
            if getattr(self.config, attr) != getattr(other.config, attr):
                raise ConfigError(
                    f"cannot merge knowledge bases with different configs ({attr})"
                )
        remap = []
        for e in other.entries:
            eid = self._find_or_add(e.level, e.origin_idx, e.slope, e.slope_digits)
            self.entries[eid].refs += e.refs
            remap.append(eid)
        return remap

    def canonical(self) -> dict[tuple, int]:
        """Insertion-order-invariant view: ``{(level, origin_idx,
        slope_key...): refs}``.  Two KBs that hold the same lines with the
        same total refcounts — e.g. the single-process KB versus the merge
        of shard KBs in ANY order — have equal canonical maps even though
        their positional entry ids differ."""
        out: dict[tuple, int] = {}
        for e in self.entries:
            key = (e.level, e.origin_idx) + _slope_key(e.slope, e.slope_digits)
            out[key] = out.get(key, 0) + e.refs
        return out

    def snapshot_id(self) -> int:
        """Semantic snapshot identity: CRC-32 over the *sorted* canonical
        entries (plus the config triple), so it is invariant under entry
        insertion order and therefore under KB merge order.  Used by the
        fleet to tag KB sync epochs; the companion
        ``serialize.kb_snapshot_id`` identifies one concrete serialized
        blob instead."""
        buf = bytearray()
        buf += struct.pack(
            "<ddB", self.config.eps_b, self.config.lam, self.config.beta_levels
        )
        for key, refs in sorted(self.canonical().items()):
            level, oidx, digits, scaled = key
            buf += struct.pack("<Bq", level & 0xFF, oidx)
            buf.append(digits & 0xFF)
            if digits == _RAW_SLOPE:
                buf += scaled  # packed f64 bytes
            else:
                buf += struct.pack("<q", scaled)
            buf += struct.pack("<q", refs)
        return zlib.crc32(bytes(buf)) & 0xFFFFFFFF

    def release(self, entry_ids: list[int]) -> None:
        """Drop one reference per id (e.g. a frame was deleted)."""
        for eid in entry_ids:
            if not 0 <= eid < len(self.entries):
                raise KBReferenceError(
                    f"release of unknown KB entry id {eid} "
                    f"(knowledge base holds {len(self.entries)} entries)",
                    entry=eid,
                )
            e = self.entries[eid]
            if e.refs <= 0:
                raise KBReferenceError(
                    f"refcount underflow on KB entry {eid}", entry=eid
                )
            e.refs -= 1

    def stats(self) -> dict:
        total_refs = sum(e.refs for e in self.entries)
        return {
            "entries": len(self.entries),
            "total_refs": total_refs,
            "dedup_ratio": total_refs / len(self.entries) if self.entries else 1.0,
        }

    # -- spill / restore (SHKB blob; byte layout in docs/wire-format.md) - #
    def to_bytes(self) -> bytes:
        buf = bytearray()
        buf += _KB_MAGIC
        buf.append(_KB_VERSION)
        buf += struct.pack(
            "<ddB", self.config.eps_b, self.config.lam, self.config.beta_levels
        )
        write_varint(buf, len(self.entries))
        prev_idx_by_level: dict[int, int] = {}
        for e in self.entries:
            buf.append(e.level & 0xFF)
            prev = prev_idx_by_level.get(e.level, 0)
            _write_svarint(buf, e.origin_idx - prev)
            prev_idx_by_level[e.level] = e.origin_idx
            if e.slope_digits <= 13:
                buf.append(e.slope_digits)
                _write_svarint(buf, int(round(e.slope * 10**e.slope_digits)))
            else:
                buf.append(_RAW_SLOPE)
                buf += struct.pack("<d", e.slope)
            write_varint(buf, e.refs)
        return bytes(buf)

    @classmethod
    def from_bytes(cls, data: bytes) -> "KnowledgeBase":
        data = bytes(data)
        if len(data) < 5 or data[:4] != _KB_MAGIC:
            raise FormatError("bad knowledge-base magic")
        if data[4] != _KB_VERSION:
            raise FormatError(f"unsupported knowledge-base version {data[4]}")
        try:
            eps_b, lam, beta_levels = struct.unpack_from("<ddB", data, 5)
            pos = 5 + 17
            kb = cls(ShrinkConfig(eps_b=eps_b, lam=lam, beta_levels=beta_levels))
            n, pos = read_varint(data, pos)
            prev_idx_by_level: dict[int, int] = {}
            for _ in range(n):
                level = data[pos]
                pos += 1
                didx, pos = _read_svarint(data, pos)
                oidx = prev_idx_by_level.get(level, 0) + didx
                prev_idx_by_level[level] = oidx
                digits = data[pos]
                pos += 1
                if digits == _RAW_SLOPE:
                    (slope,) = struct.unpack_from("<d", data, pos)
                    pos += 8
                else:
                    scaled, pos = _read_svarint(data, pos)
                    slope = scaled / 10**digits
                refs, pos = read_varint(data, pos)
                # Append positionally: entry i of the blob MUST become entry
                # id i, because frames resolve refs against positional ids
                # (kb_epoch).  A duplicate line would silently collapse via
                # _find_or_add and shift every later id — reject it instead.
                key = (level, oidx) + _slope_key(slope, int(digits))
                if key in kb._index:
                    raise FormatError(
                        f"duplicate knowledge-base line at entry {len(kb.entries)} "
                        f"(same line as entry {kb._index[key]}); no writer "
                        "produces duplicates — positional entry ids would shift"
                    )
                kb._index[key] = len(kb.entries)
                kb.entries.append(
                    KBEntry(
                        level=level,
                        origin_idx=oidx,
                        slope=slope,
                        slope_digits=int(digits),
                        refs=refs,
                    )
                )
        except ShrinkError:
            raise
        except (IndexError, struct.error) as e:
            raise TruncatedArchiveError(
                f"truncated or corrupt knowledge-base blob: {e}"
            ) from e
        if pos != len(data):
            raise FormatError(
                f"trailing garbage after knowledge-base entries "
                f"({len(data) - pos} byte(s) past entry {n - 1 if n else 'header'})"
            )
        return kb


# --------------------------------------------------------------------- #
# Per-series incremental scan state
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class _SeriesState:
    start: int = 0  # absolute sample index of the current frame's first sample
    buf: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(1024, dtype=np.float64)
    )
    n_buf: int = 0
    # incremental cone-scan state (frame-relative indices)
    scan_pos: int = 0
    cone_open: bool = False
    t0: int = 0
    theta: float = 0.0
    level: int = 0
    eps_hat: float = 0.0
    psi_lo: float = -_INF
    psi_hi: float = _INF
    chunk: int = 256
    segments: list[Segment] = dataclasses.field(default_factory=list)
    total_ingested: int = 0

    def append(self, vals: np.ndarray) -> None:
        need = self.n_buf + vals.size
        if need > self.buf.size:
            cap = max(self.buf.size * 2, need)
            grown = np.empty(cap, dtype=np.float64)
            grown[: self.n_buf] = self.buf[: self.n_buf]
            self.buf = grown
        self.buf[self.n_buf : need] = vals
        self.n_buf = need
        self.total_ingested += int(vals.size)

    def drop_prefix(self, n: int) -> None:
        keep = self.n_buf - n
        fresh = np.empty(max(1024, keep), dtype=np.float64)
        fresh[:keep] = self.buf[n : self.n_buf]
        self.buf = fresh
        self.n_buf = keep
        self.start += n
        self.scan_pos = 0
        self.cone_open = False
        self.segments = []
        self.chunk = 256


# --------------------------------------------------------------------- #
# The streaming codec
# --------------------------------------------------------------------- #
class ShrinkStreamCodec:
    """Chunk-at-a-time SHRINK compression with a shared knowledge base.

    Parameters
    ----------
    config:       the ShrinkConfig shared by all series on this gateway.
    eps_targets:  residual resolutions encoded per frame (0.0 = lossless,
                  requires ``decimals``).
    value_range:  (vmin, vmax) spec of the sensors; pins delta_global so
                  the cone scan can run incrementally (and makes output
                  independent of chunking by construction).  None defers
                  the scan to frame seal with frame-local range.
    frame_len:    samples per frame.  A frame seals (base construction,
                  residual encode, KB ingest) when full; ``None`` means
                  one frame per flush — max CR, no intra-series random
                  access granularity.
    n_hint:       pins the interval length L (Alg. 2); defaults to
                  ``frame_len``.  Both unset forces the deferred scan.
    kb:           share a KnowledgeBase across codecs; default fresh.
    kb_store:     a ``serving.kbstore.KBStore`` to attach the finalized
                  container's KB to.  The container footer then carries a
                  ``kb_snapshot_ref`` into the store, and — unless
                  ``inline_kb=True`` — omits the inline KB entirely (the
                  cross-archive dedup win).
    inline_kb:    force the inline footer KB on (self-contained fallback
                  alongside the ref) or off; default ``None`` = inline
                  exactly when no ``kb_store`` is attached.
    source:       stable attach handle for ``kb_store`` (defaults to a
                  store-assigned handle).

    ``ingest`` returns the frames sealed during the call (as
    ``(series_id, t_lo, t_hi)`` tuples); ``flush`` seals partial frames;
    ``finalize`` emits the SHRKS container.
    """

    def __init__(
        self,
        config: ShrinkConfig,
        eps_targets: list[float],
        decimals: int | None = None,
        backend: str = "best",
        value_range: tuple[float, float] | None = None,
        frame_len: int | None = None,
        n_hint: int | None = None,
        kb: KnowledgeBase | None = None,
        kb_store=None,  # serving.kbstore.KBStore (duck-typed: core must not import serving)
        inline_kb: bool | None = None,
        source: str | None = None,
    ):
        if 0.0 in eps_targets and decimals is None:
            raise ConfigError("lossless eps target 0.0 requires `decimals`")
        if frame_len is not None and frame_len < 1:
            raise ConfigError(f"frame_len must be >= 1, got {frame_len}")
        if inline_kb is False and kb_store is None:
            raise ConfigError(
                "inline_kb=False requires a kb_store (a container with "
                "neither an inline KB nor a snapshot ref loses its dictionary)"
            )
        self.config = config
        self.eps_targets = list(eps_targets)
        self.decimals = decimals
        self.backend = backend
        self.value_range = (
            (float(value_range[0]), float(value_range[1])) if value_range else None
        )
        self.frame_len = frame_len
        self.n_hint = int(n_hint) if n_hint is not None else None
        self.kb = kb if kb is not None else KnowledgeBase(config)
        self.kb_store = kb_store
        self.inline_kb = inline_kb
        self._store_source = source
        self._store_handle: str | None = None
        n_for_l = self.n_hint if self.n_hint is not None else frame_len
        self.incremental = self.value_range is not None and n_for_l is not None
        if self.incremental:
            self._L = default_interval_length(int(n_for_l), config)
            self._delta = self.value_range[1] - self.value_range[0]
        self._series: dict[int, _SeriesState] = {}
        self._sealed: list[tuple[int, int, int, int, bytes]] = []
        # frames collected but not yet residual-encoded: encoding is
        # deferred until _PENDING_ENCODE_SAMPLES accumulate (or a flush),
        # so frames completed by *different* ingest calls still share one
        # fused residual+entropy batch instead of paying a device/pipeline
        # round-trip per call
        self._pending: list[tuple[int, int, np.ndarray, Base, int, int]] = []
        self._pending_n = 0
        # running per-backend routing tally of every sealed layer payload
        self._backend_stats: dict[str, dict[str, int]] = {}

    # -- ingest -------------------------------------------------------- #
    def ingest(self, values_chunk, series_id: int = 0) -> list[tuple[int, int, int]]:
        """Feed the next chunk of one series; returns frames sealed now."""
        vals = np.asarray(values_chunk, dtype=np.float64).ravel()
        st = self._series.setdefault(int(series_id), _SeriesState())
        if vals.size:
            st.append(vals)
        sealed = []
        if self.frame_len is not None:
            while st.n_buf >= self.frame_len:
                if self.incremental:
                    self._advance(st, avail=self.frame_len, final=True)
                p = self._collect(int(series_id), st, self.frame_len)
                self._pending.append(p)
                self._pending_n += p[2].size
                sealed.append((p[1], p[4], p[5]))
            if self._pending_n >= _PENDING_ENCODE_SAMPLES:
                self._drain_pending()  # amortize dispatch across ingest calls
        if self.incremental and st.n_buf:
            self._advance(st, avail=st.n_buf, final=False)
        return sealed

    def flush(self, series_id: int | None = None) -> list[tuple[int, int, int]]:
        """Seal the open (partial) frame of one series, or of all series.
        Flushing also drains every deferred frame payload, so ``_sealed``
        is fully materialized afterwards."""
        sids = [series_id] if series_id is not None else sorted(self._series)
        sealed = []
        for sid in sids:
            st = self._series.get(sid)
            if st is None or st.n_buf == 0:
                continue
            if self.incremental:
                self._advance(st, avail=st.n_buf, final=True)
            p = self._collect(sid, st, st.n_buf)
            self._pending.append(p)
            self._pending_n += p[2].size
            sealed.append((p[1], p[4], p[5]))
        self._drain_pending()
        return sealed

    def finalize(self) -> bytes:
        """Flush everything and emit the SHRKS framed container (frames in
        seal order, knowledge base in the footer).  With a ``kb_store``
        attached, the KB is attached to the store instead and the footer
        carries a ``kb_snapshot_ref`` (plus the inline KB only when
        ``inline_kb=True``); the finished container is registered with the
        store for compaction re-basing."""
        self.flush()
        w = FramedWriter()
        for sid, t_lo, t_hi, epoch, payload in self._sealed:
            w.add_frame(sid, t_lo, t_hi, epoch, payload)
        ref = None
        if self.kb_store is not None:
            # a stable handle makes re-finalize a replace, not a double-count
            rec = self.kb_store.attach_kb(
                self.kb, source=self._store_handle or self._store_source
            )
            self._store_handle = rec.handle
            ref = rec.ref
        inline = self.inline_kb if self.inline_kb is not None else self.kb_store is None
        blob = w.finish(self.kb.to_bytes() if inline else b"", snapshot_ref=ref)
        if self.kb_store is not None:
            self.kb_store.register_container(self._store_handle, blob)
        return blob

    @property
    def sealed_frames(self) -> list[tuple[int, int, int, int]]:
        """(series_id, t_lo, t_hi, kb_epoch) of every sealed frame so far."""
        return [(sid, lo, hi, ep) for sid, lo, hi, ep, _ in self._sealed]

    def stats(self) -> dict:
        self._drain_pending()  # payload_bytes counts encoded frames only
        payload_bytes = sum(len(p) for *_, p in self._sealed)
        ingested = sum(st.total_ingested for st in self._series.values())
        return {
            "series": len(self._series),
            "frames": len(self._sealed),
            "samples_ingested": ingested,
            "samples_sealed": sum(hi - lo for _, lo, hi, _, _ in self._sealed),
            "payload_bytes": payload_bytes,
            "backends": {b: dict(d) for b, d in self._backend_stats.items()},
            "kb": self.kb.stats(),
        }

    # -- incremental cone scan ----------------------------------------- #
    def _advance(self, st: _SeriesState, avail: int, final: bool) -> None:
        """Consume buffered samples [st.scan_pos, avail) of the current
        frame.  Mirrors ``semantics.extract_semantics`` op-for-op (same
        expressions, same prefix-min/max recurrence), so the closed
        segments are bit-identical to the one-shot scan of the frame slice
        regardless of how ingest chunked the data.  ``final`` means
        ``avail`` is the frame end: the open cone is closed there and
        division windows truncate there, exactly like a series end."""
        L = self._L
        maxw = max(L, 2)
        cap = self.frame_len
        buf = st.buf
        while True:
            if not st.cone_open:
                j = st.scan_pos
                if j >= avail:
                    break
                wend = j + maxw if cap is None else min(j + maxw, cap)
                if wend > avail:
                    if not final:
                        break  # wait for look-ahead before opening the cone
                    wend = avail
                theta, level, eps_hat = divide(buf[:wend], j, L, self._delta, self.config)
                st.cone_open = True
                st.t0 = j
                st.theta, st.level, st.eps_hat = theta, level, eps_hat
                st.psi_lo, st.psi_hi = -_INF, _INF
                st.chunk = 256
                st.scan_pos = j + 1
            i, theta, eps_hat = st.t0, st.theta, st.eps_hat
            closed = False
            j = st.scan_pos
            while j < avail:
                end = min(avail, j + st.chunk)
                dt = np.arange(j - i, end - i, dtype=np.float64)
                seg_vals = buf[j:end]
                hi = (seg_vals + (eps_hat - theta)) / dt
                lo = (seg_vals - (eps_hat + theta)) / dt
                run_hi = np.minimum(np.minimum.accumulate(hi), st.psi_hi)
                run_lo = np.maximum(np.maximum.accumulate(lo), st.psi_lo)
                viol = run_lo > run_hi
                if viol.any():
                    idx = int(np.argmax(viol))
                    if idx > 0:
                        st.psi_hi = float(run_hi[idx - 1])
                        st.psi_lo = float(run_lo[idx - 1])
                    k = j + idx
                    st.segments.append(
                        Segment(
                            theta=theta, level=st.level, psi_lo=st.psi_lo,
                            psi_hi=st.psi_hi, t0=i, length=k - i,
                        )
                    )
                    st.cone_open = False
                    st.scan_pos = k
                    closed = True
                    break
                st.psi_hi = float(run_hi[-1])
                st.psi_lo = float(run_lo[-1])
                j = end
                st.chunk = min(st.chunk * 2, 65536)
            if closed:
                continue  # a new cone opens at the violation point
            st.scan_pos = avail
            if final and st.cone_open:
                st.segments.append(
                    Segment(
                        theta=theta, level=st.level, psi_lo=st.psi_lo,
                        psi_hi=st.psi_hi, t0=st.t0, length=avail - st.t0,
                    )
                )
                st.cone_open = False
            break

    # -- frame sealing ------------------------------------------------- #
    def _collect(
        self, series_id: int, st: _SeriesState, frame_n: int
    ) -> tuple[int, int, np.ndarray, Base, int, int]:
        """Close one frame: fix its semantics/base, advance the knowledge
        base (epoch order is collect order), reserve its slot in the sealed
        log, and leave the residual-encoding work to ``_drain_pending``."""
        frame_vals = st.buf[:frame_n].copy()
        if self.incremental:
            segments = st.segments
            vmin, vmax = self.value_range
        else:
            segments = extract_semantics(
                frame_vals, self.config, value_range=self.value_range, n_hint=self.n_hint
            )
            if self.value_range is not None:
                vmin, vmax = self.value_range
            else:
                vmin, vmax = global_range(frame_vals)
        base = construct_base(segments, frame_n, float(vmin), float(vmax), self.config)
        self.kb.ingest_base(base)
        t_lo, t_hi = st.start, st.start + frame_n
        slot = len(self._sealed)
        self._sealed.append((series_id, t_lo, t_hi, self.kb.epoch, b""))
        st.drop_prefix(frame_n)
        return (slot, series_id, frame_vals, base, t_lo, t_hi)

    def _drain_pending(self) -> None:
        """Residual-encode every deferred frame and fill its reserved
        payload slot.  Equal-length frames (the common case: full frames
        collected across ingest calls) share one fused batch pass; odd
        sizes (partial flush frames) encode singly.  The batched path
        produces bytes identical to the per-frame one."""
        pending, self._pending = self._pending, []
        self._pending_n = 0
        if not pending:
            return
        by_size: dict[int, list[tuple[int, int, np.ndarray, Base, int, int]]] = {}
        for p in pending:
            by_size.setdefault(p[2].size, []).append(p)
        for group in by_size.values():
            if len(group) == 1:
                _, _, frame_vals, base, _, _ = group[0]
                cs_list = [
                    encode_with_base(
                        frame_vals, base, self.eps_targets, self.decimals,
                        backend=self.backend,
                    )
                ]
            else:
                cs_list = encode_frames_with_bases(
                    np.stack([p[2] for p in group]),
                    [p[3] for p in group],
                    self.eps_targets,
                    self.decimals,
                    backend=self.backend,
                )
            for (slot, _sid, _vals, _base, _lo, _hi), cs in zip(group, cs_list):
                merge_backend_stats(self._backend_stats, cs.backend_stats())
                sid, lo, hi, epoch, _ = self._sealed[slot]
                self._sealed[slot] = (sid, lo, hi, epoch, cs_to_bytes(cs))


# --------------------------------------------------------------------- #
# Random-access decode
# --------------------------------------------------------------------- #
def _series_frames(blob: bytes, series_id: int) -> list[FrameMeta]:
    metas, _ = parse_framed_container(blob)
    frames = sorted(
        (m for m in metas if m.series_id == series_id), key=lambda m: m.t_lo
    )
    if not frames:
        raise UnknownSeriesError(
            f"no frames for series {series_id} in container", series_id=series_id
        )
    return frames


def decode_range(
    blob: bytes, series_id: int, t0: int, t1: int, eps: float
) -> np.ndarray:
    """Reconstruct samples [t0, t1) of one series at resolution ``eps``,
    decoding (and CRC-checking) only the frames that overlap the range.
    Identical to ``decode_series(blob, series_id, eps)[t0:t1]``."""
    return _decode_range_frames(blob, _series_frames(blob, series_id), series_id, t0, t1, eps)


def _decode_range_frames(
    blob: bytes, frames: list[FrameMeta], series_id: int, t0: int, t1: int, eps: float
) -> np.ndarray:
    if t1 <= t0:
        raise RangeCoverageError(f"empty range [{t0}, {t1})", series_id=series_id)
    touched = [m for m in frames if m.t_lo < t1 and m.t_hi > t0]
    if not touched or touched[0].t_lo > t0 or touched[-1].t_hi < t1:
        raise RangeCoverageError(
            f"range [{t0}, {t1}) not covered by series {series_id} frames "
            f"[{frames[0].t_lo}, {frames[-1].t_hi})",
            series_id=series_id,
        )
    out = np.empty(t1 - t0, dtype=np.float64)
    expected = t0
    for i, m in enumerate(touched):
        if m.t_lo > expected:
            raise RangeCoverageError(
                f"gap in series {series_id} frames at sample {expected} "
                f"(frame covering [{m.t_lo}, {m.t_hi}) follows)",
                series_id=series_id, frame_index=i,
            )
        cs = cs_from_bytes(frame_payload(blob, m))
        vals = decompress_at(cs, eps)
        lo, hi = max(t0, m.t_lo), min(t1, m.t_hi)
        out[lo - t0 : hi - t0] = vals[lo - m.t_lo : hi - m.t_lo]
        expected = hi
    return out


def decode_series(blob: bytes, series_id: int, eps: float) -> np.ndarray:
    """Full reconstruction of one series (all frames concatenated)."""
    frames = _series_frames(blob, series_id)
    return _decode_range_frames(
        blob, frames, series_id, frames[0].t_lo, frames[-1].t_hi, eps
    )


def read_knowledge_base(blob: bytes) -> KnowledgeBase | None:
    """The shared knowledge base spilled into the container footer, or
    ``None`` for containers written without one."""
    _, kb_bytes = parse_framed_container(blob)
    return KnowledgeBase.from_bytes(kb_bytes) if kb_bytes else None


def routing_metadata(blob: bytes) -> dict:
    """The routing-relevant view of a ``SHRKS`` container: which series it
    holds, every frame's KB epoch, and the ids of the KB snapshot riding
    in its footer.  The fleet router uses this to verify the decode
    invariant *before* placing a shard in service: every frame's
    ``kb_epoch`` must be <= the footer KB's entry count, i.e. the shipped
    snapshot already contains every line the frame references
    (``self_contained``).  A container whose KB lags its frames — e.g. a
    replica paired with a stale KB snapshot — is routable only against a
    newer snapshot with a matching ``kb_semantic_id`` lineage.  Ref-mode
    containers surface their ``kb_snapshot_ref`` under ``"kb_ref"``
    (``None`` otherwise); resolving it needs the KB store
    (``serving.kbstore.resolve_container_kb``)."""
    metas, kb_bytes = parse_framed_container(blob)
    ref = read_snapshot_ref(blob)
    kb = KnowledgeBase.from_bytes(kb_bytes) if kb_bytes else None
    max_epoch = max((m.kb_epoch for m in metas), default=0)
    return {
        "frames": [(m.series_id, m.t_lo, m.t_hi, m.kb_epoch) for m in metas],
        "series_ids": sorted({m.series_id for m in metas}),
        "kb_entries": kb.epoch if kb is not None else 0,
        "kb_snapshot_id": kb_snapshot_id(kb_bytes),
        "kb_semantic_id": kb.snapshot_id() if kb is not None else 0,
        "max_frame_epoch": max_epoch,
        "self_contained": kb is not None and max_epoch <= kb.epoch,
        "kb_ref": (
            {
                "version": ref.version,
                "entries": ref.entries,
                "sem_id": ref.sem_id,
                "n_remap": len(ref.remap),
            }
            if ref is not None
            else None
        ),
    }
