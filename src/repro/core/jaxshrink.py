"""On-device SHRINK for tensors (gradients, KV caches, checkpoint deltas).

This is the paper's two-phase decomposition restated for fixed-shape, jit
-compatible tensor data:

* **semantics/base**: a per-block linear model (theta + slope * t) over
  blocks of the flattened tensor.  Closed-form least squares replaces the
  shrinking cone — the cone's job in the paper is finding variable-length
  segments; on device we fix the block length (static shapes) and let the
  fit adapt instead.  Base parameters are stored in bf16 (the "truncated
  slope" of Alg. 5 re-expressed in binary: keep only the bits the span
  justifies).
* **residuals**: residual_quant Pallas kernel — quantize to b bits with
  per-block step, clip, and emit the error-feedback term (EF-SGD style) so
  repeated compression does not bias training.

Wire format per tensor: q int8[M, N] + (theta, slope, step) bf16[M, 1] each.
Compression ratio vs f32: 32 / (bits + 48/N)  (≈ 3.93x at N=256, b=8).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..kernels import dequant_reconstruct, residual_quant

__all__ = ["TensorCodecConfig", "CompressedTensor", "compress_tensor", "decompress_tensor", "linear_base_fit"]


@dataclasses.dataclass(frozen=True)
class TensorCodecConfig:
    block: int = 256  # SHRINK block length (lane-aligned multiple of 128)
    bits: int = 8  # residual quantization bits (int8 wire format)
    use_kernel: bool = True  # False -> pure-jnp ref path (differentiable)

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1


class CompressedTensor(NamedTuple):
    q: jax.Array  # int8/int16 [M, N]
    theta: jax.Array  # bf16 [M, 1]
    slope: jax.Array  # bf16 [M, 1]
    step: jax.Array  # f32  [M, 1]
    orig_len: int  # static
    shape: tuple  # static original shape

    def wire_bits(self) -> int:
        m = self.q.shape[0]
        per_elem = self.q.dtype.itemsize * 8
        return int(self.q.size * per_elem + m * (16 + 16 + 32))


def _blockify(x: jax.Array, block: int) -> tuple[jax.Array, int]:
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(-1, block), n


def linear_base_fit(xb: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-row least-squares line: returns (theta[M,1], slope[M,1])."""
    m, n = xb.shape
    t = jnp.arange(n, dtype=xb.dtype)
    t_mean = (n - 1) / 2.0
    tc = t - t_mean
    denom = jnp.sum(tc * tc)
    slope = (xb @ tc) / denom
    theta = jnp.mean(xb, axis=1) - slope * t_mean
    return theta[:, None], slope[:, None]


def compress_tensor(
    x: jax.Array,
    cfg: TensorCodecConfig = TensorCodecConfig(),
    step: jax.Array | None = None,
) -> tuple[CompressedTensor, jax.Array]:
    """Compress; returns (compressed, error_feedback_flat).

    ``step`` may be supplied externally (e.g. a psum-max across pods so all
    replicas quantize on the same grid); default is per-block max|r|/qmax.
    """
    xb, n = _blockify(x, cfg.block)
    theta, slope = linear_base_fit(xb)
    # bf16-truncate the base (Alg. 5's few-digit slope, binary radix)
    theta = theta.astype(jnp.bfloat16).astype(jnp.float32)
    slope = slope.astype(jnp.bfloat16).astype(jnp.float32)
    if step is None:
        t = jnp.arange(cfg.block, dtype=xb.dtype)
        r = xb - (theta + slope * t[None, :])
        step = jnp.max(jnp.abs(r), axis=1, keepdims=True) / cfg.qmax
    step = jnp.maximum(step, 1e-12)
    q, err = residual_quant(xb, theta, slope, step, qmax=cfg.qmax, force_ref=not cfg.use_kernel)
    wire_dtype = jnp.int8 if cfg.bits <= 8 else jnp.int16
    comp = CompressedTensor(
        q=q.astype(wire_dtype),
        theta=theta.astype(jnp.bfloat16),
        slope=slope.astype(jnp.bfloat16),
        step=step,
        orig_len=n,
        shape=tuple(x.shape),
    )
    err_flat = err.reshape(-1)[:n]
    return comp, err_flat


def decompress_tensor(comp: CompressedTensor, cfg: TensorCodecConfig = TensorCodecConfig()) -> jax.Array:
    xh = dequant_reconstruct(
        comp.q.astype(jnp.int32),
        comp.theta.astype(jnp.float32),
        comp.slope.astype(jnp.float32),
        comp.step,
        force_ref=not cfg.use_kernel,
    )
    return xh.reshape(-1)[: comp.orig_len].reshape(comp.shape)
