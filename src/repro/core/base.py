"""Knowledge-base construction (Alg. 4 of the paper).

Cones are grouped by their quantized origin (same adaptive-grid index and
the same fluctuation level -> identical float theta), ordered inside each
group by ascending psi_lo, and greedily merged while the spans intersect.
Sorting by the lower slope makes the greedy scan optimal (interval-graph
perfect elimination — the same argument as Sim-Piece [13], [19], [20]).

The merged sub-base keeps the *intersection* of the member spans, so any
line inside it approximates every member segment's points within that
segment's eps_hat.
"""
from __future__ import annotations

import math
from collections import defaultdict

import numpy as np

from .phases import eps_hat_for_level
from .slope import optimized_slope
from .types import Base, Segment, ShrinkConfig, SubBase

__all__ = [
    "construct_base",
    "base_predictions",
    "base_predictions_batch",
    "base_predictions_ragged",
    "origin_index",
    "practical_eps_b",
]


def origin_index(theta: float, level: int, config: ShrinkConfig) -> int:
    """Grid index of a quantized origin: theta == idx * eps_hat(level).

    This is the canonical identity of a cone origin — the serializer
    delta-codes it, Alg. 4 groups by it, and the streaming knowledge base
    dedups (level, idx, slope) line entries across frames and series.
    """
    return int(round(theta / eps_hat_for_level(level, config)))


def _origin_key(seg: Segment, config: ShrinkConfig) -> tuple[int, int]:
    return (seg.level, origin_index(seg.theta, seg.level, config))


def construct_base(
    segments: list[Segment],
    n: int,
    vmin: float,
    vmax: float,
    config: ShrinkConfig,
) -> Base:
    """Alg. 4: group by origin, sort by psi_lo, greedy merge intersections."""
    groups: dict[tuple[int, int], list[Segment]] = defaultdict(list)
    for seg in segments:
        groups[_origin_key(seg, config)].append(seg)

    subbases: list[SubBase] = []
    for key in sorted(groups.keys()):
        group = sorted(groups[key], key=lambda s: (s.psi_lo, s.psi_hi))
        cur_lo, cur_hi = -math.inf, math.inf
        cur_members: list[Segment] = []
        level, _ = key

        def _flush() -> None:
            if not cur_members:
                return
            slope, digits = optimized_slope(cur_lo, cur_hi)
            t0s = np.array([s.t0 for s in cur_members], dtype=np.int64)
            order = np.argsort(t0s)
            lengths = np.array([s.length for s in cur_members], dtype=np.int64)[order]
            subbases.append(
                SubBase(
                    theta=cur_members[0].theta,
                    level=level,
                    psi_lo=cur_lo,
                    psi_hi=cur_hi,
                    slope=slope,
                    slope_digits=digits,
                    t0s=t0s[order],
                    lengths=lengths,
                )
            )

        for seg in group:
            lo, hi = seg.psi_lo, seg.psi_hi
            new_lo = max(cur_lo, lo)
            new_hi = min(cur_hi, hi)
            if not cur_members or new_lo <= new_hi:
                cur_lo, cur_hi = new_lo, new_hi
                cur_members.append(seg)
            else:
                _flush()
                cur_lo, cur_hi, cur_members = lo, hi, [seg]
        _flush()

    # deterministic order: by first timestamp (helps delta-coding timestamps)
    subbases.sort(key=lambda sb: int(sb.t0s[0]))
    return Base(n=n, config=config, vmin=vmin, vmax=vmax, subbases=subbases)


def _flat_segments(
    base: Base,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """All member segments as parallel arrays sorted by t0 (the partition
    order): (t0s i64, lengths i64, thetas f64, slopes f64)."""
    sbs = base.subbases
    if not sbs:
        z = np.zeros(0, dtype=np.int64)
        return z, z, z.astype(np.float64), z.astype(np.float64)
    t0s = np.concatenate([sb.t0s for sb in sbs])
    lens = np.concatenate([sb.lengths for sb in sbs])
    thetas = np.concatenate([np.full(len(sb.t0s), sb.theta) for sb in sbs])
    slopes = np.concatenate([np.full(len(sb.t0s), sb.slope) for sb in sbs])
    order = np.argsort(t0s, kind="stable")  # t0s are unique: a partition
    return t0s[order], lens[order], thetas[order], slopes[order]


def base_predictions(base: Base) -> np.ndarray:
    """Vectorized reconstruction of the base-only approximation (n floats)."""
    n = base.n
    if n == 0:
        return np.zeros(0, dtype=np.float64)
    t0s, lens, thetas, slopes = _flat_segments(base)
    theta = np.repeat(thetas, lens)
    slope = np.repeat(slopes, lens)
    start = np.repeat(t0s.astype(np.float64), lens)
    t = np.arange(n, dtype=np.float64)
    return theta + slope * (t - start)


def base_predictions_batch(bases: list[Base]) -> np.ndarray:
    """``np.stack([base_predictions(b) for b in bases])`` in one repeat pass;
    all bases must share the same n."""
    s = len(bases)
    if s == 0:
        return np.zeros((0, 0), dtype=np.float64)
    n = bases[0].n
    if n == 0:
        return np.zeros((s, 0), dtype=np.float64)
    flats = [_flat_segments(b) for b in bases]
    lens = np.concatenate([f[1] for f in flats])
    theta = np.repeat(np.concatenate([f[2] for f in flats]), lens)
    slope = np.repeat(np.concatenate([f[3] for f in flats]), lens)
    start = np.repeat(np.concatenate([f[0] for f in flats]).astype(np.float64), lens)
    t = np.tile(np.arange(n, dtype=np.float64), s)
    return (theta + slope * (t - start)).reshape(s, n)


def base_predictions_ragged(bases: list[Base], pad_to: int) -> np.ndarray:
    """Ragged counterpart of ``base_predictions_batch``: bases may have any
    mix of lengths; returns [S, pad_to] with row i holding
    ``base_predictions(bases[i])`` in its first ``bases[i].n`` slots and
    0.0 beyond (one concatenated repeat pass, no per-series python loop)."""
    s = len(bases)
    out = np.zeros((s, pad_to), dtype=np.float64)
    if s == 0:
        return out
    ns = np.array([b.n for b in bases], dtype=np.int64)
    if ns.max(initial=0) > pad_to:
        raise ValueError(f"pad_to={pad_to} smaller than longest base n={ns.max()}")
    total = int(ns.sum())
    if total == 0:
        return out
    flats = [_flat_segments(b) for b in bases]
    lens = np.concatenate([f[1] for f in flats])
    theta = np.repeat(np.concatenate([f[2] for f in flats]), lens)
    slope = np.repeat(np.concatenate([f[3] for f in flats]), lens)
    start = np.repeat(np.concatenate([f[0] for f in flats]).astype(np.float64), lens)
    series_of = np.repeat(np.arange(s), ns)
    t_local = np.arange(total) - np.repeat(np.cumsum(ns) - ns, ns)
    out[series_of, t_local] = theta + slope * (t_local.astype(np.float64) - start)
    return out


def practical_eps_b(
    values: np.ndarray, base: Base, pred: np.ndarray | None = None
) -> float:
    """The paper's \\hat{eps}_b: realized max |v - base prediction|.
    ``pred`` lets callers that already materialized the reconstruction skip
    recomputing it."""
    if pred is None:
        pred = base_predictions(base)
    return float(np.max(np.abs(values - pred))) if base.n else 0.0
