"""Typed error taxonomy for the SHRINK storage and serving stack.

Every failure the codec, the containers, or the serving layer can raise
derives from :class:`ShrinkError`, which carries *machine-readable
context* — series id, frame index, byte offset, pyramid layer — so a
caller (or a fault-tolerant gateway) can scope its reaction to exactly
the corrupt unit instead of failing the whole query.  The taxonomy:

``ShrinkError`` (subclasses ``ValueError``)
├── ``FormatError``            foreign blob / bad magic / unsupported version
├── ``TruncatedArchiveError``  input cut short at any boundary
├── ``CorruptFrameError``      CRC mismatch or structural corruption
│   └── ``LayerCorruptError``  scoped to one pyramid layer (``layer=``)
├── ``UnknownSeriesError``     series id not present in a container
├── ``RangeCoverageError``     query range empty / not covered / gapped
├── ``ConfigError``            invalid construction parameters
├── ``BatcherFinalizedError``  use-after-finalize on an ingest batcher
├── ``KBReferenceError``       knowledge-base refcount/id accounting broken (``entry=``)
├── ``StaleSnapshotError``     kb_snapshot_ref does not resolve against the store
└── serving/operational
    ├── ``TransientError``     retryable (injected flake, timeout, I/O)
    ├── ``DeadlineExceededError``  per-request deadline blew
    ├── ``BackpressureError``  bounded queue full, request shed
    │   └── ``QuotaExceededError``  per-tenant admission quota exhausted
    └── ``CircuitOpenError``   per-frame breaker open, decode skipped

Deliberately ``ValueError`` at the root: the pre-taxonomy API contract
was "corrupt/foreign/truncated input raises ``ValueError``", and every
existing caller and test that catches ``ValueError`` keeps working;
callers that care about *which* failure catch the subclass.

Degradation semantics built on this taxonomy (what bound survives which
fault) are specified in ``docs/robustness.md``.
"""
from __future__ import annotations

__all__ = [
    "ShrinkError",
    "FormatError",
    "TruncatedArchiveError",
    "CorruptFrameError",
    "LayerCorruptError",
    "UnknownSeriesError",
    "RangeCoverageError",
    "ConfigError",
    "BatcherFinalizedError",
    "KBReferenceError",
    "StaleSnapshotError",
    "TransientError",
    "DeadlineExceededError",
    "BackpressureError",
    "QuotaExceededError",
    "CircuitOpenError",
]


class ShrinkError(ValueError):
    """Base of the taxonomy.  ``message`` is the human diagnosis; the
    keyword context names the corrupt/offending unit so handlers can
    quarantine precisely (all fields optional, ``None`` = not known at
    the raise site)."""

    def __init__(
        self,
        message: str,
        *,
        series_id: int | None = None,
        frame_index: int | None = None,
        offset: int | None = None,
        layer: int | None = None,
        entry: int | None = None,
    ):
        self.series_id = series_id
        self.frame_index = frame_index
        self.offset = offset
        self.layer = layer
        self.entry = entry
        ctx = []
        if series_id is not None:
            ctx.append(f"series={series_id}")
        if frame_index is not None:
            ctx.append(f"frame={frame_index}")
        if layer is not None:
            ctx.append(f"layer={layer}")
        if offset is not None:
            ctx.append(f"offset={offset}")
        if entry is not None:
            ctx.append(f"entry={entry}")
        super().__init__(message + (f" [{', '.join(ctx)}]" if ctx else ""))
        self.message = message

    def context(self) -> dict:
        """The machine-readable context as a plain dict (telemetry)."""
        return {
            "type": type(self).__name__,
            "series_id": self.series_id,
            "frame_index": self.frame_index,
            "offset": self.offset,
            "layer": self.layer,
            "entry": self.entry,
        }


class FormatError(ShrinkError):
    """Not one of ours: bad magic, unsupported version, or a field that
    no writer could have produced (foreign or misidentified input)."""


class TruncatedArchiveError(ShrinkError):
    """Input ends before a declared length/boundary — the archive (or a
    section of it) was cut short."""


class CorruptFrameError(ShrinkError):
    """Stored CRC does not match the bytes, or the structure contradicts
    itself: the unit (frame, container section, blob) cannot be trusted."""


class LayerCorruptError(CorruptFrameError):
    """Corruption scoped to ONE residual-pyramid layer (``layer=`` index).
    Layers above it remain decodable — degradation serves the finest
    intact prefix instead of failing the frame."""


class UnknownSeriesError(ShrinkError):
    """The container has no frames for the requested series id."""


class RangeCoverageError(ShrinkError):
    """The requested sample range is empty, outside the frames, or spans
    a gap between frames."""


class ConfigError(ShrinkError):
    """Invalid construction-time parameters (bad eps ladder, nonpositive
    sizes, missing ``decimals`` for a lossless tier, ...)."""


class BatcherFinalizedError(ShrinkError):
    """An ingest batcher was used after ``finalize()``."""


class KBReferenceError(ShrinkError):
    """Knowledge-base reference accounting is broken: a refcount would go
    negative, an entry id is out of range, or an attach handle is unknown.
    ``entry=`` names the offending KB entry id when one is known."""


class StaleSnapshotError(ShrinkError):
    """A ``kb_snapshot_ref`` does not resolve against the KB store: the
    snapshot version is unknown (evicted, compacted away, or from another
    store lineage), the semantic id disagrees, or a referenced entry id
    was retired.  Containers carrying an inline footer KB fall back to it;
    ref-only containers surface this error."""


# --------------------------------------------------------------------- #
# serving / operational
# --------------------------------------------------------------------- #
class TransientError(ShrinkError):
    """A retryable failure (flaky I/O, injected fault, timeout on a
    backend call).  The gateway's retry policy targets exactly this
    class — corruption errors are permanent and are never retried."""


class DeadlineExceededError(ShrinkError):
    """The request's deadline elapsed before a full-resolution answer
    could be produced."""


class BackpressureError(ShrinkError):
    """The bounded admission queue is full and the request could not be
    shed to degraded (coarse-tier) service."""


class QuotaExceededError(BackpressureError):
    """A tenant's admission quota (token bucket) is exhausted and the
    request could not be shed to a coarser tier.  Subclasses
    :class:`BackpressureError`: quota exhaustion IS backpressure, scoped
    to one tenant instead of the whole gateway — handlers that shed or
    retry-later on backpressure keep working unchanged."""


class CircuitOpenError(ShrinkError):
    """The per-frame circuit breaker is open: this frame failed
    repeatedly and decode attempts are suppressed until the recovery
    window elapses."""
