"""Core datatypes for the SHRINK codec.

The paper (SHRINK, Sun/Karras/Zhang 2024) represents compressed data as a
triple (B, R, E*):

* ``B``  — the *knowledge base*: k merged sub-bases, each an origin ``theta``
           (quantized onto the adaptive grid of Eq. 5), a span
           ``(psi_lo, psi_hi)`` and the timestamps of the member segments.
* ``R``  — quantized residuals at one or more resolutions ``eps_r``.
* ``E*`` — error thresholds {eps, eps_b, eps_r}.

Everything here is a plain dataclass so both the numpy reference codec and
the JAX on-device path can share the vocabulary.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .errors import ConfigError

# Multiplier grid for the adaptive threshold (Eq. 4).  beta is quantized to
# ``beta_levels`` discrete levels so that cone origins land on a small family
# of grids and can collide/merge (Section III-C of the paper relies on shared
# origins; with a continuous beta the floats would almost never be equal).
DEFAULT_BETA_LEVELS = 16


@dataclasses.dataclass(frozen=True)
class ShrinkConfig:
    """Static configuration of the codec (E* of the paper, plus knobs).

    eps_b:        base (semantics-extraction) error threshold, *absolute*.
                  The paper sets it to 5%..15% of the global value range.
    lam:          the lambda hyper-parameter controlling the default interval
                  length  L = lam * n * eps_b  (Alg. 2 line 4).
    beta_levels:  number of discrete fluctuation levels (see above).
    min_interval: lower clamp for the interval length L.
    max_interval: upper clamp for the interval length L.
    """

    eps_b: float
    lam: float = 1e-5
    beta_levels: int = DEFAULT_BETA_LEVELS
    min_interval: int = 2
    max_interval: int = 65536

    def __post_init__(self) -> None:
        if self.eps_b <= 0:
            raise ValueError(f"eps_b must be positive, got {self.eps_b}")
        if self.lam <= 0:
            raise ValueError(f"lam must be positive, got {self.lam}")
        if self.beta_levels < 1:
            raise ValueError("beta_levels must be >= 1")


@dataclasses.dataclass
class Segment:
    """One shrinking cone emitted by semantics extraction (Alg. 3).

    theta:     quantized origin value (Eq. 5).
    level:     quantized fluctuation level index in [0, beta_levels]; the
               adaptive threshold is ``eps_hat = eps_b * exp(2/3 - level/beta_levels)``.
    psi_lo/hi: the span (slope interval) after the cone shrank over all its
               member points.  For a one-point segment the span is the whole
               real line (lo=-inf, hi=+inf).
    t0:        start index of the segment.
    length:    number of points covered.
    """

    theta: float
    level: int
    psi_lo: float
    psi_hi: float
    t0: int
    length: int


@dataclasses.dataclass
class SubBase:
    """A merged group of cones sharing an origin (Alg. 4) + candidate line.

    slope is the paper's Alg. 5 "optimized slope": the shortest-decimal
    number inside [psi_lo, psi_hi] (see slope.py for why we deviate slightly
    from the literal pseudocode).
    """

    theta: float
    level: int
    psi_lo: float
    psi_hi: float
    slope: float
    slope_digits: int
    # Parallel arrays: start index and length of every member segment.
    t0s: np.ndarray  # int64 [m]
    lengths: np.ndarray  # int64 [m]


@dataclasses.dataclass
class Base:
    """The knowledge base B: all sub-bases + global stats needed to decode."""

    n: int
    config: ShrinkConfig
    vmin: float
    vmax: float
    subbases: list[SubBase]

    @property
    def k(self) -> int:
        return len(self.subbases)

    def segment_count(self) -> int:
        return int(sum(len(sb.t0s) for sb in self.subbases))

    def predictions(self) -> np.ndarray:
        """Reconstruct the base-only approximation for all n points."""
        out = np.empty(self.n, dtype=np.float64)
        for sb in self.subbases:
            for t0, ln in zip(sb.t0s.tolist(), sb.lengths.tolist()):
                t = np.arange(ln, dtype=np.float64)
                out[t0 : t0 + ln] = sb.theta + sb.slope * t
        return out


@dataclasses.dataclass
class ResidualStream:
    """Quantized residuals at one resolution.

    mode 'midpoint': q = floor((r - r_lo)/step), dequant at (q+0.5)*step+r_lo,
                     max abs error step/2.
    mode 'exact':    integer-exact path for lossless reconstruction of data
                     with a fixed number of decimals (step = 10^-decimals).
    """

    eps_r: float
    step: float
    r_lo: float
    mode: str  # 'midpoint' | 'exact'
    q: np.ndarray  # int64 [n]


@dataclasses.dataclass(frozen=True)
class FrameMeta:
    """Directory entry of one frame in a ``SHRKS`` framed stream container.

    A frame covers the contiguous sample range [t_lo, t_hi) of one series;
    its payload (a complete one-shot ``SHRK`` blob for that slice) lives at
    [offset, offset+length) in the container.  ``kb_epoch`` is the shared
    knowledge base's entry count when the frame sealed, so a reader can
    tell which semantic lines were already known to the gateway at write
    time (the segment-indexed layout direct-analytics consumers rely on).
    """

    series_id: int
    t_lo: int
    t_hi: int
    kb_epoch: int
    offset: int
    length: int
    crc32: int


@dataclasses.dataclass
class PyramidLayer:
    """One refinement layer of a :class:`ResidualPyramid`.

    Layer k quantizes the reconstruction error of the prefix through layer
    k-1 (layer 0 refines the bare base), so decoding at tier k is
    ``base + Σ dequant(layers[0..k])`` and the whole archive stores each
    bit of residual information once instead of once per tier.

    mode 'midpoint': lossy refinement, |prefix error| <= eps after this
                     layer (step = 2*eps, dequant at bin midpoints).
    mode 'exact':    terminal lossless refinement in the integer domain at
                     scale 1/step = 10^decimals (eps == 0.0).
    mode 'identity': the previous prefix already meets this tier's eps —
                     the tier exists in the directory but carries no bytes.

    ``corrupt`` marks a layer whose stored payload failed its CRC during a
    tolerant (``strict=False``) decode: the payload is withheld and every
    finer tier below it is unreachable, but the intact prefix above is
    still fully served (see ``docs/robustness.md``).
    """

    eps: float
    mode: str  # 'midpoint' | 'exact' | 'identity'
    step: float  # 0.0 for identity layers
    r_lo: float  # midpoint bin origin; 0.0 for exact/identity layers
    payload: Optional[bytes]  # tagged entropy blob; None iff mode == 'identity'
    corrupt: bool = False  # payload failed its CRC in a tolerant decode

    def nbytes(self) -> int:
        return len(self.payload) if self.payload is not None else 0

    def backend(self) -> Optional[str]:
        """Entropy backend that encoded this layer's payload (read off the
        stream's leading tag byte), or None for identity/corrupt layers."""
        if self.payload is None or not len(self.payload):
            return None
        from .entropy import backend_name  # lazy: keep types dependency-light

        return backend_name(self.payload[0])


@dataclasses.dataclass
class ResidualPyramid:
    """Layered refinement pyramid: tiers coarse -> fine, eps strictly
    decreasing, an optional lossless (eps == 0.0) layer last.  Replaces the
    flat per-eps dict of independent streams: a tier is decoded by summing
    the layer prefix 0..k, and finer tiers only pay for the *delta* below
    the previous tier's guarantee."""

    layers: list[PyramidLayer]

    def tiers(self) -> list[float]:
        return [layer.eps for layer in self.layers]

    def resolve(self, eps: float, eps_b_practical: float) -> int:
        """Index of the cheapest layer prefix whose guarantee is <= ``eps``
        (-1 = the bare base suffices).  Any requested eps between tiers
        resolves to the nearest finer tier; raises :class:`ConfigError`
        only when no tier (nor the base) qualifies."""
        if eps < 0.0:
            raise ConfigError(f"eps must be >= 0, got {eps}")
        if eps >= eps_b_practical:
            return -1
        for k, layer in enumerate(self.layers):
            if layer.eps <= eps:
                return k
        raise ConfigError(
            f"no tier with guarantee <= {eps!r}: archive tiers are "
            f"{self.tiers()} (base-only above {eps_b_practical!r})"
        )

    def prefix_nbytes(self, k: int) -> int:
        """Payload bytes needed to decode at layer k (-1 = base only)."""
        return sum(layer.nbytes() for layer in self.layers[: k + 1])

    def nbytes(self) -> int:
        return self.prefix_nbytes(len(self.layers) - 1)

    def backend_stats(self) -> dict[str, dict[str, int]]:
        """Per-backend ``{"streams": count, "bytes": payload bytes}`` over
        this pyramid's layers — how the adaptive dispatcher routed them."""
        out: dict[str, dict[str, int]] = {}
        for layer in self.layers:
            b = layer.backend()
            if b is None:
                continue
            d = out.setdefault(b, {"streams": 0, "bytes": 0})
            d["streams"] += 1
            d["bytes"] += layer.nbytes()
        return out


@dataclasses.dataclass
class CompressedSeries:
    """A fully encoded series: one base + a residual refinement pyramid."""

    base: Base
    base_bytes: bytes
    pyramid: ResidualPyramid
    # Practical base error threshold (max |v - base prediction|); eps values
    # above this are served base-only, exactly as Alg. 1 lines 8-10.
    eps_b_practical: float

    def tiers(self) -> list[float]:
        return self.pyramid.tiers()

    def size_at(self, eps: float) -> int:
        """Bytes needed to decode at resolution ``eps``: base + the cheapest
        sufficient layer prefix."""
        k = self.pyramid.resolve(eps, self.eps_b_practical)
        return len(self.base_bytes) + self.pyramid.prefix_nbytes(k)

    def total_nbytes(self) -> int:
        return len(self.base_bytes) + self.pyramid.nbytes()

    def backend_stats(self) -> dict[str, dict[str, int]]:
        """Per-backend stream/byte counts of this series' residual layers."""
        return self.pyramid.backend_stats()


def merge_backend_stats(
    acc: dict[str, dict[str, int]], more: dict[str, dict[str, int]]
) -> dict[str, dict[str, int]]:
    """Accumulate one ``backend_stats()`` result into ``acc`` (in place and
    returned) — the running per-backend routing tally the streaming codec,
    the ragged batcher, and the fleet surface in their ``stats()``."""
    for b, d in more.items():
        a = acc.setdefault(b, {"streams": 0, "bytes": 0})
        a["streams"] += d["streams"]
        a["bytes"] += d["bytes"]
    return acc
