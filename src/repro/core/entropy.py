"""Entropy-coding backends for SHRINK residual streams.

The paper uses Turbo Range Coder (an arithmetic coder).  This module provides:

* ``RangeEncoder`` / ``RangeDecoder`` — a carry-less (Subbotin-style) range
  coder with 32-bit state, byte renormalization.
* ``AdaptiveModel`` — order-0 adaptive frequency model over a bounded
  alphabet, Fenwick-tree cumulative frequencies (O(log A) per symbol).
* ``encode_ints`` / ``decode_ints`` — the production entry points used by the
  codec.  Residual integers are zigzag-mapped around their median and coded
  either with a single adaptive stream (small alphabets) or as split
  low-byte / high-part streams (large alphabets).  A ``zstd`` backend (stand
  -in for TRC's production speed) and a ``raw`` minimal-bit packer are also
  provided; ``backend='best'`` picks the smallest.

All backends are lossless on int64 inputs and round-trip tested.
"""
from __future__ import annotations

import struct

import numpy as np

try:  # optional fast backend
    import zstandard as _zstd
except Exception:  # pragma: no cover
    _zstd = None

__all__ = [
    "RangeEncoder",
    "RangeDecoder",
    "AdaptiveModel",
    "encode_ints",
    "decode_ints",
    "available_backends",
]

_MASK = 0xFFFFFFFF
_TOP = 1 << 24
_BOT = 1 << 16


class RangeEncoder:
    def __init__(self) -> None:
        self.low = 0
        self.rng = _MASK
        self.out = bytearray()

    def encode(self, cum_lo: int, freq: int, tot: int) -> None:
        r = self.rng // tot
        self.low = (self.low + r * cum_lo) & _MASK
        self.rng = r * freq
        low, rng, out = self.low, self.rng, self.out
        while True:
            if (low ^ (low + rng)) < _TOP:
                pass
            elif rng < _BOT:
                rng = (-low) & (_BOT - 1)
            else:
                break
            out.append((low >> 24) & 0xFF)
            low = (low << 8) & _MASK
            rng = (rng << 8) & _MASK
        self.low, self.rng = low, rng

    def finish(self) -> bytes:
        for _ in range(4):
            self.out.append((self.low >> 24) & 0xFF)
            self.low = (self.low << 8) & _MASK
        return bytes(self.out)


class RangeDecoder:
    def __init__(self, data: bytes) -> None:
        self.data = data
        self.pos = 4
        self.low = 0
        self.rng = _MASK
        code = 0
        for i in range(4):
            code = (code << 8) | (data[i] if i < len(data) else 0)
        self.code = code

    def decode_freq(self, tot: int) -> int:
        self._r = self.rng // tot
        v = (self.code - self.low) // self._r
        return min(v, tot - 1)

    def decode_update(self, cum_lo: int, freq: int, tot: int) -> None:
        r = self._r
        self.low = (self.low + r * cum_lo) & _MASK
        self.rng = r * freq
        low, rng, code = self.low, self.rng, self.code
        data, pos = self.data, self.pos
        while True:
            if (low ^ (low + rng)) < _TOP:
                pass
            elif rng < _BOT:
                rng = (-low) & (_BOT - 1)
            else:
                break
            nxt = data[pos] if pos < len(data) else 0
            pos += 1
            code = ((code << 8) | nxt) & _MASK
            low = (low << 8) & _MASK
            rng = (rng << 8) & _MASK
        self.low, self.rng, self.code, self.pos = low, rng, code, pos


class AdaptiveModel:
    """Order-0 adaptive model; Fenwick tree over symbol frequencies."""

    def __init__(self, nsym: int, inc: int = 24, max_total: int = 1 << 14) -> None:
        self.nsym = nsym
        self.inc = inc
        self.max_total = max_total
        self.freq = [1] * nsym
        self.total = nsym
        self.tree = [0] * (nsym + 1)
        for i in range(nsym):
            self._tree_add(i, 1)

    def _tree_add(self, i: int, delta: int) -> None:
        i += 1
        tree = self.tree
        while i <= self.nsym:
            tree[i] += delta
            i += i & (-i)

    def cum(self, i: int) -> int:
        """Sum of freq[0:i]."""
        s = 0
        tree = self.tree
        while i > 0:
            s += tree[i]
            i -= i & (-i)
        return s

    def find(self, target: int) -> int:
        """Largest i with cum(i) <= target; returns symbol index."""
        idx = 0
        bitmask = 1 << (self.nsym.bit_length())
        tree = self.tree
        rem = target
        while bitmask:
            nxt = idx + bitmask
            if nxt <= self.nsym and tree[nxt] <= rem:
                idx = nxt
                rem -= tree[nxt]
            bitmask >>= 1
        return idx  # freq[idx] spans [cum(idx), cum(idx)+freq[idx])

    def update(self, sym: int) -> None:
        self.freq[sym] += self.inc
        self.total += self.inc
        self._tree_add(sym, self.inc)
        if self.total > self.max_total:
            # halve all frequencies (keep >= 1), rebuild tree
            freq = self.freq
            tree = self.tree
            for i in range(len(tree)):
                tree[i] = 0
            tot = 0
            for i, f in enumerate(freq):
                nf = (f + 1) >> 1
                freq[i] = nf
                tot += nf
                self._tree_add(i, nf)
            self.total = tot

    def encode_symbol(self, enc: RangeEncoder, sym: int) -> None:
        cum_lo = self.cum(sym)
        enc.encode(cum_lo, self.freq[sym], self.total)
        self.update(sym)

    def decode_symbol(self, dec: RangeDecoder) -> int:
        target = dec.decode_freq(self.total)
        sym = self.find(target)
        cum_lo = self.cum(sym)
        dec.decode_update(cum_lo, self.freq[sym], self.total)
        self.update(sym)
        return sym


# ---------------------------------------------------------------------------
# integer-stream front end
# ---------------------------------------------------------------------------

def _zigzag(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.int64)
    return np.where(x >= 0, 2 * x, -2 * x - 1).astype(np.uint64)


def _unzigzag(z: np.ndarray) -> np.ndarray:
    z = z.astype(np.int64)
    return np.where(z % 2 == 0, z // 2, -(z + 1) // 2)


def _rc_encode_stream(symbols: np.ndarray, nsym: int) -> bytes:
    enc = RangeEncoder()
    model = AdaptiveModel(nsym)
    es = model.encode_symbol
    for s in symbols.tolist():
        es(enc, s)
    return enc.finish()


def _rc_decode_stream(data: bytes, count: int, nsym: int) -> np.ndarray:
    dec = RangeDecoder(data)
    model = AdaptiveModel(nsym)
    ds = model.decode_symbol
    out = np.empty(count, dtype=np.int64)
    for i in range(count):
        out[i] = ds(dec)
    return out


_SPLIT_ALPHABET = 4096  # above this, split into low-byte + high streams


def _rc_encode(q: np.ndarray) -> bytes:
    """Zigzag around the median, then byte-plane split until every adaptive
    stream's alphabet is <= _SPLIT_ALPHABET (keeps the Fenwick tree small
    even for pathological residual ranges)."""
    med = int(np.median(q)) if q.size else 0
    zz = _zigzag(q - med)
    zmax = int(zz.max()) if zz.size else 0
    planes: list[np.ndarray] = []
    while zmax >= _SPLIT_ALPHABET:
        planes.append((zz & np.uint64(0xFF)).astype(np.int64))
        zz = zz >> np.uint64(8)
        zmax >>= 8
    top = zz.astype(np.int64)
    header = struct.pack("<qQB", med, q.size, len(planes))
    parts = [header]
    for p in planes:
        blob = _rc_encode_stream(p, 256)
        parts.append(struct.pack("<Q", len(blob)))
        parts.append(blob)
    top_max = int(top.max()) if top.size else 0
    blob = _rc_encode_stream(top, top_max + 1)
    parts.append(struct.pack("<QQ", len(blob), top_max))
    parts.append(blob)
    return b"".join(parts)


def _rc_decode(data: bytes) -> np.ndarray:
    med, count, nplanes = struct.unpack_from("<qQB", data, 0)
    off = 17
    planes: list[np.ndarray] = []
    for _ in range(nplanes):
        (ln,) = struct.unpack_from("<Q", data, off)
        off += 8
        planes.append(_rc_decode_stream(data[off : off + ln], count, 256).astype(np.uint64))
        off += ln
    ln, top_max = struct.unpack_from("<QQ", data, off)
    off += 16
    top = _rc_decode_stream(data[off : off + ln], count, top_max + 1).astype(np.uint64)
    zz = top
    for p in reversed(planes):
        zz = (zz << np.uint64(8)) | p
    return _unzigzag(zz) + med


def _raw_encode(q: np.ndarray) -> bytes:
    """Minimal-width bit packing (no statistical modelling)."""
    lo = int(q.min()) if q.size else 0
    span = (int(q.max()) - lo + 1) if q.size else 1
    bits = max(1, int(span - 1).bit_length()) if span > 1 else 1
    vals = (q - lo).astype(np.uint64)
    header = struct.pack("<qQB", lo, q.size, bits)
    # pack with numpy: expand to bit matrix
    bitmat = ((vals[:, None] >> np.arange(bits, dtype=np.uint64)) & 1).astype(np.uint8)
    packed = np.packbits(bitmat.reshape(-1))
    return header + packed.tobytes()


def _raw_decode(data: bytes) -> np.ndarray:
    lo, count, bits = struct.unpack_from("<qQB", data, 0)
    off = 17
    packed = np.frombuffer(data, dtype=np.uint8, offset=off)
    bitvec = np.unpackbits(packed)[: count * bits]
    bitmat = bitvec.reshape(count, bits).astype(np.uint64)
    vals = (bitmat << np.arange(bits, dtype=np.uint64)).sum(axis=1)
    return vals.astype(np.int64) + lo


def _zstd_encode(q: np.ndarray, level: int = 19) -> bytes:
    assert _zstd is not None
    lo = int(q.min()) if q.size else 0
    span = (int(q.max()) - lo) if q.size else 0
    if span < (1 << 8):
        dt, code = np.uint8, 0
    elif span < (1 << 16):
        dt, code = np.uint16, 1
    elif span < (1 << 32):
        dt, code = np.uint32, 2
    else:
        dt, code = np.uint64, 3
    body = (q - lo).astype(dt).tobytes()
    comp = _zstd.ZstdCompressor(level=level).compress(body)
    return struct.pack("<qQB", lo, q.size, code) + comp


def _zstd_decode(data: bytes) -> np.ndarray:
    assert _zstd is not None
    lo, count, code = struct.unpack_from("<qQB", data, 0)
    dt = [np.uint8, np.uint16, np.uint32, np.uint64][code]
    body = _zstd.ZstdDecompressor().decompress(data[17:])
    return np.frombuffer(body, dtype=dt).astype(np.int64) + lo


_BACKENDS = {"rc": 0, "zstd": 1, "raw": 2}
_REV = {v: k for k, v in _BACKENDS.items()}


def available_backends() -> list[str]:
    out = ["rc", "raw"]
    if _zstd is not None:
        out.insert(1, "zstd")
    return out


def encode_ints(q: np.ndarray, backend: str = "best") -> bytes:
    """Losslessly encode an int64 array.  Returns tagged bytes."""
    q = np.ascontiguousarray(q, dtype=np.int64)
    if backend == "best":
        cands = []
        # rc is O(n) python — skip it for very large streams, zstd is close
        if q.size <= 300_000:
            cands.append("rc")
        if _zstd is not None:
            cands.append("zstd")
        cands.append("raw")
        blobs = [(len(b := _dispatch_encode(q, c)), c, b) for c in cands]
        _, c, b = min(blobs, key=lambda t: t[0])
        return bytes([_BACKENDS[c]]) + b
    return bytes([_BACKENDS[backend]]) + _dispatch_encode(q, backend)


def _dispatch_encode(q: np.ndarray, backend: str) -> bytes:
    if backend == "rc":
        return _rc_encode(q)
    if backend == "zstd":
        if _zstd is None:
            raise RuntimeError("zstandard not available")
        return _zstd_encode(q)
    if backend == "raw":
        return _raw_encode(q)
    raise ValueError(f"unknown backend {backend!r}")


def decode_ints(data: bytes) -> np.ndarray:
    tag = _REV[data[0]]
    body = data[1:]
    if tag == "rc":
        return _rc_decode(body)
    if tag == "zstd":
        return _zstd_decode(body)
    return _raw_decode(body)
