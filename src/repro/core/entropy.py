"""Entropy-coding backends for SHRINK residual streams.

The paper uses Turbo Range Coder (an arithmetic coder).  This module provides:

* ``RangeEncoder`` / ``RangeDecoder`` — a carry-less (Subbotin-style) range
  coder with 32-bit state, byte renormalization.
* ``AdaptiveModel`` — order-0 adaptive frequency model over a bounded
  alphabet, Fenwick-tree cumulative frequencies (O(log A) per symbol).
* ``encode_ints`` / ``decode_ints`` — the production entry points used by the
  codec.  Residual integers are zigzag-mapped around their median and coded
  either with a single adaptive stream (small alphabets) or as split
  low-byte / high-part streams (large alphabets).  A ``zstd`` backend (stand
  -in for TRC's production speed) and a ``raw`` minimal-bit packer are also
  provided; ``backend='best'`` picks the smallest.
* ``rans`` — interleaved static-frequency rANS over byte planes.  Encode and
  decode are O(n) numpy array ops: one histogram/table pass, then a
  vectorized symbol loop over K interleaved 32-bit states (16-bit
  renormalization, one conditional emission per symbol).  This is the fast
  production path; the adaptive range coder stays as the compatibility /
  compression-oracle path.
* ``bitpack`` — tight fixed-width packing at ``span.bit_length()`` bits per
  value (0 bits for constant streams).  No statistical modelling, so it is
  never larger than ``raw`` and runs at memcpy-ish speed — the fast exit for
  low-entropy tails and near-uniform planes where rANS tables don't pay.
* ``backend='best'`` — adaptive dispatch: a one-pass cost model
  (:func:`predict_backend_sizes`) predicts each backend's encoded size from
  byte-plane histograms, a run-length probe, and the max-magnitude bit
  width, and the stream goes to the predicted winner
  (:func:`choose_backend`).  ``exhaustive=True`` restores the old
  encode-with-everything-keep-smallest oracle.  Selection is encode-side
  only — the tag byte keeps decode self-describing, so a mispredict can
  only cost bytes, never correctness.

All backends are lossless on int64 inputs and round-trip tested.
"""
from __future__ import annotations

import os
import struct
import sys
import warnings

import numpy as np

from .errors import CorruptFrameError, FormatError, TruncatedArchiveError

try:  # optional fast backend
    import zstandard as _zstd
except Exception:  # pragma: no cover
    _zstd = None

__all__ = [
    "RangeEncoder",
    "RangeDecoder",
    "AdaptiveModel",
    "encode_ints",
    "decode_ints",
    "encode_ints_batch",
    "decode_ints_batch",
    "available_backends",
    "backend_name",
    "predict_backend_sizes",
    "choose_backend",
]

_MASK = 0xFFFFFFFF
_TOP = 1 << 24
_BOT = 1 << 16


class RangeEncoder:
    def __init__(self) -> None:
        self.low = 0
        self.rng = _MASK
        self.out = bytearray()

    def encode(self, cum_lo: int, freq: int, tot: int) -> None:
        r = self.rng // tot
        self.low = (self.low + r * cum_lo) & _MASK
        self.rng = r * freq
        low, rng, out = self.low, self.rng, self.out
        while True:
            if (low ^ (low + rng)) < _TOP:
                pass
            elif rng < _BOT:
                rng = (-low) & (_BOT - 1)
            else:
                break
            out.append((low >> 24) & 0xFF)
            low = (low << 8) & _MASK
            rng = (rng << 8) & _MASK
        self.low, self.rng = low, rng

    def finish(self) -> bytes:
        for _ in range(4):
            self.out.append((self.low >> 24) & 0xFF)
            self.low = (self.low << 8) & _MASK
        return bytes(self.out)


class RangeDecoder:
    def __init__(self, data: bytes) -> None:
        self.data = data
        self.pos = 4
        self.low = 0
        self.rng = _MASK
        code = 0
        for i in range(4):
            code = (code << 8) | (data[i] if i < len(data) else 0)
        self.code = code

    def decode_freq(self, tot: int) -> int:
        self._r = self.rng // tot
        v = (self.code - self.low) // self._r
        return min(v, tot - 1)

    def decode_update(self, cum_lo: int, freq: int, tot: int) -> None:
        r = self._r
        self.low = (self.low + r * cum_lo) & _MASK
        self.rng = r * freq
        low, rng, code = self.low, self.rng, self.code
        data, pos = self.data, self.pos
        while True:
            if (low ^ (low + rng)) < _TOP:
                pass
            elif rng < _BOT:
                rng = (-low) & (_BOT - 1)
            else:
                break
            nxt = data[pos] if pos < len(data) else 0
            pos += 1
            code = ((code << 8) | nxt) & _MASK
            low = (low << 8) & _MASK
            rng = (rng << 8) & _MASK
        self.low, self.rng, self.code, self.pos = low, rng, code, pos


class AdaptiveModel:
    """Order-0 adaptive model; Fenwick tree over symbol frequencies."""

    def __init__(self, nsym: int, inc: int = 24, max_total: int = 1 << 14) -> None:
        self.nsym = nsym
        self.inc = inc
        self.max_total = max_total
        self.freq = [1] * nsym
        self.total = nsym
        self.tree = [0] * (nsym + 1)
        for i in range(nsym):
            self._tree_add(i, 1)

    def _tree_add(self, i: int, delta: int) -> None:
        i += 1
        tree = self.tree
        while i <= self.nsym:
            tree[i] += delta
            i += i & (-i)

    def cum(self, i: int) -> int:
        """Sum of freq[0:i]."""
        s = 0
        tree = self.tree
        while i > 0:
            s += tree[i]
            i -= i & (-i)
        return s

    def find(self, target: int) -> int:
        """Largest i with cum(i) <= target; returns symbol index."""
        idx = 0
        bitmask = 1 << (self.nsym.bit_length())
        tree = self.tree
        rem = target
        while bitmask:
            nxt = idx + bitmask
            if nxt <= self.nsym and tree[nxt] <= rem:
                idx = nxt
                rem -= tree[nxt]
            bitmask >>= 1
        return idx  # freq[idx] spans [cum(idx), cum(idx)+freq[idx])

    def update(self, sym: int) -> None:
        self.freq[sym] += self.inc
        self.total += self.inc
        self._tree_add(sym, self.inc)
        if self.total > self.max_total:
            # halve all frequencies (keep >= 1), rebuild tree
            freq = self.freq
            tree = self.tree
            for i in range(len(tree)):
                tree[i] = 0
            tot = 0
            for i, f in enumerate(freq):
                nf = (f + 1) >> 1
                freq[i] = nf
                tot += nf
                self._tree_add(i, nf)
            self.total = tot

    def encode_symbol(self, enc: RangeEncoder, sym: int) -> None:
        cum_lo = self.cum(sym)
        enc.encode(cum_lo, self.freq[sym], self.total)
        self.update(sym)

    def decode_symbol(self, dec: RangeDecoder) -> int:
        target = dec.decode_freq(self.total)
        sym = self.find(target)
        cum_lo = self.cum(sym)
        dec.decode_update(cum_lo, self.freq[sym], self.total)
        self.update(sym)
        return sym


# ---------------------------------------------------------------------------
# integer-stream front end
# ---------------------------------------------------------------------------

def _zigzag(x: np.ndarray) -> np.ndarray:
    x = np.asarray(x, dtype=np.int64)
    # (x << 1) ^ (x >> 63): branch-free two's-complement zigzag, same values
    # as the where() formulation; the view is a free reinterpretation
    return ((x << 1) ^ (x >> 63)).view(np.uint64)


def _unzigzag(z: np.ndarray) -> np.ndarray:
    # inverse in uint64 space so full-range int64 values survive: the old
    # signed formulation wrapped for |x| >= 2^62
    z = np.asarray(z, dtype=np.uint64)
    half = (z >> np.uint64(1)).view(np.int64)
    sign = (z & np.uint64(1)).astype(np.int64)  # 0 or 1
    return half ^ -sign


def _rc_encode_stream(symbols: np.ndarray, nsym: int) -> bytes:
    enc = RangeEncoder()
    model = AdaptiveModel(nsym)
    es = model.encode_symbol
    for s in symbols.tolist():
        es(enc, s)
    return enc.finish()


def _rc_decode_stream(data: bytes, count: int, nsym: int) -> np.ndarray:
    dec = RangeDecoder(data)
    model = AdaptiveModel(nsym)
    ds = model.decode_symbol
    out = np.empty(count, dtype=np.int64)
    for i in range(count):
        out[i] = ds(dec)
    return out


_SPLIT_ALPHABET = 4096  # above this, split into low-byte + high streams


def _rc_encode(q: np.ndarray) -> bytes:
    """Zigzag around the median, then byte-plane split until every adaptive
    stream's alphabet is <= _SPLIT_ALPHABET (keeps the Fenwick tree small
    even for pathological residual ranges)."""
    med = int(np.median(q)) if q.size else 0
    zz = _zigzag(q - med)
    zmax = int(zz.max()) if zz.size else 0
    planes: list[np.ndarray] = []
    while zmax >= _SPLIT_ALPHABET:
        planes.append((zz & np.uint64(0xFF)).astype(np.int64))
        zz = zz >> np.uint64(8)
        zmax >>= 8
    top = zz.astype(np.int64)
    header = struct.pack("<qQB", med, q.size, len(planes))
    parts = [header]
    for p in planes:
        blob = _rc_encode_stream(p, 256)
        parts.append(struct.pack("<Q", len(blob)))
        parts.append(blob)
    top_max = int(top.max()) if top.size else 0
    blob = _rc_encode_stream(top, top_max + 1)
    parts.append(struct.pack("<QQ", len(blob), top_max))
    parts.append(blob)
    return b"".join(parts)


def _rc_decode(data: bytes) -> np.ndarray:
    med, count, nplanes = struct.unpack_from("<qQB", data, 0)
    off = 17
    planes: list[np.ndarray] = []
    for _ in range(nplanes):
        (ln,) = struct.unpack_from("<Q", data, off)
        off += 8
        planes.append(_rc_decode_stream(data[off : off + ln], count, 256).astype(np.uint64))
        off += ln
    ln, top_max = struct.unpack_from("<QQ", data, off)
    off += 16
    top = _rc_decode_stream(data[off : off + ln], count, top_max + 1).astype(np.uint64)
    zz = top
    for p in reversed(planes):
        zz = (zz << np.uint64(8)) | p
    return _unzigzag(zz) + med


# ---------------------------------------------------------------------------
# interleaved static-frequency rANS (vectorized)
# ---------------------------------------------------------------------------
#
# Classic 32-bit rANS with 16-bit renormalization: states live in
# I = [2^16, 2^32) and the frequency tables are normalized to M = 2^12, so a
# single conditional 16-bit emission per symbol keeps the invariant (the
# standard "at most one renorm" argument: before the state transform
# x < freq << 20, hence after it x < 2^32; after one 16-bit shift x < 2^16).
#
# K states are interleaved round-robin across the symbol stream: symbol i
# belongs to lane i % K at step i // K.  The decoder walks steps forward and,
# within a step, renormalizing lanes read words in increasing lane order; the
# encoder walks steps backward (rANS is LIFO) emitting the same words, and
# the stream is assembled in decoder order.  Every per-step operation is a
# width-K numpy vector op, so a 50k-symbol stream costs ~n/K interpreted
# iterations instead of n.

_RANS_PROB_BITS = 12
_RANS_M = 1 << _RANS_PROB_BITS
_RANS_L = 1 << 16
_RANS_K = 64  # interleaved states
# ragged batch: max dense scratch cells (steps x rows x K, ~5 B/cell) before
# the encoder splits rows into step-count groups to bound memory
_RANS_DENSE_CELLS = 16 << 20

# ------------------------------------------------------------------ #
# device engine gating.  kernels/rans.py runs the same step machines as
# one fused XLA/Pallas scan (lane axis = the K states) instead of ~n/K
# interpreted numpy dispatches; its wire bytes are identical, so routing
# is purely a perf decision:
#   SHRINK_RANS_DEVICE=0     never (numpy machine only)
#   SHRINK_RANS_DEVICE=1     always when importable (parity tests)
#   unset / auto             engage above a work threshold; only import
#                            jax (~1s) for jobs big enough to repay it
_RANS_DEVICE_MIN = 1 << 14        # symbols, when jax is already loaded
_RANS_DEVICE_MIN_COLD = 1 << 20   # symbols, when engaging means importing jax
# ragged mixes split into several padded group dispatches; on the CPU (xla)
# route those only beat the zero-waste dense-prefix numpy machine for jobs
# big enough to amortize the per-dispatch fixed cost (measured: ~780k plane
# symbols over 5 groups lose ~15% to the numpy machine on one core)
_RANS_DEVICE_RAGGED_MIN_XLA = 4 << 20
_rans_device_state: dict = {"mod": None, "broken": False}


def _rans_device(total_symbols: int):
    """The device rANS engine (``repro.kernels.rans``) for a job of
    ``total_symbols`` plane symbols, or ``None`` to run the numpy
    machine.  Any engine import failure (no jax in this environment)
    permanently falls back — the numpy coder is always available."""
    st = _rans_device_state
    if st["broken"]:
        return None
    mode = os.environ.get("SHRINK_RANS_DEVICE", "auto")
    if mode == "0":
        return None
    if mode != "1":
        thresh = (
            _RANS_DEVICE_MIN if "jax" in sys.modules else _RANS_DEVICE_MIN_COLD
        )
        if total_symbols < thresh:
            return None
    if st["mod"] is None:
        try:
            from repro.kernels import rans as kernel_rans
            st["mod"] = kernel_rans
        except Exception:
            st["broken"] = True
            return None
    return st["mod"]


def _rans_device_encode(eng, sym_mat: np.ndarray, freqs: np.ndarray):
    """``eng.encode_rows`` with the automatic-numpy-fallback contract:
    encode inputs are trusted, so an exception here is engine
    infrastructure trouble — warn once, quarantine the engine for the
    process, and let the caller run the numpy machine."""
    try:
        return eng.encode_rows(sym_mat, freqs)
    except Exception as e:
        _rans_device_state["broken"] = True
        warnings.warn(
            f"device rANS engine failed ({e!r}); falling back to the numpy "
            "coder for the rest of this process",
            RuntimeWarning,
            stacklevel=3,
        )
        return None


def _rans_plane_table(freqs: np.ndarray) -> bytes:
    """Wire bytes of one plane's frequency table: 32B presence bitmap +
    u16 freq per present symbol."""
    present = freqs > 0
    bitmap = np.packbits(present.astype(np.uint8), bitorder="little")
    return bitmap.tobytes() + freqs.astype("<u2")[present].tobytes()


def _rans_normalize_freqs(counts: np.ndarray) -> np.ndarray:
    """Scale histogram ``counts`` to sum exactly _RANS_M, keeping every
    present symbol's frequency >= 1.  Deterministic."""
    counts = counts.astype(np.int64)
    total = int(counts.sum())
    nz = counts > 0
    freqs = np.zeros_like(counts)
    if total == 0:
        return freqs
    freqs[nz] = np.maximum(1, np.rint(counts[nz] * (_RANS_M / total)).astype(np.int64))
    diff = _RANS_M - int(freqs.sum())
    if diff == 0:
        return freqs
    # distribute the rounding drift over the most frequent symbols (closed
    # form of the former round-robin loop, same output bytes):
    order = np.argsort(-counts, kind="stable")
    order = order[counts[order] > 0]
    if diff > 0:
        # +1 round-robin over `order`: everyone gets diff // len, the first
        # diff % len symbols one more
        add, rem = divmod(diff, order.size)
        freqs[order] += add
        freqs[order[:rem]] += 1
    else:
        # greedy steal in `order`: each donor gives at most freq - 1, so no
        # present symbol ever drops to 0.  A deficit means sum > M, which
        # guarantees total donor capacity covers it — assert the invariant
        # rather than silently under-stealing.
        caps = freqs[order] - 1
        cum = np.cumsum(caps)
        if int(cum[-1]) < -diff:
            raise AssertionError(
                "rANS freq normalization stalled: deficit exceeds donor "
                "capacity (histogram invariant violated)"
            )
        freqs[order] -= np.clip(-diff - (cum - caps), 0, caps)
    return freqs


def _rans_normalize_freqs_rows(counts: np.ndarray) -> np.ndarray:
    """Row-vectorized ``_rans_normalize_freqs``: normalize an [R, 256]
    histogram matrix in one pass, byte-identical per row to the scalar
    function.  The batched encoders call this once per row group instead
    of paying R python round-trips."""
    counts = counts.astype(np.int64)
    totals = counts.sum(axis=1)
    nz = counts > 0
    scale = _RANS_M / np.maximum(totals, 1).astype(np.float64)
    scaled = np.rint(counts * scale[:, None]).astype(np.int64)
    freqs = np.where(nz, np.maximum(1, scaled), 0)
    diff = _RANS_M - freqs.sum(axis=1)
    if not diff.any():
        return np.where(totals[:, None] > 0, freqs, 0)
    # ordered space: most-frequent first (stable), absent symbols last
    order = np.argsort(-counts, axis=1, kind="stable")
    freqs_ord = np.take_along_axis(freqs, order, axis=1)
    npres = nz.sum(axis=1)
    pos = np.arange(256)[None, :]
    present_pref = pos < npres[:, None]
    surplus = diff > 0
    deficit = diff < 0
    # surplus rows: +1 round-robin over the present prefix
    np1 = np.maximum(npres, 1)
    addv = np.where(surplus, diff // np1, 0)
    remv = np.where(surplus, diff % np1, 0)
    inc = present_pref * addv[:, None] + (pos < remv[:, None])
    # deficit rows: greedy steal, each donor gives at most freq - 1
    caps = np.where(present_pref, freqs_ord - 1, 0)
    cum = np.cumsum(caps, axis=1)
    need = np.where(deficit, -diff, 0)
    if (need > cum[:, -1]).any():
        raise AssertionError(
            "rANS freq normalization stalled: deficit exceeds donor "
            "capacity (histogram invariant violated)"
        )
    steal = np.clip(need[:, None] - (cum - caps), 0, caps)
    delta = np.where(surplus[:, None], inc, -steal)
    np.put_along_axis(freqs, order, freqs_ord + delta, axis=1)
    return np.where(totals[:, None] > 0, freqs, 0)


def _rans_encode_plane(sym: np.ndarray, freqs: np.ndarray, cums: np.ndarray, k: int) -> bytes:
    """Encode uint8/int64 symbols (< 256) with the given normalized tables.
    Returns states (K u32) + word count (u32) + words (u16 each)."""
    n = int(sym.size)
    steps = -(-n // k) if n else 0
    tail = n - (steps - 1) * k if steps else 0  # active lanes in last step
    f_of = freqs[sym].astype(np.uint64)
    c_of = cums[sym].astype(np.uint64)
    x = np.full(k, _RANS_L, dtype=np.uint64)
    chunks: list[np.ndarray] = []
    for t in range(steps - 1, -1, -1):
        a = tail if t == steps - 1 else k
        lo = t * k
        f = f_of[lo : lo + a]
        c = c_of[lo : lo + a]
        xa = x[:a]
        need = xa >= (f << np.uint64(32 - _RANS_PROB_BITS))
        if need.any():
            chunks.append((xa[need] & np.uint64(0xFFFF)).astype(np.uint16))
            xa = np.where(need, xa >> np.uint64(16), xa)
        x[:a] = ((xa // f) << np.uint64(_RANS_PROB_BITS)) + (xa % f) + c
    words = (
        np.concatenate(chunks[::-1]) if chunks else np.zeros(0, dtype=np.uint16)
    )
    out = bytearray()
    out += x.astype("<u4").tobytes()
    out += struct.pack("<I", words.size)
    out += words.astype("<u2").tobytes()
    return bytes(out)


def _rans_decode_plane(
    data: bytes, off: int, n: int, freqs: np.ndarray, cums: np.ndarray, k: int
) -> tuple[np.ndarray, int]:
    """Inverse of _rans_encode_plane; returns (symbols int64 [n], new off)."""
    x = np.frombuffer(data, dtype="<u4", count=k, offset=off).astype(np.uint64)
    off += 4 * k
    (nwords,) = struct.unpack_from("<I", data, off)
    off += 4
    words = np.frombuffer(data, dtype="<u2", count=nwords, offset=off).astype(np.uint64)
    off += 2 * nwords
    slot2sym = np.repeat(
        np.arange(freqs.size, dtype=np.int64), freqs.astype(np.int64)
    )
    f64 = freqs.astype(np.uint64)
    c64 = cums.astype(np.uint64)
    steps = -(-n // k) if n else 0
    tail = n - (steps - 1) * k if steps else 0
    out = np.empty(n, dtype=np.int64)
    pos = 0
    mask = np.uint64(_RANS_M - 1)
    for t in range(steps):
        a = tail if t == steps - 1 else k
        xa = x[:a]
        slot = xa & mask
        s = slot2sym[slot]
        out[t * k : t * k + a] = s
        xa = f64[s] * (xa >> np.uint64(_RANS_PROB_BITS)) + slot - c64[s]
        need = xa < _RANS_L
        cnt = int(need.sum())
        if cnt:
            w = np.zeros(a, dtype=np.uint64)
            w[need] = words[pos : pos + cnt]
            xa = np.where(need, (xa << np.uint64(16)) | w, xa)
            pos += cnt
        x[:a] = xa
    return out, off


def _rans_encode(q: np.ndarray) -> bytes:
    """Zigzag around the median, split into 8-bit planes, rANS-code each
    plane with its own static table.  Layout:

        i64 med, u64 count, u8 nplanes
        per plane: 32B presence bitmap, u16 freq per present symbol,
                   K u32 states, u32 nwords, u16 words
    """
    med = int(np.median(q)) if q.size else 0
    zz = _zigzag(q - med)
    zmax = int(zz.max()) if zz.size else 0
    nplanes = max(1, (zmax.bit_length() + 7) // 8)
    k = max(1, min(_RANS_K, q.size))  # fewer states -> less header on tiny streams
    parts = [struct.pack("<qQBB", med, q.size, nplanes, k)]
    eng = _rans_device(q.size * nplanes) if k == _RANS_K else None
    if eng is not None:
        # one fused device call over all planes (planes = machine rows)
        sym_mat = np.empty((nplanes, q.size), dtype=np.int32)
        freqs_mat = np.empty((nplanes, 256), dtype=np.int64)
        for p in range(nplanes):
            np.copyto(
                sym_mat[p], (zz >> np.uint64(8 * p)) & np.uint64(0xFF),
                casting="unsafe",
            )
            freqs_mat[p] = _rans_normalize_freqs(
                np.bincount(sym_mat[p], minlength=256)
            )
        res = _rans_device_encode(eng, sym_mat, freqs_mat)
        if res is not None:
            states, words_list = res
            for p in range(nplanes):
                words = words_list[p]
                parts.append(_rans_plane_table(freqs_mat[p]))
                parts.append(states[p].astype("<u4").tobytes())
                parts.append(struct.pack("<I", words.size))
                parts.append(words.astype("<u2").tobytes())
            return b"".join(parts)
    for p in range(nplanes):
        sym = ((zz >> np.uint64(8 * p)) & np.uint64(0xFF)).astype(np.int64)
        counts = np.bincount(sym, minlength=256)
        freqs = _rans_normalize_freqs(counts)
        cums = np.concatenate(([0], np.cumsum(freqs)[:-1]))
        parts.append(_rans_plane_table(freqs))
        parts.append(_rans_encode_plane(sym, freqs, cums, k))
    return b"".join(parts)


def _rans_encode_batch(qs: np.ndarray) -> list[bytes]:
    """Encode S equal-length int64 streams at once; returns one blob per
    row, each byte-identical to ``_rans_encode(qs[s])``.

    The per-step state updates for all S*K interleaved states run as single
    [S, K] array ops, so the interpreted symbol loop is shared by the whole
    batch; only the final word extraction and table normalization are
    per-series."""
    qs = np.ascontiguousarray(qs, dtype=np.int64)
    s_count, n = qs.shape
    med = np.median(qs, axis=1).astype(np.int64) if n else np.zeros(s_count, np.int64)
    zz = _zigzag(qs - med[:, None])
    zmax = zz.max(axis=1) if n else np.zeros(s_count, np.uint64)
    nplanes = np.array(
        [max(1, (int(z).bit_length() + 7) // 8) for z in zmax], dtype=np.int64
    )
    k = max(1, min(_RANS_K, n))
    steps = -(-n // k) if n else 0
    tail = n - (steps - 1) * k if steps else 0
    parts: list[list[bytes]] = [
        [struct.pack("<qQBB", int(med[i]), n, int(nplanes[i]), k)]
        for i in range(s_count)
    ]
    # Flatten every (series, plane) pair into one row of a single interleaved
    # state machine: the interpreted step loop then runs once for the whole
    # batch instead of once per plane.  Rows are plane-major so each series'
    # plane bodies are appended in ascending plane order.
    max_planes = int(nplanes.max()) if s_count else 0
    rows: list[tuple[int, int]] = []  # (series, plane)
    sym_blocks = []
    for p in range(max_planes):
        sel = np.flatnonzero(nplanes > p)
        rows.extend((int(s), p) for s in sel)
        zsel = zz if sel.size == s_count else zz[sel]
        plane = zsel if p == 0 else zsel >> np.uint64(8 * p)
        # int32 symbols: half the memory traffic of int64 through the
        # histogram and the device cube
        sym_blocks.append((plane & np.uint64(0xFF)).astype(np.int32))
    r_count = len(rows)
    if r_count == 0:
        return [b"".join(p) for p in parts]
    sym = np.concatenate(sym_blocks, axis=0) if max_planes > 1 else sym_blocks[0]
    offsets = np.arange(r_count, dtype=np.int32)[:, None] * 256
    flat_idx = sym + offsets
    counts = np.bincount(flat_idx.ravel(), minlength=256 * r_count).reshape(
        r_count, 256
    )
    freqs = _rans_normalize_freqs_rows(counts)
    words_list: list[np.ndarray] | None = None
    states32: np.ndarray | None = None
    eng = _rans_device(sym.size) if k == _RANS_K else None
    if eng is not None:
        res = _rans_device_encode(eng, sym, freqs)
        if res is not None:
            states_dev, words_list = res
            states32 = states_dev.astype("<u4")
    if words_list is None:
        cums = np.zeros_like(freqs)
        np.cumsum(freqs[:, :-1], axis=1, out=cums[:, 1:])
        # All loop state fits in uint32 (x < 2^32, freq <= 2^12): half the
        # memory traffic of a uint64 machine.  Lay the lookups out
        # [steps, R, k] so each step reads a contiguous block.
        def _per_step(table: np.ndarray) -> np.ndarray:
            flat = np.take(table.astype(np.uint32).ravel(), flat_idx)
            if n < steps * k:
                flat = np.pad(flat, ((0, 0), (0, steps * k - n)), constant_values=1)
            return np.ascontiguousarray(
                flat.reshape(r_count, steps, k).transpose(1, 0, 2)
            )

        f3 = _per_step(freqs)
        c3 = _per_step(cums)
        # renorm threshold minus one: x >= f << 20  <=>  x > (f << 20) - 1.
        # For f == 2^12 the shift wraps to 0 and the -1 to 0xFFFFFFFF, which
        # a uint32 state can never exceed — exactly the "never renormalize"
        # semantics the uint64 single-stream coder gets for a whole-table
        # symbol.
        f3_renorm_m1 = (f3 << np.uint32(32 - _RANS_PROB_BITS)) - np.uint32(1)
        sh16 = np.uint32(16)
        sh_prob = np.uint32(_RANS_PROB_BITS)
        x = np.full((r_count, k), _RANS_L, dtype=np.uint32)
        masks = np.zeros((steps, r_count, k), dtype=bool)
        vals = np.zeros((steps, r_count, k), dtype=np.uint16)
        for t in range(steps - 1, -1, -1):
            a = tail if t == steps - 1 else k
            f = f3[t, :, :a]
            xa = x[:, :a]
            need = xa > f3_renorm_m1[t, :, :a]
            masks[t, :, :a] = need
            np.copyto(vals[t, :, :a], xa, casting="unsafe")  # truncating low-16 store
            xa = np.where(need, xa >> sh16, xa)
            div, rem = np.divmod(xa, f)
            x[:, :a] = (div << sh_prob) + rem + c3[t, :, :a]
        # masks/vals are indexed by decode step already, so flat boolean
        # extraction yields decoder order per row: steps asc, lanes asc
        need_t = np.ascontiguousarray(masks.transpose(1, 0, 2))
        flat_w = np.ascontiguousarray(vals.transpose(1, 0, 2))[need_t]
        wcounts = need_t.reshape(r_count, -1).sum(axis=1)
        words_list = np.split(flat_w, np.cumsum(wcounts)[:-1])
        states32 = x.astype("<u4")
    freqs16 = freqs.astype("<u2")
    presents = freqs > 0
    bitmaps = np.packbits(presents, axis=1, bitorder="little")
    native_le = np.little_endian
    for i, (s, _p) in enumerate(rows):
        words = words_list[i]
        parts[s].append(bitmaps[i].tobytes())
        parts[s].append(freqs16[i][presents[i]].tobytes())
        parts[s].append(states32[i].tobytes())
        parts[s].append(struct.pack("<I", words.size))
        parts[s].append(words.tobytes() if native_le else words.astype("<u2").tobytes())
    return [b"".join(p) for p in parts]


def _rans_encode_batch_ragged(qs: list[np.ndarray]) -> list[bytes]:
    """Ragged companion to ``_rans_encode_batch``: one blob per stream, each
    byte-identical to ``_rans_encode(qs[i])``, for streams of ANY mix of
    lengths.

    Streams shorter than the full interleave width (n < K) use fewer rANS
    states (the scalar coder's small-stream header saving) and are encoded
    by the scalar path — they are tiny by definition.  The remaining
    (stream, plane) rows run through a shared state machine with no
    per-step masking:

    * rows are sorted by step count so each step operates on the dense
      prefix of still-active rows — total state-machine work is
      sum_r steps_r * K, no row pays for a longer row's symbols;
    * the scratch cube (symbols + renorm masks/words) is dense over
      [max_steps, rows, K]; when a skewed length mix would blow it past
      ``_RANS_DENSE_CELLS`` (one huge stream among many short ones), rows
      are split into power-of-two step-count groups, each padded only to
      its own longest row — memory then stays proportional to the REAL
      symbol total (within 2x) at the cost of one extra set of loop
      iterations, which only the pathological mixes pay;
    * padded lane positions carry the **identity symbol** (freq = M = 2^12,
      cum = 0): the rANS transform x -> (x//f << PROB) + x%f + c is then
      exactly x, and the renorm threshold (f << 20) - 1 wraps to the uint32
      max so no word is ever emitted — a padded lane is a true no-op, and
      the inner loop stays byte-for-byte the rectangular machine's."""
    out: list[bytes | None] = [None] * len(qs)
    big: list[int] = []
    for i, q in enumerate(qs):
        if q.size < _RANS_K:
            out[i] = _rans_encode(q)
        else:
            big.append(i)
    if not big:
        return out
    k = _RANS_K
    meds = {}
    zzs = {}
    npls = {}
    # equal-length streams (e.g. the pyramid layers of one series, or
    # same-length series in a batch) share one vectorized median/zigzag
    # pass — one partition per length group instead of one python
    # round-trip per stream
    by_len: dict[int, list[int]] = {}
    for i in big:
        by_len.setdefault(qs[i].size, []).append(i)
    for idxs in by_len.values():
        if len(idxs) == 1:
            i = idxs[0]
            med = int(np.median(qs[i]))
            zz = _zigzag(qs[i] - med)
            meds[i], zzs[i] = med, zz
            npls[i] = max(1, (int(zz.max()).bit_length() + 7) // 8)
        else:
            qstack = np.stack([qs[i] for i in idxs])
            gm = np.median(qstack, axis=1).astype(np.int64)
            zzm = _zigzag(qstack - gm[:, None])
            zmaxs = zzm.max(axis=1)
            for row, i in enumerate(idxs):
                meds[i] = int(gm[row])
                zzs[i] = zzm[row]
                npls[i] = max(1, (int(zmaxs[row]).bit_length() + 7) // 8)
    rows: list[tuple[int, int]] = []  # (stream index, plane), plane-ascending
    syms: list[np.ndarray] = []
    for i in big:
        zz = zzs[i]
        for p in range(npls[i]):
            rows.append((i, p))
            syms.append(((zz >> np.uint64(8 * p)) & np.uint64(0xFF)).astype(np.int64))
    r_count = len(rows)
    ns = np.array([sy.size for sy in syms], dtype=np.int64)
    steps_r = -(-ns // k)
    # per-row outputs, indexed by global row id
    row_freqs: list[np.ndarray] = [None] * r_count  # type: ignore[list-item]
    row_states: list[bytes] = [b""] * r_count
    row_words: list[np.ndarray] = [None] * r_count  # type: ignore[list-item]
    # The device engine pads every row of a group to the group's longest row
    # (identity-symbol no-ops), so when it is in play, rows are ALWAYS split
    # into power-of-two step-count groups — padding waste stays < 2x even
    # for skewed length mixes.  The numpy machine's dense-prefix loop does
    # no padded work, so it only splits when the scratch cube would blow
    # past _RANS_DENSE_CELLS.
    eng = _rans_device(int(ns.sum()))
    if (
        eng is not None
        and not eng.compiled_route()
        and os.environ.get("SHRINK_RANS_DEVICE") != "1"
        and int(ns.sum()) < _RANS_DEVICE_RAGGED_MIN_XLA
    ):
        # CPU fallback route: a ragged mix means SEVERAL padded group
        # dispatches, and the dense-prefix numpy machine (zero padded work,
        # one pass) beats them below this size.  The compiled TPU kernels
        # win at any size; forced mode ("1") keeps parity tests on-engine.
        eng = None
    if eng is None and int(steps_r.max()) * r_count * k <= _RANS_DENSE_CELLS:
        groups = [np.arange(r_count)]  # one dense machine: zero work waste
    else:
        # geometric step-count groups: within a group max <= 2 * min steps
        group_of = np.array([int(s).bit_length() for s in steps_r])
        groups = [np.flatnonzero(group_of == g) for g in np.unique(group_of)]
    for ids in groups:
        _rans_encode_row_group(
            [syms[r] for r in ids], ids, steps_r, k,
            row_freqs, row_states, row_words, eng=eng,
        )
    native_le = np.little_endian
    parts: dict[int, list[bytes]] = {
        i: [struct.pack("<qQBB", meds[i], qs[i].size,
                        max(1, (int(zzs[i].max()).bit_length() + 7) // 8), k)]
        for i in big
    }
    freqs_all = np.stack(row_freqs)
    present_all = freqs_all > 0
    bitmaps = np.packbits(present_all, axis=1, bitorder="little")
    freqs16 = freqs_all.astype("<u2")
    for r in range(r_count):  # original order: planes ascending per stream
        i, _p = rows[r]
        words = row_words[r]
        parts[i].append(bitmaps[r].tobytes())
        parts[i].append(freqs16[r][present_all[r]].tobytes())
        parts[i].append(row_states[r])
        parts[i].append(struct.pack("<I", words.size))
        parts[i].append(words.tobytes() if native_le else words.astype("<u2").tobytes())
    for i in big:
        out[i] = b"".join(parts[i])
    return out


def _rans_encode_row_group(
    group_syms: list[np.ndarray],
    group_ids: np.ndarray,
    steps_r: np.ndarray,
    k: int,
    row_freqs: list,
    row_states: list,
    row_words: list,
    eng=None,
) -> None:
    """Run the interleaved state machine for one step-count group of
    (stream, plane) rows; results land in the per-row output lists (see
    ``_rans_encode_batch_ragged`` for the grouping/identity-symbol
    scheme).  When ``eng`` (the device engine) is given, the whole group
    runs as one fused device call, falling back to the numpy machine on
    engine failure."""
    r_count = len(group_ids)
    order = np.argsort(-steps_r[group_ids], kind="stable")  # longest first
    steps_sorted = steps_r[group_ids][order]
    max_steps = int(steps_sorted[0])

    # per-row tables with a reserved 257th entry: the identity symbol
    # (freq = M, cum = 0) that padded lane positions carry
    _ID = 256
    counts = np.empty((r_count, 256), dtype=np.int64)
    sym_mat = np.full((r_count, max_steps * k), _ID, dtype=np.uint16)
    for pos, j in enumerate(order):
        sy = group_syms[j]
        counts[pos] = np.bincount(sy, minlength=256)
        sym_mat[pos, : sy.size] = sy
    freqs = _rans_normalize_freqs_rows(counts)
    if eng is not None:
        res = _rans_device_encode(eng, sym_mat, freqs)
        if res is not None:
            states_dev, words_list = res
            states32 = states_dev.astype("<u4")
            for pos, j in enumerate(order):
                r = int(group_ids[j])
                row_freqs[r] = freqs[pos]
                row_states[r] = states32[pos].tobytes()
                row_words[r] = words_list[pos]
            return
    cums = np.zeros_like(freqs)
    np.cumsum(freqs[:, :-1], axis=1, out=cums[:, 1:])
    f_ext = np.full((r_count, 257), _RANS_M, dtype=np.uint32)
    f_ext[:, :256] = freqs
    c_ext = np.zeros((r_count, 257), dtype=np.uint32)
    c_ext[:, :256] = cums
    f_flat, c_flat = f_ext.ravel(), c_ext.ravel()
    row_off = np.arange(r_count, dtype=np.intp)[:, None] * 257
    # rows active at step t form the sorted prefix [:nr_per_t[t]]
    nr_per_t = np.count_nonzero(
        steps_sorted[None, :] > np.arange(max_steps)[:, None], axis=1
    )
    sh16 = np.uint32(16)
    sh_prob = np.uint32(_RANS_PROB_BITS)
    x = np.full((r_count, k), _RANS_L, dtype=np.uint32)
    masks = np.zeros((max_steps, r_count, k), dtype=bool)
    vals = np.zeros((max_steps, r_count, k), dtype=np.uint16)
    for t in range(max_steps - 1, -1, -1):
        nr = int(nr_per_t[t])
        idx = sym_mat[:nr, t * k : (t + 1) * k] + row_off[:nr]
        f = f_flat[idx]
        c = c_flat[idx]
        xa = x[:nr]
        # same uint32-wrap trick as the rectangular machine: f == 2^12 (the
        # identity symbol included) shifts to 0 and the -1 wraps to the
        # uint32 max -> "never renormalize"
        need = xa > (f << np.uint32(32 - _RANS_PROB_BITS)) - np.uint32(1)
        masks[t, :nr] = need
        np.copyto(vals[t, :nr], xa, casting="unsafe")  # truncating low-16 store
        xa = np.where(need, xa >> sh16, xa)
        div, rem = np.divmod(xa, f)
        x[:nr] = (div << sh_prob) + rem + c
    states32 = x.astype("<u4")
    for pos, j in enumerate(order):
        r = int(group_ids[j])
        row_freqs[r] = freqs[pos]
        row_states[r] = states32[pos].tobytes()
        row_words[r] = vals[:, pos, :][masks[:, pos, :]]  # steps asc, lanes asc


def encode_ints_batch(
    qs: np.ndarray | list[np.ndarray], backend: str = "rans"
) -> list[bytes]:
    """Batched ``encode_ints`` over rows qs — an [S, n] array (equal-length
    rows) or a list of 1-D arrays (ragged); each returned blob is
    byte-identical to ``encode_ints(qs[s], backend)``.  ``rans`` runs the
    genuinely fused state machines; ``best`` partitions the batch by the
    cost model's per-stream pick and keeps the rans-bound group on those
    same machines; ``zstd`` shares one compressor context across the
    batch; everything else falls back to a per-row loop."""
    if isinstance(qs, np.ndarray):
        qs = np.ascontiguousarray(qs, dtype=np.int64)
        if qs.ndim != 2:
            raise ValueError(f"expected [S, n], got shape {qs.shape}")
        if backend == "rans":
            tag = bytes([_BACKENDS["rans"]])
            return [tag + blob for blob in _rans_encode_batch(qs)]
        arrs = list(qs)  # row views: contiguous int64 by construction
    else:
        arrs = [
            q
            if isinstance(q, np.ndarray)
            and q.ndim == 1
            and q.dtype == np.int64
            and q.flags.c_contiguous
            else np.ascontiguousarray(np.asarray(q).ravel(), dtype=np.int64)
            for q in qs
        ]
    if not arrs:
        return []
    if backend == "rans":
        n0 = arrs[0].size
        if all(a.size == n0 for a in arrs):  # rectangular in disguise
            return encode_ints_batch(np.stack(arrs), backend=backend)
        tag = bytes([_BACKENDS["rans"]])
        return [tag + blob for blob in _rans_encode_batch_ragged(arrs)]
    if backend == "best":
        return _adaptive_encode_batch(arrs)
    if backend == "zstd" and _zstd is not None:
        ctx = _zstd.ZstdCompressor(level=19)
        tag = bytes([_BACKENDS["zstd"]])
        return [tag + _zstd_encode(q, compressor=ctx) for q in arrs]
    return [encode_ints(q, backend=backend) for q in arrs]


def _rans_decode(data: bytes) -> np.ndarray:
    med, count, nplanes, k = struct.unpack_from("<qQBB", data, 0)
    eng = _rans_device(count * nplanes) if k == _RANS_K else None
    if eng is not None:
        try:
            # engine exceptions may be data-dependent (corrupt freq tables),
            # so do not quarantine the engine — rerun on the numpy path,
            # which raises the decoder's usual error for bad streams
            return _rans_decode_device(data, med, count, nplanes, k, eng)
        except Exception:
            pass
    off = 18
    zz = np.zeros(count, dtype=np.uint64)
    for p in range(nplanes):
        freqs, off = _rans_read_plane_table(data, off)
        cums = np.concatenate(([0], np.cumsum(freqs)[:-1]))
        sym, off = _rans_decode_plane(data, off, count, freqs, cums, k)
        zz |= sym.astype(np.uint64) << np.uint64(8 * p)
    return _unzigzag(zz) + med


def _rans_read_plane_table(data: bytes, off: int) -> tuple[np.ndarray, int]:
    """Read one plane's frequency table (32B presence bitmap + u16 per
    present symbol); returns (freqs int64 [256], new off)."""
    bitmap = np.frombuffer(data, dtype=np.uint8, count=32, offset=off)
    off += 32
    present = np.unpackbits(bitmap, bitorder="little").astype(bool)
    npresent = int(present.sum())
    freqs = np.zeros(256, dtype=np.int64)
    freqs[present] = np.frombuffer(data, dtype="<u2", count=npresent, offset=off)
    off += 2 * npresent
    return freqs, off


def _rans_decode_device(
    data: bytes, med: int, count: int, nplanes: int, k: int, eng
) -> np.ndarray:
    """Device decode: walk every plane's header on the host, then run all
    planes through one fused device scan (planes = machine rows)."""
    freqs_mat = np.empty((nplanes, 256), dtype=np.int64)
    states = np.empty((nplanes, k), dtype=np.uint32)
    words_list: list[np.ndarray] = []
    off = 18
    for p in range(nplanes):
        freqs_mat[p], off = _rans_read_plane_table(data, off)
        states[p] = np.frombuffer(data, dtype="<u4", count=k, offset=off)
        off += 4 * k
        (nwords,) = struct.unpack_from("<I", data, off)
        off += 4
        words_list.append(
            np.frombuffer(data, dtype="<u2", count=nwords, offset=off)
        )
        off += 2 * nwords
    syms = eng.decode_rows(states, freqs_mat, words_list, count)
    zz = np.zeros(count, dtype=np.uint64)
    for p in range(nplanes):
        zz |= syms[p].astype(np.uint64) << np.uint64(8 * p)
    return _unzigzag(zz) + med


def _raw_encode(q: np.ndarray) -> bytes:
    """Minimal-width bit packing (no statistical modelling)."""
    lo = int(q.min()) if q.size else 0
    span = (int(q.max()) - lo + 1) if q.size else 1
    bits = max(1, int(span - 1).bit_length()) if span > 1 else 1
    vals = (q - lo).astype(np.uint64)
    header = struct.pack("<qQB", lo, q.size, bits)
    # pack with numpy: expand to bit matrix
    bitmat = ((vals[:, None] >> np.arange(bits, dtype=np.uint64)) & 1).astype(np.uint8)
    packed = np.packbits(bitmat.reshape(-1))
    return header + packed.tobytes()


def _raw_decode(data: bytes) -> np.ndarray:
    lo, count, bits = struct.unpack_from("<qQB", data, 0)
    off = 17
    packed = np.frombuffer(data, dtype=np.uint8, offset=off)
    bitvec = np.unpackbits(packed)[: count * bits]
    bitmat = bitvec.reshape(count, bits).astype(np.uint64)
    vals = (bitmat << np.arange(bits, dtype=np.uint64)).sum(axis=1)
    return vals.astype(np.int64) + lo


def _bitpack_encode(q: np.ndarray) -> bytes:
    """Tight fixed-width packing: values biased by the stream minimum,
    packed LSB-first at ``span.bit_length()`` bits each.  A constant (or
    empty) stream has width 0 and costs only the 17-byte header, so this
    is never larger than ``raw`` (which always pays >= 1 bit per value)
    and there is no statistical modelling to mispredict."""
    lo = int(q.min()) if q.size else 0
    span = (int(q.max()) - lo) if q.size else 0
    width = span.bit_length()
    header = struct.pack("<qQB", lo, q.size, width)
    if width == 0:
        return header
    vals = (q - lo).astype(np.uint64)  # wraps mod 2^64: exact unsigned bias
    bitmat = ((vals[:, None] >> np.arange(width, dtype=np.uint64)) & 1).astype(np.uint8)
    return header + np.packbits(bitmat.reshape(-1), bitorder="little").tobytes()


def _bitpack_decode(data: bytes) -> np.ndarray:
    if len(data) < 17:
        raise TruncatedArchiveError(
            f"bitpack stream truncated: {len(data)} byte header, need 17"
        )
    lo, count, width = struct.unpack_from("<qQB", data, 0)
    if width > 64:
        raise FormatError(f"bitpack width byte {width} out of range (max 64)")
    nbytes = (count * width + 7) // 8
    if len(data) < 17 + nbytes:
        raise TruncatedArchiveError(
            f"bitpack stream truncated: payload {len(data) - 17} bytes, "
            f"need {nbytes} for {count} values at width {width}"
        )
    if len(data) > 17 + nbytes:
        raise CorruptFrameError(
            f"bitpack stream has {len(data) - 17 - nbytes} trailing bytes"
        )
    if width == 0:
        return np.full(count, lo, dtype=np.int64)
    packed = np.frombuffer(data, dtype=np.uint8, offset=17)
    bitvec = np.unpackbits(packed, bitorder="little")[: count * width]
    bitmat = bitvec.reshape(count, width).astype(np.uint64)
    vals = (bitmat << np.arange(width, dtype=np.uint64)).sum(axis=1, dtype=np.uint64)
    return vals.astype(np.int64) + lo


def _zstd_encode(q: np.ndarray, level: int = 19, compressor=None) -> bytes:
    assert _zstd is not None
    lo = int(q.min()) if q.size else 0
    span = (int(q.max()) - lo) if q.size else 0
    if span < (1 << 8):
        dt, code = np.uint8, 0
    elif span < (1 << 16):
        dt, code = np.uint16, 1
    elif span < (1 << 32):
        dt, code = np.uint32, 2
    else:
        dt, code = np.uint64, 3
    body = (q - lo).astype(dt).tobytes()
    ctx = compressor if compressor is not None else _zstd.ZstdCompressor(level=level)
    comp = ctx.compress(body)
    return struct.pack("<qQB", lo, q.size, code) + comp


def _zstd_decode(data: bytes, decompressor=None) -> np.ndarray:
    if _zstd is None:
        raise RuntimeError(
            "this stream was encoded with the zstd backend; install the "
            "'zstandard' extra to decode it"
        )
    lo, count, code = struct.unpack_from("<qQB", data, 0)
    dt = [np.uint8, np.uint16, np.uint32, np.uint64][code]
    ctx = decompressor if decompressor is not None else _zstd.ZstdDecompressor()
    body = ctx.decompress(data[17:])
    return np.frombuffer(body, dtype=dt).astype(np.int64) + lo


_BACKENDS = {"rc": 0, "zstd": 1, "raw": 2, "rans": 3, "bitpack": 4}
_REV = {v: k for k, v in _BACKENDS.items()}


def available_backends() -> list[str]:
    out = ["rc", "rans", "raw", "bitpack"]
    if _zstd is not None:
        out.insert(2, "zstd")
    return out


def backend_name(tag: int) -> str | None:
    """Backend name for a stream's leading tag byte, or None if unknown."""
    return _REV.get(tag)


# ------------------------------------------------------------------ #
# adaptive dispatch: cost model + per-stream routing
# ------------------------------------------------------------------ #

# rc is excluded from adaptive candidates: it is an O(n)-python oracle, never
# a production route.  zstd (level 19) is much slower than the packers and
# the rANS machine, so it must win the size prediction by a decisive margin
# before the dispatcher sends a stream its way.
_ZSTD_MARGIN = 0.9
# order-0 plane entropy is a lower bound on what the real coder emits (table
# quantization, 16-bit renorm granularity), so the rANS prediction is
# inflated a touch: near-ties then go to the packers, whose closed-form
# predictions are exact and therefore cannot be the wrong pick.
_RANS_PRED_INFLATE = 1.02
_ZSTD_FRAME_OVERHEAD = 13  # magic + frame header + checksum, roughly


def predict_backend_sizes(q: np.ndarray) -> dict[str, int]:
    """Predicted encoded sizes (tag byte included) per backend, from one
    O(n) feature pass: byte-plane histograms of the zigzagged stream (->
    order-0 entropy per plane and the zero-high-plane count), a run-length
    probe, and the max-magnitude bit width.  ``raw`` and ``bitpack`` are
    exact closed forms of their wire layouts; ``rans`` and ``zstd`` are
    estimates (see :func:`choose_backend` for how ties are biased)."""
    q = np.ascontiguousarray(q, dtype=np.int64)
    n = int(q.size)
    lo = int(q.min()) if n else 0
    span = (int(q.max()) - lo) if n else 0
    width = span.bit_length()
    pred = {
        "raw": 1 + 17 + (n * max(1, width) + 7) // 8,
        "bitpack": 1 + 17 + (n * width + 7) // 8,
    }
    med = int(np.median(q)) if n else 0
    zz = _zigzag(q - med)
    zmax = int(zz.max()) if n else 0
    nplanes = max(1, (zmax.bit_length() + 7) // 8)
    k = max(1, min(_RANS_K, n))
    rans = 18  # <qQBB header
    info_bits = 0.0
    nlog2n = n * np.log2(n) if n else 0.0
    for p in range(nplanes):
        sym = ((zz >> np.uint64(8 * p)) & np.uint64(0xFF)).astype(np.int64)
        counts = np.bincount(sym)
        nz = counts[counts > 0]
        rans += 32 + 2 * nz.size + 4 * k + 4
        if n:
            info_bits += float(nlog2n - (nz * np.log2(nz)).sum())
    rans += int(info_bits / 8)
    pred["rans"] = 1 + int(rans * _RANS_PRED_INFLATE) + 8
    if _zstd is not None and n:
        wbytes = 1 if width <= 8 else 2 if width <= 16 else 4 if width <= 32 else 8
        runs = int((q[1:] != q[:-1]).sum()) + 1
        # zstd sees the (q - lo) bytes: bounded below by their information
        # content (~ the plane entropies) and by what run-collapsing LZ
        # matches leave behind, whichever bites first
        pred["zstd"] = (
            1 + 17 + _ZSTD_FRAME_OVERHEAD + min(int(info_bits / 8), runs * (wbytes + 2))
        )
    return pred


def choose_backend(q: np.ndarray) -> str:
    """The cost model's pick for one stream.  Pure and deterministic per
    stream, so scalar and batched adaptive paths produce byte-identical
    blobs.  Ties go to the cheapest-to-encode exact-cost backend."""
    pred = predict_backend_sizes(q)
    best = "bitpack"
    for cand in ("rans", "raw"):
        if pred[cand] < pred[best]:
            best = cand
    z = pred.get("zstd")
    if z is not None and z < _ZSTD_MARGIN * pred[best]:
        best = "zstd"
    return best


def encode_ints(q: np.ndarray, backend: str = "best", exhaustive: bool = False) -> bytes:
    """Losslessly encode an int64 array.  Returns tagged bytes.

    ``backend='best'`` routes through the adaptive cost model (one O(n)
    feature pass, then exactly one encode).  ``exhaustive=True`` restores
    the brute-force oracle: encode with every candidate, keep the smallest
    — the compression-ratio ceiling, at ~4x the encode cost."""
    q = np.ascontiguousarray(q, dtype=np.int64)
    if backend == "best":
        if not exhaustive:
            c = choose_backend(q)
            return bytes([_BACKENDS[c]]) + _dispatch_encode(q, c)
        cands = ["rans"]
        # rc is O(n) python — skip it for very large streams; rans/zstd are
        # within a few % of its size at numpy/C speed
        if q.size <= 300_000:
            cands.append("rc")
        if _zstd is not None:
            cands.append("zstd")
        cands.append("raw")
        cands.append("bitpack")
        blobs = [(len(b := _dispatch_encode(q, c)), c, b) for c in cands]
        _, c, b = min(blobs, key=lambda t: t[0])
        return bytes([_BACKENDS[c]]) + b
    if backend not in _BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; have {sorted(_BACKENDS)} or 'best'")
    return bytes([_BACKENDS[backend]]) + _dispatch_encode(q, backend)


def _dispatch_encode(q: np.ndarray, backend: str) -> bytes:
    if backend == "rc":
        return _rc_encode(q)
    if backend == "rans":
        return _rans_encode(q)
    if backend == "zstd":
        if _zstd is None:
            raise RuntimeError("zstandard not available")
        return _zstd_encode(q)
    if backend == "raw":
        return _raw_encode(q)
    if backend == "bitpack":
        return _bitpack_encode(q)
    raise ValueError(f"unknown backend {backend!r}")


def _adaptive_encode_batch(arrs: list[np.ndarray]) -> list[bytes]:
    """``backend='best'`` over a batch: choose per stream with the cost
    model (the same pure per-stream decision the scalar path makes, so
    batch and scalar outputs stay byte-identical), then partition by
    choice — the rans-bound group keeps the fused rect/ragged machines
    (device engine included), the zstd group shares one compressor, and
    the packers loop (each already vectorized per stream)."""
    out: list[bytes] = [b""] * len(arrs)
    groups: dict[str, list[int]] = {}
    for i, q in enumerate(arrs):
        groups.setdefault(choose_backend(q), []).append(i)
    idxs = groups.pop("rans", None)
    if idxs:
        blobs = encode_ints_batch([arrs[i] for i in idxs], backend="rans")
        for i, blob in zip(idxs, blobs):
            out[i] = blob
    idxs = groups.pop("zstd", None)
    if idxs:
        ctx = _zstd.ZstdCompressor(level=19)
        tag = bytes([_BACKENDS["zstd"]])
        for i in idxs:
            out[i] = tag + _zstd_encode(arrs[i], compressor=ctx)
    for c, idxs in groups.items():
        tag = bytes([_BACKENDS[c]])
        for i in idxs:
            out[i] = tag + _dispatch_encode(arrs[i], c)
    return out


def decode_ints(data: bytes) -> np.ndarray:
    if not data:
        raise TruncatedArchiveError("entropy stream is empty (missing tag byte)")
    tag = _REV.get(data[0])
    if tag is None:
        raise FormatError(f"unknown entropy backend tag {data[0]}")
    body = data[1:]
    if tag == "rc":
        return _rc_decode(body)
    if tag == "rans":
        return _rans_decode(body)
    if tag == "zstd":
        return _zstd_decode(body)
    if tag == "bitpack":
        return _bitpack_decode(body)
    return _raw_decode(body)


def decode_ints_batch(blobs: list[bytes]) -> list[np.ndarray]:
    """Batched ``decode_ints``: one shared ``ZstdDecompressor`` serves
    every zstd-tagged stream in the batch (the scalar path pays a fresh
    context per call)."""
    ztag = _BACKENDS["zstd"]
    ctx = None
    out = []
    for data in blobs:
        if data and data[0] == ztag and _zstd is not None:
            if ctx is None:
                ctx = _zstd.ZstdDecompressor()
            out.append(_zstd_decode(data[1:], decompressor=ctx))
        else:
            out.append(decode_ints(data))
    return out
