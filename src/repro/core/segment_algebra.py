"""Closed-form segment-domain aggregates over the SHRINK knowledge base.

The follow-up work on direct analytics (PAPERS.md: "Highly Efficient
Direct Analytics on Semantic-aware Time Series Data Compression") rests on
one observation: SHRINK's base is a piecewise-*linear* partition of the
series, so sums, extrema, and threshold counts of the base approximation
have closed forms per segment — a query over [t0, t1) costs O(#segments
touched), not O(#samples), and never touches the entropy-coded residuals.

For a segment with origin ``theta``, slope ``s`` covering local indices
``i in [a, b)``:

* ``sum   = m*theta + s * (S1(b) - S1(a))``            with ``S1(x) = x(x-1)/2``
* ``sumsq = m*theta^2 + 2 theta s (S1(b)-S1(a)) + s^2 (S2(b)-S2(a))``
  with ``S2(x) = x(x-1)(2x-1)/6``
* ``min/max`` at the endpoints (the segment is monotone), and
* ``count(pred cmp c)`` is an index-interval count because
  ``theta + s*i cmp c`` solves to a half-line in ``i``.

Everything here describes the *base approximation* exactly (up to float
rounding).  The analytics engine (``repro.analytics``) turns these into
guaranteed intervals for the *true* values by composing them with a
per-point error bound: the base's practical eps, or a pyramid tier's
``eps_k`` after refinement.  Threshold counts bisect the actual float
predictions (which are monotone per segment even under rounding), so the
closed-form count equals a dense ``(pred cmp c).sum()`` over the same
float predictions for any magnitudes — the engine's margins, not this
module, absorb the approximation error.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .base import _flat_segments
from .types import Base

__all__ = [
    "BaseStats",
    "SegmentTable",
    "segment_table",
    "base_aggregate",
    "base_aggregate_with_m2",
    "base_central_m2",
    "count_cmp",
]

_CMPS = ("gt", "ge", "lt", "le")


@dataclasses.dataclass(frozen=True)
class BaseStats:
    """Exact aggregates of the base approximation over one sample range.

    ``m`` samples; ``total``/``sumsq`` are Σ pred / Σ pred²; ``vmin``/
    ``vmax`` the extrema (+inf/-inf for an empty range, matching the
    identity of min/max composition)."""

    m: int
    total: float
    sumsq: float
    vmin: float
    vmax: float

    @property
    def mean(self) -> float:
        return self.total / self.m if self.m else math.nan

    def std(self) -> float:
        """Population stddev of the base approximation (clamped at 0 so
        float cancellation in E[x²] − E[x]² cannot go negative)."""
        if not self.m:
            return math.nan
        var = self.sumsq / self.m - (self.total / self.m) ** 2
        return math.sqrt(max(var, 0.0))


@dataclasses.dataclass(frozen=True)
class SegmentTable:
    """The base's member segments as parallel arrays sorted by t0 (a
    partition of [0, n)) — the queryable form of the knowledge base.  Built
    once per base/frame and cached by the analytics engine; every query
    against the same frame reuses it."""

    n: int
    t0s: np.ndarray  # int64 [k] segment start indices
    lens: np.ndarray  # int64 [k]
    thetas: np.ndarray  # float64 [k]
    slopes: np.ndarray  # float64 [k]

    @property
    def k(self) -> int:
        return int(self.t0s.size)

    def ends(self) -> np.ndarray:
        return self.t0s + self.lens

    def overlap(self, t0: int, t1: int):
        """(segment indices, local start a[], local end b[]) of every
        segment intersecting [t0, t1); a/b are segment-local, b exclusive."""
        t0, t1 = max(int(t0), 0), min(int(t1), self.n)
        if t1 <= t0 or not self.k:
            z = np.zeros(0, dtype=np.int64)
            return z, z, z
        ends = self.ends()
        i0 = int(np.searchsorted(ends, t0, side="right"))
        i1 = int(np.searchsorted(self.t0s, t1, side="left"))
        idx = np.arange(i0, i1, dtype=np.int64)
        a = np.maximum(t0 - self.t0s[idx], 0)
        b = np.minimum(t1 - self.t0s[idx], self.lens[idx])
        keep = b > a
        return idx[keep], a[keep], b[keep]


def segment_table(base: Base) -> SegmentTable:
    t0s, lens, thetas, slopes = _flat_segments(base)
    return SegmentTable(n=base.n, t0s=t0s, lens=lens, thetas=thetas, slopes=slopes)


def _s1(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.float64)
    return x * (x - 1.0) / 2.0


def _s2(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.float64)
    return x * (x - 1.0) * (2.0 * x - 1.0) / 6.0


def base_aggregate(table: SegmentTable, t0: int, t1: int) -> BaseStats:
    """Exact (up to float rounding) aggregates of the base approximation
    over samples [t0, t1), in O(#segments touched)."""
    idx, a, b = table.overlap(t0, t1)
    if not idx.size:
        return BaseStats(m=0, total=0.0, sumsq=0.0, vmin=math.inf, vmax=-math.inf)
    theta = table.thetas[idx]
    slope = table.slopes[idx]
    m = (b - a).astype(np.float64)
    d1 = _s1(b) - _s1(a)
    d2 = _s2(b) - _s2(a)
    total = m * theta + slope * d1
    sumsq = m * theta * theta + 2.0 * theta * slope * d1 + slope * slope * d2
    # a linear segment attains its extrema at the endpoints
    va = theta + slope * a.astype(np.float64)
    vb = theta + slope * (b - 1).astype(np.float64)
    return BaseStats(
        m=int((b - a).sum()),
        total=float(total.sum()),
        sumsq=float(sumsq.sum()),
        vmin=float(np.minimum(va, vb).min()),
        vmax=float(np.maximum(va, vb).max()),
    )


def base_aggregate_with_m2(
    table: SegmentTable, t0: int, t1: int
) -> tuple[BaseStats, float]:
    """One overlap pass returning both :func:`base_aggregate` and the
    central second moment about the range's own mean — the stddev fast
    path (a stddev query would otherwise walk the segments twice)."""
    idx, a, b = table.overlap(t0, t1)
    if not idx.size:
        return BaseStats(m=0, total=0.0, sumsq=0.0, vmin=math.inf, vmax=-math.inf), 0.0
    theta = table.thetas[idx]
    slope = table.slopes[idx]
    mseg = (b - a).astype(np.float64)
    d1 = _s1(b) - _s1(a)
    d2 = _s2(b) - _s2(a)
    total = mseg * theta + slope * d1
    sumsq = mseg * theta * theta + 2.0 * theta * slope * d1 + slope * slope * d2
    va = theta + slope * a.astype(np.float64)
    vb = theta + slope * (b - 1).astype(np.float64)
    m = int((b - a).sum())
    grand = float(total.sum())
    mu = grand / m
    ibar = (a + b - 1).astype(np.float64) / 2.0
    seg_mean = theta + slope * ibar
    m2_within = slope * slope * mseg * (mseg * mseg - 1.0) / 12.0
    m2 = float((m2_within + mseg * (seg_mean - mu) ** 2).sum())
    stats = BaseStats(
        m=m,
        total=grand,
        sumsq=float(sumsq.sum()),
        vmin=float(np.minimum(va, vb).min()),
        vmax=float(np.maximum(va, vb).max()),
    )
    return stats, m2


def base_central_m2(table: SegmentTable, t0: int, t1: int, mu: float) -> float:
    """Σ (pred − mu)² over samples [t0, t1), closed form per segment.

    Computed the well-conditioned way (per-segment deviation around the
    segment's own window mean, then a Welford-style shift to ``mu``):
    within one segment the deviations are ``s·(i − ī)`` whose sum of
    squares is *exactly* ``s²·m(m²−1)/12`` — no large-moment cancellation,
    so stddev bounds stay tight even when |values| ≫ stddev."""
    idx, a, b = table.overlap(t0, t1)
    if not idx.size:
        return 0.0
    theta = table.thetas[idx]
    slope = table.slopes[idx]
    m = (b - a).astype(np.float64)
    ibar = (a + b - 1).astype(np.float64) / 2.0
    seg_mean = theta + slope * ibar
    m2_within = slope * slope * m * (m * m - 1.0) / 12.0
    return float((m2_within + m * (seg_mean - mu) ** 2).sum())


def _first_true(
    sat_fn, lo0: np.ndarray, hi0: np.ndarray, active: np.ndarray
) -> np.ndarray:
    """Vectorized lower-bound search: per row, the smallest i in
    [lo0, hi0) with ``sat_fn(i)`` True (hi0 = none), given that the
    predicate is a True-*suffix* over i on active rows.  O(log n) exact
    integer bisection — no float crossing guess anywhere, so it is immune
    to the ulp(theta)/|slope| error that breaks a solve-and-adjust
    approach on near-flat large-magnitude segments."""
    lo = lo0.astype(np.int64).copy()
    hi = hi0.astype(np.int64).copy()
    lo[~active] = hi[~active]
    while True:
        open_ = lo < hi
        if not open_.any():
            return lo
        mid = (lo + hi) // 2
        s = sat_fn(mid.astype(np.float64))
        hi = np.where(open_ & s, mid, hi)
        lo = np.where(open_ & ~s, mid + 1, lo)


def _count_upset(
    theta: np.ndarray,
    slope: np.ndarray,
    a: np.ndarray,
    b: np.ndarray,
    c: float,
    strict: bool,
) -> np.ndarray:
    """Per-segment count of local i in [a, b) with ``theta + slope*i > c``
    (``>= c`` when not strict).

    ``theta + slope*i`` is monotone in i even in floats (multiplying by a
    positive constant and adding a constant are monotone under rounding),
    so the satisfied set is a half-line of indices and an integer
    bisection against the *actual float predictions* finds its boundary
    exactly: the result equals the dense ``(pred cmp c).sum()`` over the
    same float predictions for ANY magnitudes.
    """
    m = (b - a).astype(np.float64)
    out = np.zeros(theta.shape, dtype=np.float64)

    def sat(i: np.ndarray) -> np.ndarray:
        v = theta + slope * i
        return v > c if strict else v >= c

    flat = slope == 0.0
    if flat.any():
        v0 = theta > c if strict else theta >= c
        out[flat] = np.where(v0[flat], m[flat], 0.0)

    pos = slope > 0.0
    if pos.any():
        # fp-nondecreasing pred: satisfied set is {i >= imin}
        imin = _first_true(sat, a, b, pos)
        out[pos] = (b - imin).astype(np.float64)[pos]

    neg = slope < 0.0
    if neg.any():
        # fp-nonincreasing pred: satisfied is a True-prefix; count ends at
        # the first NON-satisfied index
        end = _first_true(lambda i: ~sat(i), a, b, neg)
        out[neg] = (end - a).astype(np.float64)[neg]
    return out


def count_cmp(table: SegmentTable, t0: int, t1: int, op: str, c: float) -> int:
    """Exact count of samples in [t0, t1) whose *base approximation*
    satisfies ``pred <op> c`` — O(#segments · log len), no per-sample
    work.  Matches the dense count over the same float predictions
    (integer bisection against the actual float values)."""
    if op not in _CMPS:
        raise ValueError(f"unknown comparison {op!r}: expected one of {_CMPS}")
    idx, a, b = table.overlap(t0, t1)
    if not idx.size:
        return 0
    theta = table.thetas[idx]
    slope = table.slopes[idx]
    m = (b - a).astype(np.float64)
    if op == "gt":
        cnt = _count_upset(theta, slope, a, b, c, strict=True)
    elif op == "ge":
        cnt = _count_upset(theta, slope, a, b, c, strict=False)
    elif op == "lt":  # pred < c  ==  m - (pred >= c)
        cnt = m - _count_upset(theta, slope, a, b, c, strict=False)
    else:  # "le":     pred <= c  ==  m - (pred > c)
        cnt = m - _count_upset(theta, slope, a, b, c, strict=True)
    return int(cnt.sum())
