"""Candidate line selection (Alg. 5 of the paper).

The paper truncates the average slope to the digits shared by psi_lo and
psi_hi (plus the midpoint of the first divergent digits).  Taken literally
that construction can fall *outside* [psi_lo, psi_hi] when the divergent
digits are adjacent (e.g. [0.1258, 0.1263] -> "0.125" < psi_lo), silently
inflating the practical base error.  We implement what the algorithm is
clearly after — the *shortest-decimal number inside the span* — with the
classic interval-shortest-decimal search: find the smallest digit count d
such that ceil(lo * 10^d) <= floor(hi * 10^d) and take that grid value.
This always lies inside the span and never uses more digits than the
literal Alg. 5.  (Deviation recorded in DESIGN.md §3.)

For spans with infinite ends (single-point cones) the slope is 0.
"""
from __future__ import annotations

import math

__all__ = ["optimized_slope", "shortest_decimal_in_interval"]

_MAX_DIGITS = 12


def shortest_decimal_in_interval(lo: float, hi: float) -> tuple[float, int]:
    """Return (value, digits) — the decimal with fewest fraction digits in
    [lo, hi].  Prefers the candidate closest to the midpoint at that digit
    count.  Assumes lo <= hi and both finite."""
    if lo > hi:
        lo, hi = hi, lo
    mid = 0.5 * (lo + hi)
    for d in range(0, _MAX_DIGITS + 1):
        scale = 10.0**d
        qlo = math.ceil(lo * scale - 1e-12)
        qhi = math.floor(hi * scale + 1e-12)
        if qlo <= qhi:
            # choose the on-grid value nearest the midpoint
            q = round(mid * scale)
            q = min(max(q, qlo), qhi)
            val = q / scale
            # guard against float round-trip pushing us out of the span
            if val < lo:
                val = qlo / scale if qlo <= qhi else lo
            if val > hi:
                val = qhi / scale
            if lo <= val <= hi:
                return float(val), d
    return float(mid), _MAX_DIGITS + 1


def optimized_slope(psi_lo: float, psi_hi: float) -> tuple[float, int]:
    """Alg. 5 wrapper handling the degenerate spans.

    Returns (slope, digits).  digits is used by the serializer to store the
    slope as a small scaled integer instead of a raw float64.
    """
    lo_inf = math.isinf(psi_lo)
    hi_inf = math.isinf(psi_hi)
    if lo_inf and hi_inf:
        return 0.0, 0
    if lo_inf:
        return (float(psi_hi), _MAX_DIGITS + 1) if psi_hi < 0 else (0.0, 0)
    if hi_inf:
        return (float(psi_lo), _MAX_DIGITS + 1) if psi_lo > 0 else (0.0, 0)
    if psi_lo == psi_hi:
        return float(psi_lo), _MAX_DIGITS + 1
    return shortest_decimal_in_interval(psi_lo, psi_hi)
