"""The SHRINK codec (Alg. 1 of the paper): one base, many resolutions.

Residuals are stored as a **layered refinement pyramid**: tier 0 quantizes
the residual at the coarsest eps, every finer tier k quantizes the
reconstruction error left by tiers 0..k-1 (the lossless tier as the final
integer-domain refinement), so an archive with tiers {1e-1, 1e-2, 1e-3, 0}
stores each bit of residual information once — decode-at-eps_k is
``base + Σ layers 0..k`` and a multi-resolution archive is strictly
smaller than independent per-eps streams.

Usage:

    codec = ShrinkCodec.from_fraction(values, frac=0.05)     # eps_b = 5% range
    cs    = codec.compress(values, eps_targets=[1e-2, 1e-4], decimals=8)
    vhat  = codec.decompress_at(cs, 1e-4)                    # |vhat-v| <= 1e-4
    mid   = codec.decompress_at(cs, 3e-3)                    # nearest tier <= 3e-3 (here 1e-4)
    exact = codec.decompress_at(cs, 0.0)                     # lossless
    blob  = cs_to_bytes(cs); cs2 = cs_from_bytes(blob)

    # gateway-scale: S series in one vectorized pass — equal-length [S, T]
    # or a ragged list of 1-D arrays (length-bucketed, masked lanes)
    css   = codec.compress_batch(values_st, eps_targets=[1e-2])   # [S, T]
    css   = codec.compress_batch([v1, v2, v3], eps_targets=[1e-2])  # ragged

``decompress_at`` accepts ANY eps: it resolves the cheapest layer prefix
whose guarantee is <= the request (raising ``ValueError`` only when no
tier qualifies).  ``eps == 0.0`` denotes the lossless tier (requires
``decimals``: the fixed decimal precision of the source data, Table II's
"Decimal" column).  ``ProgressiveDecoder`` exposes the same ladder
incrementally — decode coarse now, refine later, paying only the delta.
"""
from __future__ import annotations

import math
import struct
import sys
import zlib
from dataclasses import dataclass

import numpy as np

from . import entropy
from .errors import (
    CorruptFrameError,
    FormatError,
    LayerCorruptError,
    ShrinkError,
    TruncatedArchiveError,
)
from .base import (
    base_predictions,
    base_predictions_batch,
    base_predictions_ragged,
    construct_base,
    practical_eps_b,
)
from .residuals import (
    encode_residuals_batch,
    normalize_tiers,
    quantize_pyramid,
    quantize_pyramid_batch,
)
from .semantics import (
    extract_semantics,
    extract_semantics_batch,
    extract_semantics_batch_pallas,
    global_range,
)
from .serialize import (
    decode_base,
    decode_pyramid,
    encode_base,
    encode_pyramid,
    pyramid_layers,
)
from .types import Base, CompressedSeries, ResidualStream, ShrinkConfig

__all__ = [
    "ShrinkCodec",
    "ProgressiveDecoder",
    "cs_to_bytes",
    "cs_from_bytes",
    "decompress_at",
    "encode_frames_with_bases",
    "encode_with_base",
    "original_size_bytes",
]

_CONTAINER_MAGIC = b"SHRK"
_CONTAINER_VERSION = 2

# The paper's Table II datasets store (timestamp, value) pairs; we account the
# original size as 16 bytes/row (two float64) — same accounting for every
# method in benchmarks/, so CRs are comparable across methods and with the
# paper's relative claims.
BYTES_PER_ROW = 16


def original_size_bytes(n: int) -> int:
    return BYTES_PER_ROW * n


@dataclass
class ShrinkCodec:
    config: ShrinkConfig
    backend: str = "best"

    @classmethod
    def from_fraction(
        cls,
        values: np.ndarray,
        frac: float = 0.05,
        lam: float = 1e-5,
        beta_levels: int = 16,
        backend: str = "best",
    ) -> "ShrinkCodec":
        vmin, vmax = global_range(np.asarray(values, dtype=np.float64))
        rng = max(vmax - vmin, 1e-12)
        return cls(
            config=ShrinkConfig(eps_b=frac * rng, lam=lam, beta_levels=beta_levels),
            backend=backend,
        )

    # ------------------------------------------------------------------ #
    def build_base(
        self,
        values: np.ndarray,
        value_range: tuple[float, float] | None = None,
        n_hint: int | None = None,
    ) -> Base:
        values = np.asarray(values, dtype=np.float64)
        segments = extract_semantics(values, self.config, value_range=value_range, n_hint=n_hint)
        if value_range is None:
            vmin, vmax = global_range(values)
        else:
            vmin, vmax = float(value_range[0]), float(value_range[1])
        return construct_base(segments, len(values), vmin, vmax, self.config)

    def compress(
        self,
        values: np.ndarray,
        eps_targets: list[float],
        decimals: int | None = None,
        value_range: tuple[float, float] | None = None,
        n_hint: int | None = None,
    ) -> CompressedSeries:
        """Alg. 1: extract semantics once, then the residual refinement
        pyramid over the eps-target ladder (tier k stores only the delta
        below tier k-1's guarantee; 0.0 = lossless, needs ``decimals``).


        ``value_range``/``n_hint`` pin the scan's global quantities (see
        ``extract_semantics``) so an incremental scan over the same data —
        ``core.streaming.ShrinkStreamCodec`` — produces byte-identical
        output; ``None`` derives them from ``values`` as before.
        """
        values = np.asarray(values, dtype=np.float64)
        base = self.build_base(values, value_range=value_range, n_hint=n_hint)
        return encode_with_base(values, base, eps_targets, decimals, backend=self.backend)

    def compress_batch(
        self,
        values: np.ndarray | list[np.ndarray],
        eps_targets: list[float],
        decimals: int | None = None,
        semantics: str = "auto",
        lengths: np.ndarray | None = None,
        max_buckets: int | None = None,
    ) -> list[CompressedSeries]:
        """Batched Alg. 1 over S independent series — rectangular or ragged.

        Accepted inputs:
        * ``values[S, T]`` ndarray — S equal-length series (the PR 1 fast
          path, unchanged);
        * ``values[S, T]`` + ``lengths[S]`` — ragged lanes padded to T, row
          i holding ``lengths[i]`` real samples;
        * a list of 1-D arrays of ANY mix of lengths (including empty and
          length-1 series) — the gateway's real multi-sensor regime.

        Ragged inputs are length-bucketed into ≤ ``max_buckets`` padded
        lanes (percentile buckets over the sorted lengths, so each bucket
        holds similarly sized series and padding waste stays bounded;
        ``None`` scales the bucket count with the series count — about one
        bucket per 4 series, between 4 and 16, so wide length spreads
        don't drown the masked scans in padding) and
        every stage runs the valid-length mask path: the multi-series cone
        scan carries per-lane segment IDs/lengths so padding never leaks
        into cones, residual quantization cuts each stream at its series'
        end, and ALL streams of all buckets share one rANS entropy pass
        (the masked ragged state machine).

        Semantics extraction runs as one multi-series cone scan per bucket —
        the lane-parallel Pallas kernel with XLA segment compaction on TPU,
        a chunked-vectorized numpy scan elsewhere.  With
        ``semantics="numpy"`` (the off-TPU default) every output is
        byte-identical to ``[self.compress(v, ...) for v in values]``,
        ragged or not (property-tested in tests/test_ragged_property.py).

        semantics: "auto" (pallas on TPU, numpy otherwise) | "numpy" |
        "pallas" (force the kernel route, e.g. for testing in interpret
        mode).
        """
        if semantics == "auto":
            # Only consult jax if something already imported it: forcing the
            # import costs ~1s, and a process that never touched jax is not
            # driving a TPU.
            jx = sys.modules.get("jax")
            try:
                on_tpu = jx is not None and jx.default_backend() == "tpu"
            except Exception:
                on_tpu = False
            semantics = "pallas" if on_tpu else "numpy"
        if semantics not in ("numpy", "pallas"):
            raise ValueError(f"unknown semantics impl {semantics!r}")

        if isinstance(values, (list, tuple)):
            if lengths is not None:
                raise ValueError("pass lengths only with a padded [S, T] array")
            arrs = [np.asarray(v, dtype=np.float64).ravel() for v in values]
            ns = np.array([a.size for a in arrs], dtype=np.int64)
            if ns.size and (ns == ns[0]).all():  # rectangular in disguise
                return self._compress_batch_rect(
                    np.stack(arrs) if ns[0] else np.zeros((ns.size, 0)),
                    eps_targets, decimals, semantics,
                )
            return self._compress_batch_ragged(arrs, ns, eps_targets, decimals,
                                               semantics, max_buckets)
        values = np.asarray(values, dtype=np.float64)
        if values.ndim != 2:
            raise ValueError(f"expected values[S, T], got shape {values.shape}")
        if lengths is not None:
            ns = np.asarray(lengths, dtype=np.int64).ravel()
            if ns.shape != (values.shape[0],):
                raise ValueError(
                    f"lengths must be [S]={values.shape[0]}, got shape {ns.shape}"
                )
            if (ns < 0).any() or (ns > values.shape[1]).any():
                raise ValueError(f"lengths must lie in [0, T={values.shape[1]}]")
            if (ns == values.shape[1]).all():
                return self._compress_batch_rect(values, eps_targets, decimals, semantics)
            arrs = [values[i, : ns[i]] for i in range(values.shape[0])]
            return self._compress_batch_ragged(arrs, ns, eps_targets, decimals,
                                               semantics, max_buckets)
        return self._compress_batch_rect(values, eps_targets, decimals, semantics)

    def _compress_batch_rect(
        self,
        values: np.ndarray,
        eps_targets: list[float],
        decimals: int | None,
        semantics: str,
    ) -> list[CompressedSeries]:
        """The equal-length fast path: one full-width scan, no masks."""
        s, n = values.shape
        if semantics == "pallas" and n:
            seg_lists = extract_semantics_batch_pallas(values, self.config)
        else:
            # scalar early-exit scan per row: faster than the masked
            # multi-series scan on CPU (see _compress_batch_ragged), and
            # segment-identical to it
            seg_lists = [extract_semantics(values[i], self.config) for i in range(s)]

        vmins = values.min(axis=1) if n else np.zeros(s)
        vmaxs = values.max(axis=1) if n else np.zeros(s)
        bases = [
            construct_base(seg_lists[i], n, float(vmins[i]), float(vmaxs[i]), self.config)
            for i in range(s)
        ]
        return encode_frames_with_bases(
            values, bases, eps_targets, decimals, backend=self.backend
        )

    def _compress_batch_ragged(
        self,
        arrs: list[np.ndarray],
        ns: np.ndarray,
        eps_targets: list[float],
        decimals: int | None,
        semantics: str,
        max_buckets: int | None,
    ) -> list[CompressedSeries]:
        """Mixed-length lanes: percentile length-buckets, masked scans, one
        shared entropy pass.  Byte-identical (numpy semantics) to a
        per-series ``compress`` loop."""
        tiers = normalize_tiers(eps_targets, decimals)
        s = len(arrs)
        if max_buckets is None:
            max_buckets = int(np.clip(s // 4, 4, 16))
        if max_buckets < 1:
            raise ValueError(f"max_buckets must be >= 1, got {max_buckets}")
        bases: list[Base | None] = [None] * s
        base_bytes: list[bytes | None] = [None] * s
        eps_hats = np.zeros(s)
        streams_of: list[list[ResidualStream | None]] = [
            [None] * len(tiers) for _ in range(s)
        ]
        pyramids: list = [None] * s
        todo: list[tuple[int, int, ResidualStream]] = []  # (series, layer, stream)

        nonempty = np.flatnonzero(ns > 0)
        for i in np.flatnonzero(ns == 0):
            # an empty series carries an empty base and empty/absent layers;
            # no batching to be had
            b = construct_base([], 0, 0.0, 0.0, self.config)
            cs = encode_with_base(arrs[i], b, tiers, decimals, backend=self.backend)
            bases[i], base_bytes[i] = cs.base, cs.base_bytes
            pyramids[i] = cs.pyramid
            eps_hats[i] = cs.eps_b_practical

        # percentile buckets: equal-count groups of the length-sorted series,
        # each padded to its own max — bounded padding waste for any spread
        order = nonempty[np.argsort(ns[nonempty], kind="stable")]
        buckets = (
            [b for b in np.array_split(order, min(max_buckets, order.size)) if b.size]
            if order.size
            else []
        )
        for bucket in buckets:
            nb = ns[bucket]
            t_pad = int(nb.max())
            vals = np.zeros((bucket.size, t_pad))
            for row, i in enumerate(bucket):
                vals[row, : nb[row]] = arrs[i]
            if semantics == "pallas":
                seg_lists = extract_semantics_batch_pallas(vals, self.config, lengths=nb)
            else:
                # On CPU the adaptive early-exit scalar scan beats the
                # masked multi-series scan (which pre-computes division
                # tables for every position to feed the TPU lanes); the
                # segments are identical either way (property-tested)
                seg_lists = [extract_semantics(arrs[i], self.config) for i in bucket]
            valid = np.arange(t_pad)[None, :] < nb[:, None]
            vmins = np.where(valid, vals, np.inf).min(axis=1)
            vmaxs = np.where(valid, vals, -np.inf).max(axis=1)
            bkt_bases = [
                construct_base(
                    seg_lists[row], int(nb[row]), float(vmins[row]), float(vmaxs[row]),
                    self.config,
                )
                for row in range(bucket.size)
            ]
            preds = base_predictions_ragged(bkt_bases, t_pad)
            r = vals - preds
            bkt_eps_hats = np.abs(np.where(valid, r, 0.0)).max(axis=1)
            for row, i in enumerate(bucket):
                bases[i] = bkt_bases[row]
                base_bytes[i] = encode_base(bkt_bases[row])
                eps_hats[i] = bkt_eps_hats[row]
            bkt_streams = quantize_pyramid_batch(vals, preds, tiers, decimals, lengths=nb)
            for row, i in enumerate(bucket):
                streams_of[int(i)] = bkt_streams[row]
                todo.extend(
                    (int(i), k, st)
                    for k, st in enumerate(bkt_streams[row])
                    if st is not None
                )
        # ONE entropy pass across every layer of every bucket and series:
        # the ragged rANS machine interleaves all of them
        blobs = encode_residuals_batch([st for _, _, st in todo], backend=self.backend)
        payloads: list[list[bytes | None]] = [[None] * len(tiers) for _ in range(s)]
        for (i, k, _), blob in zip(todo, blobs):
            payloads[i][k] = blob
        for i in range(s):
            if pyramids[i] is None:
                pyramids[i] = pyramid_layers(tiers, streams_of[i], payloads[i])
        return [
            CompressedSeries(
                base=bases[i],
                base_bytes=base_bytes[i],
                pyramid=pyramids[i],
                eps_b_practical=float(eps_hats[i]),
            )
            for i in range(s)
        ]

    def decompress_at(self, cs: CompressedSeries, eps: float) -> np.ndarray:
        return decompress_at(cs, eps)


class ProgressiveDecoder:
    """Incremental pyramid decode over one :class:`CompressedSeries`.

    Layer prefixes are materialized on demand and every intermediate
    reconstruction is kept, so refining from tier j to tier k > j pays
    only for the layers in between — the serving layer's frame LRU caches
    one of these per hot frame and a dashboard that first wants a coarse
    sketch and then zooms in never decodes a layer twice.

    ``prefix(k)``/``at(eps)`` return the reconstruction through layer k /
    the cheapest tier satisfying ``eps``; arrays are cached and must be
    treated as read-only by callers.
    """

    def __init__(self, cs: CompressedSeries):
        self.cs = cs
        self._layers = cs.pyramid.layers
        # _recons[0] = base predictions; _recons[d + 1] = reconstruction
        # through layer d (identity layers alias the previous entry)
        self._recons: list[np.ndarray | None] = [None] * (len(self._layers) + 1)
        self._depth = -1  # deepest materialized layer
        self.layers_decoded = 0  # entropy decodes actually paid

    # -- introspection ------------------------------------------------- #
    @property
    def depth(self) -> int:
        """Deepest decoded layer index (-1 = base predictions only)."""
        return self._depth

    def intact_depth(self) -> int:
        """Deepest layer index reachable without crossing a quarantined
        (``corrupt``) layer (-1 = base only; every layer below the first
        corrupt one is unreachable because layer k refines the
        reconstruction error OF the prefix through k-1)."""
        for k, layer in enumerate(self._layers):
            if layer.corrupt:
                return k - 1
        return len(self._layers) - 1

    def guarantee(self, k: int | None = None) -> float:
        """Error bound of the prefix through layer ``k`` (default: the
        deepest decoded prefix)."""
        d = self._depth if k is None else k
        g = self.cs.eps_b_practical
        if d >= 0:
            g = min(g, self._layers[d].eps)
        return g

    def available(self) -> tuple[np.ndarray, float] | None:
        """Best reconstruction decodable with ZERO additional entropy work:
        ``(values, guarantee)``, or ``None`` when nothing is materialized
        yet.  This is what lets a server answer coarse immediately and
        fetch refinement layers on demand."""
        if self._recons[self._depth + 1] is None:
            return None
        return self._recons[self._depth + 1], self.guarantee()

    # -- decode -------------------------------------------------------- #
    def _ensure_base(self) -> None:
        if self._recons[0] is None:
            base = self.cs.base if self.cs.base is not None else decode_base(self.cs.base_bytes)
            self._recons[0] = base_predictions(base)

    def prefix(self, k: int) -> np.ndarray:
        """Reconstruction through layer ``k`` (-1 = base only), decoding
        only the layers not yet materialized."""
        self._ensure_base()
        if k > self._depth:
            recon = self._recons[self._depth + 1]
            for d in range(self._depth + 1, k + 1):
                layer = self._layers[d]
                if layer.corrupt:
                    raise LayerCorruptError(
                        "cannot decode past quarantined pyramid layer "
                        f"(tier eps={layer.eps:g}); finest intact prefix is "
                        f"layer {d - 1}",
                        layer=d,
                    )
                if layer.mode == "identity":
                    out = recon  # tier exists, carries no bytes
                elif layer.mode == "midpoint":
                    q = self._decode_payload(layer, d, len(recon))
                    out = recon + (layer.r_lo + (q.astype(np.float64) + 0.5) * layer.step)
                    recon = out
                elif layer.mode == "exact":
                    q = self._decode_payload(layer, d, len(recon))
                    decimals = int(round(-math.log10(layer.step)))
                    scale = 10.0**decimals
                    rec_int = np.round(recon * scale).astype(np.int64)
                    out = (rec_int + q) / scale
                else:  # pragma: no cover - constructor enforces modes
                    raise ValueError(f"unknown layer mode {layer.mode!r}")
                self._recons[d + 1] = out
            self._depth = k
        return self._recons[k + 1]

    def _decode_payload(self, layer, d: int, n: int) -> np.ndarray:
        """Entropy-decode one layer's payload defensively: a payload that
        slipped past the CRC (or was handed in without one) must surface
        as a typed :class:`LayerCorruptError`, never a raw
        ``KeyError``/``IndexError`` from the entropy coder or a
        wrong-length array that would silently mis-add."""
        try:
            q = entropy.decode_ints(layer.payload)
        except ShrinkError:
            raise
        except Exception as e:
            raise LayerCorruptError(
                f"pyramid layer payload failed entropy decode: {e}", layer=d
            ) from e
        if len(q) != n:
            raise LayerCorruptError(
                f"pyramid layer decoded to {len(q)} residuals for {n} samples",
                layer=d,
            )
        self.layers_decoded += 1
        return q

    def at(self, eps: float) -> np.ndarray:
        """Reconstruction with guarantee <= ``eps`` via the cheapest
        sufficient layer prefix."""
        return self.prefix(self.cs.pyramid.resolve(eps, self.cs.eps_b_practical))


def decompress_at(cs: CompressedSeries, eps: float) -> np.ndarray:
    """Reconstruct the series from ``cs`` at resolution ``eps``: the
    cheapest layer prefix whose guarantee is <= ``eps`` (any requested eps
    resolves to the nearest sufficient tier; ``ValueError`` only when no
    tier qualifies).  Stateless — everything needed lives in the compressed
    series itself, which is what lets range-decode consumers reconstruct
    frames without a codec."""
    return ProgressiveDecoder(cs).at(eps)


def encode_with_base(
    values: np.ndarray,
    base: Base,
    eps_targets: list[float],
    decimals: int | None = None,
    backend: str = "best",
) -> CompressedSeries:
    """Residual-encoding tail of Alg. 1: given an already-constructed base,
    emit the refinement pyramid over the (normalized) eps-target ladder.
    Shared by ``ShrinkCodec.compress`` and the streaming frame sealer so
    both produce identical bytes for identical (values, base) inputs.  All
    layers run through one batched entropy pass."""
    values = np.asarray(values, dtype=np.float64)
    base_bytes = encode_base(base)
    pred = base_predictions(base)
    eps_hat = practical_eps_b(values, base, pred=pred)
    tiers = normalize_tiers(eps_targets, decimals)
    streams = quantize_pyramid(values, pred, tiers, decimals)
    todo = [(k, st) for k, st in enumerate(streams) if st is not None]
    blobs = encode_residuals_batch([st for _, st in todo], backend=backend)
    payloads: list[bytes | None] = [None] * len(tiers)
    for (k, _), blob in zip(todo, blobs):
        payloads[k] = blob
    return CompressedSeries(
        base=base,
        base_bytes=base_bytes,
        pyramid=pyramid_layers(tiers, streams, payloads),
        eps_b_practical=eps_hat,
    )


def encode_frames_with_bases(
    values: np.ndarray,
    bases: list[Base],
    eps_targets: list[float],
    decimals: int | None = None,
    backend: str = "best",
) -> list[CompressedSeries]:
    """Batched ``encode_with_base`` over F equal-length frames whose bases
    are already constructed: one prediction pass, one pyramid
    quantization, and ONE entropy pass across every layer of every frame
    — each output byte-identical to
    ``encode_with_base(values[f], bases[f], ...)``.  Shared by the
    rectangular batch compressor and the streaming sealer (which batches
    every frame completed by a single ingest call)."""
    f_count, n = values.shape
    base_bytes = [encode_base(b) for b in bases]
    preds = base_predictions_batch(bases) if f_count else np.zeros((0, n))
    eps_hats = [
        practical_eps_b(values[i], bases[i], pred=preds[i]) for i in range(f_count)
    ]
    tiers = normalize_tiers(eps_targets, decimals)
    layer_streams = quantize_pyramid_batch(values, preds, tiers, decimals)
    # ONE entropy pass for every layer of every frame: the rANS batch
    # interleaves all of them into a single vectorized state machine
    todo = [
        (i, k, st)
        for i in range(f_count)
        for k, st in enumerate(layer_streams[i])
        if st is not None
    ]
    blobs = encode_residuals_batch([st for _, _, st in todo], backend=backend)
    payloads: list[list[bytes | None]] = [[None] * len(tiers) for _ in range(f_count)]
    for (i, k, _), blob in zip(todo, blobs):
        payloads[i][k] = blob
    return [
        CompressedSeries(
            base=bases[i],
            base_bytes=base_bytes[i],
            pyramid=pyramid_layers(tiers, layer_streams[i], payloads[i]),
            eps_b_practical=float(eps_hats[i]),
        )
        for i in range(f_count)
    ]


def cs_to_bytes(cs: CompressedSeries) -> bytes:
    """``SHRK`` v2 container: version byte, header (eps_hat, base length),
    a CRC32 over header-fields + base blob, the ``SHRB`` base, then the
    ``SHRR`` v3 residual pyramid blob (normative byte layout in
    docs/wire-format.md).

    The header CRC covers ``eps_hat || base_len || base_bytes`` — without
    it a flipped bit in the eps_hat f64 would silently change the
    *reported guarantee* of every answer served from this blob, which is
    exactly the "silent wrong data" failure degradation must rule out.
    A trusted header + base is also what makes base-only fallback sound
    when the pyramid section is damaged."""
    pyr = encode_pyramid(cs.pyramid)
    header = struct.pack("<dI", cs.eps_b_practical, len(cs.base_bytes))
    buf = bytearray()
    buf += _CONTAINER_MAGIC
    buf.append(_CONTAINER_VERSION)
    buf += header
    buf += struct.pack("<I", zlib.crc32(header + cs.base_bytes) & 0xFFFFFFFF)
    buf += cs.base_bytes
    buf += struct.pack("<I", len(pyr))
    buf += pyr
    return bytes(buf)


def cs_from_bytes(data: bytes, strict: bool = True) -> CompressedSeries:
    """Parse a ``SHRK`` v2 container.  Raises a :class:`ShrinkError`
    subclass (never a raw ``struct.error``/``IndexError``) on foreign,
    truncated, or trailing-garbage input — every length is validated
    before it is read, and the header/base CRC is always verified.

    ``strict`` is forwarded to :func:`decode_pyramid`: with
    ``strict=False`` a corrupt pyramid *layer* comes back quarantined
    (``layer.corrupt``) instead of raising, so a degraded reader can still
    serve the intact layer prefix under the (CRC-trusted) base and
    eps_hat."""
    data = bytes(data)
    if len(data) < 4 or data[:4] != _CONTAINER_MAGIC:
        raise FormatError("bad container magic: not a SHRK blob")
    if len(data) < 5:
        raise TruncatedArchiveError("truncated SHRK container: missing version")
    if data[4] != _CONTAINER_VERSION:
        raise FormatError(
            f"unsupported SHRK version {data[4]} (this build reads "
            f"v{_CONTAINER_VERSION} containers)"
        )
    if len(data) < 21:
        raise TruncatedArchiveError("truncated SHRK container: incomplete header")
    eps_hat, base_len = struct.unpack_from("<dI", data, 5)
    (hdr_crc,) = struct.unpack_from("<I", data, 17)
    pos = 21
    if pos + base_len > len(data):
        raise TruncatedArchiveError("truncated SHRK container: base blob cut short")
    base_bytes = data[pos : pos + base_len]
    pos += base_len
    if zlib.crc32(data[5:17] + base_bytes) & 0xFFFFFFFF != hdr_crc:
        raise CorruptFrameError("corrupt SHRK container: header/base CRC mismatch")
    if pos + 4 > len(data):
        raise TruncatedArchiveError("truncated SHRK container: missing pyramid length")
    (pyr_len,) = struct.unpack_from("<I", data, pos)
    pos += 4
    if pos + pyr_len > len(data):
        raise TruncatedArchiveError(
            "truncated SHRK container: residual pyramid cut short"
        )
    pyramid = decode_pyramid(data[pos : pos + pyr_len], strict=strict)
    pos += pyr_len
    if pos != len(data):
        raise CorruptFrameError("corrupt SHRK container: trailing bytes after pyramid")
    return CompressedSeries(
        base=decode_base(base_bytes),
        base_bytes=bytes(base_bytes),
        pyramid=pyramid,
        eps_b_practical=eps_hat,
    )
