"""The SHRINK codec (Alg. 1 of the paper): one base, many resolutions.

Usage:

    codec = ShrinkCodec.from_fraction(values, frac=0.05)     # eps_b = 5% range
    cs    = codec.compress(values, eps_targets=[1e-2, 1e-4], decimals=8)
    vhat  = codec.decompress_at(cs, 1e-4)                    # |vhat-v| <= 1e-4
    exact = codec.decompress_at(cs, 0.0)                     # lossless
    blob  = cs_to_bytes(cs); cs2 = cs_from_bytes(blob)

    # gateway-scale: S series in one vectorized pass — equal-length [S, T]
    # or a ragged list of 1-D arrays (length-bucketed, masked lanes)
    css   = codec.compress_batch(values_st, eps_targets=[1e-2])   # [S, T]
    css   = codec.compress_batch([v1, v2, v3], eps_targets=[1e-2])  # ragged

``eps == 0.0`` denotes the lossless stream (requires ``decimals``: the fixed
decimal precision of the source data, Table II's "Decimal" column).
"""
from __future__ import annotations

import math
import struct
import sys
from dataclasses import dataclass

import numpy as np

from .base import (
    base_predictions,
    base_predictions_batch,
    base_predictions_ragged,
    construct_base,
    practical_eps_b,
)
from .residuals import (
    dequantize_exact,
    dequantize_residuals,
    quantize_exact,
    quantize_exact_batch,
    quantize_residuals,
    quantize_residuals_batch,
)
from .semantics import (
    extract_semantics,
    extract_semantics_batch,
    extract_semantics_batch_pallas,
    global_range,
)
from .serialize import (
    decode_base,
    decode_residuals,
    encode_base,
    encode_residuals,
    encode_residuals_batch,
)
from .types import Base, CompressedSeries, ResidualStream, ShrinkConfig

__all__ = [
    "ShrinkCodec",
    "cs_to_bytes",
    "cs_from_bytes",
    "decompress_at",
    "encode_with_base",
    "original_size_bytes",
]

_CONTAINER_MAGIC = b"SHRK"

# The paper's Table II datasets store (timestamp, value) pairs; we account the
# original size as 16 bytes/row (two float64) — same accounting for every
# method in benchmarks/, so CRs are comparable across methods and with the
# paper's relative claims.
BYTES_PER_ROW = 16


def original_size_bytes(n: int) -> int:
    return BYTES_PER_ROW * n


@dataclass
class ShrinkCodec:
    config: ShrinkConfig
    backend: str = "best"

    @classmethod
    def from_fraction(
        cls,
        values: np.ndarray,
        frac: float = 0.05,
        lam: float = 1e-5,
        beta_levels: int = 16,
        backend: str = "best",
    ) -> "ShrinkCodec":
        vmin, vmax = global_range(np.asarray(values, dtype=np.float64))
        rng = max(vmax - vmin, 1e-12)
        return cls(
            config=ShrinkConfig(eps_b=frac * rng, lam=lam, beta_levels=beta_levels),
            backend=backend,
        )

    # ------------------------------------------------------------------ #
    def build_base(
        self,
        values: np.ndarray,
        value_range: tuple[float, float] | None = None,
        n_hint: int | None = None,
    ) -> Base:
        values = np.asarray(values, dtype=np.float64)
        segments = extract_semantics(values, self.config, value_range=value_range, n_hint=n_hint)
        if value_range is None:
            vmin, vmax = global_range(values)
        else:
            vmin, vmax = float(value_range[0]), float(value_range[1])
        return construct_base(segments, len(values), vmin, vmax, self.config)

    def compress(
        self,
        values: np.ndarray,
        eps_targets: list[float],
        decimals: int | None = None,
        value_range: tuple[float, float] | None = None,
        n_hint: int | None = None,
    ) -> CompressedSeries:
        """Alg. 1: extract semantics once, then one residual stream per eps.

        eps == 0.0 requests the lossless stream (needs ``decimals``).
        ``value_range``/``n_hint`` pin the scan's global quantities (see
        ``extract_semantics``) so an incremental scan over the same data —
        ``core.streaming.ShrinkStreamCodec`` — produces byte-identical
        output; ``None`` derives them from ``values`` as before.
        """
        values = np.asarray(values, dtype=np.float64)
        base = self.build_base(values, value_range=value_range, n_hint=n_hint)
        return encode_with_base(values, base, eps_targets, decimals, backend=self.backend)

    def compress_batch(
        self,
        values: np.ndarray | list[np.ndarray],
        eps_targets: list[float],
        decimals: int | None = None,
        semantics: str = "auto",
        lengths: np.ndarray | None = None,
        max_buckets: int = 4,
    ) -> list[CompressedSeries]:
        """Batched Alg. 1 over S independent series — rectangular or ragged.

        Accepted inputs:
        * ``values[S, T]`` ndarray — S equal-length series (the PR 1 fast
          path, unchanged);
        * ``values[S, T]`` + ``lengths[S]`` — ragged lanes padded to T, row
          i holding ``lengths[i]`` real samples;
        * a list of 1-D arrays of ANY mix of lengths (including empty and
          length-1 series) — the gateway's real multi-sensor regime.

        Ragged inputs are length-bucketed into ≤ ``max_buckets`` padded
        lanes (percentile buckets over the sorted lengths, so each bucket
        holds similarly sized series and padding waste stays bounded) and
        every stage runs the valid-length mask path: the multi-series cone
        scan carries per-lane segment IDs/lengths so padding never leaks
        into cones, residual quantization cuts each stream at its series'
        end, and ALL streams of all buckets share one rANS entropy pass
        (the masked ragged state machine).

        Semantics extraction runs as one multi-series cone scan per bucket —
        the lane-parallel Pallas kernel with XLA segment compaction on TPU,
        a chunked-vectorized numpy scan elsewhere.  With
        ``semantics="numpy"`` (the off-TPU default) every output is
        byte-identical to ``[self.compress(v, ...) for v in values]``,
        ragged or not (property-tested in tests/test_ragged_property.py).

        semantics: "auto" (pallas on TPU, numpy otherwise) | "numpy" |
        "pallas" (force the kernel route, e.g. for testing in interpret
        mode).
        """
        if semantics == "auto":
            # Only consult jax if something already imported it: forcing the
            # import costs ~1s, and a process that never touched jax is not
            # driving a TPU.
            jx = sys.modules.get("jax")
            try:
                on_tpu = jx is not None and jx.default_backend() == "tpu"
            except Exception:
                on_tpu = False
            semantics = "pallas" if on_tpu else "numpy"
        if semantics not in ("numpy", "pallas"):
            raise ValueError(f"unknown semantics impl {semantics!r}")

        if isinstance(values, (list, tuple)):
            if lengths is not None:
                raise ValueError("pass lengths only with a padded [S, T] array")
            arrs = [np.asarray(v, dtype=np.float64).ravel() for v in values]
            ns = np.array([a.size for a in arrs], dtype=np.int64)
            if ns.size and (ns == ns[0]).all():  # rectangular in disguise
                return self._compress_batch_rect(
                    np.stack(arrs) if ns[0] else np.zeros((ns.size, 0)),
                    eps_targets, decimals, semantics,
                )
            return self._compress_batch_ragged(arrs, ns, eps_targets, decimals,
                                               semantics, max_buckets)
        values = np.asarray(values, dtype=np.float64)
        if values.ndim != 2:
            raise ValueError(f"expected values[S, T], got shape {values.shape}")
        if lengths is not None:
            ns = np.asarray(lengths, dtype=np.int64).ravel()
            if ns.shape != (values.shape[0],):
                raise ValueError(
                    f"lengths must be [S]={values.shape[0]}, got shape {ns.shape}"
                )
            if (ns < 0).any() or (ns > values.shape[1]).any():
                raise ValueError(f"lengths must lie in [0, T={values.shape[1]}]")
            if (ns == values.shape[1]).all():
                return self._compress_batch_rect(values, eps_targets, decimals, semantics)
            arrs = [values[i, : ns[i]] for i in range(values.shape[0])]
            return self._compress_batch_ragged(arrs, ns, eps_targets, decimals,
                                               semantics, max_buckets)
        return self._compress_batch_rect(values, eps_targets, decimals, semantics)

    def _compress_batch_rect(
        self,
        values: np.ndarray,
        eps_targets: list[float],
        decimals: int | None,
        semantics: str,
    ) -> list[CompressedSeries]:
        """The equal-length fast path: one full-width scan, no masks."""
        s, n = values.shape
        if semantics == "pallas" and n:
            seg_lists = extract_semantics_batch_pallas(values, self.config)
        else:
            seg_lists = extract_semantics_batch(values, self.config)

        vmins = values.min(axis=1) if n else np.zeros(s)
        vmaxs = values.max(axis=1) if n else np.zeros(s)
        bases = [
            construct_base(seg_lists[i], n, float(vmins[i]), float(vmaxs[i]), self.config)
            for i in range(s)
        ]
        base_bytes = [encode_base(b) for b in bases]
        preds = base_predictions_batch(bases) if s else np.zeros((0, n))
        eps_hats = np.array(
            [practical_eps_b(values[i], bases[i], pred=preds[i]) for i in range(s)]
        )
        r = values - preds

        residuals: list[dict[float, bytes | None]] = [{} for _ in range(s)]
        todo: list[tuple[int, float, ResidualStream]] = []  # (series, eps, stream)
        for eps in eps_targets:
            if eps == 0.0:
                if decimals is None:
                    raise ValueError("lossless stream requires `decimals`")
                streams = quantize_exact_batch(values, preds, decimals)
                todo.extend((i, 0.0, streams[i]) for i in range(s))
                continue
            need = np.flatnonzero(eps < eps_hats)
            for i in range(s):
                residuals[i][eps] = None  # base-only unless quantized below
            if need.size:
                streams = quantize_residuals_batch(r[need], eps)
                todo.extend((int(i), eps, streams[j]) for j, i in enumerate(need))
        # one entropy pass for every stream of every target: the rANS batch
        # interleaves all of them into a single vectorized state machine
        blobs = encode_residuals_batch([st for _, _, st in todo], backend=self.backend)
        for (i, eps, _), blob in zip(todo, blobs):
            residuals[i][eps] = blob
        return [
            CompressedSeries(
                base=bases[i],
                base_bytes=base_bytes[i],
                residual_bytes=residuals[i],
                eps_b_practical=float(eps_hats[i]),
            )
            for i in range(s)
        ]

    def _compress_batch_ragged(
        self,
        arrs: list[np.ndarray],
        ns: np.ndarray,
        eps_targets: list[float],
        decimals: int | None,
        semantics: str,
        max_buckets: int,
    ) -> list[CompressedSeries]:
        """Mixed-length lanes: percentile length-buckets, masked scans, one
        shared entropy pass.  Byte-identical (numpy semantics) to a
        per-series ``compress`` loop."""
        if 0.0 in eps_targets and decimals is None:
            raise ValueError("lossless stream requires `decimals`")
        if max_buckets < 1:
            raise ValueError(f"max_buckets must be >= 1, got {max_buckets}")
        s = len(arrs)
        bases: list[Base | None] = [None] * s
        base_bytes: list[bytes | None] = [None] * s
        eps_hats = np.zeros(s)
        residuals: list[dict[float, bytes | None]] = [{} for _ in range(s)]
        todo: list[tuple[int, float, ResidualStream]] = []  # (series, eps, stream)

        nonempty = np.flatnonzero(ns > 0)
        for i in np.flatnonzero(ns == 0):
            # an empty series carries an empty base and empty/absent streams;
            # no batching to be had
            b = construct_base([], 0, 0.0, 0.0, self.config)
            cs = encode_with_base(arrs[i], b, eps_targets, decimals, backend=self.backend)
            bases[i], base_bytes[i] = cs.base, cs.base_bytes
            residuals[i] = cs.residual_bytes
            eps_hats[i] = cs.eps_b_practical

        # percentile buckets: equal-count groups of the length-sorted series,
        # each padded to its own max — bounded padding waste for any spread
        order = nonempty[np.argsort(ns[nonempty], kind="stable")]
        buckets = (
            [b for b in np.array_split(order, min(max_buckets, order.size)) if b.size]
            if order.size
            else []
        )
        for bucket in buckets:
            nb = ns[bucket]
            t_pad = int(nb.max())
            vals = np.zeros((bucket.size, t_pad))
            for row, i in enumerate(bucket):
                vals[row, : nb[row]] = arrs[i]
            if semantics == "pallas":
                seg_lists = extract_semantics_batch_pallas(vals, self.config, lengths=nb)
            else:
                seg_lists = extract_semantics_batch(vals, self.config, lengths=nb)
            valid = np.arange(t_pad)[None, :] < nb[:, None]
            vmins = np.where(valid, vals, np.inf).min(axis=1)
            vmaxs = np.where(valid, vals, -np.inf).max(axis=1)
            bkt_bases = [
                construct_base(
                    seg_lists[row], int(nb[row]), float(vmins[row]), float(vmaxs[row]),
                    self.config,
                )
                for row in range(bucket.size)
            ]
            preds = base_predictions_ragged(bkt_bases, t_pad)
            r = vals - preds
            bkt_eps_hats = np.abs(np.where(valid, r, 0.0)).max(axis=1)
            for row, i in enumerate(bucket):
                bases[i] = bkt_bases[row]
                base_bytes[i] = encode_base(bkt_bases[row])
                eps_hats[i] = bkt_eps_hats[row]
            for eps in eps_targets:
                if eps == 0.0:
                    streams = quantize_exact_batch(vals, preds, decimals, lengths=nb)
                    todo.extend(
                        (int(i), 0.0, streams[row]) for row, i in enumerate(bucket)
                    )
                    continue
                for i in bucket:
                    residuals[i][eps] = None  # base-only unless quantized below
                need = np.flatnonzero(eps < bkt_eps_hats)
                if need.size:
                    streams = quantize_residuals_batch(r[need], eps, lengths=nb[need])
                    todo.extend(
                        (int(bucket[row]), eps, streams[j])
                        for j, row in enumerate(need)
                    )
        # ONE entropy pass across every stream of every bucket and target:
        # the ragged rANS machine interleaves all of them
        blobs = encode_residuals_batch([st for _, _, st in todo], backend=self.backend)
        for (i, eps, _), blob in zip(todo, blobs):
            residuals[i][eps] = blob
        return [
            CompressedSeries(
                base=bases[i],
                base_bytes=base_bytes[i],
                residual_bytes=residuals[i],
                eps_b_practical=float(eps_hats[i]),
            )
            for i in range(s)
        ]

    def decompress_at(self, cs: CompressedSeries, eps: float) -> np.ndarray:
        return decompress_at(cs, eps)


def decompress_at(cs: CompressedSeries, eps: float) -> np.ndarray:
    """Reconstruct the series from ``cs`` at resolution ``eps``.  Stateless —
    everything needed lives in the compressed series itself, which is what
    lets range-decode consumers reconstruct frames without a codec."""
    if eps not in cs.residual_bytes:
        raise KeyError(f"no stream at eps={eps}")
    blob = cs.residual_bytes[eps]
    base = cs.base if cs.base is not None else decode_base(cs.base_bytes)
    pred = base_predictions(base)
    if blob is None:
        return pred
    stream = decode_residuals(blob)
    if stream.mode == "exact":
        decimals = int(round(-math.log10(stream.step)))
        return dequantize_exact(stream, base, decimals)
    return pred + dequantize_residuals(stream)


def encode_with_base(
    values: np.ndarray,
    base: Base,
    eps_targets: list[float],
    decimals: int | None = None,
    backend: str = "best",
) -> CompressedSeries:
    """Residual-encoding tail of Alg. 1: given an already-constructed base,
    emit one residual stream per eps target.  Shared by ``ShrinkCodec
    .compress`` and the streaming frame sealer so both produce identical
    bytes for identical (values, base) inputs."""
    values = np.asarray(values, dtype=np.float64)
    base_bytes = encode_base(base)
    pred = base_predictions(base)
    eps_hat = practical_eps_b(values, base, pred=pred)
    r = values - pred

    residual_bytes: dict[float, bytes | None] = {}
    for eps in eps_targets:
        if eps == 0.0:
            if decimals is None:
                raise ValueError("lossless stream requires `decimals`")
            stream = quantize_exact(values, base, decimals, pred=pred)
            residual_bytes[0.0] = encode_residuals(stream, backend=backend)
        elif eps >= eps_hat:
            residual_bytes[eps] = None  # base-only suffices (Alg.1 l.9-10)
        else:
            stream = quantize_residuals(r, eps)
            residual_bytes[eps] = encode_residuals(stream, backend=backend)
    return CompressedSeries(
        base=base,
        base_bytes=base_bytes,
        residual_bytes=residual_bytes,
        eps_b_practical=eps_hat,
    )


def cs_to_bytes(cs: CompressedSeries) -> bytes:
    """``SHRK`` container: base + directory of residual streams (normative
    byte layout in docs/wire-format.md)."""
    buf = bytearray()
    buf += _CONTAINER_MAGIC
    buf += struct.pack("<dI", cs.eps_b_practical, len(cs.base_bytes))
    buf += cs.base_bytes
    streams = sorted(cs.residual_bytes.items())
    buf += struct.pack("<I", len(streams))
    for eps, blob in streams:
        body = blob if blob is not None else b""
        buf += struct.pack("<dI", eps, len(body))
        buf += body
    return bytes(buf)


def cs_from_bytes(data: bytes) -> CompressedSeries:
    """Parse a ``SHRK`` container.  Raises ``ValueError`` (never a raw
    ``struct.error``/``IndexError``) on foreign, truncated, or trailing-
    garbage input — every length is validated before it is read."""
    data = bytes(data)
    if len(data) < 4 or data[:4] != _CONTAINER_MAGIC:
        raise ValueError("bad container magic: not a SHRK blob")
    if len(data) < 16:
        raise ValueError("truncated SHRK container: incomplete header")
    eps_hat, base_len = struct.unpack_from("<dI", data, 4)
    pos = 16
    if pos + base_len > len(data):
        raise ValueError("truncated SHRK container: base blob cut short")
    base_bytes = data[pos : pos + base_len]
    pos += base_len
    if pos + 4 > len(data):
        raise ValueError("truncated SHRK container: missing stream count")
    (n_streams,) = struct.unpack_from("<I", data, pos)
    pos += 4
    residual_bytes: dict[float, bytes | None] = {}
    for _ in range(n_streams):
        if pos + 12 > len(data):
            raise ValueError("truncated SHRK container: stream directory cut short")
        eps, ln = struct.unpack_from("<dI", data, pos)
        pos += 12
        if pos + ln > len(data):
            raise ValueError("truncated SHRK container: residual stream cut short")
        residual_bytes[eps] = data[pos : pos + ln] if ln else None
        pos += ln
    if pos != len(data):
        raise ValueError("corrupt SHRK container: trailing bytes after last stream")
    return CompressedSeries(
        base=decode_base(base_bytes),
        base_bytes=bytes(base_bytes),
        residual_bytes=residual_bytes,
        eps_b_practical=eps_hat,
    )
