"""The SHRINK codec (Alg. 1 of the paper): one base, many resolutions.

Usage:

    codec = ShrinkCodec.from_fraction(values, frac=0.05)     # eps_b = 5% range
    cs    = codec.compress(values, eps_targets=[1e-2, 1e-4], decimals=8)
    vhat  = codec.decompress_at(cs, 1e-4)                    # |vhat-v| <= 1e-4
    exact = codec.decompress_at(cs, 0.0)                     # lossless
    blob  = cs_to_bytes(cs); cs2 = cs_from_bytes(blob)

    # gateway-scale: S series of equal length in one vectorized pass
    css   = codec.compress_batch(values_st, eps_targets=[1e-2])   # [S, T]

``eps == 0.0`` denotes the lossless stream (requires ``decimals``: the fixed
decimal precision of the source data, Table II's "Decimal" column).
"""
from __future__ import annotations

import math
import struct
import sys
from dataclasses import dataclass

import numpy as np

from .base import (
    base_predictions,
    base_predictions_batch,
    construct_base,
    practical_eps_b,
)
from .residuals import (
    dequantize_exact,
    dequantize_residuals,
    quantize_exact,
    quantize_exact_batch,
    quantize_residuals,
    quantize_residuals_batch,
)
from .semantics import (
    extract_semantics,
    extract_semantics_batch,
    extract_semantics_batch_pallas,
    global_range,
)
from .serialize import (
    decode_base,
    decode_residuals,
    encode_base,
    encode_residuals,
    encode_residuals_batch,
)
from .types import Base, CompressedSeries, ResidualStream, ShrinkConfig

__all__ = [
    "ShrinkCodec",
    "cs_to_bytes",
    "cs_from_bytes",
    "decompress_at",
    "encode_with_base",
    "original_size_bytes",
]

_CONTAINER_MAGIC = b"SHRK"

# The paper's Table II datasets store (timestamp, value) pairs; we account the
# original size as 16 bytes/row (two float64) — same accounting for every
# method in benchmarks/, so CRs are comparable across methods and with the
# paper's relative claims.
BYTES_PER_ROW = 16


def original_size_bytes(n: int) -> int:
    return BYTES_PER_ROW * n


@dataclass
class ShrinkCodec:
    config: ShrinkConfig
    backend: str = "best"

    @classmethod
    def from_fraction(
        cls,
        values: np.ndarray,
        frac: float = 0.05,
        lam: float = 1e-5,
        beta_levels: int = 16,
        backend: str = "best",
    ) -> "ShrinkCodec":
        vmin, vmax = global_range(np.asarray(values, dtype=np.float64))
        rng = max(vmax - vmin, 1e-12)
        return cls(
            config=ShrinkConfig(eps_b=frac * rng, lam=lam, beta_levels=beta_levels),
            backend=backend,
        )

    # ------------------------------------------------------------------ #
    def build_base(
        self,
        values: np.ndarray,
        value_range: tuple[float, float] | None = None,
        n_hint: int | None = None,
    ) -> Base:
        values = np.asarray(values, dtype=np.float64)
        segments = extract_semantics(values, self.config, value_range=value_range, n_hint=n_hint)
        if value_range is None:
            vmin, vmax = global_range(values)
        else:
            vmin, vmax = float(value_range[0]), float(value_range[1])
        return construct_base(segments, len(values), vmin, vmax, self.config)

    def compress(
        self,
        values: np.ndarray,
        eps_targets: list[float],
        decimals: int | None = None,
        value_range: tuple[float, float] | None = None,
        n_hint: int | None = None,
    ) -> CompressedSeries:
        """Alg. 1: extract semantics once, then one residual stream per eps.

        eps == 0.0 requests the lossless stream (needs ``decimals``).
        ``value_range``/``n_hint`` pin the scan's global quantities (see
        ``extract_semantics``) so an incremental scan over the same data —
        ``core.streaming.ShrinkStreamCodec`` — produces byte-identical
        output; ``None`` derives them from ``values`` as before.
        """
        values = np.asarray(values, dtype=np.float64)
        base = self.build_base(values, value_range=value_range, n_hint=n_hint)
        return encode_with_base(values, base, eps_targets, decimals, backend=self.backend)

    def compress_batch(
        self,
        values: np.ndarray,
        eps_targets: list[float],
        decimals: int | None = None,
        semantics: str = "auto",
    ) -> list[CompressedSeries]:
        """Batched Alg. 1 over S independent equal-length series values[S, T].

        Semantics extraction for all series runs as one multi-series cone
        scan — the lane-parallel Pallas kernel with XLA segment compaction
        on TPU, a chunked-vectorized numpy scan elsewhere — and residual
        quantization plus the rANS entropy pass are batched across series.
        With ``semantics="numpy"`` (the off-TPU default) every output is
        byte-identical to ``[self.compress(v, ...) for v in values]``.

        semantics: "auto" (pallas on TPU, numpy otherwise) | "numpy" |
        "pallas" (force the kernel route, e.g. for testing in interpret
        mode).
        """
        values = np.asarray(values, dtype=np.float64)
        if values.ndim != 2:
            raise ValueError(f"expected values[S, T], got shape {values.shape}")
        s, n = values.shape
        if semantics == "auto":
            # Only consult jax if something already imported it: forcing the
            # import costs ~1s, and a process that never touched jax is not
            # driving a TPU.
            jx = sys.modules.get("jax")
            try:
                on_tpu = jx is not None and jx.default_backend() == "tpu"
            except Exception:
                on_tpu = False
            semantics = "pallas" if on_tpu else "numpy"
        if semantics == "pallas":
            seg_lists = extract_semantics_batch_pallas(values, self.config)
        elif semantics == "numpy":
            seg_lists = extract_semantics_batch(values, self.config)
        else:
            raise ValueError(f"unknown semantics impl {semantics!r}")

        vmins = values.min(axis=1) if n else np.zeros(s)
        vmaxs = values.max(axis=1) if n else np.zeros(s)
        bases = [
            construct_base(seg_lists[i], n, float(vmins[i]), float(vmaxs[i]), self.config)
            for i in range(s)
        ]
        base_bytes = [encode_base(b) for b in bases]
        preds = base_predictions_batch(bases) if s else np.zeros((0, n))
        eps_hats = np.array(
            [practical_eps_b(values[i], bases[i], pred=preds[i]) for i in range(s)]
        )
        r = values - preds

        residuals: list[dict[float, bytes | None]] = [{} for _ in range(s)]
        todo: list[tuple[int, float, ResidualStream]] = []  # (series, eps, stream)
        for eps in eps_targets:
            if eps == 0.0:
                if decimals is None:
                    raise ValueError("lossless stream requires `decimals`")
                streams = quantize_exact_batch(values, preds, decimals)
                todo.extend((i, 0.0, streams[i]) for i in range(s))
                continue
            need = np.flatnonzero(eps < eps_hats)
            for i in range(s):
                residuals[i][eps] = None  # base-only unless quantized below
            if need.size:
                streams = quantize_residuals_batch(r[need], eps)
                todo.extend((int(i), eps, streams[j]) for j, i in enumerate(need))
        # one entropy pass for every stream of every target: the rANS batch
        # interleaves all of them into a single vectorized state machine
        blobs = encode_residuals_batch([st for _, _, st in todo], backend=self.backend)
        for (i, eps, _), blob in zip(todo, blobs):
            residuals[i][eps] = blob
        return [
            CompressedSeries(
                base=bases[i],
                base_bytes=base_bytes[i],
                residual_bytes=residuals[i],
                eps_b_practical=float(eps_hats[i]),
            )
            for i in range(s)
        ]

    def decompress_at(self, cs: CompressedSeries, eps: float) -> np.ndarray:
        return decompress_at(cs, eps)


def decompress_at(cs: CompressedSeries, eps: float) -> np.ndarray:
    """Reconstruct the series from ``cs`` at resolution ``eps``.  Stateless —
    everything needed lives in the compressed series itself, which is what
    lets range-decode consumers reconstruct frames without a codec."""
    if eps not in cs.residual_bytes:
        raise KeyError(f"no stream at eps={eps}")
    blob = cs.residual_bytes[eps]
    base = cs.base if cs.base is not None else decode_base(cs.base_bytes)
    pred = base_predictions(base)
    if blob is None:
        return pred
    stream = decode_residuals(blob)
    if stream.mode == "exact":
        decimals = int(round(-math.log10(stream.step)))
        return dequantize_exact(stream, base, decimals)
    return pred + dequantize_residuals(stream)


def encode_with_base(
    values: np.ndarray,
    base: Base,
    eps_targets: list[float],
    decimals: int | None = None,
    backend: str = "best",
) -> CompressedSeries:
    """Residual-encoding tail of Alg. 1: given an already-constructed base,
    emit one residual stream per eps target.  Shared by ``ShrinkCodec
    .compress`` and the streaming frame sealer so both produce identical
    bytes for identical (values, base) inputs."""
    values = np.asarray(values, dtype=np.float64)
    base_bytes = encode_base(base)
    pred = base_predictions(base)
    eps_hat = practical_eps_b(values, base, pred=pred)
    r = values - pred

    residual_bytes: dict[float, bytes | None] = {}
    for eps in eps_targets:
        if eps == 0.0:
            if decimals is None:
                raise ValueError("lossless stream requires `decimals`")
            stream = quantize_exact(values, base, decimals, pred=pred)
            residual_bytes[0.0] = encode_residuals(stream, backend=backend)
        elif eps >= eps_hat:
            residual_bytes[eps] = None  # base-only suffices (Alg.1 l.9-10)
        else:
            stream = quantize_residuals(r, eps)
            residual_bytes[eps] = encode_residuals(stream, backend=backend)
    return CompressedSeries(
        base=base,
        base_bytes=base_bytes,
        residual_bytes=residual_bytes,
        eps_b_practical=eps_hat,
    )


def cs_to_bytes(cs: CompressedSeries) -> bytes:
    """Container: base + directory of residual streams."""
    buf = bytearray()
    buf += _CONTAINER_MAGIC
    buf += struct.pack("<dI", cs.eps_b_practical, len(cs.base_bytes))
    buf += cs.base_bytes
    streams = sorted(cs.residual_bytes.items())
    buf += struct.pack("<I", len(streams))
    for eps, blob in streams:
        body = blob if blob is not None else b""
        buf += struct.pack("<dI", eps, len(body))
        buf += body
    return bytes(buf)


def cs_from_bytes(data: bytes) -> CompressedSeries:
    """Parse a ``SHRK`` container.  Raises ``ValueError`` (never a raw
    ``struct.error``/``IndexError``) on foreign, truncated, or trailing-
    garbage input — every length is validated before it is read."""
    data = bytes(data)
    if len(data) < 4 or data[:4] != _CONTAINER_MAGIC:
        raise ValueError("bad container magic: not a SHRK blob")
    if len(data) < 16:
        raise ValueError("truncated SHRK container: incomplete header")
    eps_hat, base_len = struct.unpack_from("<dI", data, 4)
    pos = 16
    if pos + base_len > len(data):
        raise ValueError("truncated SHRK container: base blob cut short")
    base_bytes = data[pos : pos + base_len]
    pos += base_len
    if pos + 4 > len(data):
        raise ValueError("truncated SHRK container: missing stream count")
    (n_streams,) = struct.unpack_from("<I", data, pos)
    pos += 4
    residual_bytes: dict[float, bytes | None] = {}
    for _ in range(n_streams):
        if pos + 12 > len(data):
            raise ValueError("truncated SHRK container: stream directory cut short")
        eps, ln = struct.unpack_from("<dI", data, pos)
        pos += 12
        if pos + ln > len(data):
            raise ValueError("truncated SHRK container: residual stream cut short")
        residual_bytes[eps] = data[pos : pos + ln] if ln else None
        pos += ln
    if pos != len(data):
        raise ValueError("corrupt SHRK container: trailing bytes after last stream")
    return CompressedSeries(
        base=decode_base(base_bytes),
        base_bytes=bytes(base_bytes),
        residual_bytes=residual_bytes,
        eps_b_practical=eps_hat,
    )
