"""Residuals encoding (Alg. 6 + Eq. 6 of the paper).

Residuals are the element-wise difference between the original values and the
base (candidate-line) reconstruction.  Two quantization modes:

* ``midpoint`` (lossy): step = 2*eps_r, q = floor((r - r_lo)/step), dequant
  at the bin midpoint -> max abs error eps_r.  (The paper's Eq. 6 uses step
  eps_r with left-edge reconstruction, max error < eps_r; the midpoint
  variant meets the same |err| <= eps_r guarantee with half the symbol count,
  i.e. strictly better CR at equal guarantee.  Both satisfy Def. 1.)
* ``exact`` (lossless): for series with a fixed number of decimal places d,
  work in the integer domain at scale 10^d so reconstruction is bit-exact
  after rounding to d decimals.
"""
from __future__ import annotations

import numpy as np

from . import entropy
from .types import Base, ResidualStream
from .base import base_predictions

__all__ = [
    "compute_residuals",
    "quantize_residuals",
    "quantize_residuals_batch",
    "dequantize_residuals",
    "quantize_exact",
    "quantize_exact_batch",
    "dequantize_exact",
    "normalize_tiers",
    "quantize_pyramid",
    "quantize_pyramid_batch",
]

# row-block size (in elements) for batched quantization: keeps the per-tier
# [rows, T] float64 temporaries cache-resident (measured sweet spot on the
# bench box); rows are independent so blocking never changes bytes
_BATCH_BLOCK_ELEMS = 32 * 1024


def compute_residuals(values: np.ndarray, base: Base) -> np.ndarray:
    return np.asarray(values, dtype=np.float64) - base_predictions(base)


def _quantize_midpoint_rows(r: np.ndarray, eps_r: float) -> tuple[np.ndarray, np.ndarray]:
    """The midpoint quantizer on [S, T] rows: (q int64 [S, T], r_lo [S]).
    Row s is bit-identical to quantizing r[s] alone — every op is
    elementwise or a per-row reduction."""
    step = 2.0 * eps_r
    r_lo = r.min(axis=1) if r.size else np.zeros(r.shape[0])
    q = np.floor((r - r_lo[:, None]) / step).astype(np.int64)
    # Floor at bin boundaries can land one bin off in floating point (e.g.
    # 0.5/0.0002 -> 2499.999...); correct so |r - dequant| <= step/2 holds
    # exactly (up to one ulp of the final subtraction).
    deq = r_lo[:, None] + (q.astype(np.float64) + 0.5) * step
    q += (r - deq) > step / 2
    q -= (deq - r) > step / 2
    return q, r_lo


def quantize_residuals(r: np.ndarray, eps_r: float) -> ResidualStream:
    """Lossy path: |dequant - r| <= eps_r."""
    if eps_r <= 0:
        raise ValueError("eps_r must be positive for the lossy path")
    r = np.asarray(r, dtype=np.float64)
    q, r_lo = _quantize_midpoint_rows(r[None, :], eps_r)
    return ResidualStream(
        eps_r=eps_r, step=2.0 * eps_r, r_lo=float(r_lo[0]), mode="midpoint", q=q[0]
    )


def quantize_residuals_batch(
    r: np.ndarray, eps_r: float, lengths: np.ndarray | None = None
) -> list[ResidualStream]:
    """Batched lossy path over rows r[S, T]; stream i is byte-identical to
    ``quantize_residuals(r[i], eps_r)`` — or, with ``lengths`` (ragged rows
    padded to T), to ``quantize_residuals(r[i, :lengths[i]], eps_r)``:
    the per-row minimum is taken over the valid prefix only and each q
    stream is cut at its row's length, so padding never reaches the
    entropy coder."""
    if eps_r <= 0:
        raise ValueError("eps_r must be positive for the lossy path")
    r = np.asarray(r, dtype=np.float64)
    if lengths is None:
        q, r_lo = _quantize_midpoint_rows(r, eps_r)
        return [
            ResidualStream(
                eps_r=eps_r, step=2.0 * eps_r, r_lo=float(r_lo[i]), mode="midpoint", q=q[i]
            )
            for i in range(r.shape[0])
        ]
    ns = np.asarray(lengths, dtype=np.int64)
    pad = np.arange(r.shape[1])[None, :] >= ns[:, None]
    # pad with 0.0 so every elementwise op below stays finite; the per-row
    # min ignores padding via +inf substitution (exact same float result as
    # min over the unpadded slice)
    r = np.where(pad, 0.0, r)
    step = 2.0 * eps_r
    r_lo = np.where(
        ns > 0, np.where(pad, np.inf, r).min(axis=1, initial=np.inf), 0.0
    )
    q = np.floor((r - r_lo[:, None]) / step).astype(np.int64)
    deq = r_lo[:, None] + (q.astype(np.float64) + 0.5) * step
    q += (r - deq) > step / 2
    q -= (deq - r) > step / 2
    return [
        ResidualStream(
            eps_r=eps_r,
            step=step,
            r_lo=float(r_lo[i]),
            mode="midpoint",
            q=q[i, : ns[i]].copy(),
        )
        for i in range(r.shape[0])
    ]


def dequantize_residuals(stream: ResidualStream) -> np.ndarray:
    if stream.mode == "midpoint":
        return stream.r_lo + (stream.q.astype(np.float64) + 0.5) * stream.step
    raise ValueError(f"not a lossy stream: {stream.mode}")


def quantize_exact(
    values: np.ndarray, base: Base, decimals: int, pred: np.ndarray | None = None
) -> ResidualStream:
    """Lossless path for fixed-decimal data.

    v_int = round(v * 10^d); pred_int = round(pred * 10^d);
    q = v_int - pred_int  (exact int64).  Reconstruction returns
    (pred_int + q) / 10^d == round(v, d) exactly.  ``pred`` lets callers
    that already materialized the base reconstruction skip recomputing it.
    """
    if pred is None:
        pred = base_predictions(base)
    values = np.asarray(values, dtype=np.float64)
    return quantize_exact_batch(values[None, :], pred[None, :], decimals)[0]


def quantize_exact_batch(
    values: np.ndarray, preds: np.ndarray, decimals: int,
    lengths: np.ndarray | None = None,
) -> list[ResidualStream]:
    """Batched lossless path over rows values/preds[S, T]; stream i is
    byte-identical to ``quantize_exact(values[i], ..., pred=preds[i])``.
    With ``lengths`` (ragged rows padded to T) each q stream is cut at its
    row's length; the quantization itself is elementwise, so padding never
    influences the valid symbols."""
    scale = 10.0**decimals
    v_int = np.round(np.asarray(values, dtype=np.float64) * scale).astype(np.int64)
    p_int = np.round(preds * scale).astype(np.int64)
    q = v_int - p_int
    if lengths is None:
        return [
            ResidualStream(eps_r=0.0, step=1.0 / scale, r_lo=0.0, mode="exact", q=q[i])
            for i in range(v_int.shape[0])
        ]
    ns = np.asarray(lengths, dtype=np.int64)
    return [
        ResidualStream(
            eps_r=0.0, step=1.0 / scale, r_lo=0.0, mode="exact", q=q[i, : ns[i]].copy()
        )
        for i in range(v_int.shape[0])
    ]


def dequantize_exact(stream: ResidualStream, base: Base, decimals: int) -> np.ndarray:
    scale = 10.0**decimals
    pred = base_predictions(base)
    p_int = np.round(pred * scale).astype(np.int64)
    return (p_int + stream.q) / scale


# --------------------------------------------------------------------- #
# Refinement pyramid: tier k quantizes the reconstruction error of the
# prefix through tier k-1, so an archive with many tiers stores each bit of
# residual information once (docs/architecture.md, "progressive decode").
# --------------------------------------------------------------------- #
def normalize_tiers(eps_targets: list[float], decimals: int | None) -> list[float]:
    """Canonical tier ladder: unique eps targets sorted coarse -> fine
    (strictly decreasing), the lossless tier (0.0) last.  The pyramid is
    *defined* over this order — callers may pass targets in any order."""
    tiers = sorted({float(e) for e in eps_targets}, reverse=True)
    if tiers and tiers[-1] < 0.0:
        raise ValueError(f"eps targets must be >= 0, got {tiers[-1]}")
    if tiers and tiers[-1] == 0.0 and decimals is None:
        raise ValueError("lossless stream requires `decimals`")
    return tiers


def _midpoint_rows_masked(
    e: np.ndarray, eps_r: float, ns: np.ndarray | None
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Midpoint quantizer on rows e[S, T] (optionally ragged, padded past
    ``ns``): returns (q int64 [S, T], r_lo [S], deq [S, T]) where ``deq`` is
    recomputed from the *corrected* q — bitwise the array a decoder
    produces from (q, r_lo, step), which is what lets the encoder carry the
    decoder's reconstruction forward to the next layer."""
    step = 2.0 * eps_r
    if ns is None:
        r_lo = e.min(axis=1) if e.size else np.zeros(e.shape[0])
    else:
        pad = np.arange(e.shape[1])[None, :] >= ns[:, None]
        r_lo = np.where(
            ns > 0, np.where(pad, np.inf, e).min(axis=1, initial=np.inf), 0.0
        )
    q = np.floor((e - r_lo[:, None]) / step).astype(np.int64)
    # floor at bin boundaries can land one bin off in floating point; correct
    # so |e - dequant| <= step/2 holds exactly (same fix as the flat path)
    deq = r_lo[:, None] + (q.astype(np.float64) + 0.5) * step
    q += (e - deq) > step / 2
    q -= (deq - e) > step / 2
    deq = r_lo[:, None] + (q.astype(np.float64) + 0.5) * step
    return q, r_lo, deq


def quantize_pyramid_batch(
    values: np.ndarray,
    preds: np.ndarray,
    tiers: list[float],
    decimals: int | None = None,
    lengths: np.ndarray | None = None,
) -> list[list[ResidualStream | None]]:
    """Refinement-ladder quantization over rows values/preds[S, T].

    ``tiers`` must be the :func:`normalize_tiers` ladder (strictly
    decreasing, optional 0.0 last).  Returns ``layers[s][k]``: the
    ``ResidualStream`` of series s at tier k, or ``None`` (an *identity*
    layer) when the prefix through tier k-1 already meets tier k's
    guarantee — e.g. every tier above the practical base error.

    Guarantees, each property-tested in tests/test_pyramid_property.py:

    * per-tier: |values - reconstruction through tier k| <= tiers[k];
    * row s is bit-identical to the S == 1 call on (values[s], preds[s])
      (every op is elementwise or a per-row masked reduction), which is
      what keeps one-shot / streaming / batched / ragged paths
      byte-identical per tier;
    * the carried reconstruction is recomputed from the corrected integer
      symbols exactly as a decoder recomputes it, so the lossless tier's
      integer deltas match the decoder's integer view bit-for-bit.

    With ``lengths`` (ragged rows padded to T) the per-row reductions run
    over each row's valid prefix only and every emitted q stream is cut at
    its row's length, so padding never reaches the entropy coder.
    """
    values = np.asarray(values, dtype=np.float64)
    preds = np.asarray(preds, dtype=np.float64)
    s, t = values.shape
    ns = None if lengths is None else np.asarray(lengths, dtype=np.int64)
    # Cache blocking: each tier streams several [S, T] float64 temporaries;
    # for large batches those thrash cache and run ~1.7x slower than row
    # blocks that fit.  Every op is elementwise or a per-row reduction, so
    # block outputs concatenate unchanged (bit-identical rows).
    rows_blk = max(1, _BATCH_BLOCK_ELEMS // max(1, t))
    if s > rows_blk:
        blocks: list[list[ResidualStream | None]] = []
        for lo in range(0, s, rows_blk):
            blocks.extend(
                quantize_pyramid_batch(
                    values[lo : lo + rows_blk],
                    preds[lo : lo + rows_blk],
                    tiers,
                    decimals,
                    lengths=None if ns is None else ns[lo : lo + rows_blk],
                )
            )
        return blocks
    if ns is None:
        valid = None
    else:
        valid = np.arange(t)[None, :] < ns[:, None]
        values = np.where(valid, values, 0.0)
        preds = np.where(valid, preds, 0.0)
    out: list[list[ResidualStream | None]] = [[None] * len(tiers) for _ in range(s)]
    recon = preds.copy()
    for k, eps in enumerate(tiers):
        if eps == 0.0:
            if decimals is None:
                raise ValueError("lossless stream requires `decimals`")
            scale = 10.0**decimals
            v_int = np.round(values * scale).astype(np.int64)
            rec_int = np.round(recon * scale).astype(np.int64)
            q = v_int - rec_int
            for i in range(s):
                qi = q[i] if ns is None else q[i, : ns[i]].copy()
                out[i][k] = ResidualStream(
                    eps_r=0.0, step=1.0 / scale, r_lo=0.0, mode="exact", q=qi
                )
            continue
        e = values - recon
        if valid is not None:
            e = np.where(valid, e, 0.0)
        m = np.abs(e).max(axis=1) if t else np.zeros(s)
        need = np.flatnonzero(m > eps)
        if need.size == 0:
            continue  # identity layer for every row
        full = need.size == s
        q, r_lo, deq = _midpoint_rows_masked(
            e if full else e[need], eps, None if ns is None else ns[need]
        )
        # the elementwise add is identical either way; skipping the fancy
        # indexing when every row needs the layer (the common case) avoids
        # two full-matrix gather/scatter copies per tier
        if full:
            recon = recon + deq
        else:
            recon[need] = recon[need] + deq
        step = 2.0 * eps
        for j, i in enumerate(need):
            qi = q[j] if ns is None else q[j, : ns[i]].copy()
            out[int(i)][k] = ResidualStream(
                eps_r=eps, step=step, r_lo=float(r_lo[j]), mode="midpoint", q=qi
            )
    return out


def quantize_pyramid(
    values: np.ndarray,
    pred: np.ndarray,
    tiers: list[float],
    decimals: int | None = None,
) -> list[ResidualStream | None]:
    """Single-series refinement ladder — the S == 1 row of
    :func:`quantize_pyramid_batch` (same code path, hence bit-identical)."""
    values = np.asarray(values, dtype=np.float64)
    return quantize_pyramid_batch(values[None, :], pred[None, :], tiers, decimals)[0]


def encode_residuals_batch(
    streams: list[ResidualStream], backend: str = "best"
) -> list[bytes]:
    """Entropy-encode a batch of residual streams in one fused pass — the
    single funnel every pyramid producer (one-shot, rect-batch, ragged,
    streaming drain) routes through.  ``backend='best'`` partitions the
    batch per stream via the cost model and keeps the rans-bound group on
    the fused state machines; see :func:`repro.core.entropy.encode_ints_batch`."""
    return entropy.encode_ints_batch([st.q for st in streams], backend=backend)
