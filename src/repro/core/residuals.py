"""Residuals encoding (Alg. 6 + Eq. 6 of the paper).

Residuals are the element-wise difference between the original values and the
base (candidate-line) reconstruction.  Two quantization modes:

* ``midpoint`` (lossy): step = 2*eps_r, q = floor((r - r_lo)/step), dequant
  at the bin midpoint -> max abs error eps_r.  (The paper's Eq. 6 uses step
  eps_r with left-edge reconstruction, max error < eps_r; the midpoint
  variant meets the same |err| <= eps_r guarantee with half the symbol count,
  i.e. strictly better CR at equal guarantee.  Both satisfy Def. 1.)
* ``exact`` (lossless): for series with a fixed number of decimal places d,
  work in the integer domain at scale 10^d so reconstruction is bit-exact
  after rounding to d decimals.
"""
from __future__ import annotations

import numpy as np

from .types import Base, ResidualStream
from .base import base_predictions

__all__ = [
    "compute_residuals",
    "quantize_residuals",
    "quantize_residuals_batch",
    "dequantize_residuals",
    "quantize_exact",
    "quantize_exact_batch",
    "dequantize_exact",
]


def compute_residuals(values: np.ndarray, base: Base) -> np.ndarray:
    return np.asarray(values, dtype=np.float64) - base_predictions(base)


def _quantize_midpoint_rows(r: np.ndarray, eps_r: float) -> tuple[np.ndarray, np.ndarray]:
    """The midpoint quantizer on [S, T] rows: (q int64 [S, T], r_lo [S]).
    Row s is bit-identical to quantizing r[s] alone — every op is
    elementwise or a per-row reduction."""
    step = 2.0 * eps_r
    r_lo = r.min(axis=1) if r.size else np.zeros(r.shape[0])
    q = np.floor((r - r_lo[:, None]) / step).astype(np.int64)
    # Floor at bin boundaries can land one bin off in floating point (e.g.
    # 0.5/0.0002 -> 2499.999...); correct so |r - dequant| <= step/2 holds
    # exactly (up to one ulp of the final subtraction).
    deq = r_lo[:, None] + (q.astype(np.float64) + 0.5) * step
    q += (r - deq) > step / 2
    q -= (deq - r) > step / 2
    return q, r_lo


def quantize_residuals(r: np.ndarray, eps_r: float) -> ResidualStream:
    """Lossy path: |dequant - r| <= eps_r."""
    if eps_r <= 0:
        raise ValueError("eps_r must be positive for the lossy path")
    r = np.asarray(r, dtype=np.float64)
    q, r_lo = _quantize_midpoint_rows(r[None, :], eps_r)
    return ResidualStream(
        eps_r=eps_r, step=2.0 * eps_r, r_lo=float(r_lo[0]), mode="midpoint", q=q[0]
    )


def quantize_residuals_batch(
    r: np.ndarray, eps_r: float, lengths: np.ndarray | None = None
) -> list[ResidualStream]:
    """Batched lossy path over rows r[S, T]; stream i is byte-identical to
    ``quantize_residuals(r[i], eps_r)`` — or, with ``lengths`` (ragged rows
    padded to T), to ``quantize_residuals(r[i, :lengths[i]], eps_r)``:
    the per-row minimum is taken over the valid prefix only and each q
    stream is cut at its row's length, so padding never reaches the
    entropy coder."""
    if eps_r <= 0:
        raise ValueError("eps_r must be positive for the lossy path")
    r = np.asarray(r, dtype=np.float64)
    if lengths is None:
        q, r_lo = _quantize_midpoint_rows(r, eps_r)
        return [
            ResidualStream(
                eps_r=eps_r, step=2.0 * eps_r, r_lo=float(r_lo[i]), mode="midpoint", q=q[i]
            )
            for i in range(r.shape[0])
        ]
    ns = np.asarray(lengths, dtype=np.int64)
    pad = np.arange(r.shape[1])[None, :] >= ns[:, None]
    # pad with 0.0 so every elementwise op below stays finite; the per-row
    # min ignores padding via +inf substitution (exact same float result as
    # min over the unpadded slice)
    r = np.where(pad, 0.0, r)
    step = 2.0 * eps_r
    r_lo = np.where(
        ns > 0, np.where(pad, np.inf, r).min(axis=1, initial=np.inf), 0.0
    )
    q = np.floor((r - r_lo[:, None]) / step).astype(np.int64)
    deq = r_lo[:, None] + (q.astype(np.float64) + 0.5) * step
    q += (r - deq) > step / 2
    q -= (deq - r) > step / 2
    return [
        ResidualStream(
            eps_r=eps_r,
            step=step,
            r_lo=float(r_lo[i]),
            mode="midpoint",
            q=q[i, : ns[i]].copy(),
        )
        for i in range(r.shape[0])
    ]


def dequantize_residuals(stream: ResidualStream) -> np.ndarray:
    if stream.mode == "midpoint":
        return stream.r_lo + (stream.q.astype(np.float64) + 0.5) * stream.step
    raise ValueError(f"not a lossy stream: {stream.mode}")


def quantize_exact(
    values: np.ndarray, base: Base, decimals: int, pred: np.ndarray | None = None
) -> ResidualStream:
    """Lossless path for fixed-decimal data.

    v_int = round(v * 10^d); pred_int = round(pred * 10^d);
    q = v_int - pred_int  (exact int64).  Reconstruction returns
    (pred_int + q) / 10^d == round(v, d) exactly.  ``pred`` lets callers
    that already materialized the base reconstruction skip recomputing it.
    """
    if pred is None:
        pred = base_predictions(base)
    values = np.asarray(values, dtype=np.float64)
    return quantize_exact_batch(values[None, :], pred[None, :], decimals)[0]


def quantize_exact_batch(
    values: np.ndarray, preds: np.ndarray, decimals: int,
    lengths: np.ndarray | None = None,
) -> list[ResidualStream]:
    """Batched lossless path over rows values/preds[S, T]; stream i is
    byte-identical to ``quantize_exact(values[i], ..., pred=preds[i])``.
    With ``lengths`` (ragged rows padded to T) each q stream is cut at its
    row's length; the quantization itself is elementwise, so padding never
    influences the valid symbols."""
    scale = 10.0**decimals
    v_int = np.round(np.asarray(values, dtype=np.float64) * scale).astype(np.int64)
    p_int = np.round(preds * scale).astype(np.int64)
    q = v_int - p_int
    if lengths is None:
        return [
            ResidualStream(eps_r=0.0, step=1.0 / scale, r_lo=0.0, mode="exact", q=q[i])
            for i in range(v_int.shape[0])
        ]
    ns = np.asarray(lengths, dtype=np.int64)
    return [
        ResidualStream(
            eps_r=0.0, step=1.0 / scale, r_lo=0.0, mode="exact", q=q[i, : ns[i]].copy()
        )
        for i in range(v_int.shape[0])
    ]


def dequantize_exact(stream: ResidualStream, base: Base, decimals: int) -> np.ndarray:
    scale = 10.0**decimals
    pred = base_predictions(base)
    p_int = np.round(pred * scale).astype(np.int64)
    return (p_int + stream.q) / scale
