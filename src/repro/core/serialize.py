"""Byte-level serialization of the SHRINK knowledge base and residuals.

Compression ratios in the paper are measured on real bytes; so are ours.
This module implements the ``SHRB`` base blob, the ``SHRR`` residual blob,
and the ``SHRKS`` framed stream container (append-only frames, directory +
knowledge base in a CRC'd footer, fixed 16-byte tail).

**The normative byte-layout spec — field tables, CRC rules, version-bump
procedure, golden-fixture regeneration — lives in
``docs/wire-format.md``.**  Change bytes only together with that document
and the golden fixtures under ``tests/golden/``.
"""
from __future__ import annotations

import struct
import zlib

import numpy as np

from .base import origin_index
from .phases import eps_hat_for_level
from .types import (
    Base,
    FrameMeta,
    PyramidLayer,
    ResidualPyramid,
    ResidualStream,
    ShrinkConfig,
    SubBase,
)

__all__ = [
    "write_varint",
    "read_varint",
    "encode_base",
    "decode_base",
    "pyramid_layers",
    "encode_pyramid",
    "decode_pyramid",
    "FramedWriter",
    "parse_framed_container",
    "frame_payload",
]

_BASE_MAGIC = b"SHRB"
_RES_MAGIC = b"SHRR"
_VERSION = 1
_RES_VERSION = 2
_MODE_CODE = {"midpoint": 0, "exact": 1, "identity": 2}
_MODE_NAME = {v: k for k, v in _MODE_CODE.items()}
_RAW_SLOPE = 255

_STREAM_MAGIC = b"SHRKS"
_STREAM_END_MAGIC = b"SHRE"
_STREAM_VERSION = 1
_TAIL_LEN = 8 + 4 + 4  # u64 footer offset + u32 footer crc + end magic


def write_varint(buf: bytearray, x: int) -> None:
    if x < 0:
        raise ValueError("varint must be non-negative")
    while True:
        b = x & 0x7F
        x >>= 7
        if x:
            buf.append(b | 0x80)
        else:
            buf.append(b)
            return


def read_varint(data: bytes, pos: int) -> tuple[int, int]:
    shift = 0
    out = 0
    while True:
        b = data[pos]
        pos += 1
        out |= (b & 0x7F) << shift
        if not (b & 0x80):
            return out, pos
        shift += 7


def _write_svarint(buf: bytearray, x: int) -> None:
    write_varint(buf, (x << 1) ^ (x >> 63) if x < 0 else (x << 1))


def _read_svarint(data: bytes, pos: int) -> tuple[int, int]:
    z, pos = read_varint(data, pos)
    return (z >> 1) ^ -(z & 1), pos


def encode_base(base: Base) -> bytes:
    buf = bytearray()
    buf += _BASE_MAGIC
    buf.append(_VERSION)
    write_varint(buf, base.n)
    buf += struct.pack("<ddB", base.config.eps_b, base.config.lam, base.config.beta_levels)
    buf += struct.pack("<dd", base.vmin, base.vmax)
    write_varint(buf, len(base.subbases))
    prev_idx_by_level: dict[int, int] = {}
    for sb in base.subbases:
        buf.append(sb.level & 0xFF)
        idx = origin_index(sb.theta, sb.level, base.config)
        prev = prev_idx_by_level.get(sb.level, 0)
        _write_svarint(buf, idx - prev)
        prev_idx_by_level[sb.level] = idx
        if sb.slope_digits <= 13:
            buf.append(sb.slope_digits)
            _write_svarint(buf, int(round(sb.slope * 10**sb.slope_digits)))
        else:
            buf.append(_RAW_SLOPE)
            buf += struct.pack("<d", sb.slope)
        write_varint(buf, len(sb.t0s))
        prev_t = 0
        for t0 in sb.t0s.tolist():
            write_varint(buf, t0 - prev_t)
            prev_t = t0
    return bytes(buf)


def decode_base(data: bytes) -> Base:
    if data[:4] != _BASE_MAGIC:
        raise ValueError("bad base magic")
    try:
        return _decode_base_body(data)
    except (IndexError, struct.error) as e:
        raise ValueError(f"truncated or corrupt base blob: {e}") from e


def _decode_base_body(data: bytes) -> Base:
    pos = 5  # magic + version
    n, pos = read_varint(data, pos)
    eps_b, lam, beta_levels = struct.unpack_from("<ddB", data, pos)
    pos += 17
    vmin, vmax = struct.unpack_from("<dd", data, pos)
    pos += 16
    config = ShrinkConfig(eps_b=eps_b, lam=lam, beta_levels=beta_levels)
    k, pos = read_varint(data, pos)
    subbases: list[SubBase] = []
    prev_idx_by_level: dict[int, int] = {}
    for _ in range(k):
        level = data[pos]
        pos += 1
        didx, pos = _read_svarint(data, pos)
        idx = prev_idx_by_level.get(level, 0) + didx
        prev_idx_by_level[level] = idx
        eps_hat = eps_hat_for_level(level, config)
        theta = idx * eps_hat
        digits = data[pos]
        pos += 1
        if digits == _RAW_SLOPE:
            (slope,) = struct.unpack_from("<d", data, pos)
            pos += 8
            digits = 13
        else:
            scaled, pos = _read_svarint(data, pos)
            slope = scaled / 10**digits
        m, pos = read_varint(data, pos)
        t0s = np.empty(m, dtype=np.int64)
        prev_t = 0
        for i in range(m):
            dt, pos = read_varint(data, pos)
            t0 = prev_t + dt
            prev_t = t0
            t0s[i] = t0
        subbases.append(
            SubBase(
                theta=theta,
                level=level,
                psi_lo=slope,
                psi_hi=slope,
                slope=slope,
                slope_digits=digits,
                t0s=t0s,
                lengths=np.zeros(m, dtype=np.int64),  # filled below
            )
        )
    # Segments partition [0, n): recover lengths from the global t0 order.
    flat = [(int(t0), si, mi) for si, sb in enumerate(subbases) for mi, t0 in enumerate(sb.t0s.tolist())]
    flat.sort()
    for j, (t0, si, mi) in enumerate(flat):
        end = flat[j + 1][0] if j + 1 < len(flat) else n
        subbases[si].lengths[mi] = end - t0
    return Base(n=n, config=config, vmin=vmin, vmax=vmax, subbases=subbases)


# --------------------------------------------------------------------- #
# SHRR v2: the residual pyramid blob (per-layer directory + payload CRC;
# normative byte layout in docs/wire-format.md)
# --------------------------------------------------------------------- #
def pyramid_layers(
    tiers: list[float],
    streams: list[ResidualStream | None],
    payloads: list[bytes | None],
) -> ResidualPyramid:
    """Assemble a :class:`ResidualPyramid` from the quantizer's per-tier
    streams and their already-entropy-coded payloads (``None`` at tier k
    means an identity layer).  Split from :func:`encode_pyramid` so batch
    compressors can run ONE entropy pass over every (series, layer) stream
    and then assemble each series' pyramid from the shared result."""
    layers: list[PyramidLayer] = []
    for eps, st, payload in zip(tiers, streams, payloads):
        if st is None:
            layers.append(
                PyramidLayer(eps=eps, mode="identity", step=0.0, r_lo=0.0, payload=None)
            )
        else:
            layers.append(
                PyramidLayer(
                    eps=eps, mode=st.mode, step=st.step, r_lo=st.r_lo, payload=payload
                )
            )
    return ResidualPyramid(layers=layers)


def encode_pyramid(pyramid: ResidualPyramid) -> bytes:
    """``SHRR`` v2 blob: version, per-layer directory (eps, mode, quantizer
    params, payload length), CRC32 of directory + payload sections, then
    the concatenated tagged entropy payloads in layer order."""
    directory = bytearray()
    body = bytearray()
    for layer in pyramid.layers:
        payload = layer.payload if layer.payload is not None else b""
        if layer.mode == "identity" and payload:
            raise ValueError("identity layer cannot carry a payload")
        directory += struct.pack("<d", layer.eps)
        directory.append(_MODE_CODE[layer.mode])
        directory += struct.pack("<dd", layer.step, layer.r_lo)
        write_varint(directory, len(payload))
        body += payload
    buf = bytearray()
    buf += _RES_MAGIC
    buf.append(_RES_VERSION)
    write_varint(buf, len(pyramid.layers))
    buf += directory
    # one CRC over directory + payloads: a one-shot SHRK blob has no outer
    # CRC, and a flipped f64 in the directory corrupts decode as surely as
    # a flipped payload byte
    buf += struct.pack(
        "<I", zlib.crc32(bytes(directory) + bytes(body)) & 0xFFFFFFFF
    )
    buf += body
    return bytes(buf)


def decode_pyramid(data: bytes) -> ResidualPyramid:
    """Parse a ``SHRR`` v2 blob.  Raises ``ValueError`` (never a raw
    ``struct.error``/``IndexError``) on foreign, truncated, or corrupt
    input, including a payload-section CRC mismatch."""
    data = bytes(data)
    if len(data) < 4 or data[:4] != _RES_MAGIC:
        raise ValueError("bad residual pyramid magic: not a SHRR blob")
    if len(data) < 5:
        raise ValueError("truncated SHRR blob: missing version")
    if data[4] != _RES_VERSION:
        raise ValueError(
            f"unsupported SHRR version {data[4]} (this build reads v{_RES_VERSION} "
            "refinement pyramids; v1 independent-stream archives must be re-encoded)"
        )
    try:
        pos = 5
        n_layers, pos = read_varint(data, pos)
        dir_start = pos
        dirent: list[tuple[float, int, float, float, int]] = []
        for _ in range(n_layers):
            if pos + 25 > len(data):
                raise ValueError("truncated SHRR blob: layer directory cut short")
            (eps,) = struct.unpack_from("<d", data, pos)
            mode_code = data[pos + 8]
            step, r_lo = struct.unpack_from("<dd", data, pos + 9)
            pos += 25
            ln, pos = read_varint(data, pos)
            dirent.append((eps, mode_code, step, r_lo, ln))
    except (IndexError, struct.error) as e:
        raise ValueError(f"truncated or corrupt SHRR blob: {e}") from e
    directory = data[dir_start:pos]
    if pos + 4 > len(data):
        raise ValueError("truncated SHRR blob: missing CRC")
    (crc,) = struct.unpack_from("<I", data, pos)
    pos += 4
    body = data[pos:]
    if len(body) != sum(ln for *_, ln in dirent):
        raise ValueError("corrupt SHRR blob: payload section length mismatch")
    if zlib.crc32(directory + body) & 0xFFFFFFFF != crc:
        raise ValueError("corrupt SHRR blob: CRC mismatch")
    # the tier-ladder invariant resolve() depends on is normative: eps
    # strictly decreasing coarse -> fine (0.0, the lossless tier, last)
    eps_seq = [e for e, *_ in dirent]
    if any(e < 0.0 for e in eps_seq):
        raise ValueError("corrupt SHRR blob: negative tier eps")
    if any(b >= a for a, b in zip(eps_seq, eps_seq[1:])):
        raise ValueError(
            "corrupt SHRR blob: tiers not strictly decreasing coarse -> fine"
        )
    layers: list[PyramidLayer] = []
    off = 0
    for eps, mode_code, step, r_lo, ln in dirent:
        if mode_code not in _MODE_NAME:
            raise ValueError(f"corrupt SHRR blob: unknown layer mode {mode_code}")
        mode = _MODE_NAME[mode_code]
        if mode == "identity" and ln:
            raise ValueError("corrupt SHRR blob: identity layer with payload")
        if mode != "identity" and not ln:
            raise ValueError(f"corrupt SHRR blob: {mode} layer without payload")
        payload = body[off : off + ln] if ln else None
        off += ln
        layers.append(
            PyramidLayer(eps=eps, mode=mode, step=step, r_lo=r_lo, payload=payload)
        )
    return ResidualPyramid(layers=layers)


# --------------------------------------------------------------------- #
# SHRKS framed stream container (layout table in the module docstring)
# --------------------------------------------------------------------- #
class FramedWriter:
    """Append-only writer for the ``SHRKS`` container.

    Frames are appended in seal order (any interleaving of series);
    ``finish`` emits the directory footer + knowledge-base section + tail.
    """

    def __init__(self) -> None:
        self._buf = bytearray()
        self._buf += _STREAM_MAGIC
        self._buf.append(_STREAM_VERSION)
        self._frames: list[FrameMeta] = []
        self._finished = False

    def add_frame(
        self, series_id: int, t_lo: int, t_hi: int, kb_epoch: int, payload: bytes
    ) -> FrameMeta:
        if self._finished:
            raise ValueError("container already finished")
        if t_hi <= t_lo:
            raise ValueError(f"empty frame range [{t_lo}, {t_hi})")
        meta = FrameMeta(
            series_id=int(series_id),
            t_lo=int(t_lo),
            t_hi=int(t_hi),
            kb_epoch=int(kb_epoch),
            offset=len(self._buf),
            length=len(payload),
            crc32=zlib.crc32(payload) & 0xFFFFFFFF,
        )
        self._buf += payload
        self._frames.append(meta)
        return meta

    def finish(self, kb_bytes: bytes = b"") -> bytes:
        if self._finished:
            raise ValueError("container already finished")
        self._finished = True
        footer = bytearray()
        write_varint(footer, len(self._frames))
        for m in self._frames:
            write_varint(footer, m.series_id)
            write_varint(footer, m.t_lo)
            write_varint(footer, m.t_hi - m.t_lo)
            write_varint(footer, m.kb_epoch)
            write_varint(footer, m.offset)
            write_varint(footer, m.length)
            footer += struct.pack("<I", m.crc32)
        write_varint(footer, len(kb_bytes))
        footer += kb_bytes
        footer_offset = len(self._buf)
        self._buf += footer
        self._buf += struct.pack("<QI", footer_offset, zlib.crc32(bytes(footer)) & 0xFFFFFFFF)
        self._buf += _STREAM_END_MAGIC
        return bytes(self._buf)


def parse_framed_container(blob: bytes) -> tuple[list[FrameMeta], bytes]:
    """Validate head/tail/footer of a ``SHRKS`` container and return
    (frame directory, kb_bytes).  Raises ``ValueError`` on foreign,
    truncated, or corrupt input (including a footer CRC mismatch).
    Frame *payload* CRCs are NOT checked here — see ``frame_payload``."""
    blob = bytes(blob)
    if len(blob) < 6 or blob[:5] != _STREAM_MAGIC:
        raise ValueError("bad container magic: not a SHRKS blob")
    if blob[5] != _STREAM_VERSION:
        raise ValueError(f"unsupported SHRKS version {blob[5]}")
    if len(blob) < 6 + _TAIL_LEN:
        raise ValueError("truncated SHRKS container: missing tail")
    if blob[-4:] != _STREAM_END_MAGIC:
        raise ValueError("truncated SHRKS container: bad end magic")
    footer_offset, footer_crc = struct.unpack_from("<QI", blob, len(blob) - _TAIL_LEN)
    if footer_offset < 6 or footer_offset > len(blob) - _TAIL_LEN:
        raise ValueError("corrupt SHRKS container: footer offset out of range")
    footer = blob[footer_offset : len(blob) - _TAIL_LEN]
    if zlib.crc32(footer) & 0xFFFFFFFF != footer_crc:
        raise ValueError("corrupt SHRKS container: footer CRC mismatch")
    try:
        pos = 0
        n_frames, pos = read_varint(footer, pos)
        metas: list[FrameMeta] = []
        for _ in range(n_frames):
            sid, pos = read_varint(footer, pos)
            t_lo, pos = read_varint(footer, pos)
            n, pos = read_varint(footer, pos)
            epoch, pos = read_varint(footer, pos)
            off, pos = read_varint(footer, pos)
            ln, pos = read_varint(footer, pos)
            (crc,) = struct.unpack_from("<I", footer, pos)
            pos += 4
            if off + ln > footer_offset:
                raise ValueError("corrupt SHRKS container: frame extends into footer")
            metas.append(
                FrameMeta(
                    series_id=sid, t_lo=t_lo, t_hi=t_lo + n, kb_epoch=epoch,
                    offset=off, length=ln, crc32=crc,
                )
            )
        kb_len, pos = read_varint(footer, pos)
        if pos + kb_len != len(footer):
            raise ValueError("corrupt SHRKS container: knowledge-base section length mismatch")
        kb_bytes = bytes(footer[pos : pos + kb_len])
    except (IndexError, struct.error) as e:
        raise ValueError(f"corrupt SHRKS container: footer parse failed: {e}") from e
    return metas, kb_bytes


def frame_payload(blob: bytes, meta: FrameMeta, verify_crc: bool = True) -> bytes:
    """Extract one frame's payload (a complete ``SHRK`` blob), checking its
    directory CRC unless ``verify_crc=False``."""
    payload = bytes(blob[meta.offset : meta.offset + meta.length])
    if len(payload) != meta.length:
        raise ValueError("truncated SHRKS container: frame payload cut short")
    if verify_crc and zlib.crc32(payload) & 0xFFFFFFFF != meta.crc32:
        raise ValueError(
            f"frame payload CRC mismatch (series {meta.series_id}, "
            f"samples [{meta.t_lo}, {meta.t_hi}))"
        )
    return payload
