"""Byte-level serialization of the SHRINK knowledge base and residuals.

Compression ratios in the paper are measured on real bytes; so are ours.
This module implements the ``SHRB`` base blob, the ``SHRR`` residual blob,
and the ``SHRKS`` framed stream container (append-only frames, directory +
knowledge base in a CRC'd footer, fixed 16-byte tail).

**The normative byte-layout spec — field tables, CRC rules, version-bump
procedure, golden-fixture regeneration — lives in
``docs/wire-format.md``.**  Change bytes only together with that document
and the golden fixtures under ``tests/golden/``.
"""
from __future__ import annotations

import dataclasses
import struct
import zlib

import numpy as np

from .base import origin_index
from .errors import (
    BatcherFinalizedError,
    ConfigError,
    CorruptFrameError,
    FormatError,
    LayerCorruptError,
    ShrinkError,
    TruncatedArchiveError,
)
from .phases import eps_hat_for_level
from .types import (
    Base,
    FrameMeta,
    PyramidLayer,
    ResidualPyramid,
    ResidualStream,
    ShrinkConfig,
    SubBase,
)

__all__ = [
    "write_varint",
    "read_varint",
    "encode_base",
    "decode_base",
    "pyramid_layers",
    "encode_pyramid",
    "decode_pyramid",
    "FramedWriter",
    "parse_framed_container",
    "frame_payload",
    "kb_snapshot_id",
    "KBSnapshotRef",
    "read_snapshot_ref",
]

_BASE_MAGIC = b"SHRB"
_RES_MAGIC = b"SHRR"
_VERSION = 1
_RES_VERSION = 3
_MODE_CODE = {"midpoint": 0, "exact": 1, "identity": 2}
_MODE_NAME = {v: k for k, v in _MODE_CODE.items()}
_RAW_SLOPE = 255

_STREAM_MAGIC = b"SHRKS"
_STREAM_END_MAGIC = b"SHRE"
# v2 appended the kb_snapshot_ref section (flag byte + optional ref) to the
# footer, after the inline knowledge-base section.  v1 blobs are rejected.
_STREAM_VERSION = 2
_TAIL_LEN = 8 + 4 + 4  # u64 footer offset + u32 footer crc + end magic


def write_varint(buf: bytearray, x: int) -> None:
    if x < 0:
        raise FormatError("varint must be non-negative")
    while True:
        b = x & 0x7F
        x >>= 7
        if x:
            buf.append(b | 0x80)
        else:
            buf.append(b)
            return


def read_varint(data: bytes, pos: int) -> tuple[int, int]:
    shift = 0
    out = 0
    while True:
        b = data[pos]
        pos += 1
        out |= (b & 0x7F) << shift
        if not (b & 0x80):
            return out, pos
        shift += 7


def _write_svarint(buf: bytearray, x: int) -> None:
    write_varint(buf, (x << 1) ^ (x >> 63) if x < 0 else (x << 1))


def _read_svarint(data: bytes, pos: int) -> tuple[int, int]:
    z, pos = read_varint(data, pos)
    return (z >> 1) ^ -(z & 1), pos


def encode_base(base: Base) -> bytes:
    buf = bytearray()
    buf += _BASE_MAGIC
    buf.append(_VERSION)
    write_varint(buf, base.n)
    buf += struct.pack("<ddB", base.config.eps_b, base.config.lam, base.config.beta_levels)
    buf += struct.pack("<dd", base.vmin, base.vmax)
    write_varint(buf, len(base.subbases))
    prev_idx_by_level: dict[int, int] = {}
    for sb in base.subbases:
        buf.append(sb.level & 0xFF)
        idx = origin_index(sb.theta, sb.level, base.config)
        prev = prev_idx_by_level.get(sb.level, 0)
        _write_svarint(buf, idx - prev)
        prev_idx_by_level[sb.level] = idx
        if sb.slope_digits <= 13:
            buf.append(sb.slope_digits)
            _write_svarint(buf, int(round(sb.slope * 10**sb.slope_digits)))
        else:
            buf.append(_RAW_SLOPE)
            buf += struct.pack("<d", sb.slope)
        write_varint(buf, len(sb.t0s))
        prev_t = 0
        for t0 in sb.t0s.tolist():
            write_varint(buf, t0 - prev_t)
            prev_t = t0
    return bytes(buf)


def decode_base(data: bytes) -> Base:
    if data[:4] != _BASE_MAGIC:
        raise FormatError("bad base magic")
    try:
        return _decode_base_body(data)
    except (IndexError, struct.error) as e:
        raise TruncatedArchiveError(f"truncated or corrupt base blob: {e}") from e


def _decode_base_body(data: bytes) -> Base:
    pos = 5  # magic + version
    n, pos = read_varint(data, pos)
    eps_b, lam, beta_levels = struct.unpack_from("<ddB", data, pos)
    pos += 17
    vmin, vmax = struct.unpack_from("<dd", data, pos)
    pos += 16
    config = ShrinkConfig(eps_b=eps_b, lam=lam, beta_levels=beta_levels)
    k, pos = read_varint(data, pos)
    subbases: list[SubBase] = []
    prev_idx_by_level: dict[int, int] = {}
    for _ in range(k):
        level = data[pos]
        pos += 1
        didx, pos = _read_svarint(data, pos)
        idx = prev_idx_by_level.get(level, 0) + didx
        prev_idx_by_level[level] = idx
        eps_hat = eps_hat_for_level(level, config)
        theta = idx * eps_hat
        digits = data[pos]
        pos += 1
        if digits == _RAW_SLOPE:
            (slope,) = struct.unpack_from("<d", data, pos)
            pos += 8
            digits = 13
        else:
            scaled, pos = _read_svarint(data, pos)
            slope = scaled / 10**digits
        m, pos = read_varint(data, pos)
        t0s = np.empty(m, dtype=np.int64)
        prev_t = 0
        for i in range(m):
            dt, pos = read_varint(data, pos)
            t0 = prev_t + dt
            prev_t = t0
            t0s[i] = t0
        subbases.append(
            SubBase(
                theta=theta,
                level=level,
                psi_lo=slope,
                psi_hi=slope,
                slope=slope,
                slope_digits=digits,
                t0s=t0s,
                lengths=np.zeros(m, dtype=np.int64),  # filled below
            )
        )
    # Segments partition [0, n): recover lengths from the global t0 order.
    flat = [(int(t0), si, mi) for si, sb in enumerate(subbases) for mi, t0 in enumerate(sb.t0s.tolist())]
    flat.sort()
    for j, (t0, si, mi) in enumerate(flat):
        end = flat[j + 1][0] if j + 1 < len(flat) else n
        subbases[si].lengths[mi] = end - t0
    return Base(n=n, config=config, vmin=vmin, vmax=vmax, subbases=subbases)


# --------------------------------------------------------------------- #
# SHRR v3: the residual pyramid blob (per-layer directory, per-layer
# payload CRCs + one directory CRC; normative byte layout in
# docs/wire-format.md, corruption-scoping semantics in docs/robustness.md)
# --------------------------------------------------------------------- #
def pyramid_layers(
    tiers: list[float],
    streams: list[ResidualStream | None],
    payloads: list[bytes | None],
) -> ResidualPyramid:
    """Assemble a :class:`ResidualPyramid` from the quantizer's per-tier
    streams and their already-entropy-coded payloads (``None`` at tier k
    means an identity layer).  Split from :func:`encode_pyramid` so batch
    compressors can run ONE entropy pass over every (series, layer) stream
    and then assemble each series' pyramid from the shared result."""
    layers: list[PyramidLayer] = []
    for eps, st, payload in zip(tiers, streams, payloads):
        if st is None:
            layers.append(
                PyramidLayer(eps=eps, mode="identity", step=0.0, r_lo=0.0, payload=None)
            )
        else:
            layers.append(
                PyramidLayer(
                    eps=eps, mode=st.mode, step=st.step, r_lo=st.r_lo, payload=payload
                )
            )
    return ResidualPyramid(layers=layers)


def encode_pyramid(pyramid: ResidualPyramid) -> bytes:
    """``SHRR`` v3 blob: version, per-layer directory (eps, mode, quantizer
    params, payload length, **payload CRC32**), a CRC32 of the directory
    section, then the concatenated tagged entropy payloads in layer order.

    The v3 CRC granularity is what makes corruption-scoped degradation
    possible: a flipped byte in layer k's payload fails ONLY layer k's
    CRC, so a reader can quarantine that layer and still serve the intact
    prefix 0..k-1 (the v2 single whole-blob CRC could only say
    "something, somewhere, is wrong")."""
    directory = bytearray()
    body = bytearray()
    for layer in pyramid.layers:
        payload = layer.payload if layer.payload is not None else b""
        if layer.mode == "identity" and payload:
            raise FormatError("identity layer cannot carry a payload")
        directory += struct.pack("<d", layer.eps)
        directory.append(_MODE_CODE[layer.mode])
        directory += struct.pack("<dd", layer.step, layer.r_lo)
        write_varint(directory, len(payload))
        directory += struct.pack("<I", zlib.crc32(payload) & 0xFFFFFFFF)
        body += payload
    buf = bytearray()
    buf += _RES_MAGIC
    buf.append(_RES_VERSION)
    write_varint(buf, len(pyramid.layers))
    buf += directory
    # the directory gets its own CRC (a flipped eps/step f64 corrupts
    # decode as surely as a payload byte, and the per-layer CRCs live in
    # the directory so they must themselves be trustworthy)
    buf += struct.pack("<I", zlib.crc32(bytes(directory)) & 0xFFFFFFFF)
    buf += body
    return bytes(buf)


def decode_pyramid(data: bytes, strict: bool = True) -> ResidualPyramid:
    """Parse a ``SHRR`` v3 blob.  Raises a :class:`ShrinkError` subclass
    (never a raw ``struct.error``/``IndexError``) on foreign, truncated,
    or corrupt input.

    CRC semantics (normative, docs/wire-format.md): the directory CRC is
    always verified — a blob whose directory cannot be trusted is
    rejected outright (:class:`CorruptFrameError`).  Per-layer payload
    CRCs are then verified eagerly; with ``strict=True`` (the default)
    the first mismatch raises :class:`LayerCorruptError` carrying the
    layer index.  With ``strict=False`` corrupt layers are returned
    **quarantined** (``layer.corrupt = True``, payload withheld) so a
    degraded reader can still decode the finest intact prefix."""
    data = bytes(data)
    if len(data) < 4 or data[:4] != _RES_MAGIC:
        raise FormatError("bad residual pyramid magic: not a SHRR blob")
    if len(data) < 5:
        raise TruncatedArchiveError("truncated SHRR blob: missing version")
    if data[4] != _RES_VERSION:
        raise FormatError(
            f"unsupported SHRR version {data[4]} (this build reads v{_RES_VERSION} "
            "refinement pyramids; older archives must be re-encoded)"
        )
    try:
        pos = 5
        n_layers, pos = read_varint(data, pos)
        dir_start = pos
        dirent: list[tuple[float, int, float, float, int, int]] = []
        for _ in range(n_layers):
            if pos + 25 > len(data):
                raise TruncatedArchiveError(
                    "truncated SHRR blob: layer directory cut short"
                )
            (eps,) = struct.unpack_from("<d", data, pos)
            mode_code = data[pos + 8]
            step, r_lo = struct.unpack_from("<dd", data, pos + 9)
            pos += 25
            ln, pos = read_varint(data, pos)
            if pos + 4 > len(data):
                raise TruncatedArchiveError(
                    "truncated SHRR blob: layer payload CRC cut short"
                )
            (pcrc,) = struct.unpack_from("<I", data, pos)
            pos += 4
            dirent.append((eps, mode_code, step, r_lo, ln, pcrc))
    except ShrinkError:
        raise
    except (IndexError, struct.error) as e:
        raise TruncatedArchiveError(f"truncated or corrupt SHRR blob: {e}") from e
    directory = data[dir_start:pos]
    if pos + 4 > len(data):
        raise TruncatedArchiveError("truncated SHRR blob: missing directory CRC")
    (crc,) = struct.unpack_from("<I", data, pos)
    pos += 4
    if zlib.crc32(directory) & 0xFFFFFFFF != crc:
        raise CorruptFrameError("corrupt SHRR blob: directory CRC mismatch")
    body = data[pos:]
    want = sum(ln for *_, ln, _pcrc in dirent)
    if len(body) < want:
        raise TruncatedArchiveError("truncated SHRR blob: payload section cut short")
    if len(body) != want:
        raise CorruptFrameError("corrupt SHRR blob: payload section length mismatch")
    # the tier-ladder invariant resolve() depends on is normative: eps
    # strictly decreasing coarse -> fine (0.0, the lossless tier, last)
    eps_seq = [e for e, *_ in dirent]
    if any(e < 0.0 for e in eps_seq):
        raise CorruptFrameError("corrupt SHRR blob: negative tier eps")
    if any(b >= a for a, b in zip(eps_seq, eps_seq[1:])):
        raise CorruptFrameError(
            "corrupt SHRR blob: tiers not strictly decreasing coarse -> fine"
        )
    layers: list[PyramidLayer] = []
    off = 0
    for k, (eps, mode_code, step, r_lo, ln, pcrc) in enumerate(dirent):
        if mode_code not in _MODE_NAME:
            raise CorruptFrameError(
                f"corrupt SHRR blob: unknown layer mode {mode_code}", layer=k
            )
        mode = _MODE_NAME[mode_code]
        if mode == "identity" and ln:
            raise CorruptFrameError(
                "corrupt SHRR blob: identity layer with payload", layer=k
            )
        if mode != "identity" and not ln:
            raise CorruptFrameError(
                f"corrupt SHRR blob: {mode} layer without payload", layer=k
            )
        payload = body[off : off + ln] if ln else None
        off += ln
        corrupt = ln > 0 and zlib.crc32(payload) & 0xFFFFFFFF != pcrc
        if corrupt and strict:
            raise LayerCorruptError(
                f"corrupt SHRR blob: layer payload CRC mismatch (tier eps={eps:g})",
                layer=k,
            )
        layers.append(
            PyramidLayer(
                eps=eps, mode=mode, step=step, r_lo=r_lo,
                payload=None if corrupt else payload, corrupt=corrupt,
            )
        )
    return ResidualPyramid(layers=layers)


# --------------------------------------------------------------------- #
# SHRKS framed stream container (layout table in the module docstring)
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class KBSnapshotRef:
    """Footer pointer from a container to a ``KBStore`` snapshot
    (``serving.kbstore``): instead of (or in addition to) carrying the
    whole knowledge base inline, the container records *which* store
    snapshot holds its lines and how its container-local entry ids map
    into that snapshot's id space.

    ``remap[i]`` is the store entry id of container-local entry ``i``;
    ``refs[i]`` is this container's reference count on that line — so a
    resolver can rebuild the container's private KB view (positional ids,
    exact refcounts) from the snapshot alone.  ``entries`` is the
    snapshot's total id space and ``sem_id`` its order-invariant semantic
    identity (``KnowledgeBase.snapshot_id`` over live lines) — both are
    cross-checked at resolve time so a ref never silently binds to the
    wrong snapshot."""

    version: int
    entries: int
    sem_id: int
    remap: tuple[int, ...] = ()
    refs: tuple[int, ...] = ()


class FramedWriter:
    """Append-only writer for the ``SHRKS`` container.

    Frames are appended in seal order (any interleaving of series);
    ``finish`` emits the directory footer + knowledge-base section + tail.
    """

    def __init__(self) -> None:
        self._buf = bytearray()
        self._buf += _STREAM_MAGIC
        self._buf.append(_STREAM_VERSION)
        self._frames: list[FrameMeta] = []
        self._finished = False

    def add_frame(
        self, series_id: int, t_lo: int, t_hi: int, kb_epoch: int, payload: bytes
    ) -> FrameMeta:
        if self._finished:
            raise BatcherFinalizedError("container already finished")
        if t_hi <= t_lo:
            raise ConfigError(
                f"empty frame range [{t_lo}, {t_hi})", series_id=int(series_id)
            )
        meta = FrameMeta(
            series_id=int(series_id),
            t_lo=int(t_lo),
            t_hi=int(t_hi),
            kb_epoch=int(kb_epoch),
            offset=len(self._buf),
            length=len(payload),
            crc32=zlib.crc32(payload) & 0xFFFFFFFF,
        )
        self._buf += payload
        self._frames.append(meta)
        return meta

    def finish(
        self, kb_bytes: bytes = b"", snapshot_ref: KBSnapshotRef | None = None
    ) -> bytes:
        if self._finished:
            raise BatcherFinalizedError("container already finished")
        self._finished = True
        footer = bytearray()
        write_varint(footer, len(self._frames))
        for m in self._frames:
            write_varint(footer, m.series_id)
            write_varint(footer, m.t_lo)
            write_varint(footer, m.t_hi - m.t_lo)
            write_varint(footer, m.kb_epoch)
            write_varint(footer, m.offset)
            write_varint(footer, m.length)
            footer += struct.pack("<I", m.crc32)
        write_varint(footer, len(kb_bytes))
        footer += kb_bytes
        if snapshot_ref is None:
            footer.append(0)
        else:
            if len(snapshot_ref.remap) != len(snapshot_ref.refs):
                raise ConfigError(
                    "kb_snapshot_ref remap/refs length mismatch "
                    f"({len(snapshot_ref.remap)} != {len(snapshot_ref.refs)})"
                )
            footer.append(1)
            write_varint(footer, snapshot_ref.version)
            write_varint(footer, snapshot_ref.entries)
            footer += struct.pack("<I", snapshot_ref.sem_id & 0xFFFFFFFF)
            write_varint(footer, len(snapshot_ref.remap))
            prev = 0
            for sid in snapshot_ref.remap:
                _write_svarint(footer, sid - prev)
                prev = sid
            for r in snapshot_ref.refs:
                write_varint(footer, r)
        footer_offset = len(self._buf)
        self._buf += footer
        self._buf += struct.pack("<QI", footer_offset, zlib.crc32(bytes(footer)) & 0xFFFFFFFF)
        self._buf += _STREAM_END_MAGIC
        return bytes(self._buf)


def _parse_footer(
    blob: bytes,
) -> tuple[list[FrameMeta], bytes, KBSnapshotRef | None]:
    blob = bytes(blob)
    if len(blob) < 6 or blob[:5] != _STREAM_MAGIC:
        raise FormatError("bad container magic: not a SHRKS blob")
    if blob[5] != _STREAM_VERSION:
        raise FormatError(
            f"unsupported SHRKS version {blob[5]} "
            f"(this build reads v{_STREAM_VERSION} only)"
        )
    if len(blob) < 6 + _TAIL_LEN:
        raise TruncatedArchiveError("truncated SHRKS container: missing tail")
    if blob[-4:] != _STREAM_END_MAGIC:
        raise TruncatedArchiveError(
            "truncated SHRKS container: bad end magic", offset=len(blob) - 4
        )
    footer_offset, footer_crc = struct.unpack_from("<QI", blob, len(blob) - _TAIL_LEN)
    if footer_offset < 6 or footer_offset > len(blob) - _TAIL_LEN:
        raise CorruptFrameError(
            "corrupt SHRKS container: footer offset out of range",
            offset=footer_offset,
        )
    footer = blob[footer_offset : len(blob) - _TAIL_LEN]
    if zlib.crc32(footer) & 0xFFFFFFFF != footer_crc:
        raise CorruptFrameError(
            "corrupt SHRKS container: footer CRC mismatch", offset=footer_offset
        )
    try:
        pos = 0
        n_frames, pos = read_varint(footer, pos)
        metas: list[FrameMeta] = []
        for i in range(n_frames):
            sid, pos = read_varint(footer, pos)
            t_lo, pos = read_varint(footer, pos)
            n, pos = read_varint(footer, pos)
            epoch, pos = read_varint(footer, pos)
            off, pos = read_varint(footer, pos)
            ln, pos = read_varint(footer, pos)
            (crc,) = struct.unpack_from("<I", footer, pos)
            pos += 4
            if off + ln > footer_offset:
                raise CorruptFrameError(
                    "corrupt SHRKS container: frame extends into footer",
                    series_id=sid, frame_index=i, offset=off,
                )
            metas.append(
                FrameMeta(
                    series_id=sid, t_lo=t_lo, t_hi=t_lo + n, kb_epoch=epoch,
                    offset=off, length=ln, crc32=crc,
                )
            )
        kb_len, pos = read_varint(footer, pos)
        if pos + kb_len > len(footer):
            raise CorruptFrameError(
                "corrupt SHRKS container: knowledge-base section length mismatch"
            )
        kb_bytes = bytes(footer[pos : pos + kb_len])
        pos += kb_len
        if pos >= len(footer):
            raise TruncatedArchiveError(
                "truncated SHRKS container: missing kb_snapshot_ref flag"
            )
        flag = footer[pos]
        pos += 1
        ref: KBSnapshotRef | None = None
        if flag == 1:
            version, pos = read_varint(footer, pos)
            entries, pos = read_varint(footer, pos)
            (sem_id,) = struct.unpack_from("<I", footer, pos)
            pos += 4
            n_ref, pos = read_varint(footer, pos)
            remap: list[int] = []
            prev = 0
            for _ in range(n_ref):
                d, pos = _read_svarint(footer, pos)
                prev += d
                if not 0 <= prev < entries:
                    raise CorruptFrameError(
                        "corrupt SHRKS container: kb_snapshot_ref remap id "
                        f"{prev} outside snapshot id space [0, {entries})"
                    )
                remap.append(prev)
            refs: list[int] = []
            for _ in range(n_ref):
                r, pos = read_varint(footer, pos)
                refs.append(r)
            ref = KBSnapshotRef(
                version=version,
                entries=entries,
                sem_id=sem_id,
                remap=tuple(remap),
                refs=tuple(refs),
            )
        elif flag != 0:
            raise CorruptFrameError(
                f"corrupt SHRKS container: bad kb_snapshot_ref flag {flag}"
            )
        if pos != len(footer):
            raise CorruptFrameError(
                "corrupt SHRKS container: trailing bytes after footer "
                f"({len(footer) - pos} byte(s))"
            )
    except ShrinkError:
        raise
    except (IndexError, struct.error) as e:
        raise CorruptFrameError(
            f"corrupt SHRKS container: footer parse failed: {e}"
        ) from e
    return metas, kb_bytes, ref


def parse_framed_container(blob: bytes) -> tuple[list[FrameMeta], bytes]:
    """Validate head/tail/footer of a ``SHRKS`` container and return
    (frame directory, kb_bytes).  Raises a :class:`ShrinkError` subclass
    on foreign, truncated, or corrupt input (including a footer CRC
    mismatch).  Frame *payload* CRCs are NOT checked here — see
    ``frame_payload``.  The optional ``kb_snapshot_ref`` footer field is
    validated structurally here too; read it with
    :func:`read_snapshot_ref`."""
    metas, kb_bytes, _ = _parse_footer(blob)
    return metas, kb_bytes


def read_snapshot_ref(blob: bytes) -> KBSnapshotRef | None:
    """The container's ``kb_snapshot_ref`` footer field, or ``None`` for a
    self-contained container.  Same validation/raising as
    :func:`parse_framed_container`."""
    _, _, ref = _parse_footer(blob)
    return ref


def kb_snapshot_id(kb_bytes: bytes) -> int:
    """Routing identity of a container's serialized knowledge-base
    snapshot: the CRC-32 of the footer's ``SHKB`` blob (0 for containers
    written without one).  Two containers carrying byte-identical KB
    snapshots — e.g. replicas of one shard — share an id; a snapshot that
    gained entries gets a new one.  This identifies a concrete *serialized
    snapshot*; for the insertion-order-invariant semantic identity use
    ``KnowledgeBase.snapshot_id()`` (``core/streaming.py``)."""
    return zlib.crc32(bytes(kb_bytes)) & 0xFFFFFFFF if kb_bytes else 0


def frame_payload(blob: bytes, meta: FrameMeta, verify_crc: bool = True) -> bytes:
    """Extract one frame's payload (a complete ``SHRK`` blob), checking its
    directory CRC unless ``verify_crc=False``."""
    payload = bytes(blob[meta.offset : meta.offset + meta.length])
    if len(payload) != meta.length:
        raise TruncatedArchiveError(
            "truncated SHRKS container: frame payload cut short",
            series_id=meta.series_id, offset=meta.offset,
        )
    if verify_crc and zlib.crc32(payload) & 0xFFFFFFFF != meta.crc32:
        raise CorruptFrameError(
            f"frame payload CRC mismatch (series {meta.series_id}, "
            f"samples [{meta.t_lo}, {meta.t_hi}))",
            series_id=meta.series_id, offset=meta.offset,
        )
    return payload
