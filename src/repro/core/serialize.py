"""Byte-level serialization of the SHRINK knowledge base and residuals.

Compression ratios in the paper are measured on real bytes; so are ours.
Layout (little-endian):

Base blob:
    magic  b"SHRB"
    u8     version
    varint n
    f64    eps_b, f64 lam, u8 beta_levels
    f64    vmin, f64 vmax
    varint k (number of sub-bases)
    per sub-base:
        u8      level
        svarint origin grid index (delta vs previous subbase, same-level grid)
        u8      slope_digits (0..13; 255 = raw f64 follows)
        svarint slope scaled int   (or f64 if raw)
        varint  m (number of member segments)
        varint  t0 deltas (ascending within the sub-base)
    (All varints are LEB128; svarint = zigzag LEB128.  Segment lengths are
    NOT stored: segments partition [0, n), so sorting all start indices
    globally reconstructs every length — the same trick Sim-Piece uses.)

Residual blob:
    magic  b"SHRR"
    u8     mode (0=midpoint, 1=exact)
    f64    eps_r, f64 step, f64 r_lo
    entropy-coded q (see entropy.py, self-describing)
"""
from __future__ import annotations

import struct

import numpy as np

from . import entropy
from .phases import eps_hat_for_level
from .types import Base, ResidualStream, ShrinkConfig, SubBase

__all__ = [
    "write_varint",
    "read_varint",
    "encode_base",
    "decode_base",
    "encode_residuals",
    "encode_residuals_batch",
    "decode_residuals",
]

_BASE_MAGIC = b"SHRB"
_RES_MAGIC = b"SHRR"
_VERSION = 1
_RAW_SLOPE = 255


def write_varint(buf: bytearray, x: int) -> None:
    if x < 0:
        raise ValueError("varint must be non-negative")
    while True:
        b = x & 0x7F
        x >>= 7
        if x:
            buf.append(b | 0x80)
        else:
            buf.append(b)
            return


def read_varint(data: bytes, pos: int) -> tuple[int, int]:
    shift = 0
    out = 0
    while True:
        b = data[pos]
        pos += 1
        out |= (b & 0x7F) << shift
        if not (b & 0x80):
            return out, pos
        shift += 7


def _write_svarint(buf: bytearray, x: int) -> None:
    write_varint(buf, (x << 1) ^ (x >> 63) if x < 0 else (x << 1))


def _read_svarint(data: bytes, pos: int) -> tuple[int, int]:
    z, pos = read_varint(data, pos)
    return (z >> 1) ^ -(z & 1), pos


def encode_base(base: Base) -> bytes:
    buf = bytearray()
    buf += _BASE_MAGIC
    buf.append(_VERSION)
    write_varint(buf, base.n)
    buf += struct.pack("<ddB", base.config.eps_b, base.config.lam, base.config.beta_levels)
    buf += struct.pack("<dd", base.vmin, base.vmax)
    write_varint(buf, len(base.subbases))
    prev_idx_by_level: dict[int, int] = {}
    for sb in base.subbases:
        buf.append(sb.level & 0xFF)
        eps_hat = eps_hat_for_level(sb.level, base.config)
        idx = int(round(sb.theta / eps_hat))
        prev = prev_idx_by_level.get(sb.level, 0)
        _write_svarint(buf, idx - prev)
        prev_idx_by_level[sb.level] = idx
        if sb.slope_digits <= 13:
            buf.append(sb.slope_digits)
            _write_svarint(buf, int(round(sb.slope * 10**sb.slope_digits)))
        else:
            buf.append(_RAW_SLOPE)
            buf += struct.pack("<d", sb.slope)
        write_varint(buf, len(sb.t0s))
        prev_t = 0
        for t0 in sb.t0s.tolist():
            write_varint(buf, t0 - prev_t)
            prev_t = t0
    return bytes(buf)


def decode_base(data: bytes) -> Base:
    if data[:4] != _BASE_MAGIC:
        raise ValueError("bad base magic")
    pos = 5  # magic + version
    n, pos = read_varint(data, pos)
    eps_b, lam, beta_levels = struct.unpack_from("<ddB", data, pos)
    pos += 17
    vmin, vmax = struct.unpack_from("<dd", data, pos)
    pos += 16
    config = ShrinkConfig(eps_b=eps_b, lam=lam, beta_levels=beta_levels)
    k, pos = read_varint(data, pos)
    subbases: list[SubBase] = []
    prev_idx_by_level: dict[int, int] = {}
    for _ in range(k):
        level = data[pos]
        pos += 1
        didx, pos = _read_svarint(data, pos)
        idx = prev_idx_by_level.get(level, 0) + didx
        prev_idx_by_level[level] = idx
        eps_hat = eps_hat_for_level(level, config)
        theta = idx * eps_hat
        digits = data[pos]
        pos += 1
        if digits == _RAW_SLOPE:
            (slope,) = struct.unpack_from("<d", data, pos)
            pos += 8
            digits = 13
        else:
            scaled, pos = _read_svarint(data, pos)
            slope = scaled / 10**digits
        m, pos = read_varint(data, pos)
        t0s = np.empty(m, dtype=np.int64)
        prev_t = 0
        for i in range(m):
            dt, pos = read_varint(data, pos)
            t0 = prev_t + dt
            prev_t = t0
            t0s[i] = t0
        subbases.append(
            SubBase(
                theta=theta,
                level=level,
                psi_lo=slope,
                psi_hi=slope,
                slope=slope,
                slope_digits=digits,
                t0s=t0s,
                lengths=np.zeros(m, dtype=np.int64),  # filled below
            )
        )
    # Segments partition [0, n): recover lengths from the global t0 order.
    flat = [(int(t0), si, mi) for si, sb in enumerate(subbases) for mi, t0 in enumerate(sb.t0s.tolist())]
    flat.sort()
    for j, (t0, si, mi) in enumerate(flat):
        end = flat[j + 1][0] if j + 1 < len(flat) else n
        subbases[si].lengths[mi] = end - t0
    return Base(n=n, config=config, vmin=vmin, vmax=vmax, subbases=subbases)


def _residual_header(stream: ResidualStream) -> bytes:
    return (
        _RES_MAGIC
        + bytes([0 if stream.mode == "midpoint" else 1])
        + struct.pack("<ddd", stream.eps_r, stream.step, stream.r_lo)
    )


def encode_residuals(stream: ResidualStream, backend: str = "best") -> bytes:
    return _residual_header(stream) + entropy.encode_ints(stream.q, backend=backend)


def encode_residuals_batch(streams: list[ResidualStream], backend: str = "best") -> list[bytes]:
    """Batched ``encode_residuals`` for equal-length streams.  The entropy
    stage runs through ``entropy.encode_ints_batch`` (one vectorized rANS
    pass for the whole batch on that backend); each returned blob is
    byte-identical to ``encode_residuals(streams[i], backend)``."""
    if not streams:
        return []
    qs = np.stack([st.q for st in streams])
    blobs = entropy.encode_ints_batch(qs, backend=backend)
    return [_residual_header(st) + blob for st, blob in zip(streams, blobs)]


def decode_residuals(data: bytes) -> ResidualStream:
    if data[:4] != _RES_MAGIC:
        raise ValueError("bad residual magic")
    mode = "midpoint" if data[4] == 0 else "exact"
    eps_r, step, r_lo = struct.unpack_from("<ddd", data, 5)
    q = entropy.decode_ints(data[29:])
    return ResidualStream(eps_r=eps_r, step=step, r_lo=r_lo, mode=mode, q=q)
