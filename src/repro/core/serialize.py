"""Byte-level serialization of the SHRINK knowledge base and residuals.

Compression ratios in the paper are measured on real bytes; so are ours.
This module implements the ``SHRB`` base blob, the ``SHRR`` residual blob,
and the ``SHRKS`` framed stream container (append-only frames, directory +
knowledge base in a CRC'd footer, fixed 16-byte tail).

**The normative byte-layout spec — field tables, CRC rules, version-bump
procedure, golden-fixture regeneration — lives in
``docs/wire-format.md``.**  Change bytes only together with that document
and the golden fixtures under ``tests/golden/``.
"""
from __future__ import annotations

import struct
import zlib

import numpy as np

from . import entropy
from .base import origin_index
from .phases import eps_hat_for_level
from .types import Base, FrameMeta, ResidualStream, ShrinkConfig, SubBase

__all__ = [
    "write_varint",
    "read_varint",
    "encode_base",
    "decode_base",
    "encode_residuals",
    "encode_residuals_batch",
    "decode_residuals",
    "FramedWriter",
    "parse_framed_container",
    "frame_payload",
]

_BASE_MAGIC = b"SHRB"
_RES_MAGIC = b"SHRR"
_VERSION = 1
_RAW_SLOPE = 255

_STREAM_MAGIC = b"SHRKS"
_STREAM_END_MAGIC = b"SHRE"
_STREAM_VERSION = 1
_TAIL_LEN = 8 + 4 + 4  # u64 footer offset + u32 footer crc + end magic


def write_varint(buf: bytearray, x: int) -> None:
    if x < 0:
        raise ValueError("varint must be non-negative")
    while True:
        b = x & 0x7F
        x >>= 7
        if x:
            buf.append(b | 0x80)
        else:
            buf.append(b)
            return


def read_varint(data: bytes, pos: int) -> tuple[int, int]:
    shift = 0
    out = 0
    while True:
        b = data[pos]
        pos += 1
        out |= (b & 0x7F) << shift
        if not (b & 0x80):
            return out, pos
        shift += 7


def _write_svarint(buf: bytearray, x: int) -> None:
    write_varint(buf, (x << 1) ^ (x >> 63) if x < 0 else (x << 1))


def _read_svarint(data: bytes, pos: int) -> tuple[int, int]:
    z, pos = read_varint(data, pos)
    return (z >> 1) ^ -(z & 1), pos


def encode_base(base: Base) -> bytes:
    buf = bytearray()
    buf += _BASE_MAGIC
    buf.append(_VERSION)
    write_varint(buf, base.n)
    buf += struct.pack("<ddB", base.config.eps_b, base.config.lam, base.config.beta_levels)
    buf += struct.pack("<dd", base.vmin, base.vmax)
    write_varint(buf, len(base.subbases))
    prev_idx_by_level: dict[int, int] = {}
    for sb in base.subbases:
        buf.append(sb.level & 0xFF)
        idx = origin_index(sb.theta, sb.level, base.config)
        prev = prev_idx_by_level.get(sb.level, 0)
        _write_svarint(buf, idx - prev)
        prev_idx_by_level[sb.level] = idx
        if sb.slope_digits <= 13:
            buf.append(sb.slope_digits)
            _write_svarint(buf, int(round(sb.slope * 10**sb.slope_digits)))
        else:
            buf.append(_RAW_SLOPE)
            buf += struct.pack("<d", sb.slope)
        write_varint(buf, len(sb.t0s))
        prev_t = 0
        for t0 in sb.t0s.tolist():
            write_varint(buf, t0 - prev_t)
            prev_t = t0
    return bytes(buf)


def decode_base(data: bytes) -> Base:
    if data[:4] != _BASE_MAGIC:
        raise ValueError("bad base magic")
    try:
        return _decode_base_body(data)
    except (IndexError, struct.error) as e:
        raise ValueError(f"truncated or corrupt base blob: {e}") from e


def _decode_base_body(data: bytes) -> Base:
    pos = 5  # magic + version
    n, pos = read_varint(data, pos)
    eps_b, lam, beta_levels = struct.unpack_from("<ddB", data, pos)
    pos += 17
    vmin, vmax = struct.unpack_from("<dd", data, pos)
    pos += 16
    config = ShrinkConfig(eps_b=eps_b, lam=lam, beta_levels=beta_levels)
    k, pos = read_varint(data, pos)
    subbases: list[SubBase] = []
    prev_idx_by_level: dict[int, int] = {}
    for _ in range(k):
        level = data[pos]
        pos += 1
        didx, pos = _read_svarint(data, pos)
        idx = prev_idx_by_level.get(level, 0) + didx
        prev_idx_by_level[level] = idx
        eps_hat = eps_hat_for_level(level, config)
        theta = idx * eps_hat
        digits = data[pos]
        pos += 1
        if digits == _RAW_SLOPE:
            (slope,) = struct.unpack_from("<d", data, pos)
            pos += 8
            digits = 13
        else:
            scaled, pos = _read_svarint(data, pos)
            slope = scaled / 10**digits
        m, pos = read_varint(data, pos)
        t0s = np.empty(m, dtype=np.int64)
        prev_t = 0
        for i in range(m):
            dt, pos = read_varint(data, pos)
            t0 = prev_t + dt
            prev_t = t0
            t0s[i] = t0
        subbases.append(
            SubBase(
                theta=theta,
                level=level,
                psi_lo=slope,
                psi_hi=slope,
                slope=slope,
                slope_digits=digits,
                t0s=t0s,
                lengths=np.zeros(m, dtype=np.int64),  # filled below
            )
        )
    # Segments partition [0, n): recover lengths from the global t0 order.
    flat = [(int(t0), si, mi) for si, sb in enumerate(subbases) for mi, t0 in enumerate(sb.t0s.tolist())]
    flat.sort()
    for j, (t0, si, mi) in enumerate(flat):
        end = flat[j + 1][0] if j + 1 < len(flat) else n
        subbases[si].lengths[mi] = end - t0
    return Base(n=n, config=config, vmin=vmin, vmax=vmax, subbases=subbases)


def _residual_header(stream: ResidualStream) -> bytes:
    return (
        _RES_MAGIC
        + bytes([0 if stream.mode == "midpoint" else 1])
        + struct.pack("<ddd", stream.eps_r, stream.step, stream.r_lo)
    )


def encode_residuals(stream: ResidualStream, backend: str = "best") -> bytes:
    return _residual_header(stream) + entropy.encode_ints(stream.q, backend=backend)


def encode_residuals_batch(streams: list[ResidualStream], backend: str = "best") -> list[bytes]:
    """Batched ``encode_residuals`` for a mix of stream lengths.  The
    entropy stage runs through ``entropy.encode_ints_batch`` — one
    vectorized rANS pass for the whole batch when lengths agree, the masked
    ragged machine otherwise; each returned blob is byte-identical to
    ``encode_residuals(streams[i], backend)``."""
    if not streams:
        return []
    n0 = streams[0].q.size
    if all(st.q.size == n0 for st in streams):
        qs: np.ndarray | list[np.ndarray] = np.stack([st.q for st in streams])
    else:
        qs = [st.q for st in streams]
    blobs = entropy.encode_ints_batch(qs, backend=backend)
    return [_residual_header(st) + blob for st, blob in zip(streams, blobs)]


def decode_residuals(data: bytes) -> ResidualStream:
    if data[:4] != _RES_MAGIC:
        raise ValueError("bad residual magic")
    if len(data) < 29:
        raise ValueError("truncated residual blob")
    mode = "midpoint" if data[4] == 0 else "exact"
    eps_r, step, r_lo = struct.unpack_from("<ddd", data, 5)
    try:
        q = entropy.decode_ints(data[29:])
    except (IndexError, struct.error) as e:
        raise ValueError(f"truncated or corrupt residual payload: {e}") from e
    return ResidualStream(eps_r=eps_r, step=step, r_lo=r_lo, mode=mode, q=q)


# --------------------------------------------------------------------- #
# SHRKS framed stream container (layout table in the module docstring)
# --------------------------------------------------------------------- #
class FramedWriter:
    """Append-only writer for the ``SHRKS`` container.

    Frames are appended in seal order (any interleaving of series);
    ``finish`` emits the directory footer + knowledge-base section + tail.
    """

    def __init__(self) -> None:
        self._buf = bytearray()
        self._buf += _STREAM_MAGIC
        self._buf.append(_STREAM_VERSION)
        self._frames: list[FrameMeta] = []
        self._finished = False

    def add_frame(
        self, series_id: int, t_lo: int, t_hi: int, kb_epoch: int, payload: bytes
    ) -> FrameMeta:
        if self._finished:
            raise ValueError("container already finished")
        if t_hi <= t_lo:
            raise ValueError(f"empty frame range [{t_lo}, {t_hi})")
        meta = FrameMeta(
            series_id=int(series_id),
            t_lo=int(t_lo),
            t_hi=int(t_hi),
            kb_epoch=int(kb_epoch),
            offset=len(self._buf),
            length=len(payload),
            crc32=zlib.crc32(payload) & 0xFFFFFFFF,
        )
        self._buf += payload
        self._frames.append(meta)
        return meta

    def finish(self, kb_bytes: bytes = b"") -> bytes:
        if self._finished:
            raise ValueError("container already finished")
        self._finished = True
        footer = bytearray()
        write_varint(footer, len(self._frames))
        for m in self._frames:
            write_varint(footer, m.series_id)
            write_varint(footer, m.t_lo)
            write_varint(footer, m.t_hi - m.t_lo)
            write_varint(footer, m.kb_epoch)
            write_varint(footer, m.offset)
            write_varint(footer, m.length)
            footer += struct.pack("<I", m.crc32)
        write_varint(footer, len(kb_bytes))
        footer += kb_bytes
        footer_offset = len(self._buf)
        self._buf += footer
        self._buf += struct.pack("<QI", footer_offset, zlib.crc32(bytes(footer)) & 0xFFFFFFFF)
        self._buf += _STREAM_END_MAGIC
        return bytes(self._buf)


def parse_framed_container(blob: bytes) -> tuple[list[FrameMeta], bytes]:
    """Validate head/tail/footer of a ``SHRKS`` container and return
    (frame directory, kb_bytes).  Raises ``ValueError`` on foreign,
    truncated, or corrupt input (including a footer CRC mismatch).
    Frame *payload* CRCs are NOT checked here — see ``frame_payload``."""
    blob = bytes(blob)
    if len(blob) < 6 or blob[:5] != _STREAM_MAGIC:
        raise ValueError("bad container magic: not a SHRKS blob")
    if blob[5] != _STREAM_VERSION:
        raise ValueError(f"unsupported SHRKS version {blob[5]}")
    if len(blob) < 6 + _TAIL_LEN:
        raise ValueError("truncated SHRKS container: missing tail")
    if blob[-4:] != _STREAM_END_MAGIC:
        raise ValueError("truncated SHRKS container: bad end magic")
    footer_offset, footer_crc = struct.unpack_from("<QI", blob, len(blob) - _TAIL_LEN)
    if footer_offset < 6 or footer_offset > len(blob) - _TAIL_LEN:
        raise ValueError("corrupt SHRKS container: footer offset out of range")
    footer = blob[footer_offset : len(blob) - _TAIL_LEN]
    if zlib.crc32(footer) & 0xFFFFFFFF != footer_crc:
        raise ValueError("corrupt SHRKS container: footer CRC mismatch")
    try:
        pos = 0
        n_frames, pos = read_varint(footer, pos)
        metas: list[FrameMeta] = []
        for _ in range(n_frames):
            sid, pos = read_varint(footer, pos)
            t_lo, pos = read_varint(footer, pos)
            n, pos = read_varint(footer, pos)
            epoch, pos = read_varint(footer, pos)
            off, pos = read_varint(footer, pos)
            ln, pos = read_varint(footer, pos)
            (crc,) = struct.unpack_from("<I", footer, pos)
            pos += 4
            if off + ln > footer_offset:
                raise ValueError("corrupt SHRKS container: frame extends into footer")
            metas.append(
                FrameMeta(
                    series_id=sid, t_lo=t_lo, t_hi=t_lo + n, kb_epoch=epoch,
                    offset=off, length=ln, crc32=crc,
                )
            )
        kb_len, pos = read_varint(footer, pos)
        if pos + kb_len != len(footer):
            raise ValueError("corrupt SHRKS container: knowledge-base section length mismatch")
        kb_bytes = bytes(footer[pos : pos + kb_len])
    except (IndexError, struct.error) as e:
        raise ValueError(f"corrupt SHRKS container: footer parse failed: {e}") from e
    return metas, kb_bytes


def frame_payload(blob: bytes, meta: FrameMeta, verify_crc: bool = True) -> bytes:
    """Extract one frame's payload (a complete ``SHRK`` blob), checking its
    directory CRC unless ``verify_crc=False``."""
    payload = bytes(blob[meta.offset : meta.offset + meta.length])
    if len(payload) != meta.length:
        raise ValueError("truncated SHRKS container: frame payload cut short")
    if verify_crc and zlib.crc32(payload) & 0xFFFFFFFF != meta.crc32:
        raise ValueError(
            f"frame payload CRC mismatch (series {meta.series_id}, "
            f"samples [{meta.t_lo}, {meta.t_hi}))"
        )
    return payload
