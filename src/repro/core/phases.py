"""Adaptive phase division (Alg. 2 of the paper).

Given a start index ``j``, look ahead over the *default interval*
``L = lam * n * eps_b`` points, measure the local fluctuation level
``beta = (local max-min) / (global max-min)`` and derive the adaptive base
threshold of Eq. 4:

    eps_hat_b = eps_b * exp(2/3 - beta)

The cone origin (Eq. 5) is the start value floored onto the eps_hat_b grid.

Implementation notes (documented deviations):

* ``beta`` is quantized to ``config.beta_levels`` discrete levels.  The
  paper's base-merging phase (Alg. 4) groups cones whose quantized origins
  are *equal*; with a continuous beta, eps_hat_b (and hence the origin grid)
  would almost never repeat and merging would degenerate.  Quantizing beta
  keeps adaptivity (16 levels by default) while making origin collisions —
  the mechanism the paper's compression relies on — actually occur.
* L is clamped to [min_interval, max_interval] and to the series end.
"""
from __future__ import annotations

import math

import numpy as np

from .types import ShrinkConfig

__all__ = [
    "default_interval_length",
    "beta_level",
    "eps_hat_for_level",
    "quantize_origin",
    "divide",
]


def default_interval_length(n: int, config: ShrinkConfig) -> int:
    """Alg. 2 line 4:  L = lam * n * eps_b  (clamped)."""
    raw = config.lam * n * config.eps_b
    return int(min(max(raw, config.min_interval), config.max_interval))


def beta_level(delta_local: float, delta_global: float, config: ShrinkConfig) -> int:
    """Quantized fluctuation level in [0, beta_levels]."""
    if delta_global <= 0:
        return 0
    beta = min(max(delta_local / delta_global, 0.0), 1.0)
    return int(round(beta * config.beta_levels))


def eps_hat_for_level(level: int, config: ShrinkConfig) -> float:
    """Eq. 4 with quantized beta: eps_b * exp(2/3 - level/beta_levels)."""
    beta = level / config.beta_levels
    return config.eps_b * math.exp(2.0 / 3.0 - beta)


def quantize_origin(value: float, eps_hat: float) -> float:
    """Eq. 5: Theta = floor(v / eps_hat) * eps_hat."""
    return math.floor(value / eps_hat) * eps_hat


def divide(
    values: np.ndarray,
    j: int,
    L: int,
    delta_global: float,
    config: ShrinkConfig,
) -> tuple[float, int, float]:
    """Alg. 2 (DIVISION): returns (theta, level, eps_hat) for a cone at j.

    values:       the full series (float64 [n]).
    j:            start index of the new cone.
    L:            default interval length (precomputed once per series).
    delta_global: global max - min of the series.
    """
    window = values[j : j + max(L, 2)]
    if window.size >= 2:
        delta_local = float(window.max() - window.min())
    else:
        delta_local = 0.0
    level = beta_level(delta_local, delta_global, config)
    eps_hat = eps_hat_for_level(level, config)
    theta = quantize_origin(float(values[j]), eps_hat)
    return theta, level, eps_hat
