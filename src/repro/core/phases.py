"""Adaptive phase division (Alg. 2 of the paper).

Given a start index ``j``, look ahead over the *default interval*
``L = lam * n * eps_b`` points, measure the local fluctuation level
``beta = (local max-min) / (global max-min)`` and derive the adaptive base
threshold of Eq. 4:

    eps_hat_b = eps_b * exp(2/3 - beta)

The cone origin (Eq. 5) is the start value floored onto the eps_hat_b grid.

Implementation notes (documented deviations):

* ``beta`` is quantized to ``config.beta_levels`` discrete levels.  The
  paper's base-merging phase (Alg. 4) groups cones whose quantized origins
  are *equal*; with a continuous beta, eps_hat_b (and hence the origin grid)
  would almost never repeat and merging would degenerate.  Quantizing beta
  keeps adaptivity (16 levels by default) while making origin collisions —
  the mechanism the paper's compression relies on — actually occur.
* L is clamped to [min_interval, max_interval] and to the series end.
"""
from __future__ import annotations

import math

import numpy as np

from .types import ShrinkConfig

__all__ = [
    "default_interval_length",
    "beta_level",
    "eps_hat_for_level",
    "quantize_origin",
    "divide",
    "fluctuation_table",
]


def default_interval_length(n: int, config: ShrinkConfig) -> int:
    """Alg. 2 line 4:  L = lam * n * eps_b  (clamped)."""
    raw = config.lam * n * config.eps_b
    return int(min(max(raw, config.min_interval), config.max_interval))


def beta_level(delta_local: float, delta_global: float, config: ShrinkConfig) -> int:
    """Quantized fluctuation level in [0, beta_levels]."""
    if delta_global <= 0:
        return 0
    beta = min(max(delta_local / delta_global, 0.0), 1.0)
    return int(round(beta * config.beta_levels))


def eps_hat_for_level(level: int, config: ShrinkConfig) -> float:
    """Eq. 4 with quantized beta: eps_b * exp(2/3 - level/beta_levels)."""
    beta = level / config.beta_levels
    return config.eps_b * math.exp(2.0 / 3.0 - beta)


def quantize_origin(value: float, eps_hat: float) -> float:
    """Eq. 5: Theta = floor(v / eps_hat) * eps_hat."""
    return math.floor(value / eps_hat) * eps_hat


def _sliding_forward(v: np.ndarray, w: int, ufunc: np.ufunc, pad: float) -> np.ndarray:
    """Per-row forward-window extremum: out[s, t] = ufunc.reduce(v[s, t:t+w])
    (windows truncated at the row end).  Van Herk / Gil-Werman two-pass,
    O(S*T) regardless of w."""
    s, t = v.shape
    if w >= t:
        return ufunc.accumulate(v[:, ::-1], axis=1)[:, ::-1]
    if w <= 32:  # small windows: w-1 shifted whole-array ops beat blocking
        out = v.copy()
        for d in range(1, w):
            ufunc(out[:, : t - d], v[:, d:], out=out[:, : t - d])
        return out
    nb = -(-t // w)
    p = nb * w
    vp = np.full((s, p), pad, dtype=v.dtype)
    vp[:, :t] = v
    blocks = vp.reshape(s, nb, w)
    pre = ufunc.accumulate(blocks, axis=2).reshape(s, p)
    suf = ufunc.accumulate(blocks[:, :, ::-1], axis=2)[:, :, ::-1].reshape(s, p)
    end = np.arange(t) + w - 1
    out = suf[:, :t].copy()
    inb = end < p  # windows whose last index falls inside the padded array
    out[:, inb] = ufunc(out[:, inb], pre[:, end[inb]])
    return out


def fluctuation_table(
    values: np.ndarray,
    delta_global: np.ndarray,
    config: ShrinkConfig,
    lengths: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized Alg. 2 for a batch of series: the (level, eps_hat) that
    ``divide`` would compute for a cone starting at every (series, index).

    values:       [S, T] float64.
    delta_global: [S] per-series global max - min.
    lengths:      optional [S] valid sample count per row (ragged lanes,
                  padded to T).  Each row gets its own interval length
                  ``L = default_interval_length(lengths[s])`` and its
                  division windows truncate at ``lengths[s]`` — exactly as
                  if the row were scanned alone at its true length.
                  Entries at positions >= lengths[s] are meaningless (the
                  ragged cone scan masks them).

    Returns (levels int64 [S, T], eps_hat float64 [S, T]), bit-identical to
    calling ``divide(values[s, :n_s], t, L_s, delta_global[s], config)``
    pointwise for every valid (s, t).
    """
    values = np.asarray(values, dtype=np.float64)
    s, t = values.shape
    if t == 0:
        z = np.zeros((s, 0))
        return z.astype(np.int64), z
    if lengths is None:
        w = max(default_interval_length(t, config), 2)
        dmax = _sliding_forward(values, w, np.maximum, -math.inf)
        dmin = _sliding_forward(values, w, np.minimum, math.inf)
    else:
        lengths = np.asarray(lengths, dtype=np.int64)
        # Truncate windows at each row's end by substituting non-constraining
        # values past it: -inf never raises a max, +inf never lowers a min —
        # the same semantics as the window slice stopping at the series end.
        pad_mask = np.arange(t)[None, :] >= lengths[:, None]
        vmax_in = np.where(pad_mask, -math.inf, values)
        vmin_in = np.where(pad_mask, math.inf, values)
        dmax = np.empty_like(values)
        dmin = np.empty_like(values)
        ws = np.array([max(default_interval_length(int(n), config), 2) for n in lengths])
        for w in np.unique(ws):
            rows = np.flatnonzero(ws == w)
            dmax[rows] = _sliding_forward(vmax_in[rows], int(w), np.maximum, -math.inf)
            dmin[rows] = _sliding_forward(vmin_in[rows], int(w), np.minimum, math.inf)
    delta_local = dmax - dmin
    delta_local[:, -1] = 0.0  # size-1 window -> divide() reports 0
    if lengths is not None:
        valid = np.flatnonzero(lengths > 0)
        delta_local[valid, lengths[valid] - 1] = 0.0
        delta_local[pad_mask] = 0.0  # masked positions: keep finite
    dg = np.asarray(delta_global, dtype=np.float64)[:, None]
    beta = np.clip(
        np.divide(delta_local, dg, out=np.zeros_like(delta_local), where=dg > 0),
        0.0,
        1.0,
    )
    levels = np.rint(beta * config.beta_levels).astype(np.int64)
    lut = np.array(
        [eps_hat_for_level(lv, config) for lv in range(config.beta_levels + 1)]
    )
    return levels, lut[levels]


def divide(
    values: np.ndarray,
    j: int,
    L: int,
    delta_global: float,
    config: ShrinkConfig,
) -> tuple[float, int, float]:
    """Alg. 2 (DIVISION): returns (theta, level, eps_hat) for a cone at j.

    values:       the full series (float64 [n]).
    j:            start index of the new cone.
    L:            default interval length (precomputed once per series).
    delta_global: global max - min of the series.
    """
    window = values[j : j + max(L, 2)]
    if window.size >= 2:
        delta_local = float(window.max() - window.min())
    else:
        delta_local = 0.0
    level = beta_level(delta_local, delta_global, config)
    eps_hat = eps_hat_for_level(level, config)
    theta = quantize_origin(float(values[j]), eps_hat)
    return theta, level, eps_hat
