"""Semantics extraction via shrinking cones (Alg. 3 of the paper).

A cone starts at index ``t0`` with a quantized origin ``theta`` (Alg. 2 /
phases.py) and an adaptive threshold ``eps_hat`` fixed for its lifetime.
Every subsequent point (dt = i - t0 > 0) constrains the feasible slope set to

    [ (v_i - eps_hat - theta)/dt ,  (v_i + eps_hat - theta)/dt ]

and the cone keeps the running intersection (psi_lo, psi_hi).  When the
intersection empties, the cone closes and a new one starts at the violating
point — Fig. 2(b) of the paper.

Two implementations with identical semantics:

* ``extract_semantics_py``  — literal per-point loop; the test oracle.
* ``extract_semantics``     — chunked-vectorized numpy scan (production host
  path).  Within a candidate chunk the running intersection is a prefix
  min/max (``np.minimum.accumulate``), and the first emptiness is located
  with ``argmax`` — O(n) total work, numpy-speed.

The Pallas kernel ``kernels/cone_scan.py`` implements the same recurrence on
TPU using the sequential-grid idiom; ``kernels/ref.py`` mirrors this module.
"""
from __future__ import annotations

import math

import numpy as np

from .phases import default_interval_length, divide
from .types import Segment, ShrinkConfig

__all__ = ["extract_semantics", "extract_semantics_py", "global_range"]

_INF = math.inf


def global_range(values: np.ndarray) -> tuple[float, float]:
    return float(values.min()), float(values.max())


def extract_semantics_py(values: np.ndarray, config: ShrinkConfig) -> list[Segment]:
    """Reference loop implementation (kept simple; used as the oracle)."""
    n = int(values.shape[0])
    if n == 0:
        return []
    vmin, vmax = global_range(values)
    delta_global = vmax - vmin
    L = default_interval_length(n, config)

    segments: list[Segment] = []
    i = 0
    while i < n:
        theta, level, eps_hat = divide(values, i, L, delta_global, config)
        psi_lo, psi_hi = -_INF, _INF
        j = i + 1
        while j < n:
            dt = float(j - i)
            hi = (float(values[j]) + eps_hat - theta) / dt
            lo = (float(values[j]) - eps_hat - theta) / dt
            new_hi = min(psi_hi, hi)
            new_lo = max(psi_lo, lo)
            if new_lo > new_hi:
                break  # cone empty -> close at j-1, next cone starts at j
            psi_lo, psi_hi = new_lo, new_hi
            j += 1
        segments.append(
            Segment(theta=theta, level=level, psi_lo=psi_lo, psi_hi=psi_hi, t0=i, length=j - i)
        )
        i = j
    return segments


def extract_semantics(values: np.ndarray, config: ShrinkConfig) -> list[Segment]:
    """Chunked-vectorized scan; semantics identical to extract_semantics_py."""
    values = np.asarray(values, dtype=np.float64)
    n = int(values.shape[0])
    if n == 0:
        return []
    vmin, vmax = global_range(values)
    delta_global = vmax - vmin
    L = default_interval_length(n, config)

    segments: list[Segment] = []
    i = 0
    while i < n:
        theta, level, eps_hat = divide(values, i, L, delta_global, config)
        psi_lo, psi_hi = -_INF, _INF
        j = i + 1
        chunk = 256
        closed = False
        while j < n:
            end = min(n, j + chunk)
            dt = np.arange(j - i, end - i, dtype=np.float64)
            seg_vals = values[j:end]
            hi = (seg_vals + (eps_hat - theta)) / dt
            lo = (seg_vals - (eps_hat + theta)) / dt
            run_hi = np.minimum(np.minimum.accumulate(hi), psi_hi)
            run_lo = np.maximum(np.maximum.accumulate(lo), psi_lo)
            viol = run_lo > run_hi
            if viol.any():
                idx = int(np.argmax(viol))
                if idx > 0:
                    psi_hi = float(run_hi[idx - 1])
                    psi_lo = float(run_lo[idx - 1])
                k = j + idx
                segments.append(
                    Segment(theta=theta, level=level, psi_lo=psi_lo, psi_hi=psi_hi, t0=i, length=k - i)
                )
                i = k
                closed = True
                break
            psi_hi = float(run_hi[-1])
            psi_lo = float(run_lo[-1])
            j = end
            chunk = min(chunk * 2, 65536)
        if not closed:
            segments.append(
                Segment(theta=theta, level=level, psi_lo=psi_lo, psi_hi=psi_hi, t0=i, length=n - i)
            )
            i = n
    return segments
