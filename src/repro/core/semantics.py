"""Semantics extraction via shrinking cones (Alg. 3 of the paper).

A cone starts at index ``t0`` with a quantized origin ``theta`` (Alg. 2 /
phases.py) and an adaptive threshold ``eps_hat`` fixed for its lifetime.
Every subsequent point (dt = i - t0 > 0) constrains the feasible slope set to

    [ (v_i - eps_hat - theta)/dt ,  (v_i + eps_hat - theta)/dt ]

and the cone keeps the running intersection (psi_lo, psi_hi).  When the
intersection empties, the cone closes and a new one starts at the violating
point — Fig. 2(b) of the paper.

Three implementations with identical semantics:

* ``extract_semantics_py``     — literal per-point loop; the test oracle.
* ``extract_semantics``        — chunked-vectorized numpy scan (production
  host path).  Within a candidate chunk the running intersection is a prefix
  min/max (``np.minimum.accumulate``), and the first emptiness is located
  with ``argmax`` — O(n) total work, numpy-speed.
* ``extract_semantics_batch``  — the same chunked scan run in lockstep over
  S independent series at once ([S, T] input).  Candidate slopes, running
  intersections, and first-violation searches are [S, chunk] array ops;
  only series that break inside a chunk re-scan the remainder of that
  chunk.  Because min/max and first-violation do not depend on how the time
  axis is chunked, the per-series output is bit-identical to
  ``extract_semantics`` on each row.

The Pallas kernel ``kernels/cone_scan.py`` implements the same recurrence on
TPU using the sequential-grid idiom; ``kernels/ref.py`` mirrors this module.
"""
from __future__ import annotations

import math

import numpy as np

from .phases import default_interval_length, divide, fluctuation_table
from .types import Segment, ShrinkConfig

__all__ = [
    "extract_semantics",
    "extract_semantics_py",
    "extract_semantics_batch",
    "extract_semantics_batch_pallas",
    "global_range",
]

_INF = math.inf
# row-block size (in elements) for the batched cone scan: big enough to
# amortize per-block python overhead, small enough that the [rows, T]
# temporaries stay cache-resident (measured sweet spot on the bench box)
_BATCH_BLOCK_ELEMS = 64 * 1024


def global_range(values: np.ndarray) -> tuple[float, float]:
    if values.size == 0:  # empty series compress to an empty base
        return 0.0, 0.0
    return float(values.min()), float(values.max())


def extract_semantics_py(
    values: np.ndarray,
    config: ShrinkConfig,
    value_range: tuple[float, float] | None = None,
    n_hint: int | None = None,
) -> list[Segment]:
    """Reference loop implementation (kept simple; used as the oracle).

    ``value_range``/``n_hint`` pin the two global quantities the scan
    otherwise derives from the full series (the fluctuation denominator
    ``delta_global`` and the interval length ``L``).  Streaming ingest
    pins them so a chunk-at-a-time scan matches this one-shot scan
    bit-for-bit; ``None`` keeps the derive-from-data behavior.
    """
    n = int(values.shape[0])
    if n == 0:
        return []
    vmin, vmax = global_range(values) if value_range is None else value_range
    delta_global = vmax - vmin
    L = default_interval_length(n if n_hint is None else int(n_hint), config)

    segments: list[Segment] = []
    i = 0
    while i < n:
        theta, level, eps_hat = divide(values, i, L, delta_global, config)
        psi_lo, psi_hi = -_INF, _INF
        j = i + 1
        while j < n:
            dt = float(j - i)
            hi = (float(values[j]) + eps_hat - theta) / dt
            lo = (float(values[j]) - eps_hat - theta) / dt
            new_hi = min(psi_hi, hi)
            new_lo = max(psi_lo, lo)
            if new_lo > new_hi:
                break  # cone empty -> close at j-1, next cone starts at j
            psi_lo, psi_hi = new_lo, new_hi
            j += 1
        segments.append(
            Segment(theta=theta, level=level, psi_lo=psi_lo, psi_hi=psi_hi, t0=i, length=j - i)
        )
        i = j
    return segments


def extract_semantics(
    values: np.ndarray,
    config: ShrinkConfig,
    value_range: tuple[float, float] | None = None,
    n_hint: int | None = None,
) -> list[Segment]:
    """Chunked-vectorized scan; semantics identical to extract_semantics_py.

    ``value_range``/``n_hint`` optionally pin ``delta_global`` and the
    interval length ``L`` (see ``extract_semantics_py``); defaults derive
    them from ``values`` exactly as before.
    """
    values = np.asarray(values, dtype=np.float64)
    n = int(values.shape[0])
    if n == 0:
        return []
    vmin, vmax = global_range(values) if value_range is None else value_range
    delta_global = vmax - vmin
    L = default_interval_length(n if n_hint is None else int(n_hint), config)

    segments: list[Segment] = []
    i = 0
    while i < n:
        theta, level, eps_hat = divide(values, i, L, delta_global, config)
        psi_lo, psi_hi = -_INF, _INF
        j = i + 1
        chunk = 256
        closed = False
        while j < n:
            end = min(n, j + chunk)
            dt = np.arange(j - i, end - i, dtype=np.float64)
            seg_vals = values[j:end]
            hi = (seg_vals + (eps_hat - theta)) / dt
            lo = (seg_vals - (eps_hat + theta)) / dt
            run_hi = np.minimum(np.minimum.accumulate(hi), psi_hi)
            run_lo = np.maximum(np.maximum.accumulate(lo), psi_lo)
            viol = run_lo > run_hi
            if viol.any():
                idx = int(np.argmax(viol))
                if idx > 0:
                    psi_hi = float(run_hi[idx - 1])
                    psi_lo = float(run_lo[idx - 1])
                k = j + idx
                segments.append(
                    Segment(theta=theta, level=level, psi_lo=psi_lo, psi_hi=psi_hi, t0=i, length=k - i)
                )
                i = k
                closed = True
                break
            psi_hi = float(run_hi[-1])
            psi_lo = float(run_lo[-1])
            j = end
            chunk = min(chunk * 2, 65536)
        if not closed:
            segments.append(
                Segment(theta=theta, level=level, psi_lo=psi_lo, psi_hi=psi_hi, t0=i, length=n - i)
            )
            i = n
    return segments


def extract_semantics_batch(
    values: np.ndarray,
    config: ShrinkConfig,
    chunk: int = 256,
    lengths: np.ndarray | None = None,
) -> list[list[Segment]]:
    """Multi-series cone scan: values[S, T] -> one segment list per series.

    All series advance through shared time chunks; per-series cone state
    (theta, eps_hat, t0, psi) lives in [S] vectors.  A chunk is re-scanned
    only for the series that broke inside it, with positions at or before
    the new segment start masked to non-constraining candidates.  The chunk
    length adapts to the observed break density (long segments -> bigger
    chunks); the output is invariant to chunking.

    ``lengths`` makes the lanes ragged: row s holds a series of
    ``lengths[s]`` real samples padded to T.  Positions past a row's length
    are masked to non-constraining candidates (the padding can never break
    or extend a cone) and the final segment closes at the row's own end, so
    each row's output is bit-identical to ``extract_semantics`` on its
    unpadded slice — padding never leaks into cones.
    """
    values = np.asarray(values, dtype=np.float64)
    if values.ndim != 2:
        raise ValueError(f"expected [S, T], got shape {values.shape}")
    s, n = values.shape
    # Cache blocking: the scan's whole-matrix passes (fluctuation table,
    # re-scan gathers) stream [S, T]-sized temporaries, which for large
    # batches fall out of cache and run ~1.5x slower than row blocks that
    # fit.  Rows are independent (each is bit-identical to the scalar
    # scan), so block outputs concatenate unchanged.
    rows_blk = max(1, _BATCH_BLOCK_ELEMS // max(1, n))
    if s > rows_blk:
        blocks: list[list[Segment]] = []
        for lo in range(0, s, rows_blk):
            blocks.extend(
                extract_semantics_batch(
                    values[lo : lo + rows_blk],
                    config,
                    chunk=chunk,
                    lengths=None
                    if lengths is None
                    else np.asarray(lengths, dtype=np.int64)[lo : lo + rows_blk],
                )
            )
        return blocks
    out: list[list[Segment]] = [[] for _ in range(s)]
    if n == 0 or s == 0:
        return out
    if lengths is None:
        ns = np.full(s, n, dtype=np.int64)
        delta_global = values.max(axis=1) - values.min(axis=1)
        levels_tab, eps_tab = fluctuation_table(values, delta_global, config)
    else:
        ns = np.asarray(lengths, dtype=np.int64)
        if ns.shape != (s,):
            raise ValueError(f"lengths must be [S]={s}, got shape {ns.shape}")
        if (ns < 0).any() or (ns > n).any():
            raise ValueError(f"lengths must lie in [0, T={n}]")
        pad_mask = np.arange(n)[None, :] >= ns[:, None]
        vmax_in = np.where(pad_mask, -_INF, values)
        vmin_in = np.where(pad_mask, _INF, values)
        delta_global = np.where(ns > 0, vmax_in.max(axis=1) - vmin_in.min(axis=1), 0.0)
        levels_tab, eps_tab = fluctuation_table(values, delta_global, config, lengths=ns)
    live = ns > 0  # rows with no samples emit no segments

    seg_level = levels_tab[:, 0].copy()
    eps = np.where(live, eps_tab[:, 0], 1.0)  # dead rows: any finite eps
    theta = np.floor(values[:, 0] / eps) * eps
    t0 = np.zeros(s, dtype=np.int64)
    psi_lo = np.full(s, -_INF)
    psi_hi = np.full(s, _INF)

    c0 = 1
    n_scan = int(ns.max()) if s else 0
    while c0 < n_scan:
        c1 = min(n_scan, c0 + chunk)
        active = np.flatnonzero(ns > c0)  # rows with real samples in this chunk
        lo0 = c0  # re-scans only need positions past the earliest new segment
        breaks = 0
        while active.size:
            ts = np.arange(lo0, c1, dtype=np.float64)
            v = values[active, lo0:c1]
            ep = eps[active][:, None]
            th = theta[active][:, None]
            dt = ts[None, :] - t0[active][:, None]
            with np.errstate(divide="ignore", invalid="ignore"):
                hi = (v + (ep - th)) / dt
                lo = (v - (ep + th)) / dt
            pre = dt <= 0  # positions at/before the segment start: no constraint
            if lengths is not None:
                # ragged lanes: padding is likewise non-constraining
                pre = pre | (ts[None, :] >= ns[active][:, None])
            if pre.any():
                hi[pre] = _INF
                lo[pre] = -_INF
            run_hi = np.minimum(np.minimum.accumulate(hi, axis=1), psi_hi[active][:, None])
            run_lo = np.maximum(np.maximum.accumulate(lo, axis=1), psi_lo[active][:, None])
            viol = run_lo > run_hi
            has = viol.any(axis=1)
            done = active[~has]
            if done.size:  # cone survived the chunk: carry the intersection
                psi_hi[done] = run_hi[~has, -1]
                psi_lo[done] = run_lo[~has, -1]
            if not has.any():
                break
            rows = np.flatnonzero(has)
            broke = active[has]
            breaks += broke.size
            first = viol[rows].argmax(axis=1)
            closed_hi = np.where(first > 0, run_hi[rows, first - 1], psi_hi[broke])
            closed_lo = np.where(first > 0, run_lo[rows, first - 1], psi_lo[broke])
            brk_t = lo0 + first
            for a, k, plo, phi in zip(broke, brk_t, closed_lo, closed_hi):
                out[a].append(
                    Segment(
                        theta=float(theta[a]),
                        level=int(seg_level[a]),
                        psi_lo=float(plo),
                        psi_hi=float(phi),
                        t0=int(t0[a]),
                        length=int(k - t0[a]),
                    )
                )
            # open a new cone at the violating point (Alg. 2 DIVISION)
            seg_level[broke] = levels_tab[broke, brk_t]
            eps[broke] = eps_tab[broke, brk_t]
            theta[broke] = np.floor(values[broke, brk_t] / eps[broke]) * eps[broke]
            t0[broke] = brk_t
            psi_lo[broke] = -_INF
            psi_hi[broke] = _INF
            active = broke  # re-scan the chunk tail for just these series
            lo0 = int(brk_t.min()) + 1
            if lo0 >= c1:
                break
        if breaks == 0:
            chunk = min(chunk * 2, 65536)
        else:  # aim for ~2x the observed mean segment length
            mean_len = (c1 - c0) * max(int(np.count_nonzero(ns > c0)), 1) / breaks
            chunk = int(min(max(2 * mean_len, 128), 65536))
        c0 = c1
    for a in np.flatnonzero(live):
        out[a].append(
            Segment(
                theta=float(theta[a]),
                level=int(seg_level[a]),
                psi_lo=float(psi_lo[a]),
                psi_hi=float(psi_hi[a]),
                t0=int(t0[a]),
                length=int(ns[a] - t0[a]),
            )
        )
    return out


_SPAN_SENTINEL = 1e38  # kernel spans at/beyond this magnitude mean "unbounded"


def extract_semantics_batch_pallas(
    values: np.ndarray,
    config: ShrinkConfig,
    block_t: int = 256,
    lengths: np.ndarray | None = None,
) -> list[list[Segment]]:
    """Multi-series cone scan routed through the lane-parallel Pallas kernel
    (``kernels.cone_scan``) with segment compaction done in XLA; only the
    final Segment materialization happens on the host.

    ``lengths`` activates the kernel's valid-length mask path for ragged
    lanes: row s carries ``lengths[s]`` real samples padded to T, the
    in-kernel mask freezes a lane's cone state past its length (padding
    can never break, constrain, or seed a cone), and each row's segments
    partition [0, lengths[s]).

    The device scan runs in float32 (TPU-native), so — unlike
    ``extract_semantics_batch`` — segment spans can differ from the float64
    host scan in the last ulp.  Use this path for throughput on TPU; the
    numpy path is the bit-exact reference.
    """
    from ..kernels import ops as _kops  # lazy: keep numpy-only users jax-free

    values = np.asarray(values, dtype=np.float64)
    if values.ndim != 2:
        raise ValueError(f"expected [S, T], got shape {values.shape}")
    s, n = values.shape
    if n == 0 or s == 0:
        return [[] for _ in range(s)]
    if lengths is None:
        ns = np.full(s, n, dtype=np.int64)
        delta_global = values.max(axis=1) - values.min(axis=1)
        levels_tab, eps_tab = fluctuation_table(values, delta_global, config)
    else:
        ns = np.asarray(lengths, dtype=np.int64)
        if ns.shape != (s,):
            raise ValueError(f"lengths must be [S]={s}, got shape {ns.shape}")
        if (ns < 1).any() or (ns > n).any():
            raise ValueError(
                "pallas route needs lengths in [1, T]; route empty series "
                "around the kernel (compress_batch does)"
            )
        pad_mask = np.arange(n)[None, :] >= ns[:, None]
        # benign padding for the device scan: repeat each row's last real
        # value (the kernel masks these positions; repeats just keep every
        # float op finite in float32)
        values = np.where(pad_mask, values[np.arange(s), ns - 1][:, None], values)
        vmax_in = np.where(pad_mask, -_INF, values)
        vmin_in = np.where(pad_mask, _INF, values)
        delta_global = vmax_in.max(axis=1) - vmin_in.min(axis=1)
        levels_tab, eps_tab = fluctuation_table(values, delta_global, config, lengths=ns)
        eps_tab = np.where(pad_mask, eps_tab[np.arange(s), ns - 1][:, None], eps_tab)
    bt = min(block_t, n)
    x = values
    eps_in = eps_tab
    if n % bt:
        # pad by repeating the last column so the grid stays block_t-wide;
        # the kernel's valid-length mask keeps the pad region inert.
        pad = bt - (n % bt)
        x = np.concatenate([x, np.repeat(x[:, -1:], pad, axis=1)], axis=1)
        eps_in = np.concatenate([eps_in, np.repeat(eps_in[:, -1:], pad, axis=1)], axis=1)
    counts, t0s, thetas, lo, hi = (
        np.asarray(a)
        for a in _kops.cone_scan_segments(
            np.ascontiguousarray(x.T, dtype=np.float32),
            np.ascontiguousarray(eps_in.T, dtype=np.float32),
            block_t=bt,
            lengths=ns,
        )
    )
    out: list[list[Segment]] = []
    for a in range(s):
        n_a = int(ns[a])
        c = int(counts[a])
        starts = t0s[:c, a].astype(np.int64)
        keep = starts < n_a  # defensive: masked lanes cannot break past n_a
        starts = starts[keep]
        c = starts.size
        ends = np.minimum(np.append(starts[1:], n_a), n_a)
        plo = lo[:c, a].astype(np.float64)
        phi = hi[:c, a].astype(np.float64)
        plo[plo <= -_SPAN_SENTINEL] = -_INF
        phi[phi >= _SPAN_SENTINEL] = _INF
        out.append(
            [
                Segment(
                    theta=float(thetas[k, a]),
                    level=int(levels_tab[a, starts[k]]),
                    psi_lo=float(plo[k]),
                    psi_hi=float(phi[k]),
                    t0=int(starts[k]),
                    length=int(ends[k] - starts[k]),
                )
                for k in range(c)
            ]
        )
    return out
