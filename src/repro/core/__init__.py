"""SHRINK core: semantics extraction, base construction, residual encoding.

Public API re-exports.
"""
from .errors import (  # noqa: F401
    BackpressureError,
    BatcherFinalizedError,
    CircuitOpenError,
    ConfigError,
    CorruptFrameError,
    DeadlineExceededError,
    FormatError,
    LayerCorruptError,
    QuotaExceededError,
    RangeCoverageError,
    ShrinkError,
    TransientError,
    TruncatedArchiveError,
    UnknownSeriesError,
)
from .types import (  # noqa: F401
    Base,
    CompressedSeries,
    PyramidLayer,
    ResidualPyramid,
    ResidualStream,
    Segment,
    ShrinkConfig,
    SubBase,
)
from .phases import (  # noqa: F401
    default_interval_length,
    divide,
    eps_hat_for_level,
    fluctuation_table,
)
from .semantics import (  # noqa: F401
    extract_semantics,
    extract_semantics_batch,
    extract_semantics_batch_pallas,
    extract_semantics_py,
)
from .base import base_predictions, construct_base, practical_eps_b  # noqa: F401
from .segment_algebra import (  # noqa: F401
    BaseStats,
    SegmentTable,
    base_aggregate,
    count_cmp,
    segment_table,
)
from .slope import optimized_slope, shortest_decimal_in_interval  # noqa: F401
from .residuals import (  # noqa: F401
    compute_residuals,
    dequantize_exact,
    dequantize_residuals,
    normalize_tiers,
    quantize_exact,
    quantize_exact_batch,
    quantize_pyramid,
    quantize_pyramid_batch,
    quantize_residuals,
    quantize_residuals_batch,
)
from .shrink import (  # noqa: F401
    BYTES_PER_ROW,
    ProgressiveDecoder,
    ShrinkCodec,
    cs_from_bytes,
    cs_to_bytes,
    decompress_at,
    encode_with_base,
    original_size_bytes,
)
from .streaming import (  # noqa: F401
    KnowledgeBase,
    ShrinkStreamCodec,
    decode_range,
    decode_series,
    read_knowledge_base,
    routing_metadata,
)
from . import entropy, serialize  # noqa: F401
