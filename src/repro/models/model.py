"""Top-level Model facade: config -> init / loss / prefill / decode +
ShapeDtypeStruct input specs for every assigned shape cell.

Batch conventions per shape kind (DESIGN.md §5):
  train:    tokens[B, S] + labels[B, S]                  (LM)
            frames[B, Se, D] + tokens/labels[B, Sd]      (enc-dec, Se=Sd=S/2)
            tokens[B, S] + vision[B, Nv, D]              (VLM)
  prefill:  same inputs, emits caches + last-position logits
  decode:   tokens[B, 1] + caches + cache_index (one new token against a
            KV cache of seq_len)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeSpec
from ..parallel.sharding import shard
from . import transformer as T

Params = dict


def _positions(b: int, s: int) -> jax.Array:
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------- params
    def init(self, key) -> Params:
        return T.init_params(key, self.cfg)

    def init_shapes(self, key=None) -> Any:
        """Shape-only init via eval_shape (no allocation) — dry-run path."""
        k = jax.random.PRNGKey(0) if key is None else key
        return jax.eval_shape(lambda kk: T.init_params(kk, self.cfg), k)

    # ------------------------------------------------------------ forward
    def _context(self, batch: dict) -> Optional[jax.Array]:
        cfg = self.cfg
        if cfg.family == "encdec":
            return T.encode(batch["params_ref"], batch["frames"], cfg) if False else None
        return None

    def loss(self, params: Params, batch: dict) -> tuple[jax.Array, dict]:
        """Causal LM loss (mean xent over tokens) + aux (MoE load balance,
        z-loss).  For enc-dec: encoder frames + decoder tokens."""
        cfg = self.cfg
        tokens = batch["tokens"]
        labels = batch["labels"]
        b, s = tokens.shape
        x = T.embed_tokens(params, tokens, cfg)
        cross = None
        if cfg.family == "encdec":
            cross = T.encode(params, batch["frames"].astype(T.COMPUTE_DTYPE), cfg)
        elif cfg.family == "vlm":
            cross = batch["vision"].astype(T.COMPUTE_DTYPE)
        x, _, aux = T.apply_stack(
            params, x, cfg, mode="train", positions=_positions(b, s),
            cross_source=cross,
        )
        logits = T.logits_from(params, x, cfg).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        xent = (logz - tgt).mean()
        z_loss = 1e-4 * jnp.mean(logz**2)
        moe_loss = 1e-2 * aux
        total = xent + z_loss + moe_loss
        return total, {"xent": xent, "z_loss": z_loss, "moe_aux": aux}

    def prefill(self, params: Params, batch: dict) -> tuple[jax.Array, Any]:
        """Full-sequence forward emitting caches + last-token logits."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = T.embed_tokens(params, tokens, cfg)
        cross = None
        if cfg.family == "encdec":
            cross = T.encode(params, batch["frames"].astype(T.COMPUTE_DTYPE), cfg)
        elif cfg.family == "vlm":
            cross = batch["vision"].astype(T.COMPUTE_DTYPE)
        x, caches, _ = T.apply_stack(
            params, x, cfg, mode="prefill", positions=_positions(b, s),
            cross_source=cross,
        )
        logits = T.logits_from(params, x[:, -1:, :], cfg)
        return logits, caches

    def decode_step(
        self, params: Params, tokens: jax.Array, caches: Any, cache_index: jax.Array
    ) -> tuple[jax.Array, Any]:
        """One token (tokens [B,1]) against caches at position cache_index."""
        cfg = self.cfg
        b = tokens.shape[0]
        x = T.embed_tokens(params, tokens, cfg)
        positions = jnp.full((b, 1), cache_index, jnp.int32)
        x, new_caches, _ = T.apply_stack(
            params, x, cfg, mode="decode", positions=positions,
            caches=caches, cache_index=cache_index,
        )
        logits = T.logits_from(params, x, cfg)
        return logits, new_caches

    # ---------------------------------------------------------- dry specs
    def make_decode_caches(self, batch: int, max_seq: int):
        cross_len = self._cross_len(max_seq)
        return T.make_decode_caches(self.cfg, batch, max_seq, cross_len)

    def _cross_len(self, seq: int) -> int:
        if self.cfg.family == "encdec":
            return int(seq * self.cfg.audio_frames_ratio)
        if self.cfg.family == "vlm":
            return self.cfg.vision_tokens
        return 0

    def input_specs(self, shape: ShapeSpec, per_device_batch: Optional[int] = None) -> dict:
        """ShapeDtypeStruct stand-ins for jit lowering (no allocation)."""
        cfg = self.cfg
        b = shape.global_batch if per_device_batch is None else per_device_batch
        s = shape.seq_len
        f32 = jnp.float32
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct
        if shape.kind == "train":
            batch = {}
            if cfg.family == "encdec":
                se = int(s * cfg.audio_frames_ratio)
                sd = s - se
                batch["frames"] = sds((b, se, cfg.d_model), f32)
                batch["tokens"] = sds((b, sd), i32)
                batch["labels"] = sds((b, sd), i32)
            else:
                batch["tokens"] = sds((b, s), i32)
                batch["labels"] = sds((b, s), i32)
                if cfg.family == "vlm":
                    batch["vision"] = sds((b, cfg.vision_tokens, cfg.d_model), f32)
            return batch
        if shape.kind == "prefill":
            batch = {}
            if cfg.family == "encdec":
                se = int(s * cfg.audio_frames_ratio)
                batch["frames"] = sds((b, se, cfg.d_model), f32)
                batch["tokens"] = sds((b, s - se), i32)
            else:
                batch["tokens"] = sds((b, s), i32)
                if cfg.family == "vlm":
                    batch["vision"] = sds((b, cfg.vision_tokens, cfg.d_model), f32)
            return batch
        # decode: one token + caches at seq_len context
        caches = jax.eval_shape(lambda: self.make_decode_caches(b, s))
        return {
            "tokens": sds((b, 1), i32),
            "caches": caches,
            "cache_index": sds((), i32),
        }


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
