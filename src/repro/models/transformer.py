"""Decoder-only / encoder-decoder transformer assembly.

Layers are grouped into the smallest repeating pattern (``cfg.block_group``:
e.g. ("rec","rec","local") for recurrentgemma, 4x"attn"+1x"cross" for the
VLM, ("attn","attn") with dense/MoE FFNs for llama4) and the group stack is
scanned with ``jax.lax.scan`` over stacked params — bounding HLO size and
compile time at 512 devices and giving per-group remat.  A non-divisible
tail (recurrentgemma's 38 = 12*3 + 2) runs as unscanned tail blocks.

Modes: "train" (no caches), "prefill" (emit caches/states), "decode" (one
token step against caches/states).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel.sharding import shard
from . import layers
from .layers import (
    AttnCache,
    MLACache,
    RecState,
    RwkvState,
    COMPUTE_DTYPE,
    attention_apply,
    attention_init,
    mla_apply,
    mla_init,
    mlp_apply,
    mlp_init,
    moe_apply,
    moe_init,
    rglru_apply,
    rglru_init,
    rmsnorm,
    rmsnorm_init,
    rwkv_apply,
    rwkv_init,
)

Params = dict


# ------------------------------------------------------------ block structs
def _block_kind(cfg: ModelConfig, layer_idx: int) -> str:
    return cfg.block_group[layer_idx % len(cfg.block_group)]


def block_init(key, cfg: ModelConfig, layer_idx: int, kind: str, encoder: bool = False) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {"ln1": rmsnorm_init(cfg.d_model, cfg), "ln2": rmsnorm_init(cfg.d_model, cfg)}
    if kind in ("attn", "local", "cross"):
        p["attn"] = mla_init(k1, cfg) if (cfg.mla and not encoder) else attention_init(k1, cfg)
    elif kind == "rec":
        p["rec"] = rglru_init(k1, cfg)
    elif kind == "rwkv":
        p["rwkv"] = rwkv_init(k1, cfg)
    if kind == "cross" and not encoder:
        p["ln_cross"] = rmsnorm_init(cfg.d_model, cfg)
        p["cross_attn"] = attention_init(k3, cfg, cross=True)
    if kind != "rwkv":  # rwkv embeds its channel-mix
        if cfg.layer_uses_moe(layer_idx) and not encoder:
            p["moe"] = moe_init(k2, cfg)
        else:
            p["mlp"] = mlp_init(k2, cfg)
    return p


def block_apply(
    p: Params,
    x: jax.Array,
    cfg: ModelConfig,
    kind: str,
    *,
    mode: str,
    positions: jax.Array,
    cache: Optional[dict] = None,
    cache_index: Optional[jax.Array] = None,
    cross_source: Optional[jax.Array] = None,
    encoder: bool = False,
) -> tuple[jax.Array, Optional[dict], jax.Array]:
    """Returns (x, new_cache_dict, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: dict = {}
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if kind in ("attn", "local", "cross"):
        if cfg.mla and not encoder:
            y, c = mla_apply(
                p["attn"], h, cfg, positions=positions, mode=mode,
                cache=cache.get("self") if cache else None, cache_index=cache_index,
            )
        else:
            y, c = attention_apply(
                p["attn"], h, cfg, positions=positions, mode=mode,
                mask_kind=("none" if encoder else ("local" if kind == "local" else "causal")),
                cache=cache.get("self") if cache else None, cache_index=cache_index,
                window=cfg.local_window,
            )
        if c is not None:
            new_cache["self"] = c
        x = x + y
        if kind == "cross" and not encoder:
            hc = rmsnorm(x, p["ln_cross"], cfg.norm_eps)
            if mode == "decode":
                yc, _ = attention_apply(
                    p["cross_attn"], hc, cfg, positions=positions, mode="decode_cross",
                    cache=cache.get("cross") if cache else None,
                )
                new_cache["cross"] = cache["cross"]
            else:
                yc, cc = attention_apply(
                    p["cross_attn"], hc, cfg, positions=positions, mode=mode,
                    kv_source=cross_source,
                )
                if mode == "prefill" and cc is not None:
                    new_cache["cross"] = cc
            x = x + yc
    elif kind == "rec":
        y, st = rglru_apply(p["rec"], h, cfg, mode=mode, state=cache.get("rec") if cache else None)
        if st is not None:
            new_cache["rec"] = st
        x = x + y
    elif kind == "rwkv":
        y, st = rwkv_apply(p["rwkv"], h, cfg, mode=mode, state=cache.get("rwkv") if cache else None)
        if st is not None:
            new_cache["rwkv"] = st
        return x + y, (new_cache or None), aux

    h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y2, aux = moe_apply(p["moe"], h2, cfg)
    else:
        y2 = mlp_apply(p["mlp"], h2)
    return x + y2, (new_cache or None), aux


# ------------------------------------------------------- prefill cross path
def cross_prefill_cache(p_block: Params, source: jax.Array, cfg: ModelConfig) -> AttnCache:
    """Precompute cross-attention K/V from the (enc|vision) context."""
    b, s, _ = source.shape
    hd = cfg.resolved_head_dim
    pa = p_block["cross_attn"]
    src = source.astype(COMPUTE_DTYPE)
    k = (src @ pa["wk"].astype(COMPUTE_DTYPE)).reshape(b, s, cfg.n_kv_heads, hd)
    v = (src @ pa["wv"].astype(COMPUTE_DTYPE)).reshape(b, s, cfg.n_kv_heads, hd)
    kpos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    return AttnCache(k=k, v=v, kpos=kpos)


# ------------------------------------------------------------- cache makers
def init_cache_for_kind(
    cfg: ModelConfig, kind: str, batch: int, max_seq: int, cross_len: int = 0
) -> dict:
    hd = cfg.resolved_head_dim
    def attn_cache(buf):
        return AttnCache(
            k=jnp.zeros((batch, buf, cfg.n_kv_heads, hd), COMPUTE_DTYPE),
            v=jnp.zeros((batch, buf, cfg.n_kv_heads, hd), COMPUTE_DTYPE),
            kpos=jnp.full((batch, buf), -1, jnp.int32),
        )
    c: dict = {}
    if kind in ("attn", "cross"):
        if cfg.mla:
            c["self"] = MLACache(
                c_kv=jnp.zeros((batch, max_seq, cfg.kv_lora_rank), COMPUTE_DTYPE),
                k_rope=jnp.zeros((batch, max_seq, cfg.qk_rope_head_dim), COMPUTE_DTYPE),
                kpos=jnp.full((batch, max_seq), -1, jnp.int32),
            )
        else:
            c["self"] = attn_cache(max_seq)
        if kind == "cross":
            c["cross"] = attn_cache(cross_len)
    elif kind == "local":
        c["self"] = attn_cache(min(cfg.local_window, max_seq))
    elif kind == "rec":
        w = cfg.lru_width or cfg.d_model
        c["rec"] = RecState(
            h=jnp.zeros((batch, w), jnp.float32),
            conv=jnp.zeros((batch, cfg.conv_width - 1, w), COMPUTE_DTYPE),
        )
    elif kind == "rwkv":
        hk = cfg.rwkv_head_dim
        nh = cfg.d_model // hk
        c["rwkv"] = RwkvState(
            wkv=jnp.zeros((batch, nh, hk, hk), jnp.float32),
            shift_t=jnp.zeros((batch, cfg.d_model), COMPUTE_DTYPE),
            shift_c=jnp.zeros((batch, cfg.d_model), COMPUTE_DTYPE),
        )
    return c


def _remat_policy(cfg: ModelConfig):
    if cfg.remat_policy == "dots":
        # save matmul outputs, recompute the cheap elementwise tail — trades
        # activation memory for less recompute (the §Perf remat lever)
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable


def _layer_split(cfg: ModelConfig) -> tuple[int, int, int]:
    """(n_prefix, n_groups, n_tail): prefix = leading structurally-different
    layers (deepseek's dense first layer), then scanned homogeneous groups,
    then the non-divisible tail (recurrentgemma 38 = 12*3 + 2)."""
    group = cfg.block_group
    prefix = cfg.first_dense_layers if cfg.n_experts else 0
    eff = cfg.n_layers - prefix
    n_groups = eff // len(group)
    tail = eff - n_groups * len(group)
    return prefix, n_groups, tail


def make_decode_caches(cfg: ModelConfig, batch: int, max_seq: int, cross_len: int = 0):
    """Cache pytree: prefix list + stacked groups + tail list."""
    group = cfg.block_group
    prefix, n_groups, tail = _layer_split(cfg)

    def stack(trees):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)

    prefixes = [
        init_cache_for_kind(cfg, group[i % len(group)], batch, max_seq, cross_len)
        for i in range(prefix)
    ]
    grouped = {}
    for pos, kind in enumerate(group):
        one = init_cache_for_kind(cfg, kind, batch, max_seq, cross_len)
        grouped[f"pos{pos}"] = stack([one] * n_groups) if n_groups else one
    tails = [
        init_cache_for_kind(cfg, group[i % len(group)], batch, max_seq, cross_len)
        for i in range(tail)
    ]
    return {"prefix": prefixes, "groups": grouped, "tail": tails}


# --------------------------------------------------------------- the model
def init_params(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, cfg.n_layers + cfg.n_enc_layers + 4)
    pd = jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32
    vp = cfg.padded_vocab
    params: Params = {
        "embed": (jax.random.normal(keys[0], (vp, cfg.d_model), jnp.float32) * 0.02).astype(pd),
        "ln_f": rmsnorm_init(cfg.d_model, cfg),
    }
    if not cfg.tie_embeddings:
        params["head"] = (
            jax.random.normal(keys[1], (cfg.d_model, vp), jnp.float32)
            * (cfg.d_model**-0.5)
        ).astype(pd)

    group = cfg.block_group
    prefix_n, n_groups, tail_n = _layer_split(cfg)

    params["prefix"] = [
        block_init(jax.random.fold_in(keys[2], 1000 + i), cfg, i, group[i % len(group)])
        for i in range(prefix_n)
    ]

    def one_group(gk, gi):
        gkeys = jax.random.split(gk, len(group))
        return {
            f"pos{p}": block_init(gkeys[p], cfg, prefix_n + gi * len(group) + p, kind)
            for p, kind in enumerate(group)
        }

    if cfg.scan_blocks and n_groups > 0:
        gkeys = jax.random.split(keys[2], n_groups)
        trees = [one_group(gkeys[i], i) for i in range(n_groups)]
        params["groups"] = jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
    else:
        params["groups_list"] = [one_group(jax.random.fold_in(keys[2], i), i) for i in range(n_groups)]
    params["tail"] = [
        block_init(
            jax.random.fold_in(keys[3], i), cfg,
            prefix_n + n_groups * len(group) + i, group[i % len(group)],
        )
        for i in range(tail_n)
    ]
    if cfg.n_enc_layers:
        ekeys = jax.random.split(keys[4], cfg.n_enc_layers)
        etrees = [block_init(ekeys[i], cfg, i, "attn", encoder=True) for i in range(cfg.n_enc_layers)]
        params["encoder"] = jax.tree.map(lambda *xs: jnp.stack(xs), *etrees)
        params["enc_ln_f"] = rmsnorm_init(cfg.d_model, cfg)
    return params


def _apply_group(
    gp: Params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    mode: str,
    positions,
    caches: Optional[dict],
    cache_index,
    cross_source,
):
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: dict = {}
    for pos, kind in enumerate(cfg.block_group):
        c = caches.get(f"pos{pos}") if caches else None
        x, nc, aux = block_apply(
            gp[f"pos{pos}"], x, cfg, kind,
            mode=mode, positions=positions, cache=c, cache_index=cache_index,
            cross_source=cross_source,
        )
        aux_total = aux_total + aux
        if nc is not None:
            new_caches[f"pos{pos}"] = nc
    return x, new_caches, aux_total


def apply_stack(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    mode: str,
    positions: jax.Array,
    caches: Optional[dict] = None,
    cache_index: Optional[jax.Array] = None,
    cross_source: Optional[jax.Array] = None,
):
    """Run prefix blocks + scanned groups + tail.  Returns (x, caches, aux)."""
    group = cfg.block_group
    prefix_n, n_groups, _ = _layer_split(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_group_caches = None

    new_prefix = []
    for i, pp in enumerate(params.get("prefix", [])):
        kind = group[i % len(group)]
        pc = caches["prefix"][i] if caches else None
        x, nc, aux = block_apply(
            pp, x, cfg, kind, mode=mode, positions=positions, cache=pc,
            cache_index=cache_index, cross_source=cross_source,
        )
        aux_total = aux_total + aux
        new_prefix.append(nc)

    if cfg.scan_blocks and n_groups > 0 and "groups" in params:
        use_remat = cfg.remat and mode == "train"
        if caches is None:
            emit = mode == "prefill"

            def body_nc(carry, gp):
                h, auxc = carry
                h, nc, aux = _apply_group(
                    gp, h, cfg, mode=mode, positions=positions, caches=None,
                    cache_index=cache_index, cross_source=cross_source,
                )
                return (h, auxc + aux), (nc if emit else 0)
            fn = jax.checkpoint(body_nc, policy=_remat_policy(cfg)) if use_remat else body_nc
            (x, aux_total), ys = jax.lax.scan(fn, (x, aux_total), params["groups"])
            if emit:
                new_group_caches = ys
        else:
            def body_c(carry, xs):
                h, auxc = carry
                gp, gc = xs
                h, nc, aux = _apply_group(
                    gp, h, cfg, mode=mode, positions=positions, caches=gc,
                    cache_index=cache_index, cross_source=cross_source,
                )
                return (h, auxc + aux), nc
            fn = jax.checkpoint(body_c, policy=_remat_policy(cfg)) if use_remat else body_c
            (x, aux_total), new_group_caches = jax.lax.scan(
                fn, (x, aux_total), (params["groups"], caches["groups"])
            )
    else:
        new_group_caches = {}
        for gi, gp in enumerate(params.get("groups_list", [])):
            gc = (
                jax.tree.map(lambda a: a[gi], caches["groups"]) if caches else None
            )
            x, nc, aux = _apply_group(
                gp, x, cfg, mode=mode, positions=positions, caches=gc,
                cache_index=cache_index, cross_source=cross_source,
            )
            aux_total = aux_total + aux
            if nc:
                new_group_caches[gi] = nc

    new_tail = []
    for i, tp in enumerate(params["tail"]):
        kind = group[i % len(group)]
        tc = caches["tail"][i] if caches else None
        x, nc, aux = block_apply(
            tp, x, cfg, kind, mode=mode, positions=positions, cache=tc,
            cache_index=cache_index, cross_source=cross_source,
        )
        aux_total = aux_total + aux
        new_tail.append(nc)
    out_caches = None
    if mode in ("prefill", "decode"):
        out_caches = {"prefix": new_prefix, "groups": new_group_caches, "tail": new_tail}
    return x, out_caches, aux_total


def encode(params: Params, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Encoder stack over precomputed frontend embeddings [B, S, D]."""
    x = frames
    positions = jnp.broadcast_to(
        jnp.arange(frames.shape[1], dtype=jnp.int32)[None], frames.shape[:2]
    )

    def body(h, gp):
        h, _, _ = block_apply(gp, h, cfg, "attn", mode="train",
                              positions=positions, encoder=True)
        return h, 0

    body_fn = body
    if cfg.remat:
        body_fn = jax.checkpoint(body, policy=_remat_policy(cfg))
    x, _ = jax.lax.scan(body_fn, x, params["encoder"])
    return rmsnorm(x, params["enc_ln_f"], cfg.norm_eps)


def embed_tokens(params: Params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = params["embed"].astype(COMPUTE_DTYPE)[tokens]
    return shard(x, "batch", None, None)


def logits_from(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = rmsnorm(x, params["ln_f"], cfg.norm_eps).astype(COMPUTE_DTYPE)
    if cfg.tie_embeddings:
        w = params["embed"].astype(COMPUTE_DTYPE).T
    else:
        w = params["head"].astype(COMPUTE_DTYPE)
    logits = h @ w
    return shard(logits, "batch", None, "vocab")
