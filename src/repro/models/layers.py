"""Model layers: norms, RoPE, GQA/MQA/MLA/local/cross attention, SwiGLU,
MoE (Switch/GShard scatter dispatch + shared experts), RG-LRU, RWKV6.

Functional style: ``*_init(key, cfg) -> params dict``; apply fns are pure.
Activations are computed in bfloat16 (TPU realism), softmax/norm statistics
in float32.  Sharding is annotated through ``repro.parallel.sharding.shard``
(logical names; a no-op outside a mesh context).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel.sharding import shard, shard_map_compat

Params = dict
COMPUTE_DTYPE = jnp.bfloat16


def _pdtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32


def dense_init(key, in_dim: int, out_dim: int, cfg: ModelConfig, scale: float = 1.0):
    std = scale / (in_dim**0.5)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * std).astype(_pdtype(cfg))


def rmsnorm_init(dim: int, cfg: ModelConfig):
    return jnp.ones((dim,), _pdtype(cfg))


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale.astype(x.dtype)


# --------------------------------------------------------------------- RoPE
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x[..., S, H, D]; positions[..., S] (int).  Rotates pairs (d, d+D/2)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(angles)[..., None, :].astype(x.dtype)  # [..., S, 1, half]
    sin = jnp.sin(angles)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------- attention
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AttnCache:
    """Decode-time KV cache.  k/v: [B, S_buf, KV, D]; kpos: [B, S_buf] abs
    positions (-1 = empty).  S_buf = max_seq (full) or window (local)."""

    k: jax.Array
    v: jax.Array
    kpos: jax.Array


def attention_init(key, cfg: ModelConfig, cross: bool = False) -> Params:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko, kn1, kn2 = jax.random.split(key, 6)
    p = {
        "wq": dense_init(kq, d, cfg.n_heads * hd, cfg),
        "wk": dense_init(kk, d, cfg.n_kv_heads * hd, cfg),
        "wv": dense_init(kv, d, cfg.n_kv_heads * hd, cfg),
        "wo": dense_init(ko, cfg.n_heads * hd, d, cfg),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, cfg)
        p["k_norm"] = rmsnorm_init(hd, cfg)
    return p


def _sdpa(
    q: jax.Array,  # [B, Sq, H, D]
    k: jax.Array,  # [B, Sk, KV, D]
    v: jax.Array,
    mask: Optional[jax.Array],  # [B, 1|H, Sq, Sk] additive or None
    q_chunk: int = 1024,
    softmax_bf16: bool = False,
) -> jax.Array:
    """Chunked (over Sq) softmax attention: bounds the score buffer to
    [B, H, q_chunk, Sk] — prefill_32k never materializes 32k x 32k."""
    b, sq, h, d = q.shape
    kv = k.shape[2]
    rep = h // kv
    if sq == 1 and rep > 1:
        # decode + GQA: grouped einsums, NO repeat.  A repeat on the seq-
        # sharded cache lowers to a gather, which makes GSPMD all-gather
        # K/V every layer; contracting against the raw KV heads keeps the
        # cache local and reduces over the sharded sequence with tiny
        # per-step collectives (flash-decoding).  §Perf decode lever.
        scale = d**-0.5
        qg = q.reshape(b, sq, kv, rep, d)
        s = jnp.einsum("bckrd,bskd->bkrcs", qg, k).astype(jnp.float32) * scale
        s = shard(s, "batch", None, None, None, "seq_model")
        if mask is not None:
            s = s + mask[:, None]  # [B,1|H->1,1,C,S] broadcast over (kv, rep)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        out = jnp.einsum("bkrcs,bskd->bckrd", p, v)
        return out.reshape(b, sq, h, d)
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
        # train/prefill: constrain to the q-head sharding so each model
        # shard materializes only its own slice of the repeated KV
        k = shard(k, "batch", None, "heads", None)
        v = shard(v, "batch", None, "heads", None)
    scale = d**-0.5

    def one_chunk(qc, mc):
        # qc [B, C, H, D]; mc [B, 1|H, C, Sk] or None
        acc = jnp.bfloat16 if softmax_bf16 else jnp.float32
        s = jnp.einsum("bchd,bkhd->bhck", qc, k).astype(acc) * jnp.asarray(scale, acc)
        if sq == 1:
            # decode: keep scores on the cache's sequence sharding so the
            # softmax + AV run as partial reductions (flash-decoding) instead
            # of GSPMD all-gathering K/V (the decode §Perf lever; seq_model
            # resolves to "model" only under make_decode_step's rules)
            s = shard(s, "batch", None, None, "seq_model")
        if mc is not None:
            s = s + mc.astype(s.dtype)
        # max-subtraction keeps bf16 softmax sane (exp <= 1); the row-sum in
        # bf16 over 32k keys costs ~1e-2 relative — a serving-grade trade
        p = jax.nn.softmax(s, axis=-1).astype(qc.dtype)
        return jnp.einsum("bhck,bkhd->bchd", p, v)

    if sq <= q_chunk:
        return one_chunk(q, mask)
    n_chunks = sq // q_chunk
    assert sq % q_chunk == 0, f"Sq={sq} % chunk={q_chunk}"
    dv = v.shape[-1]  # may differ from the qk head dim (MLA)
    qr = q.reshape(b, n_chunks, q_chunk, h, d).transpose(1, 0, 2, 3, 4)
    if mask is not None:
        mb, mh, _, sk = mask.shape  # leading dims may be broadcast (1)
        mr = mask.reshape(mb, mh, n_chunks, q_chunk, sk).transpose(2, 0, 1, 3, 4)
        out = jax.lax.map(lambda args: one_chunk(*args), (qr, mr))
    else:
        out = jax.lax.map(lambda qc: one_chunk(qc, None), qr)
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dv)


def _causal_mask(sq: int, sk: int, dtype=jnp.float32) -> jax.Array:
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    return jnp.where(kpos <= qpos, 0.0, -1e30).astype(dtype)[None, None]


def _local_mask(sq: int, sk: int, window: int, dtype=jnp.float32) -> jax.Array:
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    ok = (kpos <= qpos) & (qpos - kpos < window)
    return jnp.where(ok, 0.0, -1e30).astype(dtype)[None, None]


def attention_apply(
    p: Params,
    x: jax.Array,  # [B, S, D]
    cfg: ModelConfig,
    *,
    positions: jax.Array,  # [B, S]
    mode: str,  # "full" (train/prefill) | "decode"
    mask_kind: str = "causal",  # "causal" | "local" | "none" (encoder)
    cache: Optional[AttnCache] = None,
    cache_index: Optional[jax.Array] = None,
    kv_source: Optional[jax.Array] = None,  # cross-attention context
    window: int = 0,
) -> tuple[jax.Array, Optional[AttnCache]]:
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    xc = x.astype(COMPUTE_DTYPE)
    q = (xc @ p["wq"].astype(COMPUTE_DTYPE)).reshape(b, s, cfg.n_heads, hd)
    is_cross = (kv_source is not None) or mode == "decode_cross"
    if mode == "decode_cross":
        # static cross context: K/V live in the (prefill-built) cache
        assert cache is not None
        k = cache.k.astype(COMPUTE_DTYPE)
        v = cache.v.astype(COMPUTE_DTYPE)
        sk_in = k.shape[1]
    else:
        src = kv_source.astype(COMPUTE_DTYPE) if kv_source is not None else xc
        sk_in = src.shape[1]
        k = (src @ p["wk"].astype(COMPUTE_DTYPE)).reshape(b, sk_in, cfg.n_kv_heads, hd)
        v = (src @ p["wv"].astype(COMPUTE_DTYPE)).reshape(b, sk_in, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        if mode != "decode_cross":
            k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if not is_cross:
        q = rope(q, positions, cfg.rope_theta)
        kpos_new = positions
        k = rope(k, kpos_new, cfg.rope_theta)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)

    new_cache = None
    if mode == "decode" and not is_cross:
        assert cache is not None and cache_index is not None
        buf = cache.k.shape[1]
        slot = (cache_index % buf) if mask_kind == "local" else cache_index
        if cfg.masked_cache_update:
            # one-hot masked write: elementwise over the (seq-sharded) cache,
            # so every shard updates locally — no GSPMD gather around a
            # dynamic-index store (the decode §Perf lever)
            oh = (jnp.arange(buf, dtype=jnp.int32) == slot)
            ohk = oh[None, :, None, None]
            ck = jnp.where(ohk, k.astype(cache.k.dtype), cache.k)
            cv = jnp.where(ohk, v.astype(cache.v.dtype), cache.v)
            ckpos = jnp.where(oh[None, :], positions.astype(cache.kpos.dtype), cache.kpos)
        else:
            ck = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype), (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype), (0, slot, 0, 0))
            ckpos = jax.lax.dynamic_update_slice(
                cache.kpos, positions.astype(cache.kpos.dtype), (0, slot)
            )
        new_cache = AttnCache(k=ck, v=cv, kpos=ckpos)
        k, v = ck.astype(COMPUTE_DTYPE), cv.astype(COMPUTE_DTYPE)
        qpos = positions[:, :, None]  # [B, 1, 1]
        kp = ckpos[:, None, :]  # [B, 1, S_buf]
        ok = (kp >= 0) & (kp <= qpos)
        if mask_kind == "local":
            ok &= (qpos - kp) < window
        mask = jnp.where(ok, 0.0, -1e30).astype(jnp.float32)[:, None]  # [B,1,1,S_buf]
    elif mode == "decode_cross":
        ok = cache.kpos[:, None, None, :] >= 0
        mask = jnp.where(ok, 0.0, -1e30).astype(jnp.float32)
    elif is_cross or mask_kind == "none":
        mask = None
    elif mask_kind == "local":
        mask = _local_mask(s, sk_in, window)
    else:
        mask = _causal_mask(s, sk_in)

    y = _sdpa(q, k, v, mask, softmax_bf16=cfg.attn_softmax_bf16)
    y = shard(y, "batch", None, "heads", None)
    out = (y.reshape(b, s, cfg.n_heads * hd) @ p["wo"].astype(COMPUTE_DTYPE)).astype(x.dtype)
    out = shard(out, "batch", None, None)
    if mode == "prefill":
        if is_cross:
            kpos = jnp.broadcast_to(jnp.arange(sk_in, dtype=jnp.int32)[None], (b, sk_in))
        else:
            kpos = positions.astype(jnp.int32)
        new_cache = AttnCache(k=k.astype(COMPUTE_DTYPE), v=v.astype(COMPUTE_DTYPE), kpos=kpos)
    return out, new_cache


# --------------------------------------------------------------------- MLA
def mla_init(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    qk_nope, qk_rope, v_hd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    h = cfg.n_heads
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], d, h * (qk_nope + qk_rope), cfg),
        "w_dkv": dense_init(ks[1], d, cfg.kv_lora_rank, cfg),
        "w_krope": dense_init(ks[2], d, qk_rope, cfg),
        "w_kup": dense_init(ks[3], cfg.kv_lora_rank, h * qk_nope, cfg),
        "w_vup": dense_init(ks[4], cfg.kv_lora_rank, h * v_hd, cfg),
        "wo": dense_init(ks[5], h * v_hd, d, cfg),
        "kv_norm": rmsnorm_init(cfg.kv_lora_rank, cfg),
    }


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MLACache:
    """MLA latent cache: c_kv [B, S, lora] + k_rope [B, S, rope_dim]."""

    c_kv: jax.Array
    k_rope: jax.Array
    kpos: jax.Array


def mla_apply(
    p: Params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    mode: str,
    cache: Optional[MLACache] = None,
    cache_index: Optional[jax.Array] = None,
) -> tuple[jax.Array, Optional[MLACache]]:
    b, s, d = x.shape
    h = cfg.n_heads
    nd, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    xc = x.astype(COMPUTE_DTYPE)
    q = (xc @ p["wq"].astype(COMPUTE_DTYPE)).reshape(b, s, h, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    c_kv = rmsnorm(xc @ p["w_dkv"].astype(COMPUTE_DTYPE), p["kv_norm"], cfg.norm_eps)
    k_rope_new = rope(
        (xc @ p["w_krope"].astype(COMPUTE_DTYPE))[:, :, None, :], positions, cfg.rope_theta
    )[:, :, 0, :]
    c_kv = shard(c_kv, "batch", None, None)

    new_cache = None
    if mode == "decode":
        assert cache is not None and cache_index is not None
        if cfg.masked_cache_update:
            oh = (jnp.arange(cache.c_kv.shape[1], dtype=jnp.int32) == cache_index)
            ck = jnp.where(oh[None, :, None], c_kv.astype(cache.c_kv.dtype), cache.c_kv)
            cr = jnp.where(oh[None, :, None], k_rope_new.astype(cache.k_rope.dtype), cache.k_rope)
            cp = jnp.where(oh[None, :], positions.astype(jnp.int32), cache.kpos)
        else:
            ck = jax.lax.dynamic_update_slice(cache.c_kv, c_kv.astype(cache.c_kv.dtype), (0, cache_index, 0))
            cr = jax.lax.dynamic_update_slice(cache.k_rope, k_rope_new.astype(cache.k_rope.dtype), (0, cache_index, 0))
            cp = jax.lax.dynamic_update_slice(cache.kpos, positions.astype(jnp.int32), (0, cache_index))
        new_cache = MLACache(c_kv=ck, k_rope=cr, kpos=cp)
        # absorbed decode: score = q_nope @ W_kup^T @ c_kv^T + q_rope @ k_rope^T
        w_kup = p["w_kup"].astype(COMPUTE_DTYPE).reshape(-1, h, nd)  # [lora, H, nd]
        q_lat = jnp.einsum("bshn,lhn->bshl", q_nope, w_kup)  # [B,1,H,lora]
        s_lat = jnp.einsum("bshl,bkl->bhsk", q_lat, ck.astype(COMPUTE_DTYPE))
        s_rope = jnp.einsum("bshr,bkr->bhsk", q_rope, cr.astype(COMPUTE_DTYPE))
        scores = (s_lat + s_rope).astype(jnp.float32) * ((nd + rd) ** -0.5)
        kp = cp[:, None, None, :]  # [B, 1, 1, Sk]
        qp = positions[:, None, :, None]  # [B, 1, Sq, 1]
        ok = (kp >= 0) & (kp <= qp)
        scores = jnp.where(ok, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(COMPUTE_DTYPE)
        # out = probs @ c_kv @ W_vup  (stay in latent space, expand once)
        ctx_lat = jnp.einsum("bhsk,bkl->bshl", probs, ck.astype(COMPUTE_DTYPE))
        w_vup = p["w_vup"].astype(COMPUTE_DTYPE).reshape(-1, h, vd)
        ctx = jnp.einsum("bshl,lhv->bshv", ctx_lat, w_vup)
    else:
        k_nope = (c_kv @ p["w_kup"].astype(COMPUTE_DTYPE)).reshape(b, s, h, nd)
        vv = (c_kv @ p["w_vup"].astype(COMPUTE_DTYPE)).reshape(b, s, h, vd)
        k_rope_b = jnp.broadcast_to(k_rope_new[:, :, None, :], (b, s, h, rd))
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        kk = jnp.concatenate([k_nope, k_rope_b], axis=-1)
        qq = shard(qq, "batch", None, "heads", None)
        kk = shard(kk, "batch", None, "heads", None)
        ctx = _sdpa(qq, kk, vv, _causal_mask(s, s))
        if mode == "prefill":
            new_cache = MLACache(
                c_kv=c_kv.astype(COMPUTE_DTYPE),
                k_rope=k_rope_new.astype(COMPUTE_DTYPE),
                kpos=positions.astype(jnp.int32),
            )
    out = (ctx.reshape(b, s, h * vd) @ p["wo"].astype(COMPUTE_DTYPE)).astype(x.dtype)
    out = shard(out, "batch", None, None)
    return out, new_cache


# --------------------------------------------------------------------- MLPs
def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    kg, ku, ko = jax.random.split(key, 3)
    return {
        "wg": dense_init(kg, d, ff, cfg),
        "wu": dense_init(ku, d, ff, cfg),
        "wd": dense_init(ko, ff, d, cfg),
    }


def mlp_apply(p: Params, x: jax.Array) -> jax.Array:
    xc = x.astype(COMPUTE_DTYPE)
    h = jax.nn.silu(xc @ p["wg"].astype(COMPUTE_DTYPE)) * (xc @ p["wu"].astype(COMPUTE_DTYPE))
    names = ("batch",) + (None,) * (h.ndim - 2) + ("ffn",)
    h = shard(h, *names)
    out = (h @ p["wd"].astype(COMPUTE_DTYPE)).astype(x.dtype)
    return shard(out, *(("batch",) + (None,) * (out.ndim - 1)))


# --------------------------------------------------------------------- MoE
def moe_init(key, cfg: ModelConfig) -> Params:
    d, ff, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    std = 1.0 / (d**0.5)
    pd = _pdtype(cfg)
    p = {
        "router": dense_init(kr, d, e, cfg, scale=0.1),
        "wg": (jax.random.normal(kg, (e, d, ff), jnp.float32) * std).astype(pd),
        "wu": (jax.random.normal(ku, (e, d, ff), jnp.float32) * std).astype(pd),
        "wd": (jax.random.normal(kd, (e, ff, d), jnp.float32) * (ff**-0.5)).astype(pd),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks, cfg, d_ff=cfg.moe_d_ff * cfg.n_shared_experts)
    return p



def _moe_apply_ep(p: Params, x: jax.Array, cfg: ModelConfig, rules) -> tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE via replicated-dispatch shard_map (the §Perf MoE
    lever).  Activations are data-sharded and REPLICATED across the model
    axis, so each model shard already holds every token in its data row: it
    selects the tokens routed to ITS E/msz experts locally, runs its expert
    matmuls, scatters back into token space, and one [T_local, d] psum over
    'model' combines the rows.  Per-device fwd wire: T_local*d bf16 (~16 MB)
    instead of GSPMD's 3.2 GB partial-sum all-reduces of the [T*k, d]
    dispatch tensors (EXPERIMENTS.md §Perf-extended #6)."""
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    mesh = rules.mesh
    msz = mesh.shape.get("model", 1)
    e_local = e // msz
    xt = x.reshape(b * s, d).astype(COMPUTE_DTYPE)

    def body(xt_l, router, wg, wu, wd):
        # xt_l [T_l, d] (data shard, replicated over model); wg/wu/wd local
        # expert shards [E/msz, ...]; router replicated.
        t_l = xt_l.shape[0]
        midx = jax.lax.axis_index("model")
        logits = (xt_l @ router.astype(jnp.float32)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, k)  # [T_l, k]
        gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)
        # aux loss (identical on every model shard: inputs are replicated)
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(expert_idx, e).sum(axis=1), axis=0) / k
        aux = e * jnp.sum(me * ce)

        cap = max(8, int(cfg.capacity_factor * t_l * k / e))
        eidx = expert_idx.reshape(-1)
        local_e = eidx - midx * e_local  # in [0, e_local) iff mine
        mine = (local_e >= 0) & (local_e < e_local)
        safe_e = jnp.clip(local_e, 0, e_local - 1)
        onehot = jax.nn.one_hot(safe_e, e_local, dtype=jnp.int32) * mine[:, None].astype(jnp.int32)
        pos = (jnp.cumsum(onehot, axis=0) - 1) * onehot
        pos = pos.sum(-1)
        keep = mine & (pos < cap)
        gates = (gate_vals.reshape(-1) * keep).astype(COMPUTE_DTYPE)
        token_src = jnp.repeat(jnp.arange(t_l), k)
        safe_pos = jnp.where(keep, pos, cap - 1)
        buf = jnp.zeros((e_local, cap, d), COMPUTE_DTYPE)
        buf = buf.at[safe_e, safe_pos].add(jnp.where(keep[:, None], xt_l[token_src], 0))
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg.astype(COMPUTE_DTYPE)))
        h = h * jnp.einsum("ecd,edf->ecf", buf, wu.astype(COMPUTE_DTYPE))
        yb = jnp.einsum("ecf,efd->ecd", h, wd.astype(COMPUTE_DTYPE))
        contrib = yb[safe_e, safe_pos] * gates[:, None]
        y_part = jnp.zeros((t_l, d), COMPUTE_DTYPE).at[token_src].add(contrib)
        y = jax.lax.psum(y_part, "model")
        return y, aux

    wrapped = shard_map_compat(
        body,
        mesh=mesh,
        in_specs=(
            P(rules.resolve("batch")),
            P(),
            P("model", None, None),
            P("model", None, None),
            P("model", None, None),
        ),
        out_specs=(P(rules.resolve("batch")), P()),
        check_vma=False,
    )
    y, aux = wrapped(xt, p["router"], p["wg"], p["wu"], p["wd"])
    if "shared" in p:
        y = y + mlp_apply(p["shared"], xt).astype(COMPUTE_DTYPE)
    return y.reshape(b, s, d).astype(x.dtype), aux


def moe_apply(p: Params, x: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """Scatter-dispatch MoE (Switch/GShard): top-k routing with a capacity
    cap; overflowing tokens fall through on the residual path.  Returns
    (output, aux_loss)."""
    from ..parallel.sharding import current_rules

    rules = current_rules()
    if cfg.moe_ep and rules is not None and "model" in rules.mesh.axis_names \
            and cfg.n_experts % rules.mesh.shape.get("model", 1) == 0:
        return _moe_apply_ep(p, x, cfg, rules)
    b, s, d = x.shape
    n_tok = b * s
    e, k = cfg.n_experts, cfg.top_k
    cap = max(8, int(cfg.capacity_factor * n_tok * k / e))
    xt = x.reshape(n_tok, d).astype(COMPUTE_DTYPE)

    logits = (xt @ p["router"].astype(jnp.float32)).astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # [T, k]
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        (jax.nn.one_hot(expert_idx, e).sum(axis=1)).astype(jnp.float32), axis=0
    ) / k
    aux = e * jnp.sum(me * ce)

    # position of each (token, slot) within its expert, via one-hot cumsum
    onehot = jax.nn.one_hot(expert_idx.reshape(-1), e, dtype=jnp.int32)  # [T*k, E]
    pos_in_e = (jnp.cumsum(onehot, axis=0) - 1) * onehot  # [T*k, E]
    pos = pos_in_e.sum(axis=-1)  # [T*k]
    eidx = expert_idx.reshape(-1)
    keep = pos < cap
    gates = (gate_vals.reshape(-1) * keep).astype(COMPUTE_DTYPE)

    # scatter tokens into [E, cap, d]
    token_src = jnp.repeat(jnp.arange(n_tok), k)
    buf = jnp.zeros((e, cap, d), COMPUTE_DTYPE)
    safe_pos = jnp.where(keep, pos, cap - 1)
    buf = buf.at[eidx, safe_pos].add(jnp.where(keep[:, None], xt[token_src], 0))
    buf = shard(buf, "experts", None, None)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(COMPUTE_DTYPE)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["wu"].astype(COMPUTE_DTYPE))
    h = shard(h, "experts", None, None)
    yb = jnp.einsum("ecf,efd->ecd", h, p["wd"].astype(COMPUTE_DTYPE))

    # gather back: y[token] += gate * yb[expert, pos]
    contrib = yb[eidx, safe_pos] * gates[:, None]  # [T*k, d]
    y = jnp.zeros((n_tok, d), COMPUTE_DTYPE).at[token_src].add(contrib)
    if "shared" in p:
        y = y + mlp_apply(p["shared"], xt).astype(COMPUTE_DTYPE)
    return y.reshape(b, s, d).astype(x.dtype), aux


# ------------------------------------------------------------------- RG-LRU
def rglru_init(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 6)
    pd = _pdtype(cfg)
    return {
        "w_in_x": dense_init(ks[0], d, w, cfg),  # input branch
        "w_in_g": dense_init(ks[1], d, w, cfg),  # gate branch
        "conv": (jax.random.normal(ks[2], (cfg.conv_width, w), jnp.float32) * 0.1).astype(pd),
        "wa": dense_init(ks[3], w, w, cfg, scale=0.5),  # recurrence gate
        "wx": dense_init(ks[4], w, w, cfg, scale=0.5),  # input gate
        "lam": (jnp.ones((w,), jnp.float32) * 2.0).astype(pd),  # softplus^-1(a)
        "w_out": dense_init(ks[5], w, d, cfg),
    }


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RecState:
    h: jax.Array  # [B, W] recurrent state
    conv: jax.Array  # [B, conv_width-1, W] conv tail


def _rglru_core(u: jax.Array, p: Params, h0: jax.Array, c: float = 8.0):
    """u [B, S, W]; returns (y [B,S,W], h_final [B,W]).  Associative scan."""
    uc = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uc @ p["wa"].astype(jnp.float32))
    i = jax.nn.sigmoid(uc @ p["wx"].astype(jnp.float32))
    log_a0 = -jax.nn.softplus(-p["lam"].astype(jnp.float32))  # log sigmoid(lam)
    log_a = c * r * log_a0[None, None, :]
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-9)) * (i * uc)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, b1 * a2 + b2

    a_sc, b_sc = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = b_sc + a_sc * h0[:, None, :].astype(jnp.float32)
    return h.astype(u.dtype), h[:, -1, :]


def rglru_apply(
    p: Params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    mode: str,
    state: Optional[RecState] = None,
) -> tuple[jax.Array, Optional[RecState]]:
    b, s, d = x.shape
    w = cfg.lru_width or d
    xc = x.astype(COMPUTE_DTYPE)
    u = xc @ p["w_in_x"].astype(COMPUTE_DTYPE)  # [B, S, W]
    g = jax.nn.gelu(xc @ p["w_in_g"].astype(COMPUTE_DTYPE))
    u = shard(u, "batch", None, "ffn")
    # short depthwise causal conv
    cw = cfg.conv_width
    if mode == "decode":
        assert state is not None
        hist = jnp.concatenate([state.conv.astype(COMPUTE_DTYPE), u], axis=1)  # [B, cw, W]
        conv_out = jnp.einsum("bcw,cw->bw", hist, p["conv"].astype(COMPUTE_DTYPE))[:, None, :]
        new_conv = hist[:, 1:, :]
        y_core, h_fin = _rglru_core(conv_out, p, state.h)
        new_state = RecState(h=h_fin, conv=new_conv.astype(state.conv.dtype))
    else:
        pad = jnp.zeros((b, cw - 1, w), COMPUTE_DTYPE)
        up = jnp.concatenate([pad, u], axis=1)
        stacked = jnp.stack([up[:, i : i + s, :] for i in range(cw)], axis=2)  # [B,S,cw,W]
        conv_out = jnp.einsum("bscw,cw->bsw", stacked, p["conv"].astype(COMPUTE_DTYPE))
        h0 = jnp.zeros((b, w), jnp.float32) if state is None else state.h
        y_core, h_fin = _rglru_core(conv_out, p, h0)
        new_state = (
            RecState(h=h_fin, conv=up[:, -(cw - 1) :, :].astype(COMPUTE_DTYPE))
            if mode == "prefill"
            else None
        )
    y = (y_core * g) @ p["w_out"].astype(COMPUTE_DTYPE)
    y = shard(y, "batch", None, None)
    return y.astype(x.dtype), new_state


def _wkv_chunked(r, k, v, w, u, s0, chunk: int):
    """Chunked-parallel WKV6 (the §Perf hillclimb for rwkv6 train/prefill).

    The naive recurrence makes T sequential HBM round-trips of the [B,H,K,V]
    state.  Splitting T into chunks of C: within a chunk the decay factorizes
    per channel, exp(cl_{t-1} - cl_u) = exp(cl_{t-1}) * exp(-cl_u), so the
    intra-chunk contribution is an attention-like [C,C] product and the state
    advances once per chunk -> T/C sequential steps, ~C x less state traffic,
    ~2x more FLOPs (the C^2 term).  Log-space cumsums with a -60 clamp keep
    exp(-cl_u) finite (pairs spanning >60 nats of decay contribute < 1e-26).

    r,k,v,w: [B,S,H,K] (w = per-step decay in (0,1]); u: [H,K];
    s0: [B,H,K,V].  Returns (S_final, y [B,S,H*K]).
    """
    b, s, h, kd = r.shape
    nc = s // chunk
    clamp = -60.0
    f32 = jnp.float32

    def cshape(x):
        return x.astype(f32).reshape(b, nc, chunk, h, kd).transpose(1, 0, 2, 3, 4)

    rf, kf, vf = cshape(r), cshape(k), cshape(v)
    lw = jnp.log(jnp.clip(cshape(w), 1e-38, 1.0))
    cl = jnp.cumsum(lw, axis=2)  # inclusive within-chunk cumulative log-decay
    cl_before = cl - lw  # exclusive
    r_dec = rf * jnp.exp(jnp.maximum(cl_before, clamp))
    k_dec = kf * jnp.exp(jnp.maximum(-cl, clamp))
    tri = jnp.tril(jnp.ones((chunk, chunk), f32), k=-1)[None, None]  # strict t>u

    uu = u[None, None]  # [1,1,H,K]

    def chunk_step(S, inp):
        rd, kdec, vv_, cl_c, rraw, kraw = inp  # each [B,C,H,K]
        y_inter = jnp.einsum("bchk,bhkv->bchv", rd, S)
        att = jnp.einsum("bchk,bdhk->bhcd", rd, kdec)  # c = t, d = u
        att = att * tri
        y_intra = jnp.einsum("bhcd,bdhv->bchv", att, vv_)
        diag_gate = jnp.sum(rraw * uu * kraw, axis=-1)  # [B,C,H]
        y_diag = diag_gate[..., None] * vv_
        y = y_inter + y_intra + y_diag
        total = cl_c[:, -1]  # [B,H,K]
        k_fold = kraw * jnp.exp(jnp.maximum(total[:, None] - cl_c, clamp))
        S = S * jnp.exp(total)[..., None] + jnp.einsum("bchk,bchv->bhkv", k_fold, vv_)
        return S, y.astype(COMPUTE_DTYPE)

    s_fin, ys = jax.lax.scan(chunk_step, s0, (r_dec, k_dec, vf, cl, rf, kf))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h * kd)
    return s_fin, y


# -------------------------------------------------------------------- RWKV6
def rwkv_init(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    hk = cfg.rwkv_head_dim
    h = d // hk
    ks = jax.random.split(key, 10)
    pd = _pdtype(cfg)
    lora = max(32, d // 16)
    return {
        # token-shift mix coefficients (static lerp + data-dependent lora)
        "mix_rkvwg": (jax.random.uniform(ks[0], (5, d)) * 0.5 + 0.25).astype(pd),
        "w_lora_a": dense_init(ks[1], d, lora, cfg, scale=0.1),
        "w_lora_b": dense_init(ks[2], lora, d, cfg, scale=0.1),
        "decay_base": (jnp.full((h, hk), -6.0, jnp.float32)).astype(pd),
        "bonus_u": (jnp.zeros((h, hk), jnp.float32)).astype(pd),
        "wr": dense_init(ks[3], d, d, cfg),
        "wk": dense_init(ks[4], d, d, cfg),
        "wv": dense_init(ks[5], d, d, cfg),
        "wg": dense_init(ks[6], d, d, cfg),
        "wo": dense_init(ks[7], d, d, cfg),
        "ln_x": rmsnorm_init(d, cfg),
        # channel-mix
        "cm_mix": (jax.random.uniform(ks[8], (2, d)) * 0.5 + 0.25).astype(pd),
        "cm_k": dense_init(ks[9], d, cfg.d_ff, cfg),
        "cm_v": dense_init(jax.random.fold_in(key, 99), cfg.d_ff, d, cfg),
        "cm_r": dense_init(jax.random.fold_in(key, 98), d, d, cfg),
    }


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RwkvState:
    wkv: jax.Array  # [B, H, K, V]
    shift_t: jax.Array  # [B, D] last token (time-mix)
    shift_c: jax.Array  # [B, D] last token (channel-mix)


def rwkv_apply(
    p: Params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    mode: str,
    state: Optional[RwkvState] = None,
) -> tuple[jax.Array, Optional[RwkvState]]:
    """RWKV6 (Finch) block: time-mix with data-dependent decay + channel-mix.

    Sequential lax.scan over time (O(T) state recurrence).  Decode consumes
    one token with O(1) state — the long_500k cell.
    """
    b, s, d = x.shape
    hk = cfg.rwkv_head_dim
    h = d // hk
    xc = x.astype(COMPUTE_DTYPE)
    prev_t = (
        state.shift_t.astype(COMPUTE_DTYPE)[:, None, :]
        if state is not None
        else jnp.zeros((b, 1, d), COMPUTE_DTYPE)
    )
    x_prev = jnp.concatenate([prev_t, xc[:, :-1, :]], axis=1)

    mix = p["mix_rkvwg"].astype(COMPUTE_DTYPE)  # [5, D]
    def lerp(i):
        return xc + (x_prev - xc) * mix[i][None, None, :]

    def _heads(x):
        return shard(x.reshape(b, s, h, hk), "batch", None, "heads", None)

    r = _heads(lerp(0) @ p["wr"].astype(COMPUTE_DTYPE))
    kk = _heads(lerp(1) @ p["wk"].astype(COMPUTE_DTYPE))
    vv = _heads(lerp(2) @ p["wv"].astype(COMPUTE_DTYPE))
    g = jax.nn.silu(shard(lerp(4) @ p["wg"].astype(COMPUTE_DTYPE), "batch", None, "ffn"))
    # data-dependent decay (v6): w = exp(-exp(base + lora(x)))
    dd = (lerp(3) @ p["w_lora_a"].astype(COMPUTE_DTYPE)) @ p["w_lora_b"].astype(COMPUTE_DTYPE)
    decay = jnp.exp(
        -jnp.exp(jnp.clip(p["decay_base"].astype(jnp.float32).reshape(1, 1, d)
                          + dd.astype(jnp.float32), -20.0, 2.0))
    ).reshape(b, s, h, hk)
    u = p["bonus_u"].astype(jnp.float32)  # [H, K]

    s0 = (
        state.wkv.astype(jnp.float32)
        if state is not None
        else jnp.zeros((b, h, hk, hk), jnp.float32)
    )

    if cfg.rwkv_chunked and s >= 2 * cfg.rwkv_chunked and s % cfg.rwkv_chunked == 0:
        s_fin, y = _wkv_chunked(r, kk, vv, decay, u, s0, cfg.rwkv_chunked)
    else:
        def step(S, inp):
            r_t, k_t, v_t, w_t = inp  # [B,H,K] x3, [B,H,K]
            kv = jnp.einsum("bhk,bhv->bhkv", k_t.astype(jnp.float32), v_t.astype(jnp.float32))
            y = jnp.einsum("bhk,bhkv->bhv", r_t.astype(jnp.float32), S + u[None, :, :, None] * kv)
            S = S * w_t.astype(jnp.float32)[..., None] + kv
            return S, y.astype(COMPUTE_DTYPE)

        xs = (
            r.transpose(1, 0, 2, 3),
            kk.transpose(1, 0, 2, 3),
            vv.transpose(1, 0, 2, 3),
            decay.transpose(1, 0, 2, 3),
        )
        s_fin, ys = jax.lax.scan(step, s0, xs)
        y = ys.transpose(1, 0, 2, 3).reshape(b, s, d)
    y = y.reshape(b, s, d)
    y = rmsnorm(y, p["ln_x"], cfg.norm_eps) * g
    att = (y @ p["wo"].astype(COMPUTE_DTYPE)).astype(x.dtype)
    att = shard(att, "batch", None, None)

    # channel-mix (with its own shift)
    xa = xc + att.astype(COMPUTE_DTYPE)
    prev_c = (
        state.shift_c.astype(COMPUTE_DTYPE)[:, None, :]
        if state is not None
        else jnp.zeros((b, 1, d), COMPUTE_DTYPE)
    )
    xa_prev = jnp.concatenate([prev_c, xa[:, :-1, :]], axis=1)
    cmix = p["cm_mix"].astype(COMPUTE_DTYPE)
    xk = xa + (xa_prev - xa) * cmix[0][None, None, :]
    xr = xa + (xa_prev - xa) * cmix[1][None, None, :]
    kq = jnp.square(jax.nn.relu(xk @ p["cm_k"].astype(COMPUTE_DTYPE)))
    kq = shard(kq, "batch", None, "ffn")
    cm = jax.nn.sigmoid(xr @ p["cm_r"].astype(COMPUTE_DTYPE)) * (kq @ p["cm_v"].astype(COMPUTE_DTYPE))
    cm = shard(cm, "batch", None, None)
    out = (att.astype(COMPUTE_DTYPE) + cm).astype(x.dtype)

    new_state = None
    if mode in ("prefill", "decode"):
        new_state = RwkvState(
            wkv=s_fin,
            shift_t=xc[:, -1, :],
            shift_c=xa[:, -1, :],
        )
    return out, new_state
