"""Model zoo: one flexible trunk covering the 10 assigned architectures."""
from .model import Model, build_model  # noqa: F401
from . import layers, transformer  # noqa: F401
