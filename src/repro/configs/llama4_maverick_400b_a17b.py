"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128 experts top-1 — early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E family; unverified].

Llama-4 Maverick interleaves dense-FFN and MoE layers 1:1 (moe_every=2) with
one shared expert per MoE layer; with the assignment's d_ff=8192 this gives
~395B total / ~14B active — the 400B-A17B class.  40 q-heads are not
divisible by the 16-way model axis; expert-parallelism (128/16=8) carries
the model sharding and attention heads pad 40->48 under GSPMD (DESIGN.md §5,
revisited in the §Perf hillclimb).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    head_dim=128,
    n_experts=128,
    n_shared_experts=1,
    top_k=1,
    moe_d_ff=8192,
    moe_every=2,
    rope_theta=500_000.0,
    param_dtype="bfloat16",
    dcn_fsdp=True,  # ZeRO-3 across pods: 400B state cannot replicate per pod
    # §Perf: GSPMD-padded 40->48 head sharding beats replicated attention
    # 4-9x on the memory term (EXPERIMENTS.md §Perf-extended); production
    # default after validation.  Baseline tables used False.
    force_head_sharding=True,
    # §Perf: expert-parallel replicated-dispatch MoE (EXPERIMENTS.md
    # §Perf-extended #6) — production default; baseline tables used False.
    moe_ep=True,
)
