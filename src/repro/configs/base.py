"""Model / shape configuration system.

Every assigned architecture is a ``ModelConfig`` in its own module
(``src/repro/configs/<id>.py``) carrying the exact dims from the assignment.
``SHAPES`` defines the four assigned input-shape cells; per-arch skips
(e.g. long_500k on full-attention archs) are resolved by
``cells_for(arch)`` and documented in DESIGN.md §5.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES", "cells_for", "reduced_config"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None  # default d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    vocab_pad_multiple: int = 128

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim
    first_dense_layers: int = 0  # leading layers with a dense FFN
    moe_every: int = 1  # MoE FFN on every k-th layer (llama4 interleaves 1:1)
    capacity_factor: float = 1.25

    # --- MLA (deepseek) ---
    mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- hybrid (recurrentgemma): repeating block pattern ---
    # e.g. ("rec", "rec", "local") = RG-LRU : local-attn at 2:1
    block_pattern: tuple = ("attn",)
    local_window: int = 2048
    lru_width: int = 0  # RG-LRU state width (default d_model)
    conv_width: int = 4

    # --- rwkv ---
    rwkv_head_dim: int = 64

    # --- enc-dec ---
    n_enc_layers: int = 0

    # --- vlm ---
    cross_attn_every: int = 0  # one cross-attn layer per this many layers
    vision_tokens: int = 0  # stub frontend: precomputed patch embeddings

    # --- audio (enc-dec stub frontend) ---
    audio_frames_ratio: float = 0.5  # fraction of the shape's seq for encoder

    # --- precision / memory ---
    param_dtype: str = "float32"  # "bfloat16" for the very large archs
    remat: bool = True
    scan_blocks: bool = True

    # --- perf levers (hillclimb opt-ins; baselines keep defaults) ---
    rwkv_chunked: int = 0  # >0: chunked-parallel WKV with this chunk length
    masked_cache_update: bool = False  # decode: one-hot masked write, no DUS
    attn_softmax_bf16: bool = False  # keep attention probs in bf16 end-to-end
    remat_policy: str = "nothing"  # "nothing" (full recompute) | "dots"
    force_head_sharding: bool = False  # shard heads over "model" even if non-divisible (GSPMD pads)
    moe_ep: bool = False  # expert-parallel replicated-dispatch MoE (shard_map)

    # --- distribution ---
    dcn_fsdp: bool = False  # shard params across the pod axis too (ZeRO-3)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // max(self.n_heads, 1)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing (SSM state or local attention)."""
        return self.family in ("ssm", "hybrid")

    @property
    def block_group(self) -> tuple:
        """The smallest repeating layer pattern (the scan unit)."""
        if self.family == "hybrid":
            return self.block_pattern
        if self.family == "vlm" and self.cross_attn_every:
            return ("attn",) * (self.cross_attn_every - 1) + ("cross",)
        if self.family == "ssm":
            return ("rwkv",)
        if self.n_experts and self.moe_every > 1:
            return ("attn",) * self.moe_every
        return ("attn",)

    def layer_uses_moe(self, i: int) -> bool:
        if not self.n_experts or i < self.first_dense_layers:
            return False
        return (i % self.moe_every) == (self.moe_every - 1)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for rooflines."""
        d, ff, v = self.d_model, self.d_ff, self.padded_vocab
        hd = self.resolved_head_dim
        n_q = self.n_heads * hd
        n_kv = self.n_kv_heads * hd
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.mla:
            attn = d * (self.n_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim))
            attn += d * (self.kv_lora_rank + self.qk_rope_head_dim)
            attn += self.kv_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.v_head_dim)
            attn += self.n_heads * self.v_head_dim * d
        else:
            attn = d * n_q + 2 * d * n_kv + n_q * d
        dense_mlp = 3 * d * ff
        per_layer = []
        for i in range(self.n_layers):
            kind = self.block_group[i % len(self.block_group)]
            if kind == "rec":
                w = self.lru_width or d
                mix = 2 * d * w + w * d + w * self.conv_width + 2 * w * (w // 16)
            elif kind == "rwkv":
                mix = 4 * d * d + d * (d // 16) * 2  # r,k,v,o + lora mixers
            else:
                mix = attn
            if self.layer_uses_moe(i):
                mlp = 3 * d * self.moe_d_ff * (self.n_experts + self.n_shared_experts)
                mlp += d * self.n_experts
            else:
                mlp = dense_mlp
            per_layer.append(mix + mlp)
        total = emb + sum(per_layer)
        if self.n_enc_layers:
            total += self.n_enc_layers * (attn + dense_mlp)
        if self.family == "vlm":
            total += 0  # frontend is a stub; cross-attn counted via blocks
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        moe_layers = sum(1 for i in range(self.n_layers) if self.layer_uses_moe(i))
        all_experts = 3 * d * self.moe_d_ff * self.n_experts * moe_layers
        active = 3 * d * self.moe_d_ff * self.top_k * moe_layers
        return int(full - all_experts + active)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def cells_for(cfg: ModelConfig) -> list[str]:
    """The assigned shape cells this arch actually runs (skips documented in
    DESIGN.md §5: long_500k needs sub-quadratic attention)."""
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long_context:
        cells.append("long_500k")
    return cells


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    pattern = cfg.block_group
    n_layers = max(len(pattern), 2 if len(pattern) == 1 else len(pattern))
    if cfg.family == "vlm":
        n_layers = len(pattern)  # one full group (incl. the cross layer)
    changes = dict(
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) or 1,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        vocab_pad_multiple=64,
        param_dtype="float32",
        local_window=32,
        scan_blocks=cfg.scan_blocks,
    )
    if cfg.n_experts:
        # capacity_factor 8 -> dropless at smoke-test sizes, so incremental
        # decode is bitwise-consistent with the full forward (Switch-style
        # capacity drops are prefill/decode skew by construction).
        changes.update(n_experts=4, top_k=min(cfg.top_k, 2), moe_d_ff=64,
                       n_shared_experts=min(cfg.n_shared_experts, 1),
                       first_dense_layers=min(cfg.first_dense_layers, 1),
                       capacity_factor=8.0)
    if cfg.mla:
        changes.update(kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8,
                       v_head_dim=16)
    if cfg.family == "hybrid":
        changes.update(lru_width=64, n_layers=len(cfg.block_pattern))
    if cfg.n_enc_layers:
        changes.update(n_enc_layers=2)
    if cfg.family == "ssm":
        changes.update(rwkv_head_dim=16, n_layers=2)
    if cfg.vision_tokens:
        changes.update(vision_tokens=16)
    return dataclasses.replace(cfg, **changes)
