"""rwkv6-1.6b [ssm]: 24L d_model=2048 (attn-free) d_ff=7168 vocab=65536 —
Finch: data-dependent decay [arXiv:2404.05892; unverified].

Attention-free: WKV6 recurrence with token-shift; 32 heads of dim 64.
Runs the long_500k cell (O(1) state).  KV-cache compression is inapplicable
(DESIGN.md §5) — the WKV state is residual-quantized instead.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,          # wkv heads = d_model / rwkv_head_dim
    n_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    rwkv_head_dim=64,
    # production default after the §Perf hillclimb: chunked-parallel WKV
    # (227x lower HBM-traffic bound vs the sequential scan; EXPERIMENTS.md
    # §Perf H1).  Baseline tables were recorded with rwkv_chunked=0.
    rwkv_chunked=256,
)
