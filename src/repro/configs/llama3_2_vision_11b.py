"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — cross-attn image layers every 5th layer
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].

The vision frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings [B, vision_tokens, d_model]; every 5th decoder
layer cross-attends to them.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    head_dim=128,
    rope_theta=500_000.0,
    cross_attn_every=5,
    vision_tokens=1600,
    param_dtype="bfloat16",
)
