"""Assigned-architecture registry: --arch <id> resolves here."""
from .base import ModelConfig, ShapeSpec, SHAPES, cells_for, reduced_config  # noqa: F401

from . import (
    command_r_35b,
    deepseek_v2_lite_16b,
    llama3_2_vision_11b,
    llama3_8b,
    llama4_maverick_400b_a17b,
    qwen3_0_6b,
    recurrentgemma_9b,
    rwkv6_1_6b,
    seamless_m4t_medium,
    stablelm_12b,
)

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        stablelm_12b,
        qwen3_0_6b,
        llama3_8b,
        command_r_35b,
        seamless_m4t_medium,
        recurrentgemma_9b,
        llama4_maverick_400b_a17b,
        deepseek_v2_lite_16b,
        rwkv6_1_6b,
        llama3_2_vision_11b,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]
