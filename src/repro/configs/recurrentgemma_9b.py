"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (GQA kv=1) d_ff=12288
vocab=256000 — RG-LRU + local attention at 1:2 [arXiv:2402.19427; unverified].

Block pattern (rec, rec, local) repeats from layer 0; 38 = 12 full groups +
a 2-layer (rec, rec) tail, handled as unscanned tail blocks.  Runs the
long_500k cell: local attention window 2048 + O(1) recurrent state.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    block_pattern=("rec", "rec", "local"),
    local_window=2048,
    lru_width=4096,
    conv_width=4,
    param_dtype="bfloat16",
)
