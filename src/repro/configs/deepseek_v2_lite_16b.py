"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400 — MLA kv_lora=512, 2 shared + 64 routed top-6
[arXiv:2405.04434; hf].

d_ff=1408 is the per-expert hidden dim; the first layer uses a dense FFN
(10944) per the HF config.  MLA: qk_nope=128, qk_rope=64, v_head=128.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,          # dense first layer
    vocab_size=102400,
    mla=True,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    first_dense_layers=1,
    param_dtype="bfloat16",
    # §Perf: expert-parallel replicated-dispatch MoE (EXPERIMENTS.md
    # §Perf-extended #6) — production default; baseline tables used False.
    moe_ep=True,
)
