"""seamless-m4t-medium [audio]: 12L d_model=1024 16H (GQA kv=16) d_ff=4096
vocab=256206 — enc-dec, multimodal [arXiv:2308.11596; hf].

The audio frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings [B, S_enc, d_model] for the encoder; the
decoder consumes tokens.  vocab 256206 is padded to 256256 (multiple of
128) for 16-way sharding — DESIGN.md §5.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,        # decoder layers
    n_enc_layers=12,    # encoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    head_dim=64,
    audio_frames_ratio=0.5,
)
