"""Training substrate: optimizer, train steps, gradient compression,
checkpointing, fault tolerance."""
from .optimizer import AdamWConfig, adamw_init, adamw_update  # noqa: F401
from .train_step import (  # noqa: F401
    make_compressed_train_step,
    make_decode_step,
    make_ef_state,
    make_prefill_step,
    make_train_step,
)
from .grad_compress import GradCompressConfig, compression_wire_bytes  # noqa: F401
from .checkpoint import CheckpointManager, load_checkpoint, save_checkpoint  # noqa: F401
from .fault_tolerance import ShardScheduler, TrainingRunner  # noqa: F401
from .metrics import MetricsLogger  # noqa: F401
