"""Fault tolerance: deterministic resume, straggler-aware shard scheduling,
elastic restart.

At 1000+ nodes the assumptions are: (a) something is always broken, (b) a
restart must land exactly where it left off, (c) slow hosts must not stall
the input pipeline.  The pieces here:

* ``TrainingRunner`` — step loop with periodic (async) checkpoints and
  step-keyed deterministic data, so kill -9 at any point resumes bit-
  identically from the last checkpoint (tested in
  tests/test_fault_tolerance.py by crashing mid-run).
* ``ShardScheduler`` — over-decomposed data shards with heartbeat-based
  reassignment: a straggler's pending shards are re-dispatched to healthy
  workers (work stealing), bounding the tail latency of a step.
* Elastic restart — checkpoints carry no mesh assumptions; restore takes
  the NEW mesh's shardings (checkpoint.py), and the data pipeline is keyed
  by (step, shard_id), not by worker count.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax

from .checkpoint import CheckpointManager, latest_step

__all__ = ["TrainingRunner", "ShardScheduler", "WorkerState"]


@dataclasses.dataclass
class WorkerState:
    worker_id: int
    last_heartbeat: float
    assigned: list  # shard ids in flight


class ShardScheduler:
    """Over-decomposed shard assignment with straggler re-dispatch.

    ``factor`` shards per worker per step; a worker silent for longer than
    ``timeout`` gets its in-flight shards reassigned to the fastest healthy
    worker.  Completed shards are idempotent (keyed by id), so duplicated
    execution from re-dispatch is safe.
    """

    def __init__(self, n_workers: int, n_shards: int, timeout: float = 5.0,
                 now: Callable[[], float] = time.monotonic):
        self.timeout = timeout
        self.now = now
        self.workers = {
            w: WorkerState(w, self.now(), []) for w in range(n_workers)
        }
        self.pending = list(range(n_shards))
        self.done: set[int] = set()
        self.completed_by: dict[int, int] = {}

    def heartbeat(self, worker_id: int) -> None:
        self.workers[worker_id].last_heartbeat = self.now()

    def request_work(self, worker_id: int) -> Optional[int]:
        self.heartbeat(worker_id)
        self._reassign_stragglers()
        if not self.pending:
            return None
        shard = self.pending.pop(0)
        self.workers[worker_id].assigned.append(shard)
        return shard

    def complete(self, worker_id: int, shard: int) -> None:
        self.heartbeat(worker_id)
        if shard in self.done:
            return  # idempotent: re-dispatched shard finished twice
        self.done.add(shard)
        self.completed_by[shard] = worker_id
        for w in self.workers.values():
            if shard in w.assigned:
                w.assigned.remove(shard)

    def _reassign_stragglers(self) -> None:
        t = self.now()
        for w in self.workers.values():
            if t - w.last_heartbeat > self.timeout and w.assigned:
                # return the straggler's in-flight shards to the queue front
                for s in w.assigned:
                    if s not in self.done and s not in self.pending:
                        self.pending.insert(0, s)
                w.assigned.clear()

    @property
    def finished(self) -> bool:
        return len(self.done) >= len(self.completed_by) and not self.pending and all(
            not w.assigned for w in self.workers.values()
        )


class TrainingRunner:
    """Checkpointed step loop with deterministic resume.

    step_fn(state, batch) -> (state, metrics);  data_fn(step) -> batch must
    be a pure function of the step index (repro.data.pipeline is).
    """

    def __init__(
        self,
        step_fn: Callable,
        data_fn: Callable[[int], Any],
        init_state: Any,
        ckpt_dir: str,
        ckpt_every: int = 10,
        keep_n: int = 3,
        codec: str | None = None,  # None = best available (zstd or none)
        fail_at: Optional[int] = None,  # test hook: simulated crash
    ):
        self.step_fn = step_fn
        self.data_fn = data_fn
        self.manager = CheckpointManager(ckpt_dir, keep_n=keep_n, codec=codec)
        self.ckpt_every = ckpt_every
        self.fail_at = fail_at
        self.state = init_state
        self.start_step = 0
        if latest_step(self.manager.dir) is not None:
            self.state, self.start_step = self.manager.restore(init_state)
            self.start_step += 1

    def run(self, n_steps: int) -> list[dict]:
        history = []
        for step in range(self.start_step, n_steps):
            if self.fail_at is not None and step == self.fail_at:
                self.manager.wait()
                raise RuntimeError(f"simulated node failure at step {step}")
            batch = self.data_fn(step)
            self.state, metrics = self.step_fn(self.state, batch)
            history.append({"step": step, **jax.tree.map(float, metrics)})
            if step % self.ckpt_every == 0:
                self.manager.save(step, self.state, asynchronous=True)
        self.manager.wait()
        self.manager.save(n_steps - 1, self.state, asynchronous=False)
        return history
