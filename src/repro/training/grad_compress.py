"""SHRINK gradient compression for the cross-pod (DCN) all-reduce.

The paper's decomposition applied to the slowest wire in a multi-pod run:

* base      = per-block linear fit of the flattened gradient (bf16
              theta/slope per 256-block — the "semantics"),
* residuals = int8-quantized against a pod-shared step (psum-max), with
              error feedback (EF-SGD) carried in the optimizer state so the
              quantization bias does not accumulate.

Wire pattern per pod (inside shard_map, manual over the 'pod' axis):
    step   = pmax over pods of local max|r| / qmax        (tiny f32 [M,1])
    q      = residual_quant(g + ef, base, step)           (int8 [M,256])
    all_gather(q, 'pod') + local sum -> dequant -> grads  (int8 on the wire)

Collective bytes vs uncompressed f32 ring all-reduce: 8 bytes/elem -> ~0.56
bytes/elem (int8 gather at n_pods=2 + bases), a ~14x reduction of the
cross-pod term — measured in EXPERIMENTS.md §Perf from the compiled HLO.

Inapplicable combination (DESIGN.md §6): archs with dcn_fsdp=True (llama4)
reduce-scatter across pods instead of all-reducing; compressing that path is
future work, so llama4 uses the uncompressed path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..core.jaxshrink import TensorCodecConfig, linear_base_fit
from ..parallel.sharding import shard_map_compat

__all__ = ["GradCompressConfig", "compressed_psum_tree", "compression_wire_bytes"]


@dataclasses.dataclass(frozen=True)
class GradCompressConfig:
    block: int = 256
    bits: int = 8
    min_leaf_size: int = 65_536  # smaller leaves ride the wire uncompressed
    axis: str = "pod"

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1


def _compress_leaf(g: jax.Array, ef: jax.Array, cfg: GradCompressConfig):
    """One leaf: returns (summed_grad_f32, new_ef).  Runs inside shard_map
    (manual over cfg.axis)."""
    axis = cfg.axis
    n = jax.lax.psum(1, axis)
    flat = g.astype(jnp.float32).reshape(-1) + ef.reshape(-1)
    size = flat.shape[0]
    pad = (-size) % cfg.block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    xb = flat.reshape(-1, cfg.block)

    theta, slope = linear_base_fit(xb)
    theta = theta.astype(jnp.bfloat16).astype(jnp.float32)
    slope = slope.astype(jnp.bfloat16).astype(jnp.float32)
    t = jnp.arange(cfg.block, dtype=jnp.float32)[None, :]
    r = xb - (theta + slope * t)
    # pod-shared quantization step so the summed ints dequantize coherently
    step = jax.lax.pmax(jnp.max(jnp.abs(r), axis=1, keepdims=True), axis) / cfg.qmax
    step = jnp.maximum(step, 1e-12)
    q = jnp.clip(jnp.round(r / step), -cfg.qmax, cfg.qmax).astype(jnp.int8)
    local_deq = theta + slope * t + q.astype(jnp.float32) * step
    new_ef = (xb - local_deq).reshape(-1)[: size].reshape(g.shape)

    if cfg.bits <= 4:
        # nibble-pack: two 4-bit residuals per wire byte (b=4 hillclimb)
        hiq = (q[:, ::2].astype(jnp.int32) & 0xF) << 4
        loq = q[:, 1::2].astype(jnp.int32) & 0xF
        packed = (hiq | loq).astype(jnp.int8)
        p_all = jax.lax.all_gather(packed, axis)  # [n, M, B/2] int8
        hi_u = p_all.astype(jnp.int32) >> 4
        lo_u = p_all.astype(jnp.int32) & 0xF
        # sign-extend 4-bit two's complement
        sx = lambda x: jnp.where(x > 7, x - 16, x)
        q_all = jnp.stack([sx(hi_u & 0xF), sx(lo_u)], axis=-1).reshape(
            p_all.shape[0], p_all.shape[1], -1
        )
    else:
        # the wire: int8 residuals + bf16 bases, gathered then reduced locally
        q_all = jax.lax.all_gather(q, axis)  # [n, M, B] int8
    th_all = jax.lax.all_gather(theta.astype(jnp.bfloat16), axis)
    sl_all = jax.lax.all_gather(slope.astype(jnp.bfloat16), axis)
    q_sum = q_all.astype(jnp.float32).sum(axis=0)
    base_sum = (
        th_all.astype(jnp.float32).sum(axis=0)
        + sl_all.astype(jnp.float32).sum(axis=0) * t
    )
    g_sum = (base_sum + q_sum * step).reshape(-1)[: size].reshape(g.shape)
    return g_sum / n, new_ef


def compressed_psum_tree(grads, ef_tree, cfg: GradCompressConfig):
    """Tree-wise compressed mean over the pod axis.  Small leaves use a
    plain psum (negligible wire share).  Returns (mean_grads, new_ef)."""
    axis = cfg.axis
    n = jax.lax.psum(1, axis)

    def one(g, ef):
        if g.size < cfg.min_leaf_size:
            return jax.lax.psum(g.astype(jnp.float32), axis) / n, ef
        return _compress_leaf(g, ef, cfg)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef_tree)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        jax.tree.unflatten(treedef, [o[0] for o in outs]),
        jax.tree.unflatten(treedef, [o[1] for o in outs]),
    )


def make_crosspod_exchange(mesh, comp_cfg: Optional[GradCompressConfig], param_spec_tree,
                           flat: bool = False):
    """Standalone cross-pod gradient exchange stage (the DCN step of a
    multi-slice run).  Input: grads tree with a leading pod dim (the
    dry-run emulation of per-slice gradient buffers); output: pod-reduced
    mean grads + new error-feedback tree.

    FULLY MANUAL shard_map (all mesh axes): each device compresses and
    exchanges exactly its own parameter shard — the physical per-device DCN
    buffer — so no GSPMD resharding can sneak in around the flatten/
    blockify.  (Also sidesteps the partitioner crash on sharded-table
    gathers inside partial-auto regions; the model never enters this stage.)

    comp_cfg=None -> plain f32 psum over 'pod' (the baseline wire).
    """
    from jax.sharding import PartitionSpec as P

    axis = (comp_cfg.axis if comp_cfg else "pod")

    def exchange(grads_stacked, ef):
        local = jax.tree.map(lambda x: x[0], grads_stacked)
        if comp_cfg is None:
            n = jax.lax.psum(1, axis)
            if flat:
                leaves, treedef = jax.tree.flatten(local)
                sizes = [l.size for l in leaves]
                shapes = [l.shape for l in leaves]
                flat_g = jnp.concatenate([l.astype(jnp.float32).reshape(-1) for l in leaves])
                s = jax.lax.psum(flat_g, axis) / n
                outs, off = [], 0
                for sz, shp in zip(sizes, shapes):
                    outs.append(s[off : off + sz].reshape(shp))
                    off += sz
                return jax.tree.unflatten(treedef, outs), ef
            out = jax.tree.map(lambda g: jax.lax.psum(g.astype(jnp.float32), axis) / n, local)
            return out, ef
        if flat:
            # bucket ALL leaves into one flat exchange: 4 collectives per
            # step instead of ~4 per leaf (fewer rendezvous, less per-leaf
            # base overhead) — the bucketing trick of production DP stacks
            leaves, treedef = jax.tree.flatten(local)
            sizes = [l.size for l in leaves]
            shapes = [l.shape for l in leaves]
            flat_g = jnp.concatenate([l.astype(jnp.float32).reshape(-1) for l in leaves])
            ef_leaves = jax.tree.leaves(ef)
            flat_e = jnp.concatenate([l.reshape(-1) for l in ef_leaves])
            g_sum, new_e = _compress_leaf(flat_g, flat_e, comp_cfg)
            outs, es, off = [], [], 0
            for sz, shp in zip(sizes, shapes):
                outs.append(g_sum[off : off + sz].reshape(shp))
                es.append(new_e[off : off + sz].reshape(shp))
                off += sz
            return jax.tree.unflatten(treedef, outs), jax.tree.unflatten(treedef, es)
        return compressed_psum_tree(local, ef, comp_cfg)

    def wrapped(grads_stacked, ef):
        in1 = jax.tree.map(lambda s: P("pod", *s), param_spec_tree,
                           is_leaf=lambda x: isinstance(x, P))
        in2 = param_spec_tree
        return shard_map_compat(
            exchange,
            mesh=mesh,
            in_specs=(in1, in2),
            out_specs=(param_spec_tree, param_spec_tree),
            check_vma=False,
        )(grads_stacked, ef)

    return wrapped


def compression_wire_bytes(params, cfg: GradCompressConfig) -> tuple[int, int]:
    """(compressed, uncompressed-f32) cross-pod bytes per step, analytic."""
    comp = 0
    raw = 0
    for leaf in jax.tree.leaves(params):
        raw += leaf.size * 4
        if leaf.size < cfg.min_leaf_size:
            comp += leaf.size * 4
        else:
            m = -(-leaf.size // cfg.block)
            comp += leaf.size * 1 + m * (2 + 2)  # int8 + bf16 theta/slope
    return comp, raw
