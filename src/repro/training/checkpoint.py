"""Checkpointing with SHRINK compression + resharding restore.

Layout:
    <dir>/step_<N>/manifest.json        tree structure, shapes, dtypes, codec
    <dir>/step_<N>/leaf_<i>.bin         one blob per leaf
    <dir>/LATEST                        atomic pointer (written last)

Codecs per leaf:
    none            raw little-endian bytes
    zstd            zstd-19 of raw bytes (bit-exact)
    shrink:<frac>   SHRINK lossy with eps = frac * leaf value range —
                    L-infinity-bounded weights; a single checkpoint can be
                    restored bit-exact for training (pair with zstd residual
                    of the quantization error) or cheap/lossy for serving.
                    This is the paper's multiresolution property applied to
                    model state.

Restore takes target shardings, so a checkpoint saved on one mesh loads
onto another (elastic restart).  Saving snapshots to host first and writes
via a background thread (async).
"""
from __future__ import annotations

import dataclasses
import json
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

try:
    import zstandard as _zstd
except Exception:  # pragma: no cover
    _zstd = None

from ..core.shrink import ShrinkCodec, cs_from_bytes, cs_to_bytes

__all__ = [
    "save_checkpoint",
    "load_checkpoint",
    "latest_step",
    "CheckpointManager",
    "default_codec",
]


def default_codec() -> str:
    """Best exact leaf codec available: ``zstd`` when the optional
    ``zstandard`` extra is installed, raw bytes otherwise — checkpointing
    must never require the extra."""
    return "zstd" if _zstd is not None else "none"


def _encode_leaf(arr: np.ndarray, codec: str) -> tuple[bytes, dict]:
    meta = {"shape": list(arr.shape), "dtype": str(arr.dtype), "codec": codec}
    if arr.dtype == np.dtype("bfloat16"):
        raw = arr.view(np.uint16).tobytes()
        meta["bf16"] = True
    else:
        raw = arr.tobytes()
    if codec == "none":
        return raw, meta
    if codec == "zstd":
        if _zstd is None:
            raise RuntimeError("zstandard unavailable")
        return _zstd.ZstdCompressor(level=10).compress(raw), meta
    if codec.startswith("shrink:"):
        frac = float(codec.split(":", 1)[1])
        flat = np.asarray(arr, dtype=np.float64).reshape(-1)
        rng = float(flat.max() - flat.min()) if flat.size else 0.0
        if flat.size < 1024 or rng <= 0:
            meta["codec"] = "zstd"
            return _encode_leaf(arr, "zstd")[0], meta
        eps = max(frac * rng, 1e-12)
        # zstd when installed (historical choice), rans otherwise — not
        # "best", which would add an O(n) pure-python rc pass per leaf
        sc = ShrinkCodec.from_fraction(
            flat, frac=0.05, backend="zstd" if _zstd is not None else "rans"
        )
        cs = sc.compress(flat, eps_targets=[eps])
        meta["eps"] = eps
        return cs_to_bytes(cs), meta
    raise ValueError(f"unknown codec {codec!r}")


def _decode_leaf(blob: bytes, meta: dict) -> np.ndarray:
    codec = meta["codec"]
    shape = tuple(meta["shape"])
    if codec == "none" or codec == "zstd":
        raw = blob if codec == "none" else _zstd.ZstdDecompressor().decompress(blob)
        if meta.get("bf16"):
            import jax.numpy as jnp

            arr = np.frombuffer(raw, dtype=np.uint16).reshape(shape)
            return arr.view(jnp.bfloat16.dtype)
        return np.frombuffer(raw, dtype=np.dtype(meta["dtype"])).reshape(shape)
    if codec.startswith("shrink:") or "eps" in meta:
        cs = cs_from_bytes(blob)
        sc = ShrinkCodec.from_fraction(np.zeros(2), frac=0.05)
        vals = sc.decompress_at(cs, meta["eps"])
        return vals.astype(np.dtype(meta["dtype"]) if not meta.get("bf16") else np.float32).reshape(shape)
    raise ValueError(f"bad leaf meta {meta}")


def save_checkpoint(
    directory: str | Path,
    step: int,
    state: Any,
    codec: str | None = None,
    asynchronous: bool = False,
) -> threading.Thread | None:
    """Snapshot `state` (any pytree) at `step`.  Returns the writer thread
    when asynchronous.  ``codec=None`` picks :func:`default_codec`."""
    directory = Path(directory)
    if codec is None:
        codec = default_codec()
    snap = [np.asarray(jax.device_get(x)) for x in jax.tree.leaves(state)]
    treedef = jax.tree.structure(state)

    def write() -> None:
        tmp = directory / f".tmp_step_{step}"
        final = directory / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        metas = []
        for i, arr in enumerate(snap):
            blob, meta = _encode_leaf(arr, codec)
            (tmp / f"leaf_{i}.bin").write_bytes(blob)
            metas.append(meta)
        (tmp / "manifest.json").write_text(
            json.dumps({"step": step, "treedef": str(treedef), "leaves": metas})
        )
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        (directory / "LATEST").write_text(str(step))

    if asynchronous:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def latest_step(directory: str | Path) -> Optional[int]:
    p = Path(directory) / "LATEST"
    if not p.exists():
        return None
    return int(p.read_text().strip())


def load_checkpoint(
    directory: str | Path,
    like: Any,
    step: Optional[int] = None,
    shardings: Any = None,
) -> tuple[Any, int]:
    """Restore into the structure of `like`.  `shardings` (optional pytree of
    NamedSharding) places each leaf — pass the NEW mesh's shardings for an
    elastic restart on different topology."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    d = directory / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves_meta = manifest["leaves"]
    treedef = jax.tree.structure(like)
    n = treedef.num_leaves
    assert n == len(leaves_meta), f"leaf count mismatch: {n} vs {len(leaves_meta)}"
    shard_leaves = jax.tree.leaves(shardings) if shardings is not None else [None] * n
    out = []
    for i, meta in enumerate(leaves_meta):
        arr = _decode_leaf((d / f"leaf_{i}.bin").read_bytes(), meta)
        if shard_leaves[i] is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out), step


class CheckpointManager:
    """keep_n rotation + async handles + resume helper."""

    def __init__(self, directory: str | Path, keep_n: int = 3, codec: str | None = None):
        self.dir = Path(directory)
        self.keep_n = keep_n
        self.codec = codec if codec is not None else default_codec()
        self._pending: list[threading.Thread] = []

    def save(self, step: int, state: Any, asynchronous: bool = True) -> None:
        t = save_checkpoint(self.dir, step, state, codec=self.codec, asynchronous=asynchronous)
        if t:
            self._pending.append(t)
        self._gc()

    def wait(self) -> None:
        for t in self._pending:
            t.join()
        self._pending.clear()
        self._gc()  # async writes may have landed after the save-time GC

    def restore(self, like: Any, shardings: Any = None):
        return load_checkpoint(self.dir, like, shardings=shardings)

    def _gc(self) -> None:
        steps = sorted(
            int(p.name.split("_")[1]) for p in self.dir.glob("step_*") if p.is_dir()
        )
        for s in steps[: -self.keep_n]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)
