"""jit-ready train / serve steps with sharding.

Two train-step flavours:

* ``make_train_step``            — global-batch loss; GSPMD inserts the
  gradient all-reduce over ("pod","data").  The baseline.
* ``make_compressed_train_step`` — shard_map manual over "pod": each pod
  computes local gradients, the cross-pod reduction rides the SHRINK
  compressed collective (grad_compress.py), with error feedback carried in
  the step state.  Only for pod-replicated params (dcn_fsdp=False).

Both return functions ready for jax.jit with in/out shardings derived from
partition.py.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeSpec
from ..models import Model
from ..parallel.partition import param_specs, fsdp_axes_for
from ..parallel.sharding import AxisRules, axis_rules, make_rules, shard_map_compat
from .optimizer import AdamWConfig, adamw_init, adamw_update, clip_by_global_norm
from .grad_compress import GradCompressConfig, compressed_psum_tree

__all__ = [
    "make_train_step",
    "make_compressed_train_step",
    "make_prefill_step",
    "make_decode_step",
    "batch_specs",
    "cache_specs",
    "train_state_specs",
]


# ---------------------------------------------------------------- spec maps
def batch_specs(batch_tree, mesh: Mesh, batch_axes) -> Any:
    """Shard dim0 of every batch leaf over the batch axes (if divisible)."""
    size = 1
    for a in (batch_axes if isinstance(batch_axes, tuple) else (batch_axes,)):
        size *= mesh.shape[a]

    def spec(leaf):
        b = leaf.shape[0] if leaf.ndim else 0
        if leaf.ndim == 0 or b % size:
            return P()
        return P(batch_axes, *([None] * (leaf.ndim - 1)))

    return jax.tree.map(spec, batch_tree)


def cache_specs(cache_tree, mesh: Mesh, batch_axes, seq_axis: str = "model") -> Any:
    """KV caches: batch over the data axes, cache SEQUENCE over the model
    axis (flash-decoding layout: decode scores/AV reduce over the sharded
    sequence with tiny per-step collectives, and per-device cache memory is
    S/16 — always divisible, unlike kv-head counts).  Recurrent states shard
    their width/head dims over model."""
    bsz = 1
    for a in (batch_axes if isinstance(batch_axes, tuple) else (batch_axes,)):
        bsz *= mesh.shape[a]
    msz = mesh.shape.get("model", 1)
    # canonicalize ("data",) -> "data": new jax normalizes singleton spec
    # entries itself, 0.4.x keeps the tuple and the specs stop comparing equal
    if isinstance(batch_axes, tuple) and len(batch_axes) == 1:
        batch_axes = batch_axes[0]

    def spec(path, leaf):
        name = None
        for entry in reversed(path):
            k = getattr(entry, "name", None) or getattr(entry, "key", None)
            if isinstance(k, str):
                name = k
                break
        stacked = 0
        base = []
        batch = batch_axes if (leaf.ndim and leaf.shape[0] % bsz == 0) else None
        b2 = (
            batch_axes
            if (leaf.ndim > 1 and leaf.shape[1] % bsz == 0)
            else None
        )

        def seq_ok(dim_size):
            return seq_axis if dim_size % msz == 0 else None

        if name in ("k", "v") and leaf.ndim == 4:  # [B, S, KV, D]
            return P(batch, seq_ok(leaf.shape[1]), None, None)
        if name in ("k", "v") and leaf.ndim == 5:  # stacked [G, B, S, KV, D]
            return P(None, b2, seq_ok(leaf.shape[2]), None, None)
        if name == "kpos" and leaf.ndim == 2:
            return P(batch, seq_ok(leaf.shape[1]))
        if name == "kpos" and leaf.ndim == 3:
            return P(None, b2, seq_ok(leaf.shape[2]))
        if name in ("c_kv", "k_rope") and leaf.ndim == 3:  # [B, S, R]
            return P(batch, seq_ok(leaf.shape[1]), None)
        if name in ("c_kv", "k_rope") and leaf.ndim == 4:
            return P(None, b2, seq_ok(leaf.shape[2]), None)
        if name == "wkv" and leaf.ndim == 4:  # [B, H, K, V]
            return P(batch, "model" if leaf.shape[1] % msz == 0 else None, None, None)
        if name == "wkv" and leaf.ndim == 5:
            return P(None, b2, "model" if leaf.shape[2] % msz == 0 else None, None, None)
        if name == "h" and leaf.ndim == 2:
            return P(batch, "model" if leaf.shape[1] % msz == 0 else None)
        if name == "h" and leaf.ndim == 3:
            return P(None, b2, "model" if leaf.shape[2] % msz == 0 else None)
        if name == "conv" and leaf.ndim == 3:
            return P(batch, None, "model" if leaf.shape[2] % msz == 0 else None)
        if name == "conv" and leaf.ndim == 4:
            return P(None, b2, None, "model" if leaf.shape[3] % msz == 0 else None)
        if name in ("shift_t", "shift_c") and leaf.ndim == 2:
            return P(batch, None)
        if name in ("shift_t", "shift_c") and leaf.ndim == 3:
            return P(None, b2, None)
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(spec, cache_tree)


def train_state_specs(params_shapes, cfg: ModelConfig, mesh: Mesh):
    ps = param_specs(params_shapes, cfg, mesh)
    return {
        "m": ps,
        "v": ps,
        "step": P(),
    }


# ------------------------------------------------------------- train steps
def make_train_step(model: Model, mesh: Mesh, opt_cfg: AdamWConfig = AdamWConfig()):
    cfg = model.cfg
    rules = make_rules(mesh, cfg)

    def step(params, opt_state, batch):
        def loss_fn(p):
            with axis_rules(rules):
                return model.loss(p, batch)

        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        params, opt_state = adamw_update(opt_cfg, params, grads, opt_state)
        metrics = {"loss": loss, "grad_norm": gnorm, **parts}
        return params, opt_state, metrics

    return step


def make_compressed_train_step(
    model: Model,
    mesh: Mesh,
    opt_cfg: AdamWConfig = AdamWConfig(),
    comp_cfg: GradCompressConfig = GradCompressConfig(),
):
    """Cross-pod SHRINK-compressed data parallelism (DESIGN.md §6)."""
    cfg = model.cfg
    assert "pod" in mesh.axis_names, "compressed step needs a pod axis"
    assert not cfg.dcn_fsdp, "compressed collective targets pod-replicated params"
    # inside shard_map the pod axis is manual: batch rides ("data",) only
    rules = make_rules(mesh, cfg, overrides={"batch": "data"})

    def pod_step(params, opt_state, ef, batch):
        def loss_fn(p):
            with axis_rules(rules):
                return model.loss(p, batch)  # mean over the POD-LOCAL batch

        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        grads, ef = compressed_psum_tree(grads, ef, comp_cfg)
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        params, opt_state = adamw_update(opt_cfg, params, grads, opt_state)
        n = jax.lax.psum(1, comp_cfg.axis)
        # every metric must be pod-replicated to satisfy out_specs P()
        metrics = {"loss": loss, "grad_norm": gnorm, **parts}
        metrics = jax.tree.map(lambda v: jax.lax.psum(v, comp_cfg.axis) / n, metrics)
        return params, opt_state, ef, metrics

    def batch_in_specs(batch):
        return jax.tree.map(lambda _: P("pod"), batch)

    def step(params, opt_state, ef, batch):
        fn = shard_map_compat(
            pod_step,
            mesh=mesh,
            in_specs=(
                jax.tree.map(lambda _: P(), params),
                jax.tree.map(lambda _: P(), opt_state),
                jax.tree.map(lambda _: P(), ef),
                batch_in_specs(batch),
            ),
            out_specs=(
                jax.tree.map(lambda _: P(), params),
                jax.tree.map(lambda _: P(), opt_state),
                jax.tree.map(lambda _: P(), ef),
                P(),
            ),
            axis_names={"pod"},
            check_vma=False,
        )
        return fn(params, opt_state, ef, batch)

    return step


def make_ef_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


# ------------------------------------------------------------- serve steps
def make_prefill_step(model: Model, mesh: Mesh):
    rules = make_rules(mesh, model.cfg)

    def step(params, batch):
        with axis_rules(rules):
            return model.prefill(params, batch)

    return step


def make_decode_step(model: Model, mesh: Mesh):
    # seq_model: decode attention runs against sequence-sharded caches
    rules = make_rules(mesh, model.cfg, overrides={"seq_model": "model"})

    def step(params, tokens, caches, cache_index):
        with axis_rules(rules):
            return model.decode_step(params, tokens, caches, cache_index)

    return step
