"""Sharded AdamW, handwritten (no optax dependency).

Optimizer state mirrors the parameter tree: m/v in float32 with the same
PartitionSpecs as the corresponding parameter (fully sharded states — the
ZeRO-1/2/3 split follows the fsdp axes chosen in partition.py).  Updates are
computed in float32 and cast back to the parameter dtype (bf16 params keep
f32 first/second moments: standard mixed-precision training).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "clip_by_global_norm", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    # cosine decay horizon; 0 = constant after warmup
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


def adamw_init(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (s + 1) / max(cfg.warmup_steps, 1))
    if cfg.decay_steps > 0:
        t = jnp.clip((s - cfg.warmup_steps) / max(cfg.decay_steps, 1), 0.0, 1.0)
        cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    else:
        cos = 1.0
    return cfg.lr * warm * cos


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(cfg: AdamWConfig, params, grads, state) -> tuple[Any, dict]:
    step = state["step"] + 1
    lr = _schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * gf * gf
        mhat = m / bc1
        vhat = v / bc2
        pf = p.astype(jnp.float32)
        new_p = pf - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * pf)
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        "m": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "v": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state
