"""SHRINK-compressed metrics/telemetry logger.

Training at 1000+ nodes emits long scalar series (loss, grad-norm, per-layer
stats) — exactly the data class the paper targets.  MetricsLogger buffers
scalars per key and flushes SHRINK-compressed chunks (lossless at a fixed
decimal precision) through the ShardStore, so a month of step metrics costs
megabytes and supports resolution-tiered reads (coarse eps for dashboards,
lossless for analysis).
"""
from __future__ import annotations

from collections import defaultdict
from pathlib import Path

import numpy as np

from ..data.pipeline import ShardStore

__all__ = ["MetricsLogger"]


class MetricsLogger:
    def __init__(self, directory: str | Path, decimals: int = 6,
                 dashboard_eps: float = 1e-2, chunk: int = 4096):
        self.store = ShardStore(directory, chunk=chunk)
        self.decimals = decimals
        self.dashboard_eps = dashboard_eps
        self.buffers: dict[str, list[float]] = defaultdict(list)
        self.flushed: dict[str, int] = defaultdict(int)

    def log(self, step: int, metrics: dict) -> None:
        for k, v in metrics.items():
            self.buffers[k].append(float(v))

    def flush(self) -> dict:
        """Compress every buffered series; returns {key: stored_bytes}."""
        out = {}
        for k, vals in self.buffers.items():
            if not vals:
                continue
            v = np.round(np.asarray(vals, dtype=np.float64), self.decimals)
            rng = float(v.max() - v.min()) or 1.0
            meta = self.store.put(
                f"{k}_{self.flushed[k]}", v,
                eps_list=[self.dashboard_eps * rng, 0.0],
                decimals=self.decimals,
            )
            out[k] = meta["bytes"]
            self.flushed[k] += 1
            self.buffers[k] = []
        return out

    def read(self, key: str, lossless: bool = True) -> np.ndarray:
        """Concatenate all flushed chunks for `key`."""
        parts = []
        for i in range(self.flushed[key]):
            name = f"{key}_{i}"
            meta = self.store.meta(name)
            eps = 0.0 if lossless else meta["eps_list"][0]
            parts.append(self.store.get(name, eps))
        return np.concatenate(parts) if parts else np.zeros(0)
