"""Pallas TPU kernel: fused dequantize + reconstruct (inverse of
residual_quant).  pred = theta + slope * t; x_hat = pred + q * step.
One VPU pass, VMEM-tiled like residual_quant."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "dequant_kernel",
    "dequant_reconstruct_pallas",
    "pyramid_reconstruct_kernel",
    "pyramid_reconstruct_pallas",
]


def dequant_kernel(q_ref, theta_ref, slope_ref, step_ref, x_ref):
    q = q_ref[...]
    theta = theta_ref[...]
    slope = slope_ref[...]
    step = step_ref[...]
    n = q.shape[-1]
    t = jax.lax.broadcasted_iota(theta.dtype, (1, n), 1)
    x_ref[...] = theta + slope * t + q.astype(theta.dtype) * step


def pyramid_reconstruct_kernel(qs_ref, theta_ref, slope_ref, steps_ref, x_ref, *,
                               num_layers: int):
    """Fused inverse of pyramid_quant: pred + Σ_l q_l * step_l in one VPU
    pass — the layer sum never round-trips through HBM, so decoding a
    k-layer prefix costs one fused elementwise pipeline regardless of k."""
    theta = theta_ref[...]
    slope = slope_ref[...]
    n = qs_ref.shape[-1]
    t = jax.lax.broadcasted_iota(theta.dtype, (1, n), 1)
    acc = theta + slope * t
    for l in range(num_layers):
        acc = acc + qs_ref[l, ...].astype(theta.dtype) * steps_ref[0, l]
    x_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def pyramid_reconstruct_pallas(
    qs: jax.Array,
    theta: jax.Array,
    slope: jax.Array,
    steps: jax.Array,
    block_m: int = 8,
    interpret: bool = True,
):
    """qs int32 [L, M, N]; theta/slope [M, 1]; steps [L] -> x_hat [M, N].
    Pass a layer prefix (qs[:k+1], steps[:k+1]) to reconstruct at tier k."""
    num_layers, m, n = qs.shape
    steps_in = jnp.asarray(steps, theta.dtype).reshape(1, num_layers)
    bm = min(block_m, m)
    grid = (pl.cdiv(m, bm),)
    kernel = functools.partial(pyramid_reconstruct_kernel, num_layers=num_layers)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((num_layers, bm, n), lambda i: (0, i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, num_layers), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), theta.dtype),
        interpret=interpret,
    )(qs, theta, slope, steps_in)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def dequant_reconstruct_pallas(
    q: jax.Array,
    theta: jax.Array,
    slope: jax.Array,
    step: jax.Array,
    block_m: int = 8,
    interpret: bool = True,
):
    """q int32 [M, N]; theta/slope/step [M, 1] -> x_hat [M, N] (theta dtype)."""
    m, n = q.shape
    bm = min(block_m, m)
    grid = (pl.cdiv(m, bm),)
    return pl.pallas_call(
        dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), theta.dtype),
        interpret=interpret,
    )(q, theta, slope, step)
