"""Pallas TPU kernel: fused dequantize + reconstruct (inverse of
residual_quant).  pred = theta + slope * t; x_hat = pred + q * step.
One VPU pass, VMEM-tiled like residual_quant."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["dequant_kernel", "dequant_reconstruct_pallas"]


def dequant_kernel(q_ref, theta_ref, slope_ref, step_ref, x_ref):
    q = q_ref[...]
    theta = theta_ref[...]
    slope = slope_ref[...]
    step = step_ref[...]
    n = q.shape[-1]
    t = jax.lax.broadcasted_iota(theta.dtype, (1, n), 1)
    x_ref[...] = theta + slope * t + q.astype(theta.dtype) * step


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def dequant_reconstruct_pallas(
    q: jax.Array,
    theta: jax.Array,
    slope: jax.Array,
    step: jax.Array,
    block_m: int = 8,
    interpret: bool = True,
):
    """q int32 [M, N]; theta/slope/step [M, 1] -> x_hat [M, N] (theta dtype)."""
    m, n = q.shape
    bm = min(block_m, m)
    grid = (pl.cdiv(m, bm),)
    return pl.pallas_call(
        dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), theta.dtype),
        interpret=interpret,
    )(q, theta, slope, step)
