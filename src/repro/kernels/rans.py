"""Pallas TPU kernel + device engine: the interleaved K-lane rANS coder.

Hardware adaptation of ``core.entropy``'s numpy step machines
(``_rans_encode_plane`` / ``_rans_decode_plane``): the K interleaved
32-bit states map to the **lane (vector) dimension**, independent
(stream, plane) rows map to sublanes, and the serial step axis (symbol
i // K) runs as the sequential grid — the same shape as the cone-scan
kernel, with the coder state carried across grid steps in VMEM scratch.
Renormalization writes are compacted per step: each step emits a dense
[R, K] (need, low-16-bits) pair and the host's single flat boolean
extraction over the [R, T, K] transpose yields every row's wire-order
word stream at once (steps ascending, lanes ascending — decoder order).

Three execution routes, all byte-identical by construction:

* ``route="xla"`` — the jit'd ``ref.rans_encode_ref``/``rans_decode_ref``
  ``lax.scan`` machines.  This is the **production path on CPU** (and any
  non-TPU backend): one fused XLA loop over steps instead of ~n/K
  interpreted numpy dispatches, ~10-30x the numpy machine on the step
  loop itself.
* ``route="pallas"`` — the Pallas kernels below, compiled (Mosaic) on
  TPU via the house ``_run_auto`` compiled-with-interpret-fallback
  wrapper in ``ops``.
* ``route="interpret"`` — the Pallas kernels in ``interpret=True`` mode:
  the kernel body as traced JAX ops with the real block/grid
  decomposition.  Too slow for production per-step grids; used by the
  CPU CI parity suite (tests/test_rans_kernel.py) to validate the
  kernels against the oracles and the numpy wire bytes.

``encode_rows``/``decode_rows`` are the host-facing entry points used by
``core.entropy``'s device engine: numpy in, numpy out, with the
identity-symbol padding scheme (symbol 256, freq = M, cum = 0 — the rANS
transform is then exactly ``x -> x`` and the uint32 renorm threshold
wraps to "never") padding step counts and row counts to powers of two so
the jit cache sees a bounded set of shapes.  Padded cells are byte-exact
no-ops, so the wire format stays identical to the numpy coder for every
route (golden fixtures unchanged).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref

__all__ = [
    "rans_encode_pallas",
    "rans_decode_pallas",
    "encode_rows",
    "decode_rows",
]

_PROB_BITS = 12
_M = 1 << _PROB_BITS
_L = 1 << 16
_K = 64
_ID = 256  # identity pad symbol (row tables carry a reserved 257th entry)

# jit cache shape bucketing: steps and rows pad to powers of two, so a
# workload with drifting sizes compiles O(log) scan programs, not O(sizes)
_ENC_UNROLL = 8
_DEC_UNROLL = 4


def _pow2(v: int) -> int:
    return 1 << max(0, int(v - 1).bit_length())


# --------------------------------------------------------------------- #
# Pallas kernels
# --------------------------------------------------------------------- #
def _rans_encode_kernel(
    sym_ref,     # (1, R, K) int32 block: this step's symbols
    f_ref,       # (R, 257) uint32: per-row freq tables + identity column
    c_ref,       # (R, 257) uint32: per-row cum tables
    states_ref,  # (R, K) uint32 out: final states (last grid step wins)
    need_ref,    # (1, R, K) int32 out: renorm mask for this step
    val_ref,     # (1, R, K) int32 out: low 16 bits pre-renorm
    x_ref,       # VMEM (R, K) uint32 scratch: the coder state
):
    i = pl.program_id(0)
    r, k = x_ref.shape

    @pl.when(i == 0)
    def _init():
        x_ref[:, :] = jnp.full((r, k), _L, jnp.uint32)

    syms = sym_ref[0, :, :]
    f = jnp.take_along_axis(f_ref[:, :], syms, axis=1).astype(jnp.uint32)
    c = jnp.take_along_axis(c_ref[:, :], syms, axis=1).astype(jnp.uint32)
    x = x_ref[:, :]
    # same uint32 wrap trick as the numpy machine: f == 2^12 -> threshold
    # wraps to the uint32 max -> identity/pad symbols never renormalize
    need = x > (f << jnp.uint32(32 - _PROB_BITS)) - jnp.uint32(1)
    need_ref[0, :, :] = need.astype(jnp.int32)
    val_ref[0, :, :] = (x & jnp.uint32(0xFFFF)).astype(jnp.int32)
    x = jnp.where(need, x >> jnp.uint32(16), x)
    div = x // f
    rem = x - div * f
    x = (div << jnp.uint32(_PROB_BITS)) + rem + c
    x_ref[:, :] = x
    # the grid runs steps in reverse; the final (t == 0) write wins
    states_ref[:, :] = x


@functools.partial(jax.jit, static_argnames=("interpret",))
def rans_encode_pallas(
    sym_cube: jax.Array,
    f_ext: jax.Array,
    c_ext: jax.Array,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Pallas twin of ``ref.rans_encode_ref``: sym_cube[T, R, K] int32,
    f_ext/c_ext[R, 257] uint32 -> (states[R, K] uint32, need[T, R, K]
    bool, vals[T, R, K] uint16).  Grid = T sequential steps walked in
    reverse (encode is LIFO); state carried in VMEM scratch."""
    t, r, k = sym_cube.shape
    rev = lambda i: (t - 1 - i, 0, 0)
    states, need, vals = pl.pallas_call(
        _rans_encode_kernel,
        grid=(t,),
        in_specs=[
            pl.BlockSpec((1, r, k), rev),
            pl.BlockSpec((r, 257), lambda i: (0, 0)),
            pl.BlockSpec((r, 257), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((r, k), lambda i: (0, 0)),
            pl.BlockSpec((1, r, k), rev),
            pl.BlockSpec((1, r, k), rev),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, k), jnp.uint32),
            jax.ShapeDtypeStruct((t, r, k), jnp.int32),
            jax.ShapeDtypeStruct((t, r, k), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((r, k), jnp.uint32)],
        interpret=interpret,
    )(sym_cube, f_ext, c_ext)
    return states, need.astype(bool), vals.astype(jnp.uint16)


def _rans_decode_kernel(
    x0_ref,       # (R, K) uint32: final encoder states
    s2s_ref,      # (R, M) int32: slot -> symbol
    f_ref,        # (R, 256) uint32
    c_ref,        # (R, 256) uint32
    words_ref,    # (R, W) int32: row-padded renorm words
    act_ref,      # (1, R, K) int32 block: live positions this step
    syms_ref,     # (1, R, K) int32 out
    x_ref,        # VMEM (R, K) uint32 scratch
    pos_ref,      # VMEM (1, R) int32 scratch: per-row word cursor
):
    i = pl.program_id(0)
    r, k = x_ref.shape

    @pl.when(i == 0)
    def _init():
        x_ref[:, :] = x0_ref[:, :]
        pos_ref[0, :] = jnp.zeros((r,), jnp.int32)

    a = act_ref[0, :, :] != 0
    x = x_ref[:, :]
    pos = pos_ref[0, :]
    slot = (x & jnp.uint32(_M - 1)).astype(jnp.int32)
    s = jnp.take_along_axis(s2s_ref[:, :], slot, axis=1)
    f = jnp.take_along_axis(f_ref[:, :], s, axis=1).astype(jnp.uint32)
    c = jnp.take_along_axis(c_ref[:, :], s, axis=1).astype(jnp.uint32)
    x2 = f * (x >> jnp.uint32(_PROB_BITS)) + slot.astype(jnp.uint32) - c
    need = (x2 < _L) & a
    # renormalizing lanes consume this row's words in ascending lane order
    kidx = pos[:, None] + jnp.cumsum(need.astype(jnp.int32), axis=1) - 1
    w = jnp.take_along_axis(words_ref[:, :], jnp.clip(kidx, 0, None), axis=1)
    x2 = jnp.where(need, (x2 << jnp.uint32(16)) | w.astype(jnp.uint32), x2)
    x_ref[:, :] = jnp.where(a, x2, x)
    pos_ref[0, :] = pos + need.sum(axis=1, dtype=jnp.int32)
    syms_ref[0, :, :] = s


@functools.partial(jax.jit, static_argnames=("interpret",))
def rans_decode_pallas(
    states: jax.Array,
    slot2sym: jax.Array,
    f_tab: jax.Array,
    c_tab: jax.Array,
    words: jax.Array,
    act: jax.Array,
    interpret: bool = True,
) -> jax.Array:
    """Pallas twin of ``ref.rans_decode_ref``; act[T, R, K] bool ->
    syms[T, R, K] uint8.  Grid = T sequential steps, forward."""
    t, r, k = act.shape
    fwd = lambda i: (i, 0, 0)
    syms = pl.pallas_call(
        _rans_decode_kernel,
        grid=(t,),
        in_specs=[
            pl.BlockSpec((r, k), lambda i: (0, 0)),
            pl.BlockSpec((r, _M), lambda i: (0, 0)),
            pl.BlockSpec((r, 256), lambda i: (0, 0)),
            pl.BlockSpec((r, 256), lambda i: (0, 0)),
            pl.BlockSpec((r, words.shape[1]), lambda i: (0, 0)),
            pl.BlockSpec((1, r, k), fwd),
        ],
        out_specs=[pl.BlockSpec((1, r, k), fwd)],
        out_shape=[jax.ShapeDtypeStruct((t, r, k), jnp.int32)],
        scratch_shapes=[
            pltpu.VMEM((r, k), jnp.uint32),
            pltpu.VMEM((1, r), jnp.int32),
        ],
        interpret=interpret,
    )(states, slot2sym, f_tab, c_tab, words.astype(jnp.int32),
      act.astype(jnp.int32))[0]
    return syms.astype(jnp.uint8)


# --------------------------------------------------------------------- #
# Route dispatch (jit'd oracle on CPU, compiled Pallas on TPU)
# --------------------------------------------------------------------- #
_enc_ref_jit = jax.jit(ref.rans_encode_ref, static_argnames=("unroll",))
_dec_ref_jit = jax.jit(ref.rans_decode_ref, static_argnames=("unroll",))


def compiled_route() -> bool:
    """True when route ``"auto"`` resolves to the compiled Mosaic kernels
    (TPU) rather than the jit'd lax.scan CPU fallback.  Callers use this
    to decide how aggressively to batch work onto the engine: the compiled
    kernels win at any size, the CPU oracle only above a dispatch-
    amortizing threshold."""
    return jax.default_backend() == "tpu"


def _dispatch_encode(sym_cube, f_ext, c_ext, route: str):
    if route == "auto":
        route = "pallas" if jax.default_backend() == "tpu" else "xla"
    if route == "xla":
        return _enc_ref_jit(sym_cube, f_ext, c_ext, unroll=_ENC_UNROLL)
    if route == "interpret":
        return rans_encode_pallas(sym_cube, f_ext, c_ext, interpret=True)
    if route == "pallas":
        from .ops import _run_auto

        return _run_auto(
            "rans_encode",
            lambda i: rans_encode_pallas(sym_cube, f_ext, c_ext, interpret=i),
        )
    raise ValueError(f"unknown rans route {route!r}")


def _dispatch_decode(states, slot2sym, f_tab, c_tab, words, act, route: str):
    if route == "auto":
        route = "pallas" if jax.default_backend() == "tpu" else "xla"
    if route == "xla":
        return _dec_ref_jit(states, slot2sym, f_tab, c_tab, words, act,
                            unroll=_DEC_UNROLL)
    if route == "interpret":
        return rans_decode_pallas(states, slot2sym, f_tab, c_tab, words, act,
                                  interpret=True)
    if route == "pallas":
        from .ops import _run_auto

        return _run_auto(
            "rans_decode",
            lambda i: rans_decode_pallas(states, slot2sym, f_tab, c_tab, words,
                                         act, interpret=i),
        )
    raise ValueError(f"unknown rans route {route!r}")


# --------------------------------------------------------------------- #
# Host-facing engine (numpy in / numpy out; used by core.entropy)
# --------------------------------------------------------------------- #
def encode_rows(
    sym_mat: np.ndarray, freqs: np.ndarray, route: str = "auto"
) -> tuple[np.ndarray, list[np.ndarray]]:
    """Encode R independent symbol rows with per-row normalized tables.

    sym_mat[R, cols] integer symbols in [0, 256] — 256 is the identity pad
    (ragged callers pre-pad short rows with it; any extra padding to a
    step multiple is added here).  freqs[R, 256] int — each row's
    normalized histogram (sum == M) — identity-column and cum tables are
    derived internally.  Returns (states[R, K] uint32 — native order, cast
    with ``.astype('<u4')`` for the wire — and the per-row uint16 word
    streams in decoder order).
    """
    r, cols = sym_mat.shape
    steps = max(1, -(-cols // _K))
    steps_p = _pow2(steps)
    rp = _pow2(max(1, r))
    cube = np.full((rp, steps_p * _K), _ID, dtype=np.int32)
    cube[:r, :cols] = sym_mat
    cube = np.ascontiguousarray(
        cube.reshape(rp, steps_p, _K).transpose(1, 0, 2)
    )
    f_ext = np.full((rp, 257), _M, dtype=np.uint32)
    c_ext = np.zeros((rp, 257), dtype=np.uint32)
    f_ext[:r, :256] = freqs
    c_ext[:r, 1:256] = np.cumsum(freqs[:, :-1], axis=1)
    states, need, vals = _dispatch_encode(
        jnp.asarray(cube), jnp.asarray(f_ext), jnp.asarray(c_ext), route
    )
    states = np.asarray(states)[:r]
    # [T, R, K] -> [R, T, K]: one flat boolean extraction then yields every
    # row's words contiguously, already in decoder order (steps ascending,
    # lanes ascending within a step)
    need = np.asarray(need).transpose(1, 0, 2)[:r]
    vals = np.asarray(vals).transpose(1, 0, 2)[:r]
    flat = vals[need]
    counts = need.reshape(r, -1).sum(axis=1)
    words = np.split(flat, np.cumsum(counts)[:-1]) if r else []
    return states, words


def decode_rows(
    states: np.ndarray,
    freqs: np.ndarray,
    words: list[np.ndarray],
    n: int,
    route: str = "auto",
) -> np.ndarray:
    """Decode R rows of ``n`` symbols each from their final states, tables
    and word streams.  Returns syms[R, n] uint8."""
    r = states.shape[0]
    steps = max(1, -(-n // _K))
    steps_p = _pow2(steps)
    rp = _pow2(max(1, r))
    tail = n - (steps - 1) * _K if n else 0
    x0 = np.full((rp, _K), _L, dtype=np.uint32)
    x0[:r] = states
    # every row's freqs sum to M, so one flat repeat builds all the
    # slot -> symbol maps at once
    s2s = np.zeros((rp, _M), dtype=np.int32)
    s2s[:r] = np.repeat(
        np.tile(np.arange(256, dtype=np.int32), r), freqs.reshape(-1)
    ).reshape(r, _M)
    f_tab = np.zeros((rp, 256), dtype=np.uint32)
    c_tab = np.zeros((rp, 256), dtype=np.uint32)
    f_tab[:r] = freqs
    c_tab[:r, 1:] = np.cumsum(freqs[:, :-1], axis=1)
    maxw = _pow2(max(1, max((w.size for w in words), default=1)))
    words_mat = np.zeros((rp, maxw), dtype=np.uint16)
    for i, w in enumerate(words):
        words_mat[i, : w.size] = w
    act = np.zeros((steps_p, rp, _K), dtype=bool)
    act[:steps, :r, :] = True
    if steps:
        act[steps - 1, :r, tail:] = False
    syms = _dispatch_decode(
        jnp.asarray(x0), jnp.asarray(s2s), jnp.asarray(f_tab),
        jnp.asarray(c_tab), jnp.asarray(words_mat), jnp.asarray(act), route
    )
    syms = np.asarray(syms)  # [steps_p, rp, K]
    return np.ascontiguousarray(syms.transpose(1, 0, 2)[:r].reshape(r, -1)[:, :n])
