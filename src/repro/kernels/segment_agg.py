"""Pallas TPU kernel: closed-form per-segment aggregates.

The device counterpart of ``core.segment_algebra`` (the numpy path the
analytics engine runs on the host today — this route is validated against
its jnp oracle but not yet wired into a production query path): given the
knowledge base's member segments as per-row line parameters (origin
``theta``, slope ``s``) and a query's per-segment local overlap window
``[a, b)``, emit each segment's exact contribution to the aggregate —
sum, sum of squares, min, max — using the closed forms

    sum   = m*theta + s*(S1(b) - S1(a))        S1(x) = x(x-1)/2
    sumsq = m*theta^2 + 2 theta s (S1(b)-S1(a)) + s^2 (S2(b)-S2(a))
                                                S2(x) = x(x-1)(2x-1)/6
    min/max at the window endpoints (segments are monotone).

One VPU-elementwise pass over M segment rows: no per-sample work at all,
which is the whole point — a batch of aggregate queries over S series
maps to one [M, 1]-column kernel launch regardless of how many million
samples the segments cover.  Rows with b <= a (no overlap) emit the
aggregate identity (0 sums, +inf/-inf extrema).  The jnp oracle lives in
``ref.segment_agg_ref``; the numpy host path is
``core.segment_algebra.base_aggregate``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["segment_agg_kernel", "segment_agg_pallas"]

_BIG = 3.4e38  # f32 +-inf stand-in, same sentinel as the cone-scan kernel


def segment_agg_kernel(theta_ref, slope_ref, a_ref, b_ref, sum_ref, sumsq_ref,
                       min_ref, max_ref):
    theta = theta_ref[...]  # (bm, 1)
    slope = slope_ref[...]
    a = a_ref[...]
    b = b_ref[...]
    m = jnp.maximum(b - a, 0.0)
    d1 = (b * (b - 1.0) - a * (a - 1.0)) * 0.5
    d2 = (b * (b - 1.0) * (2.0 * b - 1.0) - a * (a - 1.0) * (2.0 * a - 1.0)) / 6.0
    live = m > 0.0
    sum_ref[...] = jnp.where(live, m * theta + slope * d1, 0.0)
    sumsq_ref[...] = jnp.where(
        live, m * theta * theta + 2.0 * theta * slope * d1 + slope * slope * d2, 0.0
    )
    va = theta + slope * a
    vb = theta + slope * (b - 1.0)
    min_ref[...] = jnp.where(live, jnp.minimum(va, vb), _BIG)
    max_ref[...] = jnp.where(live, jnp.maximum(va, vb), -_BIG)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def segment_agg_pallas(
    theta: jax.Array,
    slope: jax.Array,
    a: jax.Array,
    b: jax.Array,
    block_m: int = 256,
    interpret: bool = True,
):
    """theta/slope/a/b [M, 1] per-segment line params + local overlap window
    ([a, b), floats).  Returns (sum, sumsq, min, max), each [M, 1]; rows
    with b <= a emit the aggregate identity (0, 0, +BIG, -BIG)."""
    m = theta.shape[0]
    bm = min(block_m, m)
    grid = (pl.cdiv(m, bm),)
    col = pl.BlockSpec((bm, 1), lambda i: (i, 0))
    return pl.pallas_call(
        segment_agg_kernel,
        grid=grid,
        in_specs=[col, col, col, col],
        out_specs=[col, col, col, col],
        out_shape=[jax.ShapeDtypeStruct((m, 1), theta.dtype)] * 4,
        interpret=interpret,
    )(theta, slope, a, b)
