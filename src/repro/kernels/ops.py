"""Jit'd public wrappers for the SHRINK Pallas kernels.

Backend selection: on CPU (this container) the kernels execute in Pallas
``interpret=True`` mode — the kernel body runs as traced JAX ops with the
same block/grid decomposition, which validates BlockSpec tiling and the
sequential-grid state carry.  On a real TPU backend the same calls compile
to Mosaic.  ``force_ref=True`` routes to the pure-jnp oracle (used for
differentiable paths and in tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .cone_scan import cone_scan_pallas
from .flash_attention import flash_attention_pallas
from .dequant import dequant_reconstruct_pallas
from .interval_stats import interval_stats_pallas
from .residual_quant import residual_quant_pallas

__all__ = [
    "flash_attention",
    "interval_stats",
    "residual_quant",
    "dequant_reconstruct",
    "cone_scan",
    "use_interpret",
]


def use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def interval_stats(x: jax.Array, window: int, force_ref: bool = False):
    if force_ref:
        return ref.interval_stats_ref(x, window)
    return interval_stats_pallas(x, window, interpret=use_interpret())


def residual_quant(
    x: jax.Array,
    theta: jax.Array,
    slope: jax.Array,
    step: jax.Array,
    qmax: int = 127,
    force_ref: bool = False,
):
    if force_ref:
        return ref.residual_quant_ref(x, theta, slope, step, qmax=qmax)
    return residual_quant_pallas(x, theta, slope, step, qmax=qmax, interpret=use_interpret())


def dequant_reconstruct(
    q: jax.Array,
    theta: jax.Array,
    slope: jax.Array,
    step: jax.Array,
    force_ref: bool = False,
):
    if force_ref:
        return ref.dequant_reconstruct_ref(q, theta, slope, step)
    return dequant_reconstruct_pallas(q, theta, slope, step, interpret=use_interpret())


def cone_scan(x: jax.Array, eps_hat: jax.Array, block_t: int = 256, force_ref: bool = False):
    if force_ref:
        return ref.cone_scan_ref(x, eps_hat)
    t = x.shape[0]
    bt = min(block_t, t)
    if t % bt:
        pad = bt - (t % bt)
        x = jnp.concatenate([x, jnp.repeat(x[-1:], pad, axis=0)], axis=0)
        eps_hat = jnp.concatenate([eps_hat, jnp.repeat(eps_hat[-1:], pad, axis=0)], axis=0)
        out = cone_scan_pallas(x, eps_hat, block_t=bt, interpret=use_interpret())
        brk, theta, lo, hi, fin_lo, fin_hi = out
        # NOTE: fin_lo/fin_hi reflect the padded tail; callers that need the
        # open-segment span with padding should pass T % block_t == 0 data.
        return brk[:t], theta[:t], lo[:t], hi[:t], fin_lo, fin_hi
    return cone_scan_pallas(x, eps_hat, block_t=bt, interpret=use_interpret())


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True,
                    force_ref: bool = False):
    """Multi-head flash attention: q/k/v [B, H, S, D] (vmapped over B, H)."""
    if force_ref:
        fn = lambda qq, kk, vv: ref.flash_attention_ref(qq, kk, vv, causal)
    else:
        fn = lambda qq, kk, vv: flash_attention_pallas(
            qq, kk, vv, causal=causal, interpret=use_interpret()
        )
    return jax.vmap(jax.vmap(fn))(q, k, v)
