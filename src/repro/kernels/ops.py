"""Jit'd public wrappers for the SHRINK Pallas kernels.

Backend selection: on CPU (this container) the kernels execute in Pallas
``interpret=True`` mode — the kernel body runs as traced JAX ops with the
same block/grid decomposition, which validates BlockSpec tiling and the
sequential-grid state carry.  On a TPU backend the wrappers first attempt
the compiled (Mosaic, ``interpret=False``) path and automatically fall back
to interpret mode if lowering fails, remembering the failure per kernel so
the cost is paid once per process.  ``force_ref=True`` routes to the
pure-jnp oracle (used for differentiable paths and in tests).
"""
from __future__ import annotations

import warnings
from typing import Callable

import jax
import jax.numpy as jnp

from . import ref
from .cone_scan import cone_scan_pallas
from .flash_attention import flash_attention_pallas
from .dequant import dequant_reconstruct_pallas, pyramid_reconstruct_pallas
from .interval_stats import interval_stats_pallas
from .rans import decode_rows as rans_decode_rows
from .rans import encode_rows as rans_encode_rows
from .residual_quant import pyramid_quant_pallas, residual_quant_pallas
from .segment_agg import segment_agg_pallas

__all__ = [
    "flash_attention",
    "interval_stats",
    "residual_quant",
    "dequant_reconstruct",
    "pyramid_quant",
    "pyramid_reconstruct",
    "cone_scan",
    "cone_scan_segments",
    "rans_decode_rows",
    "rans_encode_rows",
    "segment_agg",
    "use_interpret",
]


def use_interpret() -> bool:
    return jax.default_backend() != "tpu"


_compiled_broken: set[str] = set()


def _run_auto(name: str, call: Callable[[bool], object]):
    """Run ``call(interpret)`` on the compiled path when it is expected to
    work, falling back to interpret mode (and caching the verdict) when
    Mosaic lowering raises."""
    if not use_interpret() and name not in _compiled_broken:
        try:
            return call(False)
        except Exception as e:  # lowering/compile failure -> interpret fallback
            out = call(True)
            # Cache the fallback only after interpret mode succeeds on the
            # same call: an error that fails both modes (bad shapes, device
            # OOM) propagates instead of poisoning the compiled path.
            _compiled_broken.add(name)
            warnings.warn(
                f"pallas kernel {name!r}: compiled path failed ({e!r}); "
                "falling back to interpret mode for the rest of this process",
                RuntimeWarning,
                stacklevel=2,
            )
            return out
    return call(True)


def interval_stats(x: jax.Array, window: int, force_ref: bool = False):
    if force_ref:
        return ref.interval_stats_ref(x, window)
    return interval_stats_pallas(x, window, interpret=use_interpret())


def residual_quant(
    x: jax.Array,
    theta: jax.Array,
    slope: jax.Array,
    step: jax.Array,
    qmax: int = 127,
    force_ref: bool = False,
    lengths: jax.Array | None = None,
):
    """``lengths`` [M] marks ragged row tails: positions >= lengths[m] emit
    q = 0 / err = 0 so padded blocks contribute no symbols or feedback."""
    if force_ref:
        return ref.residual_quant_ref(x, theta, slope, step, qmax=qmax, lengths=lengths)
    return residual_quant_pallas(
        x, theta, slope, step, lengths=lengths, qmax=qmax, interpret=use_interpret()
    )


def dequant_reconstruct(
    q: jax.Array,
    theta: jax.Array,
    slope: jax.Array,
    step: jax.Array,
    force_ref: bool = False,
):
    if force_ref:
        return ref.dequant_reconstruct_ref(q, theta, slope, step)
    return dequant_reconstruct_pallas(q, theta, slope, step, interpret=use_interpret())


def pyramid_quant(
    x: jax.Array,
    theta: jax.Array,
    slope: jax.Array,
    steps: jax.Array,
    qmax: int = 127,
    force_ref: bool = False,
    lengths: jax.Array | None = None,
):
    """Fused multi-layer refinement quantization: layer l quantizes the
    error layers 0..l-1 left behind (steps[L] coarse -> fine).  Returns
    (qs int32 [L, M, N], err [M, N]).  ``lengths`` [M] marks ragged row
    tails: positions >= lengths[m] emit q = 0 on every layer and err = 0."""
    if force_ref:
        return ref.pyramid_quant_ref(x, theta, slope, steps, qmax=qmax, lengths=lengths)
    return _run_auto(
        "pyramid_quant",
        lambda i: pyramid_quant_pallas(
            x, theta, slope, steps, lengths=lengths, qmax=qmax, interpret=i
        ),
    )


def pyramid_reconstruct(
    qs: jax.Array,
    theta: jax.Array,
    slope: jax.Array,
    steps: jax.Array,
    force_ref: bool = False,
):
    """Fused inverse of pyramid_quant: pred + Σ_l qs[l] * steps[l].  Feed a
    layer prefix (qs[:k+1], steps[:k+1]) to reconstruct at tier k."""
    if force_ref:
        return ref.pyramid_reconstruct_ref(qs, theta, slope, steps)
    return _run_auto(
        "pyramid_reconstruct",
        lambda i: pyramid_reconstruct_pallas(qs, theta, slope, steps, interpret=i),
    )


def segment_agg(
    theta: jax.Array,
    slope: jax.Array,
    a: jax.Array,
    b: jax.Array,
    force_ref: bool = False,
):
    """Closed-form per-segment aggregates for compressed-domain analytics:
    theta/slope/a/b [M, 1] -> (sum, sumsq, min, max) [M, 1] of each
    segment's predictions over its local window [a, b) — O(segments), no
    per-sample work (rows with b <= a emit the aggregate identity)."""
    if force_ref:
        return ref.segment_agg_ref(theta, slope, a, b)
    return _run_auto(
        "segment_agg",
        lambda i: segment_agg_pallas(theta, slope, a, b, interpret=i),
    )


def cone_scan(
    x: jax.Array,
    eps_hat: jax.Array,
    block_t: int = 256,
    force_ref: bool = False,
    lengths: jax.Array | None = None,
):
    """``lengths`` [S] activates the valid-length mask path for ragged lanes
    (positions past a lane's length are inert); None = all lanes full."""
    if force_ref:
        return ref.cone_scan_ref(x, eps_hat, lengths=lengths)
    t, s = x.shape
    bt = min(block_t, t)
    if t % bt:
        pad = bt - (t % bt)
        x = jnp.concatenate([x, jnp.repeat(x[-1:], pad, axis=0)], axis=0)
        eps_hat = jnp.concatenate([eps_hat, jnp.repeat(eps_hat[-1:], pad, axis=0)], axis=0)
        # masking the pad rows keeps fin_lo/fin_hi pinned to the true open
        # segment (repeat values no longer tighten the final span)
        len_in = jnp.full((s,), t, jnp.int32) if lengths is None else lengths
        out = _run_auto(
            "cone_scan",
            lambda i: cone_scan_pallas(x, eps_hat, len_in, block_t=bt, interpret=i),
        )
        brk, theta, lo, hi, fin_lo, fin_hi = out
        return brk[:t], theta[:t], lo[:t], hi[:t], fin_lo, fin_hi
    return _run_auto(
        "cone_scan",
        lambda i: cone_scan_pallas(x, eps_hat, lengths, block_t=bt, interpret=i),
    )


@jax.jit
def _compact_segments(brk, theta, psi_lo, psi_hi, fin_lo, fin_hi):
    """Dense per-point scan outputs -> per-series segment records, in XLA.

    brk/theta/psi_*[T, S].  Returns (counts[S], t0s[T, S], thetas[T, S],
    lo[T, S], hi[T, S]) where row k of each [T, S] array describes segment k
    of that series (rows >= counts[s] are padding).  The scatter is a cumsum
    over break flags — O(T) with no host round-trip.
    """
    t_len, s_len = brk.shape
    seg_of_t = jnp.cumsum(brk, axis=0) - 1  # segment index at each point
    cols = jnp.broadcast_to(jnp.arange(s_len)[None, :], (t_len, s_len))
    is_brk = brk.astype(bool)
    # scatter rows: break positions land at their segment's slot; everything
    # else goes to a dump row at index t_len
    rows = jnp.where(is_brk, seg_of_t, t_len)
    tpos = jnp.broadcast_to(jnp.arange(t_len)[:, None], (t_len, s_len))
    t0s = jnp.zeros((t_len + 1, s_len), jnp.int32).at[rows, cols].set(tpos)
    thetas = jnp.zeros((t_len + 1, s_len), theta.dtype).at[rows, cols].set(theta)
    # the span recorded at break t closes segment seg_of_t[t] - 1
    close_rows = jnp.where(is_brk & (seg_of_t > 0), seg_of_t - 1, t_len)
    lo = jnp.zeros((t_len + 1, s_len), psi_lo.dtype).at[close_rows, cols].set(psi_lo)
    hi = jnp.zeros((t_len + 1, s_len), psi_hi.dtype).at[close_rows, cols].set(psi_hi)
    counts = brk.sum(axis=0)
    # the still-open segment's span comes from the final carry
    lo = lo.at[counts - 1, jnp.arange(s_len)].set(fin_lo[0])
    hi = hi.at[counts - 1, jnp.arange(s_len)].set(fin_hi[0])
    return counts, t0s[:t_len], thetas[:t_len], lo[:t_len], hi[:t_len]


def cone_scan_segments(
    x: jax.Array,
    eps_hat: jax.Array,
    block_t: int = 256,
    lengths: jax.Array | None = None,
):
    """Lane-parallel cone scan + on-device segment compaction.

    x[T, S], eps_hat[T, S] -> (counts[S], t0s[T, S], thetas[T, S],
    psi_lo[T, S], psi_hi[T, S]); row k of the [T, S] outputs is segment k of
    that series.  Spans use +-3.4e38 as the unbounded sentinel (map to inf
    on the host).  Segment lengths follow from consecutive t0s (and the lane
    end for the last segment), since each lane's segments partition
    [0, lengths[s]).

    ``lengths`` [S] (default: T for every lane) is the valid-length mask for
    ragged lanes: positions past a lane's length are inert, so arbitrary
    padding up to T — including the block_t alignment padding — never
    creates segments or pollutes the open segment's fin_lo/fin_hi carry.
    T must be a multiple of block_t (pad x/eps_hat; the mask keeps the pad
    inert).
    """
    t = x.shape[0]
    bt = min(block_t, t)
    assert t % bt == 0, (
        f"T={t} % block_t={bt} != 0 — pad x/eps_hat to a block multiple and "
        "pass the true per-lane `lengths` so the pad stays inert"
    )
    brk, theta, lo, hi, fin_lo, fin_hi = cone_scan(x, eps_hat, block_t=bt, lengths=lengths)
    return _compact_segments(brk, theta, lo, hi, fin_lo, fin_hi)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True,
                    force_ref: bool = False):
    """Multi-head flash attention: q/k/v [B, H, S, D] (vmapped over B, H)."""
    if force_ref:
        fn = lambda qq, kk, vv: ref.flash_attention_ref(qq, kk, vv, causal)
    else:
        fn = lambda qq, kk, vv: flash_attention_pallas(
            qq, kk, vv, causal=causal, interpret=use_interpret()
        )
    return jax.vmap(jax.vmap(fn))(q, k, v)
