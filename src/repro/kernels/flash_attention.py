"""Pallas TPU kernel: flash attention (online-softmax over KV blocks).

The prefill cells' memory term is dominated by materialized score buffers
(EXPERIMENTS.md §Roofline); a fused attention keeps the working set at
[bq, bk] in VMEM with running (max, sum, acc) scratch carried across the
sequential kv grid dimension — the same TPU sequential-grid idiom as
cone_scan.  HBM traffic drops from O(S^2) scores to Q+K+V+O.

Single-head kernel over [S, D]; ops.flash_attention vmaps over (batch,
heads).  Causal masking skips fully-masked kv blocks via pl.when and
iota-masks the diagonal block.  Validated against ref.flash_attention_ref
in interpret mode (tests/test_kernels.py); on this CPU container it is a
correctness artifact — the dry-run keeps the XLA attention so the roofline
instrument sees real ops (a Mosaic custom call would hide them; DESIGN.md
§7 records the analytic-injection follow-up).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

_NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq: int, bk: int, causal: bool, scale: float, nk: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = (not causal) or (j * bk <= i * bq + bq - 1)

    @pl.when(run)
    def _body():
        q = q_ref[...].astype(jnp.float32)  # [bq, D]
        k = k_ref[...].astype(jnp.float32)  # [bk, D]
        v = v_ref[...].astype(jnp.float32)
        s = (q @ k.T) * scale  # [bq, bk]
        if causal:
            qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, _NEG)
        m_prev = m_ref[...]  # [bq, 1]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)  # [bq, bk]
        alpha = jnp.exp(m_prev - m_new)  # [bq, 1]
        l_new = l_prev * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + p @ v
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(j == nk - 1)
    def _finish():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention_pallas(
    q: jax.Array,  # [S, D]
    k: jax.Array,  # [S_k, D]
    v: jax.Array,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
):
    sq, d = q.shape
    sk = k.shape[0]
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0, f"S={sq}/{sk} % blocks {bq}/{bk}"
    nq, nk = sq // bq, sk // bk
    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, causal=causal, scale=d**-0.5, nk=nk
    )
    return pl.pallas_call(
        kernel,
        grid=(nq, nk),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
