"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are tested against
(interpret=True on CPU, shape/dtype sweeps in tests/test_kernels.py).

Conventions (shared with the kernels):

* ``interval_stats``:  x[T, S] time-major, S independent series in lanes;
  fixed window W along T.  Returns per-window (min, max) -> [T//W, S].
* ``residual_quant``:  per-row linear base (theta + slope * t) over blocks
  x[M, N]; emits clipped round((x-pred)/step) plus the error-feedback term.
* ``cone_scan``:       the SHRINK shrinking-cone recurrence, vectorized over
  S series in lanes.  Emits per-point break flags, the origin of the segment
  starting at each break, and the span of the segment that closed there.
* ``dequant_reconstruct``: inverse of residual_quant.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "flash_attention_ref",
    "interval_stats_ref",
    "residual_quant_ref",
    "dequant_reconstruct_ref",
    "pyramid_quant_ref",
    "pyramid_reconstruct_ref",
    "cone_scan_ref",
    "segment_agg_ref",
    "rans_encode_ref",
    "rans_decode_ref",
]


def interval_stats_ref(x: jax.Array, window: int) -> tuple[jax.Array, jax.Array]:
    """x[T, S] -> (mins[T//W, S], maxs[T//W, S]); T must divide by W."""
    t, s = x.shape
    assert t % window == 0, f"T={t} not divisible by window={window}"
    xr = x.reshape(t // window, window, s)
    return xr.min(axis=1), xr.max(axis=1)


def residual_quant_ref(
    x: jax.Array,
    theta: jax.Array,
    slope: jax.Array,
    step: jax.Array,
    qmax: int = 127,
    lengths: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """x[M, N]; theta/slope/step[M, 1] per-row base-line params.

    Returns (q int32 in [-qmax, qmax], err = x - (pred + q*step)).
    ``lengths`` [M] marks each row's ragged tail: positions >= lengths[m]
    emit q = 0 and err = 0 (padding carries no symbols and no feedback).
    """
    m, n = x.shape
    t = jnp.arange(n, dtype=x.dtype)[None, :]
    pred = theta + slope * t
    r = x - pred
    q = jnp.clip(jnp.round(r / step), -qmax, qmax).astype(jnp.int32)
    err = r - q.astype(x.dtype) * step
    if lengths is not None:
        valid = jnp.arange(n, dtype=jnp.int32)[None, :] < jnp.asarray(
            lengths, jnp.int32
        ).reshape(m, 1)
        q = jnp.where(valid, q, 0)
        err = jnp.where(valid, err, 0.0)
    return q, err


def dequant_reconstruct_ref(
    q: jax.Array,
    theta: jax.Array,
    slope: jax.Array,
    step: jax.Array,
) -> jax.Array:
    """Inverse of residual_quant: pred + q*step."""
    m, n = q.shape
    t = jnp.arange(n, dtype=theta.dtype)[None, :]
    pred = theta + slope * t
    return pred + q.astype(theta.dtype) * step


def pyramid_quant_ref(
    x: jax.Array,
    theta: jax.Array,
    slope: jax.Array,
    steps: jax.Array,
    qmax: int = 127,
    lengths: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Multi-layer refinement quantization (the device half of the residual
    pyramid): x[M, N]; theta/slope[M, 1] per-row base-line params;
    steps[L] strictly decreasing quantizer steps, layer l quantizing the
    error its predecessors left behind:

        e_0 = x - pred;  q_l = clip(round(e_l / step_l));  e_{l+1} = e_l - q_l*step_l

    Returns (qs int32 [L, M, N], err [M, N] = the error remaining after the
    finest layer).  ``lengths`` [M] marks each row's ragged tail: positions
    >= lengths[m] emit q = 0 across every layer and err = 0.
    """
    m, n = x.shape
    t = jnp.arange(n, dtype=x.dtype)[None, :]
    pred = theta + slope * t
    e = x - pred
    qs = []
    num_layers = int(steps.shape[0])
    for l in range(num_layers):
        step = steps[l].astype(x.dtype)
        q = jnp.clip(jnp.round(e / step), -qmax, qmax).astype(jnp.int32)
        e = e - q.astype(x.dtype) * step
        qs.append(q)
    qs = jnp.stack(qs)
    if lengths is not None:
        valid = jnp.arange(n, dtype=jnp.int32)[None, :] < jnp.asarray(
            lengths, jnp.int32
        ).reshape(m, 1)
        qs = jnp.where(valid[None], qs, 0)
        e = jnp.where(valid, e, 0.0)
    return qs, e


def pyramid_reconstruct_ref(
    qs: jax.Array,
    theta: jax.Array,
    slope: jax.Array,
    steps: jax.Array,
) -> jax.Array:
    """Inverse of pyramid_quant at any layer prefix: feed qs[:k+1] and
    steps[:k+1] to reconstruct through layer k; the full stack gives
    pred + Σ_l q_l * step_l."""
    m, n = qs.shape[1], qs.shape[2]
    t = jnp.arange(n, dtype=theta.dtype)[None, :]
    pred = theta + slope * t
    contrib = (qs.astype(theta.dtype) * steps.astype(theta.dtype)[:, None, None]).sum(0)
    return pred + contrib


def cone_scan_ref(
    x: jax.Array,
    eps_hat: jax.Array,
    lengths: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """SHRINK shrinking-cone scan, vectorized over lanes.

    x[T, S], eps_hat[T, S] (adaptive threshold to use for a segment that
    *starts* at (t, s)).  ``lengths`` [S] optionally marks ragged lanes:
    positions t >= lengths[s] are padding — they never constrain, break,
    or seed a cone, and the lane's state (hence fin_lo/fin_hi) freezes at
    its last valid sample.

    Returns (brk i32[T,S], theta f32[T,S], psi_lo f32[T,S], psi_hi f32[T,S],
             fin_lo f32[1,S], fin_hi f32[1,S]):
      * brk[t]   = 1 iff a new segment starts at t (brk[0] == 1).
      * theta[t] = origin of the segment starting at t   (valid where brk=1).
      * psi_lo/hi[t] = span of the segment that CLOSED at t-1 (valid where
        brk=1 and t>0).
      * fin_lo/hi = span of the still-open segment at the lane end (the host
        closes it when compacting segments).
    """
    big = jnp.float32(3.4e38)
    t_steps, s = x.shape
    len_vec = (
        jnp.full((s,), t_steps, jnp.int32)
        if lengths is None
        else jnp.asarray(lengths, jnp.int32)
    )

    def origin(v, eps):
        return jnp.floor(v / eps) * eps

    def step_fn(carry, inp):
        theta, lo, hi, t0, eps_seg = carry
        v, eps_t, t = inp
        dt = (t - t0).astype(x.dtype)
        cand_hi = (v + eps_seg - theta) / jnp.maximum(dt, 1.0)
        cand_lo = (v - eps_seg - theta) / jnp.maximum(dt, 1.0)
        # dt == 0 (the segment's own start point) sets theta only; it is not
        # a slope constraint — same convention as semantics.extract_semantics.
        # t >= lengths is a padded position: the lane freezes there.
        grow = (dt > 0) & (t < len_vec)
        new_hi = jnp.where(grow, jnp.minimum(hi, cand_hi), hi)
        new_lo = jnp.where(grow, jnp.maximum(lo, cand_lo), lo)
        brk = (new_lo > new_hi) & grow
        out_lo, out_hi = lo, hi  # span of the closing segment
        theta_new = origin(v, eps_t)
        theta = jnp.where(brk, theta_new, theta)
        eps_seg = jnp.where(brk, eps_t, eps_seg)
        lo = jnp.where(brk, -big, new_lo)
        hi = jnp.where(brk, big, new_hi)
        t0 = jnp.where(brk, t, t0)
        return (theta, lo, hi, t0, eps_seg), (
            brk.astype(jnp.int32),
            theta,
            out_lo,
            out_hi,
        )

    v0 = x[0]
    eps0 = eps_hat[0]
    carry0 = (
        origin(v0, eps0),
        jnp.full((s,), -big, x.dtype),
        jnp.full((s,), big, x.dtype),
        jnp.zeros((s,), jnp.int32),
        eps0,
    )
    ts = jnp.arange(t_steps, dtype=jnp.int32)
    (_, lo_f, hi_f, _, _), (brk, theta, psi_lo, psi_hi) = jax.lax.scan(
        step_fn, carry0, (x, eps_hat, ts)
    )
    brk = brk.at[0].set(jnp.ones((s,), jnp.int32))
    theta = theta.at[0].set(origin(v0, eps0))
    return brk, theta, psi_lo, psi_hi, lo_f[None, :], hi_f[None, :]


def segment_agg_ref(
    theta: jax.Array,
    slope: jax.Array,
    a: jax.Array,
    b: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Closed-form per-segment aggregates (the compressed-domain analytics
    primitive): theta/slope/a/b [M, 1] line params + local overlap window
    [a, b).  Returns (sum, sumsq, min, max) [M, 1] of the segment's
    predictions over the window; rows with b <= a emit the aggregate
    identity (0, 0, +3.4e38, -3.4e38)."""
    big = jnp.asarray(3.4e38, theta.dtype)
    m = jnp.maximum(b - a, 0.0)
    d1 = (b * (b - 1.0) - a * (a - 1.0)) * 0.5
    d2 = (b * (b - 1.0) * (2.0 * b - 1.0) - a * (a - 1.0) * (2.0 * a - 1.0)) / 6.0
    live = m > 0.0
    seg_sum = jnp.where(live, m * theta + slope * d1, 0.0)
    seg_sumsq = jnp.where(
        live, m * theta * theta + 2.0 * theta * slope * d1 + slope * slope * d2, 0.0
    )
    va = theta + slope * a
    vb = theta + slope * (b - 1.0)
    seg_min = jnp.where(live, jnp.minimum(va, vb), big)
    seg_max = jnp.where(live, jnp.maximum(va, vb), -big)
    return seg_sum, seg_sumsq, seg_min, seg_max


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True) -> jax.Array:
    """Plain softmax attention over [S, D] single head (flash oracle)."""
    sq, d = q.shape
    sk = k.shape[0]
    s = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) * (d**-0.5)
    if causal:
        qpos = jnp.arange(sq)[:, None]
        kpos = jnp.arange(sk)[None, :]
        s = jnp.where(kpos <= qpos, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return (p @ v.astype(jnp.float32)).astype(q.dtype)


# --------------------------------------------------------------------- #
# Interleaved K-lane rANS (the device entropy engine's step machines)
# --------------------------------------------------------------------- #
#
# Layout shared with core.entropy and kernels/rans.py: symbol i of a stream
# lives in lane i % K at step i // K, states are uint32 in [2^16, 2^32)
# with 16-bit renormalization and M = 2^12 probability bits.  Rows are
# independent (stream, plane) pairs; per-row tables carry a reserved 257th
# "identity" symbol (freq = M, cum = 0) whose rANS transform is exactly
# x -> x and whose renorm threshold (f << 20) - 1 wraps to the uint32 max,
# so padded steps and rows are byte-exact no-ops — that is what lets the
# host pad step counts and row counts to powers of two for jit-cache reuse
# without changing a single emitted word.

_RANS_PROB_BITS = 12
_RANS_M = 1 << _RANS_PROB_BITS
_RANS_L = 1 << 16


def rans_encode_ref(
    sym_cube: jax.Array, f_ext: jax.Array, c_ext: jax.Array, unroll: int = 8
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Encode step machine: walk steps backward (rANS is LIFO), all R*K
    states advancing as one [R, K] vector op per step.

    sym_cube[T, R, K] int32 in [0, 256] (256 = identity pad symbol),
    f_ext/c_ext[R, 257] uint32 (row tables + identity column).  Returns
    (states[R, K] uint32, need[T, R, K] bool, vals[T, R, K] uint16): step
    t's renormalizing lanes are ``need[t]`` and the 16-bit words they
    emitted are ``vals[t][need[t]]`` — already indexed by DECODE step, so
    flat boolean extraction in (row, step asc, lane asc) order yields the
    wire's word stream directly.
    """
    r, k = sym_cube.shape[1], sym_cube.shape[2]
    f_flat = f_ext.reshape(-1)
    c_flat = c_ext.reshape(-1)
    row_off = (jnp.arange(r, dtype=jnp.int32) * 257)[:, None]
    x0 = jnp.full((r, k), _RANS_L, jnp.uint32)

    def body(x, syms):
        idx = syms + row_off
        f = f_flat[idx]
        c = c_flat[idx]
        # renorm threshold minus one: x >= f << 20  <=>  x > (f << 20) - 1;
        # f == 2^12 wraps to 0xFFFFFFFF -> "never renormalize"
        need = x > (f << jnp.uint32(32 - _RANS_PROB_BITS)) - jnp.uint32(1)
        val = x.astype(jnp.uint16)  # truncating low-16 store
        x = jnp.where(need, x >> jnp.uint32(16), x)
        div = x // f
        rem = x - div * f
        x = (div << jnp.uint32(_RANS_PROB_BITS)) + rem + c
        return x, (need, val)

    x, (need, vals) = jax.lax.scan(body, x0, sym_cube, reverse=True, unroll=unroll)
    return x, need, vals


def rans_decode_ref(
    states: jax.Array,
    slot2sym: jax.Array,
    f_tab: jax.Array,
    c_tab: jax.Array,
    words: jax.Array,
    act: jax.Array,
    unroll: int = 4,
) -> jax.Array:
    """Decode step machine: walk steps forward; within a step the
    renormalizing lanes consume words in ascending lane order (a lane-axis
    cumsum indexes the row's word stream).

    states[R, K] uint32 (final encoder states), slot2sym[R, M] int32,
    f_tab/c_tab[R, 256] uint32, words[R, W] uint16 (row-padded),
    act[T, R, K] bool marks live symbol positions — padded steps, padded
    rows, and the last step's tail lanes must neither emit symbols nor
    consume words.  Returns syms[T, R, K] uint8.
    """
    r, k = states.shape
    maxw = words.shape[1]
    s2s_flat = slot2sym.reshape(-1)
    f_flat = f_tab.reshape(-1)
    c_flat = c_tab.reshape(-1)
    w_flat = words.reshape(-1)
    row_off_m = (jnp.arange(r, dtype=jnp.int32) * _RANS_M)[:, None]
    row_off_s = (jnp.arange(r, dtype=jnp.int32) * 256)[:, None]
    row_off_w = (jnp.arange(r, dtype=jnp.int32) * maxw)[:, None]
    pos0 = jnp.zeros((r,), jnp.int32)

    def body(carry, a):
        x, pos = carry
        slot = (x & jnp.uint32(_RANS_M - 1)).astype(jnp.int32)
        s = s2s_flat[slot + row_off_m]
        f = f_flat[s + row_off_s]
        c = c_flat[s + row_off_s]
        x2 = f * (x >> jnp.uint32(_RANS_PROB_BITS)) + slot.astype(jnp.uint32) - c
        need = (x2 < _RANS_L) & a
        kidx = pos[:, None] + jnp.cumsum(need.astype(jnp.int32), axis=1) - 1
        w = w_flat[jnp.clip(kidx, 0, None) + row_off_w]
        x2 = jnp.where(need, (x2 << jnp.uint32(16)) | w.astype(jnp.uint32), x2)
        pos = pos + need.sum(axis=1, dtype=jnp.int32)
        x = jnp.where(a, x2, x)
        return (x, pos), s.astype(jnp.uint8)

    (_, _), syms = jax.lax.scan(body, (states, pos0), act, unroll=unroll)
    return syms
