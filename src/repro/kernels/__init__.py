"""SHRINK compute hot-spots as Pallas TPU kernels.

Kernels (each has a pure-jnp oracle in ref.py, validated in
tests/test_kernels.py over shape/dtype sweeps):

* interval_stats — per-window min/max (Alg. 2 fluctuation stats)
* cone_scan      — shrinking-cone recurrence, sequential-grid state carry,
                   lane-parallel across series (Alg. 3); cone_scan_segments
                   adds on-device (XLA) segment compaction for the batched
                   codec pipeline
* residual_quant — fused residual + quantize + clip + error feedback (Alg. 6)
* dequant        — fused dequantize + linear reconstruct
* flash_attention — online-softmax fused attention (sequential-kv grid)
"""
from .ops import (  # noqa: F401
    cone_scan,
    cone_scan_segments,
    flash_attention,
    dequant_reconstruct,
    interval_stats,
    residual_quant,
    use_interpret,
)
from . import ref  # noqa: F401
