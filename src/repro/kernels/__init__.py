"""SHRINK compute hot-spots as Pallas TPU kernels.

Kernels (each has a pure-jnp oracle in ref.py, validated in
tests/test_kernels.py over shape/dtype sweeps):

* interval_stats — per-window min/max (Alg. 2 fluctuation stats)
* cone_scan      — shrinking-cone recurrence, sequential-grid state carry,
                   lane-parallel across series (Alg. 3); cone_scan_segments
                   adds on-device (XLA) segment compaction for the batched
                   codec pipeline
* residual_quant — fused residual + quantize + clip + error feedback (Alg. 6)
* pyramid_quant  — fused multi-layer refinement quantization (the device
                   half of the residual pyramid: layer l quantizes the
                   error layers 0..l-1 left behind, one VMEM pass)
* dequant        — fused dequantize + linear reconstruct
* pyramid_reconstruct — fused pred + Σ_l q_l·step_l over any layer prefix
* segment_agg    — closed-form per-segment aggregates (sum/sumsq/min/max):
                   the device counterpart of core.segment_algebra for
                   batched compressed-domain analytics; O(segments), no
                   per-sample work (host engine runs the numpy path today)
* flash_attention — online-softmax fused attention (sequential-kv grid)
* rans           — interleaved K-lane rANS entropy coder (encode + decode):
                   states on the lane axis, (stream, plane) rows on
                   sublanes, serial step axis as the sequential grid;
                   byte-identical to core.entropy's numpy machine, which
                   routes big jobs here as its device engine
"""
from .ops import (  # noqa: F401
    cone_scan,
    cone_scan_segments,
    flash_attention,
    dequant_reconstruct,
    interval_stats,
    pyramid_quant,
    pyramid_reconstruct,
    rans_decode_rows,
    rans_encode_rows,
    residual_quant,
    segment_agg,
    use_interpret,
)
from . import ref  # noqa: F401
