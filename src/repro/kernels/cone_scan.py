"""Pallas TPU kernel: the SHRINK shrinking-cone scan.

Hardware adaptation (DESIGN.md §3): the paper's cone scan is a sequential,
data-dependent recurrence — on a GPU one would serialize a warp; on TPU the
idiomatic equivalent exploits two facts:

1. **The TPU grid executes sequentially**, so VMEM/SMEM scratch persists
   across grid steps.  The cone state (theta, psi_lo, psi_hi, t0, eps_seg)
   lives in VMEM scratch and is carried from one time-chunk to the next —
   no HBM round-trip for the recurrence state.
2. **Lanes give free parallelism across series.**  An IoT gateway compresses
   thousands of independent streams; each of the S lanes carries one stream,
   so every per-point update is a (1, S) vector op on the VPU.  The serial
   dimension is only T/BT grid steps × BT in-kernel iterations.

Ragged lanes (the gateway's real regime — series lengths span orders of
magnitude) ride the same kernel through the **valid-length mask path**: a
per-lane length vector freezes a lane's cone state at positions
``t >= lengths[s]``, so padding can never constrain, break, or seed a cone
and the final-span carry reflects the open segment at each lane's own end.
A lane with ``lengths[s] == T`` behaves exactly as the unmasked scan.

Outputs are dense per-point arrays (break flags + segment records at break
positions); the variable-length segment compaction (a cumsum gather) happens
in XLA outside the kernel, as does base merging on the host.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["cone_scan_pallas"]

_BIG = 3.4e38


def _cone_scan_kernel(
    x_ref,
    eps_ref,
    len_ref,  # (1, S) int32: valid samples per lane
    brk_ref,
    theta_ref,
    lo_out_ref,
    hi_out_ref,
    fin_lo_ref,
    fin_hi_ref,
    state_f_ref,  # VMEM (4, S): theta, lo, hi, eps_seg
    state_i_ref,  # VMEM (1, S) int32: t0
    *,
    block_t: int,
):
    i = pl.program_id(0)
    s = x_ref.shape[1]

    @pl.when(i == 0)
    def _init():
        v0 = x_ref[0, :]
        e0 = eps_ref[0, :]
        state_f_ref[0, :] = jnp.floor(v0 / e0) * e0
        state_f_ref[1, :] = jnp.full((s,), -_BIG, x_ref.dtype)
        state_f_ref[2, :] = jnp.full((s,), _BIG, x_ref.dtype)
        state_f_ref[3, :] = e0
        state_i_ref[0, :] = jnp.zeros((s,), jnp.int32)

    lengths = len_ref[0, :]

    def body(r, carry):
        theta, lo, hi, eps_seg, t0 = carry
        t = i * block_t + r
        v = x_ref[r, :]
        eps_t = eps_ref[r, :]
        dt = (t - t0).astype(x_ref.dtype)
        denom = jnp.maximum(dt, 1.0)
        cand_hi = (v + eps_seg - theta) / denom
        cand_lo = (v - eps_seg - theta) / denom
        # dt == 0 is the segment's own start point (only t == 0 reaches here):
        # it defines theta, not a slope constraint — matching the host scan.
        # t >= lengths is a padded position: it freezes the lane entirely.
        grow = (dt > 0) & (t < lengths)
        new_hi = jnp.where(grow, jnp.minimum(hi, cand_hi), hi)
        new_lo = jnp.where(grow, jnp.maximum(lo, cand_lo), lo)
        brk = (new_lo > new_hi) & grow
        # records of the closing segment at the break position
        lo_out_ref[r, :] = lo
        hi_out_ref[r, :] = hi
        theta_new = jnp.floor(v / eps_t) * eps_t
        theta = jnp.where(brk, theta_new, theta)
        eps_seg = jnp.where(brk, eps_t, eps_seg)
        lo = jnp.where(brk, -_BIG, new_lo)
        hi = jnp.where(brk, _BIG, new_hi)
        t0 = jnp.where(brk, t, t0)
        brk_ref[r, :] = brk.astype(jnp.int32)
        theta_ref[r, :] = theta
        return theta, lo, hi, eps_seg, t0

    carry = (
        state_f_ref[0, :],
        state_f_ref[1, :],
        state_f_ref[2, :],
        state_f_ref[3, :],
        state_i_ref[0, :],
    )
    theta, lo, hi, eps_seg, t0 = jax.lax.fori_loop(0, block_t, body, carry)
    state_f_ref[0, :] = theta
    state_f_ref[1, :] = lo
    state_f_ref[2, :] = hi
    state_f_ref[3, :] = eps_seg
    state_i_ref[0, :] = t0
    # every grid step writes; the sequential grid means the last write wins
    fin_lo_ref[0, :] = lo
    fin_hi_ref[0, :] = hi


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def cone_scan_pallas(
    x: jax.Array,
    eps_hat: jax.Array,
    lengths: jax.Array | None = None,
    block_t: int = 256,
    interpret: bool = True,
):
    """x[T, S], eps_hat[T, S] -> (brk i32, theta, psi_lo, psi_hi, fin_lo[1,S],
    fin_hi[1,S]).  Semantics identical to ref.cone_scan_ref; T % block_t == 0
    (pad with anything — the valid-length mask keeps padding inert when
    ``lengths`` marks it; without ``lengths`` pad with repeats of the last
    row).  ``lengths``: optional [S] int32 of valid samples per lane (>= 1);
    None means every lane is fully valid."""
    t, s = x.shape
    bt = min(block_t, t)
    assert t % bt == 0, f"T={t} % block_t={bt} != 0"
    if lengths is None:
        lengths = jnp.full((s,), t, jnp.int32)
    len_in = jnp.asarray(lengths, jnp.int32).reshape(1, s)
    grid = (t // bt,)
    kernel = functools.partial(_cone_scan_kernel, block_t=bt)
    brk, theta, psi_lo, psi_hi, fin_lo, fin_hi = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, s), lambda i: (i, 0)),
            pl.BlockSpec((bt, s), lambda i: (i, 0)),
            pl.BlockSpec((1, s), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bt, s), lambda i: (i, 0)),
            pl.BlockSpec((bt, s), lambda i: (i, 0)),
            pl.BlockSpec((bt, s), lambda i: (i, 0)),
            pl.BlockSpec((bt, s), lambda i: (i, 0)),
            pl.BlockSpec((1, s), lambda i: (0, 0)),
            pl.BlockSpec((1, s), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, s), jnp.int32),
            jax.ShapeDtypeStruct((t, s), x.dtype),
            jax.ShapeDtypeStruct((t, s), x.dtype),
            jax.ShapeDtypeStruct((t, s), x.dtype),
            jax.ShapeDtypeStruct((1, s), x.dtype),
            jax.ShapeDtypeStruct((1, s), x.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((4, s), x.dtype),
            pltpu.VMEM((1, s), jnp.int32),
        ],
        interpret=interpret,
    )(x, eps_hat, len_in)
    # match ref: brk[0] = 1, theta[0] = quantized origin (kernel already
    # wrote theta of the first segment at row 0 via the running state)
    brk = brk.at[0].set(1)
    return brk, theta, psi_lo, psi_hi, fin_lo, fin_hi
