"""Pallas TPU kernel: fused residual computation + quantization.

The bit-level half of SHRINK as it runs *on device*: given a per-row linear
base (theta + slope * t) over blocks of a flattened tensor, compute the
residual, quantize it to a small signed integer with step ``step``, clip to
[-qmax, qmax], and emit the quantization error (error feedback for the
gradient-compression path).  Everything is one VMEM-resident fused pass —
on TPU this is a single elementwise pipeline through the VPU with no HBM
round-trip between the subtract / scale / round / clip stages.

Tiling: rows of the block matrix map to sublanes, the in-block time axis to
lanes; the block shape is (BM, N) with N the (128-multiple) SHRINK block
length, so one grid step owns BM complete blocks and the base parameters
for a grid step are a (BM, 1) column.

Ragged tails: an optional per-row valid length masks each row past its
length — padded positions emit q = 0 (no symbols for the entropy stage) and
err = 0 (no error feedback from data that does not exist).  This is the
same valid-length mask idiom as the cone-scan kernel, applied to the
residual side so a ragged batch's padded lanes stay inert end to end.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "residual_quant_kernel",
    "residual_quant_pallas",
    "pyramid_quant_kernel",
    "pyramid_quant_pallas",
]


def residual_quant_kernel(
    x_ref, theta_ref, slope_ref, step_ref, len_ref, q_ref, err_ref, *, qmax: int
):
    x = x_ref[...]
    theta = theta_ref[...]  # (bm, 1)
    slope = slope_ref[...]  # (bm, 1)
    step = step_ref[...]  # (bm, 1)
    n = x.shape[-1]
    t = jax.lax.broadcasted_iota(x.dtype, (1, n), 1)
    pred = theta + slope * t
    r = x - pred
    inv = 1.0 / step
    q = jnp.clip(jnp.round(r * inv), -qmax, qmax)
    valid = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1) < len_ref[...]  # (bm, 1)
    q_ref[...] = jnp.where(valid, q, 0.0).astype(jnp.int32)
    err_ref[...] = jnp.where(valid, r - q * step, 0.0)


def pyramid_quant_kernel(
    x_ref, theta_ref, slope_ref, steps_ref, len_ref, q_ref, err_ref, *, qmax: int,
    num_layers: int,
):
    """Fused multi-layer refinement quantization: one VMEM-resident pass
    computes the base prediction once and runs the whole layer ladder on
    the residual without ever spilling the intermediate error to HBM —
    layer l quantizes what layers 0..l-1 left behind (the device analogue
    of ``core.residuals.quantize_pyramid``'s ladder).  The layer loop is a
    static python loop, so the VPU sees one straight-line elementwise
    pipeline of L round/clip/subtract stages."""
    x = x_ref[...]
    theta = theta_ref[...]  # (bm, 1)
    slope = slope_ref[...]  # (bm, 1)
    n = x.shape[-1]
    t = jax.lax.broadcasted_iota(x.dtype, (1, n), 1)
    pred = theta + slope * t
    e = x - pred
    valid = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1) < len_ref[...]  # (bm, 1)
    for l in range(num_layers):
        step = steps_ref[0, l]
        q = jnp.clip(jnp.round(e / step), -qmax, qmax)
        e = e - q * step
        q_ref[l, ...] = jnp.where(valid, q, 0.0).astype(jnp.int32)
    err_ref[...] = jnp.where(valid, e, 0.0)


@functools.partial(jax.jit, static_argnames=("qmax", "block_m", "interpret"))
def pyramid_quant_pallas(
    x: jax.Array,
    theta: jax.Array,
    slope: jax.Array,
    steps: jax.Array,
    lengths: jax.Array | None = None,
    qmax: int = 127,
    block_m: int = 8,
    interpret: bool = True,
):
    """x[M, N]; theta/slope[M, 1]; steps[L] (coarse -> fine).  Returns
    (qs int32 [L, M, N], err [M, N]): the per-layer refinement symbols and
    the error left after the finest layer.  ``lengths`` [M] masks ragged
    row tails (all layers' q and err forced to 0 past each row's
    length)."""
    m, n = x.shape
    num_layers = int(steps.shape[0])
    if lengths is None:
        lengths = jnp.full((m,), n, jnp.int32)
    len_in = jnp.asarray(lengths, jnp.int32).reshape(m, 1)
    steps_in = jnp.asarray(steps, x.dtype).reshape(1, num_layers)
    bm = min(block_m, m)
    grid = (pl.cdiv(m, bm),)
    kernel = functools.partial(pyramid_quant_kernel, qmax=qmax, num_layers=num_layers)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, num_layers), lambda i: (0, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((num_layers, bm, n), lambda i: (0, i, 0)),
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_layers, m, n), jnp.int32),
            jax.ShapeDtypeStruct((m, n), x.dtype),
        ],
        interpret=interpret,
    )(x, theta, slope, steps_in, len_in)


@functools.partial(jax.jit, static_argnames=("qmax", "block_m", "interpret"))
def residual_quant_pallas(
    x: jax.Array,
    theta: jax.Array,
    slope: jax.Array,
    step: jax.Array,
    lengths: jax.Array | None = None,
    qmax: int = 127,
    block_m: int = 8,
    interpret: bool = True,
):
    """x[M, N]; theta/slope/step[M, 1].  Returns (q int32[M,N], err[M,N]).
    ``lengths`` [M] marks ragged row tails (q/err forced to 0 past each
    row's length); None means every row is fully valid."""
    m, n = x.shape
    if lengths is None:
        lengths = jnp.full((m,), n, jnp.int32)
    len_in = jnp.asarray(lengths, jnp.int32).reshape(m, 1)
    bm = min(block_m, m)
    grid = (pl.cdiv(m, bm),)
    kernel = functools.partial(residual_quant_kernel, qmax=qmax)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.int32),
            jax.ShapeDtypeStruct((m, n), x.dtype),
        ],
        interpret=interpret,
    )(x, theta, slope, step, len_in)
