"""Pallas TPU kernel: per-window min/max reduction (Alg. 2's interval stats).

Computes the local value range of every length-W window of S independent
series — the input to the adaptive threshold of Eq. 4 (beta = delta_local /
delta_global).  Time is the sublane axis, series are lanes; each grid step
reduces one (W, S-tile) window in VMEM.  On TPU this is a strided VPU
reduction with no cross-lane traffic (each lane is its own series).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["interval_stats_kernel", "interval_stats_pallas"]


def interval_stats_kernel(x_ref, min_ref, max_ref):
    x = x_ref[...]  # (W, bs)
    min_ref[...] = x.min(axis=0, keepdims=True)
    max_ref[...] = x.max(axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("window", "block_s", "interpret"))
def interval_stats_pallas(
    x: jax.Array,
    window: int,
    block_s: int = 128,
    interpret: bool = True,
):
    """x[T, S] -> (mins[T//W, S], maxs[T//W, S]).  T % window == 0."""
    t, s = x.shape
    assert t % window == 0, f"T={t} % window={window} != 0"
    nw = t // window
    bs = min(block_s, s)
    grid = (nw, pl.cdiv(s, bs))
    return pl.pallas_call(
        interval_stats_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((window, bs), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((1, bs), lambda i, j: (i, j)),
            pl.BlockSpec((1, bs), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nw, s), x.dtype),
            jax.ShapeDtypeStruct((nw, s), x.dtype),
        ],
        interpret=interpret,
    )(x)
