import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. constructs ShapeDtypeStruct stand-ins for params, optimizer state,
     batch and caches (zero allocation),
  3. jit-lowers the train / prefill / decode step with full in/out
     shardings, compiles it,
  4. records memory_analysis(), cost_analysis() and the per-type collective
     byte totals parsed from the compiled HLO,
  5. writes artifacts/dryrun/<arch>__<shape>__<mesh>[__comp].json.

Usage:
    python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    python -m repro.launch.dryrun --all                  # single-pod sweep
    python -m repro.launch.dryrun --all --multi-pod
    python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k \
        --multi-pod --compressed     # SHRINK cross-pod collective
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, SHAPES, cells_for, get_config
from ..models import build_model
from ..parallel.partition import param_specs
from ..training.optimizer import AdamWConfig, adamw_init
from ..training.train_step import (
    batch_specs,
    cache_specs,
    make_compressed_train_step,
    make_decode_step,
    make_ef_state,
    make_prefill_step,
    make_train_step,
)
from .hlo_analysis import analyze_hlo, compiled_cost_dict
from .mesh import HW, make_production_mesh

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _shardings_for(tree_shapes, spec_tree, mesh):
    return jax.tree.map(
        lambda sds, spec: NamedSharding(mesh, spec),
        tree_shapes,
        spec_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    compressed: bool = False,
    overrides: dict | None = None,
    tag: str = "",
) -> dict:
    import dataclasses as _dc

    cfg = get_config(arch)
    if overrides:
        model_ov = {k: v for k, v in overrides.items() if not k.startswith("comp_")}
        if model_ov:
            cfg = _dc.replace(cfg, **model_ov)
    model = build_model(cfg)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    t0 = time.time()

    params_shapes = model.init_shapes()
    # compressed path: vocab-sharded-gather partitioner bug workaround
    p_spec = param_specs(params_shapes, cfg, mesh, vocab_dim_sharded=not compressed)
    p_shard = _shardings_for(params_shapes, p_spec, mesh)
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    result: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "compressed": compressed,
        "devices": n_dev,
        "kind": shape.kind,
    }

    if shape.kind == "train":
        batch_shapes = model.input_specs(shape)
        b_spec = batch_specs(batch_shapes, mesh, batch_axes)
        b_shard = _shardings_for(batch_shapes, b_spec, mesh)
        opt_shapes = jax.eval_shape(adamw_init, params_shapes)
        o_spec = {"m": p_spec, "v": p_spec, "step": P()}
        o_shard = jax.tree.map(
            lambda sds, spec: NamedSharding(mesh, spec), opt_shapes, o_spec,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )
        if compressed:
            # The SHRINK cross-pod exchange stage (DCN step of a multi-slice
            # run), lowered standalone: grads arrive with a leading pod dim.
            from ..training.grad_compress import GradCompressConfig, make_crosspod_exchange

            n_pods = mesh.shape.get("pod", 1)
            grads_stacked = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((n_pods, *s.shape), s.dtype), params_shapes
            )
            gs_spec = jax.tree.map(lambda s: P("pod", *s), p_spec,
                                   is_leaf=lambda x: isinstance(x, P))
            gs_shard = _shardings_for(grads_stacked, gs_spec, mesh)
            ef_shapes = jax.eval_shape(make_ef_state, params_shapes)
            ef_shard = _shardings_for(ef_shapes, p_spec, mesh)
            comp_kw = {}
            if overrides:
                for k in ("bits", "block"):
                    if f"comp_{k}" in overrides:
                        comp_kw[k] = overrides[f"comp_{k}"]
            out = {}
            for variant, ccfg in (("compressed", GradCompressConfig(**comp_kw)), ("plain_psum", None)):
                step = make_crosspod_exchange(mesh, ccfg, p_spec)
                jitted = jax.jit(step, in_shardings=(gs_shard, ef_shard))
                lowered = jitted.lower(grads_stacked, ef_shapes)
                compiled = lowered.compile()
                hc = analyze_hlo(compiled.as_text())
                out[variant] = {
                    "collective_bytes": hc.collective_bytes,
                    "by_type": hc.collective_by_type,
                    "collective_s": hc.collective_bytes / HW.ICI_BW,
                }
            from ..training.grad_compress import compression_wire_bytes

            comp_b, raw_b = compression_wire_bytes(
                jax.tree.leaves(params_shapes), GradCompressConfig(**comp_kw)
            )
            result.update(
                exchange=out,
                analytic_wire={"compressed_bytes": comp_b, "f32_bytes": raw_b,
                               "ratio": raw_b / max(comp_b, 1)},
                seconds={"lower": round(time.time() - t0, 1), "compile": 0.0},
                roofline={
                    "compute_s": 0.0,
                    "memory_s": 0.0,
                    "collective_s": out["compressed"]["collective_s"],
                    "dominant": "collective",
                    "model_flops_total": 0,
                    "useful_flops_ratio": None,
                },
                tag=tag,
            )
            return result
        else:
            step = make_train_step(model, mesh)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_shapes, opt_shapes, batch_shapes)
    elif shape.kind == "prefill":
        batch_shapes = model.input_specs(shape)
        b_spec = batch_specs(batch_shapes, mesh, batch_axes)
        b_shard = _shardings_for(batch_shapes, b_spec, mesh)
        step = make_prefill_step(model, mesh)
        jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
        lowered = jitted.lower(params_shapes, batch_shapes)
    else:  # decode
        specs = model.input_specs(shape)
        tok_shapes, cache_shapes = specs["tokens"], specs["caches"]
        c_spec = cache_specs(cache_shapes, mesh, batch_axes)
        c_shard = _shardings_for(cache_shapes, c_spec, mesh)
        tok_shard = NamedSharding(
            mesh, P(batch_axes if shape.global_batch % n_dev == 0 or
                    shape.global_batch % (mesh.shape.get("data", 1) *
                                          mesh.shape.get("pod", 1)) == 0 else None, None)
        )
        if shape.global_batch == 1:
            tok_shard = NamedSharding(mesh, P(None, None))
        step = make_decode_step(model, mesh)
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, tok_shard, c_shard, NamedSharding(mesh, P())),
            donate_argnums=(2,),
        )
        lowered = jitted.lower(
            params_shapes, specs["tokens"], cache_shapes, specs["cache_index"]
        )

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    cost = compiled_cost_dict(compiled) or {}
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        mem_d = {"error": str(e)}

    hlo = compiled.as_text()
    hc = analyze_hlo(hlo)  # while-trip-corrected per-device cost model

    flops_dev = hc.flops
    bytes_dev = hc.bytes
    compute_s = flops_dev / HW.PEAK_BF16_FLOPS
    memory_s = bytes_dev / HW.HBM_BW
    coll_s = hc.collective_bytes / HW.ICI_BW

    mult = 6 if shape.kind == "train" else 2
    if cfg.family == "encdec" and shape.kind != "decode":
        # split enc/dec params over their token streams (the coarse 6*N*D
        # over-counts: enc tokens never touch dec params and vice versa)
        s_enc = int(shape.seq_len * cfg.audio_frames_ratio)
        s_dec = shape.seq_len - s_enc
        d, ff = cfg.d_model, cfg.d_ff
        hd = cfg.resolved_head_dim
        attn = d * cfg.n_heads * hd * 2 + 2 * d * cfg.n_kv_heads * hd
        per_layer = attn + 3 * d * ff
        n_enc = cfg.n_enc_layers * per_layer
        n_dec = cfg.n_layers * (per_layer + attn) + d * cfg.padded_vocab
        model_flops_total = mult * shape.global_batch * (s_enc * n_enc + s_dec * n_dec)
    else:
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
        n_active = cfg.active_param_count() - cfg.padded_vocab * cfg.d_model
        model_flops_total = mult * n_active * tokens
    model_flops_dev = model_flops_total / n_dev

    result.update(
        seconds={"lower": round(t_lower, 1), "compile": round(t_compile, 1)},
        cost={
            "flops_per_device": flops_dev,
            "bytes_per_device": bytes_dev,
            "raw_cost_analysis": {
                "flops": float(cost.get("flops", 0.0)),
                "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            },
            "dot_count": hc.dot_count,
            "while_trips": hc.while_trips,
        },
        memory=mem_d,
        collectives={
            "total_bytes": hc.collective_bytes,
            "by_type": hc.collective_by_type,
        },
        roofline={
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": coll_s,
            "dominant": max(
                ("compute", compute_s), ("memory", memory_s), ("collective", coll_s),
                key=lambda kv: kv[1],
            )[0],
            "model_flops_total": model_flops_total,
            "useful_flops_ratio": (model_flops_dev / flops_dev) if flops_dev else None,
        },
        tag=tag,
    )
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--compressed", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument(
        "--override", action="append", default=[],
        help="ModelConfig field override, e.g. --override rwkv_chunked=64",
    )
    ap.add_argument("--out", default=str(ARTIFACTS))
    args = ap.parse_args()

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        if v in ("True", "False"):
            overrides[k] = v == "True"
        else:
            try:
                overrides[k] = int(v)
            except ValueError:
                overrides[k] = v

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    cells: list[tuple[str, str]] = []
    if args.all:
        for arch, cfg in ARCHS.items():
            for shp in cells_for(cfg):
                cells.append((arch, shp))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells.append((args.arch, args.shape))

    failures = 0
    for arch, shp in cells:
        mesh_tag = "2x16x16" if args.multi_pod else "16x16"
        suffix = "__comp" if args.compressed else ""
        suffix += f"__{args.tag}" if args.tag else ""
        fname = outdir / f"{arch}__{shp}__{mesh_tag}{suffix}.json"
        if fname.exists():
            print(f"[skip] {fname.name} exists")
            continue
        print(f"[dryrun] {arch} x {shp} x {mesh_tag}{suffix} ...", flush=True)
        try:
            res = run_cell(arch, shp, multi_pod=args.multi_pod,
                           compressed=args.compressed, overrides=overrides,
                           tag=args.tag)
            fname.write_text(json.dumps(res, indent=2))
            r = res["roofline"]
            print(
                f"  ok: lower {res['seconds']['lower']}s compile {res['seconds']['compile']}s | "
                f"compute {r['compute_s']:.3e}s memory {r['memory_s']:.3e}s "
                f"collective {r['collective_s']:.3e}s -> {r['dominant']}",
                flush=True,
            )
        except Exception:
            failures += 1
            err = traceback.format_exc()
            (outdir / f"FAILED__{arch}__{shp}__{mesh_tag}{suffix}.txt").write_text(err)
            print(f"  FAILED: {err.splitlines()[-1]}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
