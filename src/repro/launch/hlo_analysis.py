"""Post-SPMD HLO cost model with while-loop trip-count accounting.

``compiled.cost_analysis()`` counts while bodies ONCE (verified in
tests/test_roofline.py), which under-counts scanned-layer models by the
scan length.  This module re-derives the three roofline terms from the
compiled per-device HLO text:

* **flops**: every ``dot`` = 2 * prod(result dims) * prod(lhs contracting
  dims), multiplied by the computation's execution count (whiles multiply
  by their trip count, parsed from the loop condition's s32 constant;
  nested whiles cascade).  Elementwise flops are ignored (dots dominate).
* **bytes**: per top-level instruction, operand bytes (reads) + result
  bytes (write), skipping pure plumbing ops (tuple/gte/parameter/constant/
  bitcast) — a fusion-aware HBM-traffic estimate since fused subgraphs
  appear as single instructions.
* **collective bytes**: result-shape bytes per collective op (x2 for
  all-reduce: ring send+recv), with the same multipliers.

All approximations are documented in EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

__all__ = ["analyze_hlo", "compiled_cost_dict", "HloCost"]


def compiled_cost_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` across jax versions: old releases
    return a one-element list of dicts (per device), new ones the dict
    itself.  Always returns the dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        return ca[0] if ca else {}
    return ca

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z]\d*[a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")
_SKIP_BYTES_OPS = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "while", "conditional", "call",
}


def _shape_dims(shape_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        dd = [int(x) for x in dims.split(",") if x] if dims else []
        out.append((dt, dd))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(shape_str):
        total += math.prod(dims) * _DTYPE_BYTES[dt] if dims else _DTYPE_BYTES[dt]
    return total


def _opcode_of(rhs: str) -> tuple[str, str]:
    """(type_str, opcode) from an instruction RHS."""
    s = rhs.strip()
    if s.startswith("("):  # tuple type: find matching paren
        depth = 0
        for i, ch in enumerate(s):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    type_str = s[: i + 1]
                    rest = s[i + 1 :]
                    break
        else:
            return s, ""
    else:
        m = re.match(r"^([\w\[\],{}:*\/]+)\s+(.*)$", s)
        if not m:
            return s, ""
        type_str, rest = m.group(1), m.group(2)
    op = re.match(r"\s*([\w\-]+)\(", rest)
    return type_str, (op.group(1) if op else "")


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes: float
    collective_bytes: float
    collective_by_type: dict
    dot_count: int
    while_trips: dict


def analyze_hlo(hlo_text: str) -> HloCost:
    # ---- split into computations
    comps: dict[str, list[str]] = {}
    sigs: dict[str, str] = {}
    cur = None
    entry = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR_RE.match(line.strip())
        if hdr and line.strip().endswith("{"):
            cur = hdr.group(1)
            comps[cur] = []
            sigs[cur] = line
            if line.strip().startswith("ENTRY"):
                entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    if entry is None:
        entry = max(comps, key=lambda c: len(comps[c])) if comps else None

    # ---- per-computation symbol tables + parsed instructions
    parsed: dict[str, list[tuple[str, str, str, str]]] = {}
    symtab: dict[str, dict[str, str]] = defaultdict(dict)
    for cname, lines in comps.items():
        # parameters from the signature line
        for pm in re.finditer(r"(\w[\w.\-]*):\s*([^,()]+(?:\([^)]*\))?)", sigs[cname]):
            symtab[cname][pm.group(1)] = pm.group(2)
        out = []
        for line in lines:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, rhs = m.group(1), m.group(2)
            type_str, opcode = _opcode_of(rhs)
            symtab[cname][name] = type_str
            out.append((name, type_str, opcode, rhs))
        parsed[cname] = out

    # ---- while trip counts: max s32 constant in the condition computation
    def cond_trip(cond_name: str) -> int:
        best = 1
        for _, _, opcode, rhs in parsed.get(cond_name, []):
            if opcode == "constant":
                m = re.search(r"constant\((\d+)\)", rhs)
                if m:
                    best = max(best, int(m.group(1)))
        return best

    # ---- multipliers via DFS over the call graph
    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    while_trips: dict[str, int] = {}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        m_here = mult[cname]
        for _, _, opcode, rhs in parsed.get(cname, []):
            children: list[tuple[str, float]] = []
            if opcode == "while":
                mb = re.search(r"body=%?([\w.\-]+)", rhs)
                mc = re.search(r"condition=%?([\w.\-]+)", rhs)
                trip = cond_trip(mc.group(1)) if mc else 1
                if mb:
                    while_trips[mb.group(1)] = trip
                    children.append((mb.group(1), m_here * trip))
                if mc:
                    children.append((mc.group(1), m_here * trip))
            elif opcode in ("call", "fusion", "reduce", "map", "scatter", "sort", "reduce-window", "custom-call", "conditional"):
                for mm in re.finditer(r"(?:to_apply|calls)=%?([\w.\-]+)", rhs):
                    children.append((mm.group(1), m_here))
                for mm in re.finditer(r"(?:branch_computations)=\{([^}]*)\}", rhs):
                    for b in _OPERAND_RE.findall(mm.group(1)):
                        children.append((b, m_here))
            for child, cm in children:
                if child in comps:
                    mult[child] += cm
                    if child not in seen:
                        seen.add(child)
                        order.append(child)

    # ---- classify inner computations whose IO is accounted by their caller
    # (fusion bodies, map/reduce/scatter/sort wrappers).  call/while/
    # conditional regions contain real top-level code and stay counted.
    inline_comps: set[str] = set()
    for cname, instrs in parsed.items():
        for _, _, opcode, rhs in instrs:
            if opcode in ("call", "while", "conditional"):
                continue
            for mm in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)", rhs):
                inline_comps.add(mm.group(1))

    def _operands(rhs: str) -> list[str]:
        arg_str = rhs.split("(", 1)[1] if "(" in rhs else ""
        arg_str = arg_str.split("),", 1)[0]
        return _OPERAND_RE.findall(arg_str)

    def _fusion_read_bytes(fcomp: str, operand_shapes: list[str]) -> float:
        """Effective reads of a fused computation: a parameter consumed only
        through dynamic-slice reads just the slices, else the full operand."""
        instrs = parsed.get(fcomp, [])
        # parameter name -> operand index
        param_idx: dict[str, int] = {}
        for name, _, opcode, rhs in instrs:
            if opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", rhs)
                if m:
                    param_idx[name] = int(m.group(1))
        reads = 0.0
        for pname, idx in param_idx.items():
            if idx >= len(operand_shapes):
                continue
            full = _shape_bytes(operand_shapes[idx])
            slice_bytes = 0.0
            only_ds = True
            used = False
            for name, t, opcode, rhs in instrs:
                if opcode == "parameter":
                    continue
                ops = _OPERAND_RE.findall(rhs)
                if pname in ops:
                    used = True
                    if opcode == "dynamic-slice" and ops and ops[0] == pname:
                        slice_bytes += _shape_bytes(t)
                    elif opcode == "dynamic-update-slice" and ops and ops[0] == pname:
                        upd = ops[1] if len(ops) > 1 else None
                        # in-place: reads/writes only the update extent
                        slice_bytes += 0.0
                    else:
                        only_ds = False
            if not used:
                continue
            reads += slice_bytes if only_ds else full
        return reads

    flops = 0.0
    bytes_total = 0.0
    coll_bytes = 0.0
    coll_by_type: dict[str, float] = defaultdict(float)
    dot_count = 0

    for cname, instrs in parsed.items():
        m_here = mult.get(cname, 0.0)
        if m_here == 0.0 or cname in inline_comps:
            continue
        tab = symtab[cname]
        for name, type_str, opcode, rhs in instrs:
            if opcode == "dot":
                ops = _operands(rhs)
                lhs_shape = tab.get(ops[0], "") if ops else ""
                lc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
                contract = 1
                if lc and lhs_shape:
                    dims = _shape_dims(lhs_shape)
                    if dims:
                        _, dd = dims[0]
                        for idx in (int(x) for x in lc.group(1).split(",") if x):
                            if idx < len(dd):
                                contract *= dd[idx]
                result_elems = 0
                for dt, dd in _shape_dims(type_str):
                    result_elems += math.prod(dd) if dd else 1
                flops += m_here * 2.0 * result_elems * contract
                dot_count += 1
            for ck in _COLLECTIVES:
                if opcode == ck or opcode.startswith(ck + "-"):
                    b = _shape_bytes(type_str)
                    factor = 2.0 if ck == "all-reduce" else 1.0
                    coll_bytes += m_here * b * factor
                    coll_by_type[ck] += m_here * b * factor
                    break
            if opcode in _SKIP_BYTES_OPS or not opcode:
                continue
            b_out = _shape_bytes(type_str)
            if opcode == "dynamic-slice":
                bytes_total += m_here * 2 * b_out
                continue
            if opcode == "dynamic-update-slice":
                ops = _operands(rhs)
                upd = tab.get(ops[1], "") if len(ops) > 1 else ""
                bytes_total += m_here * 2 * _shape_bytes(upd)
                continue
            if opcode == "fusion":
                mcall = re.search(r"calls=%?([\w.\-]+)", rhs)
                if mcall:
                    op_shapes = [tab.get(o, "") for o in _operands(rhs)]
                    bytes_total += m_here * (b_out + _fusion_read_bytes(mcall.group(1), op_shapes))
                    continue
            b_in = 0
            for opn in _operands(rhs):
                if opn in tab:
                    b_in += _shape_bytes(tab[opn])
            bytes_total += m_here * (b_out + b_in)

    return HloCost(
        flops=flops,
        bytes=bytes_total,
        collective_bytes=coll_bytes,
        collective_by_type=dict(coll_by_type),
        dot_count=dot_count,
        while_trips=while_trips,
    )
