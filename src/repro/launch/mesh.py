"""Production mesh construction.

Single pod:  (16, 16)      axes ("data", "model")       = 256 chips (v5e pod)
Multi-pod:   (2, 16, 16)   axes ("pod", "data", "model") = 512 chips

Defined as a FUNCTION so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS for 512 host devices before any jax
import; smoke tests see 1 device).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "HW"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many devices the process has (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))


class HW:
    """TPU v5e-like hardware model for the roofline (per chip)."""

    PEAK_BF16_FLOPS = 197e12  # FLOP/s
    HBM_BW = 819e9  # B/s
    ICI_BW = 50e9  # B/s per link
    HBM_BYTES = 16 * 1024**3
