"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --steps 100 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt [--reduced]

On this container it runs the reduced config on the local device; on a real
cluster the same entry point builds the production mesh and shards the
assigned config (--production, exercised shape-only by dryrun.py here).
Fault tolerance: resumes from the newest checkpoint in --ckpt-dir; data is
a pure function of the step index, so restarts are deterministic.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import SHAPES, get_config, reduced_config
from ..data.pipeline import TokenPipeline
from ..models import build_model
from ..training.fault_tolerance import TrainingRunner
from ..training.optimizer import AdamWConfig, adamw_init
from ..training.train_step import make_train_step
from .mesh import make_local_mesh, make_production_mesh


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-codec", default="zstd")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--production", action="store_true",
                    help="16x16 production mesh (real cluster)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    model = build_model(cfg)
    mesh = make_production_mesh() if args.production else make_local_mesh(1, 1)

    params = model.init(jax.random.PRNGKey(0))
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n/1e6:.1f}M mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                          decay_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, mesh, opt_cfg))
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, batch=args.batch, seq_len=args.seq)

    def runner_step(state, batch):
        p, o, metrics = step_fn(state["params"], state["opt"], batch)
        return {"params": p, "opt": o}, metrics

    def data_fn(step):
        return jax.tree.map(jnp.asarray, pipe.batch_at(step))

    runner = TrainingRunner(
        runner_step, data_fn, {"params": params, "opt": adamw_init(params)},
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every, codec=args.ckpt_codec,
    )
    hist = runner.run(args.steps)
    for h in hist[:: max(1, len(hist) // 12)]:
        print(f"step {h['step']:5d}  loss {h['loss']:.4f}  gnorm {h['grad_norm']:.3f}")
    print(f"final loss {hist[-1]['loss']:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
