"""Launchers: mesh construction, dry-run driver, train/serve entry points."""
from .mesh import HW, make_local_mesh, make_production_mesh  # noqa: F401
