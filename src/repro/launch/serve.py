"""Serving launcher: continuous batching over a reduced or production
model, batched range-query decode over a streamed SHRINK container, or
ragged multi-sensor gateway ingest through the admission scheduler.

    # LLM decode loop (continuous batching)
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
        --requests 16 --slots 8 --max-new 8

    # time-series range queries against a freshly streamed SHRKS container
    PYTHONPATH=src python -m repro.launch.serve --mode range \
        --series 8 --points 65536 --frame-len 8192 --queries 256

    # ragged gateway ingest: heterogeneous-rate sensors -> RaggedBatcher
    # (size/deadline admission, bucketed ragged compress_batch) -> SHRKS
    PYTHONPATH=src python -m repro.launch.serve --mode ingest \
        --series 64 --ticks 200 --flush-samples 131072

    # compressed-domain analytics: aggregates / threshold counts / top-k
    # straight off the container, differentially checked against decode
    PYTHONPATH=src python -m repro.launch.serve --mode analytics \
        --series 8 --points 65536 --frame-len 8192 --queries 256

    # chaos campaign: seeded fault injection (byte flips, truncation, CRC
    # smash, frame drops, transient decode failures) against the
    # fault-tolerant gateway; every answer differentially checked, exits
    # non-zero on ANY silent corruption
    PYTHONPATH=src python -m repro.launch.serve --mode chaos \
        --series 4 --points 16384 --frame-len 2048 --fault-rate 0.01
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def _serve_model(args) -> int:
    import jax

    from ..configs import get_config, reduced_config
    from ..models import build_model
    from ..serving import ContinuousBatcher, Request

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    decode = jax.jit(model.decode_step)
    rng = np.random.default_rng(0)

    batcher = ContinuousBatcher(
        decode_fn=lambda t, c, i: decode(params, t, c, i),
        make_caches=lambda: model.make_decode_caches(args.slots, args.max_seq),
        n_slots=args.slots,
        eos_token=-1,
    )
    for rid in range(args.requests):
        batcher.submit(Request(
            rid=rid,
            prompt=rng.integers(1, cfg.vocab_size, size=int(rng.integers(4, 16))).astype(np.int32),
            max_new_tokens=args.max_new,
        ))
    t0 = time.perf_counter()
    done = batcher.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.prompt) + len(r.generated) for r in done)
    print(f"served {len(done)} requests, {toks} tokens, {dt:.1f}s ({toks/dt:.1f} tok/s)")
    return 0


def _serve_range(args) -> int:
    """Stream synthetic gateway sensors into a SHRKS container, then serve
    random range queries through the frame-cached batcher."""
    from ..core import BYTES_PER_ROW, ShrinkConfig, ShrinkStreamCodec
    from ..serving import RangeQuery, RangeQueryBatcher

    rng = np.random.default_rng(0)
    s, n = args.series, args.points
    v = np.cumsum(rng.standard_normal((s, n)) * 0.05, axis=1)
    v += rng.standard_normal((s, n)) * 0.02
    v = np.round(v, 4)
    vmin, vmax = float(v.min()), float(v.max())
    cfg = ShrinkConfig(eps_b=0.05 * max(vmax - vmin, 1e-12), lam=1e-4)
    eps = args.eps * (vmax - vmin)

    codec = ShrinkStreamCodec(
        cfg, eps_targets=[eps], backend="rans",
        value_range=(vmin, vmax), frame_len=args.frame_len,
    )
    t0 = time.perf_counter()
    for c0 in range(0, n, args.chunk):  # interleaved chunk-at-a-time ingest
        for sid in range(s):
            codec.ingest(v[sid, c0 : c0 + args.chunk], series_id=sid)
    blob = codec.finalize()
    dt_ingest = time.perf_counter() - t0
    mb = s * n * BYTES_PER_ROW / 1e6
    st = codec.stats()
    print(
        f"ingested {s} series x {n} samples in {dt_ingest:.2f}s "
        f"({mb/dt_ingest:.1f} MB/s), {st['frames']} frames, "
        f"CR={s*n*BYTES_PER_ROW/len(blob):.1f}, kb={st['kb']}"
    )

    batcher = RangeQueryBatcher(blob, cache_frames=args.cache_frames)
    qrng = np.random.default_rng(1)
    for qid in range(args.queries):
        sid = int(qrng.integers(0, s))
        t_lo = int(qrng.integers(0, n - 16))
        t_hi = int(min(n, t_lo + qrng.integers(16, args.frame_len)))
        batcher.submit(RangeQuery(qid=qid, series_id=sid, t0=t_lo, t1=t_hi, eps=eps))
    t0 = time.perf_counter()
    done = batcher.run()
    dt_q = time.perf_counter() - t0
    worst = 0.0
    for q in done:
        assert q.error is None, q.error
        worst = max(worst, float(np.abs(q.result - v[q.series_id, q.t0 : q.t1]).max()))
    bs = batcher.stats
    print(
        f"served {len(done)} range queries in {dt_q:.3f}s "
        f"({len(done)/dt_q:.0f} q/s), frames decoded={bs['frames_decoded']} "
        f"cache hits={bs['frame_hits']}, max |err|={worst:.2e} (eps={eps:.2e})"
    )
    return 0 if worst <= eps * (1 + 1e-9) else 1


def _serve_analytics(args) -> int:
    """Compressed-domain analytics over a freshly streamed container: a
    mixed workload of aggregates (random ranges and resolutions),
    threshold counts at the exact tier, and top-k segment queries —
    every answer differentially verified against the decode-then-numpy
    oracle before it counts."""
    from ..analytics import AnalyticsEngine
    from ..core import BYTES_PER_ROW, ShrinkConfig, ShrinkStreamCodec
    from ..serving import RangeQueryBatcher

    rng = np.random.default_rng(0)
    s, n = args.series, args.points
    v = np.cumsum(rng.standard_normal((s, n)) * 0.05, axis=1)
    v += rng.standard_normal((s, n)) * 0.02
    v = np.round(v, 4)
    vmin, vmax = float(v.min()), float(v.max())
    vrng = max(vmax - vmin, 1e-12)
    cfg = ShrinkConfig(eps_b=0.02 * vrng, lam=1e-4)
    tiers = [1e-2 * vrng, 1e-3 * vrng, 0.0]

    codec = ShrinkStreamCodec(
        cfg, eps_targets=tiers, decimals=4, backend="rans",
        value_range=(vmin, vmax), frame_len=args.frame_len,
    )
    for sid in range(s):
        codec.ingest(v[sid], series_id=sid)
    blob = codec.finalize()
    print(
        f"streamed {s} series x {n} samples into {codec.stats()['frames']} frames, "
        f"CR={s * n * BYTES_PER_ROW / len(blob):.1f}"
    )

    eng = AnalyticsEngine(RangeQueryBatcher(blob, cache_frames=args.cache_frames))
    qrng = np.random.default_rng(1)
    ops = ["min", "max", "sum", "mean", "stddev"]
    checked = 0
    t0 = time.perf_counter()
    for qid in range(args.queries):
        sid = int(qrng.integers(0, s))
        lo = int(qrng.integers(0, n - 16))
        hi = int(min(n, lo + qrng.integers(16, 4 * args.frame_len)))
        sl = v[sid, lo:hi]
        kind = qid % 3
        if kind == 0:  # zero-decode sketch aggregate off the segments
            op = ops[qid % len(ops)]
            ans = eng.aggregate(sid, op, lo, hi, eps=None)
        elif kind == 1:  # tiered aggregate (refine loop through the LRU)
            op = ops[qid % len(ops)]
            ans = eng.aggregate(sid, op, lo, hi, eps=tiers[qid % len(tiers)])
        else:  # exact threshold count: refine only straddling frames
            c = float(qrng.uniform(sl.min(), sl.max() + 1e-9))
            ans = eng.count_where(sid, "gt", c, lo, hi, eps=0.0)
        truth = {
            "min": sl.min, "max": sl.max, "sum": sl.sum, "mean": sl.mean,
            "stddev": sl.std,
        }[op]() if kind != 2 else float((sl > c).sum())
        assert ans.lo - 1e-9 <= truth <= ans.hi + 1e-9, (qid, ans, truth)
        if kind == 2:
            assert ans.exact
        checked += 1
    dt = time.perf_counter() - t0
    st = eng.stats
    top = eng.topk_segments(0, k=3, by="length")
    print(
        f"answered {checked} verified queries in {dt:.3f}s ({checked / dt:.0f} q/s): "
        f"{st['segment_frames']} segment-domain frames, "
        f"{st['frames_skipped']} skipped, {st['frames_refined']} refined, "
        f"{st['layers_paid']} layers paid "
        f"(serving LRU hits={eng.batcher.stats['frame_hits']})"
    )
    print(f"top-3 longest segments of series 0: {[(r['t0'], r['length']) for r in top]}")
    return 0


def _serve_ingest(args) -> int:
    """Ragged gateway simulation: sensors publish at rates spanning orders
    of magnitude; every tick delivers one chunk per sensor into the
    RaggedBatcher, whose size/deadline admission policy decides when the
    pending ragged batch compresses into SHRKS frames.  Ends with a
    correctness sweep (random range decodes against the raw data)."""
    from ..core import BYTES_PER_ROW, ShrinkConfig
    from ..core.streaming import decode_range
    from ..data.synthetic import ragged_sensor_traffic
    from ..serving import RaggedBatcher

    s = args.series
    traffic = ragged_sensor_traffic(s, args.ticks, seed=0)
    history: dict[int, list[np.ndarray]] = {i: [] for i in range(s)}

    cfg = ShrinkConfig(eps_b=0.4, lam=1e-4)
    eps = args.eps * 8.0  # value walks live in roughly [-4, 4]
    batcher = RaggedBatcher(
        cfg, eps_targets=[eps], backend="rans",
        flush_samples=args.flush_samples,
        flush_deadline_s=args.flush_deadline,
        max_buckets=args.buckets,
    )
    t0 = time.perf_counter()
    frames = 0
    for tick in traffic:
        for sid, chunk in tick:
            history[sid].append(chunk)
            frames += len(batcher.submit(sid, chunk))
        frames += len(batcher.poll())
    blob = batcher.finalize()
    dt = time.perf_counter() - t0
    st = batcher.stats()
    mb = st["samples_ingested"] * BYTES_PER_ROW / 1e6
    print(
        f"ingested {st['samples_ingested']:,} samples from {st['series']} sensors "
        f"in {dt:.2f}s ({mb/dt:.1f} MB/s), {st['frames']} frames / "
        f"{st['flushes']} flushes, CR={st['samples_ingested']*BYTES_PER_ROW/len(blob):.1f}, "
        f"kb={st['kb']}"
    )

    worst = 0.0
    qrng = np.random.default_rng(1)
    checked = 0
    for sid in range(s):
        full = np.concatenate(history[sid]) if history[sid] else np.zeros(0)
        if full.size < 2:
            continue
        for _ in range(args.verify_queries):
            lo = int(qrng.integers(0, full.size - 1))
            hi = int(min(full.size, lo + 1 + qrng.integers(0, 4096)))
            got = decode_range(blob, sid, lo, hi, eps)
            worst = max(worst, float(np.abs(got - full[lo:hi]).max()))
            checked += 1
    print(f"verified {checked} range decodes, max |err|={worst:.2e} (eps={eps:.2e})")
    return 0 if worst <= eps * (1 + 1e-9) else 1


def _serve_chaos(args) -> int:
    """Seeded chaos campaign against the fault-tolerant gateway.

    Phase 1 (corruption): each round injects ONE random fault (byte flip,
    truncation, frame-CRC smash, or frame drop) into a fresh copy of a
    pristine SHRKS container and fires range queries at a gateway over the
    mutant.  Every completed answer is differentially checked against the
    raw data: it must either carry a typed error, or be within its own
    reported ``achieved`` bound.  An answer outside its bound with no
    error flag is a SILENT CORRUPTION and fails the run.

    Phase 2 (transient faults + overload): the pristine container is
    served through a flaky decode path (seeded ``TransientError`` at
    ``--fault-rate``) with a deliberately tiny admission queue, exercising
    retry-with-backoff, the per-frame circuit breaker, deadline
    enforcement, and shed-to-coarse backpressure — again with every
    answer differentially checked.
    """
    from ..core import BYTES_PER_ROW, ShrinkConfig, ShrinkStreamCodec
    from ..core.errors import ShrinkError
    from ..serving import FaultTolerantGateway, RangeQuery, RetryPolicy
    from ..testing import ChaosInjector

    rng = np.random.default_rng(0)
    s, n = args.series, args.points
    v = np.cumsum(rng.standard_normal((s, n)) * 0.05, axis=1)
    v += rng.standard_normal((s, n)) * 0.02
    v = np.round(v, 4)
    vmin, vmax = float(v.min()), float(v.max())
    cfg = ShrinkConfig(eps_b=0.05 * max(vmax - vmin, 1e-12), lam=1e-4)
    eps = args.eps * (vmax - vmin)
    codec = ShrinkStreamCodec(
        cfg, eps_targets=[eps], backend="rans",
        value_range=(vmin, vmax), frame_len=args.frame_len,
    )
    for sid in range(s):
        codec.ingest(v[sid], series_id=sid)
    blob = codec.finalize()
    print(
        f"pristine container: {s} series x {n} samples, "
        f"{codec.stats()['frames']} frames, {len(blob)} bytes, "
        f"CR={s*n*BYTES_PER_ROW/len(blob):.1f}"
    )

    def check(q) -> str:
        """Classify one completed query: 'error' (typed, fine), 'ok'
        (within requested eps), 'degraded' (flagged, within its own
        achieved bound), or 'SILENT' (out of bound, unflagged)."""
        if q.error is not None:
            return "error"
        err = float(np.abs(q.result - v[q.series_id, q.t0 : q.t1]).max())
        bound = max(q.achieved, q.eps)
        if err > bound * (1 + 1e-9):
            return "SILENT"
        return "degraded" if q.degraded else "ok"

    chaos = ChaosInjector(seed=args.chaos_seed)
    qrng = np.random.default_rng(2)
    tally = {"ok": 0, "degraded": 0, "error": 0, "SILENT": 0}
    by_kind: dict[str, int] = {}
    unreadable = 0
    t0 = time.perf_counter()
    for _ in range(args.corruptions):
        mutant, fault = chaos.corrupt(blob)
        by_kind[fault.kind] = by_kind.get(fault.kind, 0) + 1
        try:
            gw = FaultTolerantGateway(mutant, seed=args.chaos_seed)
        except ShrinkError:
            unreadable += 1  # detected at parse: typed, never silent
            continue
        for qid in range(args.queries_per_fault):
            sid = int(qrng.integers(0, s))
            lo = int(qrng.integers(0, n - 16))
            hi = int(min(n, lo + qrng.integers(16, 2 * args.frame_len)))
            gw.submit(RangeQuery(qid=qid, series_id=sid, t0=lo, t1=hi, eps=eps))
        for q in gw.run(deadline_s=10.0):
            tally[check(q)] += 1
    dt = time.perf_counter() - t0
    kinds = ", ".join(f"{k}={c}" for k, c in sorted(by_kind.items()))
    print(
        f"phase 1: {args.corruptions} corrupt containers ({kinds}) in {dt:.2f}s — "
        f"{unreadable} rejected at parse; per-query: {tally['ok']} ok, "
        f"{tally['degraded']} degraded, {tally['error']} typed errors, "
        f"{tally['SILENT']} SILENT"
    )

    gw = FaultTolerantGateway(
        blob,
        retry=RetryPolicy(max_attempts=4, base_delay_s=1e-4, max_delay_s=1e-3),
        max_queue=args.queries // 4 or 1,
        seed=args.chaos_seed,
    )
    gw.frame_decode = chaos.flaky(gw.frame_decode, fail_rate=args.fault_rate)
    tally2 = {"ok": 0, "degraded": 0, "error": 0, "SILENT": 0}
    t0 = time.perf_counter()
    for qid in range(args.queries):
        sid = int(qrng.integers(0, s))
        lo = int(qrng.integers(0, n - 16))
        hi = int(min(n, lo + qrng.integers(16, 2 * args.frame_len)))
        gw.submit(RangeQuery(qid=qid, series_id=sid, t0=lo, t1=hi, eps=eps))
        # drain in bursts only once the bounded queue has overflowed, so
        # the tail of each burst is shed to the coarse tier
        if len(gw.queue) >= gw.max_queue + 4:
            for q in gw.run(deadline_s=5.0):
                tally2[check(q)] += 1
    for q in gw.run(deadline_s=5.0):
        tally2[check(q)] += 1
    dt = time.perf_counter() - t0
    st = gw.stats
    print(
        f"phase 2: {st['queries']} queries through flaky decode "
        f"(fault rate {args.fault_rate:g}) in {dt:.2f}s — "
        f"{st['retries']} retries, {st['transient_failures']} transient faults, "
        f"{st['breaker_opens']} breaker opens, {st['shed']} shed to coarse, "
        f"{st['deadline_exceeded']} deadline misses; per-query: "
        f"{tally2['ok']} ok, {tally2['degraded']} degraded, "
        f"{tally2['error']} typed errors, {tally2['SILENT']} SILENT"
    )
    silent = tally["SILENT"] + tally2["SILENT"]
    print(f"silent corruptions: {silent}" + ("" if silent == 0 else "  <-- FAIL"))
    return 0 if silent == 0 else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--mode",
        choices=["model", "range", "ingest", "analytics", "chaos"],
        default="model",
    )
    # model mode
    ap.add_argument("--arch")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    # range mode
    ap.add_argument("--series", type=int, default=8)
    ap.add_argument("--points", type=int, default=65536)
    ap.add_argument("--frame-len", type=int, default=8192)
    ap.add_argument("--chunk", type=int, default=4096)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--eps", type=float, default=1e-3, help="fraction of value range")
    ap.add_argument("--cache-frames", type=int, default=32)
    # ingest mode
    ap.add_argument("--ticks", type=int, default=100, help="gateway polling rounds")
    ap.add_argument("--flush-samples", type=int, default=131_072)
    ap.add_argument("--flush-deadline", type=float, default=None)
    ap.add_argument("--buckets", type=int, default=4)
    ap.add_argument("--verify-queries", type=int, default=2)
    # chaos mode
    ap.add_argument("--fault-rate", type=float, default=0.01,
                    help="transient decode failure probability (phase 2)")
    ap.add_argument("--corruptions", type=int, default=48,
                    help="corrupt containers to generate (phase 1)")
    ap.add_argument("--queries-per-fault", type=int, default=8)
    ap.add_argument("--chaos-seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.mode == "chaos":
        return _serve_chaos(args)
    if args.mode == "ingest":
        return _serve_ingest(args)
    if args.mode == "analytics":
        return _serve_analytics(args)
    if args.mode == "range":
        return _serve_range(args)
    if not args.arch:
        ap.error("--arch is required in --mode model")
    return _serve_model(args)


if __name__ == "__main__":
    raise SystemExit(main())
