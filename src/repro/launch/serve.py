"""Serving launcher: continuous batching over a reduced or production
model, batched range-query decode over a streamed SHRINK container, or
ragged multi-sensor gateway ingest through the admission scheduler.

    # LLM decode loop (continuous batching)
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
        --requests 16 --slots 8 --max-new 8

    # time-series range queries against a freshly streamed SHRKS container
    PYTHONPATH=src python -m repro.launch.serve --mode range \
        --series 8 --points 65536 --frame-len 8192 --queries 256

    # ragged gateway ingest: heterogeneous-rate sensors -> RaggedBatcher
    # (size/deadline admission, bucketed ragged compress_batch) -> SHRKS
    PYTHONPATH=src python -m repro.launch.serve --mode ingest \
        --series 64 --ticks 200 --flush-samples 131072

    # compressed-domain analytics: aggregates / threshold counts / top-k
    # straight off the container, differentially checked against decode
    PYTHONPATH=src python -m repro.launch.serve --mode analytics \
        --series 8 --points 65536 --frame-len 8192 --queries 256

    # chaos campaign: seeded fault injection (byte flips, truncation, CRC
    # smash, frame drops, transient decode failures) against the
    # fault-tolerant gateway; every answer differentially checked, exits
    # non-zero on ANY silent corruption
    PYTHONPATH=src python -m repro.launch.serve --mode chaos \
        --series 4 --points 16384 --frame-len 2048 --fault-rate 0.01

    # sharded multi-tenant fleet: Poisson mixed workload (ingest + range +
    # analytics) over N shards with per-tenant admission quotas; p50/p99
    # latencies, critical-path aggregate MB/s, cross-shard differential
    # check vs the 1-shard oracle, and a shard-kill chaos tail — exits
    # non-zero on any silent corruption or cross-shard byte mismatch
    PYTHONPATH=src python -m repro.launch.serve --mode fleet --shards 4
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def _serve_model(args) -> int:
    import jax

    from ..configs import get_config, reduced_config
    from ..models import build_model
    from ..serving import ContinuousBatcher, Request

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    decode = jax.jit(model.decode_step)
    rng = np.random.default_rng(0)

    batcher = ContinuousBatcher(
        decode_fn=lambda t, c, i: decode(params, t, c, i),
        make_caches=lambda: model.make_decode_caches(args.slots, args.max_seq),
        n_slots=args.slots,
        eos_token=-1,
    )
    for rid in range(args.requests):
        batcher.submit(Request(
            rid=rid,
            prompt=rng.integers(1, cfg.vocab_size, size=int(rng.integers(4, 16))).astype(np.int32),
            max_new_tokens=args.max_new,
        ))
    t0 = time.perf_counter()
    done = batcher.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.prompt) + len(r.generated) for r in done)
    print(f"served {len(done)} requests, {toks} tokens, {dt:.1f}s ({toks/dt:.1f} tok/s)")
    return 0


def _serve_range(args) -> int:
    """Stream synthetic gateway sensors into a SHRKS container, then serve
    random range queries through the frame-cached batcher."""
    from ..core import BYTES_PER_ROW, ShrinkConfig, ShrinkStreamCodec
    from ..serving import RangeQuery, RangeQueryBatcher

    rng = np.random.default_rng(0)
    s, n = args.series, args.points
    v = np.cumsum(rng.standard_normal((s, n)) * 0.05, axis=1)
    v += rng.standard_normal((s, n)) * 0.02
    v = np.round(v, 4)
    vmin, vmax = float(v.min()), float(v.max())
    cfg = ShrinkConfig(eps_b=0.05 * max(vmax - vmin, 1e-12), lam=1e-4)
    eps = args.eps * (vmax - vmin)

    codec = ShrinkStreamCodec(
        cfg, eps_targets=[eps], backend="rans",
        value_range=(vmin, vmax), frame_len=args.frame_len,
    )
    t0 = time.perf_counter()
    for c0 in range(0, n, args.chunk):  # interleaved chunk-at-a-time ingest
        for sid in range(s):
            codec.ingest(v[sid, c0 : c0 + args.chunk], series_id=sid)
    blob = codec.finalize()
    dt_ingest = time.perf_counter() - t0
    mb = s * n * BYTES_PER_ROW / 1e6
    st = codec.stats()
    print(
        f"ingested {s} series x {n} samples in {dt_ingest:.2f}s "
        f"({mb/dt_ingest:.1f} MB/s), {st['frames']} frames, "
        f"CR={s*n*BYTES_PER_ROW/len(blob):.1f}, kb={st['kb']}"
    )

    batcher = RangeQueryBatcher(blob, cache_frames=args.cache_frames)
    qrng = np.random.default_rng(1)
    for qid in range(args.queries):
        sid = int(qrng.integers(0, s))
        t_lo = int(qrng.integers(0, n - 16))
        t_hi = int(min(n, t_lo + qrng.integers(16, args.frame_len)))
        batcher.submit(RangeQuery(qid=qid, series_id=sid, t0=t_lo, t1=t_hi, eps=eps))
    t0 = time.perf_counter()
    done = batcher.run()
    dt_q = time.perf_counter() - t0
    worst = 0.0
    for q in done:
        assert q.error is None, q.error
        worst = max(worst, float(np.abs(q.result - v[q.series_id, q.t0 : q.t1]).max()))
    bs = batcher.stats
    print(
        f"served {len(done)} range queries in {dt_q:.3f}s "
        f"({len(done)/dt_q:.0f} q/s), frames decoded={bs['frames_decoded']} "
        f"cache hits={bs['frame_hits']}, max |err|={worst:.2e} (eps={eps:.2e})"
    )
    return 0 if worst <= eps * (1 + 1e-9) else 1


def _serve_analytics(args) -> int:
    """Compressed-domain analytics over a freshly streamed container: a
    mixed workload of aggregates (random ranges and resolutions),
    threshold counts at the exact tier, and top-k segment queries —
    every answer differentially verified against the decode-then-numpy
    oracle before it counts."""
    from ..analytics import AnalyticsEngine
    from ..core import BYTES_PER_ROW, ShrinkConfig, ShrinkStreamCodec
    from ..serving import RangeQueryBatcher

    rng = np.random.default_rng(0)
    s, n = args.series, args.points
    v = np.cumsum(rng.standard_normal((s, n)) * 0.05, axis=1)
    v += rng.standard_normal((s, n)) * 0.02
    v = np.round(v, 4)
    vmin, vmax = float(v.min()), float(v.max())
    vrng = max(vmax - vmin, 1e-12)
    cfg = ShrinkConfig(eps_b=0.02 * vrng, lam=1e-4)
    tiers = [1e-2 * vrng, 1e-3 * vrng, 0.0]

    codec = ShrinkStreamCodec(
        cfg, eps_targets=tiers, decimals=4, backend="rans",
        value_range=(vmin, vmax), frame_len=args.frame_len,
    )
    for sid in range(s):
        codec.ingest(v[sid], series_id=sid)
    blob = codec.finalize()
    print(
        f"streamed {s} series x {n} samples into {codec.stats()['frames']} frames, "
        f"CR={s * n * BYTES_PER_ROW / len(blob):.1f}"
    )

    eng = AnalyticsEngine(RangeQueryBatcher(blob, cache_frames=args.cache_frames))
    qrng = np.random.default_rng(1)
    ops = ["min", "max", "sum", "mean", "stddev"]
    checked = 0
    t0 = time.perf_counter()
    for qid in range(args.queries):
        sid = int(qrng.integers(0, s))
        lo = int(qrng.integers(0, n - 16))
        hi = int(min(n, lo + qrng.integers(16, 4 * args.frame_len)))
        sl = v[sid, lo:hi]
        kind = qid % 3
        if kind == 0:  # zero-decode sketch aggregate off the segments
            op = ops[qid % len(ops)]
            ans = eng.aggregate(sid, op, lo, hi, eps=None)
        elif kind == 1:  # tiered aggregate (refine loop through the LRU)
            op = ops[qid % len(ops)]
            ans = eng.aggregate(sid, op, lo, hi, eps=tiers[qid % len(tiers)])
        else:  # exact threshold count: refine only straddling frames
            c = float(qrng.uniform(sl.min(), sl.max() + 1e-9))
            ans = eng.count_where(sid, "gt", c, lo, hi, eps=0.0)
        truth = {
            "min": sl.min, "max": sl.max, "sum": sl.sum, "mean": sl.mean,
            "stddev": sl.std,
        }[op]() if kind != 2 else float((sl > c).sum())
        assert ans.lo - 1e-9 <= truth <= ans.hi + 1e-9, (qid, ans, truth)
        if kind == 2:
            assert ans.exact
        checked += 1
    dt = time.perf_counter() - t0
    st = eng.stats
    top = eng.topk_segments(0, k=3, by="length")
    print(
        f"answered {checked} verified queries in {dt:.3f}s ({checked / dt:.0f} q/s): "
        f"{st['segment_frames']} segment-domain frames, "
        f"{st['frames_skipped']} skipped, {st['frames_refined']} refined, "
        f"{st['layers_paid']} layers paid "
        f"(serving LRU hits={eng.batcher.stats['frame_hits']})"
    )
    print(f"top-3 longest segments of series 0: {[(r['t0'], r['length']) for r in top]}")
    return 0


def _serve_ingest(args) -> int:
    """Ragged gateway simulation: sensors publish at rates spanning orders
    of magnitude; every tick delivers one chunk per sensor into the
    RaggedBatcher, whose size/deadline admission policy decides when the
    pending ragged batch compresses into SHRKS frames.  Ends with a
    correctness sweep (random range decodes against the raw data)."""
    from ..core import BYTES_PER_ROW, ShrinkConfig
    from ..core.streaming import decode_range
    from ..data.synthetic import ragged_sensor_traffic
    from ..serving import RaggedBatcher

    s = args.series
    traffic = ragged_sensor_traffic(s, args.ticks, seed=0)
    history: dict[int, list[np.ndarray]] = {i: [] for i in range(s)}

    cfg = ShrinkConfig(eps_b=0.4, lam=1e-4)
    eps = args.eps * 8.0  # value walks live in roughly [-4, 4]
    batcher = RaggedBatcher(
        cfg, eps_targets=[eps], backend="rans",
        flush_samples=args.flush_samples,
        flush_deadline_s=args.flush_deadline,
        max_buckets=args.buckets,
    )
    t0 = time.perf_counter()
    frames = 0
    for tick in traffic:
        for sid, chunk in tick:
            history[sid].append(chunk)
            frames += len(batcher.submit(sid, chunk))
        frames += len(batcher.poll())
    blob = batcher.finalize()
    dt = time.perf_counter() - t0
    st = batcher.stats()
    mb = st["samples_ingested"] * BYTES_PER_ROW / 1e6
    print(
        f"ingested {st['samples_ingested']:,} samples from {st['series']} sensors "
        f"in {dt:.2f}s ({mb/dt:.1f} MB/s), {st['frames']} frames / "
        f"{st['flushes']} flushes, CR={st['samples_ingested']*BYTES_PER_ROW/len(blob):.1f}, "
        f"kb={st['kb']}"
    )

    worst = 0.0
    qrng = np.random.default_rng(1)
    checked = 0
    for sid in range(s):
        full = np.concatenate(history[sid]) if history[sid] else np.zeros(0)
        if full.size < 2:
            continue
        for _ in range(args.verify_queries):
            lo = int(qrng.integers(0, full.size - 1))
            hi = int(min(full.size, lo + 1 + qrng.integers(0, 4096)))
            got = decode_range(blob, sid, lo, hi, eps)
            worst = max(worst, float(np.abs(got - full[lo:hi]).max()))
            checked += 1
    print(f"verified {checked} range decodes, max |err|={worst:.2e} (eps={eps:.2e})")
    return 0 if worst <= eps * (1 + 1e-9) else 1


def _serve_chaos(args) -> int:
    """Seeded chaos campaign against the fault-tolerant gateway.

    Phase 1 (corruption): each round injects ONE random fault (byte flip,
    truncation, frame-CRC smash, or frame drop) into a fresh copy of a
    pristine SHRKS container and fires range queries at a gateway over the
    mutant.  Every completed answer is differentially checked against the
    raw data: it must either carry a typed error, or be within its own
    reported ``achieved`` bound.  An answer outside its bound with no
    error flag is a SILENT CORRUPTION and fails the run.

    Phase 2 (transient faults + overload): the pristine container is
    served through a flaky decode path (seeded ``TransientError`` at
    ``--fault-rate``) with a deliberately tiny admission queue, exercising
    retry-with-backoff, the per-frame circuit breaker, deadline
    enforcement, and shed-to-coarse backpressure — again with every
    answer differentially checked.

    Phase 3 (KB store): faults against the cross-archive store path —
    byte flips and truncations of SHKS snapshot blobs must raise typed
    errors, a stale ``kb_snapshot_ref`` must fall back to the inline
    footer KB (both-mode container) or raise ``StaleSnapshotError``
    (ref-only), and decode of the faulted containers must stay exact.
    """
    from ..core import BYTES_PER_ROW, ShrinkConfig, ShrinkStreamCodec
    from ..core.errors import ShrinkError
    from ..serving import FaultTolerantGateway, RangeQuery, RetryPolicy
    from ..testing import ChaosInjector

    rng = np.random.default_rng(0)
    s, n = args.series, args.points
    v = np.cumsum(rng.standard_normal((s, n)) * 0.05, axis=1)
    v += rng.standard_normal((s, n)) * 0.02
    v = np.round(v, 4)
    vmin, vmax = float(v.min()), float(v.max())
    cfg = ShrinkConfig(eps_b=0.05 * max(vmax - vmin, 1e-12), lam=1e-4)
    eps = args.eps * (vmax - vmin)
    codec = ShrinkStreamCodec(
        cfg, eps_targets=[eps], backend="rans",
        value_range=(vmin, vmax), frame_len=args.frame_len,
    )
    for sid in range(s):
        codec.ingest(v[sid], series_id=sid)
    blob = codec.finalize()
    print(
        f"pristine container: {s} series x {n} samples, "
        f"{codec.stats()['frames']} frames, {len(blob)} bytes, "
        f"CR={s*n*BYTES_PER_ROW/len(blob):.1f}"
    )

    def check(q) -> str:
        """Classify one completed query: 'error' (typed, fine), 'ok'
        (within requested eps), 'degraded' (flagged, within its own
        achieved bound), or 'SILENT' (out of bound, unflagged)."""
        if q.error is not None:
            return "error"
        err = float(np.abs(q.result - v[q.series_id, q.t0 : q.t1]).max())
        bound = max(q.achieved, q.eps)
        if err > bound * (1 + 1e-9):
            return "SILENT"
        return "degraded" if q.degraded else "ok"

    chaos = ChaosInjector(seed=args.chaos_seed)
    qrng = np.random.default_rng(2)
    tally = {"ok": 0, "degraded": 0, "error": 0, "SILENT": 0}
    by_kind: dict[str, int] = {}
    unreadable = 0
    t0 = time.perf_counter()
    for _ in range(args.corruptions):
        mutant, fault = chaos.corrupt(blob)
        by_kind[fault.kind] = by_kind.get(fault.kind, 0) + 1
        try:
            gw = FaultTolerantGateway(mutant, seed=args.chaos_seed)
        except ShrinkError:
            unreadable += 1  # detected at parse: typed, never silent
            continue
        for qid in range(args.queries_per_fault):
            sid = int(qrng.integers(0, s))
            lo = int(qrng.integers(0, n - 16))
            hi = int(min(n, lo + qrng.integers(16, 2 * args.frame_len)))
            gw.submit(RangeQuery(qid=qid, series_id=sid, t0=lo, t1=hi, eps=eps))
        for q in gw.run(deadline_s=10.0):
            tally[check(q)] += 1
    dt = time.perf_counter() - t0
    kinds = ", ".join(f"{k}={c}" for k, c in sorted(by_kind.items()))
    print(
        f"phase 1: {args.corruptions} corrupt containers ({kinds}) in {dt:.2f}s — "
        f"{unreadable} rejected at parse; per-query: {tally['ok']} ok, "
        f"{tally['degraded']} degraded, {tally['error']} typed errors, "
        f"{tally['SILENT']} SILENT"
    )

    gw = FaultTolerantGateway(
        blob,
        retry=RetryPolicy(max_attempts=4, base_delay_s=1e-4, max_delay_s=1e-3),
        max_queue=args.queries // 4 or 1,
        seed=args.chaos_seed,
    )
    gw.frame_decode = chaos.flaky(gw.frame_decode, fail_rate=args.fault_rate)
    tally2 = {"ok": 0, "degraded": 0, "error": 0, "SILENT": 0}
    t0 = time.perf_counter()
    for qid in range(args.queries):
        sid = int(qrng.integers(0, s))
        lo = int(qrng.integers(0, n - 16))
        hi = int(min(n, lo + qrng.integers(16, 2 * args.frame_len)))
        gw.submit(RangeQuery(qid=qid, series_id=sid, t0=lo, t1=hi, eps=eps))
        # drain in bursts only once the bounded queue has overflowed, so
        # the tail of each burst is shed to the coarse tier
        if len(gw.queue) >= gw.max_queue + 4:
            for q in gw.run(deadline_s=5.0):
                tally2[check(q)] += 1
    for q in gw.run(deadline_s=5.0):
        tally2[check(q)] += 1
    dt = time.perf_counter() - t0
    st = gw.stats
    print(
        f"phase 2: {st['queries']} queries through flaky decode "
        f"(fault rate {args.fault_rate:g}) in {dt:.2f}s — "
        f"{st['retries']} retries, {st['transient_failures']} transient faults, "
        f"{st['breaker_opens']} breaker opens, {st['shed']} shed to coarse, "
        f"{st['deadline_exceeded']} deadline misses; per-query: "
        f"{tally2['ok']} ok, {tally2['degraded']} degraded, "
        f"{tally2['error']} typed errors, {tally2['SILENT']} SILENT"
    )
    # phase 3: the KB-store path — snapshot corruption and stale refs
    from ..core.errors import StaleSnapshotError
    from ..core.streaming import decode_series
    from ..serving import KBStore
    from ..serving.kbstore import resolve_container_kb, snapshot_from_bytes
    from ..testing import flip_byte, stale_snapshot_ref, truncate

    store = KBStore(cfg)

    def _store_codec(source, inline):
        sc = ShrinkStreamCodec(
            cfg, eps_targets=[eps], backend="rans",
            value_range=(vmin, vmax), frame_len=args.frame_len,
            kb_store=store, inline_kb=inline, source=source,
        )
        sc.ingest(v[0])
        return sc.finalize()

    ref_only = _store_codec("ref-only", None)
    both = _store_codec("both", True)
    snap = store.snapshots[-1].blob
    frng = np.random.default_rng(args.chaos_seed + 3)
    tally3 = {"typed": 0, "fallback": 0, "SILENT": 0}
    n_snap_faults = max(16, args.corruptions)
    for _ in range(n_snap_faults):
        if frng.random() < 0.5:
            bad, _ = flip_byte(snap, int(frng.integers(0, len(snap))),
                               bit=int(frng.integers(0, 8)))
        else:
            bad, _ = truncate(snap, int(frng.integers(0, len(snap))))
        try:
            snapshot_from_bytes(bad)
            tally3["SILENT"] += 1  # corrupt snapshot decoded without complaint
        except ShrinkError:
            tally3["typed"] += 1
    pristine = decode_series(ref_only, 0, eps)
    stale_ref_only, _ = stale_snapshot_ref(ref_only)
    try:
        resolve_container_kb(stale_ref_only, store)
        tally3["SILENT"] += 1  # a stale ref bound to the wrong snapshot
    except StaleSnapshotError:
        tally3["typed"] += 1
    stale_both, _ = stale_snapshot_ref(both)
    _, origin = resolve_container_kb(stale_both, store)
    if origin == "inline-fallback":
        tally3["fallback"] += 1
    else:
        tally3["SILENT"] += 1
    for mutant in (stale_ref_only, stale_both):
        if not np.array_equal(decode_series(mutant, 0, eps), pristine):
            tally3["SILENT"] += 1  # a footer fault must never move frame bytes
    print(
        f"phase 3: {n_snap_faults} snapshot faults + 2 stale refs — "
        f"{tally3['typed']} typed, {tally3['fallback']} inline fallbacks, "
        f"{tally3['SILENT']} SILENT"
    )

    silent = tally["SILENT"] + tally2["SILENT"] + tally3["SILENT"]
    print(f"silent corruptions: {silent}" + ("" if silent == 0 else "  <-- FAIL"))
    return 0 if silent == 0 else 1


def _serve_kbstore(args) -> int:
    """Cross-archive KB store demo: many small archives tiling a shared
    motif bank are encoded twice — self-contained (inline footer KB) and
    in ref mode against one shared :class:`KBStore` — then every archive
    is decoded both ways and compared exactly.  The store is then
    exercised through its whole lifecycle: detach a third of the corpus,
    ``compact()`` (re-basing the survivors, decode re-verified), spill the
    snapshots to disk, and reload; refs from the re-based containers must
    resolve against the loaded store to the writers' exact KB views.
    Exits nonzero on any decode or KB-view mismatch."""
    import tempfile

    from ..core import ShrinkConfig, ShrinkStreamCodec
    from ..core.errors import StaleSnapshotError
    from ..core.semantics import global_range
    from ..core.serialize import parse_framed_container, read_snapshot_ref
    from ..core.streaming import decode_series
    from ..serving import KBStore

    n_arch = 8 if args.quick else 32
    rng = np.random.default_rng(args.chaos_seed)
    motif_len, tiles = 128, 2
    bank = []
    for _ in range(8):  # piecewise-linear motifs: recurring KB lines
        knots = np.sort(rng.choice(np.arange(4, motif_len - 4), 15, replace=False))
        xs = np.concatenate([[0], knots, [motif_len - 1]])
        ys = np.round(rng.uniform(-4.0, 4.0, size=xs.size), 1)
        bank.append(np.round(np.interp(np.arange(motif_len), xs, ys), 3))
    series = [
        np.concatenate([bank[rng.integers(0, len(bank))] for _ in range(tiles)])
        for _ in range(n_arch)
    ]
    vr = global_range(np.concatenate(series))
    cfg = ShrinkConfig(eps_b=0.05 * (vr[1] - vr[0]), lam=1e-3)
    eps = 0.02 * (vr[1] - vr[0])

    def encode(v, store=None, source=None):
        sc = ShrinkStreamCodec(
            cfg, eps_targets=[eps], decimals=3, backend="best",
            value_range=vr, frame_len=tiles * motif_len,
            kb_store=store, source=source,
        )
        sc.ingest(v)
        return sc, sc.finalize()

    inline = [encode(v)[1] for v in series]
    store = KBStore(cfg)
    writers = [encode(v, store, f"ar{i}")[0] for i, v in enumerate(series)]
    inline_bytes = sum(len(b) for b in inline)
    shared_bytes = (
        sum(len(store.container(f"ar{i}")) for i in range(n_arch))
        + len(store.snapshots[-1].blob)
    )
    st = store.stats()
    print(
        f"corpus: {n_arch} archives x {tiles * motif_len} samples; "
        f"inline={inline_bytes:,}B (KB share "
        f"{sum(len(parse_framed_container(b)[1]) for b in inline) / inline_bytes:.1%}), "
        f"shared={shared_bytes:,}B -> CR={shared_bytes / inline_bytes:.3f}"
    )
    print(
        f"store: {st['live']} live entries, dedup {st['dedup_ratio']:.1f}x, "
        f"{st['snapshots']} snapshots"
    )

    bad = 0
    for i in range(n_arch):
        if not np.array_equal(
            decode_series(inline[i], 0, eps),
            decode_series(store.container(f"ar{i}"), 0, eps),
        ):
            bad += 1
    print(f"differential decode (ref vs inline): {n_arch - bad}/{n_arch} exact")

    dropped = list(range(0, n_arch, 3))
    old_refs = {i: read_snapshot_ref(store.container(f"ar{i}")) for i in dropped}
    for i in dropped:
        store.detach(f"ar{i}")
    rep = store.compact()
    survivors = [i for i in range(n_arch) if i not in dropped]
    for i in survivors:
        if not np.array_equal(
            decode_series(store.container(f"ar{i}"), 0, eps),
            decode_series(inline[i], 0, eps),
        ):
            bad += 1
    stale_ok = 0
    for ref in old_refs.values():
        try:
            store.resolve(ref)
        except StaleSnapshotError:
            stale_ok += 1
    print(
        f"compact: dropped {rep['dropped']} entries "
        f"({rep['entries_before']} -> {rep['entries_after']}), rebased "
        f"{len(rep['rebased'])} containers, decode exact; "
        f"{stale_ok}/{len(old_refs)} retired refs typed stale"
    )
    bad += len(old_refs) - stale_ok

    with tempfile.TemporaryDirectory() as d:
        paths = store.spill(d)
        loaded = KBStore.load(d)
        kb_bad = 0
        for i in survivors:
            ref = read_snapshot_ref(store.container(f"ar{i}"))
            kb = loaded.container_kb(ref)
            if kb.canonical() != writers[i].kb.canonical():
                kb_bad += 1
        print(
            f"spill/load: {len(paths)} snapshot file(s), sem_id match: "
            f"{loaded.sem_id() == store.sem_id()}, "
            f"{len(survivors) - kb_bad}/{len(survivors)} KB views exact"
        )
        bad += kb_bad
        if loaded.sem_id() != store.sem_id():
            bad += 1

    print(f"mismatches: {bad}" + ("" if bad == 0 else "  <-- FAIL"))
    return 0 if bad == 0 else 1


class _SimClock:
    """Deterministic monotonic clock for quota/deadline decisions: the sim
    advances it a fixed step per tick, so admission outcomes replay
    byte-identically from the seed (wall latencies are measured separately
    with ``perf_counter``)."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def _gen_traffic(series: int, ticks: int, seed: int):
    """Poisson sensor mix: per-series arrival probability and mean chunk
    size span an order of magnitude; each admitted chunk continues that
    series' random walk.  Returns (per-tick [(sid, chunk)], full history)."""
    rng = np.random.default_rng(seed)
    rates = 10.0 ** rng.uniform(-0.8, 0.0, size=series)
    means = rng.integers(24, 160, size=series)
    last = np.zeros(series)
    traffic, history = [], {i: [] for i in range(series)}
    for _ in range(ticks):
        tick = []
        for sid in range(series):
            if rng.random() < rates[sid]:
                m = 1 + int(rng.poisson(means[sid]))
                chunk = np.round(last[sid] + np.cumsum(rng.standard_normal(m) * 0.05), 4)
                last[sid] = chunk[-1]
                tick.append((sid, chunk))
                history[sid].append(chunk)
        traffic.append(tick)
    return traffic, history


def _pcts(ms: list[float]) -> dict:
    if not ms:
        return {"p50_ms": 0.0, "p99_ms": 0.0}
    a = np.asarray(ms)
    return {"p50_ms": float(np.percentile(a, 50)), "p99_ms": float(np.percentile(a, 99))}


def _ingest_fleet(traffic, n_shards: int, flush_samples: int, tick_dt: float = 0.01):
    """Drive one fleet through the traffic, attributing each submit's wall
    time to the owning shard (the critical-path throughput model: on one
    host the shards run sequentially; a real fleet runs them on the mesh's
    "data" axis, so aggregate rate = total bytes / max per-shard busy)."""
    from ..core import ShrinkConfig
    from ..core.errors import QuotaExceededError
    from ..serving import ShrinkFleet, TenantQuota

    clk = _SimClock()
    # four tenants round-robin over series; t3 runs on a tight bucket so
    # quota rejection/shed paths are exercised deterministically
    quotas = {
        f"t{k}": TenantQuota(rate_per_s=4e6, burst=4e6, clock=clk) for k in range(3)
    }
    quotas["t3"] = TenantQuota(rate_per_s=2_000.0, burst=3_000.0, clock=clk)
    fleet = ShrinkFleet(
        ShrinkConfig(eps_b=0.4, lam=1e-4),
        eps_targets=[8e-3],
        n_shards=n_shards,
        flush_samples=flush_samples,
        tenant_of=lambda sid: f"t{sid % 4}",
        quotas=quotas,
        clock=clk,
    )
    busy = [0.0] * n_shards
    lat_ms, admitted, rejected = [], {}, 0
    for tick in traffic:
        for sid, chunk in tick:
            shard = fleet.shard_of(sid)
            t0 = time.perf_counter()
            try:
                fleet.submit(sid, chunk)
            except QuotaExceededError:
                rejected += 1
                continue
            finally:
                dt = time.perf_counter() - t0
                busy[shard] += dt
            lat_ms.append(dt * 1e3)
            admitted.setdefault(sid, []).append(chunk)
        clk.t += tick_dt
        fleet.poll()
    # seal: each shard pays for compressing its own residual pending pool
    # (finalize is idempotent, so fleet.seal() below reuses these containers)
    for i, b in enumerate(fleet.batchers):
        t0 = time.perf_counter()
        b.finalize()
        busy[i] += time.perf_counter() - t0
    fleet.seal()
    return fleet, busy, lat_ms, admitted, rejected


def run_fleet_sim(
    n_shards: int = 4,
    series: int = 32,
    ticks: int = 120,
    queries: int = 192,
    flush_samples: int = 2048,
    seed: int = 0,
    check: bool = True,
    kill: bool = True,
) -> dict:
    """The fleet simulation behind ``--mode fleet`` and the ``fleet``
    BENCH section: Poisson mixed workload through a sharded multi-tenant
    fleet, p50/p99 ingest+query latency, critical-path aggregate MB/s,
    cross-shard differential vs the 1-shard oracle (``check``), and a
    shard-kill chaos tail (``kill``).  Everything is seeded; the returned
    dict's ``silent``/``byte_mismatch`` MUST be zero."""
    from ..core import BYTES_PER_ROW
    from ..serving import RangeQuery
    from ..testing import ChaosInjector

    eps = 8e-3
    traffic, _ = _gen_traffic(series, ticks, seed)
    fleet, busy, ingest_ms, admitted, rejected = _ingest_fleet(
        traffic, n_shards, flush_samples
    )
    full = {sid: np.concatenate(cs) for sid, cs in admitted.items()}
    samples = sum(v.size for v in full.values())
    mb = samples * BYTES_PER_ROW / 1e6
    critical = max(busy) if busy else 1e-12

    def check_range(q, tally) -> None:
        if q.error is not None:
            tally["error"] += 1
            return
        err = float(np.abs(q.result - full[q.series_id][q.t0 : q.t1]).max())
        if err > max(q.achieved, q.eps) * (1 + 1e-9):
            tally["SILENT"] += 1
        else:
            tally["degraded" if q.degraded else "ok"] += 1

    # mixed query workload: 70% range / 20% aggregate / 10% threshold count
    qrng = np.random.default_rng(seed + 1)
    sids = sorted(s for s, v in full.items() if v.size >= 16)
    tally = {"ok": 0, "degraded": 0, "error": 0, "SILENT": 0}
    query_ms = []
    for qid in range(queries):
        sid = int(qrng.choice(sids))
        n = full[sid].size
        lo = int(qrng.integers(0, n - 8))
        hi = int(min(n, lo + 8 + qrng.integers(0, 4096)))
        kind = qid % 10
        t0 = time.perf_counter()
        if kind < 7:
            q = fleet.query(RangeQuery(qid=qid, series_id=sid, t0=lo, t1=hi, eps=eps))
            query_ms.append((time.perf_counter() - t0) * 1e3)
            check_range(q, tally)
            continue
        sl = full[sid][lo:hi]
        if kind < 9:
            ans = fleet.aggregate(sid, ("sum", "min")[kind % 2], lo, hi, eps=eps)
            truth = float(sl.sum() if kind % 2 == 0 else sl.min())
        else:
            c = float(qrng.uniform(sl.min(), sl.max() + 1e-9))
            ans = fleet.count_where(sid, "gt", c, lo, hi, eps=None)
            truth = float((sl > c).sum())
        query_ms.append((time.perf_counter() - t0) * 1e3)
        if ans.lo - 1e-9 <= truth <= ans.hi + 1e-9:
            tally["degraded" if ans.degraded else "ok"] += 1
        else:
            tally["SILENT"] += 1

    # cross-shard differential: every series' frames byte-identical to the
    # 1-shard oracle built from the same traffic
    byte_mismatch = 0
    if check and n_shards > 1:
        oracle, _, _, _, _ = _ingest_fleet(traffic, 1, flush_samples)
        for sid in sorted(full):
            if fleet.series_frames(sid) != oracle.series_frames(sid):
                byte_mismatch += 1
        if fleet.global_kb.canonical() != oracle.global_kb.canonical():
            byte_mismatch += 1

    # shard-kill chaos tail: corrupt one shard, healthy shards must stay
    # exact and the dead shard typed/flagged — never silent
    kill_tally = {"ok": 0, "degraded": 0, "error": 0, "SILENT": 0}
    fault_detail = ""
    if kill and n_shards > 1:
        chaos = ChaosInjector(seed=seed + 7)
        fault = chaos.kill_shard(fleet, shard=0, mode="corrupt")
        fault_detail = fault.detail
        for qid in range(min(queries, 64)):
            sid = int(qrng.choice(sids))
            n = full[sid].size
            lo = int(qrng.integers(0, n - 8))
            hi = int(min(n, lo + 8 + qrng.integers(0, 2048)))
            q = fleet.query(
                RangeQuery(qid=10_000 + qid, series_id=sid, t0=lo, t1=hi, eps=eps)
            )
            check_range(q, kill_tally)

    st = fleet.fleet_stats()
    return {
        "n_shards": n_shards,
        "series": series,
        "samples": samples,
        "mb": mb,
        "ingest": {
            "chunks": len(ingest_ms),
            "rejected_quota": rejected,
            "busy_s": [round(b, 4) for b in busy],
            "critical_path_s": critical,
            "agg_mb_s": mb / critical,
            **_pcts(ingest_ms),
        },
        "query": {"count": queries, **_pcts(query_ms), **tally},
        "kill": {"fault": fault_detail, **kill_tally},
        "kb": {
            "syncs": st["kb_syncs"],
            "global_entries": fleet.global_kb.epoch,
            "semantic_id": fleet.global_kb.snapshot_id(),
        },
        "byte_mismatch": byte_mismatch,
        "silent": tally["SILENT"] + kill_tally["SILENT"],
    }


def _serve_fleet(args) -> int:
    """Sharded fleet simulation (see :func:`run_fleet_sim`); prints the
    latency/throughput summary and fails on any silent corruption or
    cross-shard byte divergence."""
    scale = 0.25 if args.quick else 1.0
    r = run_fleet_sim(
        n_shards=args.shards,
        series=max(8, int(args.series * 4 * scale)),
        ticks=max(30, int(args.ticks * scale)),
        queries=max(48, int(args.queries * scale)),
        flush_samples=args.flush_samples,
        seed=args.chaos_seed,
    )
    ing, q, k = r["ingest"], r["query"], r["kill"]
    print(
        f"fleet: {r['n_shards']} shards, {r['series']} series, "
        f"{r['samples']:,} samples ({r['mb']:.1f} MB), "
        f"{ing['chunks']} chunks admitted, {ing['rejected_quota']} quota-rejected"
    )
    print(
        f"ingest: p50={ing['p50_ms']:.2f}ms p99={ing['p99_ms']:.2f}ms, "
        f"critical path {ing['critical_path_s']:.2f}s -> {ing['agg_mb_s']:.1f} MB/s "
        f"aggregate (busy per shard: {ing['busy_s']})"
    )
    print(
        f"query: {q['count']} mixed (range/aggregate/count) "
        f"p50={q['p50_ms']:.2f}ms p99={q['p99_ms']:.2f}ms — "
        f"{q['ok']} ok, {q['degraded']} degraded, {q['error']} typed errors, "
        f"{q['SILENT']} SILENT"
    )
    if k["fault"]:
        print(
            f"shard-kill [{k['fault']}]: {k['ok']} ok, {k['degraded']} degraded, "
            f"{k['error']} typed errors, {k['SILENT']} SILENT"
        )
    print(
        f"kb: {r['kb']['syncs']} syncs, {r['kb']['global_entries']} global entries; "
        f"cross-shard diff vs 1-shard oracle: {r['byte_mismatch']} mismatches"
    )
    bad = r["silent"] + r["byte_mismatch"]
    print(f"silent corruptions + byte mismatches: {bad}" + ("" if bad == 0 else "  <-- FAIL"))
    return 0 if bad == 0 else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--mode",
        choices=["model", "range", "ingest", "analytics", "chaos", "fleet", "kbstore"],
        default="model",
    )
    # model mode
    ap.add_argument("--arch")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    # range mode
    ap.add_argument("--series", type=int, default=8)
    ap.add_argument("--points", type=int, default=65536)
    ap.add_argument("--frame-len", type=int, default=8192)
    ap.add_argument("--chunk", type=int, default=4096)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--eps", type=float, default=1e-3, help="fraction of value range")
    ap.add_argument("--cache-frames", type=int, default=32)
    # ingest mode
    ap.add_argument("--ticks", type=int, default=100, help="gateway polling rounds")
    ap.add_argument("--flush-samples", type=int, default=131_072)
    ap.add_argument("--flush-deadline", type=float, default=None)
    ap.add_argument("--buckets", type=int, default=4)
    ap.add_argument("--verify-queries", type=int, default=2)
    # chaos mode
    ap.add_argument("--fault-rate", type=float, default=0.01,
                    help="transient decode failure probability (phase 2)")
    ap.add_argument("--corruptions", type=int, default=48,
                    help="corrupt containers to generate (phase 1)")
    ap.add_argument("--queries-per-fault", type=int, default=8)
    ap.add_argument("--chaos-seed", type=int, default=0)
    # fleet mode
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--quick", action="store_true",
                    help="scaled-down fleet sim (CI smoke)")
    args = ap.parse_args(argv)

    if args.mode == "kbstore":
        return _serve_kbstore(args)
    if args.mode == "fleet":
        return _serve_fleet(args)
    if args.mode == "chaos":
        return _serve_chaos(args)
    if args.mode == "ingest":
        return _serve_ingest(args)
    if args.mode == "analytics":
        return _serve_analytics(args)
    if args.mode == "range":
        return _serve_range(args)
    if not args.arch:
        ap.error("--arch is required in --mode model")
    return _serve_model(args)


if __name__ == "__main__":
    raise SystemExit(main())
