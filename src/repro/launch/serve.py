"""Serving launcher: continuous batching over a reduced or production model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
        --requests 16 --slots 8 --max-new 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config, reduced_config
from ..models import build_model
from ..serving import ContinuousBatcher, Request


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    decode = jax.jit(model.decode_step)
    rng = np.random.default_rng(0)

    batcher = ContinuousBatcher(
        decode_fn=lambda t, c, i: decode(params, t, c, i),
        make_caches=lambda: model.make_decode_caches(args.slots, args.max_seq),
        n_slots=args.slots,
        eos_token=-1,
    )
    for rid in range(args.requests):
        batcher.submit(Request(
            rid=rid,
            prompt=rng.integers(1, cfg.vocab_size, size=int(rng.integers(4, 16))).astype(np.int32),
            max_new_tokens=args.max_new,
        ))
    t0 = time.perf_counter()
    done = batcher.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.prompt) + len(r.generated) for r in done)
    print(f"served {len(done)} requests, {toks} tokens, {dt:.1f}s ({toks/dt:.1f} tok/s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
