"""Serving launcher: continuous batching over a reduced or production
model, or batched range-query decode over a streamed SHRINK container.

    # LLM decode loop (continuous batching)
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
        --requests 16 --slots 8 --max-new 8

    # time-series range queries against a freshly streamed SHRKS container
    PYTHONPATH=src python -m repro.launch.serve --mode range \
        --series 8 --points 65536 --frame-len 8192 --queries 256
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def _serve_model(args) -> int:
    import jax

    from ..configs import get_config, reduced_config
    from ..models import build_model
    from ..serving import ContinuousBatcher, Request

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    decode = jax.jit(model.decode_step)
    rng = np.random.default_rng(0)

    batcher = ContinuousBatcher(
        decode_fn=lambda t, c, i: decode(params, t, c, i),
        make_caches=lambda: model.make_decode_caches(args.slots, args.max_seq),
        n_slots=args.slots,
        eos_token=-1,
    )
    for rid in range(args.requests):
        batcher.submit(Request(
            rid=rid,
            prompt=rng.integers(1, cfg.vocab_size, size=int(rng.integers(4, 16))).astype(np.int32),
            max_new_tokens=args.max_new,
        ))
    t0 = time.perf_counter()
    done = batcher.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.prompt) + len(r.generated) for r in done)
    print(f"served {len(done)} requests, {toks} tokens, {dt:.1f}s ({toks/dt:.1f} tok/s)")
    return 0


def _serve_range(args) -> int:
    """Stream synthetic gateway sensors into a SHRKS container, then serve
    random range queries through the frame-cached batcher."""
    from ..core import BYTES_PER_ROW, ShrinkConfig, ShrinkStreamCodec
    from ..serving import RangeQuery, RangeQueryBatcher

    rng = np.random.default_rng(0)
    s, n = args.series, args.points
    v = np.cumsum(rng.standard_normal((s, n)) * 0.05, axis=1)
    v += rng.standard_normal((s, n)) * 0.02
    v = np.round(v, 4)
    vmin, vmax = float(v.min()), float(v.max())
    cfg = ShrinkConfig(eps_b=0.05 * max(vmax - vmin, 1e-12), lam=1e-4)
    eps = args.eps * (vmax - vmin)

    codec = ShrinkStreamCodec(
        cfg, eps_targets=[eps], backend="rans",
        value_range=(vmin, vmax), frame_len=args.frame_len,
    )
    t0 = time.perf_counter()
    for c0 in range(0, n, args.chunk):  # interleaved chunk-at-a-time ingest
        for sid in range(s):
            codec.ingest(v[sid, c0 : c0 + args.chunk], series_id=sid)
    blob = codec.finalize()
    dt_ingest = time.perf_counter() - t0
    mb = s * n * BYTES_PER_ROW / 1e6
    st = codec.stats()
    print(
        f"ingested {s} series x {n} samples in {dt_ingest:.2f}s "
        f"({mb/dt_ingest:.1f} MB/s), {st['frames']} frames, "
        f"CR={s*n*BYTES_PER_ROW/len(blob):.1f}, kb={st['kb']}"
    )

    batcher = RangeQueryBatcher(blob, cache_frames=args.cache_frames)
    qrng = np.random.default_rng(1)
    for qid in range(args.queries):
        sid = int(qrng.integers(0, s))
        t_lo = int(qrng.integers(0, n - 16))
        t_hi = int(min(n, t_lo + qrng.integers(16, args.frame_len)))
        batcher.submit(RangeQuery(qid=qid, series_id=sid, t0=t_lo, t1=t_hi, eps=eps))
    t0 = time.perf_counter()
    done = batcher.run()
    dt_q = time.perf_counter() - t0
    worst = 0.0
    for q in done:
        assert q.error is None, q.error
        worst = max(worst, float(np.abs(q.result - v[q.series_id, q.t0 : q.t1]).max()))
    bs = batcher.stats
    print(
        f"served {len(done)} range queries in {dt_q:.3f}s "
        f"({len(done)/dt_q:.0f} q/s), frames decoded={bs['frames_decoded']} "
        f"cache hits={bs['frame_hits']}, max |err|={worst:.2e} (eps={eps:.2e})"
    )
    return 0 if worst <= eps * (1 + 1e-9) else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["model", "range"], default="model")
    # model mode
    ap.add_argument("--arch")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    # range mode
    ap.add_argument("--series", type=int, default=8)
    ap.add_argument("--points", type=int, default=65536)
    ap.add_argument("--frame-len", type=int, default=8192)
    ap.add_argument("--chunk", type=int, default=4096)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--eps", type=float, default=1e-3, help="fraction of value range")
    ap.add_argument("--cache-frames", type=int, default=32)
    args = ap.parse_args(argv)

    if args.mode == "range":
        return _serve_range(args)
    if not args.arch:
        ap.error("--arch is required in --mode model")
    return _serve_model(args)


if __name__ == "__main__":
    raise SystemExit(main())
