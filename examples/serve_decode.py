"""Serving example: continuous batching + SHRINK-quantized KV cache +
range-query decode over a streamed SHRINK container.

    PYTHONPATH=src python examples/serve_decode.py

Boots a reduced qwen3-family model, submits a stream of requests through
the continuous batcher (more requests than slots -> slot recycling), then
shows the SHRINK residual-quantized KV block store (~3.7x cache memory at
a bounded L-infinity error), and finally streams two synthetic sensor
series chunk-at-a-time into a SHRKS framed container and serves
random-access range queries against it through the frame-cached
RangeQueryBatcher.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced_config
from repro.core import BYTES_PER_ROW, ShrinkConfig, ShrinkStreamCodec
from repro.core.jaxshrink import TensorCodecConfig
from repro.models import build_model
from repro.serving import (
    ContinuousBatcher,
    RangeQuery,
    RangeQueryBatcher,
    Request,
    dequantize_cache,
    quantize_cache,
)


def demo_range_serving():
    """Stream two sensors into one container, then serve range queries."""
    rng = np.random.default_rng(3)
    n = 32_768
    sensors = {
        0: np.round(np.cumsum(rng.standard_normal(n)) * 0.02, 4),       # drift
        1: np.round(np.sin(np.arange(n) * 0.01) * 2
                    + rng.standard_normal(n) * 0.01, 4),                # periodic
    }
    vmin = min(float(v.min()) for v in sensors.values())
    vmax = max(float(v.max()) for v in sensors.values())
    cfg = ShrinkConfig(eps_b=0.05 * (vmax - vmin), lam=1e-4)
    eps = 1e-3 * (vmax - vmin)
    codec = ShrinkStreamCodec(cfg, eps_targets=[eps], backend="rans",
                              value_range=(vmin, vmax), frame_len=4096)
    for c0 in range(0, n, 1024):  # gateway loop: 1k-sample chunks, interleaved
        for sid, v in sensors.items():
            codec.ingest(v[c0 : c0 + 1024], series_id=sid)
    blob = codec.finalize()
    st = codec.stats()
    print(f"\nstreamed {len(sensors)} sensors x {n} samples -> "
          f"{len(blob)/1e3:.1f}KB container ({st['frames']} frames, "
          f"CR={len(sensors)*n*BYTES_PER_ROW/len(blob):.1f}, "
          f"kb entries={st['kb']['entries']})")

    batcher = RangeQueryBatcher(blob, cache_frames=8)
    qrng = np.random.default_rng(4)
    for qid in range(32):
        sid = int(qrng.integers(0, 2))
        t0 = int(qrng.integers(0, n - 512))
        t1 = min(n, t0 + int(qrng.integers(64, 4096)))
        batcher.submit(RangeQuery(qid=qid, series_id=sid, t0=t0, t1=t1, eps=eps))
    done = batcher.run()
    worst = max(float(np.abs(q.result - sensors[q.series_id][q.t0:q.t1]).max())
                for q in done)
    print(f"served {len(done)} range queries: frames decoded="
          f"{batcher.stats['frames_decoded']} cache hits={batcher.stats['frame_hits']}, "
          f"max |err|={worst:.2e} <= eps={eps:.2e}")


def main():
    cfg = reduced_config(ARCHS["qwen3-0.6b"])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    decode = jax.jit(model.decode_step)
    rng = np.random.default_rng(0)

    batcher = ContinuousBatcher(
        decode_fn=lambda t, c, i: decode(params, t, c, i),
        make_caches=lambda: model.make_decode_caches(8, 128),
        n_slots=8,
        eos_token=-1,
    )
    n_requests = 20
    for rid in range(n_requests):
        batcher.submit(Request(
            rid=rid,
            prompt=rng.integers(1, cfg.vocab_size, size=int(rng.integers(4, 12))).astype(np.int32),
            max_new_tokens=8,
        ))
    t0 = time.perf_counter()
    done = batcher.run()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.prompt) + len(r.generated) for r in done)
    print(f"served {len(done)} requests / {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens/dt:.1f} tok/s on 1 CPU core, 8 slots)")
    for r in done[:3]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.generated}")

    # --- SHRINK-quantized KV block ---
    caches = batcher.caches
    c0 = jax.tree.map(lambda a: a[0], caches["groups"]["pos0"])  # first group
    cache = c0["self"]
    q = quantize_cache(cache, TensorCodecConfig(block=128, bits=8))
    back = dequantize_cache(q)
    raw_bits = cache.k.size * 16 + cache.v.size * 16 + cache.kpos.size * 32
    err = float(jnp.max(jnp.abs(back.k.astype(jnp.float32) - cache.k.astype(jnp.float32))))
    print(f"\nquantized KV block: {raw_bits/8/1e3:.1f}KB -> {q.memory_bits()/8/1e3:.1f}KB "
          f"({raw_bits/q.memory_bits():.2f}x), max dequant err {err:.2e}")

    # --- streamed container + range-query serving ---
    demo_range_serving()


if __name__ == "__main__":
    main()
