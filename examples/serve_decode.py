"""Serving example: continuous batching + SHRINK-quantized KV cache.

    PYTHONPATH=src python examples/serve_decode.py

Boots a reduced qwen3-family model, submits a stream of requests through
the continuous batcher (more requests than slots -> slot recycling), then
shows the SHRINK residual-quantized KV block store: ~3.7x cache memory at a
bounded L-infinity error.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced_config
from repro.core.jaxshrink import TensorCodecConfig
from repro.models import build_model
from repro.serving import ContinuousBatcher, Request, dequantize_cache, quantize_cache


def main():
    cfg = reduced_config(ARCHS["qwen3-0.6b"])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    decode = jax.jit(model.decode_step)
    rng = np.random.default_rng(0)

    batcher = ContinuousBatcher(
        decode_fn=lambda t, c, i: decode(params, t, c, i),
        make_caches=lambda: model.make_decode_caches(8, 128),
        n_slots=8,
        eos_token=-1,
    )
    n_requests = 20
    for rid in range(n_requests):
        batcher.submit(Request(
            rid=rid,
            prompt=rng.integers(1, cfg.vocab_size, size=int(rng.integers(4, 12))).astype(np.int32),
            max_new_tokens=8,
        ))
    t0 = time.perf_counter()
    done = batcher.run()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.prompt) + len(r.generated) for r in done)
    print(f"served {len(done)} requests / {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens/dt:.1f} tok/s on 1 CPU core, 8 slots)")
    for r in done[:3]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.generated}")

    # --- SHRINK-quantized KV block ---
    caches = batcher.caches
    c0 = jax.tree.map(lambda a: a[0], caches["groups"]["pos0"])  # first group
    cache = c0["self"]
    q = quantize_cache(cache, TensorCodecConfig(block=128, bits=8))
    back = dequantize_cache(q)
    raw_bits = cache.k.size * 16 + cache.v.size * 16 + cache.kpos.size * 32
    err = float(jnp.max(jnp.abs(back.k.astype(jnp.float32) - cache.k.astype(jnp.float32))))
    print(f"\nquantized KV block: {raw_bits/8/1e3:.1f}KB -> {q.memory_bits()/8/1e3:.1f}KB "
          f"({raw_bits/q.memory_bits():.2f}x), max dequant err {err:.2e}")


if __name__ == "__main__":
    main()
