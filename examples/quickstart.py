"""Quickstart: the SHRINK codec end to end.

    PYTHONPATH=src python examples/quickstart.py

1. Generates an IoT-like series (WindSpeed analogue).
2. Compresses ONCE, decompresses at three resolutions + lossless
   (the paper's multiresolution property).
3. Shows the knowledge base staying small as data grows.
4. Runs the on-device (Pallas) residual-quant kernel on the same data.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.core import ShrinkCodec, cs_to_bytes, original_size_bytes
from repro.data.synthetic import load


def main():
    v = load("WindSpeed", n=200_000)
    rng = float(v.max() - v.min())
    S = original_size_bytes(len(v))
    print(f"series: WindSpeed analogue, n={len(v):,}, range={rng:.2f}, raw={S/1e6:.1f}MB")

    codec = ShrinkCodec.from_fraction(v, frac=0.05, backend="best")
    eps_list = [1e-2 * rng, 1e-3 * rng, 1e-4 * rng]
    cs = codec.compress(v, eps_targets=eps_list + [0.0], decimals=2)

    print(f"\nknowledge base: {cs.base.k} sub-bases from {cs.base.segment_count()} cones "
          f"({len(cs.base_bytes):,} bytes)")
    print(f"{'resolution':>12s} {'size':>12s} {'CR':>8s} {'max err':>12s}")
    for eps in eps_list + [0.0]:
        vhat = codec.decompress_at(cs, eps)
        err = np.max(np.abs(vhat - v))
        sz = cs.size_at(eps)
        print(f"{eps:12.4g} {sz:12,d} {S/sz:8.1f} {err:12.2e}")
    exact = np.array_equal(np.round(codec.decompress_at(cs, 0.0), 2), v)
    print(f"lossless round-trip exact: {exact}")
    blob = cs_to_bytes(cs)
    print(f"full container (all resolutions): {len(blob):,} bytes")

    # --- base stays small as data grows (the scaling claim) ---
    print("\nbase size vs data size:")
    for n in (50_000, 100_000, 200_000):
        vv = load("WindSpeed", n=n)
        cc = ShrinkCodec.from_fraction(vv, frac=0.05, backend="rans")
        cso = cc.compress(vv, eps_targets=[1e-3 * rng])
        print(f"  n={n:8,d}  base={len(cso.base_bytes):8,d}B  "
              f"residuals={cso.pyramid.nbytes():10,d}B")

    # --- the on-device kernel path (interpret mode on CPU) ---
    import jax.numpy as jnp
    from repro.core.jaxshrink import TensorCodecConfig, compress_tensor, decompress_tensor

    x = jnp.asarray(v[:65_536], jnp.float32)
    comp, err_fb = compress_tensor(x, TensorCodecConfig(block=256, bits=8))
    xh = decompress_tensor(comp)
    print(f"\nPallas residual-quant kernel: {comp.wire_bits()/8/1e3:.1f}KB for "
          f"{x.size*4/1e3:.1f}KB f32 ({x.size*32/comp.wire_bits():.2f}x), "
          f"max err {float(jnp.max(jnp.abs(xh - x))):.2e}")


if __name__ == "__main__":
    main()
