"""End-to-end training driver: a small LM through the full framework stack.

    PYTHONPATH=src python examples/train_lm_e2e.py [--steps 200] [--resume]

Exercises: ModelConfig -> build_model -> sharded AdamW train step ->
deterministic TokenPipeline -> TrainingRunner with async SHRINK-compressed
checkpoints -> crash-free resume.  On this container it runs a ~9M-param
qwen3-family model on the single CPU device; the identical code path jits
onto the 256-chip mesh (launch/train.py).
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig
from repro.data.pipeline import TokenPipeline
from repro.models import build_model
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_step import make_train_step
from repro.training.fault_tolerance import TrainingRunner
from repro.launch.mesh import make_local_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args(argv)

    cfg = ModelConfig(
        name="lm-9m", family="dense", n_layers=4, d_model=256, n_heads=8,
        n_kv_heads=4, d_ff=1024, vocab_size=8192, head_dim=32, qk_norm=True,
        tie_embeddings=True,
    )
    model = build_model(cfg)
    mesh = make_local_mesh(1, 1)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model: {cfg.name}  {n_params/1e6:.1f}M params")

    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=20, decay_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, mesh, opt_cfg))
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, batch=args.batch, seq_len=args.seq, seed=7)

    def runner_step(state, batch):
        params, opt = state["params"], state["opt"]
        params, opt, metrics = step_fn(params, opt, batch)
        return {"params": params, "opt": opt}, metrics

    def data_fn(step):
        return jax.tree.map(jnp.asarray, pipe.batch_at(step))

    runner = TrainingRunner(
        runner_step, data_fn,
        {"params": params, "opt": adamw_init(params)},
        ckpt_dir=args.ckpt_dir, ckpt_every=50, codec="shrink:1e-4",
    )
    print(f"starting at step {runner.start_step} (resume-aware)")
    hist = runner.run(args.steps)
    first = np.mean([h["loss"] for h in hist[:10]])
    last = np.mean([h["loss"] for h in hist[-10:]])
    for h in hist[:: max(1, len(hist) // 10)]:
        print(f"  step {h['step']:4d}  loss {h['loss']:.4f}  gnorm {h['grad_norm']:.3f}")
    print(f"\nloss: {first:.4f} -> {last:.4f}  ({'IMPROVED' if last < first else 'no improvement'})")
    assert last < first, "training failed to reduce loss"


if __name__ == "__main__":
    main()
