"""Multi-pod training with the SHRINK-compressed cross-pod exchange.

    PYTHONPATH=src python examples/train_multipod_compressed.py [--steps 30]

Trains the same model twice:

  A. plain f32 cross-pod mean of per-pod gradients
  B. SHRINK exchange: per-block linear base + int8 residuals quantized on a
     pod-shared step, error feedback carried across steps (the paper's
     two-phase decomposition on the DCN wire)

and prints both loss curves + the wire bytes.  The point: ~4x less
cross-pod traffic with indistinguishable convergence.

NOTE: this container exposes ONE physical core; XLA:CPU's collective
rendezvous deadlocks when several virtual device threads time-share it, so
the exchange here runs in single-device EMULATION (bit-identical math to
``training.grad_compress._compress_leaf``: shared quantization step across
pods, per-pod int8 residuals, summed then dequantized).  The real
shard_map collective version of the same code is exercised by
``python -m repro.launch.dryrun --multi-pod --compressed`` (512 devices)
and unit-tested in tests/test_sharding.py.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig
from repro.data.pipeline import TokenPipeline
from repro.models import build_model
from repro.training.grad_compress import GradCompressConfig, compression_wire_bytes
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update, clip_by_global_norm

N_PODS = 2


def emulated_exchange(grads_stacked, ef, cfg: GradCompressConfig):
    """Single-device emulation of the compressed pod exchange: same math as
    grad_compress._compress_leaf, with the psum/pmax/all_gather replaced by
    explicit axis-0 reductions over the pod dim."""
    from repro.core.jaxshrink import linear_base_fit

    def one(gs, e):  # gs [P, ...], e [...]
        p = gs.shape[0]
        flat = gs.astype(jnp.float32).reshape(p, -1) + e.reshape(1, -1)
        size = flat.shape[1]
        pad = (-size) % cfg.block
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros((p, pad), jnp.float32)], axis=1)
        xb = flat.reshape(p, -1, cfg.block)
        theta, slope = jax.vmap(linear_base_fit)(xb)
        theta = theta.astype(jnp.bfloat16).astype(jnp.float32)
        slope = slope.astype(jnp.bfloat16).astype(jnp.float32)
        t = jnp.arange(cfg.block, dtype=jnp.float32)[None, None, :]
        r = xb - (theta + slope * t)
        step = jnp.max(jnp.abs(r), axis=(0, 2), keepdims=True) / cfg.qmax  # pod-shared
        step = jnp.maximum(step, 1e-12)
        q = jnp.clip(jnp.round(r / step), -cfg.qmax, cfg.qmax).astype(jnp.int8)
        local_deq = theta + slope * t + q.astype(jnp.float32) * step
        new_ef = (xb[0] - local_deq[0]).reshape(-1)[:size].reshape(e.shape)
        base_sum = theta.sum(0) + slope.sum(0) * t[0]
        g_sum = base_sum + q.astype(jnp.float32).sum(0) * step[0]
        return (g_sum.reshape(-1)[:size].reshape(gs.shape[1:]) / p), new_ef

    outs = [one(g, e) for g, e in zip(jax.tree.leaves(grads_stacked), jax.tree.leaves(ef))]
    td = jax.tree.structure(ef)
    return (
        jax.tree.unflatten(td, [o[0] for o in outs]),
        jax.tree.unflatten(td, [o[1] for o in outs]),
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args(argv)

    cfg = ModelConfig(
        name="lm-2m", family="dense", n_layers=2, d_model=96, n_heads=4,
        n_kv_heads=2, d_ff=384, vocab_size=2048, head_dim=24,
    )
    model = build_model(cfg)
    params0 = model.init(jax.random.PRNGKey(0))
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, batch=8, seq_len=128, seed=3)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=5, decay_steps=args.steps)
    comp_cfg = GradCompressConfig(block=256, bits=8, min_leaf_size=0)

    @jax.jit
    def pod_grads(params, batch):
        def one(b):
            return jax.value_and_grad(lambda p: model.loss(p, b)[0])(params)
        return jax.vmap(one)(batch)

    exchange_c = jax.jit(lambda g, e: emulated_exchange(g, e, comp_cfg))

    @jax.jit
    def exchange_p(g, e):
        return jax.tree.map(lambda x: x.astype(jnp.float32).mean(0), g), e

    def run(compressed: bool):
        params = jax.tree.map(jnp.copy, params0)
        opt = adamw_init(params)
        ef = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        losses = []
        for step in range(args.steps):
            gb = pipe.batch_at(step)
            batch = jax.tree.map(
                lambda a: jnp.asarray(a).reshape(N_PODS, -1, *a.shape[1:]), gb
            )
            losses_pod, grads_stacked = pod_grads(params, batch)
            grads, ef = (exchange_c if compressed else exchange_p)(grads_stacked, ef)
            grads, _ = clip_by_global_norm(grads, opt_cfg.grad_clip)
            params, opt = adamw_update(opt_cfg, params, grads, opt)
            losses.append(float(jnp.mean(losses_pod)))
        return losses

    print(f"training {N_PODS} pods (emulated exchange), ~1.6M params ...")
    plain = run(False)
    comp = run(True)
    cb, rb = compression_wire_bytes(jax.tree.leaves(params0), comp_cfg)
    print(f"\n{'step':>4s} {'plain':>9s} {'compressed':>11s}")
    for i in range(0, args.steps, max(1, args.steps // 10)):
        print(f"{i:4d} {plain[i]:9.4f} {comp[i]:11.4f}")
    print(f"\nfinal loss: plain {plain[-1]:.4f}  compressed {comp[-1]:.4f} "
          f"(gap {abs(plain[-1]-comp[-1]):.4f})")
    print(f"cross-pod wire: {rb/1e6:.2f}MB f32 -> {cb/1e6:.2f}MB SHRINK ({rb/cb:.2f}x)")
    assert comp[-1] < comp[0], "compressed run failed to learn"


if __name__ == "__main__":
    main()
