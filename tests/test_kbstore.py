"""Deterministic contract for the persistent cross-archive KB store.

Pins the full lifecycle: exact attach/detach reference accounting
(replace semantics under a stable handle), typed release errors, LRU
eviction with pinning, snapshot sealing + ref resolution (including the
stale-ref proofs), byte-identical compaction re-basing, spill/load
round-trips, the reader fallback ladder, and the fleet/codec/batcher
integration points."""
import numpy as np
import pytest

from repro.core import ShrinkConfig, ShrinkStreamCodec, decode_series
from repro.core.errors import (
    ConfigError,
    KBReferenceError,
    ShrinkError,
    StaleSnapshotError,
)
from repro.core.serialize import (
    KBSnapshotRef,
    parse_framed_container,
    read_snapshot_ref,
)
from repro.core.semantics import global_range
from repro.core.streaming import KnowledgeBase, routing_metadata
from repro.serving import KBStore, RaggedBatcher, ShrinkFleet
from repro.serving.batching import RangeQueryBatcher
from repro.serving.kbstore import (
    resolve_container_kb,
    snapshot_from_bytes,
    snapshot_to_bytes,
)

_RNG = np.random.default_rng(42)
_CFG = ShrinkConfig(eps_b=0.5, lam=1e-4)
_EPS = [0.5, 0.05, 0.0]
_DEC = 4  # every generated series lands on a 4-decimal grid


def _walk(n: int) -> np.ndarray:
    return np.round(np.cumsum(_RNG.standard_normal(n) * 0.1), 4)


def _motif_series(n: int, seed: int) -> np.ndarray:
    """Series tiling a tiny shared motif bank — guarantees cross-archive
    KB line repetition (the store's reason to exist)."""
    rng = np.random.default_rng(seed % 4)  # few distinct banks => overlap
    bank = [np.round(rng.standard_normal(32) * 2.0, 2) for _ in range(4)]
    rng2 = np.random.default_rng(seed)
    out = np.concatenate([bank[rng2.integers(0, 4)] for _ in range(n // 32 + 1)])
    return out[:n]


def _codec_kb(v: np.ndarray) -> KnowledgeBase:
    sc = ShrinkStreamCodec(
        _CFG, eps_targets=_EPS, decimals=_DEC, value_range=global_range(v),
        frame_len=256,
    )
    sc.ingest(v)
    sc.finalize()
    return sc.kb


def _ref_codec(store, v, source, inline=None):
    sc = ShrinkStreamCodec(
        _CFG, eps_targets=_EPS, decimals=_DEC, value_range=global_range(v),
        frame_len=256, kb_store=store, inline_kb=inline, source=source,
    )
    sc.ingest(v)
    return sc, sc.finalize()


class TestAttachDetach:
    def test_attach_detach_exact_reversal(self):
        store = KBStore(_CFG)
        kb1 = _codec_kb(_motif_series(512, seed=1))
        kb2 = _codec_kb(_motif_series(512, seed=2))
        r1 = store.attach_kb(kb1, source="a")
        before = store.stats()
        r2 = store.attach_kb(kb2, source="b")
        store.detach(r2.handle)
        after = store.stats()
        assert after["total_refs"] == before["total_refs"]
        assert after["live"] >= before["live"]  # b's novel lines drop to 0 refs
        store.detach(r1.handle)
        assert store.stats()["total_refs"] == 0

    def test_reattach_same_source_replaces_not_doubles(self):
        store = KBStore(_CFG)
        kb = _codec_kb(_motif_series(512, seed=3))
        store.attach_kb(kb, source="shard0")
        once = store.stats()["total_refs"]
        for _ in range(3):
            store.attach_kb(kb, source="shard0")
        assert store.stats()["total_refs"] == once
        assert len(store._handles) == 1

    def test_attach_dedups_identical_lines(self):
        store = KBStore(_CFG)
        kb = _codec_kb(_motif_series(512, seed=4))
        store.attach_kb(kb, source="a")
        live_once = store.live_count
        store.attach_kb(kb, source="b")  # identical KB, different handle
        assert store.live_count == live_once  # no new lines
        assert store.stats()["dedup_ratio"] > 1.0

    def test_detach_unknown_handle_typed(self):
        store = KBStore(_CFG)
        with pytest.raises(KBReferenceError):
            store.detach("nope")

    def test_attach_whole_container(self):
        store = KBStore(_CFG)
        v = _motif_series(512, seed=5)
        sc = ShrinkStreamCodec(
            _CFG, eps_targets=_EPS, decimals=_DEC, value_range=global_range(v),
            frame_len=256,
        )
        sc.ingest(v)
        blob = sc.finalize()
        rec = store.attach(blob, source="ar0")
        assert store.container(rec.handle) == blob
        assert store.stats()["total_refs"] > 0

    def test_config_mismatch_rejected(self):
        store = KBStore(_CFG)
        kb = KnowledgeBase(ShrinkConfig(eps_b=9.0, lam=1e-4))
        with pytest.raises(ConfigError):
            store.attach_kb(kb)


class TestReleaseTyped:
    """Satellite: KnowledgeBase.release failures must be a typed
    ShrinkError subclass carrying the offending entry id."""

    def test_release_underflow_typed_with_entry_context(self):
        kb = _codec_kb(_motif_series(256, seed=6))
        eid = 0
        kb.release([eid] * kb.entries[eid].refs)  # drain to zero
        with pytest.raises(KBReferenceError) as ei:
            kb.release([eid])
        assert isinstance(ei.value, ShrinkError)
        assert ei.value.context()["entry"] == eid
        assert f"entry={eid}" in str(ei.value)

    def test_release_out_of_range_typed(self):
        kb = _codec_kb(_motif_series(256, seed=7))
        bad = len(kb.entries) + 5
        with pytest.raises(KBReferenceError) as ei:
            kb.release([bad])
        assert ei.value.context()["entry"] == bad


class TestEviction:
    def test_zero_ref_entries_evicted_lru(self):
        store = KBStore(_CFG, max_entries=4)
        kb = _codec_kb(_motif_series(2048, seed=8))
        assert len(kb.entries) > 4
        rec = store.attach_kb(kb, source="a")
        assert store.live_count > 4  # pinned by the live attachment: soft bound
        store.detach(rec.handle)
        assert store.live_count <= 4
        assert store.counters["evictions"] > 0
        # eviction only touched zero-ref entries
        for eid in store._tombstones:
            assert store.kb.entries[eid].refs == 0

    def test_eviction_tombstones_never_shift_ids(self):
        store = KBStore(_CFG, max_entries=2)
        kb1 = _codec_kb(_motif_series(1024, seed=9))
        rec1 = store.attach_kb(kb1, source="a")
        n_before = len(store.kb.entries)
        store.detach(rec1.handle)
        # tombstoning must not shrink the positional id space
        assert len(store.kb.entries) == n_before

    def test_pinned_entries_survive_eviction(self):
        store = KBStore(_CFG, max_entries=1)
        kb = _codec_kb(_motif_series(1024, seed=10))
        rec = store.attach_kb(kb, source="a")
        # live attachment pins every remapped id even at zero refs
        for rid in store._remaps[rec.handle]:
            assert rid not in store._tombstones


class TestSnapshots:
    def test_snapshot_roundtrip(self):
        store = KBStore(_CFG)
        store.attach_kb(_codec_kb(_motif_series(512, seed=11)), source="a")
        snap = store.snapshots[-1]
        version, sem, master, tombs = snapshot_from_bytes(snap.blob)
        assert (version, sem) == (snap.version, snap.sem_id)
        assert len(master.entries) == snap.entries
        assert tombs == set()

    def test_snapshot_roundtrip_with_tombstones(self):
        live = _codec_kb(_motif_series(512, seed=12))
        tombs = [1, 4, 5]
        blob = snapshot_to_bytes(7, live.snapshot_id(), live, tombs)
        version, sem, master, got_tombs = snapshot_from_bytes(blob)
        assert version == 7 and got_tombs == set(tombs)
        assert len(master.entries) == len(live.entries) + len(tombs)
        # live entries keep their gap-adjusted positional slots
        live_ids = [i for i in range(len(master.entries)) if i not in got_tombs]
        for slot, e in zip(live_ids, live.entries):
            assert master.entries[slot] == e

    def test_resolve_proves_ref(self):
        store = KBStore(_CFG)
        kb = _codec_kb(_motif_series(512, seed=13))
        rec = store.attach_kb(kb, source="a")
        resolved = store.container_kb(rec.ref)
        assert resolved.canonical() == kb.canonical()
        assert [e.refs for e in resolved.entries] == [e.refs for e in kb.entries]

    def test_unknown_version_stale(self):
        store = KBStore(_CFG)
        rec = store.attach_kb(_codec_kb(_motif_series(512, seed=14)), source="a")
        bad = KBSnapshotRef(
            version=rec.ref.version + 99, entries=rec.ref.entries,
            sem_id=rec.ref.sem_id, remap=rec.ref.remap, refs=rec.ref.refs,
        )
        with pytest.raises(StaleSnapshotError):
            store.resolve(bad)

    def test_sem_id_mismatch_stale(self):
        store = KBStore(_CFG)
        rec = store.attach_kb(_codec_kb(_motif_series(512, seed=15)), source="a")
        bad = KBSnapshotRef(
            version=rec.ref.version, entries=rec.ref.entries,
            sem_id=rec.ref.sem_id ^ 0xFFFF, remap=rec.ref.remap, refs=rec.ref.refs,
        )
        with pytest.raises(StaleSnapshotError):
            store.resolve(bad)


class TestRefContainers:
    def test_ref_mode_omits_inline_kb_and_decodes(self):
        store = KBStore(_CFG)
        v = _motif_series(768, seed=16)
        sc, blob = _ref_codec(store, v, source="ar0")
        _, kb_bytes = parse_framed_container(blob)
        assert kb_bytes == b""  # the cross-archive byte win
        assert read_snapshot_ref(blob) is not None
        got = np.round(decode_series(blob, 0, 0.0), 4)
        assert np.array_equal(got, v)

    def test_ref_mode_smaller_than_inline(self):
        store = KBStore(_CFG)
        v = _motif_series(768, seed=17)
        _, ref_blob = _ref_codec(store, v, source="ar0")
        sc2 = ShrinkStreamCodec(
            _CFG, eps_targets=_EPS, decimals=_DEC, value_range=global_range(v),
            frame_len=256,
        )
        sc2.ingest(v)
        inline_blob = sc2.finalize()
        assert len(ref_blob) < len(inline_blob)

    def test_container_kb_matches_writer_kb(self):
        store = KBStore(_CFG)
        v = _motif_series(768, seed=18)
        sc, blob = _ref_codec(store, v, source="ar0")
        kb, origin = resolve_container_kb(blob, store)
        assert origin == "store"
        assert kb.canonical() == sc.kb.canonical()
        assert [e.refs for e in kb.entries] == [e.refs for e in sc.kb.entries]

    def test_both_mode_keeps_inline_and_ref(self):
        store = KBStore(_CFG)
        v = _motif_series(768, seed=19)
        _, blob = _ref_codec(store, v, source="ar0", inline=True)
        _, kb_bytes = parse_framed_container(blob)
        assert kb_bytes and read_snapshot_ref(blob) is not None

    def test_inline_false_without_store_rejected(self):
        with pytest.raises(ConfigError):
            ShrinkStreamCodec(_CFG, eps_targets=_EPS, inline_kb=False)

    def test_refinalize_does_not_double_count(self):
        store = KBStore(_CFG)
        v = _motif_series(768, seed=20)
        sc, blob1 = _ref_codec(store, v, source="ar0")
        once = store.stats()["total_refs"]
        blob2 = sc.finalize()  # replace semantics under the stable handle
        assert store.stats()["total_refs"] == once
        assert np.array_equal(
            decode_series(blob2, 0, 0.0), decode_series(blob1, 0, 0.0)
        )

    def test_routing_metadata_exposes_ref(self):
        store = KBStore(_CFG)
        v = _motif_series(768, seed=21)
        _, blob = _ref_codec(store, v, source="ar0")
        md = routing_metadata(blob)
        assert md["kb_ref"] is not None
        assert md["kb_ref"]["version"] == read_snapshot_ref(blob).version

    def test_resolve_ladder(self):
        store = KBStore(_CFG)
        v = _motif_series(768, seed=22)
        _, ref_only = _ref_codec(store, v, source="a")
        _, both = _ref_codec(store, v, source="b", inline=True)
        sc3 = ShrinkStreamCodec(
            _CFG, eps_targets=_EPS, decimals=_DEC, value_range=global_range(v),
            frame_len=256,
        )
        sc3.ingest(v)
        inline_only = sc3.finalize()
        assert resolve_container_kb(ref_only, store)[1] == "store"
        assert resolve_container_kb(both, None)[1] == "inline"
        assert resolve_container_kb(inline_only, store)[1] == "inline"
        with pytest.raises(StaleSnapshotError):  # ref-only, no store
            resolve_container_kb(ref_only, None)


class TestCompaction:
    def test_compact_rebases_byte_identical_decode(self):
        store = KBStore(_CFG)
        v1 = _motif_series(768, seed=23)
        v2 = _motif_series(768, seed=24)
        sc1, blob1 = _ref_codec(store, v1, source="a")
        sc2, blob2 = _ref_codec(store, v2, source="b")
        dec1 = decode_series(blob1, 0, 0.0)
        store.detach(sc2._store_handle)  # orphan b's lines
        rep = store.compact()
        assert rep["dropped"] >= 0
        new_blob = store.container("a")
        assert np.array_equal(decode_series(new_blob, 0, 0.0), dec1)
        new_ref = read_snapshot_ref(new_blob)
        assert new_ref.version == rep["version"]
        kb = store.container_kb(new_ref)
        assert kb.canonical() == sc1.kb.canonical()

    def test_compact_retires_old_refs_by_design(self):
        store = KBStore(_CFG)
        v = _motif_series(768, seed=25)
        _, blob = _ref_codec(store, v, source="a")
        old_ref = read_snapshot_ref(blob)
        store.compact()
        with pytest.raises(StaleSnapshotError):
            store.resolve(old_ref)

    def test_compact_drops_tombstones(self):
        store = KBStore(_CFG, max_entries=2)
        rec = store.attach_kb(_codec_kb(_motif_series(1024, seed=26)), source="a")
        store.detach(rec.handle)
        assert store._tombstones or store.counters["evictions"] == 0
        store.compact()
        assert store._tombstones == set()
        assert len(store.kb.entries) == store.live_count


class TestSpillLoad:
    def test_spill_load_roundtrip(self, tmp_path):
        store = KBStore(_CFG)
        v = _motif_series(768, seed=27)
        _, blob = _ref_codec(store, v, source="a")
        paths = store.spill(tmp_path)
        assert paths and all(p.endswith(".shks") for p in paths)
        loaded = KBStore.load(tmp_path)
        assert loaded.sem_id() == store.sem_id()
        ref = read_snapshot_ref(blob)
        kb = loaded.container_kb(ref)
        assert kb.canonical() == store.container_kb(ref).canonical()

    def test_load_empty_dir_rejected(self, tmp_path):
        from repro.core.errors import FormatError

        with pytest.raises(FormatError):
            KBStore.load(tmp_path)

    def test_load_continues_version_counter(self, tmp_path):
        store = KBStore(_CFG)
        store.attach_kb(_codec_kb(_motif_series(512, seed=28)), source="a")
        store.spill(tmp_path)
        loaded = KBStore.load(tmp_path)
        rec = loaded.attach_kb(_codec_kb(_motif_series(512, seed=29)), source="b")
        assert rec.ref.version > store.snapshots[-1].version


class TestIntegration:
    def test_ragged_batcher_ref_mode(self):
        store = KBStore(_CFG)
        b = RaggedBatcher(
            _CFG, eps_targets=_EPS, decimals=_DEC, flush_samples=None,
            kb_store=store, source="rag0",
        )
        series = {0: _motif_series(300, seed=30), 1: _motif_series(70, seed=31)}
        for sid, v in series.items():
            b.submit(sid, v)
        blob = b.finalize()
        _, kb_bytes = parse_framed_container(blob)
        assert kb_bytes == b"" and read_snapshot_ref(blob) is not None
        for sid, v in series.items():
            assert np.array_equal(np.round(decode_series(blob, sid, 0.0), 4), v)
        assert store.container("rag0") == blob

    def test_range_query_batcher_kb_source(self):
        store = KBStore(_CFG)
        v = _motif_series(512, seed=32)
        _, blob = _ref_codec(store, v, source="a")
        rb = RangeQueryBatcher(blob, kb_store=store)
        assert rb.stats["kb_source"] == "store"
        rb2 = RangeQueryBatcher(blob)
        assert rb2.stats["kb_source"] == "ref-unresolved"

    def test_fleet_gossip_feeds_store(self):
        store = KBStore(_CFG)
        fleet = ShrinkFleet(
            _CFG, eps_targets=_EPS, decimals=_DEC, n_shards=2,
            kb_sync_every=None, kb_store=store,
        )
        for sid in range(6):
            fleet.submit(sid, _motif_series(200, seed=33 + sid))
        fleet.seal()
        rec = fleet.kb_syncs[-1]
        assert rec["store"]["live"] == store.live_count
        # shards are the store's only sources: its semantic id equals the
        # merged global KB's snapshot id exactly
        assert rec["store"]["sem_id"] == fleet.global_kb.snapshot_id()
        # repeat sync: replace semantics keep refs conserved
        refs_once = store.stats()["total_refs"]
        fleet.sync_kbs()
        assert store.stats()["total_refs"] == refs_once
