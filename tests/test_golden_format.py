"""Golden-format regression: the SHRK / SHRKS wire formats must be stable
across PRs.

The fixtures under tests/golden/ were produced by tests/golden/regen.py
from a closed-form (RNG-free) series; this test rebuilds them from the
current code and asserts byte equality.  If this fails, either you broke
the wire format accidentally (fix the code), or you changed it ON PURPOSE
— in that case bump the format version in serialize.py, rename the
fixtures to the new version, and rerun ``PYTHONPATH=src python
tests/golden/regen.py`` (full procedure in that file's docstring).
"""
import importlib.util
import pathlib

import numpy as np
import pytest

_REGEN = pathlib.Path(__file__).resolve().parent / "golden" / "regen.py"
_spec = importlib.util.spec_from_file_location("golden_regen", _REGEN)
golden = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(golden)


def _fixture(path):
    if not path.exists():
        pytest.fail(
            f"missing golden fixture {path.name}; run "
            "`PYTHONPATH=src python tests/golden/regen.py` and commit it"
        )
    return path.read_bytes()


def test_shrk_bytes_stable():
    expected = _fixture(golden.GOLDEN_SHRK)
    got = golden.build_shrk()
    assert got == expected, (
        "SHRK container bytes changed — wire-format regression "
        "(see tests/golden/regen.py for the intentional-change procedure)"
    )


def test_shrks_bytes_stable():
    expected = _fixture(golden.GOLDEN_SHRKS)
    got = golden.build_shrks()
    assert got == expected, (
        "SHRKS framed container bytes changed — wire-format regression "
        "(see tests/golden/regen.py for the intentional-change procedure)"
    )


def test_ragged_shrks_bytes_stable():
    expected = _fixture(golden.GOLDEN_RAGGED)
    got = golden.build_ragged_shrks()
    assert got == expected, (
        "ragged SHRKS container bytes changed — wire-format or ragged-batch "
        "regression (see tests/golden/regen.py for the intentional-change "
        "procedure)"
    )


def test_pyramid_shrk_bytes_stable():
    expected = _fixture(golden.GOLDEN_PYRAMID)
    got = golden.build_pyramid_shrk()
    assert got == expected, (
        "4-tier pyramid SHRK bytes changed — wire-format regression "
        "(see tests/golden/regen.py for the intentional-change procedure)"
    )


def test_pyramid_golden_fixture_still_decodes_every_tier():
    """The checked-in 4-tier archive must decode at every tier within that
    tier's guarantee, and bit-exactly at the lossless tier — guards the
    layer-prefix decoder against misreading old pyramid data."""
    import numpy as np

    from repro.core import cs_from_bytes
    from repro.core.shrink import decompress_at

    v = golden.golden_series()
    cs = cs_from_bytes(_fixture(golden.GOLDEN_PYRAMID))
    tiers = golden.pyramid_tiers(v)
    assert cs.tiers() == tiers
    assert cs.pyramid.layers[0].mode == "identity"  # 1e-1·range > epŝ_b
    for eps in tiers[:-1]:
        err = np.max(np.abs(decompress_at(cs, eps) - v))
        assert err <= eps * (1 + 1e-9), eps
    assert np.array_equal(np.round(decompress_at(cs, 0.0), golden.DECIMALS), v)


def test_ragged_golden_fixture_still_decodes():
    """The checked-in ragged container must reconstruct every series from
    its two frames — guards the decoder against misreading old ragged
    data even if re-encoding happens to match."""
    from repro.core import decode_series

    blob = _fixture(golden.GOLDEN_RAGGED)
    for sid, v in enumerate(golden.golden_ragged_series()):
        got = np.round(decode_series(blob, sid, 0.0), golden.DECIMALS)
        assert np.array_equal(got, v), sid


def test_analytics_answers_stable():
    """Compressed-domain query answers over the checked-in archives must
    not drift: interval bounds, achieved guarantees, planner frame
    accounting, and top-k segment records are all pinned.  A wire-format
    change, a bound-composition change, or a planner change that moves ANY
    of them fails here loudly (regen via tests/golden/regen.py if the
    change is intentional)."""
    import json

    path = golden.GOLDEN_ANALYTICS
    if not path.exists():
        pytest.fail(
            "missing golden fixture golden_analytics.json; run "
            "`PYTHONPATH=src python tests/golden/regen.py` and commit it"
        )
    expected = json.loads(path.read_text())
    got = json.loads(json.dumps(golden.build_analytics()))  # normalize floats
    assert got == expected, (
        "compressed-domain analytics answers changed over the golden "
        "archives — engine/bound/planner regression (see tests/golden/"
        "regen.py for the intentional-change procedure)"
    )


def test_kbstore_fixtures_bytes_stable():
    expected_ref = _fixture(golden.GOLDEN_REF)
    expected_snap = _fixture(golden.GOLDEN_KBSTORE)
    got_ref, got_snap = golden.build_kbstore()
    assert got_ref == expected_ref, (
        "KB-store-attached SHRKS bytes changed — kb_snapshot_ref footer "
        "regression (see tests/golden/regen.py for the intentional-change "
        "procedure)"
    )
    assert got_snap == expected_snap, (
        "SHKS store snapshot bytes changed — snapshot-layout regression "
        "(see tests/golden/regen.py for the intentional-change procedure)"
    )


def test_kbstore_golden_fixtures_still_resolve(tmp_path):
    """The checked-in ref container must decode, and its kb_snapshot_ref
    must resolve against a store rebuilt from the checked-in SHKS blob to
    the exact inline footer KB — guards both decoders against misreading
    old store data even if re-encoding happens to match."""
    from repro.core import decode_series
    from repro.core.serialize import parse_framed_container, read_snapshot_ref
    from repro.core.streaming import KnowledgeBase
    from repro.serving.kbstore import KBStore, snapshot_from_bytes

    blob = _fixture(golden.GOLDEN_REF)
    v = golden.golden_series()
    got = np.round(decode_series(blob, 0, 0.0), golden.DECIMALS)
    assert np.array_equal(got, v)

    snap_blob = _fixture(golden.GOLDEN_KBSTORE)
    version, sem_id, master, tombs = snapshot_from_bytes(snap_blob)
    assert tombs == set()
    assert master.snapshot_id() == sem_id

    (tmp_path / f"kbsnap_v{version:08d}.shks").write_bytes(snap_blob)
    store = KBStore.load(tmp_path)
    ref = read_snapshot_ref(blob)
    assert ref is not None and ref.version == version
    resolved = store.container_kb(ref)
    _, inline_bytes = parse_framed_container(blob)
    inline = KnowledgeBase.from_bytes(inline_bytes)
    assert resolved.canonical() == inline.canonical()
    assert [e.refs for e in resolved.entries] == [e.refs for e in inline.entries]


def test_golden_fixture_still_decodes():
    """The checked-in container (not the rebuilt one) must decode: guards
    the decoder against changes that re-encode identically but misread
    old data."""
    from repro.core import cs_from_bytes, decode_series
    from repro.core.shrink import decompress_at

    v = golden.golden_series()
    cs = cs_from_bytes(_fixture(golden.GOLDEN_SHRK))
    assert np.array_equal(
        np.round(decompress_at(cs, 0.0), golden.DECIMALS), v
    )
    full = decode_series(_fixture(golden.GOLDEN_SHRKS), 0, 0.0)
    assert np.array_equal(np.round(full, golden.DECIMALS), v)
