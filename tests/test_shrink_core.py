"""Unit tests for the SHRINK codec: error guarantees, multiresolution,
lossless round-trip, serialization, and the adaptive threshold mechanics."""
import math

import numpy as np
import pytest

from repro.core import (
    Base,
    ShrinkCodec,
    ShrinkConfig,
    base_predictions,
    construct_base,
    cs_from_bytes,
    cs_to_bytes,
    default_interval_length,
    eps_hat_for_level,
    extract_semantics,
    extract_semantics_py,
    optimized_slope,
    practical_eps_b,
    shortest_decimal_in_interval,
)
from repro.core.serialize import decode_base, encode_base
from repro.data.synthetic import load


def _series(n=20_000, seed=0, decimals=4):
    rng = np.random.default_rng(seed)
    t = np.arange(n)
    v = (
        np.sin(t * 0.01) * 3
        + 0.5 * np.sin(t * 0.002)
        + rng.normal(0, 0.05, n)
    )
    return np.round(v, decimals)


# ---------------------------------------------------------------- semantics
def test_vectorized_scan_matches_reference_loop():
    v = _series(3000)
    cfg = ShrinkConfig(eps_b=0.2, lam=1e-4)
    fast = extract_semantics(v, cfg)
    slow = extract_semantics_py(v, cfg)
    assert len(fast) == len(slow)
    for a, b in zip(fast, slow):
        assert a.t0 == b.t0 and a.length == b.length
        assert a.theta == pytest.approx(b.theta)
        assert a.level == b.level
        if math.isfinite(a.psi_lo):
            assert a.psi_lo == pytest.approx(b.psi_lo)
            assert a.psi_hi == pytest.approx(b.psi_hi)


def test_segments_partition_series():
    v = _series(5000, seed=3)
    cfg = ShrinkConfig(eps_b=0.1)
    segs = extract_semantics(v, cfg)
    cursor = 0
    for s in segs:
        assert s.t0 == cursor
        assert s.length >= 1
        cursor += s.length
    assert cursor == len(v)


def test_cone_covers_points_within_eps_hat():
    """Any slope inside the final span approximates all points within eps_hat."""
    v = _series(2000, seed=5)
    cfg = ShrinkConfig(eps_b=0.3)
    for s in extract_semantics(v, cfg):
        if s.length < 2:
            continue
        eps_hat = eps_hat_for_level(s.level, cfg)
        mid = 0.5 * (s.psi_lo + s.psi_hi)
        t = np.arange(s.length)
        approx = s.theta + mid * t
        err = np.max(np.abs(v[s.t0 : s.t0 + s.length] - approx))
        assert err <= eps_hat * (1 + 1e-9) + 1e-12


def test_adaptive_threshold_direction():
    """High fluctuation -> tighter threshold (Eq. 4)."""
    cfg = ShrinkConfig(eps_b=1.0, beta_levels=16)
    assert eps_hat_for_level(16, cfg) < eps_hat_for_level(0, cfg)
    assert eps_hat_for_level(0, cfg) == pytest.approx(math.exp(2 / 3))
    assert eps_hat_for_level(16, cfg) == pytest.approx(math.exp(2 / 3 - 1))


def test_interval_length_formula():
    cfg = ShrinkConfig(eps_b=0.5, lam=1e-4)
    assert default_interval_length(100_000, cfg) == int(1e-4 * 100_000 * 0.5)
    # clamped below
    assert default_interval_length(10, cfg) == cfg.min_interval


# ---------------------------------------------------------------- slope
def test_shortest_decimal_in_interval():
    v, d = shortest_decimal_in_interval(0.12385382, 0.12389554)
    assert 0.12385382 <= v <= 0.12389554
    assert d <= 5  # the paper's example yields 5 digits
    v, d = shortest_decimal_in_interval(0.94, 1.06)
    assert v == pytest.approx(1.0) and d == 0
    # adjacent-digit case that breaks the literal Alg. 5
    v, d = shortest_decimal_in_interval(0.1258, 0.1263)
    assert 0.1258 <= v <= 0.1263


def test_optimized_slope_degenerate():
    assert optimized_slope(-math.inf, math.inf) == (0.0, 0)
    s, _ = optimized_slope(0.5, 0.5)
    assert s == 0.5


# ---------------------------------------------------------------- base
def test_base_merge_reduces_subbases():
    v = _series(20_000)
    cfg = ShrinkConfig(eps_b=0.3)
    segs = extract_semantics(v, cfg)
    base = construct_base(segs, len(v), float(v.min()), float(v.max()), cfg)
    assert base.k <= len(segs)
    assert base.segment_count() == len(segs)


def test_base_serialization_roundtrip():
    v = _series(10_000, seed=7)
    cfg = ShrinkConfig(eps_b=0.25)
    segs = extract_semantics(v, cfg)
    base = construct_base(segs, len(v), float(v.min()), float(v.max()), cfg)
    blob = encode_base(base)
    base2 = decode_base(blob)
    np.testing.assert_allclose(base_predictions(base), base_predictions(base2), rtol=0, atol=1e-12)


def test_practical_eps_bounded():
    """Base-only error is bounded by max eps_hat + slope-truncation slack."""
    v = _series(30_000, seed=11)
    codec = ShrinkCodec.from_fraction(v, frac=0.05)
    base = codec.build_base(v)
    eps_hat_max = eps_hat_for_level(0, codec.config)
    assert practical_eps_b(v, base) <= eps_hat_max * (1 + 1e-6)


# ---------------------------------------------------------------- codec
@pytest.mark.parametrize("eps", [1e-1, 1e-2, 1e-3, 1e-4])
def test_linf_guarantee(eps):
    v = _series(20_000, seed=13)
    codec = ShrinkCodec.from_fraction(v, frac=0.05)
    cs = codec.compress(v, eps_targets=[eps])
    vhat = codec.decompress_at(cs, eps)
    if cs.pyramid.layers[0].mode == "identity":
        assert np.max(np.abs(vhat - v)) <= cs.eps_b_practical * (1 + 1e-9)
    else:
        assert np.max(np.abs(vhat - v)) <= eps * (1 + 1e-9)


def test_lossless_roundtrip_decimal_grid():
    for name, decimals in [("WindSpeed", 2), ("Pressure", 5)]:
        v = load(name, n=20_000)
        codec = ShrinkCodec.from_fraction(v, frac=0.05)
        cs = codec.compress(v, eps_targets=[0.0], decimals=decimals)
        vhat = codec.decompress_at(cs, 0.0)
        assert np.array_equal(np.round(vhat, decimals), v)


def test_multiresolution_single_base():
    """One base serves many eps; finer eps -> larger stream, smaller error."""
    v = _series(30_000, seed=17)
    codec = ShrinkCodec.from_fraction(v, frac=0.05)
    eps_list = [1e-2, 1e-3, 1e-4]
    cs = codec.compress(v, eps_targets=eps_list)
    sizes = [cs.size_at(e) for e in eps_list]
    assert sizes == sorted(sizes)  # finer -> bigger
    errs = [np.max(np.abs(codec.decompress_at(cs, e) - v)) for e in eps_list]
    tol = 1 + 1e-9
    assert errs[0] <= 1e-2 * tol and errs[1] <= 1e-3 * tol and errs[2] <= 1e-4 * tol


def test_container_roundtrip():
    v = _series(5000, seed=19)
    codec = ShrinkCodec.from_fraction(v, frac=0.05)
    cs = codec.compress(v, eps_targets=[1e-2, 0.0], decimals=4)
    blob = cs_to_bytes(cs)
    cs2 = cs_from_bytes(blob)
    np.testing.assert_allclose(
        codec.decompress_at(cs2, 1e-2), codec.decompress_at(cs, 1e-2), atol=0
    )
    assert np.array_equal(codec.decompress_at(cs2, 0.0), codec.decompress_at(cs, 0.0))


def test_base_only_for_loose_eps():
    v = _series(10_000, seed=23)
    codec = ShrinkCodec.from_fraction(v, frac=0.05)
    loose = 10.0  # way above eps_b_practical
    cs = codec.compress(v, eps_targets=[loose])
    assert cs.pyramid.layers[0].mode == "identity"
    assert cs.pyramid.layers[0].payload is None
