"""Negative-path tests for the ``bitpack`` entropy backend (tag id 4),
mirroring ``test_serialize_hardening.py``: truncated payloads, trailing
garbage, a bad width byte, and foreign tag bytes must each raise a typed
:class:`ShrinkError` (a ``ValueError`` subclass) — never a raw
``struct.error`` / ``IndexError``, and never garbage ints."""
import numpy as np
import pytest

from repro.core import entropy
from repro.core.errors import (
    CorruptFrameError,
    FormatError,
    ShrinkError,
    TruncatedArchiveError,
)

_RNG = np.random.default_rng(20250808)


@pytest.fixture(scope="module")
def blob():
    q = np.round(_RNG.standard_normal(1000) * 300).astype(np.int64)
    b = entropy.encode_ints(q, backend="bitpack")
    assert b[0] == entropy._BACKENDS["bitpack"]
    return b


def test_truncated_at_every_boundary(blob):
    """Every strict prefix (empty blob, mid-header, mid-payload) raises a
    typed truncation error."""
    for cut in range(len(blob)):
        with pytest.raises(ShrinkError):
            entropy.decode_ints(blob[:cut])
    # the specific types at the interesting boundaries:
    with pytest.raises(TruncatedArchiveError):
        entropy.decode_ints(b"")  # no tag byte at all
    with pytest.raises(TruncatedArchiveError):
        entropy.decode_ints(blob[:10])  # inside the <qQB> header
    with pytest.raises(TruncatedArchiveError):
        entropy.decode_ints(blob[:-1])  # payload one byte short


def test_trailing_bytes_rejected(blob):
    """count * width pins the exact payload length; any trailing bytes mean
    the stream is not what its header claims."""
    with pytest.raises(CorruptFrameError):
        entropy.decode_ints(blob + b"\x00")
    with pytest.raises(CorruptFrameError):
        entropy.decode_ints(blob + b"\xff" * 9)


def test_trailing_bytes_rejected_width_zero():
    """Width-0 (constant) streams have an empty payload — the length check
    must still fire rather than silently ignoring extra bytes."""
    q = np.full(64, 7, dtype=np.int64)
    b = entropy.encode_ints(q, backend="bitpack")
    assert len(b) == 18
    with pytest.raises(CorruptFrameError):
        entropy.decode_ints(b + b"\x01")


def test_bad_width_byte(blob):
    """The width byte is <= 64 by construction; 65..255 is a format error,
    not an allocation of a 200-bit bit matrix."""
    for bad_width in (65, 100, 255):
        mutated = bytearray(blob)
        mutated[17] = bad_width  # width byte: tag(1) + lo(8) + count(8)
        with pytest.raises(FormatError):
            entropy.decode_ints(bytes(mutated))


def test_foreign_tag_byte(blob):
    """An unknown backend tag raises FormatError instead of KeyError —
    bitpack payloads can never be misparsed as a future backend's."""
    for tag in (5, 17, 255):
        with pytest.raises(FormatError):
            entropy.decode_ints(bytes([tag]) + blob[1:])


def test_corrupt_count_never_garbage(blob):
    """Inflating the count field makes the payload short for the claimed
    stream — a typed truncation error, never a misaligned decode."""
    mutated = bytearray(blob)
    mutated[9:17] = (2**40).to_bytes(8, "little")  # count field
    with pytest.raises(TruncatedArchiveError):
        entropy.decode_ints(bytes(mutated))


def test_all_errors_are_value_errors(blob):
    """Callers that predate the taxonomy catch ValueError; every typed
    error here must still satisfy that contract."""
    for data in (b"", blob[:5], blob + b"\x00", bytes([250]) + blob[1:]):
        with pytest.raises(ValueError):
            entropy.decode_ints(data)
