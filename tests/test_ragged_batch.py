"""Ragged pipeline contract: compress_batch over mixed-length series is
byte-identical to a python loop of compress — across input forms (list vs
padded+lengths), bucket counts (1, default, one-bucket-per-series), eps
regimes, and the edge cases the gateway actually sees (empty series,
length-1 series, orders-of-magnitude spread) — and the RaggedBatcher
admission scheduler seals frames that standard SHRKS consumers decode."""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    ShrinkCodec,
    ShrinkConfig,
    cs_from_bytes,
    cs_to_bytes,
    extract_semantics,
    extract_semantics_batch,
    fluctuation_table,
)
from repro.core.phases import default_interval_length, divide
from repro.core.streaming import decode_range, decode_series, read_knowledge_base
from repro.serving.ragged import RaggedBatcher

_RNG = np.random.default_rng(99)


def _ragged_series(lengths) -> list[np.ndarray]:
    out = []
    for n in lengths:
        v = np.cumsum(_RNG.standard_normal(n) * 0.05) + _RNG.standard_normal(n) * 0.02
        out.append(np.round(v, 4))
    return out


def _codec_for(series, backend="rans") -> tuple[ShrinkCodec, float]:
    allv = np.concatenate([v for v in series if v.size]) if any(
        v.size for v in series
    ) else np.zeros(1)
    rng = max(float(allv.max() - allv.min()), 1e-9)
    return ShrinkCodec(config=ShrinkConfig(eps_b=0.05 * rng, lam=1e-3), backend=backend), rng


# --------------------------------------------------------- ragged cone scan
def test_ragged_scan_matches_single():
    lengths = [1000, 1, 2, 17, 513, 257, 64, 999, 3, 128]
    series = _ragged_series(lengths)
    codec, _ = _codec_for(series)
    t = max(lengths)
    padded = np.zeros((len(series), t))
    for i, v in enumerate(series):
        padded[i, : v.size] = v
    batch = extract_semantics_batch(
        padded, codec.config, chunk=64, lengths=np.array(lengths)
    )
    for i, v in enumerate(series):
        single = extract_semantics(v, codec.config)
        assert [dataclasses.astuple(x) for x in single] == [
            dataclasses.astuple(x) for x in batch[i]
        ], i


def test_ragged_fluctuation_table_matches_divide():
    lengths = [300, 7, 150, 2, 299]
    series = _ragged_series(lengths)
    cfg = ShrinkConfig(eps_b=0.3, lam=1e-3)
    t = max(lengths)
    padded = np.zeros((len(series), t))
    for i, v in enumerate(series):
        padded[i, : v.size] = v
    ns = np.array(lengths)
    dg = np.array([float(v.max() - v.min()) for v in series])
    levels, eps = fluctuation_table(padded, dg, cfg, lengths=ns)
    for i, v in enumerate(series):
        el = default_interval_length(v.size, cfg)
        for tt in range(0, v.size, 5):
            _, lv, eh = divide(v, tt, el, float(dg[i]), cfg)
            assert lv == levels[i, tt], (i, tt)
            assert eh == eps[i, tt], (i, tt)


# --------------------------------------------------------- full pipeline
@pytest.mark.parametrize("backend", ["rans", "best"])
def test_ragged_compress_batch_byte_identical(backend):
    lengths = [0, 1, 2, 17, 513, 257, 64, 1500, 3, 129, 5]
    series = _ragged_series(lengths)
    codec, rng = _codec_for(series, backend=backend)
    # spans base-only, quantized, and lossless regimes
    eps_ts = [0.5 * rng, 1e-2 * rng, 1e-3 * rng, 0.0]
    batch = codec.compress_batch(series, eps_targets=eps_ts, decimals=4)
    for i, v in enumerate(series):
        single = codec.compress(v, eps_targets=eps_ts, decimals=4)
        assert cs_to_bytes(batch[i]) == cs_to_bytes(single), (i, lengths[i])


def test_ragged_padded_lengths_input_equivalent():
    lengths = [40, 3, 120, 1, 77]
    series = _ragged_series(lengths)
    codec, rng = _codec_for(series)
    t = max(lengths)
    padded = np.zeros((len(series), t))
    for i, v in enumerate(series):
        padded[i, : v.size] = v
    a = codec.compress_batch(series, eps_targets=[1e-2 * rng, 0.0], decimals=4)
    b = codec.compress_batch(
        padded, eps_targets=[1e-2 * rng, 0.0], decimals=4, lengths=np.array(lengths)
    )
    assert [cs_to_bytes(x) for x in a] == [cs_to_bytes(x) for x in b]


def test_ragged_bucketing_invariance():
    """Output must not depend on the bucket count — including the
    pathological one-bucket-per-series spread and a single shared bucket."""
    lengths = [2048, 4, 512, 33, 1, 900, 65, 7]
    series = _ragged_series(lengths)
    codec, rng = _codec_for(series)
    eps_ts = [1e-2 * rng, 0.0]
    want = [
        cs_to_bytes(codec.compress(v, eps_targets=eps_ts, decimals=4)) for v in series
    ]
    for buckets in (1, 3, len(series), 2 * len(series)):
        got = codec.compress_batch(
            series, eps_targets=eps_ts, decimals=4, max_buckets=buckets
        )
        assert [cs_to_bytes(x) for x in got] == want, buckets


def test_ragged_equal_length_list_hits_rect_path():
    series = _ragged_series([256, 256, 256])
    codec, rng = _codec_for(series)
    a = codec.compress_batch(series, eps_targets=[1e-2 * rng])
    b = codec.compress_batch(np.stack(series), eps_targets=[1e-2 * rng])
    assert [cs_to_bytes(x) for x in a] == [cs_to_bytes(x) for x in b]


def test_ragged_roundtrip_guarantees():
    lengths = [700, 1, 90, 2, 350]
    series = _ragged_series(lengths)
    codec, rng = _codec_for(series)
    eps = 1e-3 * rng
    batch = codec.compress_batch(series, eps_targets=[eps, 0.0], decimals=4)
    for i, v in enumerate(series):
        cs = cs_from_bytes(cs_to_bytes(batch[i]))  # survive the container
        vhat = codec.decompress_at(cs, eps)
        bound = batch[i].eps_b_practical if batch[i].pyramid.layers[0].mode == "identity" else eps
        if v.size:
            assert np.max(np.abs(vhat - v)) <= bound * (1 + 1e-9) + 1e-12
        np.testing.assert_array_equal(np.round(codec.decompress_at(cs, 0.0), 4), v)


def test_ragged_compress_batch_pallas_route_runs():
    """The kernel route (interpret mode on CPU) on ragged lanes: float32 on
    device so bytes may differ from numpy, but every codec guarantee must
    hold at every length."""
    lengths = [513, 1, 64, 300, 2]
    series = _ragged_series(lengths)
    codec, rng = _codec_for(series)
    eps = 1e-2 * rng
    batch = codec.compress_batch(series, eps_targets=[eps], semantics="pallas")
    for i, v in enumerate(series):
        vhat = codec.decompress_at(batch[i], eps)
        bound = batch[i].eps_b_practical if batch[i].pyramid.layers[0].mode == "identity" else eps
        assert np.max(np.abs(vhat - v)) <= bound * (1 + 1e-6) + 1e-9, i


def test_ragged_compress_batch_validates_input():
    codec = ShrinkCodec(config=ShrinkConfig(eps_b=1.0))
    with pytest.raises(ValueError):  # lengths alongside a ragged list
        codec.compress_batch([np.zeros(4)], eps_targets=[0.1], lengths=np.array([4]))
    with pytest.raises(ValueError):  # lengths shape mismatch
        codec.compress_batch(np.zeros((2, 8)), eps_targets=[0.1], lengths=np.array([8]))
    with pytest.raises(ValueError):  # length out of range
        codec.compress_batch(
            np.zeros((2, 8)), eps_targets=[0.1], lengths=np.array([4, 9])
        )
    with pytest.raises(ValueError):  # lossless needs decimals (ragged path)
        codec.compress_batch(
            [np.zeros(4), np.zeros(7)], eps_targets=[0.0]
        )
    with pytest.raises(ValueError):  # bucket count
        codec.compress_batch(
            [np.zeros(4), np.zeros(7)], eps_targets=[0.1], max_buckets=0
        )


def test_empty_batch_and_all_empty_series():
    codec = ShrinkCodec(config=ShrinkConfig(eps_b=1.0), backend="rans")
    assert codec.compress_batch([], eps_targets=[0.1]) == []
    batch = codec.compress_batch(
        [np.zeros(0), np.zeros(0)], eps_targets=[0.1, 0.0], decimals=4
    )
    for cs in batch:
        assert cs.base.n == 0
        assert cs_to_bytes(cs) == cs_to_bytes(
            codec.compress(np.zeros(0), eps_targets=[0.1, 0.0], decimals=4)
        )
        assert codec.decompress_at(cs, 0.0).size == 0


# --------------------------------------------------------- RaggedBatcher
class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _cfg_for_batcher(series) -> ShrinkConfig:
    allv = np.concatenate([v for v in series if v.size])
    return ShrinkConfig(eps_b=0.05 * float(allv.max() - allv.min()), lam=1e-3)


def test_batcher_size_trigger_and_decode():
    lengths = [400, 37, 1200, 5, 800, 64]
    series = _ragged_series(lengths)
    cfg = _cfg_for_batcher(series)
    b = RaggedBatcher(cfg, eps_targets=[0.0], decimals=4, flush_samples=1000)
    sealed = []
    for c0 in range(0, max(lengths), 100):  # interleaved chunk arrivals
        for sid, v in enumerate(series):
            sealed += b.submit(sid, v[c0 : c0 + 100])
    blob = b.finalize()
    assert b.stats()["flushes"] >= 2  # the size trigger actually fired
    for sid, v in enumerate(series):
        np.testing.assert_array_equal(np.round(decode_series(blob, sid, 0.0), 4), v)
        mid = max(1, v.size // 2)
        np.testing.assert_array_equal(
            np.round(decode_range(blob, sid, 0, mid, 0.0), 4), v[:mid]
        )
    # frames are contiguous per series
    spans: dict[int, int] = {}
    for sid, lo, hi in b.sealed_frames:
        assert lo == spans.get(sid, 0)
        spans[sid] = hi
    assert spans == {sid: v.size for sid, v in enumerate(series)}
    kb = read_knowledge_base(blob)
    assert kb is not None and kb.stats()["entries"] > 0


def test_batcher_deadline_trigger():
    clock = _FakeClock()
    cfg = ShrinkConfig(eps_b=0.5, lam=1e-3)
    b = RaggedBatcher(
        cfg, eps_targets=[1e-2], flush_samples=None, flush_deadline_s=5.0, clock=clock
    )
    v = np.round(np.cumsum(_RNG.standard_normal(50) * 0.1), 4)
    assert b.submit(0, v) == []
    clock.t = 4.9
    assert b.poll() == []  # deadline not reached
    clock.t = 5.1
    sealed = b.poll()
    assert sealed == [(0, 0, 50)]
    assert b.poll() == []  # nothing pending anymore
    # deadline restarts from the next submit, not the old epoch
    clock.t = 100.0
    assert b.submit(0, v[:10]) == []
    clock.t = 104.9
    assert b.poll() == []
    clock.t = 105.0
    assert b.poll() == [(0, 50, 60)]


def test_batcher_frames_match_stream_codec_deferred_seal():
    """A RaggedBatcher frame must be byte-identical to what the deferred-scan
    ShrinkStreamCodec seals for the same buffer (both reduce to one-shot
    compress of the window) — the two ingest paths share one wire format."""
    from repro.core import ShrinkStreamCodec
    from repro.core.serialize import frame_payload, parse_framed_container

    v = np.round(np.cumsum(_RNG.standard_normal(333) * 0.05), 4)
    cfg = ShrinkConfig(eps_b=0.05 * float(v.max() - v.min()), lam=1e-3)

    b = RaggedBatcher(cfg, eps_targets=[0.0], decimals=4, flush_samples=None)
    b.submit(7, v)
    blob_b = b.finalize()
    sc = ShrinkStreamCodec(cfg, eps_targets=[0.0], decimals=4, backend="rans")
    sc.ingest(v, series_id=7)
    blob_s = sc.finalize()
    pb, _ = parse_framed_container(blob_b)
    ps, _ = parse_framed_container(blob_s)
    assert frame_payload(blob_b, pb[0]) == frame_payload(blob_s, ps[0])


def test_batcher_shares_knowledge_base():
    series = _ragged_series([300, 200])
    cfg = _cfg_for_batcher(series)
    from repro.core.streaming import KnowledgeBase

    kb = KnowledgeBase(cfg)
    b1 = RaggedBatcher(cfg, eps_targets=[1e-2], kb=kb, flush_samples=None)
    b2 = RaggedBatcher(cfg, eps_targets=[1e-2], kb=kb, flush_samples=None)
    b1.submit(0, series[0])
    b1.flush()
    entries_after_first = kb.stats()["entries"]
    b2.submit(0, series[0])  # identical data -> identical lines -> dedup
    b2.flush()
    assert kb.stats()["entries"] == entries_after_first
    assert kb.stats()["total_refs"] >= 2 * entries_after_first


def test_batcher_rejects_use_after_finalize():
    cfg = ShrinkConfig(eps_b=0.5)
    b = RaggedBatcher(cfg, eps_targets=[1e-2])
    b.submit(0, np.ones(4))
    b.finalize()
    with pytest.raises(ValueError):
        b.submit(0, np.ones(4))
    with pytest.raises(ValueError):
        RaggedBatcher(cfg, eps_targets=[0.0])  # lossless without decimals


def test_flush_after_finalize_is_noop():
    """Regression: a ``flush_deadline_s`` timer that fires after
    ``finalize`` (the race window of any real deployment, where the timer
    loop and the shutdown path interleave) must be a no-op — it used to
    reach the sealed writer and double-seal the pending pool."""
    clock = _FakeClock()
    cfg = ShrinkConfig(eps_b=0.5, lam=1e-3)
    b = RaggedBatcher(
        cfg, eps_targets=[1e-2], flush_samples=None, flush_deadline_s=5.0, clock=clock
    )
    v = np.round(np.cumsum(_RNG.standard_normal(64) * 0.1), 4)
    b.submit(0, v)
    blob = b.finalize()
    frames = list(b.sealed_frames)
    clock.t = 100.0  # the deadline is long past due when the timer fires
    assert b.due() is False
    assert b.due_series() == []
    assert b.poll() == []
    assert b.flush() == []
    assert b.sealed_frames == frames  # nothing double-sealed
    assert b.finalize() == blob  # container unchanged


def test_reentrant_flush_during_compression_cannot_double_seal():
    """Regression for the deadline/finalize double-seal: a flush trigger
    firing *while a flush is compressing* (timer thread, or anything the
    compression path calls back into) must find an empty pending pool —
    the buffers are detached before compression starts."""
    clock = _FakeClock()
    cfg = ShrinkConfig(eps_b=0.5, lam=1e-3)
    b = RaggedBatcher(
        cfg, eps_targets=[1e-2], flush_samples=None, flush_deadline_s=5.0, clock=clock
    )
    v = np.round(np.cumsum(_RNG.standard_normal(80) * 0.1), 4)
    b.submit(0, v)
    clock.t = 10.0  # deadline fired; the poll below starts the flush

    inner: dict = {"polls": [], "finalized_inside": False}
    real = b.codec.compress_batch

    def reentrant(arrs, **kw):
        # a concurrent timer tick AND a concurrent shutdown, mid-flush
        inner["polls"].append(b.poll())
        inner["flush"] = b.flush()
        return real(arrs, **kw)

    b.codec.compress_batch = reentrant
    sealed = b.poll()
    b.codec.compress_batch = real

    assert sealed == [(0, 0, 80)]
    assert inner["polls"] == [[]] and inner["flush"] == []  # reentrants no-op
    assert b.sealed_frames == [(0, 0, 80)]  # exactly once
    blob = b.finalize()
    got = decode_range(blob, 0, 0, 80, 1e-2)
    assert float(np.abs(got - v).max()) <= 1e-2 * (1 + 1e-9)


def test_scope_series_flush_isolation():
    """Under ``scope="series"`` a series' flush trigger is a pure function
    of its OWN ingest history — co-batched series neither trigger it nor
    get dragged into its frames early (the property that makes fleet
    sharding byte-invariant; see tests/test_fleet.py)."""
    series = _ragged_series([100, 100])
    cfg = _cfg_for_batcher(series)
    # batch scope: the aggregate pool (32+32 >= 64) seals BOTH series,
    # even though neither alone reached the threshold
    b = RaggedBatcher(cfg, eps_targets=[1e-2], flush_samples=64, scope="batch")
    assert b.submit(0, series[0][:32]) == []
    assert {s for s, _, _ in b.submit(1, series[1][:32])} == {0, 1}
    # series scope: each series seals alone, exactly when ITS 64 arrive
    s = RaggedBatcher(cfg, eps_targets=[1e-2], flush_samples=64, scope="series")
    assert s.submit(0, series[0][:32]) == []
    assert s.submit(1, series[1][:64]) == [(1, 0, 64)]  # 0 untouched
    assert s.submit(0, series[0][32:64]) == [(0, 0, 64)]
    with pytest.raises(ValueError):
        RaggedBatcher(cfg, eps_targets=[1e-2], scope="frame")  # unknown scope
