"""HLO cost-model units: the while-trip correction (the reason this module
exists), dot-flop accounting, collective byte counting."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze_hlo, compiled_cost_dict


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_cost_analysis_undercounts_scans_and_we_fix_it():
    """jax's compiled.cost_analysis() counts while bodies once — verify the
    defect exists and analyze_hlo corrects it by the trip count."""
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)

    def scanned(x, ws):
        def body(c, w):
            return c @ w, 0
        c, _ = jax.lax.scan(body, x, ws)
        return c

    compiled = jax.jit(scanned).lower(x, ws).compile()
    raw = compiled_cost_dict(compiled)["flops"]
    fixed = analyze_hlo(compiled.as_text()).flops
    one_matmul = 2 * 256**3
    assert raw < 2 * one_matmul, "cost_analysis now loop-corrects; update docs"
    assert abs(fixed - 10 * one_matmul) / (10 * one_matmul) < 0.05


def test_dot_flops_plain():
    a = jax.ShapeDtypeStruct((128, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 64), jnp.float32)
    hlo = _compile_text(lambda a, b: a @ b, a, b)
    cost = analyze_hlo(hlo)
    assert abs(cost.flops - 2 * 128 * 512 * 64) / (2 * 128 * 512 * 64) < 0.01
    assert cost.dot_count >= 1


def test_nested_scan_multiplies():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def nested(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ ci, 0
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, 0
        c, _ = jax.lax.scan(outer, x, None, length=5)
        return c

    cost = analyze_hlo(_compile_text(nested, x))
    expect = 15 * 2 * 64**3  # 5 * 3 matmuls
    assert abs(cost.flops - expect) / expect < 0.05


def test_bytes_positive_and_scaled_by_trip():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def f1(x):
        return jnp.tanh(x) * 2 + 1

    def f10(x):
        def body(c, _):
            return jnp.tanh(c) * 2 + 1, 0
        c, _ = jax.lax.scan(body, x, None, length=10)
        return c

    b1 = analyze_hlo(_compile_text(f1, x)).bytes
    b10 = analyze_hlo(_compile_text(f10, x)).bytes
    assert b1 > 0 and b10 > 5 * b1


def test_collective_bytes_zero_single_device():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    cost = analyze_hlo(_compile_text(lambda x: x + 1, x))
    assert cost.collective_bytes == 0
